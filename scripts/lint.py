#!/usr/bin/env python
"""repro-lint CLI: run the AST static-analysis suite (DESIGN.md §18).

Usage:
  python scripts/lint.py                     # lint src/ + examples/
  python scripts/lint.py src/repro/sim       # lint a subtree
  python scripts/lint.py --rules sim-determinism,dma-pairing
  python scripts/lint.py --ci --json /tmp/lint.json
  python scripts/lint.py --list-rules

Exit code 0 when clean, 1 when any finding survives suppressions.
Suppress a finding inline with ``# lint: disable=<rule> -- why`` on
(or on the comment line above) the flagged line.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import (  # noqa: E402
    ALL_RULES, Analyzer, render_human, to_json,
)

DEFAULT_PATHS = ("src", "examples")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files or directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--rules", default="",
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--json", metavar="PATH", default="",
                    help="also write machine-readable findings to PATH")
    ap.add_argument("--ci", action="store_true",
                    help="CI mode: summary line with timing")
    ap.add_argument("--list-rules", action="store_true",
                    help="print rule ids and one-line docs, then exit")
    args = ap.parse_args(argv)

    rules = [cls() for cls in ALL_RULES]
    if args.list_rules:
        for r in rules:
            doc = (type(r).__module__ or "").rsplit(".", 1)[-1]
            head = (sys.modules[type(r).__module__].__doc__ or doc)
            head = head.strip().splitlines()[0]
            print(f"{r.name:<18} {head}")
        return 0
    if args.rules:
        wanted = {s.strip() for s in args.rules.split(",") if s.strip()}
        known = {r.name for r in rules}
        unknown = wanted - known
        if unknown:
            ap.error(f"unknown rule(s): {sorted(unknown)} "
                     f"(known: {sorted(known)})")
        rules = [r for r in rules if r.name in wanted]

    t0 = time.perf_counter()
    analyzer = Analyzer(rules, ROOT)
    ctxs = analyzer.load(args.paths)
    findings = analyzer.run(ctxs)
    dt = time.perf_counter() - t0

    if args.json:
        pathlib.Path(args.json).write_text(
            to_json(findings, rules=[r.name for r in rules]) + "\n"
        )
    if findings:
        print(render_human(findings))
    if args.ci or not findings:
        print(f"repro-lint: {len(ctxs)} files, "
              f"{len(rules)} rules, {len(findings)} finding(s) "
              f"in {dt:.2f}s")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
