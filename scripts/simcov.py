#!/usr/bin/env python
"""Per-package statement-coverage floors for the repro codebase.

CI gates each package in ``GATES`` on a minimum statement coverage
from its own test modules: the fleet layer (DESIGN.md §16), the fault
layer (DESIGN.md §19) and the repro-lint analysis suite (DESIGN.md
§18) at 90%, the shot-batched stencil engine + FWI solver (DESIGN.md
§17) at 85%.  When ``pytest-cov`` is installed this delegates to
``pytest --cov=<pkg> --cov-fail-under``; otherwise (the default
container has no coverage tooling) it falls back to the stdlib
``trace`` module: run the gate's test modules under a line tracer,
intersect the executed lines with each module's executable lines, and
enforce the same floor.  Each test set is traced in a FRESH subprocess
(one gate's imports and jit-compile caches must not leak into the next
gate's tracer — see ``_traced_lines``), and traced runs are cached per
test set, so gates that share tests pay the (10-30x slower under
trace) run once.

Usage:  PYTHONPATH=src python scripts/simcov.py [--only PKG[,PKG...]]
"""
from __future__ import annotations

import argparse
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

#: (dotted target, floor %, test modules).  A target may be a package
#: directory or a single module; tests are chosen fast-but-relevant —
#: jax-heavy suites run 10-30x slower under ``trace``, so each gate
#: lists the smallest set that genuinely exercises its target.
GATES = [
    ("repro.sim", 90.0,
     ("tests/test_fleet.py", "tests/test_fleet_properties.py",
      "tests/test_faults.py")),
    # identical test tuple -> shares the repro.sim traced run
    ("repro.sim.faults", 90.0,
     ("tests/test_fleet.py", "tests/test_fleet_properties.py",
      "tests/test_faults.py")),
    ("repro.kernels.stencil", 85.0,
     ("tests/test_kernels.py", "tests/test_shot_batch.py",
      "tests/test_streamed_kernel.py", "tests/test_fwi.py",
      "tests/test_fused_engine.py")),
    ("repro.fwi.solver", 85.0,
     ("tests/test_kernels.py", "tests/test_shot_batch.py",
      "tests/test_streamed_kernel.py", "tests/test_fwi.py",
      "tests/test_fused_engine.py")),
    ("repro.analysis", 90.0, ("tests/test_lint.py",)),
]


def _target_files(dotted: str) -> list[pathlib.Path]:
    """Source files a dotted target covers (package dir or module)."""
    base = ROOT / "src" / pathlib.Path(*dotted.split("."))
    if base.is_dir():
        return sorted(base.glob("*.py"))
    mod = base.with_suffix(".py")
    if mod.is_file():
        return [mod]
    raise SystemExit(f"simcov: no such target {dotted!r} ({base})")


def _have_pytest_cov() -> bool:
    try:
        import pytest_cov  # noqa: F401
    except ModuleNotFoundError:
        return False
    return True


def _run_with_pytest_cov(gates) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    rc = 0
    for dotted, floor, tests in gates:
        cmd = [
            sys.executable, "-m", "pytest", "-q",
            f"--cov={dotted}", f"--cov-fail-under={floor:g}", *tests,
        ]
        rc = subprocess.call(cmd, cwd=ROOT, env=env) or rc
    return rc


def _traced_lines(tests: tuple[str, ...],
                  _cache: dict = {}) -> dict[str, set[int]]:
    """Executed lines per absolute filename for one traced test run.

    The run happens in a FRESH subprocess (``--trace-json`` child
    mode).  Tracing in-process would let one gate's run poison the
    next: modules already in ``sys.modules`` never re-execute their
    top level under the later tracer, and jax functions compiled by an
    earlier gate's tests are cache hits whose tracing the tracer never
    sees — e.g. a jax-importing test in the ``repro.sim`` gate would
    silently deflate the stencil/solver gates by ~15-25 points.
    """
    if tests in _cache:
        return _cache[tests]
    import json
    import tempfile

    fd, out = tempfile.mkstemp(suffix=".json", prefix="simcov-")
    os.close(fd)
    try:
        env = dict(os.environ)
        env["PYTHONPATH"] = str(ROOT / "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        rc = subprocess.call(
            [sys.executable, os.path.abspath(__file__),
             "--trace-json", out, *tests],
            cwd=ROOT, env=env,
        )
        if rc != 0:
            raise SystemExit(f"simcov: test run failed (exit {rc}): {tests}")
        with open(out) as fh:
            raw = json.load(fh)
    finally:
        os.unlink(out)
    executed = {fn: set(lines) for fn, lines in raw.items()}
    _cache[tests] = executed
    return executed


def _trace_json(out: str, tests: list[str]) -> int:
    """Child mode: run ``tests`` under a line tracer, dump hit lines."""
    import json
    import trace

    import pytest

    os.chdir(ROOT)
    sys.path.insert(0, str(ROOT / "src"))
    # NB: no ignoredirs — trace._Ignore caches decisions by bare module
    # name, so ignoring stdlib ``queue.py``/``__init__.py`` would also
    # silently ignore repro/sim/queue.py and repro/sim/__init__.py
    tracer = trace.Trace(count=1, trace=0)
    rc = tracer.runfunc(
        pytest.main, ["-q", "-p", "no:cacheprovider", *tests]
    )
    if rc != 0:
        return int(rc)
    executed: dict[str, list[int]] = {}
    for (fn, lineno), cnt in tracer.results().counts.items():
        if cnt > 0:
            executed.setdefault(os.path.abspath(fn), []).append(lineno)
    with open(out, "w") as fh:
        json.dump(executed, fh)
    return 0


def _run_with_trace(gates) -> int:
    import trace

    failed = []
    for dotted, floor, tests in gates:
        executed = _traced_lines(tests)
        tot_hit = tot_exec = 0
        print(f"-- {dotted} (floor {floor:g}%, tests: "
              f"{', '.join(t.rsplit('/', 1)[-1] for t in tests)})")
        print(f"{'module':<28}{'stmts':>7}{'hit':>7}{'cover':>8}")
        for py in _target_files(dotted):
            fn = str(py.resolve())
            # executable line numbers straight from the code objects —
            # the same analysis `trace --count --missing` reports on
            lnos = set(trace._find_executable_linenos(fn))
            hit = executed.get(fn, set()) & lnos
            pct = 100.0 * len(hit) / len(lnos) if lnos else 100.0
            tot_hit += len(hit)
            tot_exec += len(lnos)
            print(f"{py.name:<28}{len(lnos):>7}{len(hit):>7}{pct:>7.1f}%")
        pct = 100.0 * tot_hit / tot_exec if tot_exec else 100.0
        print(f"{'TOTAL':<28}{tot_exec:>7}{tot_hit:>7}{pct:>7.1f}%")
        if pct < floor:
            failed.append((dotted, pct, floor))
            print(f"simcov: {dotted} coverage {pct:.1f}% is below the "
                  f"{floor:g}% floor", file=sys.stderr)
        else:
            print(f"simcov OK: {dotted} {pct:.1f}% >= {floor:g}% floor")
    return 1 if failed else 0


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if argv[:1] == ["--trace-json"]:  # internal child mode
        return _trace_json(argv[1], argv[2:])
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help="comma-separated dotted targets to gate")
    args = ap.parse_args(argv)
    only = {s for s in args.only.split(",") if s}
    gates = [g for g in GATES if not only or g[0] in only]
    unknown = only - {g[0] for g in gates}
    if unknown:
        ap.error(f"unknown target(s): {sorted(unknown)}")
    if _have_pytest_cov():
        return _run_with_pytest_cov(gates)
    return _run_with_trace(gates)


if __name__ == "__main__":
    sys.exit(main())
