#!/usr/bin/env python
"""Statement-coverage floor for the ``repro.sim`` package.

CI gates the fleet layer (DESIGN.md §16) on a minimum statement
coverage from its own test modules.  When ``pytest-cov`` is installed
this delegates to ``pytest --cov=repro.sim --cov-fail-under``;
otherwise (the default container has no coverage tooling) it falls
back to the stdlib ``trace`` module: run the fleet test modules under
a line tracer, intersect the executed lines with each sim module's
executable lines, and enforce the same floor.

Usage:  PYTHONPATH=src python scripts/simcov.py [--floor PCT]
"""
from __future__ import annotations

import argparse
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
SIM_DIR = ROOT / "src" / "repro" / "sim"
#: fleet-layer test modules — fast, pure-Python, exercise repro.sim
TESTS = ["tests/test_fleet.py", "tests/test_fleet_properties.py"]
DEFAULT_FLOOR = 90.0


def _have_pytest_cov() -> bool:
    try:
        import pytest_cov  # noqa: F401
    except ModuleNotFoundError:
        return False
    return True


def _run_with_pytest_cov(floor: float) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    cmd = [
        sys.executable, "-m", "pytest", "-q",
        "--cov=repro.sim", f"--cov-fail-under={floor:g}", *TESTS,
    ]
    return subprocess.call(cmd, cwd=ROOT, env=env)


def _run_with_trace(floor: float) -> int:
    import trace

    import pytest

    os.chdir(ROOT)
    sys.path.insert(0, str(ROOT / "src"))
    # NB: no ignoredirs — trace._Ignore caches decisions by bare module
    # name, so ignoring stdlib ``queue.py``/``__init__.py`` would also
    # silently ignore repro/sim/queue.py and repro/sim/__init__.py
    tracer = trace.Trace(count=1, trace=0)
    rc = tracer.runfunc(
        pytest.main, ["-q", "-p", "no:cacheprovider", *TESTS]
    )
    if rc not in (0,):
        print(f"simcov: test run failed (exit {rc})", file=sys.stderr)
        return int(rc)

    executed: dict[str, set[int]] = {}
    for (fn, lineno), cnt in tracer.results().counts.items():
        if cnt > 0:
            executed.setdefault(os.path.abspath(fn), set()).add(lineno)

    tot_hit = tot_exec = 0
    print(f"{'module':<28}{'stmts':>7}{'hit':>7}{'cover':>8}")
    for py in sorted(SIM_DIR.glob("*.py")):
        fn = str(py.resolve())
        # executable line numbers straight from the code objects — the
        # same analysis `trace --count --missing` reports against
        lnos = set(trace._find_executable_linenos(fn))
        hit = executed.get(fn, set()) & lnos
        pct = 100.0 * len(hit) / len(lnos) if lnos else 100.0
        tot_hit += len(hit)
        tot_exec += len(lnos)
        print(f"{py.name:<28}{len(lnos):>7}{len(hit):>7}{pct:>7.1f}%")
    total_pct = 100.0 * tot_hit / tot_exec if tot_exec else 100.0
    print(f"{'TOTAL':<28}{tot_exec:>7}{tot_hit:>7}{total_pct:>7.1f}%")
    if total_pct < floor:
        print(
            f"simcov: repro.sim coverage {total_pct:.1f}% is below the "
            f"{floor:g}% floor", file=sys.stderr,
        )
        return 1
    print(f"simcov OK: repro.sim {total_pct:.1f}% >= {floor:g}% floor")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--floor", type=float, default=DEFAULT_FLOOR)
    args = ap.parse_args(argv)
    if _have_pytest_cov():
        return _run_with_pytest_cov(args.floor)
    return _run_with_trace(args.floor)


if __name__ == "__main__":
    sys.exit(main())
