#!/usr/bin/env bash
# Tier-1 verification + benchmark smoke.
#
# 1. the repo's tier-1 test command (ROADMAP.md): full pytest, -x -q
# 2. benchmark smoke: the fused-scan engine rows (steps/sec for
#    loop-vs-scan, temporal blocking) and the §3.3 overhead rows must
#    produce output without raising — this catches engine regressions
#    that unit tests (which run tiny grids) would miss.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke =="
python - <<'EOF'
import sys
sys.path.insert(0, ".")
from benchmarks import bench_fused_scan, bench_overheads

rows = bench_overheads.run() + bench_fused_scan.run()
for r in rows:
    print(r)

speedup = next(
    float(r.rsplit(",", 1)[1]) for r in rows
    if r.startswith("fused_scan.speedup_x")
)
print(f"scan-fused speedup over seed loop: {speedup:.2f}x")
assert speedup > 1.0, "scan-fused engine slower than per-step loop"
EOF
echo "CI OK"
