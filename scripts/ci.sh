#!/usr/bin/env bash
# Tier-1 verification + benchmark smoke + docs consistency.
#
# 1. the repo's tier-1 test command (ROADMAP.md): full pytest, -x -q
# 2. benchmark smoke: the fused-scan engine rows (steps/sec for
#    loop-vs-scan, temporal blocking) and the §3.3 overhead rows must
#    produce output without raising — this catches engine regressions
#    that unit tests (which run tiny grids) would miss.
# 3. fleet smoke: the autoscaler policy × scenario sweep must uphold
#    the paper's claim at fleet scale — the deadline-aware policy beats
#    no-burst on hit-rate in the overload scenario at lower cost than
#    always-burst, and retires the cloud pod once a spike clears.
# 4. docs consistency: every `DESIGN.md §N` cited under src/ or
#    examples/ must resolve to a real section heading in DESIGN.md.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke =="
python - <<'EOF'
import sys
sys.path.insert(0, ".")
from benchmarks import bench_fused_scan, bench_overheads

rows = bench_overheads.run() + bench_fused_scan.run()
for r in rows:
    print(r)

speedup = next(
    float(r.rsplit(",", 1)[1]) for r in rows
    if r.startswith("fused_scan.speedup_x")
)
print(f"scan-fused speedup over seed loop: {speedup:.2f}x")
assert speedup > 1.0, "scan-fused engine slower than per-step loop"
EOF

echo "== fleet smoke =="
python - <<'EOF'
import sys
sys.path.insert(0, ".")
from benchmarks import bench_fleet_scenarios

rows = bench_fleet_scenarios.run()
for r in rows:
    print(r)

def derived(name):
    return next(
        r.rsplit(",", 1)[1] for r in rows if r.startswith(name)
    )

assert derived("fleet.overload_plan_beats_noburst") == "1", \
    "deadline-aware policy must beat no-burst on the overload scenario"
assert derived("fleet.overload_plan_cheaper_than_always") == "1", \
    "deadline-aware policy must undercut always-burst on cloud cost"
assert derived("fleet.spike_cloud_retired_at_end") == "1", \
    "cloud pod must be retired once the transient spike clears"
EOF

echo "== docs consistency =="
python - <<'EOF'
import pathlib
import re
import sys

design = pathlib.Path("DESIGN.md").read_text()
sections = set(re.findall(r"^#+\s+§([\w.-]+)", design, re.M))
cite_re = re.compile(r"DESIGN\.md\s+((?:§[\w.-]+)(?:,\s*§[\w.-]+)*)")
dangling = {}
files = sorted(
    list(pathlib.Path("src").rglob("*.py"))
    + list(pathlib.Path("examples").rglob("*.py"))
)
n_cites = 0
for p in files:
    for m in cite_re.finditer(p.read_text()):
        for tok in re.findall(r"§([\w.-]+)", m.group(1)):
            n_cites += 1
            if tok not in sections:
                dangling.setdefault(tok, []).append(str(p))
print(f"DESIGN.md sections: {sorted(sections, key=str)}")
print(f"citations checked: {n_cites}")
if dangling:
    for tok, where in sorted(dangling.items()):
        print(f"DANGLING: DESIGN.md §{tok} cited in {', '.join(where)}")
    sys.exit(1)
print("docs consistency OK")
EOF
echo "CI OK"
