#!/usr/bin/env bash
# Lint + tier-1 verification + engine/benchmark smokes.
#
# 1. the repo's tier-1 test command (ROADMAP.md): full pytest, -x -q
# 2. fused-engine smoke: the k=4 fused block (interpret-mode Pallas AND
#    the pure-XLA block body) must match the per-step reference on a
#    tiny config — a fast end-to-end equivalence gate for the engine.
# 3. bench-schema smoke: `benchmarks/run.py --json` on a cheap bench
#    subset must produce the machine-readable schema (bench schema
#    breakage fails CI before it breaks the perf-trajectory tooling).
# 4. benchmark smoke: the fused-scan engine rows (steps/sec for
#    loop-vs-scan, fused block, sharded variants) and the §3.3 overhead
#    rows must produce output without raising — this catches engine
#    regressions that unit tests (which run tiny grids) would miss.
# 5. fleet smoke: the autoscaler policy × scenario sweep must uphold
#    the paper's claim at fleet scale — the deadline-aware policy beats
#    no-burst on hit-rate in the overload scenario at lower cost than
#    always-burst, and retires the cloud pod once a spike clears.
# 5b. fleet-tournament smoke: the policy × scheduler × scenario grid
#    of the multi-tenant queue layer (DESIGN.md §16) must uphold the
#    §3.3 claim at fleet scale — some deadline-aware (scheduler,
#    policy) cell beats FIFO+no-burst on hit-rate while spending less
#    than FIFO+always-burst on the overload scenario — and conserve
#    every queued job.
# 5c. coverage floors: per-package statement-coverage gates from each
#    package's own test modules (pytest-cov when installed, stdlib
#    `trace` fallback otherwise — scripts/simcov.py): repro.sim >=90%,
#    repro.sim.faults >=90%, repro.kernels.stencil and repro.fwi.solver
#    >=85% (DESIGN.md §17).
# 5d. fault-storm smoke (DESIGN.md §19): the hardened `plan` loop must
#    keep its hit-rate >= the unhardened baseline under the SAME fault
#    draws at bounded cost (<=1.5x a fault-free run), the fault run
#    must be bit-deterministic per seed, and scavenger preemption must
#    admit the expired weighted job within one evaluation interval —
#    the acceptance rows also ride the bench-schema gate (faults bench).
# 6. real-elastic smoke: a small FWI config driven by the `react`
#    policy through the real orchestrator (2 host devices) must apply
#    at least one GROW and one RETIRE through real re-striping and keep
#    the final wavefield equal to an unscaled reference run — the
#    checkpoint/remesh/reshard invariance gate for the real-session
#    elastic loop (DESIGN.md §14).  The sim-vs-real bench rows
#    (cost-aware beats cost-blind at equal hit-rate) are asserted via
#    the bench-schema smoke, which also registers the new bench.
# 7. big-grid streaming smoke: a 2048² k=4 block through the STREAMED
#    Pallas kernel (interpret mode — real BlockSpec/DMA semantics)
#    under a forced small VMEM budget must be genuinely multi-strip
#    (no whole-height fallback) and match the XLA reference; the strips
#    mirror must stay BITWISE (DESIGN.md §15).
# 8. trajectory schema: the committed BENCH_fwi.json must carry the
#    production-scale tier point with BOTH big grid configs, the VMEM
#    capacity bookkeeping, and the recorded schedule_auto choice — AND
#    the shot-batch tier point (DESIGN.md §17) with a batched-vs-
#    vmapped Pallas ratio > 1, in-budget s-aware VMEM bookkeeping, and
#    the batched traffic model beating the vmapped one.
# 9. lint (runs FIRST, before the test tiers): scripts/lint.py --ci —
#    the repro-lint static-analysis suite (DESIGN.md §18): vmem-budget,
#    dma-pairing, sim-determinism, tracer-hygiene, design-citations
#    (the latter subsumes the old docs-consistency grep gate).  The
#    repo must lint clean, the JSON report must carry all five rules,
#    and the stage must finish in under 10 s.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# Persistent XLA compilation cache: every python step below re-lowers
# the same executables; a workspace-local disk cache turns the repeat
# compiles into loads (benchmarks/run.py prints the hit/miss counts).
export JAX_COMPILATION_CACHE_DIR="${JAX_COMPILATION_CACHE_DIR:-$PWD/.jax_cache}"
export JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS=0
export JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES=-1

echo "== lint =="
LINT_T0=$SECONDS
python scripts/lint.py --ci --json /tmp/lint_ci.json
LINT_ELAPSED=$((SECONDS - LINT_T0))
python - <<'EOF'
import json

doc = json.load(open("/tmp/lint_ci.json"))
assert doc["version"] == 1, doc
assert doc["count"] == 0 and doc["findings"] == [], doc["findings"]
assert set(doc["rules"]) == {
    "vmem-budget", "dma-pairing", "sim-determinism",
    "tracer-hygiene", "design-citations",
}, doc["rules"]
print("lint json schema OK (5 rules, 0 findings)")
EOF
if [ "$LINT_ELAPSED" -ge 10 ]; then
    echo "lint stage took ${LINT_ELAPSED}s (budget: <10s)" >&2
    exit 1
fi

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== fused-engine smoke =="
python - <<'EOF'
import jax, jax.numpy as jnp, numpy as np
from repro.fwi.solver import FWIConfig, ShotState, make_step_fn
from repro.fwi.domain import make_sharded_multistep, stripe_mesh

cfg = FWIConfig(nz=32, nx=64, timesteps=8, n_shots=1, sponge_width=4)
step = make_step_fn(cfg)
st = ShotState.init(cfg)
p, pp = st.p, st.p_prev
traces = []
for t in range(8):
    p, pp, tr = step(p, pp, t)
    traces.append(tr)
ref_tr = jnp.stack(traces, axis=1)

for use_pallas, label, tol in ((False, "xla-block", 1.2e-38),
                               (True, "pallas-interpret", 1e-5)):
    blk, place = make_sharded_multistep(
        cfg, stripe_mesh(1), k=4, use_pallas=use_pallas
    )
    s = ShotState.init(cfg)
    a, b = place((s.p, s.p_prev))
    trs = []
    for bb in range(2):
        a, b, tr = blk(a, b, bb * 4)
        trs.append(tr)
    tr = jnp.concatenate(trs, axis=1)
    perr = float(jnp.max(jnp.abs(np.asarray(a) - np.asarray(p))))
    terr = float(jnp.max(jnp.abs(np.asarray(tr) - np.asarray(ref_tr))))
    assert perr <= tol and terr <= tol, (label, perr, terr)
    print(f"fused-engine smoke [{label}]: max err p={perr:.2e} tr={terr:.2e}")
print("fused-engine smoke OK")
EOF

echo "== bench-schema smoke =="
python benchmarks/run.py --only envs,capacity_fit,real_elastic,faults \
    --json /tmp/bench_ci.json
python - <<'EOF'
import json

doc = json.load(open("/tmp/bench_ci.json"))
assert doc["failures"] == 0, doc["errors"]
assert set(doc["benches"]) == {
    "envs", "capacity_fit", "real_elastic", "faults",
}, doc["benches"].keys()
for name, rows in doc["benches"].items():
    assert rows, f"bench {name} produced no rows"
    for rec in rows:
        assert set(rec) == {"name", "us_per_call", "derived"}, rec
        assert isinstance(rec["us_per_call"], float)
# the sim-vs-real acceptance rows (DESIGN.md §14): cost-aware planning
# beats the cost-blind solve on $ at equal hit-rate in the superlinear
# scenario, and is never worse on the real orchestrator
by_name = {r["name"]: r for r in doc["benches"]["real_elastic"]}
assert by_name["real_elastic.costaware_cheaper_at_equal_hit"]["derived"] \
    == "1"
assert by_name["real_elastic.real_costaware_no_worse"]["derived"] == "1"
assert by_name["real_elastic.sim_vs_real"]["derived"].startswith(
    "hit_match=1")
# the §19 robustness acceptance rows: hardened hit-rate >= the
# unhardened baseline, cost bounded vs a fault-free run, and the
# preempted admission landing within one evaluation interval
by_name = {r["name"]: r for r in doc["benches"]["faults"]}
assert by_name["faults.hardened_hit_ge_baseline"]["derived"] == "1"
assert by_name["faults.hardened_cost_bounded"]["derived"] == "1"
assert by_name["faults.preempt_admit_latency_ok"]["derived"] == "1"
print("bench json schema OK (incl. real_elastic + faults rows)")
EOF

echo "== benchmark smoke =="
python - <<'EOF'
import sys
sys.path.insert(0, ".")
from benchmarks import bench_fused_scan, bench_overheads

rows = bench_overheads.run() + bench_fused_scan.run()
for r in rows:
    print(r)

speedup = next(
    float(r.rsplit(",", 1)[1]) for r in rows
    if r.startswith("fused_scan.speedup_x")
)
print(f"scan-fused speedup over seed loop: {speedup:.2f}x")
import os
cores = len(os.sched_getaffinity(0))
if cores >= 2:
    assert speedup > 1.0, "scan-fused engine slower than per-step loop"
else:
    # single-core cgroup: the scan engine's win is multi-core XLA
    # parallelism, so the strict gate can't be validated here — keep a
    # regression floor only (BENCH_fwi.json holds the multi-core claim)
    print(f"WARNING: {cores} core visible; speedup gate relaxed to >0.5")
    assert speedup > 0.5, "scan-fused engine catastrophically slow"
EOF

echo "== fleet smoke =="
python - <<'EOF'
import sys
sys.path.insert(0, ".")
from benchmarks import bench_fleet_scenarios

rows = bench_fleet_scenarios.run()
for r in rows:
    print(r)

def derived(name):
    return next(
        r.rsplit(",", 1)[1] for r in rows if r.startswith(name)
    )

assert derived("fleet.overload_plan_beats_noburst") == "1", \
    "deadline-aware policy must beat no-burst on the overload scenario"
assert derived("fleet.overload_plan_cheaper_than_always") == "1", \
    "deadline-aware policy must undercut always-burst on cloud cost"
assert derived("fleet.spike_cloud_retired_at_end") == "1", \
    "cloud pod must be retired once the transient spike clears"
EOF

echo "== fleet-tournament smoke =="
python - <<'EOF'
import sys
sys.path.insert(0, ".")
from benchmarks import bench_fleet_tournament

rows = bench_fleet_tournament.run()
for r in rows:
    print(r)

def derived(name):
    return next(
        r.rsplit(",", 1)[1] for r in rows if r.startswith(name)
    )

assert derived("fleet_tournament.aware_beats_fifo_noburst") == "1", \
    "some deadline-aware (scheduler, policy) cell must beat " \
    "FIFO+no-burst on hit-rate at lower cloud $ than FIFO+always-burst"
assert derived("fleet_tournament.jobs_conserved") == "1", \
    "every submitted job must end finished/running/queued in every cell"
EOF

echo "== fault-storm smoke =="
python - <<'EOF'
import dataclasses
import hashlib

from repro.sim import FleetSim, PlanAutoscaler
from repro.sim.scenarios import fault_storm, preemption_pressure

def digest(rec):
    return hashlib.sha256(
        repr(dataclasses.asdict(rec)).encode()
    ).hexdigest()

h = FleetSim(fault_storm(0, hardened=True), PlanAutoscaler, seed=0).run()
again = FleetSim(fault_storm(0, hardened=True), PlanAutoscaler,
                 seed=0).run()
assert digest(h) == digest(again), "fault run not bit-deterministic"
b = FleetSim(fault_storm(0, hardened=False), PlanAutoscaler,
             seed=0).run()
assert all(j.finished for j in h.jobs), "hardened run must finish"
assert h.hit_rate >= b.hit_rate, (h.hit_rate, b.hit_rate)
clean = dataclasses.replace(fault_storm(0, hardened=True),
                            faults=None, retry=None, name="clean")
c = FleetSim(clean, PlanAutoscaler, seed=0).run()
assert h.cloud_cost <= 1.5 * c.cloud_cost, (h.cloud_cost, c.cloud_cost)
sc = preemption_pressure(0)
p = FleetSim(sc, PlanAutoscaler, seed=0).run()
gold = next(j for j in p.jobs if j.name == "gold0")
admit = next(t for t, k, _ in gold.events if k == "admit")
limit = 60.0 + sc.starve_patience_s + sc.eval_interval_s
assert gold.met_deadline and admit <= limit, (admit, limit)
print(f"fault-storm smoke OK: hardened hit={h.hit_rate:.2f} >= "
      f"baseline {b.hit_rate:.2f}, cost {h.cloud_cost:.0f} <= "
      f"1.5x clean {c.cloud_cost:.0f}, preempt admit at {admit:.0f}s")
EOF

echo "== coverage floors =="
python scripts/simcov.py

echo "== real-elastic smoke =="
python - <<'EOF'
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
import jax.numpy as jnp

from repro.core import (
    BurstPlanner, DeadlinePredictor, ElasticOrchestrator,
    LogCapacityModel, OverheadModel, PodSpec, Resources, elastic_chips,
)
from repro.fwi.driver import TimeModel, elastic_stripes_for, \
    fwi_session_factory
from repro.fwi.solver import FWIConfig, run_forward
from repro.sim import ReactAutoscaler

cfg = FWIConfig(nz=32, nx=64, timesteps=80, n_shots=1, sponge_width=4)
W, K, LEGAL = 64.0, 1.4, [16, 32, 64]
cs = sorted(set(LEGAL) | {64})
planner = BurstPlanner(
    cluster_model=LogCapacityModel.fit(cs, [W / c for c in cs]),
    cloud_model=LogCapacityModel.fit(cs, [K * W / c for c in cs]),
    chips_cluster=64, legal_slices=LEGAL,
    overheads=OverheadModel(ckpt_s=3.0, provision_s=6.0, restart_s=3.0),
    price_per_chip_hour=3.0,
)
orch = ElasticOrchestrator(
    planner=planner, predictor=DeadlinePredictor(300.0),
    check_every=6, ckpt_every=24, eval_interval_s=6.0, cloud_slowdown=K,
)
base = fwi_session_factory(
    cfg, TimeModel(chip_seconds_per_step=W, jitter=0.01),
    stripes_for=elastic_stripes_for(1, 2),
    exchange_interval=4, scan_block=8,
)
sessions = []

def factory(res, start, restored):
    s = base(res, start, restored)
    sessions.append(s)
    return s

rec = orch.run(
    session_factory=factory,
    initial=Resources(pods=[PodSpec(chips=64, name="cluster")],
                      shares=[1.0]),
    steps_total=80, autoscaler=ReactAutoscaler(slowdown=K),
    deadline_changes=[(15.0, 70.0), (45.0, 300.0)],
)
kinds = [e.detail["kind"] for e in rec.events if e.kind == "scale"]
assert "grow" in kinds and "retire" in kinds, kinds
assert elastic_chips(rec.final_resources) == 0
assert max(s._n_stripes for s in sessions) == 2, "grow must re-stripe"
ref, _ = run_forward(cfg, steps=80)
last = sessions[-1]
assert last.t == 80, last.t
err = float(jnp.max(jnp.abs(np.asarray(last.p) - np.asarray(ref.p))))
assert err < 1e-8, f"wavefield checksum broke across scale events: {err}"
print(f"real-elastic smoke OK: scales={kinds} wavefield max err={err:.2e}")
EOF

echo "== big-grid streaming smoke =="
python - <<'EOF'
import jax, jax.numpy as jnp, numpy as np
from repro.kernels.stencil.kernel import (
    HALO, pick_bz_stream, should_stream, wave_block_stream_pallas,
)
from repro.kernels.stencil.ref import wave_block_ref, wave_block_strips_ref

nz = nx = 2048
k, budget = 4, 4 * 1024 * 1024
assert should_stream(nz, nx, k, vmem_budget=budget)
bz = pick_bz_stream(nz, nx, k, vmem_budget=budget)
assert bz + 2 * k * HALO < nz, (bz, "whole-height fallback")
ks = jax.random.split(jax.random.key(0), 4)
p = jax.random.normal(ks[0], (nz, nx), jnp.float32)
pp = jax.random.normal(ks[1], (nz, nx), jnp.float32)
v = jax.random.uniform(ks[2], (nz, nx), jnp.float32, 0.05, 0.2)
s = jnp.clip(jax.random.uniform(ks[3], (nz, nx)), 0.9, 1.0)
srcv = jnp.linspace(0.5, 1.0, k)
ref = wave_block_ref(p, pp, v, s, srcv, 100, 200, receiver_row=7)
strips = wave_block_strips_ref(p, pp, v, s, srcv, 100, 200,
                               receiver_row=7, bz=bz)
for a, b in zip(ref, strips):
    assert np.array_equal(np.asarray(a), np.asarray(b)), "strips not bitwise"
out = wave_block_stream_pallas(p, pp, v, s, srcv, 100, 200,
                               receiver_row=7, bz=bz, vmem_budget=budget)
err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(ref, out))
assert err <= 1e-5, err
print(f"big-grid streaming smoke OK: 2048x2048 k=4 bz={bz} "
      f"({nz // bz} strips) max err={err:.2e}")
EOF

echo "== trajectory schema =="
python - <<'EOF'
import json

doc = json.load(open("BENCH_fwi.json"))
big = [pt for pt in doc["points"] if pt.get("tier") == "big"]
assert big, "BENCH_fwi.json missing the production-scale tier point"
sb = [pt for pt in doc["points"] if pt.get("tier") == "shot_batch"]
assert sb, "BENCH_fwi.json missing the shot-batch tier point"
pt = sb[-1]
for key in ("config", "host_parallel_scaling", "steps_per_sec",
            "batched_vs_vmapped", "vmem", "traffic_model", "big"):
    assert key in pt, key
assert pt["batched_vs_vmapped"]["pallas"] > 1.0, \
    "batched Pallas engine must beat the vmapped per-shot path"
assert pt["vmem"]["stream_bytes_sS"] <= pt["vmem"]["budget_bytes"], \
    "streamed shot-batched kernel must honor the VMEM budget"
assert pt["vmem"]["resident_bytes_tile"] <= pt["vmem"]["budget_bytes"], \
    "default shot tile must fit resident VMEM"
assert pt["traffic_model"]["batched_bytes"] \
    < pt["traffic_model"]["vmapped_bytes"]
assert pt["big"]["vmem"]["stream_bytes_sS"] \
    <= pt["big"]["vmem"]["budget_bytes"]
print(f"trajectory schema OK: shot_batch tier "
      f"pallas ratio={pt['batched_vs_vmapped']['pallas']}")
pt = big[-1]
assert "host_parallel_scaling" in pt, pt.keys()
assert set(pt["grids"]) >= {"4096x4096", "8192x2048"}, pt["grids"].keys()
for gname, g in pt["grids"].items():
    for key in ("config", "steps_per_sec", "us_per_step",
                "speedup_vs_sharded_fused", "engine_meta", "vmem",
                "hbm_boundary_proxy"):
        assert key in g, (gname, key)
    assert g["vmem"]["fits_resident"] is False, gname
    assert g["vmem"]["stream_bytes"] <= g["vmem"]["budget_bytes"], gname
    assert g["engine_meta"]["schedule_auto"] in \
        ("fused", "overlap", "pipeline"), gname
    streamed = g["speedup_vs_sharded_fused"]["fused_block_streamed"]
    resident = g["speedup_vs_sharded_fused"]["fused_block_resident"]
    assert streamed > resident, (gname, streamed, resident)
print(f"trajectory schema OK: big tier grids={sorted(pt['grids'])}")
EOF

echo "CI OK"
