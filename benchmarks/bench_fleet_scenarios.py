"""Policy × scenario sweep of the hybrid-fleet simulator (DESIGN.md §11).

Scores every autoscaler policy against every generated scenario on the
three axes the auto-scaling literature (and the paper's cost/deadline
trade-off) cares about:

  deadline-hit-rate   fraction of foreground jobs finishing in time
  cloud cost          $ for elastic chip-hours actually held
  useful-work frac    useful chip·s / total chip·s consumed

Acceptance of the paper's core claim at fleet scale: on the overload
scenario the deadline-aware `plan` policy must beat the `no-burst`
baseline on hit-rate while spending strictly less than `always-burst`.
"""
from __future__ import annotations

import time

from repro.sim import POLICY_FACTORIES, FleetSim
from repro.sim.scenarios import default_scenarios

SEED = 0


def sweep(seed: int = SEED) -> dict[tuple[str, str], object]:
    out = {}
    for sc in default_scenarios(seed):
        for pname, pf in POLICY_FACTORIES.items():
            out[(sc.name, pname)] = FleetSim(sc, pf, seed=seed).run()
    return out


def run() -> list[str]:
    t0 = time.perf_counter()
    recs = sweep()
    dt_us = (time.perf_counter() - t0) * 1e6
    n = len(recs)
    rows = [f"fleet.policy_x_scenario_runs,{dt_us / n:.0f},{n}"]
    for (sc, pol), r in sorted(recs.items()):
        rows.append(
            f"fleet.{sc}.{pol},{dt_us / n:.0f},"
            f"hit={r.hit_rate:.2f};cost={r.cloud_cost:.2f};"
            f"useful={r.useful_frac:.3f};makespan_s={r.makespan_s:.0f}"
        )
    # the §3.3 claim at fleet scale (also asserted by tests/CI)
    plan = recs[("overload_ramp", "plan")]
    nb = recs[("overload_ramp", "no-burst")]
    ab = recs[("overload_ramp", "always-burst")]
    rows.append(
        f"fleet.overload_plan_beats_noburst,{dt_us / n:.0f},"
        f"{int(plan.hit_rate > nb.hit_rate)}"
    )
    rows.append(
        f"fleet.overload_plan_cheaper_than_always,{dt_us / n:.0f},"
        f"{int(plan.cloud_cost < ab.cloud_cost)}"
    )
    spike = recs[("transient_spike", "plan")]
    rows.append(
        f"fleet.spike_cloud_retired_at_end,{dt_us / n:.0f},"
        f"{int(spike.cloud_timeline[-1][1] == 0)}"
    )
    return rows
