"""Scan-fused propagation engine vs the seed per-step loop, and
temporally-blocked vs exchange-every-step halo communication.

Two claims, measured on the paper's 600×600 / 4-shot geometry:

* steps/sec: the seed engine dispatched ONE jitted step per timestep
  from Python with the roll-based laplacian and stacked traces on the
  host — reproduced here verbatim as the baseline.  The fused engine is
  a single ``lax.scan`` dispatch (unrolled body, pad-slice laplacian,
  traces collected inside the scan).  Target: ≥ 3×.
* ppermute count: the temporally-blocked sharded runner exchanges one
  packed k·HALO halo per k timesteps — same 2 collective-permutes per
  block as k=1, i.e. k× fewer per timestep (latency, not bandwidth, is
  what the slow cluster↔cloud seam charges — paper §3.3).

CPU wall numbers (interpret-free jnp paths); relative ratios are the
deliverable, absolute times are not TPU projections.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.fwi.domain import (
    halo_exchange_plan,
    make_sharded_scan_runner,
    stripe_mesh,
)
from repro.fwi.solver import (
    FWIConfig,
    ShotState,
    make_scan_runner,
    ricker,
    sponge_taper,
    velocity_model,
)
from repro.kernels.stencil.ref import laplacian_roll


def _seed_step_fn(cfg: FWIConfig):
    """The seed engine's per-timestep function, verbatim: roll-based
    laplacian, one jitted dispatch per step."""
    v = velocity_model(cfg)
    v2dt2 = (v * cfg.dt / cfg.dx) ** 2
    sponge = sponge_taper(cfg)
    wavelet = ricker(cfg)
    pos = cfg.shot_positions()
    src_z = jnp.asarray(pos[:, 0])
    src_x = jnp.asarray(pos[:, 1])

    def one_shot(p, p_prev, t, zi, xi):
        lap = laplacian_roll(p)
        p_next = (2.0 * p - p_prev + v2dt2 * lap) * sponge
        p_damped = p * sponge
        p_next = p_next.at[zi, xi].add(wavelet[t] * (cfg.dt ** 2))
        return p_next, p_damped

    @jax.jit
    def step(p, p_prev, t):
        p_next, p_damped = jax.vmap(
            one_shot, in_axes=(0, 0, None, 0, 0)
        )(p, p_prev, t, src_z, src_x)
        return p_next, p_damped, p_next[:, cfg.receiver_depth, :]

    return step


def _best(fn, repeats: int = 5) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> list[str]:
    rows = []
    cfg = FWIConfig()                      # paper Table 2: 600x600, 4 shots
    st = ShotState.init(cfg)
    steps = 48

    # --- seed per-step Python loop (incl. host-side trace stacking) ----
    step = _seed_step_fn(cfg)
    def loop():
        p, pp, traces = st.p, st.p_prev, []
        for t in range(steps):
            p, pp, tr = step(p, pp, t)
            traces.append(tr)
        jax.block_until_ready(jnp.stack(traces, axis=1))
    loop()                                 # compile
    t_loop = _best(loop) / steps
    loop_sps = 1.0 / t_loop
    rows.append(f"fused_scan.loop_per_step,{t_loop * 1e6:.0f},"
                f"{loop_sps:.1f}")

    # --- scan-fused runner (traces inside the scan) --------------------
    runner = make_scan_runner(cfg, collect_traces=True)
    def scan():
        jax.block_until_ready(runner(st.p, st.p_prev, 0, steps))
    scan()                                 # compile
    t_scan = _best(scan) / steps
    scan_sps = 1.0 / t_scan
    rows.append(f"fused_scan.scan_per_step,{t_scan * 1e6:.0f},"
                f"{scan_sps:.1f}")
    rows.append(f"fused_scan.speedup_x,0,{t_loop / t_scan:.2f}")

    # --- exchange-every-step vs temporally-blocked (sharded) -----------
    mesh = stripe_mesh(1)
    blocked = {}
    for k in (1, 4):
        run_k, place, keff = make_sharded_scan_runner(cfg, mesh, k=k)
        p, pp = place((st.p, st.p_prev))
        blocks = steps // keff
        def shard_run():
            jax.block_until_ready(run_k(p, pp, 0, blocks))
        shard_run()                        # compile
        t_k = _best(shard_run) / (blocks * keff)
        blocked[k] = t_k
        plan = halo_exchange_plan(cfg, 1, k=keff)
        rows.append(
            f"fused_scan.sharded_k{k}_per_step,{t_k * 1e6:.0f},"
            f"ppermutes_per_step={plan['ppermutes_per_step']}"
        )
    rows.append(f"fused_scan.temporal_block_speedup_x,0,"
                f"{blocked[1] / blocked[4]:.2f}")
    return rows
