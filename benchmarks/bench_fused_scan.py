"""Overlap-and-fuse propagation engine vs its two ancestors.

Engines, measured on the paper's 600×600 / 4-shot geometry:

* seed loop — ONE jitted step per timestep dispatched from Python with
  the roll-based laplacian and host-side trace stacking (reproduced
  verbatim as the baseline).
* PR 1 scan — single ``lax.scan`` dispatch, unrolled per-step body,
  pad-slice laplacian, in-scan traces (``make_scan_runner``).
* fused block — ``make_block_runner``: scan over k-step fused
  ``wave_block`` regions (field padded across inner steps, damped
  previous folded into the leapfrog, epilogue-fused injection/traces).
* sharded fused — the full overlap-and-fuse engine
  (``make_sharded_scan_runner``): fused blocks per stripe, one packed
  halo exchange per block issued before the interior compute.  With ≥ 2
  host devices the stripes run on real parallel XLA executables — the
  configuration recorded in BENCH_fwi.json.
* shot-parallel fused — ``make_shot_parallel_runner``: the paper's
  first-level task-parallel split (independent shots) on the fused
  block body; zero communication, so it bounds what the host's cores
  can give the engine.

Timing is INTERLEAVED round-robin (machine-wide throughput drift on a
shared host hits every engine equally) and best-of is reported.  The
HBM-traffic proxy is ``hlo_cost.entry_boundary_bytes``: wavefield bytes
crossing the jit boundary per step — a k-step fused block moves the
fields once per k steps (the per-op cost_analysis sum cannot see this).
CPU wall numbers; relative ratios are the deliverable.
"""
from __future__ import annotations

import os
import sys

# 2 host devices so the striped engine measures real parallelism; must
# precede the first jax import (harmless no-op on real accelerators)
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=2"
        ).strip()

import functools  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.fwi.domain import (  # noqa: E402
    halo_exchange_plan,
    make_sharded_scan_runner,
    pick_schedule,
    stripe_mesh,
)
from repro.fwi.solver import (  # noqa: E402
    FWIConfig,
    ShotState,
    make_block_runner,
    make_scan_runner,
    make_shot_parallel_runner,
    ricker,
    sponge_taper,
    velocity_model,
)
from repro.kernels.stencil.ops import pick_k, wave_block, wave_step  # noqa: E402
from repro.kernels.stencil.ref import laplacian_roll  # noqa: E402
from repro.launch.hlo_cost import (  # noqa: E402
    entry_boundary_bytes,
    xla_cost_analysis,
)


def _seed_step_fn(cfg: FWIConfig):
    """The seed engine's per-timestep function, verbatim: roll-based
    laplacian, one jitted dispatch per step."""
    v = velocity_model(cfg)
    v2dt2 = (v * cfg.dt / cfg.dx) ** 2
    sponge = sponge_taper(cfg)
    wavelet = ricker(cfg)
    pos = cfg.shot_positions()
    src_z = jnp.asarray(pos[:, 0])
    src_x = jnp.asarray(pos[:, 1])

    def one_shot(p, p_prev, t, zi, xi):
        lap = laplacian_roll(p)
        p_next = (2.0 * p - p_prev + v2dt2 * lap) * sponge
        p_damped = p * sponge
        p_next = p_next.at[zi, xi].add(wavelet[t] * (cfg.dt ** 2))
        return p_next, p_damped

    @jax.jit
    def step(p, p_prev, t):
        p_next, p_damped = jax.vmap(
            one_shot, in_axes=(0, 0, None, 0, 0)
        )(p, p_prev, t, src_z, src_x)
        return p_next, p_damped, p_next[:, cfg.receiver_depth, :]

    return step


def host_parallel_scaling() -> float:
    """Measured 2-process CPU scaling of THIS host right now.

    The container advertises 2 CPUs but shares a hypervisor; under
    neighbor steal, two busy processes can run SLOWER than one
    (observed 0.45×–1.9× across hours).  The sharded engines need real
    parallel cores, so every trajectory point records this probe —
    a point taken at scaling ≪ 2 understates the engine, not the code.
    """
    import subprocess

    code = "x=0\nfor i in range(2_000_000): x+=i*i"
    t0 = time.perf_counter()
    subprocess.run([sys.executable, "-c", code])
    t1 = time.perf_counter() - t0
    t0 = time.perf_counter()
    ps = [subprocess.Popen([sys.executable, "-c", code]) for _ in range(2)]
    for p in ps:
        p.wait()
    t2 = time.perf_counter() - t0
    return 2.0 * t1 / max(t2, 1e-9)


def _interleaved_best(engines: dict, rounds: int = 6) -> dict[str, float]:
    """Round-robin timing: every engine measured in every round, so
    host-wide throughput drift cancels out of the ratios."""
    for fn in engines.values():
        fn()                                   # compile
    best = {name: float("inf") for name in engines}
    for _ in range(rounds):
        for name, fn in engines.items():
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def build_engines(cfg: FWIConfig, steps: int, *, stripes: int | None = None):
    """(engines dict, meta dict) for the steps/sec comparison."""
    st = ShotState.init(cfg)
    k = pick_k(cfg.nz)
    n = stripes if stripes is not None else min(2, jax.device_count())

    step = _seed_step_fn(cfg)

    def loop():
        p, pp, traces = st.p, st.p_prev, []
        for t in range(steps):
            p, pp, tr = step(p, pp, t)
            traces.append(tr)
        jax.block_until_ready(jnp.stack(traces, axis=1))

    scan_runner = make_scan_runner(cfg, collect_traces=True)

    def scan():
        jax.block_until_ready(scan_runner(st.p, st.p_prev, 0, steps))

    block_runner = make_block_runner(cfg, k=k)

    def block():
        jax.block_until_ready(block_runner(st.p, st.p_prev, 0, steps))

    engines = {
        "seed_loop": loop,
        "pr1_scan": scan,
        "fused_block": block,
    }

    def add_sharded(name, kk, overlap):
        run_s, place, keff = make_sharded_scan_runner(
            cfg, stripe_mesh(n), k=kk, overlap=overlap
        )
        ps, pps = place((st.p, st.p_prev))
        blocks = steps // keff

        def sharded(run_s=run_s, ps=ps, pps=pps, blocks=blocks):
            jax.block_until_ready(run_s(ps, pps, 0, blocks))

        engines[name] = sharded
        return keff

    # the shipped engine (schedule auto-selected per backend) at the
    # heuristic block length and half of it — block length is a tuned
    # knob, the bench records which setting carried the day
    keffs = {}
    for kk in sorted({k, max(k // 2, 1)}):
        keffs[kk] = add_sharded(f"sharded_fused_k{kk}", kk, None)
    # the overlap schedule, forced, for the record on this backend
    add_sharded(f"sharded_overlap_k{k}", k, True)

    # shot-parallel fused blocks: the paper's first-level task-parallel
    # split (shots are independent) — zero communication, so parallel
    # efficiency is bounded only by the host.  Uneven splits are legal
    # now (the runner pads the batch to the device count), so the old
    # ``n_shots % n == 0`` gate is gone.
    if n > 1:
        run_sp, place_sp = make_shot_parallel_runner(cfg, n, k=k)
        psp, ppsp = place_sp((st.p, st.p_prev))

        def shot_par():
            jax.block_until_ready(run_sp(psp, ppsp, 0, steps))

        engines[f"shot_parallel_k{k}"] = shot_par

    meta = {"k": k, "stripes": n, "k_effective": keffs,
            "sharded_variants": sorted(
                nm for nm in engines
                if nm.startswith(("sharded", "shot_parallel"))
            )}
    return engines, meta


def hbm_boundary_proxy(cfg: FWIConfig, k: int = 4) -> dict:
    """Per-step WAVEFIELD bytes crossing the launch boundary, step
    engine vs k-step fused block, via ``entry_boundary_bytes`` — the
    HBM-traffic proxy for temporal fusion (a k-step block round-trips
    the fields once per k steps).  The raw ``xla_cost_analysis``
    'bytes accessed' totals are recorded alongside for transparency:
    that per-op sum charges every fused-region intermediate identically
    inside and outside the block, so it cannot see the boundary win."""
    p = jnp.zeros((cfg.nz, cfg.nx), jnp.float32)
    v = jnp.full((cfg.nz, cfg.nx), 0.1, jnp.float32)
    s = jnp.ones((cfg.nz, cfg.nx), jnp.float32)
    srcv = jnp.zeros((k,), jnp.float32)
    f_step = jax.jit(
        lambda a, b, vv, ss: wave_step(a, b, vv, ss)
    ).lower(p, p, v, s).compile()
    f_block = jax.jit(
        lambda a, b, vv, ss, sv: wave_block(a, b, vv, ss, sv, 3, 4)
    ).lower(p, p, v, s, srcv).compile()
    shape = (cfg.nz, cfg.nx)
    step_b = entry_boundary_bytes(f_step.as_text(), shape)["total_bytes"]
    block_b = entry_boundary_bytes(f_block.as_text(), shape)["total_bytes"]
    ca_step = float(xla_cost_analysis(f_step).get("bytes accessed", 0.0))
    ca_block = float(xla_cost_analysis(f_block).get("bytes accessed", 0.0))
    return {
        "step_bytes_per_step": float(step_b),
        "block_bytes_per_step": float(block_b) / k,
        "k": k,
        "reduction_x": step_b / (block_b / k),
        "xla_cost_analysis_step_bytes": ca_step,
        "xla_cost_analysis_block_bytes_per_step": ca_block / k,
    }


def trajectory_point(cfg: FWIConfig | None = None, steps: int = 48,
                     rounds: int = 6) -> dict:
    """One perf-trajectory point (the BENCH_fwi.json payload)."""
    cfg = cfg or FWIConfig()
    engines, meta = build_engines(cfg, steps)
    best = _interleaved_best(engines, rounds=rounds)
    sps = {name: steps / t for name, t in best.items()}
    proxy = hbm_boundary_proxy(cfg, k=4)
    spd = {k: best["pr1_scan"] / t for k, t in best.items()}
    fused = {nm: s for nm, s in spd.items()
             if nm.startswith(("sharded_fused", "shot_parallel"))}
    headline = max(fused, key=fused.get) if fused else "fused_block"
    return {
        "config": {"nz": cfg.nz, "nx": cfg.nx, "n_shots": cfg.n_shots,
                   "timesteps_measured": steps},
        "host_parallel_scaling": round(host_parallel_scaling(), 2),
        "engine_meta": meta,
        "steps_per_sec": {k: round(v, 2) for k, v in sps.items()},
        "us_per_step": {k: round(t / steps * 1e6, 1)
                        for k, t in best.items()},
        "speedup_vs_pr1_scan": {k: round(v, 3) for k, v in spd.items()},
        "fused_engine": {"name": headline,
                         "speedup_vs_pr1_scan": round(spd[headline], 3)},
        "hbm_boundary_proxy": {k: round(v, 3) if isinstance(v, float)
                               else v for k, v in proxy.items()},
    }


BIG_GRIDS = ((4096, 4096), (8192, 2048))


def build_big_engines(cfg: FWIConfig, steps: int, *,
                      stripes: int | None = None):
    """Reduced engine set for production-scale grids (DESIGN.md §15).

    The seed loop / PR 1 scan ancestors are dropped (minutes per round
    at 4096² for a long-settled comparison); what matters at scale is
    resident vs STREAMED tiling and the fused vs overlap vs pipeline
    halo schedules.  Pallas-interpret streaming is correctness-only on
    CPU (the ci.sh big-grid smoke covers it); wall-clock rows here use
    the XLA mirrors of the same tilings."""
    st = ShotState.init(cfg)
    k = 4
    n = stripes if stripes is not None else min(2, jax.device_count())
    engines = {}

    for name, stream in (("fused_block_resident", False),
                         ("fused_block_streamed", True)):
        runner = make_block_runner(cfg, k=k, stream=stream,
                                   collect_traces=False)

        def fn(runner=runner):
            jax.block_until_ready(runner(st.p, st.p_prev, 0, steps))

        engines[name] = fn

    keffs = {}
    for name, sched in (("sharded_fused", "fused"),
                        ("sharded_overlap", "overlap"),
                        ("sharded_pipeline", "pipeline")):
        run_s, place, keff = make_sharded_scan_runner(
            cfg, stripe_mesh(n), k=k, overlap=sched
        )
        ps, pps = place((st.p, st.p_prev))
        blocks = steps // keff

        def sharded(run_s=run_s, ps=ps, pps=pps, blocks=blocks):
            jax.block_until_ready(run_s(ps, pps, 0, blocks))

        engines[f"{name}_k{keff}"] = sharded
        keffs[name] = keff

    meta = {"k": k, "stripes": n, "k_effective": keffs,
            "schedule_auto": pick_schedule()}
    return engines, meta


def big_trajectory_point(grids=BIG_GRIDS, steps: int = 8,
                         rounds: int = 2) -> dict:
    """Production-scale trajectory point: per-grid steps/sec for the
    streamed-vs-resident tilings and the three halo schedules, the HBM
    boundary proxy, and the VMEM capacity bookkeeping that motivates
    the streamed kernel (resident bytes vs budget vs streamed bytes)."""
    from repro.kernels.stencil.kernel import (
        DEFAULT_VMEM_BUDGET,
        pick_bz_stream,
        resident_vmem_bytes,
        should_stream,
        stream_vmem_bytes,
    )

    point = {
        "tier": "big",
        "host_parallel_scaling": round(host_parallel_scaling(), 2),
        "grids": {},
    }
    for nz, nx in grids:
        cfg = FWIConfig(nz=nz, nx=nx, n_shots=1,
                        timesteps=max(steps, 8))
        engines, meta = build_big_engines(cfg, steps)
        best = _interleaved_best(engines, rounds=rounds)
        base = best[f"sharded_fused_k{meta['k_effective']['sharded_fused']}"]
        k = meta["k"]
        sbz = pick_bz_stream(nz, nx, k)
        proxy = hbm_boundary_proxy(cfg, k=k)
        point["grids"][f"{nz}x{nx}"] = {
            "config": {"nz": nz, "nx": nx, "n_shots": cfg.n_shots,
                       "timesteps_measured": steps},
            "steps_per_sec": {nm: round(steps / t, 3)
                              for nm, t in best.items()},
            "us_per_step": {nm: round(t / steps * 1e6, 1)
                            for nm, t in best.items()},
            "speedup_vs_sharded_fused": {nm: round(base / t, 3)
                                         for nm, t in best.items()},
            "engine_meta": meta,
            "vmem": {
                "budget_bytes": DEFAULT_VMEM_BUDGET,
                "resident_bytes_k4": resident_vmem_bytes(nz, nx, k),
                "fits_resident": not should_stream(nz, nx, k),
                "stream_bz": sbz,
                "stream_bytes": stream_vmem_bytes(nz, nx, sbz, k),
            },
            "hbm_boundary_proxy": {kk: round(v, 3) if isinstance(v, float)
                                   else v for kk, v in proxy.items()},
        }
    return point


def _vmapped_block_runner(cfg: FWIConfig, k: int):
    """The PRE-shot-batch engine body, reconstructed for the bench:
    ``jax.vmap`` of the per-shot ``wave_block_ref`` inside the block
    scan — exactly what ``_block_scan_body`` did before DESIGN.md §17
    replaced it with one batched ``wave_block`` call.  This is the
    baseline the shot-batched engine is measured against."""
    from repro.kernels.stencil.ref import wave_block_ref

    v = velocity_model(cfg)
    v2dt2 = (v * cfg.dt / cfg.dx) ** 2
    sponge = sponge_taper(cfg)
    wavelet = ricker(cfg)
    pos = cfg.shot_positions()
    src_z = jnp.asarray(pos[:, 0])
    src_x = jnp.asarray(pos[:, 1])

    @functools.partial(jax.jit, static_argnames=("steps",))
    def run(p, p_prev, t0, steps):
        blocks = steps // k

        def body(carry, b):
            pc, pp = carry
            tt = t0 + b * k + jnp.arange(k)
            srcv = wavelet[jnp.clip(tt, 0, cfg.timesteps - 1)] \
                * (cfg.dt ** 2)

            def one(a, bb, zi, xi):
                return wave_block_ref(
                    a, bb, v2dt2, sponge, srcv, zi, xi,
                    receiver_row=cfg.receiver_depth,
                )

            pn, pd, tr = jax.vmap(one, (0, 0, 0, 0))(pc, pp, src_z, src_x)
            return (pn, pd), tr

        (p, p_prev), trs = jax.lax.scan(body, (p, p_prev),
                                        jnp.arange(blocks))
        return p, p_prev, trs

    return run


SHOT_BATCH_BIG_GRID = (1536, 1536, 2, 4)   # nz, nx, shots, k: must stream


def shot_batch_point(steps: int = 48, rounds: int = 6,
                     pallas_rounds: int = 3) -> dict:
    """Trajectory point (tier "shot_batch") for the batched engine.

    Rows come in matched batched-vs-vmapped pairs (DESIGN.md §17):

    * XLA scan runners at the paper geometry — the old vmapped block
      body vs ``make_block_runner``'s batched dispatch.  On CPU XLA
      compiles the vmapped body into the same fused loop as the
      hand-batched mirror (they are bitwise-identical), so this pair is
      expected to be a wash; it is recorded to pin that fact.
    * Pallas-interpret per-block rows — ``vmap``-of-
      ``wave_block_pallas`` (one kernel per shot) vs the batched kernel
      at the dispatch's default shot tile and the streamed full-batch
      kernel.  Here the launch/grid-pass amortization is real work
      removed (S·nz/bz passes → (S/tile)·nz/bz), so this pair carries
      the batched-beats-vmapped acceptance ratio.
    * A big-tier pair at a grid whose batch CANNOT sit resident in
      VMEM, where the streamed batched kernel is the only in-budget
      path (XLA strip mirrors for wall clock, per the big-tier
      convention, plus the interpret pair for the record).
    """
    from repro.kernels.stencil.kernel import (
        DEFAULT_VMEM_BUDGET,
        pick_bz_block,
        pick_bz_stream,
        pick_shot_tile,
        resident_vmem_bytes,
        stream_vmem_bytes,
        wave_block_pallas,
    )
    from repro.kernels.stencil.ref import (
        wave_block_shots_strips_ref,
        wave_block_strips_ref,
    )
    from repro.launch.hlo_cost import shot_batch_strip_bytes

    cfg = FWIConfig()
    S, k = cfg.n_shots, pick_k(cfg.nz)
    st = ShotState.init(cfg)

    vmapped = _vmapped_block_runner(cfg, k)
    batched = make_block_runner(cfg, k=k)
    xla = {
        "xla_vmapped": lambda: jax.block_until_ready(
            vmapped(st.p, st.p_prev, 0, steps)),
        "xla_batched": lambda: jax.block_until_ready(
            batched(st.p, st.p_prev, 0, steps)),
    }
    best = _interleaved_best(xla, rounds=rounds)
    sps = {nm: steps / t for nm, t in best.items()}

    # Pallas rows: per-block timing (interpret mode is the CPU stand-in
    # for the TPU kernel; one block = k timesteps)
    v = velocity_model(cfg)
    v2dt2 = (v * cfg.dt / cfg.dx) ** 2
    sponge = sponge_taper(cfg)
    srcv = ricker(cfg)[:k] * (cfg.dt ** 2)
    pos = cfg.shot_positions()
    sz = jnp.asarray(pos[:, 0])
    sx = jnp.asarray(pos[:, 1])
    bz = pick_bz_block(cfg.nz, k)
    tile = pick_shot_tile(S, cfg.nz, cfg.nx, k, bz=bz)
    sbz = pick_bz_stream(cfg.nz, cfg.nx, k, s=S)

    def one(a, b, zi, xi):
        return wave_block_pallas(a, b, v2dt2, sponge, srcv, zi, xi,
                                 receiver_row=cfg.receiver_depth, bz=bz)

    vm = jax.jit(jax.vmap(one, (0, 0, 0, 0)))
    pal = {
        "pallas_vmapped": lambda: jax.block_until_ready(
            vm(st.p, st.p_prev, sz, sx)),
        f"pallas_batched_tile{tile}": lambda: jax.block_until_ready(
            wave_block(st.p, st.p_prev, v2dt2, sponge, srcv, sz, sx,
                       receiver_row=cfg.receiver_depth, use_pallas=True,
                       bz=bz, stream=False)),
        f"pallas_batched_stream_s{S}": lambda: jax.block_until_ready(
            wave_block(st.p, st.p_prev, v2dt2, sponge, srcv, sz, sx,
                       receiver_row=cfg.receiver_depth, use_pallas=True,
                       stream=True, shot_tile=S)),
    }
    pbest = _interleaved_best(pal, rounds=pallas_rounds)
    sps.update({nm: k / t for nm, t in pbest.items()})
    pal_batched = {nm: s for nm, s in sps.items()
                   if nm.startswith("pallas_batched")}
    pal_head = max(pal_batched, key=pal_batched.get)

    # big tier: the batch cannot sit resident — streaming is mandatory
    bnz, bnx, bS, bk = SHOT_BATCH_BIG_GRID
    bcfg = FWIConfig(nz=bnz, nx=bnx, n_shots=bS, timesteps=max(bk, 8))
    bst = ShotState.init(bcfg)
    bv = velocity_model(bcfg)
    bv2dt2 = (bv * bcfg.dt / bcfg.dx) ** 2
    bsponge = sponge_taper(bcfg)
    bsrcv = ricker(bcfg)[:bk] * (bcfg.dt ** 2)
    bpos = bcfg.shot_positions()
    bsz = jnp.asarray(bpos[:, 0])
    bsx = jnp.asarray(bpos[:, 1])
    bsbz1 = pick_bz_stream(bnz, bnx, bk)        # per-shot strip
    bsbzS = pick_bz_stream(bnz, bnx, bk, s=bS)  # batched strip

    def big_one(a, b, zi, xi):
        return wave_block_strips_ref(a, b, bv2dt2, bsponge, bsrcv, zi, xi,
                                     receiver_row=bcfg.receiver_depth,
                                     bz=bsbz1)

    big_vm = jax.jit(jax.vmap(big_one, (0, 0, 0, 0)))
    big_batched = jax.jit(functools.partial(
        wave_block_shots_strips_ref, receiver_row=bcfg.receiver_depth,
        bz=bsbz1))
    big = {
        "xla_vmapped_strips": lambda: jax.block_until_ready(
            big_vm(bst.p, bst.p_prev, bsz, bsx)),
        "xla_batched_strips": lambda: jax.block_until_ready(
            big_batched(bst.p, bst.p_prev, bv2dt2, bsponge, bsrcv,
                        bsz, bsx)),
        "pallas_vmapped_stream": lambda: jax.block_until_ready(
            jax.tree_util.tree_map(lambda *a: jnp.stack(a), *[
                wave_block(bst.p[i], bst.p_prev[i], bv2dt2, bsponge,
                           bsrcv, bsz[i], bsx[i],
                           receiver_row=bcfg.receiver_depth,
                           use_pallas=True, stream=True, bz=bsbz1)
                for i in range(bS)])),
        "pallas_batched_stream": lambda: jax.block_until_ready(
            wave_block(bst.p, bst.p_prev, bv2dt2, bsponge, bsrcv,
                       bsz, bsx, receiver_row=bcfg.receiver_depth,
                       use_pallas=True, stream=True, shot_tile=bS,
                       bz=bsbzS)),
    }
    bbest = _interleaved_best(big, rounds=max(pallas_rounds - 1, 1))
    big_sps = {nm: bk / t for nm, t in bbest.items()}

    return {
        "tier": "shot_batch",
        "config": {"nz": cfg.nz, "nx": cfg.nx, "n_shots": S, "k": k,
                   "bz": bz, "shot_tile": tile, "stream_bz": sbz,
                   "timesteps_measured": steps},
        "host_parallel_scaling": round(host_parallel_scaling(), 2),
        "steps_per_sec": {nm: round(v, 2) for nm, v in sps.items()},
        "batched_vs_vmapped": {
            "xla": round(sps["xla_batched"] / sps["xla_vmapped"], 3),
            "pallas": round(sps[pal_head] / sps["pallas_vmapped"], 3),
            "pallas_engine": pal_head,
        },
        "vmem": {
            "budget_bytes": DEFAULT_VMEM_BUDGET,
            "resident_bytes_s1": resident_vmem_bytes(
                cfg.nz, cfg.nx, k, bz=bz),
            "resident_bytes_sS": resident_vmem_bytes(
                cfg.nz, cfg.nx, k, bz=bz, s=S),
            "resident_bytes_tile": resident_vmem_bytes(
                cfg.nz, cfg.nx, k, bz=bz, s=tile),
            "stream_bytes_sS": stream_vmem_bytes(
                cfg.nz, cfg.nx, sbz, k, s=S),
            "shot_tile": tile,
        },
        "traffic_model": {nm: val for nm, val in
                          shot_batch_strip_bytes(cfg.nz, cfg.nx, S,
                                                 k=k).items()},
        "big": {
            "config": {"nz": bnz, "nx": bnx, "n_shots": bS, "k": bk,
                       "stream_bz_s1": bsbz1, "stream_bz_sS": bsbzS},
            "steps_per_sec": {nm: round(v, 3)
                              for nm, v in big_sps.items()},
            "batched_vs_vmapped": {
                "xla": round(big_sps["xla_batched_strips"]
                             / big_sps["xla_vmapped_strips"], 3),
                "pallas": round(big_sps["pallas_batched_stream"]
                                / big_sps["pallas_vmapped_stream"], 3),
            },
            "vmem": {
                "budget_bytes": DEFAULT_VMEM_BUDGET,
                "resident_bytes_sS": resident_vmem_bytes(
                    bnz, bnx, bk, s=bS),
                "stream_bytes_sS": stream_vmem_bytes(
                    bnz, bnx, bsbzS, bk, s=bS),
            },
            "traffic_model": shot_batch_strip_bytes(bnz, bnx, bS, k=bk),
        },
    }


def run_shot_batch() -> list[str]:
    """The shot-batch tier as harness rows."""
    point = shot_batch_point()
    rows = [f"shot_batch.host_parallel_scaling,0,"
            f"{point['host_parallel_scaling']}"]
    for nm, v in point["steps_per_sec"].items():
        rows.append(f"shot_batch.{nm}_steps_per_sec,0,{v}")
    bb = point["batched_vs_vmapped"]
    rows.append(f"shot_batch.batched_vs_vmapped_xla,0,{bb['xla']}")
    rows.append(f"shot_batch.batched_vs_vmapped_pallas,0,{bb['pallas']}")
    vm = point["vmem"]
    rows.append(
        f"shot_batch.vmem,0,"
        f"tile={vm['shot_tile']};"
        f"resident_sS_mb={vm['resident_bytes_sS'] / 2**20:.1f};"
        f"tile_mb={vm['resident_bytes_tile'] / 2**20:.1f};"
        f"stream_sS_mb={vm['stream_bytes_sS'] / 2**20:.1f};"
        f"budget_mb={vm['budget_bytes'] / 2**20:.0f}"
    )
    tm = point["traffic_model"]
    rows.append(
        f"shot_batch.traffic_ratio,0,{tm['traffic_ratio']:.4f}"
    )
    for nm, v in point["big"]["steps_per_sec"].items():
        rows.append(f"shot_batch.big.{nm}_steps_per_sec,0,{v}")
    bigbb = point["big"]["batched_vs_vmapped"]
    rows.append(f"shot_batch.big.batched_vs_vmapped_pallas,0,"
                f"{bigbb['pallas']}")
    return rows


def run() -> list[str]:
    rows = []
    cfg = FWIConfig()                      # paper Table 2: 600x600, 4 shots
    steps = 48
    point = trajectory_point(cfg, steps=steps)
    sps = point["steps_per_sec"]
    us = point["us_per_step"]
    spd = point["speedup_vs_pr1_scan"]

    rows.append(f"fused_scan.loop_per_step,{us['seed_loop']:.0f},"
                f"{sps['seed_loop']:.1f}")
    rows.append(f"fused_scan.scan_per_step,{us['pr1_scan']:.0f},"
                f"{sps['pr1_scan']:.1f}")
    rows.append(f"fused_scan.speedup_x,0,"
                f"{sps['pr1_scan'] / sps['seed_loop']:.2f}")
    rows.append(f"fused_scan.block_per_step,{us['fused_block']:.0f},"
                f"{sps['fused_block']:.1f}")
    rows.append(f"fused_scan.block_speedup_x,0,{spd['fused_block']:.2f}")
    meta = point["engine_meta"]
    for nm in meta["sharded_variants"]:
        rows.append(
            f"fused_scan.{nm}_per_step,{us[nm]:.0f},"
            f"n{meta['stripes']}={sps[nm]:.1f}"
        )
    head = point["fused_engine"]
    rows.append(
        f"fused_scan.fused_engine_speedup_x,0,"
        f"{head['speedup_vs_pr1_scan']:.2f}"
    )
    rows.append(f"fused_scan.fused_engine_config,0,{head['name']}")
    proxy = point["hbm_boundary_proxy"]
    rows.append(
        f"fused_scan.hbm_boundary_step_bytes,0,"
        f"{proxy['step_bytes_per_step']:.0f}"
    )
    rows.append(
        f"fused_scan.hbm_boundary_block_k{proxy['k']}_bytes,0,"
        f"{proxy['block_bytes_per_step']:.0f}"
    )
    rows.append(
        f"fused_scan.hbm_boundary_reduction_x,0,{proxy['reduction_x']:.2f}"
    )

    # temporal blocking: ppermutes per step at k=1 vs k=4 (plan model)
    for kk in (1, 4):
        plan = halo_exchange_plan(cfg, 1, k=kk)
        rows.append(
            f"fused_scan.halo_plan_k{kk},0,"
            f"ppermutes_per_step={plan['ppermutes_per_step']};"
            f"overlap_fraction={plan['overlap_fraction']:.3f}"
        )
    return rows


def run_big() -> list[str]:
    """The --big tier as harness rows (one per engine per grid)."""
    point = big_trajectory_point()
    rows = [f"fused_scan_big.host_parallel_scaling,0,"
            f"{point['host_parallel_scaling']}"]
    for gname, g in point["grids"].items():
        for nm, us in g["us_per_step"].items():
            rows.append(
                f"fused_scan_big.{gname}.{nm},{us:.0f},"
                f"sps={g['steps_per_sec'][nm]};"
                f"vs_sharded_fused={g['speedup_vs_sharded_fused'][nm]}"
            )
        vm = g["vmem"]
        rows.append(
            f"fused_scan_big.{gname}.vmem,0,"
            f"resident_mb={vm['resident_bytes_k4'] / 2**20:.0f};"
            f"budget_mb={vm['budget_bytes'] / 2**20:.0f};"
            f"fits_resident={vm['fits_resident']};"
            f"stream_bz={vm['stream_bz']};"
            f"stream_mb={vm['stream_bytes'] / 2**20:.1f}"
        )
        rows.append(
            f"fused_scan_big.{gname}.schedule_auto,0,"
            f"{g['engine_meta']['schedule_auto']}"
        )
    return rows


if __name__ == "__main__":
    import json

    big = "--big" in sys.argv
    shot_batch = "--shot-batch" in sys.argv
    argv = [a for a in sys.argv if a not in ("--big", "--shot-batch")]
    if len(argv) > 1 and argv[1] == "--write-trajectory":
        path = argv[2] if len(argv) > 2 else "BENCH_fwi.json"
        if shot_batch:
            point = shot_batch_point()
        elif big:
            point = big_trajectory_point()
        else:
            point = trajectory_point()
        try:
            with open(path) as f:
                doc = json.load(f)
        except (FileNotFoundError, ValueError):
            doc = {"description": "FWI engine perf trajectory, one point "
                                  "per engine-touching PR", "points": []}
        doc["points"].append(point)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {path} ({len(doc['points'])} points)")
    else:
        if shot_batch:
            rows = run_shot_batch()
        elif big:
            rows = run_big()
        else:
            rows = run()
        for row in rows:
            print(row)
