"""Paper Fig. 5 / eq. 8: execution time vs domain width γ (linear model).

REAL wall-clock measurements on this host: the FWI solver is timed over a
sweep of domain widths with height fixed (the paper's simplification),
then t = a·γ + b is fitted and the inverse g(t) = (t-b)/a is what the
planner uses to size the split."""
from __future__ import annotations

import time

from repro.fwi.calibrate import measure_gamma_sweep
from repro.core.gamma import GammaModel
from repro.fwi.solver import FWIConfig


def run() -> list[str]:
    base = FWIConfig(nz=512, nx=2048, timesteps=20, n_shots=1,
                     sponge_width=16)
    widths = [256, 512, 1024, 1536, 2048]
    t0 = time.perf_counter()
    g, t = measure_gamma_sweep(base, widths, steps=10, repeats=2)
    model = GammaModel.fit(g, t, "fwi-width")
    dt_us = (time.perf_counter() - t0) * 1e6
    r2 = model.r2(g, t)
    rows = [
        f"gamma_fit.a_seconds_per_column,{dt_us:.0f},{model.a:.3e}",
        f"gamma_fit.b_offset_seconds,{dt_us:.0f},{model.b:.3e}",
        f"gamma_fit.r2,{dt_us:.0f},{r2:.5f}",
    ]
    for gi, ti in zip(g, t):
        rows.append(f"gamma_fit.width_{gi},{ti * 1e6:.0f},{ti:.6f}")
    # inverse-property check at the largest width
    g_back = model.gamma_for(model.time_for(widths[-1]))
    rows.append(f"gamma_fit.inverse_check,{dt_us:.0f},"
                f"{abs(g_back - widths[-1])}")
    return rows
