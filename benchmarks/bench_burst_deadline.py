"""Paper §3.3 core claim: the self-adaptive burst meets a deadline the
static on-premise allocation misses, net of checkpoint/provision/transfer
overheads.  Emits elapsed times for static / adaptive / oracle."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    BurstPlanner,
    DeadlinePredictor,
    ElasticOrchestrator,
    LogCapacityModel,
    OverheadModel,
    PodSpec,
    Resources,
)
from repro.core.events import SlowdownWindow
from repro.core.sim_session import SimWorkload, sim_session_factory

WORK = 2000.0
CHIPS = [16, 32, 64, 128, 256]
DEADLINE = 3000.0
STEPS = 300


def _run(max_burst, seed=0):
    cluster = LogCapacityModel.fit(CHIPS, [WORK / c for c in CHIPS])
    cloud = LogCapacityModel.fit(CHIPS, [1.4 * WORK / c for c in CHIPS])
    planner = BurstPlanner(
        cluster_model=cluster, cloud_model=cloud, chips_cluster=256,
        legal_slices=CHIPS,
        overheads=OverheadModel(ckpt_s=5, provision_s=60, restart_s=20),
        max_burst_chips=max_burst,
    )
    orch = ElasticOrchestrator(
        planner=planner, predictor=DeadlinePredictor(DEADLINE),
        check_every=8, ckpt_every=25,
    )
    factory = sim_session_factory(
        SimWorkload(WORK, jitter=0.01), rng=np.random.default_rng(seed),
        windows={0: [SlowdownWindow(40, 10 ** 9, 2.2)]},
        sync_overhead_s=0.05,
    )
    return orch.run(
        session_factory=factory,
        initial=Resources(pods=[PodSpec(chips=256, name="cluster")],
                          shares=[1.0]),
        steps_total=STEPS,
    )


def run() -> list[str]:
    t0 = time.perf_counter()
    static = _run(max_burst=0)
    adaptive = _run(max_burst=256)
    dt_us = (time.perf_counter() - t0) * 1e6
    bursts = [e for e in adaptive.events if e.kind == "burst"]
    burst_chips = bursts[0].detail["chips"] if bursts else 0
    burst_step = bursts[0].step if bursts else -1
    return [
        f"burst.deadline_s,{dt_us:.0f},{DEADLINE}",
        f"burst.static_elapsed_s,{dt_us:.0f},{static.elapsed_s:.1f}",
        f"burst.static_met,{dt_us:.0f},{int(static.met_deadline)}",
        f"burst.adaptive_elapsed_s,{dt_us:.0f},{adaptive.elapsed_s:.1f}",
        f"burst.adaptive_met,{dt_us:.0f},{int(adaptive.met_deadline)}",
        f"burst.burst_step,{dt_us:.0f},{burst_step}",
        f"burst.burst_chips,{dt_us:.0f},{burst_chips}",
        f"burst.speedup,{dt_us:.0f},"
        f"{static.elapsed_s / adaptive.elapsed_s:.3f}",
    ]
