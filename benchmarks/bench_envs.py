"""Paper Tables 1-2 analogue: execution-platform + workload configuration.

Paper Table 1 compared cluster vs cloud hardware; our two environments
are the reserved pod vs burst pod (TPU v5e both, heterogeneity expressed
as the correction factor K).  Paper Table 2 lists the FWI run geometry,
which we reproduce exactly (600x600 grid, 4 shots)."""
from __future__ import annotations

from repro.fwi.solver import FWIConfig
from repro.launch.hw import TPU_V5E


def run() -> list[str]:
    cfg = FWIConfig()
    hw = TPU_V5E
    return [
        f"envs.chip,0,{hw.name}",
        f"envs.peak_tflops_bf16,0,{hw.peak_flops_bf16 / 1e12:.0f}",
        f"envs.hbm_gb_per_s,0,{hw.hbm_bw / 1e9:.0f}",
        f"envs.hbm_gib,0,{hw.hbm_bytes / 2 ** 30:.0f}",
        f"envs.ici_gb_per_s_link,0,{hw.ici_link_bw / 1e9:.0f}",
        f"envs.dci_gb_per_s,0,{hw.dci_bw / 1e9:.2f}",
        "envs.pod_shape,0,16x16",
        "envs.multi_pod_shape,0,2x16x16",
        f"envs.fwi_grid,0,{cfg.nz}x{cfg.nx}",
        f"envs.fwi_timesteps,0,{cfg.timesteps}",
        f"envs.fwi_shots,0,{cfg.n_shots}",
        f"envs.fwi_dt_s,0,{cfg.dt}",
    ]
