"""Fault-storm robustness grid (DESIGN.md §19).

Runs the ``fault_storm`` scenario — overload plus provisioning
denials/timeouts, a market-wide reclaim storm, silent checkpoint
corruption and straggler pods — through the (policy × hardening) grid,
plus the same world with faults disarmed and the ``preemption_pressure``
scavenger scenario.  The acceptance rows CI pins:

  faults.hardened_hit_ge_baseline   hardened `plan` hit-rate >= the
                                    unhardened baseline under the SAME
                                    fault draws
  faults.hardened_cost_bounded      hardened cloud $ <= 1.5 x the
                                    fault-free run's (robustness not
                                    bought with runaway spend)
  faults.preempt_admit_latency_ok   the expired weighted job is
                                    admitted within one evaluation
                                    interval of patience expiry
"""
from __future__ import annotations

import dataclasses
import time

from repro.sim import POLICY_FACTORIES, FleetSim
from repro.sim.scenarios import fault_storm, preemption_pressure

SEED = 0
POLICIES = ("plan", "react")


def sweep(seed: int = SEED) -> dict[tuple[str, str], object]:
    out = {}
    for pol in POLICIES:
        pf = POLICY_FACTORIES[pol]
        for hardened in (True, False):
            sc = fault_storm(seed, hardened=hardened)
            tag = "hardened" if hardened else "baseline"
            out[(pol, tag)] = FleetSim(sc, pf, seed=seed).run()
        clean = dataclasses.replace(
            fault_storm(seed, hardened=True),
            faults=None, retry=None, name="clean",
        )
        out[(pol, "clean")] = FleetSim(clean, pf, seed=seed).run()
    return out


def run() -> list[str]:
    t0 = time.perf_counter()
    recs = sweep()
    sc = preemption_pressure(SEED)
    pre = FleetSim(sc, POLICY_FACTORIES["plan"], seed=SEED).run()
    dt_us = (time.perf_counter() - t0) * 1e6
    n = len(recs) + 1
    rows = [f"faults.storm_grid_runs,{dt_us / n:.0f},{n}"]
    for (pol, tag), r in sorted(recs.items()):
        retries = sum(j.retries for j in r.jobs)
        gave_up = sum(j.gave_up for j in r.jobs)
        rows.append(
            f"faults.storm.{pol}.{tag},{dt_us / n:.0f},"
            f"hit={r.hit_rate:.2f};cost={r.cloud_cost:.2f};"
            f"retries={retries};gave_up={gave_up}"
        )
    gold = next(j for j in pre.jobs if j.name == "gold0")
    scav = next(j for j in pre.jobs if j.name == "scav0")
    admit_s = next(t for t, k, _ in gold.events if k == "admit")
    rows.append(
        f"faults.preemption_pressure.plan,{dt_us / n:.0f},"
        f"gold_hit={int(gold.met_deadline)};"
        f"scav_preemptions={scav.preemptions};"
        f"gold_admit_s={admit_s:.0f}"
    )
    # ---- acceptance rows (pinned by ci.sh bench-schema gate) -------
    h, b = recs[("plan", "hardened")], recs[("plan", "baseline")]
    clean = recs[("plan", "clean")]
    rows.append(
        f"faults.hardened_hit_ge_baseline,{dt_us / n:.0f},"
        f"{int(h.hit_rate >= b.hit_rate)}"
    )
    rows.append(
        f"faults.hardened_cost_bounded,{dt_us / n:.0f},"
        f"{int(h.cloud_cost <= 1.5 * clean.cloud_cost)}"
    )
    deadline = (
        gold.events[0][0] + sc.starve_patience_s + sc.eval_interval_s
    )
    rows.append(
        f"faults.preempt_admit_latency_ok,{dt_us / n:.0f},"
        f"{int(gold.met_deadline and admit_s <= deadline)}"
    )
    return rows
