# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

  bench_capacity_fit    — Fig. 4 / eqs. 6-7 (time-vs-cores log fits)
  bench_gamma_fit       — Fig. 5 / eq. 8 (time-vs-γ linear fit, REAL timing)
  bench_burst_deadline  — §3.3 core claim (static misses, adaptive meets)
  bench_overheads       — §3.3 message-size/monitor/checkpoint overheads
  bench_envs            — Tables 1-2 (platform + workload configuration)
  bench_kernels         — Pallas kernel µbenches (interpret mode)
  bench_roofline        — EXPERIMENTS §Roofline from dry-run artifacts
  bench_fused_scan      — scan-fused engine vs seed loop; temporal
                          blocking vs per-step halo exchange
  bench_fleet_scenarios — autoscaler policy suite × fleet scenarios
                          (hit-rate / cloud cost / useful-work frac)
"""
from __future__ import annotations

import sys
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import (  # noqa: E402
    bench_burst_deadline,
    bench_capacity_fit,
    bench_envs,
    bench_fleet_scenarios,
    bench_fused_scan,
    bench_gamma_fit,
    bench_kernels,
    bench_overheads,
    bench_roofline,
)

BENCHES = [
    ("envs", bench_envs),
    ("capacity_fit", bench_capacity_fit),
    ("gamma_fit", bench_gamma_fit),
    ("burst_deadline", bench_burst_deadline),
    ("fleet_scenarios", bench_fleet_scenarios),
    ("overheads", bench_overheads),
    ("kernels", bench_kernels),
    ("fused_scan", bench_fused_scan),
    ("roofline", bench_roofline),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in BENCHES:
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception as e:  # keep the harness going
            failures += 1
            print(f"{name}.FAILED,0,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
