# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure.

  bench_capacity_fit    — Fig. 4 / eqs. 6-7 (time-vs-cores log fits)
  bench_gamma_fit       — Fig. 5 / eq. 8 (time-vs-γ linear fit, REAL timing)
  bench_burst_deadline  — §3.3 core claim (static misses, adaptive meets)
  bench_overheads       — §3.3 message-size/monitor/checkpoint overheads
  bench_envs            — Tables 1-2 (platform + workload configuration)
  bench_kernels         — Pallas kernel µbenches (interpret mode)
  bench_roofline        — EXPERIMENTS §Roofline from dry-run artifacts
  bench_fused_scan      — overlap-and-fuse engine vs PR 1 scan vs seed
                          loop; HBM launch-boundary proxy
  bench_fleet_scenarios — autoscaler policy suite × fleet scenarios
                          (hit-rate / cloud cost / useful-work frac)
  bench_faults          — fault-storm robustness grid: hardened vs
                          unhardened loop under the same fault draws
                          (hit-rate / cost bound / preemption latency)
  bench_fleet_tournament— policy × scheduler × scenario tournament of
                          the multi-tenant queue layer (hit-rate /
                          cloud $ / fairness); ``--big`` adds the
                          thousand-job tier
  bench_real_elastic    — sim-vs-real elastic loop: the same squeeze
                          scenario through FleetSim and the real
                          orchestrator+FWISession; cost-aware vs
                          cost-blind planning brackets

Usage:
  python benchmarks/run.py [--only a,b,...] [--json PATH] [--big]

``--big`` adds the production-scale ``fused_scan_big`` tier (4096²,
8192×2048 streamed-vs-resident + sharded-schedule rows); it is off by
default because it takes minutes on CPU.

``--json`` additionally writes machine-readable results: one record per
row with the name/us_per_call/derived fields parsed apart, plus the
failure count — the schema the CI bench smoke pins.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback
from pathlib import Path

# 2 host devices so the sharded engine benches measure real parallelism
# (must precede the first jax import; no-op on real accelerators)
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2"
    ).strip()

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

# Persistent XLA compilation cache: the harness compiles dozens of
# executables that are byte-identical run to run — warm re-runs (and
# the CI bench smoke) load them from disk instead of recompiling.
# The dir follows JAX_COMPILATION_CACHE_DIR when set (ci.sh exports a
# workspace-local one); hit/miss counts come from the cache's own
# on-disk entries: every served entry touches its ``*-atime`` marker,
# every compile writes a new ``*-cache`` file (jax has no public
# counter API on this version, so the preamble counts files).
CACHE_DIR = os.environ.get(
    "JAX_COMPILATION_CACHE_DIR",
    str(Path(__file__).resolve().parents[1] / ".jax_cache"),
)


def _enable_compilation_cache() -> None:
    import jax

    jax.config.update("jax_compilation_cache_dir", CACHE_DIR)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)


def _cache_counts(since: float) -> tuple[int, int]:
    """(hits, misses) since ``since``: touched atime markers vs new
    cache entries."""
    touched = misses = 0
    try:
        for f in os.listdir(CACHE_DIR):
            p = os.path.join(CACHE_DIR, f)
            if f.endswith("-atime") and os.path.getmtime(p) >= since:
                touched += 1
            elif f.endswith("-cache") and os.path.getmtime(p) >= since:
                misses += 1
    except OSError:
        pass
    # a fresh compile writes BOTH files, so its atime touch is not a hit
    return max(touched - misses, 0), misses

from benchmarks import (  # noqa: E402
    bench_burst_deadline,
    bench_capacity_fit,
    bench_envs,
    bench_faults,
    bench_fleet_scenarios,
    bench_fleet_tournament,
    bench_fused_scan,
    bench_gamma_fit,
    bench_kernels,
    bench_overheads,
    bench_real_elastic,
    bench_roofline,
)

class _BigFusedScan:
    """`--big` tier shim: bench module whose run() is run_big()."""

    run = staticmethod(bench_fused_scan.run_big)


class _BigFleetTournament:
    """`--big` tier shim: thousand-job tournament (run_big())."""

    run = staticmethod(bench_fleet_tournament.run_big)


BENCHES = [
    ("envs", bench_envs),
    ("capacity_fit", bench_capacity_fit),
    ("gamma_fit", bench_gamma_fit),
    ("burst_deadline", bench_burst_deadline),
    ("fleet_scenarios", bench_fleet_scenarios),
    ("fleet_tournament", bench_fleet_tournament),
    ("faults", bench_faults),
    ("real_elastic", bench_real_elastic),
    ("overheads", bench_overheads),
    ("kernels", bench_kernels),
    ("fused_scan", bench_fused_scan),
    ("roofline", bench_roofline),
]


def parse_row(row: str) -> dict:
    """'name,us,derived' -> record (derived may itself hold commas)."""
    parts = row.split(",", 2)
    name = parts[0]
    try:
        us = float(parts[1]) if len(parts) > 1 else 0.0
    except ValueError:
        us = 0.0
    return {
        "name": name,
        "us_per_call": us,
        "derived": parts[2] if len(parts) > 2 else "",
    }


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help="comma-separated bench names to run")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write machine-readable results to PATH")
    ap.add_argument("--big", action="store_true",
                    help="include the production-scale fused_scan_big "
                         "tier (minutes on CPU)")
    args = ap.parse_args(argv)
    only = {s for s in args.only.split(",") if s}
    benches = list(BENCHES)
    if args.big or "fused_scan_big" in only:
        benches.append(("fused_scan_big", _BigFusedScan))
    if args.big or "fleet_tournament_big" in only:
        benches.append(("fleet_tournament_big", _BigFleetTournament))
    unknown = only - {name for name, _ in benches}
    if unknown:
        ap.error(f"unknown bench(es): {sorted(unknown)}")
    selected = [(n, m) for n, m in benches if not only or n in only]

    _enable_compilation_cache()
    import time as _time
    t_start = _time.time()
    n_existing = sum(1 for f in os.listdir(CACHE_DIR)
                     if f.endswith("-cache")) if os.path.isdir(CACHE_DIR) \
        else 0
    print(f"# jax compilation cache: {CACHE_DIR} "
          f"({n_existing} entries on disk)", file=sys.stderr)

    print("name,us_per_call,derived")
    failures = 0
    results: dict[str, list[dict]] = {}
    errors: dict[str, str] = {}
    for name, mod in selected:
        try:
            rows = list(mod.run())
            results[name] = [parse_row(r) for r in rows]
            for row in rows:
                print(row, flush=True)
        except Exception as e:  # keep the harness going
            failures += 1
            errors[name] = repr(e)
            print(f"{name}.FAILED,0,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
    hits, misses = _cache_counts(t_start)
    print(f"# jax compilation cache: {hits} hits, {misses} misses "
          f"this run", file=sys.stderr)
    if args.json:
        doc = {
            "benches": results,
            "failures": failures,
            "errors": errors,
            "compilation_cache": {"dir": CACHE_DIR, "hits": hits,
                                  "misses": misses},
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"json results -> {args.json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
