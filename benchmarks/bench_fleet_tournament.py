"""Policy × scheduler × scenario tournament of the fleet layer
(DESIGN.md §16).

Runs every placement Scheduler against every (per-job policy,
fleet-pool policy) pairing on the queued multi-tenant scenarios and
scores each cell on the three axes the multi-tenant story adds to the
paper's single-job trade-off:

  deadline-hit-rate   fraction of jobs finishing inside their deadline
  cloud cost          $ for elastic + pool chip-hours actually held
  fairness            mean demand-bounded min weighted share over the
                      contended window (allocator.min_weighted_share)

Acceptance (also asserted by CI on the smoke grid): at least one
deadline-aware (scheduler, policy) pair must beat the FIFO + no-burst
discipline baseline on hit-rate while spending less than FIFO +
always-burst — the paper's §3.3 claim lifted to fleet scale.

The default grid is the CI smoke (3 schedulers × 3 policy pairs ×
2 scenarios, tens of jobs, a few seconds).  ``run_big()`` — the
``--big`` / ``fleet_tournament_big`` tier — replays the rush world
with 1000+ concurrent jobs through the full scheduler grid, which is
minutes of simulated fleet time but still seconds of wall time per
cell.
"""
from __future__ import annotations

import time

from repro.sim import POLICY_FACTORIES, FleetSim
from repro.sim.scenarios import multi_tenant_rush, queued_scenarios

SEED = 0

#: CI smoke grid: 3 schedulers × 3 (policy, fleet-policy) pairs
SCHEDULERS = ("fifo", "fill", "best-fit")
PAIRS = (
    ("no-burst", "none"),        # discipline baseline
    ("react", "adapt"),          # deadline-aware, rate-controlled pool
    ("always-burst", "adapt"),   # spend ceiling
)

#: full grids for the big tier
SCHEDULERS_BIG = ("fifo", "fill", "best-fit", "worst-fit")
PAIRS_BIG = PAIRS + (("plan", "reg"), ("react", "token"),
                     ("react", "conpaas"))


def tournament(
    scenarios, schedulers=SCHEDULERS, pairs=PAIRS, seed: int = SEED
) -> dict[tuple[str, str, str, str], object]:
    out = {}
    for sc in scenarios:
        for sched in schedulers:
            for pol, fp in pairs:
                rec = FleetSim(
                    sc, POLICY_FACTORIES[pol], seed=seed,
                    scheduler=sched, fleet_policy=fp,
                ).run()
                out[(sc.name, sched, pol, fp)] = rec
    return out


def _rows(recs: dict, prefix: str, dt_us: float) -> list[str]:
    n = max(len(recs), 1)
    rows = [f"{prefix}.cells,{dt_us / n:.0f},{n}"]
    for (sc, sched, pol, fp), r in sorted(recs.items()):
        rows.append(
            f"{prefix}.{sc}.{sched}.{pol}+{fp},{dt_us / n:.0f},"
            f"hit={r.hit_rate:.2f};cost={r.cloud_cost:.2f};"
            f"fair={r.fairness:.3f};wait_s={r.mean_wait_s:.0f};"
            f"pool_cost={r.pool_cost:.2f};makespan_s={r.makespan_s:.0f}"
        )
    return rows


def _acceptance(recs: dict, prefix: str, scenario: str,
                dt_us: float, n: int) -> list[str]:
    """The §3.3 claim at fleet scale: some deadline-aware cell beats
    the FIFO discipline baseline on hit-rate AND spends less than the
    FIFO spend ceiling, on the overload scenario."""
    base = recs[(scenario, "fifo", "no-burst", "none")]
    ceil = recs[(scenario, "fifo", "always-burst", "adapt")]
    aware = [
        r for (sc, sched, pol, fp), r in recs.items()
        if sc == scenario and pol not in ("no-burst", "always-burst")
    ]
    wins = [
        r for r in aware
        if r.hit_rate > base.hit_rate and r.cloud_cost < ceil.cloud_cost
    ]
    return [
        f"{prefix}.aware_beats_fifo_noburst,{dt_us / n:.0f},"
        f"{int(bool(wins))}",
        f"{prefix}.jobs_conserved,{dt_us / n:.0f},"
        + str(int(all(
            all(j.state in ("finished", "running", "queued")
                for j in r.jobs)
            for r in recs.values()
        ))),
    ]


def run() -> list[str]:
    t0 = time.perf_counter()
    recs = tournament(queued_scenarios(SEED))
    dt_us = (time.perf_counter() - t0) * 1e6
    rows = _rows(recs, "fleet_tournament", dt_us)
    rows += _acceptance(recs, "fleet_tournament", "multi_tenant_rush",
                        dt_us, len(recs))
    return rows


def run_big() -> list[str]:
    """Thousand-job tier: the same rush world with n_jobs=1000 (all in
    flight — queued, running, or bursting — while the rush lasts)."""
    sc = multi_tenant_rush(
        SEED, n_jobs=1000, rate_per_hour=1200.0, budget_usd=6000.0,
    )
    t0 = time.perf_counter()
    recs = tournament([sc], SCHEDULERS_BIG, PAIRS_BIG)
    dt_us = (time.perf_counter() - t0) * 1e6
    rows = _rows(recs, "fleet_tournament_big", dt_us)
    rows += _acceptance(recs, "fleet_tournament_big",
                        "multi_tenant_rush", dt_us, len(recs))
    rows.append(
        f"fleet_tournament_big.n_jobs,{dt_us / len(recs):.0f},"
        f"{len(sc.jobs)}"
    )
    return rows
