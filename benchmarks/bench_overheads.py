"""Paper §3.3 overhead measurements: halo message size (the 21 KB claim),
measured ppermute seam latency feeding OverheadModel.with_measured_seam,
monitor/planner per-step cost, checkpoint save/restore wall time."""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.compat import shard_map
from repro.core import (
    BurstPlanner,
    DeadlinePredictor,
    LogCapacityModel,
    OverheadModel,
    StepTimeMonitor,
)
from repro.fwi.domain import (
    halo_bytes_per_step,
    halo_exchange_plan,
    stripe_mesh,
)
from repro.fwi.solver import FWIConfig


def measured_ppermute_latency_s(payload_bytes: int, iters: int = 50) -> float:
    """Median wall time of one jitted ``lax.ppermute`` dispatch over a
    seam-sized payload on this host's single-device stripe mesh.

    This is the dispatch-latency floor of a halo exchange — the number
    ``OverheadModel.with_measured_seam`` consumes (provenance documented
    there).  On real multi-pod hardware, rerun over the actual cross-DCI
    link to get the RTT-dominated figure.
    """
    mesh = stripe_mesh(1)
    n = max(payload_bytes // 4, 1)

    f = jax.jit(shard_map(
        lambda x: jax.lax.ppermute(x, "stripe", [(0, 0)]),
        mesh=mesh, in_specs=P("stripe"), out_specs=P("stripe"),
    ))
    x = jnp.zeros((n,), jnp.float32)
    f(x).block_until_ready()  # compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        f(x).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def run() -> list[str]:
    rows = []
    cfg = FWIConfig()  # paper Table 2 geometry: 600 x 600, 4 shots
    hb = halo_bytes_per_step(cfg, 4)
    rows.append(f"overheads.halo_bytes_per_seam_step,0,{hb}")
    rows.append(f"overheads.halo_kb_per_seam_step,0,{hb / 1024:.1f}")
    rows.append("overheads.paper_claim_kb,0,21")
    # temporal blocking: k x fewer seam messages per step (the slow-link
    # cost is latency-dominated at 21 KB payloads)
    for k in (1, 4):
        plan = halo_exchange_plan(cfg, 4, k=k)
        rows.append(
            f"overheads.halo_plan_k{k},0,"
            f"msgs_per_step={plan['ppermutes_per_step']:.2f};"
            f"kb_per_exchange={plan['bytes_per_exchange'] / 1024:.1f};"
            f"kb_per_step={plan['bytes_per_step'] / 1024:.1f}"
        )

    # measured seam: ppermute dispatch latency over the k=1 payload,
    # folded into the planner's OverheadModel (ROADMAP item; provenance
    # in the OverheadModel docstring) — temporal blocking divides the
    # recurring per-step seam tax by k
    plan1 = halo_exchange_plan(cfg, 4, k=1)
    t_pp = measured_ppermute_latency_s(int(plan1["bytes_per_exchange"]))
    rows.append(
        f"overheads.ppermute_latency_us,{t_pp * 1e6:.1f},{t_pp * 1e6:.1f}"
    )
    for k in (1, 4):
        plan = halo_exchange_plan(cfg, 4, k=k)
        om = OverheadModel().with_measured_seam(plan, t_pp)
        rows.append(
            f"overheads.measured_seam_s_per_step_k{k},{t_pp * 1e6:.1f},"
            f"{om.seam_s_per_step():.6f}"
        )

    # overlap-adjusted seam: the overlapped engine hides the exchange
    # behind the stripe-interior compute (per-block cost becomes
    # max(interior, seam) + boundary — DESIGN.md §13); the planner sees
    # only the un-hidden residue.  compute_s_per_step from a quick
    # measured step of the fused block runner on this host.
    from repro.fwi.solver import ShotState, make_block_runner

    st = ShotState.init(cfg)
    blk = make_block_runner(cfg, k=4, collect_traces=False)
    jax.block_until_ready(blk(st.p, st.p_prev, 0, 8))     # compile
    t0 = time.perf_counter()
    jax.block_until_ready(blk(st.p, st.p_prev, 0, 8))
    t_compute = (time.perf_counter() - t0) / 8
    for k in (1, 4):
        plan = halo_exchange_plan(cfg, 4, k=k)
        om = OverheadModel().with_overlapped_seam(plan, t_pp, t_compute)
        rows.append(
            f"overheads.overlapped_seam_s_per_step_k{k},{t_pp * 1e6:.1f},"
            f"eff={om.seam_s_per_step():.6f};"
            f"overlap_frac={plan['overlap_fraction']:.3f};"
            f"compute_s={t_compute:.4f}"
        )

    # measured-vs-modeled seam (DESIGN.md §15): the REAL probe —
    # cross-device packed ppermute + measured stripe-interior compute
    # (fwi.calibrate.measure_seam_latency) — against the planner's two
    # seam models, so the with_measured_seam dispatch floor is auditable
    # against the overlap-credited figure sim/scenarios.py actually uses
    from repro.fwi.calibrate import measure_seam_latency

    probe = measure_seam_latency(cfg, n_stripes=2, k=4, iters=20)
    om_floor = OverheadModel().with_measured_seam(
        probe["plan"], probe["ppermute_latency_s"]
    )
    om_probe = OverheadModel().with_overlapped_seam(
        probe["plan"], probe["ppermute_latency_s"],
        probe["interior_compute_s_per_step"],
    )
    rows.append(
        f"overheads.seam_probe,{probe['ppermute_latency_s'] * 1e6:.1f},"
        f"ppermute_us={probe['ppermute_latency_s'] * 1e6:.1f};"
        f"interior_ms_per_step={probe['interior_compute_s_per_step'] * 1e3:.3f};"
        f"mesh_devices={probe['mesh_devices']};backend={probe['backend']}"
    )
    rows.append(
        f"overheads.seam_measured_vs_modeled,"
        f"{om_floor.seam_s_per_step() * 1e6:.1f},"
        f"floor_s_per_step={om_floor.seam_s_per_step():.6f};"
        f"overlapped_s_per_step={om_probe.seam_s_per_step():.6f};"
        f"hidden={om_probe.seam_s_per_step() == 0.0}"
    )

    # monitor + planner per-step cost
    mon = StepTimeMonitor()
    pred = DeadlinePredictor(1000.0)
    chips = [16, 32, 64, 128, 256]
    m = LogCapacityModel.fit(chips, [2000.0 / c for c in chips])
    planner = BurstPlanner(cluster_model=m, cloud_model=m,
                           chips_cluster=256, legal_slices=chips)
    for i in range(64):
        mon.observe(1.0 + 0.01 * i)
    n = 2000
    t0 = time.perf_counter()
    for i in range(n):
        mon.observe(1.0)
        est = pred.estimate(mon, i, 10 * n, float(i))
        planner.plan(est, i, 10 * n, observed_step_s=1.0,
                     effective_chips=256)
    per_call_us = (time.perf_counter() - t0) / n * 1e6
    rows.append(f"overheads.monitor_plus_planner,{per_call_us:.2f},"
                f"{per_call_us:.2f}")

    # checkpoint save/restore (64 MB state)
    state = {"p": jnp.asarray(np.random.default_rng(0)
                              .standard_normal((4, 1024, 2048))
                              .astype(np.float32))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        t0 = time.perf_counter()
        mgr.save(1, state)
        t_save = time.perf_counter() - t0
        t0 = time.perf_counter()
        mgr.restore(
            {"p": jnp.zeros((4, 1024, 2048), jnp.float32)}, step=1
        )
        t_restore = time.perf_counter() - t0
    rows.append(f"overheads.ckpt_save_64mb_s,{t_save * 1e6:.0f},"
                f"{t_save:.3f}")
    rows.append(f"overheads.ckpt_restore_64mb_s,{t_restore * 1e6:.0f},"
                f"{t_restore:.3f}")
    return rows
