"""Sim-vs-real validation of the elastic loop (DESIGN.md §14).

Closes the loop the ROADMAP asked for: the SAME deadline-squeeze
scenario runs through (a) the FleetSim discrete-event world driving
SimSession and (b) the real ElasticOrchestrator driving FWISession
(real wavefield compute; platform-model clock), both under the `plan`
policy, and the rows report predicted-vs-actual hit / cloud-$ /
scale-overhead.  A second bracket scores cost-aware vs cost-blind
planning (BurstPlanner.cost_weight) in both worlds on the superlinear
scaling story:

  real_elastic.costaware_cheaper_at_equal_hit   fleet world — the
      cost-aware planner buys the SAME deadline hit-rate for strictly
      fewer cloud $ than the deadline-first minimal-slice solve
  real_elastic.real_costaware_no_worse          real world — under
      sustained congestion the cost-aware slice hits a deadline the
      under-escalating cost-blind solve misses
"""
from __future__ import annotations

import time

from repro.core import (
    BurstPlanner,
    DeadlinePredictor,
    ElasticOrchestrator,
    LogCapacityModel,
    OverheadModel,
    PodSpec,
    Resources,
)
from repro.fwi.driver import (
    TimeModel,
    elastic_stripes_for,
    fwi_session_factory,
)
from repro.fwi.solver import FWIConfig
from repro.sim import FleetSim, PlanAutoscaler, superlinear_cache
from repro.sim.fleet import CloudProvider, JobSpec
from repro.sim.scenarios import Scenario

#: shared world constants for the squeeze scenario — one knob set so
#: the two worlds stay comparable (DESIGN.md §14 boundary table)
LEGAL = (16, 32, 64, 128)
ONPREM = 64
W = 64.0                       # chip·s per step -> 1.0 s/step on-prem
K = 1.4
PRICE = 3.0
STEPS = 120
DEADLINE0, SQUEEZED = 400.0, 105.0
OV = OverheadModel(ckpt_s=5.0, provision_s=10.0, restart_s=5.0)
CFG = FWIConfig(nz=48, nx=96, timesteps=STEPS, n_shots=1, sponge_width=8)


def _planner(alpha: float = 1.0, cost_weight: float = 0.5):
    cs = sorted(set(LEGAL) | {ONPREM})
    return BurstPlanner(
        cluster_model=LogCapacityModel.fit(
            cs, [W * ONPREM ** (alpha - 1.0) / c ** alpha for c in cs]
        ),
        cloud_model=LogCapacityModel.fit(
            cs, [K * W * ONPREM ** (alpha - 1.0) / c ** alpha for c in cs]
        ),
        chips_cluster=ONPREM, legal_slices=list(LEGAL), overheads=OV,
        price_per_chip_hour=PRICE, cost_weight=cost_weight,
    )


def _real_run(*, tm: TimeModel, deadline_changes=(), alpha: float = 1.0,
              cost_weight: float = 0.5, deadline: float = DEADLINE0):
    """One policy-driven FWISession run on the real orchestrator."""
    import jax

    n_grown = 2 if len(jax.devices()) > 1 else 1
    orch = ElasticOrchestrator(
        planner=_planner(alpha, cost_weight),
        predictor=DeadlinePredictor(deadline),
        check_every=8, ckpt_every=40, eval_interval_s=7.0,
        cloud_slowdown=K,
    )
    return orch.run(
        session_factory=fwi_session_factory(
            CFG, tm, stripes_for=elastic_stripes_for(1, n_grown),
            exchange_interval=4, scan_block=8,
        ),
        initial=Resources(pods=[PodSpec(chips=ONPREM, name="cluster")],
                          shares=[1.0]),
        steps_total=STEPS,
        autoscaler=PlanAutoscaler(),
        deadline_changes=deadline_changes,
    )


def _squeeze_mirror() -> Scenario:
    """The real squeeze scenario, expressed as a 1-job fleet world."""
    return Scenario(
        name="squeeze_mirror",
        jobs=(JobSpec(name="job0", arrival_s=0.0, steps_total=STEPS,
                      deadline_s=DEADLINE0, chip_seconds_per_step=W,
                      onprem_chips=ONPREM),),
        deadline_changes=((20.0, "job0", SQUEEZED),
                          (60.0, "job0", DEADLINE0)),
        site_chips=ONPREM,
        cloud=CloudProvider(legal_slices=LEGAL, provision_delay_s=10.0,
                            price_per_chip_hour=PRICE, slowdown=K),
        overheads=OV, eval_interval_s=7.0, ckpt_every=40,
        planner_cost_weight=0.5,
    )


def _scale_kinds(events):
    return [e.detail["kind"] for e in events if e.kind == "scale"]


def run() -> list[str]:
    rows: list[str] = []
    t0 = time.perf_counter()

    # --- the same squeeze through both worlds -------------------------
    real = _real_run(
        tm=TimeModel(chip_seconds_per_step=W, jitter=0.01),
        deadline_changes=[(20.0, SQUEEZED), (60.0, DEADLINE0)],
    )
    kinds = _scale_kinds(real.events)
    real_ov = sum(e.detail["overhead_s"] for e in real.events
                  if e.kind == "scale")
    sim = FleetSim(_squeeze_mirror(), PlanAutoscaler, seed=0).run()
    sj = sim.jobs[0]
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        f"real_elastic.real_squeeze,{us:.0f},"
        f"hit={int(real.met_deadline)};cost={real.cloud_cost_usd:.2f};"
        f"elapsed_s={real.elapsed_s:.0f};overhead_s={real_ov:.0f};"
        f"grows={kinds.count('grow')};retires={kinds.count('retire')}"
    )
    rows.append(
        f"real_elastic.sim_squeeze,{us:.0f},"
        f"hit={sim.hit_rate:.2f};cost={sim.cloud_cost:.2f};"
        f"elapsed_s={sj.elapsed_s:.0f};overhead_s={sj.overhead_s:.0f}"
    )
    rows.append(
        f"real_elastic.sim_vs_real,{us:.0f},"
        f"hit_match={int(int(real.met_deadline) == int(sim.hit_rate))};"
        f"cost_ratio={real.cloud_cost_usd / max(sim.cloud_cost, 1e-9):.2f};"
        f"elapsed_ratio={real.elapsed_s / max(sj.elapsed_s, 1e-9):.2f}"
    )

    # --- cost-aware vs cost-blind, fleet world ------------------------
    aware = FleetSim(superlinear_cache(0), PlanAutoscaler, seed=0).run()
    blind = FleetSim(
        superlinear_cache(0, cost_weight=0.0), PlanAutoscaler, seed=0
    ).run()
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        f"real_elastic.superlinear_sim_aware,{us:.0f},"
        f"hit={aware.hit_rate:.2f};cost={aware.cloud_cost:.2f}"
    )
    rows.append(
        f"real_elastic.superlinear_sim_blind,{us:.0f},"
        f"hit={blind.hit_rate:.2f};cost={blind.cloud_cost:.2f}"
    )
    rows.append(
        f"real_elastic.costaware_cheaper_at_equal_hit,{us:.0f},"
        f"{int(aware.hit_rate == blind.hit_rate and aware.cloud_cost < blind.cloud_cost)}"
    )

    # --- cost-aware vs cost-blind, real world -------------------------
    # sustained congestion on the superlinear law: the cost-blind
    # minimal-slice solve under-escalates (each resize sizes for the
    # calibrated estimate of the moment) and misses the deadline the
    # cost-aware slice hits
    alpha = 1.3
    w_sup = W * ONPREM ** (alpha - 1.0)
    tm = TimeModel(chip_seconds_per_step=w_sup, scaling_alpha=alpha,
                   congestion_from=5, congestion_factor=2.0, jitter=0.01)
    r_aware = _real_run(tm=tm, alpha=alpha, cost_weight=0.6,
                        deadline=225.0)
    r_blind = _real_run(tm=tm, alpha=alpha, cost_weight=0.0,
                        deadline=225.0)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        f"real_elastic.superlinear_real_aware,{us:.0f},"
        f"hit={int(r_aware.met_deadline)};cost={r_aware.cloud_cost_usd:.2f};"
        f"elapsed_s={r_aware.elapsed_s:.0f}"
    )
    rows.append(
        f"real_elastic.superlinear_real_blind,{us:.0f},"
        f"hit={int(r_blind.met_deadline)};cost={r_blind.cloud_cost_usd:.2f};"
        f"elapsed_s={r_blind.elapsed_s:.0f}"
    )
    rows.append(
        f"real_elastic.real_costaware_no_worse,{us:.0f},"
        f"{int(r_aware.met_deadline >= r_blind.met_deadline)}"
    )
    return rows
