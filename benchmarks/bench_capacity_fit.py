"""Paper Fig. 4 / eqs. 6-7: time-vs-cores log-law fits per environment.

Reproduces the paper's §3.2 methodology: run the (FWI) workload at several
core counts in each environment, fit L(c) = -A·ln c + B on log10 time, and
derive the correction factor K.  The paper reports the cloud ~150% slower
at 10 cores shrinking to ~50% at 40 cores; we emit our fitted coefficients
and ratio curve for comparison (cloud slowdown here is the configurable
simulation parameter; the fitting path is the production code).
"""
from __future__ import annotations

import time

from repro.core.capacity import LogCapacityModel, correction_factor
from repro.fwi.calibrate import fit_capacity_models
from repro.fwi.solver import FWIConfig


def run() -> list[str]:
    cfg = FWIConfig(nz=96, nx=192, timesteps=30, n_shots=1, sponge_width=8)
    t0 = time.perf_counter()
    cluster, cloud, samples = fit_capacity_models(
        cfg, chip_counts=(10, 20, 30, 40, 64, 128), cloud_slowdown=1.5,
    )
    dt_us = (time.perf_counter() - t0) * 1e6
    r2c = cluster.r2(samples["chips"], samples["t_cluster"])
    r2d = cloud.r2(samples["chips"], samples["t_cloud"])
    ratio10 = cloud.predict_time(10) / cluster.predict_time(10)
    ratio40 = cloud.predict_time(40) / cluster.predict_time(40)
    rows = [
        f"capacity_fit.cluster_A,{dt_us:.0f},{cluster.A:.4f}",
        f"capacity_fit.cluster_B,{dt_us:.0f},{cluster.B:.4f}",
        f"capacity_fit.cloud_A,{dt_us:.0f},{cloud.A:.4f}",
        f"capacity_fit.cloud_B,{dt_us:.0f},{cloud.B:.4f}",
        f"capacity_fit.r2_cluster,{dt_us:.0f},{r2c:.6f}",
        f"capacity_fit.r2_cloud,{dt_us:.0f},{r2d:.6f}",
        f"capacity_fit.cloud_over_cluster_at10,{dt_us:.0f},{ratio10:.3f}",
        f"capacity_fit.cloud_over_cluster_at40,{dt_us:.0f},{ratio40:.3f}",
        f"capacity_fit.K_at40,{dt_us:.0f},"
        f"{correction_factor(cloud, cluster, 40):.4f}",
        # paper's own fitted coefficients for side-by-side (eqs. 6-7)
        "capacity_fit.paper_eq6_cloud_A,0,0.77",
        "capacity_fit.paper_eq6_cloud_B,0,7.1",
        "capacity_fit.paper_eq7_cluster_A,0,0.65",
        "capacity_fit.paper_eq7_cluster_B,0,6.5",
    ]
    return rows
