"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*/<arch>/<shape>.json produced by
repro.launch.dryrun and emits one row per cell plus aggregates.  Run the
dry-run first: `python -m repro.launch.dryrun --all`.

Also emits the ANALYTIC shot-batch traffic model rows
(``launch.hlo_cost.shot_batch_strip_bytes``, DESIGN.md §17) — no
artifacts needed: the memory-bound ceiling of batching S shots into one
stencil sweep, i.e. how much of the ``4·S → 2·S + 2`` array-read drop
a perfectly memory-bound engine could bank."""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def shot_batch_rows(nz: int = 600, nx: int = 600,
                    s_values: tuple[int, ...] = (1, 2, 4, 8)) -> list[str]:
    """traffic-model rows: batched-vs-vmapped HBM bytes per sweep."""
    from repro.launch.hlo_cost import shot_batch_strip_bytes

    rows = []
    for s in s_values:
        m = shot_batch_strip_bytes(nz, nx, s)
        rows.append(
            f"roofline.shot_batch.{nz}x{nx}.s{s}.traffic_ratio,"
            f"{m['batched_bytes'] / 1e6:.1f},{m['traffic_ratio']:.4f}"
        )
        rows.append(
            f"roofline.shot_batch.{nz}x{nx}.s{s}.launch_ratio,"
            f"{m['launches_batched']},{m['launches_vmapped']}"
        )
    return rows


def run() -> list[str]:
    rows = shot_batch_rows()
    cells = sorted(ARTIFACTS.glob("*/*/*.json"))
    if not cells:
        return rows + ["roofline.no_artifacts_run_dryrun_first,0,0"]
    n_ok = n_skip = n_err = 0
    worst = (2.0, None)
    for p in cells:
        r = json.loads(p.read_text())
        tag = f"{r['mesh']}.{r['arch']}.{r['shape']}"
        if r["status"] == "skipped":
            n_skip += 1
            continue
        if r["status"] != "ok":
            n_err += 1
            rows.append(f"roofline.{tag}.ERROR,0,1")
            continue
        n_ok += 1
        rl = r["roofline"]
        us = rl["step_time_lower_bound_s"] * 1e6
        rows.append(f"roofline.{tag}.frac,{us:.0f},"
                    f"{rl['roofline_fraction']:.4f}")
        rows.append(f"roofline.{tag}.dominant,{us:.0f},{rl['dominant']}")
        if r["mesh"] == "single" and rl["roofline_fraction"] < worst[0] \
                and r["shape"] == "train_4k":
            worst = (rl["roofline_fraction"], tag)
    rows.append(f"roofline.cells_ok,0,{n_ok}")
    rows.append(f"roofline.cells_skipped_by_design,0,{n_skip}")
    rows.append(f"roofline.cells_error,0,{n_err}")
    if worst[1]:
        rows.append(f"roofline.worst_train_cell,0,{worst[1]}")
    return rows
