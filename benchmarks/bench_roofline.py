"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/*/<arch>/<shape>.json produced by
repro.launch.dryrun and emits one row per cell plus aggregates.  Run the
dry-run first: `python -m repro.launch.dryrun --all`."""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run() -> list[str]:
    rows = []
    cells = sorted(ARTIFACTS.glob("*/*/*.json"))
    if not cells:
        return ["roofline.no_artifacts_run_dryrun_first,0,0"]
    n_ok = n_skip = n_err = 0
    worst = (2.0, None)
    for p in cells:
        r = json.loads(p.read_text())
        tag = f"{r['mesh']}.{r['arch']}.{r['shape']}"
        if r["status"] == "skipped":
            n_skip += 1
            continue
        if r["status"] != "ok":
            n_err += 1
            rows.append(f"roofline.{tag}.ERROR,0,1")
            continue
        n_ok += 1
        rl = r["roofline"]
        us = rl["step_time_lower_bound_s"] * 1e6
        rows.append(f"roofline.{tag}.frac,{us:.0f},"
                    f"{rl['roofline_fraction']:.4f}")
        rows.append(f"roofline.{tag}.dominant,{us:.0f},{rl['dominant']}")
        if r["mesh"] == "single" and rl["roofline_fraction"] < worst[0] \
                and r["shape"] == "train_4k":
            worst = (rl["roofline_fraction"], tag)
    rows.append(f"roofline.cells_ok,0,{n_ok}")
    rows.append(f"roofline.cells_skipped_by_design,0,{n_skip}")
    rows.append(f"roofline.cells_error,0,{n_err}")
    if worst[1]:
        rows.append(f"roofline.worst_train_cell,0,{worst[1]}")
    return rows
