"""Kernel micro-benchmarks.

Interpret-mode vs compiled semantics: off-TPU, the Pallas kernels run in
INTERPRET mode (auto-selected by ``stencil.kernel.default_interpret``) —
the kernel body executes with real Pallas semantics (BlockSpec tiling,
halo views, @pl.when predication are all exercised), but each grid step
is a Python-driven emulation, so absolute ``*_pallas_*`` times here are
one to two orders of magnitude above both the compiled-TPU times and the
XLA-fused ``*_ref_*`` rows.  They are regression trackers for the
kernels' *structure* (a tiling bug usually shows up as a blow-up), NOT
TPU projections — those come from the roofline analysis.  On a TPU
backend the same rows time the compiled kernels and are directly
comparable.  Each row also emits the kernel's arithmetic-intensity
estimate (flops/byte) used to pick block shapes; the stencil section
additionally reports the ``autotune_bz`` winner for the paper grid."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import attention
from repro.kernels.rmsnorm.ops import rmsnorm_residual
from repro.kernels.ssd.ops import ssd_chunk
from repro.kernels.stencil.ops import autotune_bz, wave_step


def _time(fn, *args, n=3, **kw):
    fn(*args, **kw)  # compile
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list[str]:
    rows = []
    # stencil: 512x512 strip-tiled
    nz = nx = 512
    p = jnp.ones((nz, nx), jnp.float32)
    v = jnp.full((nz, nx), 0.1, jnp.float32)
    us_ref = _time(wave_step, p, p, v, v, use_pallas=False)
    us_pal = _time(wave_step, p, p, v, v, use_pallas=True, bz=128)
    flops = nz * nx * 16
    bytes_ = nz * nx * 4 * 6
    rows += [
        f"kernels.stencil_ref_512,{us_ref:.0f},{flops / bytes_:.2f}",
        f"kernels.stencil_pallas_512,{us_pal:.0f},{flops / bytes_:.2f}",
        f"kernels.stencil_autotune_bz_512,0,{autotune_bz(nz, nx)}",
    ]
    # flash attention 1x4x512x64
    q = jnp.ones((1, 4, 512, 64), jnp.float32)
    k = jnp.ones((1, 2, 512, 64), jnp.float32)
    us_ref = _time(attention, q, k, k, causal=True)
    us_pal = _time(attention, q, k, k, causal=True, use_pallas=True,
                   bq=128, bk=128)
    ai = (2 * 512 * 64) / (3 * 64 * 4)  # per-row flops/bytes order
    rows += [
        f"kernels.flash_ref_512,{us_ref:.0f},{ai:.1f}",
        f"kernels.flash_pallas_512,{us_pal:.0f},{ai:.1f}",
    ]
    # ssd chunk (8,4,128,64,64)
    xdt = jnp.ones((8, 4, 128, 64), jnp.float32)
    bm = jnp.ones((8, 4, 128, 64), jnp.float32)
    cs = -jnp.cumsum(jnp.full((8, 4, 128), 0.01), -1)
    us_ref = _time(ssd_chunk, xdt, bm, bm, cs)
    us_pal = _time(ssd_chunk, xdt, bm, bm, cs, use_pallas=True)
    rows += [
        f"kernels.ssd_ref_128,{us_ref:.0f},64",
        f"kernels.ssd_pallas_128,{us_pal:.0f},64",
    ]
    # rmsnorm 4096x1024
    x = jnp.ones((4096, 1024), jnp.float32)
    sc = jnp.ones((1024,), jnp.float32)
    us_ref = _time(rmsnorm_residual, x, x, sc)
    us_pal = _time(rmsnorm_residual, x, x, sc, use_pallas=True, bn=256)
    rows += [
        f"kernels.rmsnorm_ref_4kx1k,{us_ref:.0f},0.6",
        f"kernels.rmsnorm_pallas_4kx1k,{us_pal:.0f},0.6",
    ]
    return rows
