"""Property-based suite for the fleet-of-jobs layer (DESIGN.md §16).

Randomized small worlds through the full FleetController — queue,
schedulers, pool policies, caps — checking the invariants the
tournament's numbers silently rely on:

  * the site is never over-allocated at any event time
  * billed cloud chip-seconds reconstruct EXACTLY from the event log
    (job admit/scale/rollback/finish events + fleet pool events)
  * the global $ gate: no provisioning request is issued after spend
    crosses the budget; the chip cap bounds held cloud chips always
  * fair-share never starves a nonzero-weight tenant (the starvation
    guard: nobody is admitted past a patience-expired weighted entry)
  * queue conservation: every job ends finished / running / queued —
    none dropped, none duplicated

The worlds come from a seeded generator, so the suite is deterministic
and runs everywhere; when ``hypothesis`` is installed (the
test_core_properties.py arrangement) it additionally fuzzes the same
generator through the same invariant checks, with shrinking on the
world seed.  Pure-primitive properties (max_min_fair_allocation
water-filling, min_weighted_share bounds, floor_to_legal_slice,
CentralQueue ordering, scheduler placement) are checked directly.
"""
import math
import random

import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:          # pragma: no cover - env dependent
    st = None

from repro.core import (
    floor_to_legal_slice,
    max_min_fair_allocation,
    min_weighted_share,
)
from repro.sim import (
    POLICY_FACTORIES,
    CentralQueue,
    FleetSim,
    JobSpec,
    QueueEntry,
    Tenant,
)
from repro.sim.scenarios import Scenario
from repro.sim.schedulers import (
    SCHEDULER_FACTORIES,
    BestFitScheduler,
    FifoScheduler,
    FillScheduler,
    WorstFitScheduler,
)

LEGAL = (16, 32, 64, 128, 256)
PRICE = 3.0
TENANTS = (
    Tenant("a", weight=2.0, priority=1.0),
    Tenant("b", weight=1.0),
    Tenant("z", weight=0.0),            # scavenger
)


def make_world(rng: random.Random):
    """One small random queued world plus the knobs of one run."""
    n = rng.randint(3, 8)
    t = 0.0
    jobs = []
    for i in range(n):
        t += rng.uniform(0.0, 150.0)
        chips = rng.choice([16, 32, 64])
        jobs.append(JobSpec(
            name=f"j{i}", arrival_s=t,
            steps_total=rng.randint(4, 12),
            deadline_s=rng.uniform(300.0, 2500.0),
            chip_seconds_per_step=8.0 * chips,
            onprem_chips=chips,
            tenant=rng.choice(["a", "b", "z"]),
        ))
    sc = Scenario(
        name="prop", jobs=tuple(jobs), site_chips=128,
        scheduler=rng.choice(sorted(SCHEDULER_FACTORIES)),
        fleet_policy=rng.choice(
            ["none", "adapt", "reg", "conpaas", "token"]
        ),
        cloud_chip_cap=rng.choice([None, 64, 192]),
        cloud_budget_usd=rng.choice([math.inf, 30.0, 150.0]),
        tenants=TENANTS,
        starve_patience_s=rng.choice([240.0, 900.0]),
    )
    policy = rng.choice(["no-burst", "react", "always-burst"])
    return sc, policy, rng.randint(0, 3)


N_WORLDS = 18
_RECORDS: dict[int, object] = {}


def world(i: int):
    return make_world(random.Random(i))


def record(i: int):
    if i not in _RECORDS:
        sc, policy, seed = world(i)
        _RECORDS[i] = FleetSim(
            sc, POLICY_FACTORIES[policy], seed=seed
        ).run()
    return _RECORDS[i]


# ---- event-log reconstruction helpers -------------------------------------

def _holdings(job):
    """(time, cloud_chips_held) step function for one job from its own
    event log: rented home pod from the admit placement, elastic pod
    from scale/rollback events."""
    steps = []
    rented = 0
    for t, kind, d in job.events:
        if kind == "admit":
            rented = d["chips"] if d["placement"] == "cloud" else 0
            steps.append((t, rented))
        elif kind == "arrival" and not steps:
            steps.append((t, 0))       # immediate-mode placement
        elif kind in ("scale", "spot_reclaim", "node_failure"):
            steps.append((t, rented + d["cloud_chips"]))
        elif kind == "finish":
            steps.append((t, 0))
    return steps


def _integrate(steps, end_s):
    total = 0.0
    for (t0, c), (t1, _) in zip(steps, steps[1:]):
        total += c * (t1 - t0)
    if steps:
        t_last, c_last = steps[-1]
        total += c_last * max(end_s - t_last, 0.0)
    return total


def _pool_steps(fleet_events):
    """(time, pool_free) step function from the fleet event log."""
    delta = {
        "pool_online": +1, "pool_return": +1,
        "pool_draw": -1, "pool_host": -1,
        "pool_shrink": -1, "pool_drain": -1,
    }
    level = 0
    steps = [(0.0, 0)]
    for t, kind, d in fleet_events:
        if kind in delta:
            level += delta[kind] * d["chips"]
            steps.append((t, level))
    return steps


# ---- the invariant checks (shared by seeded + hypothesis drivers) ---------

def check_site_never_over_allocated(sc, r):
    # at equal timestamps releases come first: _finish frees the site
    # and then runs the admission pass at the same virtual time
    changes = []
    for job in r.jobs:
        site_chips = 0
        for t, kind, d in job.events:
            if kind == "admit" and d["placement"] == "site":
                site_chips = d["chips"]
                changes.append((t, 1, site_chips))
                assert d["site_used_after"] <= sc.site_chips
            elif kind == "finish" and site_chips:
                changes.append((t, 0, -site_chips))
    used = 0
    for _, _, dc in sorted(changes, key=lambda x: (x[0], x[1])):
        used += dc
        assert 0 <= used <= sc.site_chips


def check_billing_reconstructs(sc, r):
    for job in r.jobs:
        if not job.finished:
            continue
        want = _integrate(_holdings(job), job.finish_s)
        assert job.cloud_chip_s == pytest.approx(want, abs=1e-6), job.name
    steps = _pool_steps(r.fleet_events)
    assert all(level >= 0 for _, level in steps)
    if r.queued_at_end == 0 and steps[-1][1] == 0:
        pool_s = _integrate(steps, steps[-1][0])
        assert r.pool_cost == pytest.approx(
            pool_s / 3600.0 * PRICE, abs=1e-6
        )


def check_budget_gate_and_chip_cap(sc, r):
    if sc.cloud_chip_cap is not None:
        assert all(c <= sc.cloud_chip_cap for _, c in r.cloud_timeline)
    if sc.cloud_budget_usd == math.inf:
        return
    job_steps = [_holdings(j) for j in r.jobs]
    ends = [j.finish_s if j.finished else math.inf for j in r.jobs]
    pool = _pool_steps(r.fleet_events)

    def spent(t):
        chip_s = sum(
            _integrate([(t0, c) for t0, c in s if t0 <= t], min(t, e))
            for s, e in zip(job_steps, ends)
        )
        chip_s += _integrate([(t0, c) for t0, c in pool if t0 <= t], t)
        return chip_s / 3600.0 * PRICE

    reqs = [
        t for j in r.jobs for t, k, _ in j.events
        if k == "provision_request"
    ] + [
        t for t, k, _ in r.fleet_events
        if k == "pool_provision_request"
    ]
    for t in reqs:
        assert spent(t) < sc.cloud_budget_usd + 1e-9


def check_no_weighted_tenant_starved(sc, r):
    for job in r.jobs:
        for _, kind, d in job.events:
            if kind == "admit" and d["expired_present"]:
                # the starvation guard: while a weighted tenant waits
                # past patience, only expired entries are admitted
                assert d["entry_expired"], job.name


def check_queue_conservation(sc, r):
    assert len(r.jobs) == len(sc.jobs)
    assert {j.name for j in r.jobs} == {j.name for j in sc.jobs}
    for job in r.jobs:
        assert job.state in ("finished", "running", "queued", "pending")
        kinds = [k for _, k, _ in job.events]
        if job.finished:
            assert "arrival" in kinds
            assert kinds.count("finish") == 1
        if job.state == "queued":
            assert "arrival" not in kinds
    if r.queued_at_end == 0:
        assert all(
            any(k == "arrival" for _, k, _ in j.events) for j in r.jobs
        )


def check_scores_well_formed(sc, r):
    assert 0.0 <= r.fairness <= 1.0
    assert r.mean_wait_s <= r.max_wait_s + 1e-9
    assert all(j.wait_s >= 0 for j in r.jobs)
    assert 0.0 <= r.hit_rate <= 1.0


CHECKS = [
    check_site_never_over_allocated,
    check_billing_reconstructs,
    check_budget_gate_and_chip_cap,
    check_no_weighted_tenant_starved,
    check_queue_conservation,
    check_scores_well_formed,
]


# ---- seeded drivers (always run) ------------------------------------------

@pytest.mark.parametrize("i", range(N_WORLDS))
def test_site_never_over_allocated(i):
    check_site_never_over_allocated(world(i)[0], record(i))


@pytest.mark.parametrize("i", range(N_WORLDS))
def test_billing_reconstructs_from_event_log(i):
    check_billing_reconstructs(world(i)[0], record(i))


@pytest.mark.parametrize("i", range(N_WORLDS))
def test_budget_gate_and_chip_cap(i):
    check_budget_gate_and_chip_cap(world(i)[0], record(i))


@pytest.mark.parametrize("i", range(N_WORLDS))
def test_no_weighted_tenant_starved(i):
    check_no_weighted_tenant_starved(world(i)[0], record(i))


@pytest.mark.parametrize("i", range(N_WORLDS))
def test_queue_conservation(i):
    check_queue_conservation(world(i)[0], record(i))


@pytest.mark.parametrize("i", range(N_WORLDS))
def test_fairness_and_waits_well_formed(i):
    check_scores_well_formed(world(i)[0], record(i))


# ---- hypothesis driver (when installed) -----------------------------------

if st is not None:
    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(0, 10 ** 6))
    def test_hypothesis_fuzz_fleet_invariants(world_seed):
        sc, policy, seed = make_world(random.Random(world_seed))
        r = FleetSim(sc, POLICY_FACTORIES[policy], seed=seed).run()
        for check in CHECKS:
            check(sc, r)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_fuzz_fleet_invariants():
        pass


# ---- primitive properties -------------------------------------------------

def _float_cases(n, lo, hi, seed):
    rng = random.Random(seed)
    return [rng.uniform(lo, hi) for _ in range(n)]


def test_max_min_fair_allocation_is_water_filling():
    rng = random.Random(7)
    for _ in range(200):
        n = rng.randint(1, 8)
        cap = rng.uniform(0.0, 1e4)
        demands = [rng.uniform(0.0, 1e3) for _ in range(n)]
        weights = [rng.choice([0.0, 0.5, 1.0, 3.0]) for _ in range(n)]
        alloc = max_min_fair_allocation(cap, demands, weights)
        assert len(alloc) == n
        for a, d in zip(alloc, demands):
            assert -1e-9 <= a <= d + 1e-9
        assert sum(alloc) <= min(cap, sum(demands)) + 1e-6
        if sum(d for d, w in zip(demands, weights) if w > 0) <= cap:
            for a, d, w in zip(alloc, demands, weights):
                if w > 0:
                    assert a == pytest.approx(d, abs=1e-6)
        # water level: unsatisfied positive-weight parties sit at the
        # common per-weight level; satisfied ones at or below it
        unsat = [
            a / w for a, d, w in zip(alloc, demands, weights)
            if w > 0 and a < d - 1e-6
        ]
        if unsat:
            level = min(unsat)
            assert max(unsat) == pytest.approx(level, rel=1e-6,
                                               abs=1e-6)
            for a, d, w in zip(alloc, demands, weights):
                if w > 0:
                    assert a / w <= level + 1e-6


def test_max_min_zero_weight_served_from_residual_only():
    # capacity 100: the weighted demand takes 80, the scavenger gets
    # only the 20 left over
    alloc = max_min_fair_allocation(100.0, [80.0, 50.0], [1.0, 0.0])
    assert alloc == pytest.approx([80.0, 20.0])
    # no residual -> scavenger gets nothing
    alloc = max_min_fair_allocation(60.0, [80.0, 50.0], [1.0, 0.0])
    assert alloc == pytest.approx([60.0, 0.0])


def test_min_weighted_share_bounds():
    rng = random.Random(11)
    for _ in range(200):
        n = rng.randint(2, 6)
        usage = [rng.uniform(0.0, 1e3) for _ in range(n)]
        weights = [rng.uniform(0.1, 5.0) for _ in range(n)]
        s = min_weighted_share(usage, weights)
        assert 0.0 <= s <= 1.0
        # exactly proportional usage is perfectly fair
        total = sum(weights)
        prop = [w / total * 100.0 for w in weights]
        assert min_weighted_share(prop, weights) == pytest.approx(1.0)


def test_min_weighted_share_demand_bounded():
    # party 0 asked for little and got all of it: not a fairness victim
    assert min_weighted_share(
        [10.0, 1000.0], [1.0, 1.0], demands=[10.0, 5000.0]
    ) == pytest.approx(1.0)
    # same usage without the demand bound: heavily unfair
    assert min_weighted_share([10.0, 1000.0], [1.0, 1.0]) < 0.05
    # a starved positive-weight party with real demand scores 0
    assert min_weighted_share(
        [0.0, 100.0], [1.0, 1.0], demands=[50.0, 100.0]
    ) == 0.0


def test_floor_to_legal_slice_props():
    for c in range(0, 600, 7):
        f = floor_to_legal_slice(c, LEGAL)
        assert f in (0,) + LEGAL
        assert f <= c
        bigger = [s for s in LEGAL if s <= c]
        assert f == (max(bigger) if bigger else 0)


def _entry(name, tenant, chips, t=0.0, prio=0.0):
    return QueueEntry(name=name, tenant=tenant, chips=chips,
                      work_chip_s=100.0, enqueued_s=t, priority=prio)


def test_central_queue_fair_share_ordering():
    q = CentralQueue({t.name: t for t in TENANTS})
    q.push(_entry("heavy", "b", 16, t=0.0))
    q.push(_entry("light", "a", 16, t=1.0))
    q.push(_entry("scav", "z", 16, t=-5.0))
    # tenant a has consumed less per unit weight -> goes first; the
    # scavenger goes last despite the earliest arrival
    order = [e.name for e in q.order({"a": 100.0, "b": 400.0})]
    assert order == ["light", "heavy", "scav"]
    # priority breaks deficit ties
    q2 = CentralQueue({t.name: t for t in TENANTS})
    q2.push(_entry("lo", "b", 16, t=0.0))
    q2.push(_entry("hi", "b", 16, t=1.0, prio=2.0))
    assert [e.name for e in q2.order()] == ["hi", "lo"]
    with pytest.raises(ValueError):
        q.push(_entry("light", "a", 16))


def test_fifo_blocks_fill_backfills():
    big = _entry("big", "a", 100, t=0.0)
    small = _entry("small", "b", 16, t=1.0)
    free = {"site": 32}
    assert FifoScheduler().select([big, small], free) == []
    assert FillScheduler().select([big, small], free) == [
        (small, "site")
    ]


def test_best_fit_packs_worst_fit_spreads():
    a = _entry("a", "a", 16)
    b = _entry("b", "b", 64)
    free = {"site": 80, "cloud": 24}
    best = BestFitScheduler().select([a, b], dict(free))
    # best-fit puts the 16 on the 24-chip pool (leftover 8), the 64 on
    # the site (leftover 16)
    assert sorted((e.name, tgt) for e, tgt in best) == [
        ("a", "cloud"), ("b", "site")
    ]
    worst = WorstFitScheduler().select([a, b], dict(free))
    # worst-fit keeps headroom: the 16 goes on the big site first
    assert worst[0] == (a, "site")
