"""repro-lint suite (DESIGN.md §18).

Per-rule fixture pairs (a bad source that must be flagged, a good one
that must pass), suppression semantics, the symbolic evaluator, and —
the acceptance surface — real-source injection tests: deliberately
re-introducing a VMEM-formula drift, an unpaired DMA ``.start()``, a
double-buffer slot mismatch, an unsorted dict iteration in
``repro.sim``, or a ``.item()`` in traced code must each produce a
finding, while the pristine tree stays clean (so the CI lint stage
both passes today and would catch the regression).
"""
import ast
import json
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (
    Analyzer,
    DesignCitationsRule,
    DmaPairingRule,
    Finding,
    SimDeterminismRule,
    SymEval,
    SymEvalError,
    TracerHygieneRule,
    VmemBudgetRule,
    analyze_source,
    default_rules,
    render_human,
    to_json,
)

ROOT = pathlib.Path(__file__).resolve().parents[1]
KERNEL = ROOT / "src" / "repro" / "kernels" / "stencil" / "kernel.py"
FLEET = ROOT / "src" / "repro" / "sim" / "fleet.py"
KREL = "src/repro/kernels/stencil/kernel.py"
FREL = "src/repro/sim/fleet.py"

ALL_RULE_IDS = {"vmem-budget", "dma-pairing", "sim-determinism",
                "tracer-hygiene", "design-citations"}


# ---------------------------------------------------------------------------
# vmem-budget fixtures
# ---------------------------------------------------------------------------

VMEM_RESIDENT = textwrap.dedent('''\
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    ELEM = 4


    def resident_vmem_bytes(nz, nx, k, s=1, bz=None):
        return 5 * s * nz * nx * ELEM


    def stream_vmem_bytes(nz, nx, bz, k, s=1):
        return 5 * s * bz * nx * ELEM


    def _body(p_ref, pp_ref, v_ref, s_ref, o_ref):
        o_ref[...] = p_ref[...]


    def wave_block_pallas(p, pp, v, sp):
        spec = pl.BlockSpec((nz, nx), lambda: (0, 0))
        return pl.pallas_call(
            _body,
            in_specs=[spec, spec, spec, spec],
            out_specs=spec,
            scratch_shapes=[],
        )(p, pp, v, sp)
''')

VMEM_STREAM = textwrap.dedent('''\
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    ELEM = 4


    def resident_vmem_bytes(nz, nx, k, s=1, bz=None):
        return 5 * s * nz * nx * ELEM


    def stream_vmem_bytes(nz, nx, bz, k, s=1):
        return 5 * s * bz * nx * ELEM


    def _body(hbm_ref, o_ref, win):
        o_ref[...] = win[...]


    def wave_block_stream_pallas(p, bz, vmem_budget):
        any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
        return pl.pallas_call(
            _body,
            in_specs=[any_spec],
            out_specs=any_spec,
            scratch_shapes=[pltpu.VMEM((5 * bz, nx), jnp.float32)],
            compiler_params=pltpu.CompilerParams(
                vmem_limit_bytes=vmem_budget,
            ),
        )(p)
''')

VMEM_DISPATCH = textwrap.dedent('''\
    def resident_vmem_bytes(nz, nx, k, s=1, bz=None):
        return 5 * s * nz * nx * 4


    def stream_vmem_bytes(nz, nx, bz, k, s=1):
        return 5 * s * bz * nx * 4


    def should_stream(nz, nx, k, vmem_budget, s=1):
        return resident_vmem_bytes(nz, nx, k, s=s) > vmem_budget
''')


def _vmem(src):
    return analyze_source(src, VmemBudgetRule(),
                          filename="src/repro/kernels/toy/kernel.py")


def test_vmem_mapped_wrapper_matching_formula_is_clean():
    assert _vmem(VMEM_RESIDENT) == []


def test_vmem_mapped_wrapper_drift_is_flagged():
    bad = VMEM_RESIDENT.replace("return 5 * s * nz * nx * ELEM",
                                "return 6 * s * nz * nx * ELEM")
    fs = _vmem(bad)
    assert len(fs) == 1
    assert fs[0].rule == "vmem-budget"
    assert "drift" in fs[0].message


def test_vmem_streamed_wrapper_with_limit_is_clean():
    assert _vmem(VMEM_STREAM) == []


def test_vmem_streamed_wrapper_without_limit_is_flagged():
    bad = VMEM_STREAM.replace(
        "        compiler_params=pltpu.CompilerParams(\n"
        "            vmem_limit_bytes=vmem_budget,\n"
        "        ),\n", "")
    fs = _vmem(bad)
    assert len(fs) == 1
    assert "vmem_limit_bytes" in fs[0].message


def test_vmem_unmapped_kernel_with_scratch_is_flagged():
    src = VMEM_RESIDENT.replace("wave_block_pallas", "fancy_new_kernel") \
        .replace("scratch_shapes=[]",
                 "scratch_shapes=[pltpu.VMEM((128, 128), jnp.float32)]")
    fs = _vmem(src)
    assert len(fs) == 1
    assert "no capacity-formula mapping" in fs[0].message


def test_vmem_unmapped_kernel_without_scratch_is_clean():
    src = VMEM_RESIDENT.replace("wave_block_pallas", "plain_kernel")
    assert _vmem(src) == []


def test_vmem_dispatch_rule_consistent_is_clean():
    assert _vmem(VMEM_DISPATCH) == []


def test_vmem_dispatch_rule_drift_is_flagged():
    bad = VMEM_DISPATCH.replace("> vmem_budget", "> 2 * vmem_budget")
    fs = _vmem(bad)
    assert len(fs) == 1
    assert "dispatch rule drifted" in fs[0].message


def test_vmem_rule_ignores_files_outside_kernels():
    fs = analyze_source(VMEM_RESIDENT.replace(
        "return 5 * s * nz * nx * ELEM", "return 6 * s * nz * nx * ELEM"),
        VmemBudgetRule(), filename="src/repro/fwi/solver.py")
    assert fs == []


# ---------------------------------------------------------------------------
# dma-pairing fixtures
# ---------------------------------------------------------------------------

DMA_GOOD = textwrap.dedent('''\
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu


    def _stream_kernel(hbm, out, sem):
        i = pl.program_id(0)
        n = pl.num_programs(0)

        def dma(slot, strip):
            return [pltpu.make_async_copy(hbm.at[strip], out.at[slot],
                                          sem.at[slot])]

        @pl.when(i == 0)
        def _warm():
            for c in dma(i % 2, i):
                c.start()

        @pl.when(i + 1 < n)
        def _prefetch():
            for c in dma((i + 1) % 2, i + 1):
                c.start()

        for c in dma(i % 2, i):
            c.wait()
''')


def _dma(src):
    return analyze_source(src, DmaPairingRule(),
                          filename="src/repro/kernels/toy/kernel.py")


def test_dma_correct_double_buffer_idiom_is_clean():
    assert _dma(DMA_GOOD) == []


def test_dma_start_without_wait_is_flagged():
    bad = DMA_GOOD.replace("    for c in dma(i % 2, i):\n"
                           "        c.wait()\n", "")
    fs = _dma(bad)
    assert len(fs) == 1
    assert "no matching `.wait()`" in fs[0].message


def test_dma_wait_without_start_is_flagged():
    bad = DMA_GOOD.replace("c.start()", "pass")
    fs = _dma(bad)
    assert len(fs) == 1
    assert "no matching `.start()`" in fs[0].message


def test_dma_all_waits_guarded_is_flagged():
    bad = DMA_GOOD.replace(
        "    for c in dma(i % 2, i):\n        c.wait()",
        "    @pl.when(i > 0)\n"
        "    def _late():\n"
        "        for c in dma(i % 2, i):\n"
        "            c.wait()")
    fs = _dma(bad)
    assert len(fs) == 1
    assert "guarded" in fs[0].message


def test_dma_slot_mismatch_is_flagged():
    bad = DMA_GOOD.replace("for c in dma((i + 1) % 2, i + 1):",
                           "for c in dma(i % 2, i + 1):")
    fs = _dma(bad)
    assert len(fs) == 1
    assert "slot mismatch" in fs[0].message


def test_dma_inline_handle_pairing():
    src = textwrap.dedent('''\
        from jax.experimental.pallas import tpu as pltpu


        def _kernel(hbm, out, sem):
            copy = pltpu.make_async_copy(hbm, out, sem)
            copy.start()
    ''')
    fs = _dma(src)
    assert len(fs) == 1 and "no matching `.wait()`" in fs[0].message
    assert _dma(src + "    copy.wait()\n") == []


# ---------------------------------------------------------------------------
# sim-determinism fixtures
# ---------------------------------------------------------------------------

SIM_BAD = textwrap.dedent('''\
    import random
    import numpy as np
    import time


    def tally(usage, members):
        for t, u in usage.items():
            _ = (t, u)
        picks = {m for m in members}
        order = [m.name for m in picks]
        ranks = {m: 0 for m in picks}
        mat = list(picks)
        jitter = np.random.rand()
        rng = np.random.default_rng()
        now = time.time()
        tag = id(usage)
        members.sort(key=id)
        return order, ranks, mat, jitter, rng, now, tag
''')

SIM_GOOD = textwrap.dedent('''\
    import numpy as np


    def tally(usage, members):
        for t, u in sorted(usage.items()):
            _ = (t, u)
        picks = {m for m in members}
        total = sum(m.cost for m in picks)
        order = sorted(m.name for m in picks)
        rng = np.random.default_rng(1234)
        return total, order, rng.normal()
''')


def _sim(src, filename="src/repro/sim/toy.py"):
    return analyze_source(src, SimDeterminismRule(), filename=filename)


def test_sim_determinism_flags_every_entropy_source():
    fs = _sim(SIM_BAD)
    assert len(fs) == 10
    blob = "\n".join(f.message for f in fs)
    for frag in ("for-loop over a dict view", "comprehension over a set",
                 "materializes a set/dict view", "stdlib `random`",
                 "global numpy RNG", "without a seed", "wall clock",
                 "id() is a CPython address", "key=id"):
        assert frag in blob, frag


def test_sim_determinism_ordered_idioms_are_clean():
    assert _sim(SIM_GOOD) == []


def test_sim_determinism_only_applies_under_sim():
    assert _sim(SIM_BAD, filename="src/repro/fwi/driver.py") == []


# ---------------------------------------------------------------------------
# tracer-hygiene fixtures
# ---------------------------------------------------------------------------

TRACER_BAD = textwrap.dedent('''\
    import functools

    import jax
    import numpy as np
    from jax.experimental import pallas as pl


    def _helper(x):
        return x.item()


    @functools.partial(jax.jit, static_argnames=("steps",))
    def run(x, steps):
        y = _helper(x)
        z = float(x)
        print(z)
        w = np.asarray(x)
        return y + w + float(steps)


    def _kernel(x_ref, o_ref):
        print("inside kernel")


    def call_kernel(x):
        return pl.pallas_call(_kernel, out_shape=x)(x)
''')

TRACER_GOOD = textwrap.dedent('''\
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np


    def _staging(cfg):
        return np.asarray(cfg.grid)


    @functools.partial(jax.jit, static_argnames=("bz",))
    def run(x, bz):
        k = int(x.shape[0])
        s = float(bz)
        return x * k * s


    def outer(x):
        tbl = _staging(x)
        return run(jnp.asarray(tbl), bz=4)
''')


def _tracer(src):
    return analyze_source(src, TracerHygieneRule(),
                          filename="src/repro/fwi/toy.py")


def test_tracer_hygiene_flags_syncs_in_reachable_functions():
    fs = _tracer(TRACER_BAD)
    assert len(fs) == 5
    blob = "\n".join(f.message for f in fs)
    assert "`.item()` in traced `_helper`" in blob
    assert "`float()` on a traced value in `run`" in blob
    assert "`print()` in traced `run`" in blob
    assert "`np.asarray` in traced `run`" in blob
    assert "`print()` in traced `_kernel`" in blob


def test_tracer_hygiene_builders_and_static_casts_are_clean():
    assert _tracer(TRACER_GOOD) == []


def test_tracer_hygiene_scan_and_lambda_roots():
    src = textwrap.dedent('''\
        import jax


        def _step(carry, x):
            jax.debug.print("t={}", x)
            val = carry.item()
            return val, x


        def _helper2(x):
            return float(x)


        def drive(xs):
            return jax.lax.scan(_step, 0.0, xs)


        def apply(xs):
            return jax.vmap(lambda x: _helper2(x) + 1)(xs)
    ''')
    fs = _tracer(src)
    assert len(fs) == 2
    blob = "\n".join(f.message for f in fs)
    assert "`.item()` in traced `_step`" in blob          # scan body
    assert "`float()` on a traced value in `_helper2`" in blob  # via vmap
    # jax.debug.print is NOT print() — it must stay unflagged
    assert "debug" not in blob


# ---------------------------------------------------------------------------
# design-citations
# ---------------------------------------------------------------------------

def test_design_citations_resolution(tmp_path):
    (tmp_path / "DESIGN.md").write_text(
        "# §1 — intro\n\n## §2.5 — tiling\n")
    good = '"""Implements the plan (DESIGN.md §1, §2.5)."""\n'
    assert analyze_source(good, DesignCitationsRule(),
                          filename="src/x.py", root=tmp_path) == []
    bad = 'X = 1\n"""See DESIGN.md §9 for details."""\n'
    fs = analyze_source(bad, DesignCitationsRule(),
                        filename="src/x.py", root=tmp_path)
    assert len(fs) == 1
    assert fs[0].line == 2 and "no §9 heading" in fs[0].message


def test_design_citations_skips_when_design_missing(tmp_path):
    fs = analyze_source('"""DESIGN.md §9"""\n', DesignCitationsRule(),
                        filename="src/x.py", root=tmp_path / "nowhere")
    assert fs == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_on_flagged_line():
    src = "import random  # lint: disable=sim-determinism -- fixture\n"
    assert _sim(src) == []


def test_suppression_comment_block_covers_next_code_line():
    src = ("# lint: disable=sim-determinism -- justification that\n"
           "# spans two comment lines\n"
           "import random\n")
    assert _sim(src) == []


def test_suppression_for_other_rule_does_not_apply():
    src = "import random  # lint: disable=tracer-hygiene\n"
    assert len(_sim(src)) == 1


def test_suppression_all_keyword():
    src = "import random  # lint: disable=all\n"
    assert _sim(src) == []


# ---------------------------------------------------------------------------
# symbolic evaluator
# ---------------------------------------------------------------------------

def test_symeval_calls_and_builtins():
    tree = ast.parse(textwrap.dedent('''\
        K = 3


        def f(a, b=2, *, c=5):
            d = a + b
            if d > 4:
                return d * c
            return -d


        def g(x):
            return min(x, K) + max(x, K) + int(x / 2) + abs(-x)


        def h():
            raise ValueError("nope")
    '''))
    ev = SymEval(tree)
    assert ev.call("f", [3]) == 25
    assert ev.call("f", [1]) == -3
    assert ev.call("f", [1], {"b": 9}) == 50
    assert ev.call("g", [4]) == 13
    assert ev.eval(ast.parse("K + 1", mode="eval").body) == 4
    with pytest.raises(SymEvalError):
        ev.call("h")                  # raise reached
    with pytest.raises(SymEvalError):
        ev.call("missing")
    with pytest.raises(SymEvalError):
        ev.call("f", [])              # missing required arg


def test_symeval_scope_env_and_defaults():
    tree = ast.parse(textwrap.dedent('''\
        def wrap(n, bz=8, budget=None):
            k = n // 2
            total = k * bz
            pick = 16 if budget is None else budget
    '''))
    fdef = tree.body[0]
    ev = SymEval(tree, env={"n": 10}, scope=fdef)
    name = lambda s: ast.parse(s, mode="eval").body  # noqa: E731
    assert ev.eval(name("total")) == 40       # env n, default bz
    assert ev.eval(name("pick")) == 16        # is-None conditional
    assert ev.eval(name("n > 4 and bz")) == 8
    with pytest.raises(SymEvalError):
        ev.eval(name("unknown_name"))
    with pytest.raises(SymEvalError):
        ev.eval(name("[x for x in (1, 2)]"))  # outside the subset


# ---------------------------------------------------------------------------
# real-source injections (the acceptance surface)
# ---------------------------------------------------------------------------

def test_real_kernels_are_clean_under_every_rule():
    src = KERNEL.read_text()
    for rule in (VmemBudgetRule(), DmaPairingRule(), TracerHygieneRule()):
        assert analyze_source(src, rule, filename=KREL) == []


def test_injected_vmem_formula_drift_is_caught():
    src = KERNEL.read_text()
    old = "2 * 2 * s * bz * nx"
    assert old in src
    fs = analyze_source(src.replace(old, "2 * 3 * s * bz * nx"),
                        VmemBudgetRule(), filename=KREL)
    # all four formula-mapped wrappers drift from the edited formula
    assert len(fs) == 4
    assert all("drift" in f.message for f in fs)


def test_injected_should_stream_drift_is_caught():
    src = KERNEL.read_text()
    old = "return resident_vmem_bytes(nz, nx, k, s=s) > budget"
    assert old in src
    fs = analyze_source(
        src.replace(old,
                    "return resident_vmem_bytes(nz, nx, k, s=s) "
                    "> 2 * budget"),
        VmemBudgetRule(), filename=KREL)
    assert len(fs) == 1 and "dispatch rule drifted" in fs[0].message


def test_injected_unpaired_dma_start_is_caught():
    src = KERNEL.read_text()
    old = ("    slot = i % 2\n"
           "    for c in dma(slot, i):           "
           "# wait for our window to land\n"
           "        c.wait()")
    assert old in src
    fs = analyze_source(src.replace(old, "    slot = i % 2", 1),
                        DmaPairingRule(), filename=KREL)
    assert len(fs) == 1 and "no matching `.wait()`" in fs[0].message


def test_injected_dma_slot_mismatch_is_caught():
    src = KERNEL.read_text()
    old = "for c in dma((i + 1) % 2, i + 1):"
    assert src.count(old) == 2        # both streamed kernels
    fs = analyze_source(src.replace(old, "for c in dma(i % 2, i + 1):"),
                        DmaPairingRule(), filename=KREL)
    assert len(fs) == 2
    assert all("slot mismatch" in f.message for f in fs)


def test_injected_dict_iteration_in_sim_is_caught():
    src = FLEET.read_text()
    assert analyze_source(src, SimDeterminismRule(), filename=FREL) == []
    old = "for j in self.jobs:"
    assert old in src
    fs = analyze_source(
        src.replace(old, "for _t, _u in usage.items():", 1),
        SimDeterminismRule(), filename=FREL)
    assert len(fs) == 1 and "dict view" in fs[0].message


def test_injected_item_in_traced_kernel_is_caught():
    src = KERNEL.read_text()
    old = "prevd = cur * sw"
    assert old in src
    fs = analyze_source(src.replace(old, "prevd = (cur * sw).item()", 1),
                        TracerHygieneRule(), filename=KREL)
    assert len(fs) == 1 and "`.item()`" in fs[0].message


# ---------------------------------------------------------------------------
# framework plumbing, whole-repo run, CLI
# ---------------------------------------------------------------------------

def test_finding_render_and_json_roundtrip():
    f = Finding("a.py", 3, 0, "some-rule", "msg")
    assert f.render() == "a.py:3:0: some-rule msg"
    assert render_human([f]) == f.render()
    doc = json.loads(to_json([f], rules=["some-rule"]))
    assert doc["version"] == 1 and doc["count"] == 1
    assert doc["findings"][0] == {"file": "a.py", "line": 3, "col": 0,
                                  "rule": "some-rule", "message": "msg"}


def test_analyzer_whole_repo_is_clean():
    analyzer = Analyzer(default_rules(), ROOT)
    ctxs = analyzer.load(["src", "examples"])
    assert len(ctxs) > 50
    assert {r.name for r in analyzer.rules} == ALL_RULE_IDS
    findings = analyzer.run(ctxs)
    assert findings == [], render_human(findings)


def test_analyzer_loads_single_file():
    analyzer = Analyzer([DmaPairingRule()], ROOT)
    ctxs = analyzer.load([str(KERNEL)])
    assert [c.rel for c in ctxs] == [KREL]
    assert analyzer.run(ctxs) == []


def test_cli_clean_repo_and_json_schema(tmp_path):
    out = tmp_path / "lint.json"
    proc = subprocess.run(
        [sys.executable, "scripts/lint.py", "--ci", "--json", str(out)],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "repro-lint:" in proc.stdout
    doc = json.loads(out.read_text())
    assert doc["version"] == 1 and doc["count"] == 0
    assert doc["findings"] == []
    assert set(doc["rules"]) == ALL_RULE_IDS


def test_cli_fails_on_injected_violation(tmp_path):
    bad_dir = tmp_path / "sim"
    bad_dir.mkdir()
    (bad_dir / "toy.py").write_text("import random\n")
    proc = subprocess.run(
        [sys.executable, "scripts/lint.py", "--rules", "sim-determinism",
         str(bad_dir)],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 1
    assert "sim-determinism" in proc.stdout


def test_cli_list_rules_and_unknown_rule(tmp_path):
    proc = subprocess.run(
        [sys.executable, "scripts/lint.py", "--list-rules"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0
    for rule in ALL_RULE_IDS:
        assert rule in proc.stdout
    proc = subprocess.run(
        [sys.executable, "scripts/lint.py", "--rules", "bogus"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 2
    assert "unknown rule" in proc.stderr
