"""Shot-batched stencil engine (DESIGN.md §17): parity, VMEM
accounting, tiling, autotune, and the uneven shot split."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.stencil.kernel import (
    DEFAULT_VMEM_BUDGET,
    HALO,
    autotune_bz_k,
    pick_bz_stream,
    pick_shot_tile,
    resident_vmem_bytes,
    should_stream,
    stream_vmem_bytes,
    wave_block_pallas,
    wave_block_shots_pallas,
    wave_block_shots_stream_pallas,
)
from repro.kernels.stencil.ops import wave_block
from repro.kernels.stencil.ref import (
    wave_block_ref,
    wave_block_shots_ref,
    wave_block_shots_strips_ref,
    wave_block_strips_ref,
)

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _case(S, nz, nx, k, *, per_shot_src=False, seed=0):
    ks = jax.random.split(jax.random.key(seed + 7 * S + nz + nx), 7)
    p = jax.random.normal(ks[0], (S, nz, nx), jnp.float32)
    pp = jax.random.normal(ks[1], (S, nz, nx), jnp.float32)
    v = jax.random.uniform(ks[2], (nz, nx), jnp.float32, 0.05, 0.2)
    sp = jnp.clip(jax.random.uniform(ks[3], (nz, nx)), 0.9, 1.0)
    if per_shot_src:
        srcv = jax.random.normal(ks[4], (S, k), jnp.float32)
    else:
        srcv = jnp.linspace(0.5, 1.0, k, dtype=jnp.float32)
    sz = jax.random.randint(ks[5], (S,), HALO, nz - HALO)
    sx = jax.random.randint(ks[6], (S,), 0, nx)
    return p, pp, v, sp, srcv, sz, sx


def _vmap_ref(p, pp, v, sp, srcv, sz, sx, rrow):
    """The pre-batching semantics: one ``wave_block_ref`` per shot."""
    svb = srcv if srcv.ndim == 2 else \
        jnp.broadcast_to(srcv, (p.shape[0],) + srcv.shape)

    def one(a, b, sv, zi, xi):
        return wave_block_ref(a, b, v, sp, sv, zi, xi, receiver_row=rrow)

    return jax.vmap(one, (0, 0, 0, 0, 0))(p, pp, svb, sz, sx)


# ------------------------------------------------- XLA mirrors: bitwise


@pytest.mark.parametrize("S", [1, 2, 3, 4])
@pytest.mark.parametrize("per_shot_src", [False, True])
def test_shots_ref_bitwise_vs_vmap(S, per_shot_src):
    p, pp, v, sp, srcv, sz, sx = _case(S, 48, 64, 4,
                                       per_shot_src=per_shot_src)
    ref = _vmap_ref(p, pp, v, sp, srcv, sz, sx, 3)
    out = wave_block_shots_ref(p, pp, v, sp, srcv, sz, sx,
                               receiver_row=3)
    for a, b in zip(ref, out):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("S,bz", [(1, 8), (3, 16), (4, 8)])
def test_shots_strips_ref_bitwise(S, bz):
    p, pp, v, sp, srcv, sz, sx = _case(S, 48, 64, 2)
    whole = wave_block_shots_ref(p, pp, v, sp, srcv, sz, sx,
                                 receiver_row=5)
    strips = wave_block_shots_strips_ref(p, pp, v, sp, srcv, sz, sx,
                                         receiver_row=5, bz=bz)

    def one(a, b, zi, xi):
        return wave_block_strips_ref(a, b, v, sp, srcv, zi, xi,
                                     receiver_row=5, bz=bz)

    vm = jax.vmap(one, (0, 0, 0, 0))(p, pp, sz, sx)
    for a, b, c in zip(whole, strips, vm):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.array_equal(np.asarray(b), np.asarray(c))


# --------------------------------------------- Pallas (interpret): 1e-5


@pytest.mark.parametrize("S", [1, 2, 3, 4])
def test_shots_pallas_matches_ref(S):
    p, pp, v, sp, srcv, sz, sx = _case(S, 64, 128, 4)
    ref = _vmap_ref(p, pp, v, sp, srcv, sz, sx, 7)
    out = wave_block_shots_pallas(p, pp, v, sp, srcv, sz, sx,
                                  receiver_row=7, bz=16)
    for a, b in zip(ref, out):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)


def test_shots_stream_bitwise_vs_resident():
    S = 3
    p, pp, v, sp, srcv, sz, sx = _case(S, 64, 128, 4)
    res = wave_block_shots_pallas(p, pp, v, sp, srcv, sz, sx,
                                  receiver_row=7, bz=16)
    stm = wave_block_shots_stream_pallas(p, pp, v, sp, srcv, sz, sx,
                                         receiver_row=7, bz=16)
    for a, b in zip(res, stm):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_shots_s1_bitwise_vs_2d_kernel():
    p, pp, v, sp, srcv, sz, sx = _case(1, 64, 128, 4)
    batched = wave_block_shots_pallas(p, pp, v, sp, srcv, sz, sx,
                                      receiver_row=7, bz=16)
    single = wave_block_pallas(p[0], pp[0], v, sp, srcv, sz[0], sx[0],
                               receiver_row=7, bz=16)
    for a, b in zip(batched, single):
        assert np.array_equal(np.asarray(a)[0] if a.ndim == b.ndim + 1
                              else np.asarray(a), np.asarray(b))


# ------------------------------------------- dispatch + unaligned tiles


@pytest.mark.parametrize("tile", [1, 2, 3, 4])
def test_dispatch_xla_shot_tile_bitwise(tile):
    """Any tile — divisor or ragged — is value-preserving on XLA."""
    p, pp, v, sp, srcv, sz, sx = _case(4, 48, 64, 2)
    full = wave_block_shots_ref(p, pp, v, sp, srcv, sz, sx,
                                receiver_row=3)
    tiled = wave_block(p, pp, v, sp, srcv, sz, sx, receiver_row=3,
                       shot_tile=tile)
    for a, b in zip(full, tiled):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("tile", [3, 4])
def test_dispatch_pallas_shot_tile(tile):
    """Unaligned Pallas tiles run a remainder tile; per-shot math is
    identical at any batch size, so tilings agree bitwise with each
    other and to 1e-5 with the XLA reference."""
    p, pp, v, sp, srcv, sz, sx = _case(4, 64, 128, 4)
    ref = _vmap_ref(p, pp, v, sp, srcv, sz, sx, 7)
    out = wave_block(p, pp, v, sp, srcv, sz, sx, receiver_row=7,
                     use_pallas=True, bz=16, stream=False,
                     shot_tile=tile)
    whole = wave_block(p, pp, v, sp, srcv, sz, sx, receiver_row=7,
                       use_pallas=True, bz=16, stream=False, shot_tile=4)
    for a, b, c in zip(ref, out, whole):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)
        assert np.array_equal(np.asarray(b), np.asarray(c))


# --------------------------------------------------- s-aware VMEM model


def test_vmem_formulas_reduce_at_s1():
    nz, nx, bz, k = 600, 600, 120, 8
    # the pre-§17 single-shot accounting, written out long-hand
    assert resident_vmem_bytes(nz, nx, k, bz=bz) == \
        4 * (4 * nz * nx + 4 * bz * nx + k * nx)
    win = min(bz + 2 * k * HALO, nz)
    assert stream_vmem_bytes(nz, nx, bz, k) == \
        4 * (2 * 4 * win * nx + 4 * bz * nx + k * nx)


def test_vmem_monotone_in_s():
    nz, nx, bz, k = 256, 256, 32, 4
    res = [resident_vmem_bytes(nz, nx, k, bz=bz, s=s) for s in (1, 2, 4)]
    stm = [stream_vmem_bytes(nz, nx, bz, k, s=s) for s in (1, 2, 4)]
    assert res == sorted(res) and len(set(res)) == 3
    assert stm == sorted(stm) and len(set(stm)) == 3
    # the model fields are charged ONCE per batch: doubling s less than
    # doubles the bytes (the whole point of the shared slot)
    assert res[1] < 2 * res[0] and stm[1] < 2 * stm[0]


def test_pick_bz_stream_s_aware():
    bz1 = pick_bz_stream(1536, 1536, 4)
    bz2 = pick_bz_stream(1536, 1536, 4, s=2)
    assert bz2 <= bz1
    assert stream_vmem_bytes(1536, 1536, bz2, 4, s=2) \
        <= DEFAULT_VMEM_BUDGET
    with pytest.raises(ValueError):
        pick_bz_stream(1536, 1536, 4, vmem_budget=64 * 1024, s=2)


def test_should_stream_s_aware():
    assert not should_stream(600, 600, 8)
    assert should_stream(600, 600, 8, s=4)
    assert should_stream(2048, 2048, 4)


def test_pick_shot_tile():
    # 600² k=8: s=4 blows the 16 MiB resident budget, s=2 fits
    t = pick_shot_tile(4, 600, 600, 8, bz=120)
    assert t == 2
    assert resident_vmem_bytes(600, 600, 8, bz=120, s=t) \
        <= DEFAULT_VMEM_BUDGET
    assert resident_vmem_bytes(600, 600, 8, bz=120, s=4) \
        > DEFAULT_VMEM_BUDGET
    # a small grid takes the whole batch; a starved budget degrades to 1
    assert pick_shot_tile(4, 64, 64, 4, bz=16) == 4
    assert pick_shot_tile(4, 600, 600, 8, bz=120,
                          vmem_budget=1024) == 1
    # only divisors are picked by default (no ragged tiles)
    assert 6 % pick_shot_tile(6, 600, 600, 8, bz=120) == 0


def test_autotune_shots_returns_triple():
    bz, k, tile = autotune_bz_k(
        48, 64, bz_candidates=(8, 16), k_candidates=(2,), repeats=1,
        backend="interpret", stream=False, n_shots=2,
    )
    assert (bz, k) in {(8, 2), (16, 2)}
    assert tile in (1, 2) and 2 % tile == 0


def test_autotune_without_shots_still_pair():
    out = autotune_bz_k(48, 64, bz_candidates=(8, 16),
                        k_candidates=(2,), repeats=1,
                        backend="interpret", stream=False)
    assert len(out) == 2


def test_shot_parallel_runner_single_device():
    """n_devices=1 runs in-process (no forced device count), pinning
    the sharded runner against the plain block runner."""
    from repro.fwi.solver import (
        FWIConfig, ShotState, make_block_runner,
        make_shot_parallel_runner,
    )

    cfg = FWIConfig(nz=48, nx=64, timesteps=8, n_shots=3,
                    sponge_width=4)
    st = ShotState.init(cfg)
    run_sp, place = make_shot_parallel_runner(cfg, 1, k=4)
    ref_run = make_block_runner(cfg, k=4)
    a = run_sp(*place((st.p, st.p_prev)), 0, 8)
    b = ref_run(st.p, st.p_prev, 0, 8)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-6)


# ------------------------------------- uneven shot split across devices

_UNEVEN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro.core import Resources, PodSpec
from repro.fwi.driver import elastic_stripes_for
from repro.fwi.solver import FWIConfig, ShotState, make_shot_parallel_runner

assert jax.device_count() >= 4
cfg = FWIConfig(nz=48, nx=64, timesteps=16, n_shots=4, sponge_width=4)
st = ShotState.init(cfg)

# the elastic GROW decides the device count: a burst pod re-splits the
# shot axis to 3 devices, a non-divisor of the 4-shot batch
grown = elastic_stripes_for(1, 3)(
    Resources(pods=[PodSpec(chips=1, name="cluster"),
                    PodSpec(chips=1, name="burst")],
              shares=[0.5, 0.5]))
assert grown == 3

run1, place1 = make_shot_parallel_runner(cfg, 1, k=4)
run3, place3 = make_shot_parallel_runner(cfg, grown, k=4)
o1 = run1(*place1((st.p, st.p_prev)), 0, 16)
o3 = run3(*place3((st.p, st.p_prev)), 0, 16)
for a, b in zip(o1, o3):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape and a.shape[0] == cfg.n_shots, (a.shape,
                                                              b.shape)
    # documented contract: f32-ULP equal (1e-6 relative), not bitwise
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
print("uneven-split OK")
"""


def test_uneven_shot_split_matches_single_device():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _UNEVEN, SRC],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    assert "uneven-split OK" in out.stdout
