"""Distributed-path tests that need >1 device — run in subprocesses with
forced host device counts (the dry-run trick, scoped to the child)."""
import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(script: str, timeout=600) -> str:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", script, SRC],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    return out.stdout


_RESHARD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.checkpoint.manager import CheckpointManager
from repro.configs import RunConfig, get_config, smoke_config
from repro.configs.shapes import ShapeConfig
from repro.data.pipeline import SyntheticLMPipeline
from repro.optim import constant, make_optimizer
from repro.runtime.train_step import (
    batch_shardings, build_train_step, state_schema, state_shardings,
)
from repro.sharding.rules import abstract_params, init_params, make_rules

cfg = smoke_config(get_config("granite-8b"))
run = RunConfig(loss_chunk=32)
shape = ShapeConfig("t", "train", 32, 8)
opt = make_optimizer("adamw", constant(1e-3))
sch = state_schema(cfg, run, opt)
pipe = SyntheticLMPipeline(cfg, shape)

def session(mesh_shape, axes):
    mesh = jax.make_mesh(mesh_shape, axes,
                         devices=jax.devices()[: int(np.prod(mesh_shape))])
    rules = make_rules(mesh, "train")
    sh = state_shardings(sch, rules, run)
    fn = jax.jit(build_train_step(cfg, run, opt, rules))
    return mesh, rules, sh, fn

# --- phase 1: "cluster" = 4 chips (2 data x 2 model) ---
mesh1, rules1, sh1, step1 = session((2, 2), ("data", "model"))
params = jax.device_put(init_params(sch["params"], jax.random.key(0)),
                        sh1["params"])
state = {"params": params, "opt": opt.init(params),
         "step": jnp.zeros((), jnp.int32)}
for i in range(4):
    state, m = step1(state, pipe.batch_at(i))
loss_before = float(m["loss"])

with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d, async_save=False)
    mgr.save(4, state, extra={"data_step": 4})

    # --- burst: re-mesh to 8 chips (2 pod x 2 data x 2 model) ---
    mesh2, rules2, sh2, step2 = session((2, 2, 2), ("pod", "data", "model"))
    restored, extra = mgr.restore(abstract_params(sch), shardings=sh2)
    assert int(extra["data_step"]) == 4
    for i in range(4, 8):
        restored, m2 = step2(restored, pipe.batch_at(i))
    loss_after = float(m2["loss"])

    # --- reference: same 8 steps without the re-mesh ---
    params_r = jax.device_put(init_params(sch["params"], jax.random.key(0)),
                              sh1["params"])
    ref = {"params": params_r, "opt": opt.init(params_r),
           "step": jnp.zeros((), jnp.int32)}
    for i in range(8):
        ref, mr = step1(ref, pipe.batch_at(i))

for a, b in zip(jax.tree.leaves(restored["params"]),
                jax.tree.leaves(ref["params"])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-5)
print("RESHARD_OK", loss_before, loss_after)
"""


def test_checkpoint_reshard_across_meshes():
    """The burst mechanism: train on a (2,2) mesh, checkpoint, restore
    onto a (2,2,2) pod mesh, continue — matches the un-burst run."""
    out = _run(_RESHARD)
    assert "RESHARD_OK" in out


_COMPRESSED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import RunConfig, get_config, smoke_config
from repro.configs.shapes import ShapeConfig
from repro.data.pipeline import SyntheticLMPipeline
from repro.compat import configure_partial_auto, shard_map
configure_partial_auto()
from repro.optim.compression import cross_pod_reduce
from repro.runtime.train_step import batch_shardings, compute_grads
from repro.sharding.rules import axis_rules, init_params, make_rules

cfg = smoke_config(get_config("yi-6b"))
run = RunConfig(loss_chunk=32)
shape = ShapeConfig("t", "train", 32, 8)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = make_rules(mesh, "train")
inner_rules = dataclasses.replace(
    rules, rules={**rules.rules, "batch": (("data",),)})
pipe = SyntheticLMPipeline(cfg, shape)
from repro.models import model as M
params = init_params(M.schema(cfg), jax.random.key(0))
batch = pipe.batch_at(0)

# 1) pure SPMD gradients (XLA reduces over pod+data)
def g_spmd(p, b):
    with axis_rules(rules):
        g, _ = compute_grads(cfg, run, p, b)
    return g
grads_spmd = jax.jit(g_spmd)(params, batch)

# 2) manual-pod shard_map with exact psum / int8 exchange
# (token-weighted cross-pod mean: each pod normalizes by its own count)
def make_manual(method):
    def inner(p, b):
        with axis_rules(inner_rules):
            g, m = compute_grads(cfg, run, p, b)
        cnt = m["token_count"].astype(jnp.float32)
        g = jax.tree.map(lambda x: x * cnt, g)
        g = cross_pod_reduce(g, "pod", method=method)
        cnt_total = jax.lax.psum(cnt, "pod")
        return jax.tree.map(lambda x: x / cnt_total, g)
    def f(p, b):
        pspec = jax.tree.map(lambda _: P(), p)
        bspec = jax.tree.map(lambda x: P("pod") if x.ndim else P(), b)
        return shard_map(inner, mesh=mesh, in_specs=(pspec, bspec),
                         out_specs=pspec, axis_names={"pod"},
                         check_vma=False)(p, b)
    return jax.jit(f)

grads_exact = make_manual("none")(params, batch)
grads_int8 = make_manual("int8")(params, batch)

for a, b_ in zip(jax.tree.leaves(grads_spmd), jax.tree.leaves(grads_exact)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b_, np.float32), atol=2e-5)
# int8 path: blockwise quantization error bound (scale/127 per element of
# the exchanged pod-partial gradient)
for a, b_ in zip(jax.tree.leaves(grads_exact), jax.tree.leaves(grads_int8)):
    a, b_ = np.asarray(a, np.float32), np.asarray(b_, np.float32)
    bound = max(np.abs(a).max() / 127.0, 1e-6) * 1.5 + 1e-7
    assert np.abs(a - b_).max() <= bound, (np.abs(a - b_).max(), bound)
print("COMPRESSED_OK")
"""


def test_compressed_cross_pod_gradients():
    """Two-level reduction: shard_map-manual pod axis with int8 gradient
    exchange ≈ the exact SPMD gradients; quantization error bounded by
    the blockwise absmax/127 scale."""
    out = _run(_COMPRESSED)
    assert "COMPRESSED_OK" in out


_SHARDED_EQUIV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import RunConfig, get_config, smoke_config
from repro.configs.shapes import SMOKE_SHAPES, input_specs, tokens_like
from repro.models import model as M
from repro.sharding.rules import init_params, make_rules, axis_rules
from repro.launch.mesh import make_mesh

# loss on 1 device == loss on a (2,2)/(2,2,2) sharded mesh.
# deepseek-v2 runs with ep_over_dp=True: the explicit shard_map all-to-all
# expert dispatch must agree with the single-device grouped-einsum path
# (drop-free smoke capacity => group-invariant routing).
for arch in ["yi-6b", "deepseek-v2-236b", "mamba2-370m", "jamba-v0.1-52b"]:
    cfg = smoke_config(get_config(arch))
    params = init_params(M.schema(cfg), jax.random.key(0))
    batch = tokens_like(input_specs(cfg, SMOKE_SHAPES["train_4k"]))
    loss0, _ = jax.jit(lambda p, b: M.loss_fn(cfg, p, b, loss_chunk=32))(
        params, batch)
    for shape, axes in [((2, 2), ("data", "model")),
                        ((2, 2, 2), ("pod", "data", "model"))]:
        mesh = make_mesh(shape, axes)
        rules = make_rules(mesh, "train")
        def f(p, b):
            with axis_rules(rules):
                return M.loss_fn(cfg, p, b, loss_chunk=32)
        loss1, _ = jax.jit(f)(params, batch)
        err = abs(float(loss0) - float(loss1))
        # 5e-4 abs on a ~4.9 loss: the EP path splits the d-contraction
        # across "model" (psum), a pure f32 reassociation
        assert err < 5e-4, (arch, shape, err)
print("SHARDED_EQUIV_OK")
"""


def test_sharded_loss_equals_single_device():
    """SPMD partitioning must not change the math (MoE group-scan, MLA,
    SSD and hybrid paths under real >1-device meshes)."""
    out = _run(_SHARDED_EQUIV)
    assert "SHARDED_EQUIV_OK" in out


_PIPELINE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding
from repro.configs import RunConfig, get_config, smoke_config
from repro.configs.base import BlockDef
from repro.configs.shapes import ShapeConfig
from repro.data.pipeline import SyntheticLMPipeline
from repro.optim import constant, make_optimizer
from repro.compat import configure_partial_auto
configure_partial_auto()
from repro.runtime.pipeline import build_pipeline_train_step
from repro.runtime.train_step import build_train_step, state_schema
from repro.sharding.rules import init_params, make_rules

base = smoke_config(get_config("granite-8b"))
# 2 layers so each of the 2 stages owns one
cfg = dataclasses.replace(
    base, num_layers=2,
    blocks=(BlockDef(pattern=(("attn", "dense"),), repeat=2),),
).validate()
run = RunConfig(loss_chunk=32, pipeline_stages=2, pp_microbatches=4)
shape = ShapeConfig("t", "train", 32, 8)
opt = make_optimizer("adamw", constant(1e-3))
sch = state_schema(cfg, run, opt)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = make_rules(mesh, "train")
pipe = SyntheticLMPipeline(cfg, shape)

def init():
    p = init_params(sch["params"], jax.random.key(0))
    return {"params": p, "opt": opt.init(p),
            "step": jnp.zeros((), jnp.int32)}

pp_step, pp_specs = build_pipeline_train_step(cfg, run, opt, rules)
pp_step = jax.jit(pp_step)
dp_step = jax.jit(build_train_step(cfg, run, opt, rules))

s_pp, s_dp = init(), init()
for i in range(3):
    b = pipe.batch_at(i)
    s_pp, m_pp = pp_step(s_pp, b)
    s_dp, m_dp = dp_step(s_dp, b)
    dl = abs(float(m_pp["loss"]) - float(m_dp["loss"]))
    assert dl < 5e-4, (i, float(m_pp["loss"]), float(m_dp["loss"]))
for a, b_ in zip(jax.tree.leaves(s_pp["params"]),
                 jax.tree.leaves(s_dp["params"])):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b_, np.float32), atol=3e-3)
print("PIPELINE_OK", float(m_pp["loss"]))
"""


def test_pipeline_parallel_matches_data_parallel():
    """2-stage GPipe over the pod axis trains identically (modulo fp
    reordering across µbatches) to the plain SPMD step."""
    out = _run(_PIPELINE)
    assert "PIPELINE_OK" in out
