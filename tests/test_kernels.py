"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import attention
from repro.kernels.rmsnorm.ops import rmsnorm_residual
from repro.kernels.ssd.ops import ssd_chunk
from repro.kernels.stencil.ops import wave_step

# --------------------------------------------------------------- stencil


@pytest.mark.parametrize("nz,nx,bz", [
    (256, 256, 128), (128, 384, 32), (512, 128, 64), (64, 640, 8),
])
def test_stencil_matches_ref(nz, nx, bz):
    ks = jax.random.split(jax.random.key(nz + nx), 4)
    p = jax.random.normal(ks[0], (nz, nx), jnp.float32)
    pp = jax.random.normal(ks[1], (nz, nx), jnp.float32)
    v = jax.random.uniform(ks[2], (nz, nx), jnp.float32, 0.05, 0.2)
    sponge = jnp.clip(jax.random.uniform(ks[3], (nz, nx)), 0.9, 1.0)
    a1, a2 = wave_step(p, pp, v, sponge)
    b1, b2 = wave_step(p, pp, v, sponge, use_pallas=True, bz=bz)
    np.testing.assert_allclose(a1, b1, atol=3e-6)
    np.testing.assert_allclose(a2, b2, atol=3e-6)


def test_stencil_boundary_rows_match_ref():
    """First/last strips must use zero halo exactly like the ref."""
    nz, nx = 64, 128
    p = jnp.ones((nz, nx), jnp.float32)
    pp = jnp.zeros_like(p)
    v = jnp.full_like(p, 0.1)
    sponge = jnp.ones_like(p)
    a, _ = wave_step(p, pp, v, sponge)
    b, _ = wave_step(p, pp, v, sponge, use_pallas=True, bz=8)
    np.testing.assert_allclose(a[:4], b[:4], atol=1e-6)
    np.testing.assert_allclose(a[-4:], b[-4:], atol=1e-6)


# --------------------------------------------------------- flash attention


@pytest.mark.parametrize("B,H,KH,S,D,bq,bk", [
    (2, 4, 2, 256, 64, 128, 128),
    (1, 8, 8, 128, 128, 64, 64),
    (2, 4, 1, 64, 32, 32, 32),
    (1, 2, 2, 512, 64, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, KH, S, D, bq, bk, dtype):
    ks = jax.random.split(jax.random.key(S + H), 3)
    q = jax.random.normal(ks[0], (B, H, S, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KH, S, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KH, S, D)).astype(dtype)
    a = attention(q, k, v, causal=True)
    b = attention(q, k, v, causal=True, use_pallas=True, bq=bq, bk=bk)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), atol=tol
    )


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.float32)
    a = attention(q, k, v, causal=False)
    b = attention(q, k, v, causal=False, use_pallas=True, bq=64, bk=64)
    np.testing.assert_allclose(a, b, atol=2e-5)


# --------------------------------------------------------------------- ssd


@pytest.mark.parametrize("BC,H,Q,N,P", [
    (4, 2, 64, 32, 64), (2, 4, 128, 128, 64), (3, 1, 32, 16, 16),
])
def test_ssd_chunk_matches_ref(BC, H, Q, N, P):
    ks = jax.random.split(jax.random.key(Q + N), 4)
    xdt = jax.random.normal(ks[0], (BC, H, Q, P), jnp.float32)
    b = jax.random.normal(ks[1], (BC, H, Q, N), jnp.float32)
    c = jax.random.normal(ks[2], (BC, H, Q, N), jnp.float32)
    csum = -jnp.cumsum(jax.random.uniform(ks[3], (BC, H, Q)), axis=-1)
    y1, s1 = ssd_chunk(xdt, b, c, csum)
    y2, s2 = ssd_chunk(xdt, b, c, csum, use_pallas=True)
    np.testing.assert_allclose(y1, y2, atol=1e-5)
    np.testing.assert_allclose(s1, s2, atol=1e-5)


def test_ssd_chunked_equals_naive_recurrence():
    """The full chunked SSD algorithm (models/mamba2.ssd_chunked) against
    a literal sequential state-space recurrence."""
    from repro.models.mamba2 import ssd_chunked

    B_, S, H, P, N, chunk = 2, 48, 2, 16, 8, 16
    ks = jax.random.split(jax.random.key(0), 4)
    xs = jax.random.normal(ks[0], (B_, S, H, P), jnp.float32) * 0.5
    bs = jax.random.normal(ks[1], (B_, S, 1, N), jnp.float32) * 0.5
    cs = jax.random.normal(ks[2], (B_, S, 1, N), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B_, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.key(9), (H,)) * 0.3)
    y_fast, state_fast = ssd_chunked(
        xs, bs, cs, dt, dt * A, chunk=chunk, n_heads=H
    )
    h = np.zeros((B_, H, N, P))
    ys = np.zeros((B_, S, H, P))
    xsn, bsn, csn, dtn, dAn = map(
        np.asarray, (xs, bs, cs, dt, dt * A)
    )
    for t in range(S):
        for b_ in range(B_):
            for hh in range(H):
                h[b_, hh] = np.exp(dAn[b_, t, hh]) * h[b_, hh] + np.outer(
                    bsn[b_, t, 0], dtn[b_, t, hh] * xsn[b_, t, hh]
                )
                ys[b_, t, hh] = csn[b_, t, 0] @ h[b_, hh]
    np.testing.assert_allclose(y_fast, ys, atol=5e-5)
    np.testing.assert_allclose(state_fast, h, atol=5e-5)


# ----------------------------------------------------------------- rmsnorm


@pytest.mark.parametrize("N,d,bn", [(512, 256, 128), (64, 640, 8),
                                    (256, 1024, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(N, d, bn, dtype):
    x = jax.random.normal(jax.random.key(1), (N, d)).astype(dtype)
    r = jax.random.normal(jax.random.key(2), (N, d)).astype(dtype)
    sc = jax.random.normal(jax.random.key(3), (d,), jnp.float32)
    a1, a2 = rmsnorm_residual(x, r, sc)
    b1, b2 = rmsnorm_residual(x, r, sc, use_pallas=True, bn=bn)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(a1, np.float32), np.asarray(b1, np.float32), atol=tol
    )
    np.testing.assert_allclose(
        np.asarray(a2, np.float32), np.asarray(b2, np.float32), atol=tol
    )
