"""Per-architecture smoke tests: reduced same-family configs run one
forward/train step on CPU, asserting output shapes and no NaNs, plus
prefill-vs-decode logits consistency (the serving invariant)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, RunConfig, get_config, smoke_config
from repro.configs.shapes import SMOKE_SHAPES, input_specs, tokens_like
from repro.models import model as M
from repro.optim import constant, make_optimizer
from repro.runtime.train_step import build_train_step, state_schema
from repro.sharding.rules import count_params, init_params


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke_config(get_config(arch))
            params = init_params(M.schema(cfg), jax.random.key(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch, arch_state):
    cfg, params = arch_state(arch)
    run = RunConfig(microbatch=2, loss_chunk=32)
    opt = make_optimizer(cfg.optimizer, constant(1e-3))
    state = {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    step = jax.jit(build_train_step(cfg, run, opt))
    batch = tokens_like(input_specs(cfg, SMOKE_SHAPES["train_4k"]))
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss), (arch, loss)
    assert 0.0 < loss < 50.0, (arch, loss)
    for leaf in jax.tree.leaves(state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_schema_counts(arch):
    cfg = get_config(arch)
    total, active = M.param_counts(cfg)
    assert total > 0 and 0 < active <= total
    if cfg.moe is not None:
        assert active < total, "MoE must have fewer active params"
    smoke = smoke_config(cfg)
    assert count_params(M.schema(smoke)) < 2_000_000


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch, arch_state):
    cfg, params = arch_state(arch)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full = {"tokens": toks}
    pre = {"tokens": toks[:, : S - 1]}
    if cfg.cross_attention:
        enc = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_frames, cfg.d_model),
            jnp.float32,
        )
        full["enc_embeds"] = enc
        pre["enc_embeds"] = enc
    if cfg.rope_type == "mrope":
        pos = jnp.broadcast_to(
            jnp.arange(S)[None, None], (B, 3, S)
        ).astype(jnp.int32)
        full["positions"] = pos
        pre["positions"] = pos[:, :, : S - 1]
    logits_full, _ = M.prefill(cfg, params, full)
    _, cache = M.prefill(cfg, params, pre, max_seq=S)
    dec = {"token": toks[:, S - 1], "pos": jnp.asarray(S - 1, jnp.int32)}
    if cfg.rope_type == "mrope":
        dec["positions"] = jnp.broadcast_to(
            jnp.asarray(S - 1)[None, None], (B, 3)
        ).astype(jnp.int32)
    logits_dec, new_cache = M.decode_step(cfg, params, cache, dec)
    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    assert err < 2e-4, (arch, err)
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["mamba2-370m", "jamba-v0.1-52b"])
def test_subquadratic_decode_state_is_constant_size(arch):
    """long-context capability: decode state must not grow with seq for
    the archs that run the long_500k cell (SSM state is O(1))."""
    cfg = smoke_config(get_config(arch))
    small = M.cache_schema(cfg, batch=1, max_seq=64)
    big = M.cache_schema(cfg, batch=1, max_seq=256)
    from repro.sharding.rules import count_params

    if arch == "mamba2-370m":
        assert count_params(small) == count_params(big)
    else:  # hybrid: only the 4 attention layers' caches grow
        growth = count_params(big) / count_params(small)
        assert growth < 4.0


def test_vlm_embeds_input_path():
    cfg = smoke_config(get_config("qwen2-vl-72b"))
    params = init_params(M.schema(cfg), jax.random.key(0))
    batch = tokens_like(input_specs(cfg, SMOKE_SHAPES["train_4k"]))
    assert "embeds" in batch and "positions" in batch
    loss, _ = M.loss_fn(cfg, params, batch, loss_chunk=32)
    assert jnp.isfinite(loss)
