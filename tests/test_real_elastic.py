"""Real-session elastic loop (DESIGN.md §14).

Covers: wall-clock-driven policy evaluation, first-class mid-run
deadline changes, elastic chip-second billing in the orchestrator, the
FWISession amortization rescale across RESHARD onto a different fleet,
and — in a subprocess with multiple host devices — the full acceptance
scenario: FWISession completes a deadline-squeeze under the `plan`
policy with ≥1 GROW and ≥1 RETIRE applied through real re-striping, and
the final wavefield matches an unscaled reference run.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    BurstPlanner,
    DeadlinePredictor,
    ElasticOrchestrator,
    LogCapacityModel,
    OverheadModel,
    PodSpec,
    Resources,
    ScaleAction,
    elastic_chips,
)
from repro.core.sim_session import SimWorkload, sim_session_factory

LEGAL = [16, 32, 64, 128, 256]
OV = OverheadModel(ckpt_s=5, provision_s=60, restart_s=20)


def _planner(**kw):
    m = LogCapacityModel.fit(LEGAL, [2000.0 / c for c in LEGAL])
    defaults = dict(
        cluster_model=m, cloud_model=m, chips_cluster=256,
        legal_slices=LEGAL, overheads=OV,
    )
    defaults.update(kw)
    return BurstPlanner(**defaults)


class _Counting:
    """Records every policy evaluation's (step, elapsed)."""

    name = "counting"

    def __init__(self):
        self.calls = []

    def decide(self, ctx):
        self.calls.append((ctx.step, ctx.elapsed_s))
        return ScaleAction("hold")


class _Scripted:
    name = "scripted"

    def __init__(self, grow_at=16, shrink_at=32, retire_at=48,
                 chips=64, slowdown=1.4):
        self.grow_at, self.shrink_at, self.retire_at = \
            grow_at, shrink_at, retire_at
        self.chips, self.slowdown = chips, slowdown

    def decide(self, ctx):
        if ctx.step == self.grow_at:
            return ScaleAction("grow", chips=self.chips,
                               slowdown=self.slowdown)
        if ctx.step == self.shrink_at:
            return ScaleAction("shrink", chips=self.chips // 2)
        if ctx.step == self.retire_at:
            return ScaleAction("retire")
        return ScaleAction("hold")


def _initial(chips=256):
    return Resources(pods=[PodSpec(chips, name="cluster")], shares=[1.0])


def test_wall_clock_eval_interval_drives_policy():
    """eval_interval_s evaluates the policy on the session clock, not a
    step count: ~elapsed/interval calls, spaced ≥ one interval apart."""
    pol = _Counting()
    orch = ElasticOrchestrator(
        planner=_planner(), predictor=DeadlinePredictor(10_000.0),
        check_every=1, ckpt_every=1000, eval_interval_s=50.0,
    )
    rec = orch.run(
        session_factory=sim_session_factory(
            SimWorkload(2000.0, jitter=0.0),
            rng=np.random.default_rng(0),
        ),
        initial=_initial(), steps_total=60, autoscaler=pol,
    )
    # 60 steps × 7.8125 s = 468.75 s → crossings at 50,100,...,450
    assert len(pol.calls) == 9
    gaps = [b - a for (_, a), (_, b) in zip(pol.calls, pol.calls[1:])]
    assert all(g >= 50.0 - 7.82 for g in gaps)
    assert rec.completed
    # with check_every=1 and no interval it would have been 59 calls
    pol2 = _Counting()
    orch2 = ElasticOrchestrator(
        planner=_planner(), predictor=DeadlinePredictor(10_000.0),
        check_every=1, ckpt_every=1000,
    )
    orch2.run(
        session_factory=sim_session_factory(
            SimWorkload(2000.0, jitter=0.0),
            rng=np.random.default_rng(0),
        ),
        initial=_initial(), steps_total=60, autoscaler=pol2,
    )
    assert len(pol2.calls) == 59


def test_nonpositive_eval_interval_rejected():
    for bad in (0.0, -5.0):
        with pytest.raises(ValueError):
            ElasticOrchestrator(
                planner=_planner(), predictor=DeadlinePredictor(1000.0),
                eval_interval_s=bad,
            )


def test_deadline_changes_schedule_applies_and_triggers_burst():
    orch = ElasticOrchestrator(
        planner=_planner(), predictor=DeadlinePredictor(10_000.0),
        check_every=8,
    )
    rec = orch.run(
        session_factory=sim_session_factory(
            SimWorkload(2000.0, jitter=0.01),
            rng=np.random.default_rng(1),
        ),
        initial=_initial(), steps_total=300,
        deadline_changes=[(450.0, 1800.0)],
    )
    assert orch.predictor.deadline_s == 1800.0
    assert [e for e in rec.events if e.kind == "deadline"]
    assert [e for e in rec.events if e.kind == "burst"]
    # the history records when the tightening landed
    assert orch.predictor.deadline_at(0.0) == 10_000.0
    assert orch.predictor.deadline_at(rec.elapsed_s) == 1800.0


def test_orchestrator_bills_elastic_chip_seconds():
    """cloud_chip_s integrates elastic chips over held time (steps plus
    non-provisioning scale overheads) and is priced via the planner."""
    planner = _planner(price_per_chip_hour=3.0)
    orch = ElasticOrchestrator(
        planner=planner, predictor=DeadlinePredictor(10_000.0),
        check_every=8, ckpt_every=1000, cloud_slowdown=1.4,
    )
    rec = orch.run(
        session_factory=sim_session_factory(
            SimWorkload(2000.0, jitter=0.0),
            rng=np.random.default_rng(0),
        ),
        initial=_initial(), steps_total=60,
        autoscaler=_Scripted(grow_at=16, shrink_at=32, retire_at=48),
    )
    # reconstruct expected billing from the recorded step times/events
    held = {e.step: e.detail["cloud_chips"] for e in rec.events
            if e.kind == "scale"}
    chips, expect = 0, 0.0
    resize_ov = OV.ckpt_s + OV.restart_s      # provisioning not billed
    for i, dt in enumerate(rec.step_times):
        step = i + 1
        expect += chips * dt
        if step in held:
            chips = held[step]
            expect += chips * resize_ov
    assert rec.cloud_chip_s == pytest.approx(expect)
    assert rec.cloud_cost_usd == pytest.approx(expect / 3600.0 * 3.0)
    assert rec.cloud_chip_s > 0
    # grown pod carries the provider's true K, not the policy belief
    grow = next(e for e in rec.events
                if e.kind == "scale" and e.detail["kind"] == "grow")
    assert grow.detail["cloud_chips"] == 64


def test_nonburst_run_bills_zero():
    planner = _planner(price_per_chip_hour=3.0)
    orch = ElasticOrchestrator(
        planner=planner, predictor=DeadlinePredictor(10_000.0),
        check_every=8,
    )
    rec = orch.run(
        session_factory=sim_session_factory(
            SimWorkload(2000.0, jitter=0.0),
            rng=np.random.default_rng(0),
        ),
        initial=_initial(), steps_total=40,
    )
    assert rec.cloud_chip_s == 0.0 and rec.cloud_cost_usd == 0.0


# --------------------------- FWISession amortization across RESHARD


def _fwi_cfg():
    from repro.fwi.solver import FWIConfig
    return FWIConfig(nz=32, nx=64, timesteps=32, n_shots=1,
                     sponge_width=4)


def test_fwi_amortized_rescaled_when_resources_differ():
    """Regression: amortized_s restored verbatim across RESHARD made
    the first post-reshard monitor sample report the OLD fleet's step
    time; a fleet-signature mismatch now rescales it by the modeled
    effective-throughput ratio."""
    from repro.fwi.driver import FWISession, TimeModel

    cfg = _fwi_cfg()
    res1 = Resources(pods=[PodSpec(chips=64, name="cluster")],
                     shares=[1.0])
    rng = np.random.default_rng(0)
    s = FWISession(cfg, res1, 0, None, time_model=TimeModel(jitter=0.0),
                   rng=rng, exchange_interval=4, scan_block=8)
    for i in range(5):
        s.run_step(i)
    a0 = s._amortized
    assert a0 > 0
    snap = s.checkpoint(5)
    # identical fleet: the mid-block measurement survives verbatim
    s_same = FWISession(cfg, res1, 5, snap,
                        time_model=TimeModel(jitter=0.0), rng=rng,
                        exchange_interval=4, scan_block=8)
    assert s_same._amortized == a0
    # grown fleet: rescaled by eff_old / eff_new
    res2 = ElasticOrchestrator.apply_scale(
        res1, ScaleAction("grow", chips=64, slowdown=1.4)
    )
    s2 = FWISession(cfg, res2, 5, snap,
                    time_model=TimeModel(jitter=0.0), rng=rng,
                    exchange_interval=4, scan_block=8)
    eff1, eff2 = 64.0, 64.0 + 64.0 / 1.4
    assert s2._amortized == pytest.approx(a0 * eff1 / eff2)
    assert s2._amortized < a0


def test_fwi_amortized_rescale_through_orchestrator_reshard():
    from repro.fwi.driver import TimeModel, fwi_session_factory

    cfg = _fwi_cfg()
    base = fwi_session_factory(
        cfg, TimeModel(jitter=0.0), exchange_interval=4, scan_block=8
    )
    sessions = []                        # (session, amortized at birth)

    def factory(res, start_step, restored):
        s = base(res, start_step, restored)
        sessions.append((s, s._amortized))
        return s

    orch = ElasticOrchestrator(
        planner=_planner(chips_cluster=64),
        predictor=DeadlinePredictor(10_000.0),
        check_every=6, ckpt_every=1000, cloud_slowdown=1.4,
    )
    orch.run(
        session_factory=factory,
        initial=Resources(pods=[PodSpec(chips=64, name="cluster")],
                          shares=[1.0]),
        steps_total=16,
        autoscaler=_Scripted(grow_at=6, shrink_at=10 ** 9,
                             retire_at=10 ** 9, chips=64),
    )
    assert len(sessions) == 2            # initial + post-grow reshard
    (pre, _), (_, post_a0) = sessions
    # pre measured exactly one block (abandoned mid-block at the grow)
    assert pre._amortized > 0
    eff1, eff2 = 64.0, 64.0 + 64.0 / 1.4
    assert post_a0 == pytest.approx(pre._amortized * eff1 / eff2)


def test_elastic_stripes_for_mapping():
    from repro.fwi.driver import elastic_stripes_for

    f = elastic_stripes_for(1, 2)
    onprem = _initial(64)
    grown = ElasticOrchestrator.apply_scale(
        onprem, ScaleAction("grow", chips=32, slowdown=1.4)
    )
    assert f(onprem) == 1 and f(grown) == 2
    assert elastic_chips(grown) == 32
    retired = ElasticOrchestrator.apply_scale(
        grown, ScaleAction("retire")
    )
    assert f(retired) == 1


# ----------------------------- end-to-end acceptance (subprocess)

_E2E_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax.numpy as jnp

from repro.core import (
    BurstPlanner, DeadlinePredictor, ElasticOrchestrator,
    LogCapacityModel, OverheadModel, PodSpec, Resources, elastic_chips,
)
from repro.fwi.driver import TimeModel, elastic_stripes_for, \
    fwi_session_factory
from repro.fwi.solver import FWIConfig, run_forward
from repro.sim import PlanAutoscaler

cfg = FWIConfig(nz=48, nx=96, timesteps=120, n_shots=1, sponge_width=8)
W, K, LEGAL = 64.0, 1.4, [16, 32, 64, 128]
cs = sorted(set(LEGAL) | {64})
planner = BurstPlanner(
    cluster_model=LogCapacityModel.fit(cs, [W / c for c in cs]),
    cloud_model=LogCapacityModel.fit(cs, [K * W / c for c in cs]),
    chips_cluster=64, legal_slices=LEGAL,
    overheads=OverheadModel(ckpt_s=5.0, provision_s=10.0, restart_s=5.0),
    price_per_chip_hour=3.0, cost_weight=0.5,
)
orch = ElasticOrchestrator(
    planner=planner, predictor=DeadlinePredictor(400.0),
    check_every=8, ckpt_every=40, eval_interval_s=7.0,
    cloud_slowdown=K,
)
base = fwi_session_factory(
    cfg, TimeModel(chip_seconds_per_step=W, jitter=0.01),
    stripes_for=elastic_stripes_for(1, 2),
    exchange_interval=4, scan_block=8,
)
sessions = []

def factory(res, start_step, restored):
    s = base(res, start_step, restored)
    sessions.append((s, len(res.pods)))
    return s

rec = orch.run(
    session_factory=factory,
    initial=Resources(pods=[PodSpec(chips=64, name="cluster")],
                      shares=[1.0]),
    steps_total=120,
    autoscaler=PlanAutoscaler(),
    deadline_changes=[(20.0, 105.0), (60.0, 400.0)],
)
kinds = [e.detail["kind"] for e in rec.events if e.kind == "scale"]
assert "grow" in kinds, kinds
assert ("retire" in kinds) or ("shrink" in kinds), kinds
assert rec.met_deadline, (rec.elapsed_s, rec.deadline_s)
assert rec.cloud_chip_s > 0
assert elastic_chips(rec.final_resources) == 0, "pod must be retired"
# the grow really re-striped the domain across 2 devices
assert max(s._n_stripes for s, _ in sessions) == 2
assert sessions[-1][0]._n_stripes == 1

# wavefield invariance: the policy-scaled run (1 -> 2 -> 1 stripes,
# every transition through ckpt -> remesh -> reshard) matches an
# unscaled single-device reference bit-for-bit up to the documented
# sharded-schedule tolerance
ref, _ = run_forward(cfg, steps=120)
last = sessions[-1][0]
assert last.t == 120, last.t
err = float(jnp.max(jnp.abs(
    np.asarray(last.p) - np.asarray(ref.p)
)))
assert err < 1e-8, f"wavefield diverged across scale events: {err}"
print("E2E_OK", len(kinds), round(rec.elapsed_s, 1), err)
"""


def test_fwi_deadline_squeeze_plan_policy_end_to_end_subprocess():
    src = str(Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _E2E_SCRIPT, src],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "E2E_OK" in out.stdout
