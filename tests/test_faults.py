"""Fault-injection harness + failure-hardened elastic loop (§19).

Covers the robustness acceptance surface: seeded determinism of the
fault streams (in-process and across subprocess boundaries), the
hardened-vs-unhardened gap on ``fault_storm`` (retry/backoff +
checkpoint-integrity fallback keep the hit-rate where the baseline
collapses), scavenger preemption admitting an expired weighted job,
admission-time deadline renegotiation, the CheckpointManager's CRC
verification / atomic-swap / fallback semantics, the SIGTERM
preemption hook (unit + kill→restore subprocess e2e reproducing the
uninterrupted wavefield), and the real orchestrator's fault-hook
retry loop and degraded-pod detector.
"""
import dataclasses
import os
import signal
import subprocess
import sys
import types
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    BurstPlanner,
    DeadlinePredictor,
    ElasticOrchestrator,
    LogCapacityModel,
    OverheadModel,
    PodSpec,
    Resources,
    ScaleAction,
    elastic_chips,
)
from repro.core.sim_session import SimWorkload, sim_session_factory
from repro.sim import (
    FaultInjector,
    FaultPlan,
    FleetSim,
    JobSpec,
    PlanAutoscaler,
    RetryPolicy,
)
from repro.sim.autoscalers import provider_backoff_active
from repro.sim.scenarios import (
    WORK,
    Scenario,
    fault_storm,
    preemption_pressure,
)

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _events(rec, kind):
    return [(j.name, t, d) for j in rec.jobs
            for t, k, d in j.events if k == kind]


# ----------------------------------------------------- faults.py units


def test_retry_policy_backoff_grows_caps_and_jitters():
    pol = RetryPolicy(max_retries=4, base_s=5.0, mult=2.0, cap_s=30.0,
                      jitter_frac=0.1)
    rng = np.random.default_rng(0)
    waits = [pol.backoff_s(k, rng) for k in range(1, 7)]
    for k, w in enumerate(waits, start=1):
        base = min(5.0 * 2.0 ** (k - 1), 30.0)
        assert base <= w <= base * 1.1
    # capped: attempts 4+ all draw from the same 30 s base
    assert all(30.0 <= w <= 33.0 for w in waits[3:])
    # deterministic given the same generator state
    again = [RetryPolicy(cap_s=30.0).backoff_s(k, np.random.default_rng(0))
             for k in (1,)]
    assert again[0] == RetryPolicy(cap_s=30.0).backoff_s(
        1, np.random.default_rng(0)
    )


def test_fault_injector_streams_are_per_job_deterministic():
    plan = FaultPlan(provision_fail_p=0.5, provision_timeout_p=0.5,
                     ckpt_corrupt_p=0.5, straggler_p=0.5)
    a = FaultInjector(plan, seed=7, job_index=0)
    b = FaultInjector(plan, seed=7, job_index=0)
    seq_a = [a.provision_outcome() for _ in range(4)] \
        + [a.ckpt_corrupt() for _ in range(4)] \
        + [a.straggler_k(1.4) for _ in range(4)]
    seq_b = [b.provision_outcome() for _ in range(4)] \
        + [b.ckpt_corrupt() for _ in range(4)] \
        + [b.straggler_k(1.4) for _ in range(4)]
    assert seq_a == seq_b
    other = FaultInjector(plan, seed=7, job_index=1)
    seq_o = [other.provision_outcome() for _ in range(4)] \
        + [other.ckpt_corrupt() for _ in range(4)] \
        + [other.straggler_k(1.4) for _ in range(4)]
    assert seq_o != seq_a


def test_provision_outcome_stream_position_is_plan_independent():
    """Both draws happen even at probability 0, so the stream position
    after N attempts never depends on the FaultPlan's parameters."""
    calm = FaultInjector(FaultPlan(), seed=3, job_index=0)
    wild = FaultInjector(
        FaultPlan(provision_fail_p=1.0, provision_timeout_p=1.0,
                  ckpt_corrupt_p=0.9),
        seed=3, job_index=0,
    )
    for _ in range(5):
        calm.provision_outcome()
        wild.provision_outcome()
    # identical positions -> identical next raw draw
    assert float(calm.rng.uniform()) == float(wild.rng.uniform())
    assert FaultPlan().any_faults() is False
    assert FaultPlan(straggler_p=0.1).any_faults() is True


def test_provider_backoff_active_cooldown():
    mk = lambda f, s: types.SimpleNamespace(  # noqa: E731
        provision_failures=f, since_failure_s=s
    )
    assert provider_backoff_active(mk(0, 0.0)) is False
    assert provider_backoff_active(mk(1, 30.0)) is True
    assert provider_backoff_active(mk(1, 61.0)) is False
    # doubling, capped at 960 s
    assert provider_backoff_active(mk(3, 200.0)) is True
    assert provider_backoff_active(mk(3, 250.0)) is False
    assert provider_backoff_active(mk(9, 959.0)) is True
    assert provider_backoff_active(mk(9, 961.0)) is False


# -------------------------------------------------- fleet: fault storm


def test_fault_storm_hardened_beats_unhardened_baseline():
    """The acceptance row: same faults, same seeds — the hardened loop
    keeps its hit-rate above the baseline at lower cloud cost."""
    for seed in (0, 1, 3):
        h = FleetSim(fault_storm(seed, hardened=True), PlanAutoscaler,
                     seed=seed).run()
        b = FleetSim(fault_storm(seed, hardened=False), PlanAutoscaler,
                     seed=seed).run()
        assert h.hit_rate > b.hit_rate, seed
        assert h.cloud_cost < b.cloud_cost, seed


def test_fault_storm_hardened_cost_bounded_vs_clean():
    """Robustness must not be bought with runaway spend: the hardened
    run under the full fault mix stays within 1.5x the cloud cost of
    the same scenario with faults disarmed."""
    sc = fault_storm(0, hardened=True)
    clean = dataclasses.replace(sc, faults=None, retry=None, name="clean")
    h = FleetSim(sc, PlanAutoscaler, seed=0).run()
    c = FleetSim(clean, PlanAutoscaler, seed=0).run()
    assert c.hit_rate == 1.0
    assert h.cloud_cost <= 1.5 * c.cloud_cost


def test_fault_runs_bit_deterministic_in_process():
    for hardened in (True, False):
        a = FleetSim(fault_storm(3, hardened=hardened), PlanAutoscaler,
                     seed=3).run()
        b = FleetSim(fault_storm(3, hardened=hardened), PlanAutoscaler,
                     seed=3).run()
        assert dataclasses.asdict(a) == dataclasses.asdict(b)
    c = FleetSim(fault_storm(4, hardened=True), PlanAutoscaler,
                 seed=4).run()
    assert dataclasses.asdict(c) != dataclasses.asdict(a)


def test_fault_run_deterministic_across_subprocess():
    """All fault draws flow from seeded streams in event-loop order —
    the digest of a hardened storm run pins across interpreters."""
    import hashlib

    script = (
        "import dataclasses, hashlib\n"
        "from repro.sim import FleetSim, PlanAutoscaler\n"
        "from repro.sim.scenarios import fault_storm\n"
        "rec = FleetSim(fault_storm(3, hardened=True), PlanAutoscaler,\n"
        "               seed=3).run()\n"
        "print(hashlib.sha256(\n"
        "    repr(dataclasses.asdict(rec)).encode()).hexdigest())\n"
    )
    rec = FleetSim(fault_storm(3, hardened=True), PlanAutoscaler,
                   seed=3).run()
    here = hashlib.sha256(
        repr(dataclasses.asdict(rec)).encode()
    ).hexdigest()
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}, check=True,
    )
    assert out.stdout.strip() == here


def test_retry_backoff_recovers_where_baseline_gives_up():
    """Seed 0: the hardened run retries denied provisioning into a
    success (retries > 0, nobody gives up); the unhardened baseline
    abandons its request on the first denial."""
    h = FleetSim(fault_storm(0, hardened=True), PlanAutoscaler,
                 seed=0).run()
    assert sum(j.retries for j in h.jobs) > 0
    assert not any(j.gave_up for j in h.jobs)
    assert _events(h, "provision_denied") and _events(h, "provision_retry")
    assert not _events(h, "provision_gave_up")
    b = FleetSim(fault_storm(0, hardened=False), PlanAutoscaler,
                 seed=0).run()
    gave = _events(b, "provision_gave_up")
    assert gave and any(j.gave_up for j in b.jobs)
    assert not _events(b, "provision_retry")


def test_ckpt_integrity_fallback_vs_blind_trust():
    """Hardened restore resumes from an older *intact* generation
    (resume_step < bad_step, > 0); the unhardened baseline trusts the
    corrupt latest and collapses the rollback to step 0."""
    h = FleetSim(fault_storm(1, hardened=True), PlanAutoscaler,
                 seed=1).run()
    falls = _events(h, "ckpt_fallback")
    assert falls
    for _, _, d in falls:
        assert 0 < d["resume_step"] < d["bad_step"]
    assert not _events(h, "ckpt_restore_failed")
    b = FleetSim(fault_storm(1, hardened=False), PlanAutoscaler,
                 seed=1).run()
    failed = _events(b, "ckpt_restore_failed")
    assert failed
    # the rollback that hit the corrupt generation restarted from 0
    names = {n for n, _, _ in failed}
    assert any(
        d["resume_step"] == 0 and d["lost_steps"] > 0
        for j in b.jobs if j.name in names
        for _, k, d in j.events if k == "spot_reclaim"
    )


def test_storm_and_straggler_events_surface():
    rec = FleetSim(fault_storm(2, hardened=True), PlanAutoscaler,
                   seed=2).run()
    storms = [(t, d) for t, k, d in rec.fleet_events
              if k == "reclaim_storm"]
    assert len(storms) == 1 and storms[0][0] == pytest.approx(1450.0)
    # the p=1.0 storm reclaims every job holding elastic chips then
    reclaims = [(n, t) for n, t, _ in _events(rec, "spot_reclaim")
                if t == pytest.approx(1450.0)]
    assert reclaims
    sc = fault_storm(2)
    stragglers = _events(rec, "straggler_pod")
    assert stragglers
    for _, _, d in stragglers:
        assert d["slowdown"] == pytest.approx(
            sc.cloud.slowdown * sc.faults.straggler_x
        )


# ------------------------------------------- preemption + renegotiation


def test_preemption_admits_expired_weighted_job():
    """The ROADMAP item: the starvation guard checkpoints the
    zero-weight scavenger through ckpt->restart and admits the expired
    gold job within one evaluation interval of patience expiry."""
    sc = preemption_pressure(0)
    rec = FleetSim(sc, PlanAutoscaler, seed=0).run()
    scav = next(j for j in rec.jobs if j.name == "scav0")
    gold = next(j for j in rec.jobs if j.name == "gold0")
    assert gold.finished and gold.met_deadline
    assert scav.finished and scav.preemptions == 1
    admit = next(t for t, k, _ in gold.events if k == "admit")
    # arrival 60 + patience 180 -> expired at 240; one 30 s interval
    assert admit <= 60.0 + sc.starve_patience_s + sc.eval_interval_s
    pre = next(d for _, k, d in scav.events if k == "preempted")
    assert pre["for_job"] == "gold0" if "for_job" in pre else True
    resume = next(d for _, k, d in scav.events if k == "resume")
    assert resume["resume_step"] > 0          # resumed from checkpoint
    assert any(k == "preempt" for _, k, _ in rec.fleet_events)


def test_preemption_off_starves_the_weighted_job():
    sc = dataclasses.replace(preemption_pressure(0), preemption=False)
    rec = FleetSim(sc, PlanAutoscaler, seed=0).run()
    gold = next(j for j in rec.jobs if j.name == "gold0")
    assert gold.finished and not gold.met_deadline


def _admission_run(deadline_s: float, admission: str):
    jobs = (
        JobSpec(name="j0", arrival_s=0.0, steps_total=50,
                deadline_s=deadline_s, chip_seconds_per_step=WORK,
                onprem_chips=128),
        JobSpec(name="j1", arrival_s=0.0, steps_total=50,
                deadline_s=10.0 ** 6, chip_seconds_per_step=WORK,
                onprem_chips=128),
    )
    sc = Scenario(name="adm", jobs=jobs, admission=admission)
    return FleetSim(sc, PlanAutoscaler, seed=0).run()


def test_admission_reject_excludes_infeasible_job():
    rec = _admission_run(10.0, "reject")
    j0 = next(j for j in rec.jobs if j.name == "j0")
    assert j0.state == "rejected" and not j0.finished
    t, k, d = j0.events[0]
    assert k == "admission_rejected" and d["min_feasible_s"] > 10.0
    # excluded from the hit-rate denominator: the feasible job alone
    assert rec.hit_rate == 1.0
    assert any(k == "admission_rejected" for _, k, _ in rec.fleet_events)


def test_admission_renegotiate_counter_offers_and_meets_it():
    rec = _admission_run(10.0, "renegotiate")
    j0 = next(j for j in rec.jobs if j.name == "j0")
    assert j0.renegotiated
    d = next(d for _, k, d in j0.events if k == "deadline_renegotiated")
    assert d["asked_s"] == 10.0
    assert d["offered_s"] == pytest.approx(
        d["min_feasible_s"] * 1.1
    )
    # the record judges against the offered deadline — and meets it
    assert j0.deadline_s == pytest.approx(d["offered_s"])
    assert j0.finished and j0.met_deadline


def test_admission_feasible_deadline_untouched():
    rec = _admission_run(10.0 ** 6, "renegotiate")
    j0 = next(j for j in rec.jobs if j.name == "j0")
    assert not j0.renegotiated
    assert not any(k == "deadline_renegotiated" for _, k, _ in j0.events)
    assert j0.deadline_s == 10.0 ** 6


# --------------------------------------- CheckpointManager hardening


jax = pytest.importorskip("jax")

from repro.checkpoint.manager import (  # noqa: E402
    CheckpointManager,
    NoIntactCheckpointError,
    install_preemption_hook,
)


def _save_gens(tmp_path, steps=(1, 2, 3), keep=3):
    m = CheckpointManager(tmp_path, async_save=False, keep=keep)
    for s in steps:
        m.save(s, {"x": np.full((4,), float(s))}, extra={"step": s})
    return m


def _corrupt(tmp_path, step):
    leaf = Path(tmp_path) / f"step_{step:08d}" / "x.npy"
    leaf.write_bytes(leaf.read_bytes()[:-3] + b"\x00\x00\x00")


def test_manager_crc_detects_corruption_and_falls_back(tmp_path):
    m = _save_gens(tmp_path)
    assert m.verify(3)
    _corrupt(tmp_path, 3)
    assert not m.verify(3)
    with pytest.warns(UserWarning, match="failed integrity"):
        state, extra = m.restore({"x": np.zeros(4)})
    assert extra["step"] == 2
    np.testing.assert_array_equal(np.asarray(state["x"]),
                                  np.full((4,), 2.0))


def test_manager_no_intact_checkpoint_is_a_clear_error(tmp_path):
    m = _save_gens(tmp_path, steps=(1, 2))
    for s in (1, 2):
        _corrupt(tmp_path, s)
    with pytest.warns(UserWarning):
        with pytest.raises(NoIntactCheckpointError, match="no intact"):
            m.restore({"x": np.zeros(4)})
    # explicit request for a corrupt generation also refuses
    with pytest.raises(NoIntactCheckpointError, match="step 2"):
        m.restore({"x": np.zeros(4)}, step=2)
    # and an empty directory is "nothing saved", not "all corrupt"
    empty = CheckpointManager(tmp_path / "empty", async_save=False)
    with pytest.raises(FileNotFoundError):
        empty.restore({"x": np.zeros(4)})


def test_manager_atomic_swap_artifacts_are_invisible(tmp_path):
    m = _save_gens(tmp_path, steps=(1, 2))
    # a crash mid-save leaves .tmp / .old staging dirs behind; neither
    # may ever surface as a restorable generation
    for suffix in (".tmp", ".old"):
        d = Path(tmp_path) / f"step_{9:08d}{suffix}"
        d.mkdir()
        (d / "manifest.json").write_text("{}")
    assert m.all_steps() == [1, 2]
    assert m.latest_step() == 2
    # overwriting an existing step goes through the rename-aside swap
    # and leaves no .old behind
    m.save(2, {"x": np.full((4,), 22.0)}, extra={"step": 2})
    assert not (Path(tmp_path) / f"step_{2:08d}.old").exists()
    state, _ = m.restore({"x": np.zeros(4)})
    np.testing.assert_array_equal(np.asarray(state["x"]),
                                  np.full((4,), 22.0))


def test_manager_keep_floor_preserves_a_fallback_candidate(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False, keep=1)
    assert m.keep == 2
    for s in (1, 2, 3):
        m.save(s, {"x": np.full((2,), float(s))})
    assert m.all_steps() == [2, 3]
    _corrupt(tmp_path, 3)
    with pytest.warns(UserWarning):
        state, _ = m.restore({"x": np.zeros(2)})
    np.testing.assert_array_equal(np.asarray(state["x"]),
                                  np.full((2,), 2.0))


# -------------------------------------------------- preemption hook


def test_install_preemption_hook_sigterm_saves_then_exits():
    saved = []
    prev = install_preemption_hook(lambda: saved.append(True),
                                   exit_code=143)
    try:
        with pytest.raises(SystemExit) as exc:
            signal.raise_signal(signal.SIGTERM)
        assert exc.value.code == 143
        assert saved == [True]
    finally:
        signal.signal(signal.SIGTERM, prev)
    assert signal.getsignal(signal.SIGTERM) is prev


def test_install_preemption_hook_saves_even_if_exit_is_suppressed():
    """The save must run before the exit is raised (try/finally):
    catching SystemExit still leaves the snapshot persisted."""
    saved = []
    prev = install_preemption_hook(lambda: saved.append(True))
    try:
        try:
            signal.raise_signal(signal.SIGTERM)
        except SystemExit:
            pass
        assert saved == [True]
    finally:
        signal.signal(signal.SIGTERM, prev)


def test_preemption_guard_roundtrips_session_snapshot(tmp_path):
    from repro.fwi.driver import (
        PreemptionGuard,
        load_session_snapshot,
        save_session_snapshot,
    )

    snap = {
        "p": np.arange(12.0, dtype=np.float32).reshape(3, 4),
        "p_prev": np.ones((3, 4), np.float32),
        "t": 8, "pending": 3, "amortized_s": 0.25,
        "res_sig": (2, ((1, 1.0), (1, 1.4))), "amortized_eff": 1.714,
    }
    session = types.SimpleNamespace(checkpoint=lambda step: dict(snap))
    m = CheckpointManager(tmp_path, async_save=False)
    guard = PreemptionGuard(m).install()
    try:
        guard._save()                       # nothing published yet
        assert m.latest_step() is None
        guard.publish(session, steps_done=7)
        guard._save()
    finally:
        guard.uninstall()
    restored, steps_done = load_session_snapshot(m)
    assert steps_done == 7
    assert restored["t"] == 8 and restored["pending"] == 3
    # JSON round-trip must hand back tuples (FWISession compares !=)
    assert restored["res_sig"] == (2, ((1, 1.0), (1, 1.4)))
    np.testing.assert_array_equal(restored["p"], snap["p"])
    # save_session_snapshot is the same path the guard used
    save_session_snapshot(m, 9, snap)
    _, again = load_session_snapshot(m)
    assert again == 9


_E2E_CHILD = """
import sys, time
import numpy as np
from repro.checkpoint.manager import CheckpointManager
from repro.core.orchestrator import PodSpec, Resources
from repro.fwi.driver import (
    FWISession, PreemptionGuard, TimeModel, load_session_snapshot,
)
from repro.fwi.solver import FWIConfig

mode, ckpt_dir, out = sys.argv[1], sys.argv[2], sys.argv[3]
TOTAL = 20
cfg = FWIConfig(nz=32, nx=64, timesteps=32, n_shots=1, sponge_width=4)
res = Resources(pods=[PodSpec(chips=1, name="cluster")], shares=[1.0])
mgr = CheckpointManager(ckpt_dir, async_save=False)
kw = dict(time_model=TimeModel(jitter=0.0),
          rng=np.random.default_rng(0), exchange_interval=4,
          scan_block=4)
if mode == "run":
    guard = PreemptionGuard(mgr).install()
    session = FWISession(cfg, res, 0, None, **kw)
    start = 0
else:
    restored, start = load_session_snapshot(mgr)
    session = FWISession(cfg, res, start, restored, **kw)
for step in range(start, TOTAL):
    session.run_step(step)
    if mode == "run":
        guard.publish(session, step + 1)
        print(f"STEP {step + 1}", flush=True)
        time.sleep(0.2)
np.save(out, np.asarray(session.p))
print(f"DONE {start}", flush=True)
"""


def test_sigterm_kill_and_restore_reproduces_wavefield(tmp_path):
    """The whole preemption chain, end to end: SIGTERM mid-run ->
    handler persists the published snapshot -> exit 143 -> a fresh
    process restores and finishes -> final wavefield matches an
    uninterrupted run to f32 tolerance."""
    from repro.fwi.driver import FWISession, TimeModel
    from repro.fwi.solver import FWIConfig

    child = tmp_path / "child.py"
    child.write_text(_E2E_CHILD)
    ckpt = tmp_path / "ckpt"
    out = tmp_path / "resumed.npy"
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.Popen(
        [sys.executable, str(child), "run", str(ckpt), str(out)],
        stdout=subprocess.PIPE, text=True, env=env,
    )
    steps_seen = 0
    for line in proc.stdout:
        if line.startswith("STEP"):
            steps_seen = int(line.split()[1])
            if steps_seen >= 3:
                proc.send_signal(signal.SIGTERM)
                break
    proc.stdout.read()
    assert proc.wait(timeout=120) == 143   # clean preemption exit
    assert not out.exists()                # it never ran to the end
    second = subprocess.run(
        [sys.executable, str(child), "resume", str(ckpt), str(out)],
        capture_output=True, text=True, env=env, check=True,
        timeout=300,
    )
    resumed_from = int(second.stdout.strip().split()[-1])
    assert 3 <= resumed_from < 20          # mid-run, not a restart
    # uninterrupted reference in-process (bit-identical math: the
    # wavefield depends only on the dispatched timesteps)
    cfg = FWIConfig(nz=32, nx=64, timesteps=32, n_shots=1,
                    sponge_width=4)
    res = Resources(pods=[PodSpec(chips=1, name="cluster")],
                    shares=[1.0])
    ref = FWISession(cfg, res, 0, None, time_model=TimeModel(jitter=0.0),
                     rng=np.random.default_rng(0), exchange_interval=4,
                     scan_block=4)
    for step in range(20):
        ref.run_step(step)
    np.testing.assert_allclose(
        np.load(out), np.asarray(ref.p), atol=1e-6
    )


# -------------------------------------- real orchestrator hardening


LEGAL = [16, 32, 64, 128, 256]


def _planner(**kw):
    m = LogCapacityModel.fit(LEGAL, [2000.0 / c for c in LEGAL])
    defaults = dict(
        cluster_model=m, cloud_model=m, chips_cluster=256,
        legal_slices=LEGAL,
        overheads=OverheadModel(ckpt_s=5, provision_s=60, restart_s=20),
    )
    defaults.update(kw)
    return BurstPlanner(**defaults)


class _GrowOnce:
    name = "grow-once"

    def __init__(self, at=8):
        self.at = at

    def decide(self, ctx):
        if ctx.step == self.at and ctx.cloud_chips == 0:
            return ScaleAction("grow", chips=64, slowdown=1.4)
        return ScaleAction("hold")


def _orch(**kw):
    return ElasticOrchestrator(
        planner=_planner(), predictor=DeadlinePredictor(10_000.0),
        check_every=8, ckpt_every=25, **kw,
    )


def test_orchestrator_fault_hook_retries_into_success():
    factory = sim_session_factory(
        SimWorkload(2000.0, jitter=0.0), rng=np.random.default_rng(0)
    )
    rec = _orch().run(
        session_factory=factory,
        initial=Resources(pods=[PodSpec(256, name="cluster")],
                          shares=[1.0]),
        steps_total=40, autoscaler=_GrowOnce(),
        fault_hook=lambda kind, d: d["attempt"] <= 2,
        retry_policy=RetryPolicy(max_retries=4, base_s=1.0),
    )
    assert rec.completed and rec.retries == 2 and not rec.gave_up
    kinds = [e.kind for e in rec.events]
    assert kinds.count("provision_denied") == 2
    assert kinds.count("provision_retry") == 2
    # the third attempt succeeded: the grow actually landed
    assert any(e.kind == "scale" and e.detail["kind"] == "grow"
               for e in rec.events)
    # the paid backoff is on the session clock
    backoff = sum(e.detail["backoff_s"] for e in rec.events
                  if e.kind == "provision_retry")
    assert backoff > 0


def test_orchestrator_fault_hook_exhaustion_gives_up():
    factory = sim_session_factory(
        SimWorkload(2000.0, jitter=0.0), rng=np.random.default_rng(0)
    )
    rec = _orch().run(
        session_factory=factory,
        initial=Resources(pods=[PodSpec(256, name="cluster")],
                          shares=[1.0]),
        steps_total=40, autoscaler=_GrowOnce(),
        fault_hook=lambda kind, d: True,
        retry_policy=RetryPolicy(max_retries=3, base_s=1.0),
    )
    assert rec.completed and rec.gave_up
    assert rec.retries == 4                # max_retries + final attempt
    assert any(e.kind == "provision_gave_up" for e in rec.events)
    assert not any(e.kind == "scale" and e.detail["kind"] == "grow"
                   for e in rec.events)
    assert elastic_chips(rec.final_resources) == 0
    # without a retry policy the very first denial gives up
    rec2 = _orch().run(
        session_factory=sim_session_factory(
            SimWorkload(2000.0, jitter=0.0),
            rng=np.random.default_rng(0),
        ),
        initial=Resources(pods=[PodSpec(256, name="cluster")],
                          shares=[1.0]),
        steps_total=40, autoscaler=_GrowOnce(),
        fault_hook=lambda kind, d: True,
    )
    assert rec2.gave_up and rec2.retries == 1


def test_orchestrator_degraded_pod_detector_retires():
    """A pod measuring far above the calibrated model is sick: the
    detector forces a RETIRE and the loop re-stripes around it."""
    mk = lambda: sim_session_factory(  # noqa: E731
        SimWorkload(2000.0, jitter=0.0), rng=np.random.default_rng(0),
        extra_slowdown=lambda i, step: 6.0 if i > 0 else 1.0,
    )
    initial = Resources(pods=[PodSpec(256, name="cluster")],
                        shares=[1.0])
    rec = _orch(degraded_factor=2.0).run(
        session_factory=mk(), initial=initial, steps_total=40,
        autoscaler=_GrowOnce(),
    )
    degraded = [e for e in rec.events if e.kind == "degraded"]
    assert degraded
    assert degraded[0].detail["measured_s"] > \
        2.0 * degraded[0].detail["modeled_s"]
    retire = [e for e in rec.events
              if e.kind == "scale" and e.detail["kind"] == "retire"]
    assert retire and retire[0].detail["reason"].startswith("degraded")
    assert elastic_chips(rec.final_resources) == 0
    # without the detector the sick pod is kept all the way
    rec2 = _orch().run(
        session_factory=mk(), initial=initial, steps_total=40,
        autoscaler=_GrowOnce(),
    )
    assert not any(e.kind == "degraded" for e in rec2.events)
    assert elastic_chips(rec2.final_resources) > 0
    # and the degraded run finished sooner than the stuck one
    assert rec.elapsed_s < rec2.elapsed_s
