"""Unit tests for the HLO static cost model (launch/hlo_cost.py) — the
foundation of every roofline number in EXPERIMENTS.md."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import (
    HloCostModel,
    Shape,
    _collective_wire_bytes,
    analyze,
    parse_module,
    parse_type,
    xla_cost_analysis,
)


def test_parse_type_scalar_tensor_tuple():
    s = parse_type("f32[8,4]{1,0}")
    assert isinstance(s, Shape) and s.dims == (8, 4) and s.bytes == 128
    assert parse_type("pred[]").size == 1
    tup = parse_type("(bf16[2,2]{1,0}, s32[])")
    assert isinstance(tup, list) and tup[0].bytes == 8 and tup[1].bytes == 4


def test_scan_trip_count_correction():
    """The reason this module exists: XLA counts while bodies once."""
    W = jnp.zeros((8, 256, 256), jnp.float32)
    x = jnp.zeros((4, 256), jnp.float32)

    def f(W, x):
        return jax.lax.scan(lambda x, w: (x @ w, None), x, W)[0]

    c = jax.jit(f).lower(W, x).compile()
    r = analyze(c.as_text(), total_devices=1)
    assert r["flops"] == pytest.approx(8 * 2 * 4 * 256 * 256)
    assert 8 in r["while_trips"]
    # raw XLA counts one iteration
    assert xla_cost_analysis(c)["flops"] == pytest.approx(
        2 * 4 * 256 * 256, 1
    )


def test_nested_scan_trip_multiplication():
    W = jnp.zeros((4, 3, 64, 64), jnp.float32)
    x = jnp.zeros((2, 64), jnp.float32)

    def f(W, x):
        def outer(x, Wi):
            def inner(x, w):
                return x @ w, None

            return jax.lax.scan(inner, x, Wi)[0], None

        return jax.lax.scan(outer, x, W)[0]

    c = jax.jit(f).lower(W, x).compile()
    r = analyze(c.as_text(), total_devices=1)
    assert r["flops"] == pytest.approx(4 * 3 * 2 * 2 * 64 * 64)


def test_collective_wire_formulas():
    # 1 MB payload, group of 4
    mb = 1 << 20
    assert _collective_wire_bytes("all-gather", mb, 4) == mb * 3 / 4
    assert _collective_wire_bytes("all-reduce", mb, 4) == 2 * mb * 3 / 4
    assert _collective_wire_bytes("reduce-scatter", mb, 4) == mb * 3
    assert _collective_wire_bytes("collective-permute", mb, 4) == mb
    # -start variants normalize
    assert _collective_wire_bytes("all-reduce-start", mb, 4) == \
        _collective_wire_bytes("all-reduce", mb, 4)


_SYNTH = """
HloModule synth

ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %cp = f32[16,128]{1,0} collective-permute(%ar), channel_id=2, source_target_pairs={{0,4},{4,0}}
  ROOT %ag = f32[16,128]{1,0} all-gather(%cp), channel_id=3, replica_groups={{0,4},{1,5}}, dimensions={0}
}
"""


def test_synthetic_collectives_and_dci_attribution():
    m = HloCostModel(_SYNTH, total_devices=8, pod_size=4)
    c = m.entry_cost()
    nbytes = 16 * 128 * 4
    # all-reduce: groups {0..3} within pod 0 -> ICI
    # permute: 0<->4 crosses the pod-size-4 boundary -> DCI
    # all-gather: groups {0,4} cross -> DCI
    expected_ar = 2 * nbytes * 3 / 4
    expected_cp = nbytes
    expected_ag = nbytes * 1 / 2
    assert c.coll_bytes == pytest.approx(
        expected_ar + expected_cp + expected_ag
    )
    assert c.coll_dci_bytes == pytest.approx(expected_cp + expected_ag)
    assert c.coll_count == 3


def test_parse_module_entry_detection():
    comps, entry = parse_module(_SYNTH)
    assert entry == "main"
    assert len(comps["main"].ops) == 4


def test_dus_in_place_credit():
    """A decode-style cache update must charge ~slice bytes, not the full
    cache round trip."""
    cache = jnp.zeros((4, 1024, 64), jnp.float32)
    new = jnp.ones((4, 1, 64), jnp.float32)

    def f(cache, new):
        return jax.lax.dynamic_update_slice(cache, new, (0, 5, 0))

    c = jax.jit(f, donate_argnums=(0,)).lower(cache, new).compile()
    r = analyze(c.as_text(), total_devices=1)
    full = 4 * 1024 * 64 * 4
    assert r["hbm_bytes"] < 0.2 * full, r["hbm_bytes"]


def test_layout_fusions_charged_zero():
    """bf16->f32 convert chains (CPU staging) must not count as HBM
    traffic — on TPU they fuse into the consuming dot."""
    w = jnp.zeros((512, 512), jnp.bfloat16)
    x = jnp.zeros((64, 512), jnp.bfloat16)

    def f(x, w):
        return (x @ w).astype(jnp.bfloat16)

    c = jax.jit(f).lower(x, w).compile()
    r = analyze(c.as_text(), total_devices=1)
    true_traffic = (64 * 512 + 512 * 512 + 64 * 512) * 2  # bf16 in/out
    # allow 2x slack for residual f32 charging, but not the naive 4-6x
    assert r["hbm_bytes"] <= 2.5 * true_traffic, (
        r["hbm_bytes"], true_traffic
    )
