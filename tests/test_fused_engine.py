"""Overlap-and-fuse propagation engine tests.

Equivalence ladder for the fused engine:
  pad-slice laplacian  == roll laplacian          (bitwise)
  scan-runner          == per-step jitted loop    (bitwise, incl. traces)
  wave_block (XLA)     == k sequential ref steps  (BITWISE — the fused
                          block is a pure re-scheduling of the same ops)
  wave_block (Pallas)  == same, to documented allclose tolerance (the
                          kernel's z/x stencil accumulation order
                          differs from the reference)
  overlapped sharded k-step block == reference    (bitwise on the XLA
                          path, incl. across REAL stripe seams)
plus the communication claims: ppermute count per timestep drops k×,
the halo-plan bookkeeping (incl. overlap fields) matches the lowered
HLO, and the launch-boundary HBM proxy drops k× for fused blocks.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fwi.domain import (
    effective_block,
    halo_bytes_per_step,
    halo_exchange_plan,
    make_sharded_multistep,
    make_sharded_scan_runner,
    stripe_mesh,
)
from repro.fwi.solver import (
    FWIConfig,
    ShotState,
    make_scan_runner,
    make_step_fn,
    run_forward,
    velocity_model,
)
from repro.kernels.stencil.ref import laplacian, laplacian_roll

CFG = FWIConfig(nz=64, nx=128, timesteps=48, n_shots=2, sponge_width=8)


# ------------------------------------------------------------ solver layer


def test_laplacian_pad_equals_roll_bitwise():
    p = jax.random.normal(jax.random.key(3), (2, 96, 80), jnp.float32)
    a = laplacian_roll(p)
    b = laplacian(p)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scan_runner_traces_equal_per_step_loop():
    """The fused scan must reproduce the per-step dispatch loop exactly,
    receiver traces included."""
    step = make_step_fn(CFG)
    st = ShotState.init(CFG)
    p, pp = st.p, st.p_prev
    traces = []
    for t in range(CFG.timesteps):
        p, pp, tr = step(p, pp, t)
        traces.append(tr)
    loop_tr = jnp.stack(traces, axis=1)
    st_scan, scan_tr = run_forward(CFG)
    np.testing.assert_array_equal(np.asarray(st_scan.p), np.asarray(p))
    np.testing.assert_array_equal(np.asarray(scan_tr), np.asarray(loop_tr))


def test_scan_runner_restart_offset_no_retrace():
    """t0 is traced: restarting mid-run reuses the compiled runner and
    matches the straight-through run bit-for-bit."""
    run = make_scan_runner(CFG, collect_traces=True)
    st = ShotState.init(CFG)
    p_a, pp_a, tr_a = run(st.p, st.p_prev, 0, 48)
    p_b, pp_b, tr1 = run(st.p, st.p_prev, 0, 24)
    p_b, pp_b, tr2 = run(p_b, pp_b, 24, 24)
    np.testing.assert_array_equal(np.asarray(p_a), np.asarray(p_b))
    np.testing.assert_array_equal(
        np.asarray(tr_a), np.asarray(jnp.concatenate([tr1, tr2], axis=1))
    )


def test_model_building_memoized():
    assert velocity_model(CFG) is velocity_model(CFG)
    assert make_scan_runner(CFG) is make_scan_runner(CFG)
    assert make_step_fn(CFG) is make_step_fn(CFG)


# ------------------------------------------------- temporal blocking layer


@pytest.mark.parametrize("k", [2, 4, 8])
def test_temporal_block_equals_sequential_ref(k):
    """One k-step block (single halo exchange) == k sequential reference
    steps, to well under the 1e-4 acceptance tolerance."""
    ref, ref_tr = run_forward(CFG, steps=CFG.timesteps)
    mesh = stripe_mesh(1)
    blk, place = make_sharded_multistep(CFG, mesh, k=k)
    s = ShotState.init(CFG)
    p, pp = place((s.p, s.p_prev))
    trs = []
    for b in range(CFG.timesteps // k):
        p, pp, tr = blk(p, pp, b * k)
        trs.append(tr)
    tr = jnp.concatenate(trs, axis=1)
    np.testing.assert_allclose(np.asarray(p), np.asarray(ref.p), atol=1e-6)
    np.testing.assert_allclose(np.asarray(tr), np.asarray(ref_tr),
                               atol=1e-6)


def test_sharded_scan_runner_equals_reference():
    ref, ref_tr = run_forward(CFG, steps=CFG.timesteps)
    run, place, k = make_sharded_scan_runner(CFG, stripe_mesh(1), k=4)
    s = ShotState.init(CFG)
    p, pp = place((s.p, s.p_prev))
    p, pp, tr = run(p, pp, 0, CFG.timesteps // k)
    np.testing.assert_allclose(np.asarray(p), np.asarray(ref.p), atol=1e-6)
    np.testing.assert_allclose(np.asarray(tr), np.asarray(ref_tr),
                               atol=1e-6)


_MULTI_STRIPE_BLOCKED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro.fwi.solver import FWIConfig, ShotState, run_forward
from repro.fwi.domain import stripe_mesh, make_sharded_multistep

cfg = FWIConfig(nz=64, nx=128, timesteps=40, n_shots=2, sponge_width=8)
ref, ref_tr = run_forward(cfg, steps=40)
for overlap in (True, False):
    for k in (2, 4, 8):
        for n in (2, 4):
            mesh = stripe_mesh(n)
            blk, place = make_sharded_multistep(
                cfg, mesh, k=k, overlap=overlap
            )
            s = ShotState.init(cfg)
            p, pp = place((s.p, s.p_prev))
            trs = []
            for b in range(40 // blk.k):
                p, pp, tr = blk(p, pp, b * blk.k)
                trs.append(tr)
            tr = jnp.concatenate(trs, axis=1)
            if overlap:
                # the overlapped XLA block path is pinned BITWISE equal
                # to the seed reference, seams included
                assert np.array_equal(np.asarray(p), np.asarray(ref.p)), (k, n)
                assert np.array_equal(np.asarray(tr), np.asarray(ref_tr)), (k, n)
            else:
                # the single-window schedule computes the identical op
                # sequence but its different fusion shapes may flush
                # denormal wavefront tails differently — equal up to
                # sub-normal noise (< FLT_MIN = 1.2e-38)
                perr = np.max(np.abs(np.asarray(p) - np.asarray(ref.p)))
                terr = np.max(np.abs(np.asarray(tr) - np.asarray(ref_tr)))
                assert perr < 1.2e-38 and terr < 1.2e-38, (k, n, perr, terr)

# shot-parallel fused runner: zero-communication first-level split;
# contract is f32-ULP allclose (per-device batch changes XLA's
# vectorization/FMA contraction), documented in the factory docstring
from repro.fwi.solver import make_shot_parallel_runner
run_sp, place_sp = make_shot_parallel_runner(cfg, 2, k=4)
s = ShotState.init(cfg)
p, pp = place_sp((s.p, s.p_prev))
p, pp, tr = run_sp(p, pp, 0, 40)
scale = float(np.max(np.abs(np.asarray(ref.p)))) or 1.0
perr = np.max(np.abs(np.asarray(p) - np.asarray(ref.p))) / scale
terr = np.max(np.abs(np.asarray(tr) - np.asarray(ref_tr))) / scale
assert perr < 1e-6 and terr < 1e-6, (perr, terr)
print("BLOCKED_MULTI_STRIPE_OK")
"""


def test_temporal_block_multi_stripe_subprocess():
    """Temporal blocking across REAL stripe boundaries (4 host devices):
    k-step blocks with one packed exchange match the reference for
    several (k, stripe-count) combinations."""
    src = str(Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _MULTI_STRIPE_BLOCKED, src],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "BLOCKED_MULTI_STRIPE_OK" in out.stdout


def test_ppermute_count_drops_k_fold():
    """k=4 temporal blocking must emit the SAME 2 collective-permutes
    per block as k=1 — i.e. 4× fewer per timestep."""
    mesh = stripe_mesh(1)
    s = ShotState.init(CFG)
    counts = {}
    for k in (1, 4):
        blk, place = make_sharded_multistep(CFG, mesh, k=k)
        p, pp = place((s.p, s.p_prev))
        txt = jax.jit(blk).lower(p, pp, 0).as_text()
        counts[k] = txt.count("collective_permute") \
            + txt.count("collective-permute")
    assert counts[1] == 2, counts
    assert counts[4] == 2, counts          # per-timestep: 2 vs 0.5 = 4x


def test_halo_exchange_plan_bookkeeping():
    # seed formula preserved at k=1
    assert halo_bytes_per_step(CFG, 4) == 2 * 2 * CFG.nz * CFG.n_shots * 4
    plan1 = halo_exchange_plan(CFG, 4, k=1)
    plan4 = halo_exchange_plan(CFG, 4, k=4)
    assert plan1["ppermutes_per_step"] == 2.0
    assert plan4["ppermutes_per_step"] == 0.5
    assert plan4["steps_per_exchange"] == 4
    # packed p+p_prev edges: amortized bytes exactly 2x the k=1 stream
    assert plan4["bytes_per_step"] == 2 * plan1["bytes_per_step"]
    # k clamps so the overlap fits in a stripe, and the clamped value is
    # exposed on the block step so callers advance t0 correctly
    assert effective_block(CFG, CFG.nx // 2, 64) == 1
    blk, _ = make_sharded_multistep(CFG, stripe_mesh(1), k=4)
    assert blk.k == 4


def test_effective_block_keeps_overlap_inside_stripe():
    """Regression: the clamp must keep the boundary-window source
    regions (2·k·HALO columns each side) inside one stripe for ANY
    requested k — otherwise the interior/boundary split would read
    columns a stripe does not own."""
    from repro.fwi.domain import HALO

    for n in (1, 2, 4, 8, 16, 32):
        if CFG.nx % n:
            continue
        nxl = CFG.nx // n
        for k in (1, 2, 4, 8, 64, 1000):
            keff = effective_block(CFG, n, k)
            assert 1 <= keff <= k
            assert 2 * keff * HALO <= nxl or keff == 1, (n, k, keff)


def test_halo_exchange_plan_overlap_fields():
    plan = halo_exchange_plan(CFG, 4, k=4)
    nxl = CFG.nx // 4
    pad = plan["k"] * 2
    assert plan["interior_cols"] == nxl
    assert plan["boundary_cols"] == 6 * pad
    assert 0.0 < plan["overlap_fraction"] < 1.0
    assert plan["overlap_fraction"] == nxl / (nxl + 6 * pad)
    # more stripes -> narrower stripes -> less hidable work
    wide = halo_exchange_plan(CFG, 1, k=4)["overlap_fraction"]
    narrow = halo_exchange_plan(CFG, 8, k=4)["overlap_fraction"]
    assert narrow < wide


def test_overhead_model_overlapped_seam():
    from repro.core import OverheadModel

    plan = halo_exchange_plan(CFG, 4, k=4)
    om_measured = OverheadModel().with_measured_seam(plan, 1e-3)
    # unknown compute time -> no overlap credit: degrades to measured
    om0 = OverheadModel().with_overlapped_seam(plan, 1e-3, 0.0)
    assert om0.seam_s_per_step() == om_measured.seam_s_per_step()
    # interior compute larger than the seam -> fully hidden
    om_hidden = OverheadModel().with_overlapped_seam(plan, 1e-3, 1.0)
    assert om_hidden.seam_s_per_step() == 0.0
    # partial hiding: residue = seam_block - interior_block, monotone
    seam_block = plan["ppermutes_per_exchange"] * 1e-3
    t_c = 0.5 * seam_block / (
        plan["steps_per_exchange"] * plan["overlap_fraction"]
    )
    om_half = OverheadModel().with_overlapped_seam(plan, 1e-3, t_c)
    assert 0.0 < om_half.seam_latency_s < seam_block
    np.testing.assert_allclose(om_half.seam_latency_s, seam_block / 2)


# --------------------------------------------------- fused block kernel


def _sequential_ref(p, pp, v, s, srcv, zi, xi, rrow):
    """k seed-form steps + injection + receiver rows — the oracle the
    fused block must reproduce."""
    from repro.kernels.stencil.ref import wave_step_ref

    traces = []
    for j in range(srcv.shape[0]):
        pn, pd = wave_step_ref(p, pp, v, s)
        pn = pn.at[zi, xi].add(srcv[j])
        traces.append(pn[rrow])
        p, pp = pn, pd
    return p, pp, jnp.stack(traces)


def _block_fields(nz, nx, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    p = jax.random.normal(ks[0], (nz, nx), jnp.float32)
    pp = jax.random.normal(ks[1], (nz, nx), jnp.float32)
    v = jax.random.uniform(ks[2], (nz, nx), jnp.float32, 0.05, 0.2)
    s = jnp.clip(jax.random.uniform(ks[3], (nz, nx)), 0.9, 1.0)
    return p, pp, v, s


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_wave_block_xla_bitwise_vs_sequential_ref(k):
    """The pure-XLA fused block is a re-scheduling of the identical ops:
    BITWISE equal to k sequential seed-form steps (random fields put
    energy at every physical domain edge)."""
    from repro.kernels.stencil.ops import wave_block

    nz, nx = 64, 96
    p, pp, v, s = _block_fields(nz, nx, seed=k)
    srcv = jnp.linspace(0.5, 1.0, k)
    zi, xi = nz // 3, nx // 2
    a = _sequential_ref(p, pp, v, s, srcv, zi, xi, 2)
    b = wave_block(p, pp, v, s, srcv, zi, xi, receiver_row=2)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("k", [1, 2, 4, 8])
@pytest.mark.parametrize("bz", [8, 32, None])
def test_wave_block_pallas_matches_ref(k, bz):
    """Pallas trapezoid kernel vs the sequential reference across
    (bz, k).  Contract: allclose at 5e-5 (NOT bitwise — the kernel
    accumulates the z then x stencil rings, the reference interleaves
    them per ring; each inner step compounds ~1e-6)."""
    from repro.kernels.stencil.ops import wave_block

    nz, nx = 64, 96
    p, pp, v, s = _block_fields(nz, nx, seed=10 + k)
    srcv = jnp.linspace(0.5, 1.0, k)
    zi, xi = 1, nx - 2            # source ON the corner boundary region
    a = _sequential_ref(p, pp, v, s, srcv, zi, xi, 2)
    b = wave_block(p, pp, v, s, srcv, zi, xi, receiver_row=2,
                   use_pallas=True, bz=bz)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=5e-5)


def test_wave_block_single_strip_fallback():
    """Grids too short for any multi-strip trapezoid (prime nz) run as
    one whole-height strip and still match."""
    from repro.kernels.stencil.kernel import pick_bz_block
    from repro.kernels.stencil.ops import wave_block

    nz, nx = 37, 64
    assert pick_bz_block(nz, 8) == nz
    p, pp, v, s = _block_fields(nz, nx, seed=3)
    srcv = jnp.ones((8,)) * 0.5
    a = _sequential_ref(p, pp, v, s, srcv, 5, 6, 1)
    b = wave_block(p, pp, v, s, srcv, 5, 6, receiver_row=1,
                   use_pallas=True)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=5e-5)


def test_block_runner_factories_key_on_full_knobs():
    """Memoized factories must key on (k, bz, use_pallas) so autotuned
    variants don't collide in the cache — and still hit for equal args."""
    from repro.fwi.solver import make_block_runner

    a = make_block_runner(CFG, k=2)
    assert make_block_runner(CFG, k=2) is a
    assert make_block_runner(CFG, k=4) is not a
    assert make_block_runner(CFG, k=2, bz=8) is not a
    assert make_block_runner(CFG, k=2, use_pallas=True) is not a
    m1, _ = make_sharded_multistep(CFG, stripe_mesh(1), k=2)
    m2, _ = make_sharded_multistep(CFG, stripe_mesh(1), k=2, bz=8)
    m3, _ = make_sharded_multistep(CFG, stripe_mesh(1), k=2)
    assert m1 is m3 and m1 is not m2


def test_autotune_bz_k_memoized_per_shape_and_backend():
    """The joint (bz, k) autotune must be measured once per (shape,
    backend) — RESHARD-triggered session rebuilds hit the cache."""
    from repro.kernels.stencil.kernel import (
        _autotune_bz_k_cached, autotune_bz_k,
    )

    nz, nx = 32, 64
    before = _autotune_bz_k_cached.cache_info()
    r1 = autotune_bz_k(nz, nx, bz_candidates=(8, 16),
                       k_candidates=(1, 2), repeats=1)
    mid = _autotune_bz_k_cached.cache_info()
    r2 = autotune_bz_k(nz, nx, bz_candidates=(8, 16),
                       k_candidates=(1, 2), repeats=1)
    after = _autotune_bz_k_cached.cache_info()
    assert r1 == r2
    assert mid.misses == before.misses + 1
    assert after.hits == mid.hits + 1 and after.misses == mid.misses
    bz, k = r1
    assert nz % bz == 0 and k in (1, 2)


def test_entry_boundary_bytes_drops_k_fold():
    """The launch-boundary HBM proxy: a k-step fused block moves the
    wavefields across the jit boundary once per k steps."""
    from repro.kernels.stencil.ops import wave_block, wave_step
    from repro.launch.hlo_cost import entry_boundary_bytes

    nz, nx, k = 64, 96, 4
    p, pp, v, s = _block_fields(nz, nx)
    f_step = jax.jit(
        lambda a, b, vv, ss: wave_step(a, b, vv, ss)
    ).lower(p, pp, v, s).compile()
    srcv = jnp.zeros((k,))
    f_blk = jax.jit(
        lambda a, b, vv, ss, sv: wave_block(a, b, vv, ss, sv, 3, 4)
    ).lower(p, pp, v, s, srcv).compile()
    shape = (nz, nx)
    sb = entry_boundary_bytes(f_step.as_text(), shape)
    bb = entry_boundary_bytes(f_blk.as_text(), shape)
    assert sb["n_params"] == 4 and sb["n_results"] == 2
    assert bb["n_params"] == 4 and bb["n_results"] == 2
    ratio = sb["total_bytes"] / (bb["total_bytes"] / k)
    assert ratio >= 2.0, ratio                   # acceptance: >= 2x at k=4
    np.testing.assert_allclose(ratio, k)


# --------------------------------------------------------- kernel layer


@pytest.mark.parametrize("bz", [8, 16, 32, 64, None])
def test_pallas_bz_sweep_matches_ref(bz):
    """Single-input BlockSpec kernel vs ref across strip heights,
    including the auto-picked one (bz=None)."""
    from repro.kernels.stencil.ops import wave_step

    nz, nx = 64, 256
    ks = jax.random.split(jax.random.key(7), 4)
    p = jax.random.normal(ks[0], (nz, nx), jnp.float32)
    pp = jax.random.normal(ks[1], (nz, nx), jnp.float32)
    v = jax.random.uniform(ks[2], (nz, nx), jnp.float32, 0.05, 0.2)
    sponge = jnp.clip(jax.random.uniform(ks[3], (nz, nx)), 0.9, 1.0)
    a1, a2 = wave_step(p, pp, v, sponge)
    b1, b2 = wave_step(p, pp, v, sponge, use_pallas=True, bz=bz)
    np.testing.assert_allclose(a1, b1, atol=3e-6)
    np.testing.assert_allclose(a2, b2, atol=3e-6)


def test_sharded_pallas_path_equals_reference():
    """use_pallas wired through the sharded local step: the fused kernel
    runs inside the shard_map region and matches the reference."""
    cfg = FWIConfig(nz=32, nx=64, timesteps=8, n_shots=1, sponge_width=4)
    ref, ref_tr = run_forward(cfg, steps=8)
    blk, place = make_sharded_multistep(
        cfg, stripe_mesh(1), k=4, use_pallas=True
    )
    s = ShotState.init(cfg)
    p, pp = place((s.p, s.p_prev))
    trs = []
    for b in range(2):
        p, pp, tr = blk(p, pp, b * 4)
        trs.append(tr)
    tr = jnp.concatenate(trs, axis=1)
    np.testing.assert_allclose(np.asarray(p), np.asarray(ref.p), atol=1e-5)
    np.testing.assert_allclose(np.asarray(tr), np.asarray(ref_tr),
                               atol=1e-5)


def test_driver_checkpoint_carries_block_progress():
    """A mid-block checkpoint/restore must not re-dispatch the pending
    steps: physical timesteps stay in lockstep with logical steps."""
    from repro.core.orchestrator import PodSpec, Resources
    from repro.fwi.driver import FWISession, TimeModel

    cfg = FWIConfig(nz=32, nx=64, timesteps=32, n_shots=1, sponge_width=4)
    res = Resources(pods=[PodSpec(chips=1, name="cluster")], shares=[1.0])
    rng = np.random.default_rng(0)
    s = FWISession(cfg, res, 0, None, time_model=TimeModel(jitter=0.0),
                   rng=rng, exchange_interval=4, scan_block=8)
    for i in range(5):                      # mid-block: 3 steps pending
        s.run_step(i)
    snap = s.checkpoint(5)
    assert snap["t"] == 8 and snap["pending"] == 3
    s2 = FWISession(cfg, res, 5, snap, time_model=TimeModel(jitter=0.0),
                    rng=rng, exchange_interval=4, scan_block=8)
    for i in range(5, 16):
        s2.run_step(i)
    # 16 logical steps = exactly two blocks of 8 physical timesteps
    assert s2.t == 16


def test_interpret_auto_selects_off_tpu():
    from repro.kernels.stencil.kernel import HALO, default_interpret, pick_bz

    if jax.default_backend() != "tpu":
        assert default_interpret() is True
    assert 600 % pick_bz(600) == 0 and pick_bz(600) % 8 == 0
    assert pick_bz(64) == 64
    # strips shorter than the halo would silently mis-clamp the
    # neighbor-row slices: prime heights fall back to one whole strip
    assert pick_bz(251) == 251
    assert pick_bz(127) >= HALO


def test_step_and_block_share_interpret_default():
    """wave_step and wave_block must agree on backend detection through
    the ONE shared helper — a drifted copy would silently run one
    kernel compiled and the other interpreted."""
    import inspect

    from repro.kernels.stencil import kernel, ops

    assert ops.default_interpret is kernel.default_interpret
    src_step = inspect.getsource(kernel.wave_step_pallas)
    src_blk = inspect.getsource(kernel.wave_block_pallas)
    assert "default_interpret()" in src_step
    assert "default_interpret()" in src_blk


def test_pick_bz_block_and_pick_k():
    from repro.kernels.stencil.kernel import HALO, pick_bz_block, pick_k

    for nz in (32, 64, 128, 251, 600):
        for k in (1, 2, 4, 8):
            bz = pick_bz_block(nz, k)
            assert nz % bz == 0
            # either a real trapezoid fits, or whole-height fallback
            assert bz + 2 * k * HALO <= nz or bz == nz
        kk = pick_k(nz)
        assert 1 <= kk <= 8
    assert pick_k(600) == 8
    assert pick_bz_block(600, 8) == 120


def test_pallas_prime_height_auto_bz():
    """nz with no divisor in [HALO, cap] (prime 251) must still match
    the reference through the auto-picked single-strip path."""
    from repro.kernels.stencil.ops import wave_step

    nz, nx = 251, 128
    ks = jax.random.split(jax.random.key(11), 4)
    p = jax.random.normal(ks[0], (nz, nx), jnp.float32)
    pp = jax.random.normal(ks[1], (nz, nx), jnp.float32)
    v = jax.random.uniform(ks[2], (nz, nx), jnp.float32, 0.05, 0.2)
    sponge = jnp.clip(jax.random.uniform(ks[3], (nz, nx)), 0.9, 1.0)
    a1, a2 = wave_step(p, pp, v, sponge)
    b1, b2 = wave_step(p, pp, v, sponge, use_pallas=True)
    np.testing.assert_allclose(a1, b1, atol=3e-6)
    np.testing.assert_allclose(a2, b2, atol=3e-6)
