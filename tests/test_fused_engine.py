"""Scan-fused, communication-avoiding propagation engine tests.

Equivalence ladder for the fused engine:
  pad-slice laplacian  == roll laplacian          (bitwise)
  scan-runner          == per-step jitted loop    (bitwise, incl. traces)
  k-step temporal block == k sequential ref steps (several k / stripes)
  pallas kernel        == ref across bz choices   (new single-input spec)
plus the communication claims: ppermute count per timestep drops k×,
and the halo-plan bookkeeping matches the lowered HLO.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fwi.domain import (
    effective_block,
    halo_bytes_per_step,
    halo_exchange_plan,
    make_sharded_multistep,
    make_sharded_scan_runner,
    stripe_mesh,
)
from repro.fwi.solver import (
    FWIConfig,
    ShotState,
    make_scan_runner,
    make_step_fn,
    run_forward,
    velocity_model,
)
from repro.kernels.stencil.ref import laplacian, laplacian_roll

CFG = FWIConfig(nz=64, nx=128, timesteps=48, n_shots=2, sponge_width=8)


# ------------------------------------------------------------ solver layer


def test_laplacian_pad_equals_roll_bitwise():
    p = jax.random.normal(jax.random.key(3), (2, 96, 80), jnp.float32)
    a = laplacian_roll(p)
    b = laplacian(p)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scan_runner_traces_equal_per_step_loop():
    """The fused scan must reproduce the per-step dispatch loop exactly,
    receiver traces included."""
    step = make_step_fn(CFG)
    st = ShotState.init(CFG)
    p, pp = st.p, st.p_prev
    traces = []
    for t in range(CFG.timesteps):
        p, pp, tr = step(p, pp, t)
        traces.append(tr)
    loop_tr = jnp.stack(traces, axis=1)
    st_scan, scan_tr = run_forward(CFG)
    np.testing.assert_array_equal(np.asarray(st_scan.p), np.asarray(p))
    np.testing.assert_array_equal(np.asarray(scan_tr), np.asarray(loop_tr))


def test_scan_runner_restart_offset_no_retrace():
    """t0 is traced: restarting mid-run reuses the compiled runner and
    matches the straight-through run bit-for-bit."""
    run = make_scan_runner(CFG, collect_traces=True)
    st = ShotState.init(CFG)
    p_a, pp_a, tr_a = run(st.p, st.p_prev, 0, 48)
    p_b, pp_b, tr1 = run(st.p, st.p_prev, 0, 24)
    p_b, pp_b, tr2 = run(p_b, pp_b, 24, 24)
    np.testing.assert_array_equal(np.asarray(p_a), np.asarray(p_b))
    np.testing.assert_array_equal(
        np.asarray(tr_a), np.asarray(jnp.concatenate([tr1, tr2], axis=1))
    )


def test_model_building_memoized():
    assert velocity_model(CFG) is velocity_model(CFG)
    assert make_scan_runner(CFG) is make_scan_runner(CFG)
    assert make_step_fn(CFG) is make_step_fn(CFG)


# ------------------------------------------------- temporal blocking layer


@pytest.mark.parametrize("k", [2, 4, 8])
def test_temporal_block_equals_sequential_ref(k):
    """One k-step block (single halo exchange) == k sequential reference
    steps, to well under the 1e-4 acceptance tolerance."""
    ref, ref_tr = run_forward(CFG, steps=CFG.timesteps)
    mesh = stripe_mesh(1)
    blk, place = make_sharded_multistep(CFG, mesh, k=k)
    s = ShotState.init(CFG)
    p, pp = place((s.p, s.p_prev))
    trs = []
    for b in range(CFG.timesteps // k):
        p, pp, tr = blk(p, pp, b * k)
        trs.append(tr)
    tr = jnp.concatenate(trs, axis=1)
    np.testing.assert_allclose(np.asarray(p), np.asarray(ref.p), atol=1e-6)
    np.testing.assert_allclose(np.asarray(tr), np.asarray(ref_tr),
                               atol=1e-6)


def test_sharded_scan_runner_equals_reference():
    ref, ref_tr = run_forward(CFG, steps=CFG.timesteps)
    run, place, k = make_sharded_scan_runner(CFG, stripe_mesh(1), k=4)
    s = ShotState.init(CFG)
    p, pp = place((s.p, s.p_prev))
    p, pp, tr = run(p, pp, 0, CFG.timesteps // k)
    np.testing.assert_allclose(np.asarray(p), np.asarray(ref.p), atol=1e-6)
    np.testing.assert_allclose(np.asarray(tr), np.asarray(ref_tr),
                               atol=1e-6)


_MULTI_STRIPE_BLOCKED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro.fwi.solver import FWIConfig, ShotState, run_forward
from repro.fwi.domain import stripe_mesh, make_sharded_multistep

cfg = FWIConfig(nz=64, nx=128, timesteps=40, n_shots=2, sponge_width=8)
ref, ref_tr = run_forward(cfg, steps=40)
for k in (2, 4):
    for n in (2, 4):
        mesh = stripe_mesh(n)
        blk, place = make_sharded_multistep(cfg, mesh, k=k)
        s = ShotState.init(cfg)
        p, pp = place((s.p, s.p_prev))
        trs = []
        for b in range(40 // k):
            p, pp, tr = blk(p, pp, b * k)
            trs.append(tr)
        tr = jnp.concatenate(trs, axis=1)
        err = float(jnp.max(jnp.abs(np.asarray(p) - np.asarray(ref.p))))
        terr = float(jnp.max(jnp.abs(np.asarray(tr) - np.asarray(ref_tr))))
        assert err < 1e-4 and terr < 1e-4, (k, n, err, terr)
print("BLOCKED_MULTI_STRIPE_OK")
"""


def test_temporal_block_multi_stripe_subprocess():
    """Temporal blocking across REAL stripe boundaries (4 host devices):
    k-step blocks with one packed exchange match the reference for
    several (k, stripe-count) combinations."""
    src = str(Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _MULTI_STRIPE_BLOCKED, src],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "BLOCKED_MULTI_STRIPE_OK" in out.stdout


def test_ppermute_count_drops_k_fold():
    """k=4 temporal blocking must emit the SAME 2 collective-permutes
    per block as k=1 — i.e. 4× fewer per timestep."""
    mesh = stripe_mesh(1)
    s = ShotState.init(CFG)
    counts = {}
    for k in (1, 4):
        blk, place = make_sharded_multistep(CFG, mesh, k=k)
        p, pp = place((s.p, s.p_prev))
        txt = jax.jit(blk).lower(p, pp, 0).as_text()
        counts[k] = txt.count("collective_permute") \
            + txt.count("collective-permute")
    assert counts[1] == 2, counts
    assert counts[4] == 2, counts          # per-timestep: 2 vs 0.5 = 4x


def test_halo_exchange_plan_bookkeeping():
    # seed formula preserved at k=1
    assert halo_bytes_per_step(CFG, 4) == 2 * 2 * CFG.nz * CFG.n_shots * 4
    plan1 = halo_exchange_plan(CFG, 4, k=1)
    plan4 = halo_exchange_plan(CFG, 4, k=4)
    assert plan1["ppermutes_per_step"] == 2.0
    assert plan4["ppermutes_per_step"] == 0.5
    assert plan4["steps_per_exchange"] == 4
    # packed p+p_prev edges: amortized bytes exactly 2x the k=1 stream
    assert plan4["bytes_per_step"] == 2 * plan1["bytes_per_step"]
    # k clamps so the overlap fits in a stripe, and the clamped value is
    # exposed on the block step so callers advance t0 correctly
    assert effective_block(CFG, CFG.nx // 2, 64) == 1
    blk, _ = make_sharded_multistep(CFG, stripe_mesh(1), k=4)
    assert blk.k == 4


# --------------------------------------------------------- kernel layer


@pytest.mark.parametrize("bz", [8, 16, 32, 64, None])
def test_pallas_bz_sweep_matches_ref(bz):
    """Single-input BlockSpec kernel vs ref across strip heights,
    including the auto-picked one (bz=None)."""
    from repro.kernels.stencil.ops import wave_step

    nz, nx = 64, 256
    ks = jax.random.split(jax.random.key(7), 4)
    p = jax.random.normal(ks[0], (nz, nx), jnp.float32)
    pp = jax.random.normal(ks[1], (nz, nx), jnp.float32)
    v = jax.random.uniform(ks[2], (nz, nx), jnp.float32, 0.05, 0.2)
    sponge = jnp.clip(jax.random.uniform(ks[3], (nz, nx)), 0.9, 1.0)
    a1, a2 = wave_step(p, pp, v, sponge)
    b1, b2 = wave_step(p, pp, v, sponge, use_pallas=True, bz=bz)
    np.testing.assert_allclose(a1, b1, atol=3e-6)
    np.testing.assert_allclose(a2, b2, atol=3e-6)


def test_sharded_pallas_path_equals_reference():
    """use_pallas wired through the sharded local step: the fused kernel
    runs inside the shard_map region and matches the reference."""
    cfg = FWIConfig(nz=32, nx=64, timesteps=8, n_shots=1, sponge_width=4)
    ref, ref_tr = run_forward(cfg, steps=8)
    blk, place = make_sharded_multistep(
        cfg, stripe_mesh(1), k=4, use_pallas=True
    )
    s = ShotState.init(cfg)
    p, pp = place((s.p, s.p_prev))
    trs = []
    for b in range(2):
        p, pp, tr = blk(p, pp, b * 4)
        trs.append(tr)
    tr = jnp.concatenate(trs, axis=1)
    np.testing.assert_allclose(np.asarray(p), np.asarray(ref.p), atol=1e-5)
    np.testing.assert_allclose(np.asarray(tr), np.asarray(ref_tr),
                               atol=1e-5)


def test_driver_checkpoint_carries_block_progress():
    """A mid-block checkpoint/restore must not re-dispatch the pending
    steps: physical timesteps stay in lockstep with logical steps."""
    from repro.core.orchestrator import PodSpec, Resources
    from repro.fwi.driver import FWISession, TimeModel

    cfg = FWIConfig(nz=32, nx=64, timesteps=32, n_shots=1, sponge_width=4)
    res = Resources(pods=[PodSpec(chips=1, name="cluster")], shares=[1.0])
    rng = np.random.default_rng(0)
    s = FWISession(cfg, res, 0, None, time_model=TimeModel(jitter=0.0),
                   rng=rng, exchange_interval=4, scan_block=8)
    for i in range(5):                      # mid-block: 3 steps pending
        s.run_step(i)
    snap = s.checkpoint(5)
    assert snap["t"] == 8 and snap["pending"] == 3
    s2 = FWISession(cfg, res, 5, snap, time_model=TimeModel(jitter=0.0),
                    rng=rng, exchange_interval=4, scan_block=8)
    for i in range(5, 16):
        s2.run_step(i)
    # 16 logical steps = exactly two blocks of 8 physical timesteps
    assert s2.t == 16


def test_interpret_auto_selects_off_tpu():
    from repro.kernels.stencil.kernel import HALO, default_interpret, pick_bz

    if jax.default_backend() != "tpu":
        assert default_interpret() is True
    assert 600 % pick_bz(600) == 0 and pick_bz(600) % 8 == 0
    assert pick_bz(64) == 64
    # strips shorter than the halo would silently mis-clamp the
    # neighbor-row slices: prime heights fall back to one whole strip
    assert pick_bz(251) == 251
    assert pick_bz(127) >= HALO


def test_pallas_prime_height_auto_bz():
    """nz with no divisor in [HALO, cap] (prime 251) must still match
    the reference through the auto-picked single-strip path."""
    from repro.kernels.stencil.ops import wave_step

    nz, nx = 251, 128
    ks = jax.random.split(jax.random.key(11), 4)
    p = jax.random.normal(ks[0], (nz, nx), jnp.float32)
    pp = jax.random.normal(ks[1], (nz, nx), jnp.float32)
    v = jax.random.uniform(ks[2], (nz, nx), jnp.float32, 0.05, 0.2)
    sponge = jnp.clip(jax.random.uniform(ks[3], (nz, nx)), 0.9, 1.0)
    a1, a2 = wave_step(p, pp, v, sponge)
    b1, b2 = wave_step(p, pp, v, sponge, use_pallas=True)
    np.testing.assert_allclose(a1, b1, atol=3e-6)
    np.testing.assert_allclose(a2, b2, atol=3e-6)
