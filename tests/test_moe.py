"""MoE routing invariants + dispatch-path equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod
from repro.sharding.rules import init_params


def _cfg(dispatch="einsum", cf=4.0, E=8, k=2):
    base = smoke_config(get_config("deepseek-v2-236b"))
    return dataclasses.replace(
        base,
        moe=MoEConfig(
            num_experts=E, num_shared_experts=1, top_k=k, d_ff=64,
            capacity_factor=cf, group_size=16, dispatch=dispatch,
        ),
    )


@pytest.fixture(scope="module")
def moe_params():
    cfg = _cfg()
    return cfg, init_params(moe_mod.moe_schema(cfg), jax.random.key(0))


def test_routing_invariants(moe_params):
    cfg, params = moe_params
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
    gate, idx, mask, lb, z = moe_mod.route(
        cfg, params, x.reshape(-1, cfg.d_model).astype(jnp.float32)
    )
    # normalized gates
    np.testing.assert_allclose(np.asarray(gate.sum(-1)), 1.0, atol=1e-5)
    # distinct experts per token (top-k without replacement)
    idx_np = np.asarray(idx)
    for row in idx_np:
        assert len(set(row.tolist())) == len(row)
    # aux losses sane: balanced lb ≈ 1 for uniform router
    assert 0.5 < float(lb) < float(cfg.moe.num_experts)
    assert float(z) >= 0


def test_capacity_never_exceeded(moe_params):
    cfg, params = moe_params
    T, C = 64, moe_mod.expert_capacity(64, cfg)
    x = jax.random.normal(jax.random.key(2), (1, T, cfg.d_model))
    gate, idx, mask, *_ = moe_mod.route(
        cfg, params, x.reshape(1, T, cfg.d_model).astype(jnp.float32)
    )
    pos = moe_mod._positions_in_expert(mask)
    kept = np.asarray(pos < C)
    idx_np, pos_np = np.asarray(idx), np.asarray(pos)
    counts = np.zeros(cfg.moe.num_experts, np.int64)
    for t in range(T):
        for j in range(cfg.moe.top_k):
            if kept[0, t, j]:
                counts[idx_np[0, t, j]] += 1
                assert pos_np[0, t, j] < C
    assert (counts <= C).all()


def test_einsum_vs_scatter_dispatch_equivalent(moe_params):
    """The two dispatch implementations are numerically interchangeable
    (drop-free config so routing is group-invariant)."""
    cfg_e = _cfg("einsum")
    cfg_s = _cfg("scatter")
    params = init_params(moe_mod.moe_schema(cfg_e), jax.random.key(0))
    x = jax.random.normal(jax.random.key(3), (2, 32, cfg_e.d_model),
                          jnp.float32)
    y_e, aux_e = moe_mod.apply_moe(cfg_e, params, x)
    y_s, aux_s = moe_mod.apply_moe(cfg_s, params, x)
    np.testing.assert_allclose(
        np.asarray(y_e), np.asarray(y_s), atol=2e-5
    )
    assert abs(float(aux_e["lb_loss"]) - float(aux_s["lb_loss"])) < 1e-6


def test_dropping_under_tight_capacity():
    """cf < 1 must drop tokens (outputs differ from drop-free) without
    producing NaNs — dropped tokens pass through the residual."""
    cfg_tight = _cfg(cf=0.5)
    cfg_loose = _cfg(cf=4.0)
    params = init_params(moe_mod.moe_schema(cfg_tight), jax.random.key(0))
    x = jax.random.normal(jax.random.key(4), (2, 32, cfg_tight.d_model),
                          jnp.float32)
    y_t, _ = moe_mod.apply_moe(cfg_tight, params, x)
    y_l, _ = moe_mod.apply_moe(cfg_loose, params, x)
    assert bool(jnp.all(jnp.isfinite(y_t)))
    assert float(jnp.max(jnp.abs(y_t - y_l))) > 1e-6


def test_moe_grads_flow_to_all_parts(moe_params):
    cfg, params = moe_params

    def loss(p, x):
        y, aux = moe_mod.apply_moe(cfg, p, x)
        return jnp.sum(y ** 2) + aux["lb_loss"] + aux["z_loss"]

    x = jax.random.normal(jax.random.key(5), (2, 32, cfg.d_model),
                          jnp.float32)
    g = jax.grad(loss)(params, x)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0, name
