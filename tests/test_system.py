"""End-to-end behaviour tests for the paper's system: the self-adaptive
burst meets a deadline a static allocation would miss; failures recover
from checkpoints; the FWI application adapts on the real solver."""
import numpy as np
import pytest

from repro.core import (
    BurstPlanner,
    DeadlinePredictor,
    ElasticOrchestrator,
    LogCapacityModel,
    OverheadModel,
    PodSpec,
    Resources,
)
from repro.core.events import SlowdownWindow
from repro.core.sim_session import SimWorkload, sim_session_factory

WORK = 2000.0  # chip-seconds per step
CHIPS = [16, 32, 64, 128, 256]


def _models(cloud_slowdown=1.4):
    cluster = LogCapacityModel.fit(CHIPS, [WORK / c for c in CHIPS])
    cloud = LogCapacityModel.fit(
        CHIPS, [cloud_slowdown * WORK / c for c in CHIPS]
    )
    return cluster, cloud


def _planner(max_burst=256, **kw):
    cluster, cloud = _models()
    return BurstPlanner(
        cluster_model=cluster, cloud_model=cloud, chips_cluster=256,
        legal_slices=[16, 32, 64, 128, 256],
        overheads=OverheadModel(ckpt_s=5, provision_s=60, restart_s=20),
        max_burst_chips=max_burst, **kw,
    )


def _run(planner, deadline, windows=None, failures=None, steps=300, seed=0):
    orch = ElasticOrchestrator(
        planner=planner, predictor=DeadlinePredictor(deadline),
        check_every=8, ckpt_every=25,
    )
    factory = sim_session_factory(
        SimWorkload(WORK, jitter=0.01), rng=np.random.default_rng(seed),
        windows=windows, failures=failures, sync_overhead_s=0.05,
    )
    return orch.run(
        session_factory=factory,
        initial=Resources(pods=[PodSpec(chips=256, name="cluster")],
                          shares=[1.0]),
        steps_total=steps,
    )


CONGESTION = {0: [SlowdownWindow(40, 10 ** 9, 2.2)]}


def test_burst_meets_deadline_where_static_misses():
    """The paper's core claim (its §3.3 / conclusion)."""
    deadline = 3000.0
    rec_static = _run(_planner(max_burst=0), deadline, windows=CONGESTION)
    rec_adapt = _run(_planner(), deadline, windows=CONGESTION)
    assert not rec_static.met_deadline
    assert rec_adapt.met_deadline
    bursts = [e for e in rec_adapt.events if e.kind == "burst"]
    assert bursts, "must actually burst"
    assert rec_adapt.elapsed_s < rec_static.elapsed_s


def test_no_burst_when_deadline_safe():
    rec = _run(_planner(), deadline=10_000.0, windows=None)
    assert rec.met_deadline
    assert not [e for e in rec.events if e.kind == "burst"]


def test_burst_declined_when_overhead_dominates():
    """Near-infeasible overheads: the planner must decline (beyond-paper
    overhead accounting, its §3.3 future work)."""
    cluster, cloud = _models()
    planner = BurstPlanner(
        cluster_model=cluster, cloud_model=cloud, chips_cluster=256,
        legal_slices=[256],
        overheads=OverheadModel(ckpt_s=500, provision_s=5000,
                                restart_s=500),
    )
    rec = _run(planner, deadline=2400.0, windows=CONGESTION)
    assert not [e for e in rec.events if e.kind == "burst"]


def test_failure_recovers_from_checkpoint():
    rec = _run(_planner(), deadline=10_000.0, failures={100: 0},
               steps=150)
    fails = [e for e in rec.events if e.kind == "failure"]
    assert len(fails) == 1
    assert rec.completed and rec.steps == 150


def test_dynamic_deadline_change_triggers_burst():
    """Paper §2: the deadline itself may change at runtime."""
    orch = ElasticOrchestrator(
        planner=_planner(), predictor=DeadlinePredictor(10_000.0),
        check_every=8,
    )
    factory = sim_session_factory(
        SimWorkload(WORK, jitter=0.01), rng=np.random.default_rng(1),
    )

    class TighteningSession:
        def __init__(self, inner):
            self.inner = inner

        def run_step(self, step):
            if step == 60:
                orch.predictor.set_deadline(1800.0)  # tightened mid-run
            return self.inner.run_step(step)

        def checkpoint(self, step):
            return self.inner.checkpoint(step)

    def wrapped_factory(res, start, restored):
        return TighteningSession(factory(res, start, restored))

    rec = orch.run(
        session_factory=wrapped_factory,
        initial=Resources(pods=[PodSpec(chips=256)], shares=[1.0]),
        steps_total=300,
    )
    assert [e for e in rec.events if e.kind == "burst"]


def test_fwi_adaptive_on_real_solver():
    from repro.fwi.calibrate import fit_capacity_models
    from repro.fwi.driver import TimeModel, fwi_session_factory
    from repro.fwi.solver import FWIConfig

    cfg = FWIConfig(nz=64, nx=128, timesteps=120, n_shots=1,
                    sponge_width=8)
    cluster, cloud, samples = fit_capacity_models(
        cfg, cloud_slowdown=1.4, chip_counts=(8, 16, 32, 64, 128),
    )
    assert cluster.r2(samples["chips"], samples["t_cluster"]) > 0.99
    work = samples["t1_measured"]
    tm = TimeModel(chip_seconds_per_step=work, congestion_from=30,
                   congestion_factor=2.0, jitter=0.01)
    deadline = work / 64 * 120 * 1.35
    planner = BurstPlanner(
        cluster_model=cluster, cloud_model=cloud, chips_cluster=64,
        legal_slices=[8, 16, 32, 64, 128],
        overheads=OverheadModel(ckpt_s=work / 64 * 2,
                                provision_s=work / 64 * 6,
                                restart_s=work / 64 * 2),
    )
    orch = ElasticOrchestrator(
        planner=planner, predictor=DeadlinePredictor(deadline),
        check_every=6, ckpt_every=40,
    )
    rec = orch.run(
        session_factory=fwi_session_factory(cfg, tm),
        initial=Resources(pods=[PodSpec(chips=64, name="cluster")],
                          shares=[1.0]),
        steps_total=120,
    )
    assert rec.met_deadline
    assert [e for e in rec.events if e.kind == "burst"]
