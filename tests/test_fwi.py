"""FWI solver: physics sanity, path equivalence, multi-stripe halo
exchange (subprocess with 4 host devices), checkpoint/re-stripe."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fwi.domain import halo_bytes_per_step, make_sharded_step, stripe_mesh
from repro.fwi.solver import (
    FWIConfig,
    ShotState,
    make_step_fn,
    run_forward,
    sponge_taper,
    velocity_model,
)

CFG = FWIConfig(nz=128, nx=128, timesteps=60, n_shots=2, sponge_width=16)


def test_wavefield_nontrivial_and_finite():
    st, traces = run_forward(CFG)
    assert bool(jnp.all(jnp.isfinite(st.p)))
    assert float(jnp.max(jnp.abs(st.p))) > 0
    assert float(jnp.sum(traces ** 2)) > 0


def test_sponge_absorbs_energy():
    """With the source off after t0, total field energy must decay under
    the sponge (no reflecting boundary blowup)."""
    cfg = FWIConfig(nz=96, nx=96, timesteps=300, n_shots=1,
                    sponge_width=24, sponge_strength=0.02)
    st_mid, _ = run_forward(cfg, steps=150)
    e_mid = float(jnp.sum(st_mid.p ** 2))
    st_end, _ = run_forward(cfg, state=st_mid, steps=150)
    e_end = float(jnp.sum(st_end.p ** 2))
    assert e_end < e_mid


def test_velocity_model_has_salt_dome():
    v = np.asarray(velocity_model(CFG))
    assert v.min() >= 1500.0 and v.max() == 4500.0
    assert (v == 4500.0).sum() > 100  # dome exists


def test_cfl_stability():
    """(v·dt/dx) must satisfy the 4th-order 2-D CFL bound."""
    v = float(np.max(np.asarray(velocity_model(CFG))))
    courant = v * CFG.dt / CFG.dx
    assert courant < 0.606, f"CFL violated: {courant}"


def test_sharded_single_stripe_equals_reference():
    st_ref, _ = run_forward(CFG, steps=40)
    mesh = stripe_mesh(1)
    step, place = make_sharded_step(CFG, mesh)
    s = ShotState.init(CFG)
    p, pp = place((s.p, s.p_prev))
    for t in range(40):
        p, pp, _ = step(p, pp, t)
    np.testing.assert_allclose(np.asarray(p), np.asarray(st_ref.p),
                               atol=1e-10)


def test_pallas_path_equals_reference():
    st_ref, _ = run_forward(CFG, steps=40)
    st_pal, _ = run_forward(CFG, use_pallas=True, steps=40)
    np.testing.assert_allclose(np.asarray(st_pal.p), np.asarray(st_ref.p),
                               atol=1e-9)


def test_checkpoint_restart_mid_run():
    """Fig.1 steps 2+7: stop, snapshot, restart — bit-identical result."""
    st_full, _ = run_forward(CFG, steps=50)
    st_a, _ = run_forward(CFG, steps=25)
    snap = {"p": np.asarray(st_a.p), "p_prev": np.asarray(st_a.p_prev),
            "t": st_a.t}
    st_b = ShotState(p=jnp.asarray(snap["p"]),
                     p_prev=jnp.asarray(snap["p_prev"]), t=snap["t"])
    st_b, _ = run_forward(CFG, state=st_b, steps=25)
    np.testing.assert_array_equal(np.asarray(st_full.p), np.asarray(st_b.p))


def test_halo_bytes_small():
    """Paper §3.3: striped partitioning keeps messages tiny (21 KB there;
    here 2 cols × NZ × shots × 4 B per seam per step)."""
    b = halo_bytes_per_step(CFG, 4)
    assert b == 2 * 2 * CFG.nz * CFG.n_shots * 4
    assert b < 64 * 1024


_MULTI_STRIPE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro.fwi.solver import FWIConfig, ShotState, run_forward
from repro.fwi.domain import stripe_mesh, make_sharded_step

cfg = FWIConfig(nz=64, nx=128, timesteps=40, n_shots=2, sponge_width=8)
ref, _ = run_forward(cfg, steps=40)
mesh = stripe_mesh(4)
step, place = make_sharded_step(cfg, mesh)
s = ShotState.init(cfg)
p, pp = place((s.p, s.p_prev))
for t in range(40):
    p, pp, _ = step(p, pp, t)
err = float(jnp.max(jnp.abs(np.asarray(p) - np.asarray(ref.p))))
assert err < 1e-10, f"halo exchange mismatch: {err}"
print("MULTI_STRIPE_OK", err)
"""


def test_multi_stripe_halo_exchange_subprocess():
    """4-way striped decomposition with ppermute halo exchange matches
    the single-device solver exactly (run with 4 host devices)."""
    src = str(Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _MULTI_STRIPE_SCRIPT, src],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTI_STRIPE_OK" in out.stdout
