"""Checkpoint manager: roundtrip, atomicity, GC, resume, reshard hook."""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.manager import CheckpointManager


def _abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def test_roundtrip_and_extra(tmp_path):
    state = {
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "opt": {"m": jnp.zeros((3, 4)), "count": jnp.asarray(3)},
    }
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(5, state, extra={"data_step": 5})
    restored, extra = m.restore(_abstract(state))
    assert extra == {"data_step": 5}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


@given(
    shapes=st.lists(
        st.tuples(st.integers(1, 5), st.integers(1, 5)),
        min_size=1, max_size=4,
    ),
    step=st.integers(0, 10 ** 6),
)
@settings(max_examples=15, deadline=None)
def test_roundtrip_property(tmp_path_factory, shapes, step):
    tmp = tmp_path_factory.mktemp("ckpt")
    rng = np.random.default_rng(0)
    state = {
        f"t{i}": jnp.asarray(rng.standard_normal(s).astype(np.float32))
        for i, s in enumerate(shapes)
    }
    m = CheckpointManager(tmp, async_save=False)
    m.save(step, state)
    restored, _ = m.restore(_abstract(state), step=step)
    for k in state:
        np.testing.assert_array_equal(state[k], restored[k])


def test_async_save_and_gc(tmp_path):
    m = CheckpointManager(tmp_path, async_save=True, keep=2)
    state = {"x": jnp.ones((8, 8))}
    for s in (10, 20, 30, 40):
        m.save(s, state)
    m.wait()
    assert m.all_steps() == [30, 40]  # keep=2


def test_atomicity_no_partial_dirs(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(1, {"x": jnp.ones((512, 512))})
    for p in tmp_path.glob("*.tmp"):
        pytest.fail(f"left-over tmp dir {p}")
    manifest = json.loads(
        (tmp_path / "step_00000001" / "manifest.json").read_text()
    )
    assert manifest["step"] == 1 and "x" in manifest["leaves"]


def test_restore_latest_and_missing_leaf_error(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(7, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        m.restore(_abstract({"a": jnp.zeros((2,)),
                             "missing": jnp.zeros((3,))}))


def test_restore_with_shardings_places_on_device(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P())
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(1, state)
    restored, _ = m.restore(_abstract(state), shardings={"w": sh})
    assert restored["w"].sharding == sh


def test_train_resume_bit_exact(tmp_path):
    """Training N steps straight == training k, checkpoint, resume N-k."""
    from repro.configs import RunConfig, get_config, smoke_config
    from repro.data.pipeline import SyntheticLMPipeline
    from repro.configs.shapes import ShapeConfig
    from repro.optim import constant, make_optimizer
    from repro.runtime.train_step import build_train_step, state_schema
    from repro.sharding.rules import abstract_params, init_params

    cfg = smoke_config(get_config("yi-6b"))
    run = RunConfig(loss_chunk=32)
    shape = ShapeConfig("t", "train", 32, 2)
    opt = make_optimizer("adamw", constant(1e-3))
    sch = state_schema(cfg, run, opt)
    step_fn = jax.jit(build_train_step(cfg, run, opt))

    def fresh():
        params = init_params(sch["params"], jax.random.key(0))
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    pipe = SyntheticLMPipeline(cfg, shape)
    s_full = fresh()
    for i in range(6):
        s_full, _ = step_fn(s_full, pipe.batch_at(i))

    m = CheckpointManager(tmp_path, async_save=False)
    s_part = fresh()
    for i in range(3):
        s_part, _ = step_fn(s_part, pipe.batch_at(i))
    m.save(3, s_part, extra={"data_step": 3})
    restored, extra = m.restore(abstract_params(sch))
    for i in range(int(extra["data_step"]), 6):
        restored, _ = step_fn(restored, pipe.batch_at(i))

    for a, b in zip(jax.tree.leaves(s_full), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )
