"""Streamed VMEM-tiled stencil pipeline tests (DESIGN.md §15).

Capacity ladder for production-scale grids:

  strips-ref (XLA)      == wave_block_ref            (BITWISE — strip
                           tiling is a pure re-slicing of the same ops)
  streamed Pallas       == wave_block_ref            (allclose ≤ 1e-5,
                           same contract as the resident Pallas kernel)
  streamed Pallas       == resident Pallas           (BITWISE — both run
                           the one _trapezoid_k_steps body)
  pipeline schedule     == overlap == fused          (BITWISE across
                           REAL stripe seams, 2 and 4 stripes)

plus the capacity bookkeeping (should_stream / stream_vmem_bytes /
pick_bz_stream refuses the whole-height fallback), the tall-grid
StripFallbackWarning on the resident pickers, and the planner's seam
provenance: sim scenarios consume the measured-probe overlapped seam,
not the dispatch-latency floor.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.stencil.kernel import (
    DEFAULT_VMEM_BUDGET,
    HALO,
    StripFallbackWarning,
    pick_bz,
    pick_bz_block,
    pick_bz_stream,
    resident_vmem_bytes,
    should_stream,
    stream_vmem_bytes,
    wave_block_pallas,
    wave_block_stream_pallas,
)
from repro.kernels.stencil.ops import wave_block
from repro.kernels.stencil.ref import wave_block_ref, wave_block_strips_ref

SMALL_BUDGET = 4 * 1024 * 1024          # forces multi-strip streaming


def _fields(nz, nx, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    p = jax.random.normal(ks[0], (nz, nx), jnp.float32)
    pp = jax.random.normal(ks[1], (nz, nx), jnp.float32)
    v = jax.random.uniform(ks[2], (nz, nx), jnp.float32, 0.05, 0.2)
    s = jnp.clip(jax.random.uniform(ks[3], (nz, nx)), 0.9, 1.0)
    return p, pp, v, s


# ------------------------------------------------- production-scale grid


@pytest.mark.parametrize("k", [1, 4, 8])
def test_streamed_2048_grid_matches_ref(k):
    """2048×2048 under a forced 4 MiB budget (a grid the whole-array
    resident design cannot hold): the XLA strips mirror is BITWISE equal
    to ``wave_block_ref`` and the streamed Pallas kernel (interpret mode
    off-TPU — real BlockSpec/DMA semantics) matches to the documented
    1e-5, with NO whole-height fallback (win < nz)."""
    nz = nx = 2048
    assert should_stream(nz, nx, k, vmem_budget=SMALL_BUDGET)
    bz = pick_bz_stream(nz, nx, k, vmem_budget=SMALL_BUDGET)
    assert bz + 2 * k * HALO < nz          # genuinely multi-strip
    p, pp, v, s = _fields(nz, nx, seed=k)
    srcv = jnp.linspace(0.5, 1.0, k)
    zi, xi = nz // 3, nx // 2
    ref = wave_block_ref(p, pp, v, s, srcv, zi, xi, receiver_row=7)

    strips = wave_block_strips_ref(p, pp, v, s, srcv, zi, xi,
                                   receiver_row=7, bz=bz)
    for a, b in zip(ref, strips):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    streamed = wave_block_stream_pallas(
        p, pp, v, s, srcv, zi, xi, receiver_row=7, bz=bz,
        vmem_budget=SMALL_BUDGET,
    )
    for a, b in zip(ref, streamed):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5)


@pytest.mark.parametrize("k", [1, 4])
def test_streamed_pallas_bitwise_vs_resident_pallas(k):
    """Streamed and resident Pallas kernels share one trapezoid body
    (_trapezoid_k_steps): identical strip geometry must produce BITWISE
    identical fields and traces — the DMA pipeline is pure data
    movement."""
    nz, nx = 128, 160
    bz = 16
    p, pp, v, s = _fields(nz, nx, seed=20 + k)
    srcv = jnp.linspace(0.5, 1.0, k)
    a = wave_block_pallas(p, pp, v, s, srcv, 40, 80, receiver_row=3,
                          bz=bz)
    b = wave_block_stream_pallas(p, pp, v, s, srcv, 40, 80,
                                 receiver_row=3, bz=bz)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("bz", [25, 50])
def test_strips_ref_unaligned_and_degenerate_windows(bz):
    """Non-8-aligned strips and the win==nz degenerate case stay
    bitwise (the strips mirror must cover every geometry
    ``pick_bz_stream``'s unaligned fallback can emit)."""
    nz, nx = 250, 96
    k = 4
    p, pp, v, s = _fields(nz, nx, seed=5)
    srcv = jnp.linspace(0.2, 0.9, k)
    ref = wave_block_ref(p, pp, v, s, srcv, 100, 30, receiver_row=2)
    out = wave_block_strips_ref(p, pp, v, s, srcv, 100, 30,
                                receiver_row=2, bz=bz)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- auto-dispatch


def test_wave_block_auto_streams_over_budget():
    """ops.wave_block with stream=None must auto-select the streamed
    tiling when the resident footprint exceeds the budget — and stay
    BITWISE on the XLA path while doing so."""
    nz, nx, k = 512, 512, 4
    budget = 1 * 1024 * 1024
    assert should_stream(nz, nx, k, vmem_budget=budget)
    assert not should_stream(nz, nx, k)    # default budget holds 512²
    p, pp, v, s = _fields(nz, nx, seed=9)
    srcv = jnp.linspace(0.5, 1.0, k)
    ref = wave_block_ref(p, pp, v, s, srcv, 17, 400, receiver_row=1)
    out = wave_block(p, pp, v, s, srcv, 17, 400, receiver_row=1,
                     vmem_budget=budget)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_autotune_dispatches_to_stream_space():
    """autotune_bz_k(stream=True) searches the streamed (strip, depth)
    space: the winner must satisfy the divisor + trapezoid + budget
    constraints (and never the whole-height fallback)."""
    from repro.kernels.stencil.kernel import autotune_bz_k

    nz, nx = 128, 128
    budget = 512 * 1024
    bz, k = autotune_bz_k(nz, nx, bz_candidates=(8, 16, 32),
                          k_candidates=(1, 2), repeats=1, stream=True,
                          vmem_budget=budget)
    assert nz % bz == 0 and k in (1, 2)
    assert bz + 2 * k * HALO <= nz
    assert stream_vmem_bytes(nz, nx, bz, k) <= budget


# ------------------------------------------------- capacity bookkeeping


def test_vmem_accounting_motivates_streaming():
    """The numbers behind DESIGN.md §15's capacity table: 4096² cannot
    be VMEM-resident (256 MB ≫ 16 MB) but streams in O(bz·nx); the
    streamed footprint is NZ-independent."""
    nz = nx = 4096
    k = 4
    assert resident_vmem_bytes(nz, nx, k) > 16 * DEFAULT_VMEM_BUDGET
    assert should_stream(nz, nx, k)
    assert not should_stream(600, 600, k)  # paper grid stays resident
    bz = pick_bz_stream(nz, nx, k)
    assert stream_vmem_bytes(nz, nx, bz, k) <= DEFAULT_VMEM_BUDGET
    # streamed footprint depends on the strip, not the field height
    assert stream_vmem_bytes(nz, nx, 32, k) == \
        stream_vmem_bytes(8 * nz, nx, 32, k)


def test_pick_bz_stream_refuses_whole_height():
    """No silent whole-field fallback on the streamed path: geometries
    that cannot stream under the budget raise instead of quietly going
    resident (the exact footgun the resident pickers only warn about)."""
    with pytest.raises(ValueError):
        pick_bz_stream(251, 128, 4)              # prime nz: no divisor
    with pytest.raises(ValueError):
        pick_bz_stream(2048, 2048, 4, vmem_budget=64 * 1024)
    with pytest.raises(ValueError):
        pick_bz_stream(16, 128, 8)               # too short for k=8


def test_resident_pickers_warn_on_whole_height_fallback():
    """Tall grids with no usable strip divisor fall back to ONE
    whole-height resident strip — now loudly."""
    with pytest.warns(StripFallbackWarning):
        assert pick_bz(251) == 251
    with pytest.warns(StripFallbackWarning):
        assert pick_bz_block(1009, 4) == 1009
    # small / composite grids take the normal branch silently
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error", StripFallbackWarning)
        assert pick_bz(600) == 120
        assert pick_bz(64) == 64
        assert pick_bz(37) == 37               # short prime: under cap
        assert pick_bz_block(600, 4) == 120


# -------------------------------------------- sharded pipeline schedule


_PIPELINE_INVARIANCE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp, numpy as np
from repro.fwi.solver import FWIConfig, ShotState, run_forward
from repro.fwi.domain import stripe_mesh, make_sharded_scan_runner

cfg = FWIConfig(nz=64, nx=128, timesteps=40, n_shots=2, sponge_width=8)
ref, ref_tr = run_forward(cfg, steps=40)
for n in (2, 4):
    outs = {}
    for sched in ("fused", "overlap", "pipeline"):
        run, place, keff = make_sharded_scan_runner(
            cfg, stripe_mesh(n), k=4, overlap=sched
        )
        s = ShotState.init(cfg)
        p, pp = place((s.p, s.p_prev))
        p, pp, tr = run(p, pp, 0, 40 // keff)
        outs[sched] = (np.asarray(p), np.asarray(pp), np.asarray(tr))
    # the double-buffered pipeline must be BITWISE identical to the
    # eager-exchange schedule (same per-block op graph, reordered);
    # vs the comm-avoiding fused window the op sequence is identical
    # but fusion shapes may flush denormal wavefront tails differently
    # (same contract as test_fused_engine) — equal up to sub-normal
    # noise (< FLT_MIN = 1.2e-38)
    for a, b in zip(outs["pipeline"], outs["overlap"]):
        assert np.array_equal(a, b), n
    for a, b in zip(outs["pipeline"], outs["fused"]):
        err = np.max(np.abs(a - b))
        assert err < 1.2e-38, (n, err)
    assert np.max(np.abs(outs["pipeline"][0] - np.asarray(ref.p))) < 1e-6, n
    assert np.max(np.abs(outs["pipeline"][2] - np.asarray(ref_tr))) < 1e-6, n
print("PIPELINE_INVARIANCE_OK")
"""


def test_pipeline_schedule_invariance_subprocess():
    """Double-buffered halo pipeline vs eager exchange vs fused window
    across 2- and 4-stripe REAL seams (4 host devices): bitwise
    invariant, and allclose to the seed reference."""
    src = str(Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _PIPELINE_INVARIANCE, src],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "PIPELINE_INVARIANCE_OK" in out.stdout


def test_pick_schedule_and_normalization():
    from repro.fwi.domain import _as_schedule, pick_schedule

    assert pick_schedule("tpu") == "pipeline"
    assert pick_schedule("cpu") == "fused"
    assert _as_schedule(None) == pick_schedule()
    assert _as_schedule(True) == "overlap"     # legacy bool knob
    assert _as_schedule(False) == "fused"
    assert _as_schedule("pipeline") == "pipeline"
    with pytest.raises(ValueError):
        _as_schedule("bogus")


# ------------------------------------------------- seam provenance


def test_planner_consumes_probe_fed_overlapped_seam():
    """The fleet scenarios' OverheadModel must be built from the
    MEASURED seam probe through ``with_overlapped_seam`` — charging only
    the un-hidden residue — and not the ``with_measured_seam`` dispatch
    floor (which ignores the pipeline's overlap entirely)."""
    from repro.core import OverheadModel
    from repro.sim.scenarios import (
        OVERHEADS,
        SEAM_PROBE,
        overheads_from_probe,
    )

    om_probe = OverheadModel().with_overlapped_seam(
        SEAM_PROBE["plan"], SEAM_PROBE["ppermute_latency_s"],
        SEAM_PROBE["interior_compute_s_per_step"],
    )
    assert OVERHEADS.seam_s_per_step() == om_probe.seam_s_per_step()
    assert OVERHEADS.seam_latency_s == om_probe.seam_latency_s

    om_floor = OverheadModel().with_measured_seam(
        SEAM_PROBE["plan"], SEAM_PROBE["ppermute_latency_s"]
    )
    # the floor is real and nonzero; the probe shows the pipeline hides
    # it completely behind the measured stripe-interior compute
    assert om_floor.seam_s_per_step() > 0.0
    assert om_probe.seam_s_per_step() == 0.0
    assert OVERHEADS.seam_s_per_step() != om_floor.seam_s_per_step()

    # rebuilding from the committed snapshot is the one sanctioned path
    om2 = overheads_from_probe(SEAM_PROBE)
    assert om2.seam_latency_s == OVERHEADS.seam_latency_s
