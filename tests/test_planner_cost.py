"""Cost-aware burst planning (DESIGN.md §14).

Pins both sides of the cost/deadline trade-off knob: with slack to
spend, the planner deviates from the deadline-first minimal slice to a
larger-but-cheaper-overall one (superlinear scaling laws make a big
slice finish and retire early enough to bill fewer chip-hours); with
tight slack — or the knob at zero — it falls back to the deadline-first
solve exactly.
"""
import pytest

from repro.core import BurstPlanner, LogCapacityModel, OverheadModel
from repro.core.deadline import DeadlineEstimate

LEGAL = [16, 32, 64, 128, 256]
ONPREM = 128
OV = OverheadModel(ckpt_s=5, provision_s=60, restart_s=15)


def _models(alpha: float, work: float = 4280.0, k: float = 1.4):
    cs = sorted(set(LEGAL) | {ONPREM})
    cluster = LogCapacityModel.fit(
        cs, [work / c ** alpha for c in cs], name="site"
    )
    cloud = LogCapacityModel.fit(
        cs, [k * work / c ** alpha for c in cs], name="cloud"
    )
    return cluster, cloud


def _planner(alpha: float, cost_weight: float,
             price: float = 3.0) -> BurstPlanner:
    cluster, cloud = _models(alpha)
    return BurstPlanner(
        cluster_model=cluster, cloud_model=cloud, chips_cluster=ONPREM,
        legal_slices=LEGAL, overheads=OV,
        price_per_chip_hour=price, cost_weight=cost_weight,
    )


def _est(elapsed, deadline, t_obs, steps_done, steps_total):
    rem = (steps_total - steps_done) * t_obs
    total = elapsed + rem
    return DeadlineEstimate(
        estimated_total_s=total, elapsed_s=elapsed, remaining_s=rem,
        deadline_s=deadline, slack_s=deadline - total,
        will_miss=True, predictable=True,
    )


def _congested_plan(planner, *, elapsed=500.0, deadline=1500.0,
                    congestion=2.0):
    """Mid-run congestion: observed step time is `congestion`× the
    model at the on-premise operating point."""
    t_obs = congestion * planner.cluster_model.predict_time(ONPREM)
    est = _est(elapsed, deadline, t_obs, 100, 200)
    return planner.plan(est, 100, 200, observed_step_s=t_obs,
                        effective_chips=ONPREM)


def test_cost_aware_picks_larger_but_cheaper_slice_when_slack_allows():
    blind = _congested_plan(_planner(1.3, cost_weight=0.0))
    aware = _congested_plan(_planner(1.3, cost_weight=0.6))
    assert blind.burst and aware.burst
    # deadline-first minimal slice vs the cost-chosen larger one
    assert aware.chips_burst > blind.chips_burst
    assert blind.chips_burst == 64 and aware.chips_burst == 256
    # and the larger slice is projected strictly cheaper overall:
    # superlinear scaling retires it early enough to bill fewer chip-h
    assert 0 < aware.est_cost_usd < blind.est_cost_usd
    assert 0 < aware.est_hold_s < blind.est_hold_s
    assert "cost-aware" in aware.reason and "$" in aware.reason


def test_cost_aware_falls_back_to_deadline_first_when_slack_tight():
    # low knob: the spendable budget w·(deadline − elapsed) admits no
    # candidate, so the deadline-first solve stands
    low = _congested_plan(_planner(1.3, cost_weight=0.3))
    blind = _congested_plan(_planner(1.3, cost_weight=0.0))
    assert low.chips_burst == blind.chips_burst == 64
    assert "cost-aware" not in low.reason
    # genuinely tight deadline: even at w = 1 the minimal solve already
    # IS the only feasible slice — no deviation, no cost-aware note
    tight = _planner(1.3, cost_weight=1.0)
    t_obs = 2.0 * tight.cluster_model.predict_time(ONPREM)
    est = _est(1800.0, 2300.0, t_obs, 100, 200)
    d = tight.plan(est, 100, 200, observed_step_s=t_obs,
                   effective_chips=ONPREM)
    assert d.burst and d.chips_burst == 256
    assert "cost-aware" not in d.reason


def test_knob_zero_is_exactly_the_deadline_first_solve():
    """cost_weight = 0 must reproduce the price-free planner's decision
    bit-for-bit on every sizing field (cost projection aside)."""
    free = _congested_plan(_planner(1.3, cost_weight=0.0, price=0.0))
    priced = _congested_plan(_planner(1.3, cost_weight=0.0, price=3.0))
    for f in ("burst", "chips_burst", "gamma", "correction_K",
              "cores_needed", "est_time_burst_s", "overhead_s"):
        assert getattr(free, f) == getattr(priced, f), f
    assert free.est_cost_usd == 0.0 and priced.est_cost_usd > 0.0


def test_linear_law_cost_aware_keeps_minimal_slice():
    """Work-conserving (t ∝ 1/c) scaling: chip-hours are monotone in
    slice size, so the cheapest feasible slice IS the minimal one and
    cost-awareness must not change the pick (fleet back-compat)."""
    blind = _congested_plan(_planner(1.0, cost_weight=0.0))
    aware = _congested_plan(_planner(1.0, cost_weight=1.0))
    assert aware.chips_burst == blind.chips_burst
    assert "cost-aware" not in aware.reason


def test_cost_projection_is_price_times_chip_hours():
    d = _congested_plan(_planner(1.3, cost_weight=0.6))
    assert d.est_cost_usd == pytest.approx(
        3.0 * d.chips_burst * d.est_hold_s / 3600.0
    )
    # the hold projection never exceeds running the whole remainder on
    # the combined fleet
    p = _planner(1.3, cost_weight=0.6)
    assert d.est_hold_s <= 100 * p.cluster_model.predict_time(ONPREM) * 2
