"""Fleet simulator + autoscaler policy suite (DESIGN.md §11).

Covers the ISSUE-2 acceptance surface: bit-determinism of seeded runs,
the paper's core claim at fleet scale (deadline-aware beats no-burst on
the overload scenario at lower cost than always-burst), that SHRINK /
RETIRE actually returns chips (cloud spend stops once load clears), and
that orchestrator grow/shrink transitions preserve checkpoint/restore
invariants.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import (
    BurstPlanner,
    DeadlinePredictor,
    ElasticOrchestrator,
    LogCapacityModel,
    OverheadModel,
    PodSpec,
    Resources,
    ScaleAction,
    elastic_chips,
    legal_step_down,
    legal_step_up,
    proportional_shares,
)
from repro.core.sim_session import SimSession, SimWorkload, \
    sim_session_factory
from repro.sim import (
    POLICY_FACTORIES,
    FleetSim,
    JobSpec,
    NoBurstAutoscaler,
    PlanAutoscaler,
    Scenario,
    Tenant,
)
from repro.sim.scenarios import (
    calm,
    default_scenarios,
    overload_ramp,
    superlinear_cache,
    transient_spike,
)

LEGAL = [16, 32, 64, 128, 256]


def _planner(**kw):
    m = LogCapacityModel.fit(LEGAL, [2000.0 / c for c in LEGAL])
    defaults = dict(
        cluster_model=m, cloud_model=m, chips_cluster=256,
        legal_slices=LEGAL,
        overheads=OverheadModel(ckpt_s=5, provision_s=60, restart_s=20),
    )
    defaults.update(kw)
    return BurstPlanner(**defaults)


# ------------------------------------------------------ scale primitives


def test_apply_scale_grow_creates_cloud_pod_with_gamma_split():
    res = Resources(pods=[PodSpec(128, name="site")], shares=[1.0])
    grown = ElasticOrchestrator.apply_scale(
        res, ScaleAction("grow", chips=64, slowdown=1.6)
    )
    assert [p.name for p in grown.pods] == ["site", "cloud"]
    assert elastic_chips(grown) == 64
    # shares ∝ chips/K and sum to 1
    tps = [128.0, 64.0 / 1.6]
    want = [t / sum(tps) for t in tps]
    assert np.allclose(grown.shares, want)


def test_apply_scale_shrink_keeps_measured_k_and_retire_drops():
    res = Resources(pods=[PodSpec(128, name="site")], shares=[1.0])
    grown = ElasticOrchestrator.apply_scale(
        res, ScaleAction("grow", chips=128, slowdown=1.5)
    )
    shrunk = ElasticOrchestrator.apply_scale(
        grown, ScaleAction("shrink", chips=32)
    )
    assert elastic_chips(shrunk) == 32
    cloud = [p for p in shrunk.pods if p.name == "cloud"][0]
    assert cloud.slowdown == 1.5          # K survives the resize
    retired = ElasticOrchestrator.apply_scale(
        shrunk, ScaleAction("retire")
    )
    assert elastic_chips(retired) == 0
    assert retired.shares == [1.0]
    # hold and unknown kinds are no-ops
    assert ElasticOrchestrator.apply_scale(grown, ScaleAction("hold")) \
        is grown
    assert ElasticOrchestrator.apply_scale(
        grown, ScaleAction("rebalance")) is grown


def test_legal_step_helpers_and_proportional_shares():
    assert legal_step_up(0, LEGAL) == 16
    assert legal_step_up(16, LEGAL) == 32
    assert legal_step_up(256, LEGAL) == 256
    assert legal_step_down(16, LEGAL) == 0
    assert legal_step_down(256, LEGAL) == 128
    assert np.allclose(sum(proportional_shares([3.0, 1.0])), 1.0)
    assert proportional_shares([0.0, 0.0]) == [0.5, 0.5]


def test_sim_session_extra_slowdown_hook():
    res = Resources(pods=[PodSpec(128, name="site")], shares=[1.0])
    mk = lambda f: SimSession(  # noqa: E731
        SimWorkload(1000.0, jitter=0.0), res, 0, None,
        rng=np.random.default_rng(0), extra_slowdown=f,
    )
    base = mk(None).run_step(0)
    slowed = mk(lambda i, step: 2.5).run_step(0)
    assert slowed == pytest.approx(2.5 * base)


# ------------------------------------------------------ fleet behaviour


def test_fleet_seeded_runs_are_bit_deterministic():
    for pf in (PlanAutoscaler, POLICY_FACTORIES["react"]):
        a = FleetSim(overload_ramp(3), pf, seed=11).run()
        b = FleetSim(overload_ramp(3), pf, seed=11).run()
        assert dataclasses.asdict(a) == dataclasses.asdict(b)
    c = FleetSim(overload_ramp(3), PlanAutoscaler, seed=12).run()
    d = FleetSim(overload_ramp(3), PlanAutoscaler, seed=11).run()
    assert dataclasses.asdict(d) != dataclasses.asdict(c)


def test_overload_plan_beats_noburst_and_undercuts_alwaysburst():
    sc = overload_ramp(0)
    plan = FleetSim(sc, PlanAutoscaler, seed=0).run()
    nb = FleetSim(sc, NoBurstAutoscaler, seed=0).run()
    ab = FleetSim(sc, POLICY_FACTORIES["always-burst"], seed=0).run()
    assert plan.hit_rate > nb.hit_rate          # strictly higher
    assert plan.cloud_cost < ab.cloud_cost      # strictly cheaper
    assert nb.cloud_cost == 0.0


def test_scale_down_retires_cloud_chips_after_load_clears():
    rec = FleetSim(transient_spike(0), PlanAutoscaler, seed=0).run()
    peak = max(c for _, c in rec.cloud_timeline)
    assert peak > 0, "policy should burst during the spike"
    assert rec.cloud_timeline[-1][1] == 0, "cloud pod must be retired"
    # cost therefore stays far below holding the peak for the makespan
    held = rec.makespan_s * peak / 3600.0 \
        * transient_spike(0).cloud.price_per_chip_hour
    assert rec.cloud_cost < 0.5 * held
    # retire happened while jobs were still running, not at finish
    t_retire = max(
        t for j in rec.jobs for t, kind, d in j.events
        if kind == "scale" and d["kind"] == "retire"
    )
    assert t_retire < rec.makespan_s


def test_fleet_all_scenarios_complete_all_jobs():
    for sc in default_scenarios(1):
        for name, pf in POLICY_FACTORIES.items():
            rec = FleetSim(sc, pf, seed=1).run()
            assert all(j.finished for j in rec.jobs), (sc.name, name)
            assert 0.0 <= rec.useful_frac <= 1.0
            assert rec.cloud_cost >= 0.0


def test_spot_reclaims_roll_back_and_rerun_lost_steps():
    from repro.sim.scenarios import spot_market
    rec = FleetSim(spot_market(0), PlanAutoscaler, seed=0).run()
    reclaims = [
        (t, d) for j in rec.jobs for t, kind, d in j.events
        if kind == "spot_reclaim"
    ]
    assert reclaims, "spot scenario should reclaim at least one pod"
    for _, d in reclaims:
        assert d["cloud_chips"] == 0      # pod really gone
    assert all(j.finished for j in rec.jobs)


# ---------------------------------------------- accounting regressions


def test_record_unfinished_jobs_report_elapsed_so_far():
    """Regression: _record used finish_s − arrival_s for *unfinished*
    jobs too — an unset finish_s made elapsed negative (silently
    clamped into consumed) and garbage in the JobRecord."""
    sim = FleetSim(overload_ramp(0), NoBurstAutoscaler, seed=0)
    sim.now = 0.0
    sim._arrive(sim.jobs[0])          # job0 running; job1 never arrived
    sim.now = 500.0
    rec = sim._record()
    j0, j1 = rec.jobs
    assert not j0.finished and not j0.met_deadline
    assert j0.elapsed_s == 500.0      # elapsed-so-far, not -arrival_s
    assert j1.elapsed_s == 0.0        # not the negative -60 of old
    assert all(j.elapsed_s >= 0.0 for j in rec.jobs)
    assert 0.0 <= rec.useful_frac <= 1.0


def test_deadline_missing_job_has_sane_elapsed_and_accounting():
    rec = FleetSim(overload_ramp(0), NoBurstAutoscaler, seed=0).run()
    for j in rec.jobs:
        assert j.finished and not j.met_deadline
        assert j.elapsed_s > j.deadline_s > 0
        assert j.elapsed_s == pytest.approx(
            j.finish_s - next(
                s.arrival_s for s in overload_ramp(0).jobs
                if s.name == j.name
            )
        )
    assert 0.0 <= rec.useful_frac <= 1.0


def test_met_deadline_judged_against_deadline_in_force_at_finish():
    """Regression: a deadline change landing *after* a job finished
    must not retro-judge it — _record reads the predictor's change
    history at finish time, not its latest value."""
    sim = FleetSim(calm(0), NoBurstAutoscaler, seed=0)
    rec = sim.run()
    assert all(j.met_deadline for j in rec.jobs)
    for jrt in sim.jobs:              # tighten AFTER every finish
        jrt.predictor.set_deadline(1.0, at_s=sim.now + 100.0)
    rec2 = sim._record()
    assert [j.met_deadline for j in rec2.jobs] == \
        [j.met_deadline for j in rec.jobs]
    assert [j.deadline_s for j in rec2.jobs] == \
        [j.deadline_s for j in rec.jobs]


def test_deadline_squeeze_judged_against_tightened_deadline():
    """Jobs running through the squeeze ARE judged against the new
    value (the change was in force when they finished)."""
    from repro.sim.scenarios import deadline_squeeze
    rec = FleetSim(deadline_squeeze(0), NoBurstAutoscaler, seed=0).run()
    for j in rec.jobs:
        assert j.deadline_s == 2000.0  # tightened, not the 2600 start


def test_predictor_deadline_history():
    from repro.core import DeadlinePredictor
    p = DeadlinePredictor(2600.0)
    p.set_deadline(2000.0, at_s=800.0)
    p.set_deadline(2400.0, at_s=1500.0)
    assert p.deadline_at(700.0) == 2600.0
    assert p.deadline_at(800.0) == 2000.0
    assert p.deadline_at(1400.0) == 2000.0
    assert p.deadline_at(2000.0) == 2400.0
    assert p.deadline_s == 2400.0


def test_predictor_untimestamped_change_is_not_retroactive():
    """A legacy set_deadline() without at_s must govern the current
    deadline but never be presumed to predate a finite finish time."""
    from repro.core import DeadlinePredictor
    p = DeadlinePredictor(100.0)
    p.set_deadline(50.0)              # no clock available
    assert p.deadline_s == 50.0
    assert p.deadline_at(10.0) == 100.0
    assert p.deadline_at(1e12) == 100.0


def test_predictor_out_of_order_changes():
    from repro.core import DeadlinePredictor
    p = DeadlinePredictor(100.0)
    p.set_deadline(50.0, at_s=900.0)
    p.set_deadline(70.0, at_s=800.0)  # logged late, effective earlier
    assert p.deadline_at(850.0) == 70.0
    assert p.deadline_at(950.0) == 50.0
    assert p.deadline_at(700.0) == 100.0


def test_record_snapshot_includes_accrued_cloud_chip_seconds():
    """A mid-run _record must bill the currently-held pod up to `now`,
    not just what _bill_cloud flushed at the last scale event."""
    sim = FleetSim(overload_ramp(0), NoBurstAutoscaler, seed=0)
    sim.now = 0.0
    sim._arrive(sim.jobs[0])
    jrt = sim.jobs[0]
    jrt.res = ElasticOrchestrator.apply_scale(
        jrt.res, ScaleAction("grow", chips=64, slowdown=1.4)
    )
    jrt.cloud_since = 100.0
    sim.now = 500.0
    rec = sim._record()
    assert rec.jobs[0].cloud_chip_s == pytest.approx(64 * 400.0)
    assert rec.jobs[0].cloud_cost == pytest.approx(
        sim.cloud.cost(64 * 400.0)
    )
    # the accrual is a snapshot, not a flush: runtime state untouched
    assert jrt.cloud_chip_s == 0.0 and jrt.cloud_since == 100.0


def test_no_duplicate_grow_in_provision_attach_window():
    """Regression: between provision-complete and the step-boundary
    attach, an evaluate saw cloud=0/pending=0 and re-requested (and
    re-paid) the same slice."""
    rec = FleetSim(superlinear_cache(0), PlanAutoscaler, seed=0).run()
    for j in rec.jobs:
        scales = [
            (d["kind"], d["cloud_chips"]) for _, k, d in j.events
            if k == "scale"
        ]
        for (k1, c1), (k2, c2) in zip(scales, scales[1:]):
            assert not (k1 == k2 == "grow" and c1 == c2), scales


def test_superlinear_cost_aware_beats_blind_at_equal_hit_rate():
    """The §14 claim at fleet scale: on the cache-superlinear world the
    cost-aware planner buys the same deadline hit-rate for strictly
    fewer cloud $ than the cost-blind minimal-slice solve."""
    aware = FleetSim(superlinear_cache(0), PlanAutoscaler, seed=0).run()
    blind = FleetSim(
        superlinear_cache(0, cost_weight=0.0), PlanAutoscaler, seed=0
    ).run()
    assert aware.hit_rate == blind.hit_rate == 1.0
    assert aware.cloud_cost < blind.cloud_cost
    # the aware run actually held larger slices, not just shorter ones
    peak_aware = max(c for _, c in aware.cloud_timeline)
    peak_blind = max(c for _, c in blind.cloud_timeline)
    assert peak_aware > peak_blind


# ------------------------------------- orchestrator scale transitions


class _Scripted:
    """Grow at one step, shrink later, retire near the end."""

    name = "scripted"

    def __init__(self, grow_at=24, shrink_at=64, retire_at=96):
        self.grow_at, self.shrink_at, self.retire_at = \
            grow_at, shrink_at, retire_at

    def decide(self, ctx):
        if ctx.step == self.grow_at:
            return ScaleAction("grow", chips=64, slowdown=1.4)
        if ctx.step == self.shrink_at:
            return ScaleAction("shrink", chips=32)
        if ctx.step == self.retire_at:
            return ScaleAction("retire")
        return ScaleAction("hold")


def test_orchestrator_grow_shrink_preserves_checkpoint_invariants():
    orch = ElasticOrchestrator(
        planner=_planner(), predictor=DeadlinePredictor(10_000.0),
        check_every=8, ckpt_every=25,
    )
    base = sim_session_factory(
        SimWorkload(2000.0, jitter=0.01), rng=np.random.default_rng(0)
    )
    transitions = []

    def factory(res, start_step, restored):
        transitions.append((
            start_step,
            None if restored is None else restored.get("step"),
            elastic_chips(res),
        ))
        return base(res, start_step, restored)

    rec = orch.run(
        session_factory=factory,
        initial=Resources(pods=[PodSpec(256, name="cluster")],
                          shares=[1.0]),
        steps_total=120,
        autoscaler=_Scripted(),
    )
    assert rec.completed and rec.steps == 120
    kinds = [e.detail["kind"] for e in rec.events if e.kind == "scale"]
    assert kinds == ["grow", "shrink", "retire"]
    # every transition restored the checkpoint taken at that very step,
    # and the chip trajectory matches the scripted actions
    assert transitions[0] == (0, None, 0)
    assert [(s, r) for s, r, _ in transitions[1:]] == \
        [(24, 24), (64, 64), (96, 96)]
    assert [c for _, _, c in transitions[1:]] == [64, 32, 0]
    assert elastic_chips(rec.final_resources) == 0
    # shares always a valid γ split
    for e in rec.events:
        if e.kind == "scale":
            assert np.isclose(sum(e.detail["shares"]), 1.0)


def test_orchestrator_scale_overheads_accounted():
    ov = OverheadModel(ckpt_s=5, provision_s=60, restart_s=20)
    orch = ElasticOrchestrator(
        planner=_planner(overheads=ov),
        predictor=DeadlinePredictor(10_000.0),
        check_every=8, ckpt_every=1000,
    )
    base = sim_session_factory(
        SimWorkload(2000.0, jitter=0.0), rng=np.random.default_rng(0)
    )
    plain = orch.run(
        session_factory=base,
        initial=Resources(pods=[PodSpec(256, name="cluster")],
                          shares=[1.0]),
        steps_total=60,
        autoscaler=NoBurstAutoscaler(),
    )
    orch2 = ElasticOrchestrator(
        planner=_planner(overheads=ov),
        predictor=DeadlinePredictor(10_000.0),
        check_every=8, ckpt_every=1000,
    )
    scaled = orch2.run(
        session_factory=base,
        initial=Resources(pods=[PodSpec(256, name="cluster")],
                          shares=[1.0]),
        steps_total=60,
        autoscaler=_Scripted(grow_at=16, shrink_at=32, retire_at=48),
    )
    grow = ov.total()
    resize = ov.ckpt_s + ov.restart_s
    overhead_paid = sum(
        e.detail["overhead_s"] for e in scaled.events
        if e.kind == "scale"
    )
    assert overhead_paid == pytest.approx(grow + 2 * resize)
    # the scaled run can only be slower by overheads it actually paid
    # (the grown pod also speeds steps up, so bound from above only)
    assert scaled.elapsed_s <= plain.elapsed_s + overhead_paid + 1e-6


# ------------------------------------------- fleet-of-jobs layer (§16)


def test_queued_fleet_bit_deterministic():
    """The PR-2 determinism pin extended to the multi-job queue layer:
    identical (scenario, scheduler, policy, seed) -> bitwise-identical
    FleetRecords, including wait/fairness/pool fields and both event
    logs."""
    from repro.sim.scenarios import multi_tenant_rush

    sc = multi_tenant_rush(0, n_jobs=14)
    for sched, fp in (("fill", "adapt"), ("fifo", "token")):
        a = FleetSim(sc, POLICY_FACTORIES["react"], seed=7,
                     scheduler=sched, fleet_policy=fp).run()
        b = FleetSim(sc, POLICY_FACTORIES["react"], seed=7,
                     scheduler=sched, fleet_policy=fp).run()
        assert dataclasses.asdict(a) == dataclasses.asdict(b)
    c = FleetSim(sc, POLICY_FACTORIES["react"], seed=8,
                 scheduler="fill", fleet_policy="adapt").run()
    assert dataclasses.asdict(c) != dataclasses.asdict(a)


def test_queued_fleet_deterministic_across_subprocess():
    """Bit-determinism must hold across process boundaries (no dict /
    hash / id ordering may leak into the record): the same queued run
    digests identically in a fresh interpreter."""
    import hashlib
    import subprocess
    import sys
    from pathlib import Path

    from repro.sim.scenarios import multi_tenant_rush

    script = (
        "import dataclasses, hashlib\n"
        "from repro.sim import FleetSim, POLICY_FACTORIES\n"
        "from repro.sim.scenarios import multi_tenant_rush\n"
        "rec = FleetSim(multi_tenant_rush(0, n_jobs=10),\n"
        "               POLICY_FACTORIES['react'], seed=3,\n"
        "               scheduler='best-fit', fleet_policy='reg').run()\n"
        "print(hashlib.sha256(\n"
        "    repr(dataclasses.asdict(rec)).encode()).hexdigest())\n"
    )
    rec = FleetSim(multi_tenant_rush(0, n_jobs=10),
                   POLICY_FACTORIES["react"], seed=3,
                   scheduler="best-fit", fleet_policy="reg").run()
    here = hashlib.sha256(
        repr(dataclasses.asdict(rec)).encode()
    ).hexdigest()
    src = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={"PYTHONPATH": src, "PATH": "/usr/bin:/bin"}, check=True,
    )
    assert out.stdout.strip() == here


def test_mid_run_snapshot_bills_rented_pool_pod():
    """The §16 generalization of the accrual fix: a mid-run FleetRecord
    must bill EVERY concurrently-held pod up to `now` — including a
    cloud-hosted (rented) home pod that _bill_cloud never flushed."""
    from repro.sim.queue import Tenant
    from repro.sim.scenarios import Scenario

    job = JobSpec(name="wide", arrival_s=0.0, steps_total=100,
                  deadline_s=10_000.0, chip_seconds_per_step=256.0,
                  onprem_chips=32)
    sc = Scenario(name="tiny_site", jobs=(job,), site_chips=16,
                  scheduler="fill", fleet_policy="adapt",
                  tenants=(Tenant("user0"),))
    sim = FleetSim(sc, POLICY_FACTORIES["no-burst"], seed=0)
    rec = sim.run(until_s=400.0)
    j = rec.jobs[0]
    assert j.state == "running"
    admit = next(d for _, k, d in j.events if k == "admit")
    assert admit["placement"] == "cloud"
    admit_t = next(t for t, k, _ in j.events if k == "admit")
    assert j.cloud_chip_s == pytest.approx(32 * (400.0 - admit_t))
    assert j.cloud_cost == pytest.approx(
        sim.cloud.cost(32 * (400.0 - admit_t))
    )


def test_staged_pods_count_in_fleet_committed():
    """The PR-4 double-request fix generalized fleet-wide: the global
    cap must see chips *staged* for any job (pending grow action or
    in-flight provision) plus rented home pods and the pool."""
    from repro.sim.scenarios import multi_tenant_rush

    sim = FleetSim(multi_tenant_rush(0, n_jobs=4),
                   POLICY_FACTORIES["react"], seed=0)
    sim.now = 0.0
    sim._arrive(sim.jobs[0])
    sim._admit_pass()
    jrt = sim.jobs[0]
    assert jrt.arrived
    base = sim._fleet_committed()
    jrt.pending_action = ScaleAction("grow", chips=64, slowdown=1.4)
    assert sim._fleet_committed() == base + 64
    jrt.pending_action = None
    jrt.pending_target = 128
    assert sim._fleet_committed() == base + 128
    jrt.pending_target = 0
    sim.pool_free += 32
    sim.pool_pending += 16
    assert sim._fleet_committed() == base + 48


def test_starvation_guard_blocks_and_releases():
    """While a weighted tenant has waited past patience and cannot fit,
    NOBODY may be admitted past it; once it fits it goes first."""
    from repro.sim.queue import Tenant
    from repro.sim.scenarios import Scenario

    def _j(name, chips, arrival, tenant):
        return JobSpec(name=name, arrival_s=arrival, steps_total=50,
                       deadline_s=50_000.0,
                       chip_seconds_per_step=8.0 * chips,
                       onprem_chips=chips, tenant=tenant)

    sc = Scenario(
        name="starve", site_chips=64, scheduler="fill",
        starve_patience_s=600.0,
        tenants=(Tenant("a", weight=2.0), Tenant("b", weight=1.0)),
        jobs=(_j("big", 48, 0.0, "a"), _j("mid", 32, 10.0, "b"),
              _j("small", 16, 650.0, "a")),
    )
    sim = FleetSim(sc, POLICY_FACTORIES["no-burst"], seed=0)
    sim.now = 0.0
    sim._arrive(sim.jobs[0])          # occupies 48 of 64
    sim.now = 10.0
    sim._arrive(sim.jobs[1])          # 32 > 16 free: waits
    sim.now = 650.0
    sim._arrive(sim.jobs[2])          # fits, but 'mid' expired: blocked
    assert sim.jobs[2].state == "queued"
    assert any(k == "admission_blocked" for _, k, _ in sim.fleet_events)
    sim._finish(sim.jobs[0])          # frees the site at t=650
    assert sim.jobs[1].state == "running"     # expired head goes first
    sim._admit_pass()
    assert sim.jobs[2].state == "running"     # then normal admission
    admit = next(d for _, k, d in sim.jobs[1].events if k == "admit")
    assert admit["expired_present"] and admit["entry_expired"]


def test_rented_pool_chips_return_on_finish():
    """Cloud-side admission is a loan from the pool: the home pod's
    chips must flow back to pool_free when the job finishes."""
    from repro.sim.queue import Tenant
    from repro.sim.scenarios import Scenario

    job = JobSpec(name="wide", arrival_s=0.0, steps_total=10,
                  deadline_s=10_000.0, chip_seconds_per_step=256.0,
                  onprem_chips=32)
    sc = Scenario(name="tiny_site", jobs=(job,), site_chips=16,
                  scheduler="fill", fleet_policy="adapt",
                  tenants=(Tenant("user0"),))
    rec = FleetSim(sc, POLICY_FACTORIES["no-burst"], seed=0).run()
    j = rec.jobs[0]
    assert j.finished
    returns = [
        d for t, k, d in rec.fleet_events
        if k == "pool_return" and d["job"] == "wide"
    ]
    assert any(d["chips"] == 32 for d in returns)
    # and the job paid for its rented chips
    assert j.cloud_chip_s > 0


@pytest.mark.slow
@pytest.mark.skipif(
    not os.environ.get("RUN_SLOW"),
    reason="thousand-job tournament cell (~5 s); set RUN_SLOW=1",
)
def test_thousand_job_rush_conserves_and_scores():
    from repro.sim.scenarios import multi_tenant_rush

    sc = multi_tenant_rush(0, n_jobs=1000, rate_per_hour=1200.0,
                           budget_usd=6000.0)
    rec = FleetSim(sc, POLICY_FACTORIES["react"], seed=0,
                   scheduler="fill", fleet_policy="adapt").run()
    assert len(rec.jobs) == 1000
    assert all(j.state == "finished" for j in rec.jobs)
    assert 0.0 <= rec.hit_rate <= 1.0 and 0.0 <= rec.fairness <= 1.0
