"""Property-based tests (hypothesis) on the auto-scaler's invariants."""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    GammaModel,
    LogCapacityModel,
    burst_cores,
    conservation_ok,
    correction_factor,
    heterogeneous_split,
    round_to_legal_slice,
)
from repro.core.monitor import StepTimeMonitor

# ------------------------------------------------------- capacity models


@given(
    A=st.floats(0.1, 1.0),
    B=st.floats(-2.0, 4.0),
    cores=st.lists(
        st.integers(2, 4096), min_size=3, max_size=10, unique=True
    ),
)
def test_capacity_fit_recovers_exact_model(A, B, cores):
    m_true = LogCapacityModel(A=A, B=B)
    times = [m_true.predict_time(c) for c in cores]
    m_fit = LogCapacityModel.fit(cores, times)
    assert abs(m_fit.A - A) < 1e-6
    assert abs(m_fit.B - B) < 1e-6
    assert m_fit.r2(cores, times) > 1 - 1e-9


@given(
    A=st.floats(0.2, 1.0), B=st.floats(-1.0, 3.0),
    c=st.floats(1.0, 1e5),
)
def test_capacity_inverse_property(A, B, c):
    """cores_for(predict_time(c)) == c (model inversion is exact)."""
    m = LogCapacityModel(A=A, B=B)
    c_back = m.cores_for(m.predict_time(c))
    assert abs(c_back - c) / c < 1e-6


@given(A=st.floats(0.2, 1.0), B=st.floats(-1.0, 3.0))
def test_capacity_monotone_in_cores(A, B):
    m = LogCapacityModel(A=A, B=B)
    times = [m.predict_time(c) for c in [1, 2, 8, 64, 512]]
    assert all(t1 > t2 for t1, t2 in zip(times, times[1:]))


@given(
    need=st.floats(0, 2048), have=st.integers(1, 1024),
    K=st.floats(0.25, 4.0),
)
def test_burst_cores_nonnegative_and_scaled(need, have, K):
    c_n = burst_cores(need, have, K)
    assert c_n >= 0
    if need > have:
        assert abs(c_n - (need - have) * K) < 1e-9


@given(c_n=st.floats(0, 600))
def test_round_to_legal_always_covers(c_n):
    legal = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512]
    s = round_to_legal_slice(c_n, legal)
    if c_n <= 0:
        assert s == 0
    elif c_n <= max(legal):
        assert s >= c_n and s in legal
    else:
        assert s == max(legal)


def test_correction_factor_matches_paper_form():
    """mode='paper': K = (-A ln c + B)/(-D ln c + E), the paper's literal
    ratio; mode='time' is the stable throughput ratio (see capacity.py)."""
    cloud = LogCapacityModel(A=0.77, B=7.1)      # paper eq. 6
    cluster = LogCapacityModel(A=0.65, B=6.5)    # paper eq. 7
    for c in [10, 20, 40]:
        K = correction_factor(cloud, cluster, c, mode="paper")
        expected = (-0.77 * math.log(c) + 7.1) / (-0.65 * math.log(c) + 6.5)
        assert abs(K - expected) < 1e-9
        K_time = correction_factor(cloud, cluster, c, mode="time")
        assert K_time == pytest.approx(
            cloud.predict_time(c) / cluster.predict_time(c)
        )


def test_correction_factor_stable_near_one_second():
    """The paper's L-ratio diverges when log10(t) ≈ 0; the time-ratio K
    must stay finite and sensible there (the LM-step regime)."""
    cluster = LogCapacityModel.fit([2, 4, 8], [2.0, 1.0, 0.5])  # t(4)=1s
    cloud = LogCapacityModel.fit([2, 4, 8], [2.5, 1.25, 0.625])
    K = correction_factor(cloud, cluster, 4.0)
    assert 1.2 < K < 1.3


# ------------------------------------------------------------ gamma model


@given(
    a=st.floats(1e-4, 10.0), b=st.floats(-5.0, 5.0),
    gamma=st.integers(1, 10_000),
)
def test_gamma_inverse_property(a, b, gamma):
    m = GammaModel(a=a, b=b)
    g = m.gamma_for(m.time_for(gamma))
    assert abs(g - gamma) <= 1  # integer ceil rounding


@given(
    a=st.floats(0.001, 5.0), b=st.floats(0.0, 5.0),
    gammas=st.lists(st.integers(1, 5000), min_size=3, max_size=8,
                    unique=True),
)
def test_gamma_fit_recovers_exact_model(a, b, gammas):
    m_true = GammaModel(a=a, b=b)
    times = [m_true.time_for(g) for g in gammas]
    m = GammaModel.fit(gammas, times)
    assert abs(m.a - a) / a < 1e-6
    assert m.r2(gammas, times) > 1 - 1e-9


# -------------------------------------------------------------- allocator


@given(
    n_mb=st.integers(1, 64),
    mb=st.sampled_from([1, 2, 4, 8]),
    seq=st.sampled_from([16, 64]),
    tps=st.lists(st.floats(0.1, 10.0), min_size=1, max_size=4),
)
def test_allocator_conserves_work(n_mb, mb, seq, tps):
    gb = n_mb * mb
    plan = heterogeneous_split(
        global_batch=gb, microbatch=mb, seq_len=seq, throughputs=tps
    )
    assert conservation_ok(plan, gb)
    assert plan.total_tokens == gb * seq
    padded = {s.padded_microbatches for s in plan.shares}
    assert len(padded) == 1  # uniform padded count (SPMD requirement)
    for s in plan.shares:
        m = plan.mask_for(s.pod)
        assert m.sum() == s.microbatches
        assert len(m) == s.padded_microbatches


@given(tp2=st.floats(0.1, 10.0))
def test_allocator_share_monotone_in_throughput(tp2):
    plan = heterogeneous_split(
        global_batch=64, microbatch=1, seq_len=8, throughputs=[1.0, tp2]
    )
    a, b = plan.shares[0].microbatches, plan.shares[1].microbatches
    if tp2 > 1.5:
        assert b >= a
    if tp2 < 0.67:
        assert a >= b


# ---------------------------------------------------------------- monitor


def test_monitor_predictable_on_constant_series():
    m = StepTimeMonitor(window=16)
    for _ in range(10):
        m.observe(1.0)
    assert m.predictable()
    assert abs(m.step_time() - 1.0) < 1e-6


def test_monitor_detects_regime_change():
    m = StepTimeMonitor(window=16)
    for _ in range(16):
        m.observe(1.0)
    for _ in range(8):
        m.observe(2.2)
    assert m.regime_changes, "sustained slowdown must flush the window"
    assert m.step_time() > 1.8


def test_monitor_isolated_straggler_filtered():
    m = StepTimeMonitor(window=16)
    for _ in range(12):
        m.observe(1.0)
    m.observe(8.0)  # single straggler
    for _ in range(3):
        m.observe(1.0)
    assert abs(m.step_time() - 1.0) < 0.1
    assert len(m.stragglers) == 1


# ------------------------------------------------------ int8 quantization


@given(
    data=st.lists(
        st.floats(-1e3, 1e3, allow_nan=False), min_size=128, max_size=256
    )
)
@settings(max_examples=30, deadline=None)
def test_q8_roundtrip_error_bound(data):
    import jax.numpy as jnp

    from repro.optim.adamw import QBLOCK, _dq8, _q8

    n = (len(data) // QBLOCK) * QBLOCK
    if n == 0:
        return
    x = jnp.asarray(np.asarray(data[:n], np.float32))
    q, scale = _q8(x)
    back = _dq8(q, scale, x.shape)
    blocks = np.asarray(x).reshape(-1, QBLOCK)
    # half-step rounding bound with slack for f32 arithmetic at exact
    # .5-ulp boundaries (e.g. 250 with absmax 500 -> error == bound)
    bound = np.abs(blocks).max(axis=1) / 127.0 * 0.5 * (1 + 1e-4) + 1e-6
    err = np.abs(np.asarray(back) - np.asarray(x)).reshape(-1, QBLOCK)
    assert (err.max(axis=1) <= bound).all()


@given(
    scale=st.floats(1e-12, 1e3),
    ratio=st.floats(1.0, 1e6),
)
@settings(max_examples=30, deadline=None)
def test_q8log_relative_error_small_across_magnitudes(scale, ratio):
    """Log-space quantization keeps relative error bounded even when a
    block spans many orders of magnitude (the linear-quant failure)."""
    import jax.numpy as jnp

    from repro.optim.adamw import QBLOCK, _dq8log, _q8log

    rng = np.random.default_rng(0)
    x = np.exp(
        rng.uniform(np.log(scale), np.log(scale * ratio), QBLOCK)
    ).astype(np.float32)
    xj = jnp.asarray(x)
    q, lo, span = _q8log(xj)
    back = np.asarray(_dq8log(q, lo, span, xj.shape))
    rel = np.abs(back - x) / np.maximum(x, 1e-20)
    assert rel.max() < 0.05
