"""Elastic LM training with a REAL mid-run burst (paper Fig. 1 end-to-end).

Launches with 8 placeholder host devices (launcher-style script — tests
and benches still see 1 device).  A granite-family model trains on a
"cluster" of 4 chips; at step 40 an injected congestion (time stretch)
slows it down; the monitor detects the regime change, the planner solves
eqs. 1-3 for the burst size, and the orchestrator checkpoints, rebuilds
the mesh as (pod=2, data, model), reshards the state onto 8 chips and
resumes — the same training run, now spanning both "environments".

    PYTHONPATH=src python examples/elastic_burst_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import RunConfig, get_config, smoke_config  # noqa: E402
from repro.configs.shapes import ShapeConfig  # noqa: E402
from repro.core import (  # noqa: E402
    BurstPlanner,
    DeadlinePredictor,
    ElasticOrchestrator,
    LogCapacityModel,
    OverheadModel,
    PodSpec,
    Resources,
)
from repro.data.pipeline import SyntheticLMPipeline  # noqa: E402
from repro.optim import constant, make_optimizer  # noqa: E402
from repro.runtime.train_step import (  # noqa: E402
    build_train_step,
    state_schema,
    state_shardings,
)
from repro.sharding.rules import (  # noqa: E402
    abstract_params,
    init_params,
    make_rules,
)

CFG = smoke_config(get_config("granite-8b"))
RUN = RunConfig(loss_chunk=32)
SHAPE = ShapeConfig("demo", "train", 64, 8)
OPT = make_optimizer("adamw", constant(1e-3))
SCH = state_schema(CFG, RUN, OPT)
PIPE = SyntheticLMPipeline(CFG, SHAPE)

STEPS = 120
CONGESTION_FROM = 40
CONGESTION = 2.5          # injected slowdown of the "cluster"

# What is REAL here: the training math, the mid-run checkpoint, the mesh
# rebuild (2,2) -> (2,2,2) and the reshard-on-restore.  What is MODELED:
# step wall time (this host has one core, so 8 placeholder devices cannot
# speed anything up) — reported step times follow the platform model
# W·share/(chips/slowdown), exactly like the FWI driver (DESIGN.md §10).


class LMSession:
    """Real JAX training session over the current Resources."""

    work_chip_s: float = 4.0  # chip-seconds per step (modeled platform)

    def __init__(self, res: Resources, start_step: int, restored):
        self.res = res
        n_pods = len(res.pods)
        if n_pods == 1:
            mesh = jax.make_mesh((2, 2), ("data", "model"),
                                 devices=jax.devices()[:4])
        else:
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                                 devices=jax.devices()[:8])
        self.rules = make_rules(mesh, "train")
        self.shardings = state_shardings(SCH, self.rules, RUN)
        self.step_fn = jax.jit(build_train_step(CFG, RUN, OPT, self.rules))
        if restored is None:
            params = jax.device_put(
                init_params(SCH["params"], jax.random.key(0)),
                self.shardings["params"],
            )
            self.state = {
                "params": params, "opt": OPT.init(params),
                "step": jnp.zeros((), jnp.int32),
            }
        else:
            # reshard-on-restore: host snapshot -> new mesh layout
            self.state = jax.tree.map(
                lambda a, s: jax.device_put(jnp.asarray(a), s),
                restored, self.shardings,
            )
        self.mesh_desc = dict(mesh.shape)

    def run_step(self, step: int) -> float:
        batch = PIPE.batch_at(step)
        self.state, metrics = self.step_fn(self.state, batch)
        self.last_loss = float(metrics["loss"])  # blocks (real compute)
        # platform-modeled step time (per-step sync: slowest pod wins)
        times = []
        for pod, share in zip(self.res.pods, self.res.shares):
            if share <= 0:
                continue
            t = self.work_chip_s * share / pod.chips * pod.slowdown
            if pod.name == "cluster" and step >= CONGESTION_FROM:
                t *= CONGESTION
            times.append(t)
        return max(times)

    def checkpoint(self, step: int):
        return jax.tree.map(lambda x: np.asarray(x), self.state)


def main():
    print(f"devices: {len(jax.devices())}")
    work = LMSession.work_chip_s
    t_step = work / 4
    chips = [1, 2, 4, 8]
    cluster = LogCapacityModel.fit(chips, [work / c for c in chips])
    cloud = LogCapacityModel.fit(chips, [1.25 * work / c for c in chips])
    deadline = t_step * STEPS * 1.6
    print(f"modeled step {t_step * 1000:.0f} ms on 4 chips -> deadline "
          f"{deadline:.1f}s for {STEPS} steps")

    planner = BurstPlanner(
        cluster_model=cluster, cloud_model=cloud, chips_cluster=4,
        legal_slices=[1, 2, 4],
        overheads=OverheadModel(ckpt_s=t_step, provision_s=4 * t_step,
                                restart_s=4 * t_step),
    )
    orch = ElasticOrchestrator(
        planner=planner, predictor=DeadlinePredictor(deadline),
        check_every=6, ckpt_every=30, max_bursts=1,
    )

    def factory(res, start_step, restored):
        sess = LMSession(res, start_step, restored)
        print(f"  [session] pods={[p.chips for p in res.pods]} "
              f"mesh={sess.mesh_desc} from step {start_step}")
        return sess

    rec = orch.run(
        session_factory=factory,
        initial=Resources(pods=[PodSpec(4, name="cluster")], shares=[1.0]),
        steps_total=STEPS,
    )
    print(f"elapsed {rec.elapsed_s:.1f}s vs deadline {deadline:.1f}s "
          f"-> met={rec.met_deadline}")
    for e in rec.events:
        if e.kind != "ckpt":
            print(f"  step {e.step}: {e.kind} {e.detail}")
    assert rec.met_deadline, "demo expects the burst to rescue the deadline"
    print("elastic_burst_demo OK")


if __name__ == "__main__":
    main()
