"""Hybrid-fleet auto-scaling demo: the paper's decision loop at fleet
scale (DESIGN.md §11).

Two scientific jobs share a 256-chip on-premise site.  Background
tenants ramp demand to 2.5× capacity, so "cluster overloaded" emerges
from contention.  Each autoscaler policy is evaluated every 30 simulated
seconds and may GROW / SHRINK / RETIRE a cloud pod per job; every resize
rides the same CHECKPOINT → REMESH → RESHARD → RESUME path as the
paper's one-shot burst.

    PYTHONPATH=src python examples/fleet_autoscale_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.sim import FleetSim, POLICY_FACTORIES  # noqa: E402
from repro.sim.scenarios import overload_ramp, transient_spike  # noqa: E402


def show(scenario):
    print(f"\n=== scenario: {scenario.name} ===")
    print(f"    {scenario.description}")
    print(f"{'policy':14s} {'hit-rate':>8s} {'cloud $':>9s} "
          f"{'useful':>7s} {'makespan':>9s}")
    recs = {}
    for pname, pf in POLICY_FACTORIES.items():
        rec = FleetSim(scenario, pf, seed=0).run()
        recs[pname] = rec
        print(f"{pname:14s} {rec.hit_rate:8.2f} {rec.cloud_cost:9.2f} "
              f"{rec.useful_frac:7.3f} {rec.makespan_s:8.0f}s")
    return recs


def main():
    recs = show(overload_ramp(0))
    plan, nb, ab = recs["plan"], recs["no-burst"], recs["always-burst"]
    assert plan.hit_rate > nb.hit_rate, "plan must rescue the deadline"
    assert plan.cloud_cost < ab.cloud_cost, "plan must undercut always-burst"

    # what the deadline-aware policy actually did for job0
    job0 = recs["plan"].jobs[0]
    print("\njob0 under `plan` (scale/rollback events):")
    for t, kind, detail in job0.events:
        if kind in ("scale", "provision_request", "spot_reclaim"):
            print(f"  t={t:7.1f}s {kind:18s} {detail}")

    recs = show(transient_spike(0))
    assert recs["plan"].cloud_timeline[-1][1] == 0, \
        "cloud pod must be retired once the spike clears"
    print("\nfleet_autoscale_demo OK")


if __name__ == "__main__":
    main()
