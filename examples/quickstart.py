"""Quickstart: train a reduced-config LM for a few steps with the
deadline monitor, checkpoint it, resume, then serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import serve, train  # noqa: E402

with tempfile.TemporaryDirectory() as ckpt:
    print("=== train 30 steps with a deadline monitor ===")
    train.main([
        "--arch", "yi-6b", "--smoke", "--steps", "30",
        "--batch", "4", "--seq", "64", "--deadline", "120",
        "--ckpt-dir", ckpt, "--ckpt-every", "10",
    ])
    print("=== resume from the checkpoint for 10 more ===")
    train.main([
        "--arch", "yi-6b", "--smoke", "--steps", "40",
        "--batch", "4", "--seq", "64",
        "--ckpt-dir", ckpt, "--resume",
    ])

print("=== batched serving (prefill + decode) ===")
serve.main([
    "--arch", "yi-6b", "--smoke", "--batch", "2",
    "--prompt-len", "16", "--gen", "8",
])
print("quickstart OK")
