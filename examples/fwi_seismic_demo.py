"""FWI seismic forward modeling with self-adaptive bursting — the paper's
own application end-to-end on the real solver (paper-scale 600x600 grid,
4 shots, reduced timestep count for the demo).

    PYTHONPATH=src python examples/fwi_seismic_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp  # noqa: E402

from repro.core import (  # noqa: E402
    BurstPlanner,
    DeadlinePredictor,
    ElasticOrchestrator,
    OverheadModel,
    PodSpec,
    Resources,
)
from repro.fwi.calibrate import fit_capacity_models  # noqa: E402
from repro.fwi.driver import TimeModel, fwi_session_factory  # noqa: E402
from repro.fwi.solver import FWIConfig, run_forward  # noqa: E402


def main():
    # 1) plain forward modeling: propagate + record receiver traces
    cfg = FWIConfig(nz=600, nx=600, timesteps=120, n_shots=4)
    st, traces = run_forward(cfg, steps=120)
    print(f"wavefield max |p| = {float(jnp.max(jnp.abs(st.p))):.3e}, "
          f"traces {traces.shape}, energy {float(jnp.sum(traces ** 2)):.3e}")

    # 2) calibration (paper §3.2): fit eqs. 6-8 from measured step times
    cal_cfg = FWIConfig(nz=128, nx=256, timesteps=60, n_shots=1,
                        sponge_width=16)
    cluster, cloud, samples = fit_capacity_models(
        cal_cfg, cloud_slowdown=1.4,
    )
    print(f"fitted: L_cluster(c) = -{cluster.A:.3f} ln c + {cluster.B:.2f}"
          f" | L_cloud(c) = -{cloud.A:.3f} ln c + {cloud.B:.2f}")

    # 3) self-adaptive run: congestion at step 30, deadline at 1.35x ideal
    work = samples["t1_measured"]
    tm = TimeModel(chip_seconds_per_step=work, congestion_from=30,
                   congestion_factor=2.0, jitter=0.01)
    deadline = work / 64 * 180 * 1.35
    planner = BurstPlanner(
        cluster_model=cluster, cloud_model=cloud, chips_cluster=64,
        legal_slices=[8, 16, 32, 64, 128],
        overheads=OverheadModel(ckpt_s=work / 64 * 2,
                                provision_s=work / 64 * 6,
                                restart_s=work / 64 * 2),
    )
    orch = ElasticOrchestrator(
        planner=planner, predictor=DeadlinePredictor(deadline),
        check_every=6, ckpt_every=40,
    )
    rec = orch.run(
        session_factory=fwi_session_factory(cal_cfg, tm),
        initial=Resources(pods=[PodSpec(chips=64, name="cluster")],
                          shares=[1.0]),
        steps_total=180,
    )
    print(f"adaptive FWI: elapsed {rec.elapsed_s:.2f}s vs deadline "
          f"{deadline:.2f}s -> met={rec.met_deadline}")
    for e in rec.events:
        if e.kind == "burst":
            print(f"  burst at step {e.step}: +{e.detail['chips']} chips, "
                  f"shares={['%.2f' % s for s in e.detail['shares']]}")
    print("fwi_seismic_demo OK")


if __name__ == "__main__":
    main()
