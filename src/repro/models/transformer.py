"""Decoder stack: scan-over-layers blocks, hybrid patterns, MTP.

Layers are grouped into BlockDefs (config); each group's params are
stacked on a leading "layers" dim and the group is applied with lax.scan —
HLO stays O(pattern) instead of O(num_layers), which keeps 61-80 layer
dry-run compiles fast and is remat/sharding friendly (the MaxText trick).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import BlockDef, ModelConfig
from repro.models import attention as attn
from repro.models import mamba2
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.layers import apply_mlp, apply_norm, mlp_schema, norm_schema
from repro.sharding.rules import shard, stack_schema


def remat_wrap(cfg: ModelConfig, fn, override: str | None = None):
    mode = override if override is not None else cfg.remat
    if mode == "none":
        return fn
    if mode == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# One layer
# ---------------------------------------------------------------------------


def layer_schema(cfg: ModelConfig, mixer: str, mlp: str, cross: bool = False):
    s: dict[str, Any] = {"norm1": norm_schema(cfg)}
    if mixer == "attn":
        s["mixer"] = attn.attn_schema(cfg)
    elif mixer == "mla":
        s["mixer"] = mla_mod.mla_schema(cfg)
    elif mixer == "mamba":
        s["mixer"] = mamba2.mamba_schema(cfg)
    else:
        raise ValueError(mixer)
    if cross:
        s["norm_x"] = norm_schema(cfg)
        s["cross"] = attn.attn_schema(cfg)
    if mlp == "dense":
        s["norm2"] = norm_schema(cfg)
        s["mlp"] = mlp_schema(cfg)
    elif mlp == "moe":
        s["norm2"] = norm_schema(cfg)
        s["mlp"] = moe_mod.moe_schema(cfg)
    elif mlp != "none":
        raise ValueError(mlp)
    return s


def layer_cache_schema(
    cfg: ModelConfig, mixer: str, batch: int, max_seq: int, long: bool,
    cross: bool = False,
):
    c: dict[str, Any] = {}
    if mixer == "attn":
        c["mixer"] = attn.attn_cache_schema(cfg, batch, max_seq, long)
    elif mixer == "mla":
        c["mixer"] = mla_mod.mla_cache_schema(cfg, batch, max_seq, long)
    elif mixer == "mamba":
        c["mixer"] = mamba2.mamba_cache_schema(cfg, batch)
    if cross:
        c["cross"] = attn.cross_cache_schema(cfg, batch)
    return c


def apply_layer_full(
    cfg: ModelConfig, p, x, mixer: str, mlp: str, *,
    rope_cs, causal=True, return_cache=False, long=False, enc_out=None,
):
    """Train/prefill layer.  x (B,S,d)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["norm1"], x)
    cache: dict[str, Any] = {}
    if mixer == "attn":
        y, c = attn.apply_attn_full(
            cfg, p["mixer"], h, rope_cs=rope_cs, causal=causal,
            return_cache=return_cache, long=long,
        )
    elif mixer == "mla":
        y, c = mla_mod.apply_mla_full(
            cfg, p["mixer"], h, rope_cs=rope_cs, causal=causal,
            return_cache=return_cache, long=long,
        )
    else:
        y, c = mamba2.apply_mamba_full(
            cfg, p["mixer"], h, return_cache=return_cache,
        )
    if return_cache:
        cache["mixer"] = c
    x = x + y.astype(x.dtype)
    if "cross" in p:
        hx = apply_norm(cfg, p["norm_x"], x)
        kv = attn.cross_kv(cfg, p["cross"], enc_out)
        if return_cache:
            cache["cross"] = kv
        x = x + attn.apply_cross_attn(cfg, p["cross"], hx, kv).astype(x.dtype)
    if mlp != "none":
        h2 = apply_norm(cfg, p["norm2"], x)
        if mlp == "moe":
            y2, moe_aux = moe_mod.apply_moe(cfg, p["mlp"], h2)
            aux = aux + moe_aux["lb_loss"] + moe_aux["z_loss"]
        else:
            y2 = apply_mlp(cfg, p["mlp"], h2)
        x = x + y2.astype(x.dtype)
    x = shard(x, "batch", "seq_res", "d_model")
    return x, cache, aux


def apply_layer_decode(
    cfg: ModelConfig, p, x, cache, pos, mixer: str, mlp: str, *,
    rope_cs, long=False,
):
    """Decode layer.  x (B,d)."""
    h = apply_norm(cfg, p["norm1"], x)
    if mixer == "attn":
        y, c = attn.apply_attn_decode(
            cfg, p["mixer"], h, cache["mixer"], pos, rope_cs=rope_cs, long=long,
        )
    elif mixer == "mla":
        y, c = mla_mod.apply_mla_decode(
            cfg, p["mixer"], h, cache["mixer"], pos, rope_cs=rope_cs, long=long,
        )
    else:
        y, c = mamba2.apply_mamba_decode(cfg, p["mixer"], h, cache["mixer"])
    new_cache = {"mixer": c}
    x = x + y.astype(x.dtype)
    if "cross" in p:
        hx = apply_norm(cfg, p["norm_x"], x)
        kv = cache["cross"]
        new_cache["cross"] = kv
        x = x + attn.apply_cross_attn(cfg, p["cross"], hx, kv).astype(x.dtype)
    if mlp != "none":
        h2 = apply_norm(cfg, p["norm2"], x)
        if mlp == "moe":
            y2, _ = moe_mod.apply_moe(cfg, p["mlp"], h2[:, None])
            y2 = y2[:, 0]
        else:
            y2 = apply_mlp(cfg, p["mlp"], h2)
        x = x + y2.astype(x.dtype)
    return x, new_cache


# ---------------------------------------------------------------------------
# Block groups (scan over stacked layers)
# ---------------------------------------------------------------------------


def block_schema(cfg: ModelConfig, bdef: BlockDef, cross: bool = False):
    unit = {
        f"l{i}": layer_schema(cfg, mixer, mlp, cross=cross)
        for i, (mixer, mlp) in enumerate(bdef.pattern)
    }
    return stack_schema(unit, bdef.repeat)


def block_cache_schema(
    cfg: ModelConfig, bdef: BlockDef, batch: int, max_seq: int, long: bool,
    cross: bool = False,
):
    unit = {
        f"l{i}": layer_cache_schema(cfg, mixer, batch, max_seq, long, cross)
        for i, (mixer, _) in enumerate(bdef.pattern)
    }
    return stack_schema(unit, bdef.repeat, axis_name="layers")


def apply_block_full(
    cfg: ModelConfig, bdef: BlockDef, params, x, *,
    rope_cs, causal=True, return_cache=False, long=False, enc_out=None,
    remat: str | None = None,
):
    """x (B,S,d) -> (x, stacked_caches|None, aux)."""

    def body(carry, layer_params):
        x, aux = carry
        caches = {}
        for i, (mixer, mlp) in enumerate(bdef.pattern):
            x, c, a = apply_layer_full(
                cfg, layer_params[f"l{i}"], x, mixer, mlp,
                rope_cs=rope_cs, causal=causal,
                return_cache=return_cache, long=long, enc_out=enc_out,
            )
            caches[f"l{i}"] = c
            aux = aux + a
        return (x, aux), caches

    body = remat_wrap(cfg, body, remat)
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params
    )
    return x, (caches if return_cache else None), aux


def apply_block_decode(
    cfg: ModelConfig, bdef: BlockDef, params, x, cache, pos, *,
    rope_cs, long=False,
):
    """fori_loop (not scan) over the stacked layers: the cache is a loop
    CARRY updated in place per layer, so the buffer aliases with the
    donated input.  A scan would emit the updated cache as stacked
    outputs (ys) — a full second cache allocation per decode step (+5 GiB
    on qwen2-72b decode) and a full extra copy of HBM traffic."""

    def body(i, carry):
        x, cache = carry
        layer_params = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            params,
        )
        layer_cache = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            cache,
        )
        new = {}
        for li, (mixer, mlp) in enumerate(bdef.pattern):
            x, nc = apply_layer_decode(
                cfg, layer_params[f"l{li}"], x, layer_cache[f"l{li}"], pos,
                mixer, mlp, rope_cs=rope_cs, long=long,
            )
            new[f"l{li}"] = nc
        cache = jax.tree.map(
            lambda c, n_: jax.lax.dynamic_update_index_in_dim(
                c, n_.astype(c.dtype), i, 0
            ),
            cache, new,
        )
        return x, cache

    x, new_cache = jax.lax.fori_loop(0, bdef.repeat, body, (x, cache))
    return x, new_cache
