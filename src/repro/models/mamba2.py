"""Mamba-2 mixer via SSD (state-space duality), TPU-adapted.

Train/prefill use the chunked SSD algorithm (arXiv:2405.21060 §6): the
sequence is cut into chunks of Q tokens; within a chunk the recurrence is
computed in attention form (MXU-dense Q×Q matmuls), across chunks a cheap
lax.scan carries the (H, N, P) state.  Decode is the O(1) recurrent
update — this is why the ssm/hybrid archs run the long_500k cell.

Projections are split per stream (z/x/B/C/dt) instead of one fused
in_proj so each shards independently on "model" (d_inner 16-way); the
depthwise causal conv is expressed as width-4 shifted adds (channel-
sharded, no halo).  kernels/ssd holds the Pallas intra-chunk kernel for
real TPU; this module is the portable/sharded formulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.sharding.rules import (
    ParamSpec,
    normal_param,
    param,
    scale_param,
    shard,
    zeros_param,
)


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    return s, d_in, H, s.n_groups, s.d_state, s.head_dim


def mamba_schema(cfg: ModelConfig):
    s, d_in, H, G, N, P = _dims(cfg)
    d = cfg.d_model
    pd = cfg.pdtype

    def dt_bias_init(key, shape, dtype):
        # dt in [dt_min, dt_max] at init (inverse-softplus of uniform draw)
        u = jax.random.uniform(key, shape, jnp.float32)
        dt = jnp.exp(
            u * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min)
        )
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)

    def a_log_init(key, shape, dtype):
        del key
        return jnp.log(jnp.arange(1, shape[0] + 1, dtype=jnp.float32)).astype(
            dtype
        )

    return {
        "wz": param((d, d_in), ("embed", "ssm_inner"), pd),
        "wx": param((d, d_in), ("embed", "ssm_inner"), pd),
        "wb": param((d, G * N), ("embed", None), pd),
        "wc": param((d, G * N), ("embed", None), pd),
        "wdt": param((d, H), ("embed", "ssm_heads"), pd),
        "conv_x": normal_param((s.d_conv, d_in), ("conv_w", "ssm_inner"), 0.1, pd),
        "conv_b": normal_param((s.d_conv, G * N), ("conv_w", None), 0.1, pd),
        "conv_c": normal_param((s.d_conv, G * N), ("conv_w", None), 0.1, pd),
        "conv_x_bias": zeros_param((d_in,), ("ssm_inner",), pd),
        "conv_b_bias": zeros_param((G * N,), (None,), pd),
        "conv_c_bias": zeros_param((G * N,), (None,), pd),
        "A_log": ParamSpec((H,), ("ssm_heads",), pd, a_log_init),
        "D": scale_param((H,), ("ssm_heads",), pd),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), pd, dt_bias_init),
        "norm": scale_param((d_in,), ("ssm_inner",), pd),
        "out": param((d_in, d), ("ssm_inner", "embed"), pd),
    }


def mamba_cache_schema(cfg: ModelConfig, batch: int):
    s, d_in, H, G, N, P = _dims(cfg)
    cw = s.d_conv - 1
    return {
        "conv_x": zeros_param((batch, cw, d_in), ("batch", "conv_w", "ssm_inner"), cfg.cdtype),
        "conv_b": zeros_param((batch, cw, G * N), ("batch", "conv_w", None), cfg.cdtype),
        "conv_c": zeros_param((batch, cw, G * N), ("batch", "conv_w", None), cfg.cdtype),
        "state": zeros_param(
            (batch, H, N, P), ("batch", "ssm_heads", "ssm_state", None),
            jnp.float32,
        ),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv as shifted adds.  x (B,S,C), w (W,C)."""
    W = w.shape[0]
    out = x * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(x[:, :-i], ((0, 0), (i, 0), (0, 0)))
        out = out + shifted * w[W - 1 - i]
    return out + b


def _conv_step(x_new: jax.Array, cache: jax.Array, w: jax.Array, b: jax.Array):
    """x_new (B,C); cache (B,W-1,C) previous raw inputs."""
    window = jnp.concatenate([cache, x_new[:, None]], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", window, w) + b
    return y, window[:, 1:]


def apply_mamba_full(
    cfg: ModelConfig,
    p,
    x: jax.Array,                 # (B, S, d)
    *,
    return_cache: bool = False,
):
    s, d_in, H, G, N, P = _dims(cfg)
    dt_c = cfg.cdtype
    B_, S, _ = x.shape
    x = x.astype(dt_c)
    z = x @ p["wz"].astype(dt_c)
    xs_raw = x @ p["wx"].astype(dt_c)
    b_raw = x @ p["wb"].astype(dt_c)
    c_raw = x @ p["wc"].astype(dt_c)
    dt_in = x @ p["wdt"].astype(dt_c)
    xs = jax.nn.silu(_causal_conv(xs_raw, p["conv_x"].astype(dt_c),
                                  p["conv_x_bias"].astype(dt_c)))
    bs = jax.nn.silu(_causal_conv(b_raw, p["conv_b"].astype(dt_c),
                                  p["conv_b_bias"].astype(dt_c)))
    cs = jax.nn.silu(_causal_conv(c_raw, p["conv_c"].astype(dt_c),
                                  p["conv_c_bias"].astype(dt_c)))
    xs = shard(xs.reshape(B_, S, H, P), "batch", None, "ssm_heads", None)
    bs = bs.reshape(B_, S, G, N)
    cs = cs.reshape(B_, S, G, N)
    dt = jax.nn.softplus(
        dt_in.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                       # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,)
    dA = dt * A                                             # (B,S,H) <= 0

    y, final_state = ssd_chunked(
        xs, bs, cs, dt, dA, chunk=min(s.chunk, S), n_heads=H,
    )
    y = y + xs * p["D"].astype(dt_c)[None, None, :, None]
    y = y.reshape(B_, S, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out"].astype(dt_c)
    out = shard(out, "batch", None, "d_model")
    if return_cache:
        cw = s.d_conv - 1
        cache = {
            "conv_x": xs_raw[:, -cw:],
            "conv_b": b_raw[:, -cw:],
            "conv_c": c_raw[:, -cw:],
            "state": final_state,
        }
        return out, cache
    return out, None


def ssd_chunked(xs, bs, cs, dt, dA, *, chunk: int, n_heads: int):
    """Chunked SSD.  xs (B,S,H,P), bs/cs (B,S,G,N), dt/dA (B,S,H).

    Returns y (B,S,H,P) and final state (B,H,N,P) fp32.
    """
    B_, S, H, P = xs.shape
    G, N = bs.shape[2], bs.shape[3]
    rep = H // G
    pad = (-S) % chunk
    if pad:
        # zero-pad is exact: dA=0 -> decay exp(0)=1, x*dt=0 -> no input
        zseq = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xs, bs, cs, dt, dA = map(zseq, (xs, bs, cs, dt, dA))
    Sp = S + pad
    nc = Sp // chunk
    Q = chunk
    dt_c = xs.dtype

    xc = xs.reshape(B_, nc, Q, H, P)
    bc = jnp.repeat(bs.reshape(B_, nc, Q, G, N), rep, axis=3)   # (B,nc,Q,H,N)
    cc = jnp.repeat(cs.reshape(B_, nc, Q, G, N), rep, axis=3)
    dtc = dt.reshape(B_, nc, Q, H)
    dAc = dA.reshape(B_, nc, Q, H)
    csum = jnp.cumsum(dAc, axis=2)                              # (B,nc,Q,H)

    xdt = (xc.astype(jnp.float32) * dtc[..., None]).astype(dt_c)
    # intra-chunk (attention form)
    cb = jnp.einsum("bcqhn,bcthn->bchqt", cc, bc,
                    preferred_element_type=jnp.float32)
    diff = csum[:, :, :, None, :] - csum[:, :, None, :, :]      # (B,nc,Q,Q,H)
    diff = jnp.moveaxis(diff, -1, 2)                            # (B,nc,H,Q,Q)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    y_intra = jnp.einsum(
        "bchqt,bcthp->bcqhp", (cb * decay).astype(dt_c), xdt
    )
    # chunk states
    to_end = jnp.exp(csum[:, :, -1:, :] - csum)                 # (B,nc,Q,H)
    states = jnp.einsum(
        "bcthn,bcthp->bchnp",
        (bc.astype(jnp.float32) * to_end[..., None]).astype(dt_c), xdt,
        preferred_element_type=jnp.float32,
    )                                                           # (B,nc,H,N,P)
    chunk_decay = jnp.exp(csum[:, :, -1, :])                    # (B,nc,H)

    def scan_body(h, inp):
        st, cd = inp                                            # (B,H,N,P),(B,H)
        h_next = h * cd[..., None, None] + st.astype(jnp.float32)
        return h_next, h                                        # emit h_prev

    h0 = jnp.zeros((B_, H, N, P), jnp.float32)
    final, h_prevs = jax.lax.scan(
        scan_body, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prevs, 0, 1)                        # (B,nc,H,N,P)
    c_in = (cc.astype(jnp.float32) * jnp.exp(csum)[..., None]).astype(dt_c)
    y_inter = jnp.einsum(
        "bcqhn,bchnp->bcqhp", c_in, h_prev.astype(dt_c)
    )
    y = (y_intra + y_inter).reshape(B_, Sp, H, P)
    return (y[:, :S] if pad else y), final


def apply_mamba_decode(
    cfg: ModelConfig,
    p,
    x: jax.Array,                 # (B, d)
    cache,
):
    s, d_in, H, G, N, P = _dims(cfg)
    dt_c = cfg.cdtype
    B_ = x.shape[0]
    x = x.astype(dt_c)
    z = x @ p["wz"].astype(dt_c)
    x_raw = x @ p["wx"].astype(dt_c)
    b_raw = x @ p["wb"].astype(dt_c)
    c_raw = x @ p["wc"].astype(dt_c)
    dt_in = x @ p["wdt"].astype(dt_c)
    xs, conv_x = _conv_step(x_raw, cache["conv_x"], p["conv_x"].astype(dt_c),
                            p["conv_x_bias"].astype(dt_c))
    bs, conv_b = _conv_step(b_raw, cache["conv_b"], p["conv_b"].astype(dt_c),
                            p["conv_b_bias"].astype(dt_c))
    cs, conv_c = _conv_step(c_raw, cache["conv_c"], p["conv_c"].astype(dt_c),
                            p["conv_c_bias"].astype(dt_c))
    xs, bs, cs = jax.nn.silu(xs), jax.nn.silu(bs), jax.nn.silu(cs)
    xs = xs.reshape(B_, H, P)
    bs = jnp.repeat(bs.reshape(B_, G, N), H // G, axis=1)       # (B,H,N)
    cs = jnp.repeat(cs.reshape(B_, G, N), H // G, axis=1)
    dt = jax.nn.softplus(
        dt_in.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                           # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                        # (B,H)
    h = cache["state"]                                          # (B,H,N,P) f32
    upd = jnp.einsum("bhn,bhp->bhnp", bs.astype(jnp.float32),
                     (xs.astype(jnp.float32) * dt[..., None]))
    h = h * dA[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", cs.astype(jnp.float32), h).astype(dt_c)
    y = y + xs * p["D"].astype(dt_c)[None, :, None]
    y = y.reshape(B_, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out"].astype(dt_c)
    new_cache = {
        "conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c, "state": h,
    }
    return out, new_cache
