"""Public model API: schema / loss / prefill / decode for every arch.

All functions are pure and jit-friendly; params/caches are plain pytrees
described by ParamSpec schemas (sharding/rules.py), so the same code path
serves CPU smoke tests, the 256-chip dry-run and elastic re-meshes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec
from repro.models.layers import (
    apply_norm,
    embed_schema,
    embed_tokens,
    mrope_cos_sin,
    norm_schema,
    rope_cos_sin,
    sinusoidal_positions,
    unembed,
)
from repro.models.transformer import (
    apply_block_decode,
    apply_block_full,
    apply_layer_full,
    block_cache_schema,
    block_schema,
    layer_schema,
)
from repro.sharding.rules import count_params, param, shard

# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------


def schema(cfg: ModelConfig):
    s: dict[str, Any] = dict(embed_schema(cfg))
    cross = cfg.cross_attention
    for i, bdef in enumerate(cfg.blocks):
        s[f"b{i}"] = block_schema(cfg, bdef, cross=cross)
    s["final_norm"] = norm_schema(cfg)
    if cfg.encoder_layers:
        s["encoder"] = encdec.encoder_schema(cfg)
    if cfg.mtp:
        mixer = "mla" if cfg.mla is not None else "attn"
        s["mtp"] = {
            "norm_h": norm_schema(cfg),
            "norm_e": norm_schema(cfg),
            "proj": param(
                (2 * cfg.d_model, cfg.d_model), (None, "d_model"), cfg.pdtype
            ),
            "layer": layer_schema(cfg, mixer, "dense"),
            "final_norm": norm_schema(cfg),
        }
    return s


def cache_schema(cfg: ModelConfig, batch: int, max_seq: int):
    long = batch < 8  # batch-1 long-context cells shard cache over data+model
    return {
        f"b{i}": block_cache_schema(
            cfg, bdef, batch, max_seq, long, cross=cfg.cross_attention
        )
        for i, bdef in enumerate(cfg.blocks)
    }


def param_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) parameter counts."""
    total = count_params(schema(cfg))
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        n_moe_layers = sum(
            b.repeat * sum(1 for _, mlp in b.pattern if mlp == "moe")
            for b in cfg.blocks
        )
        per_expert = 3 * cfg.d_model * m.d_ff
        active -= n_moe_layers * per_expert * (m.num_experts - m.top_k)
    return total, active


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def _rope_dim(cfg: ModelConfig) -> int:
    if cfg.mla is not None:
        return cfg.mla.qk_rope_head_dim
    return cfg.head_dim


def rope_full(cfg: ModelConfig, S: int, positions=None):
    """cos/sin for a full sequence, shaped to broadcast with (B,S,H,D)."""
    if cfg.rope_type == "none":
        return None
    dim = _rope_dim(cfg)
    if cfg.rope_type == "mrope":
        cos, sin = mrope_cos_sin(positions, dim, cfg.rope_theta,
                                 cfg.mrope_sections)        # (B,S,D2)
        return cos[:, :, None, :], sin[:, :, None, :]
    pos = jnp.arange(S) if positions is None else positions
    cos, sin = rope_cos_sin(pos, dim, cfg.rope_theta)       # (S,D2)
    return cos[None, :, None, :], sin[None, :, None, :]


def rope_decode(cfg: ModelConfig, pos, positions=None):
    if cfg.rope_type == "none":
        return None
    dim = _rope_dim(cfg)
    if cfg.rope_type == "mrope":
        cos, sin = mrope_cos_sin(positions[:, :, None], dim, cfg.rope_theta,
                                 cfg.mrope_sections)        # (B,1,D2)
        return cos[:, :, None, :], sin[:, :, None, :]       # (B,1,1,D2)
    cos, sin = rope_cos_sin(pos[None], dim, cfg.rope_theta)  # (1,D2)
    return cos[None], sin[None]                              # (1,1,D2)


def _inputs_to_x(cfg: ModelConfig, params, batch_inputs, S: int):
    if cfg.input_mode == "embeds" and "embeds" in batch_inputs:
        x = batch_inputs["embeds"].astype(cfg.cdtype)
    else:
        x = embed_tokens(cfg, params, batch_inputs["tokens"])
    if cfg.pos_embed == "sinusoidal":
        x = x + sinusoidal_positions(S, cfg.d_model).astype(cfg.cdtype)
    return shard(x, "batch", "seq_res", "d_model")


def backbone_full(
    cfg: ModelConfig, params, x, *, rope_cs, return_cache=False, long=False,
    enc_out=None, remat: str | None = None,
):
    aux = jnp.zeros((), jnp.float32)
    caches = {}
    for i, bdef in enumerate(cfg.blocks):
        x, c, a = apply_block_full(
            cfg, bdef, params[f"b{i}"], x,
            rope_cs=rope_cs, causal=True, return_cache=return_cache,
            long=long, enc_out=enc_out, remat=remat,
        )
        caches[f"b{i}"] = c
        aux = aux + a
    return x, (caches if return_cache else None), aux


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------


def chunked_xent(
    cfg: ModelConfig, params, h: jax.Array, labels: jax.Array,
    mask: jax.Array, loss_chunk: int = 512,
):
    """Memory-bounded cross-entropy: scan over sequence chunks so the
    (tokens, vocab) fp32 logits never materialize at once.  Returns
    (sum_nll, sum_mask)."""
    B, S, d = h.shape

    def piece(h_c, lab_c, m_c):
        logits = unembed(cfg, params, h_c)                   # (B,c,V) fp32
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(
            logits, lab_c[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        return jnp.sum((lse - lab) * m_c), jnp.sum(m_c)

    if S <= loss_chunk:
        return piece(h, labels, mask)
    assert S % loss_chunk == 0, (S, loss_chunk)
    nc = S // loss_chunk
    hs = jnp.moveaxis(h.reshape(B, nc, loss_chunk, d), 1, 0)
    # keep the batch dim sharded through the chunk scan — without the
    # constraint GSPMD replicates the full (B,S,d) hidden per device
    hs = shard(hs, None, "batch", None, None)
    ls = jnp.moveaxis(labels.reshape(B, nc, loss_chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, nc, loss_chunk), 1, 0)
    ls = shard(ls, None, "batch", None)
    ms = shard(ms, None, "batch", None)

    def body(acc, inp):
        nll, cnt = piece(*inp)
        return (acc[0] + nll, acc[1] + cnt), None

    (nll, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hs, ls, ms))
    return nll, cnt


def _shift_left(x: jax.Array, n: int = 1):
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, n)
    return jnp.pad(x[:, n:], pad)


def loss_fn(
    cfg: ModelConfig, params, batch, *, loss_chunk: int = 512,
    remat: str | None = None,
):
    tokens = batch["tokens"]
    B, S = tokens.shape
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    x = _inputs_to_x(cfg, params, batch, S)
    rope_cs = rope_full(cfg, S, batch.get("positions"))
    enc_out = None
    if cfg.cross_attention:
        enc_out = encdec.apply_encoder(cfg, params["encoder"],
                                       batch["enc_embeds"])
    h, _, aux = backbone_full(
        cfg, params, x, rope_cs=rope_cs, enc_out=enc_out, remat=remat,
    )
    h = apply_norm(cfg, params["final_norm"], h)
    labels = _shift_left(tokens)
    lmask = _shift_left(mask)
    nll, cnt = chunked_xent(cfg, params, h, labels, lmask, loss_chunk)
    metrics = {"nll_sum": nll, "token_count": cnt, "aux_loss": aux}
    loss = nll / jnp.maximum(cnt, 1.0) + aux

    if cfg.mtp:
        mp = params["mtp"]
        e_next = embed_tokens(cfg, params, _shift_left(tokens))
        x_mtp = jnp.concatenate(
            [apply_norm(cfg, mp["norm_h"], h),
             apply_norm(cfg, mp["norm_e"], e_next)], axis=-1
        ) @ mp["proj"].astype(cfg.cdtype)
        mixer = "mla" if cfg.mla is not None else "attn"
        x_mtp, _, _ = apply_layer_full(
            cfg, mp["layer"], x_mtp, mixer, "dense", rope_cs=rope_cs,
        )
        h_mtp = apply_norm(cfg, mp["final_norm"], x_mtp)
        labels2 = _shift_left(tokens, 2)
        lmask2 = _shift_left(mask, 2)
        nll2, cnt2 = chunked_xent(cfg, params, h_mtp, labels2, lmask2,
                                  loss_chunk)
        mtp_loss = nll2 / jnp.maximum(cnt2, 1.0)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + cfg.mtp_weight * mtp_loss

    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params, inputs, max_seq: int | None = None):
    """inputs: tokens/embeds (+positions/enc_embeds).  Returns
    (last_token_logits (B,V) fp32, cache)."""
    if cfg.input_mode == "embeds" and "embeds" in inputs:
        B, S = inputs["embeds"].shape[:2]
    else:
        B, S = inputs["tokens"].shape
    long = B < 8
    x = _inputs_to_x(cfg, params, inputs, S)
    rope_cs = rope_full(cfg, S, inputs.get("positions"))
    enc_out = None
    if cfg.cross_attention:
        enc_out = encdec.apply_encoder(cfg, params["encoder"],
                                       inputs["enc_embeds"])
    h, caches, _ = backbone_full(
        cfg, params, x, rope_cs=rope_cs, return_cache=True, long=long,
        enc_out=enc_out, remat="none",
    )
    h_last = apply_norm(cfg, params["final_norm"], h[:, -1])
    logits = unembed(cfg, params, h_last)
    if max_seq is not None and max_seq != S:
        from repro.sharding.rules import abstract_params

        target = abstract_params(cache_schema(cfg, B, max_seq))
        caches = pad_cache_to(caches, target)
    return logits, caches


def pad_cache_to(cache, target_abstract):
    """Zero-pad prefill caches out to the decode max_seq layout."""

    def pad(x, t):
        pads = [(0, ts - xs) for xs, ts in zip(x.shape, t.shape)]
        if any(p[1] for p in pads):
            return jnp.pad(x, pads)
        return x

    return jax.tree.map(pad, cache, target_abstract)


def decode_step(cfg: ModelConfig, params, cache, inputs):
    """inputs: token (B,), pos (), [positions (B,3)].  Returns
    (logits (B,V) fp32, new_cache)."""
    token, pos = inputs["token"], inputs["pos"]
    B = token.shape[0]
    # infer long-context layout from the cache itself
    long = B < 8
    x = embed_tokens(cfg, params, token)
    if cfg.pos_embed == "sinusoidal":
        # table lookup at dynamic position
        max_seq = _cache_max_seq(cfg, cache)
        tab = sinusoidal_positions(max_seq, cfg.d_model).astype(cfg.cdtype)
        x = x + jax.lax.dynamic_index_in_dim(tab, pos, keepdims=False)
    rope_cs = rope_decode(cfg, pos, inputs.get("positions"))
    new_cache = {}
    for i, bdef in enumerate(cfg.blocks):
        x, nc = apply_block_decode(
            cfg, bdef, params[f"b{i}"], x, cache[f"b{i}"], pos,
            rope_cs=rope_cs, long=long,
        )
        new_cache[f"b{i}"] = nc
    h = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params, h)
    return logits, new_cache


def _cache_max_seq(cfg: ModelConfig, cache) -> int:
    # self-attention K cache: (layers, B, S, KH, Dh) / MLA ckv (layers, B, S, R)
    leaves = jax.tree.leaves(cache["b0"])
    return max(l.shape[2] for l in leaves if l.ndim >= 3)
