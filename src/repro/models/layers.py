"""Shared layers: norms, MLPs, rotary embeddings (incl. M-RoPE), embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.rules import normal_param, param, scale_param, shard

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_schema(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {
            "scale": scale_param((d,), ("d_model",), cfg.pdtype),
            "bias": normal_param((d,), ("d_model",), 0.0, cfg.pdtype),
        }
    return {"scale": scale_param((d,), ("d_model",), cfg.pdtype)}


def apply_norm(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / squared-ReLU / GELU)
# ---------------------------------------------------------------------------


def mlp_schema(cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s = {"down": param((f, d), ("mlp", "embed"), cfg.pdtype)}
    if cfg.mlp_act == "swiglu":
        s["gate"] = param((d, f), ("embed", "mlp"), cfg.pdtype)
        s["up"] = param((d, f), ("embed", "mlp"), cfg.pdtype)
    else:
        s["up"] = param((d, f), ("embed", "mlp"), cfg.pdtype)
    return s


def apply_mlp(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    dt = cfg.cdtype
    x = x.astype(dt)
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(x @ p["gate"].astype(dt)) * (x @ p["up"].astype(dt))
    elif cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["up"].astype(dt)))
    else:  # gelu
        h = jax.nn.gelu(x @ p["up"].astype(dt), approximate=True)
    h = shard(h, "batch", *(None,) * (h.ndim - 2), "mlp")
    return h @ p["down"].astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE) and sinusoidal absolute positions
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def rope_cos_sin(positions: jax.Array, dim: int, theta: float):
    """positions (...,) -> cos/sin (..., dim/2), float32."""
    freqs = rope_freqs(dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def _mrope_select(ang: jax.Array, sections) -> jax.Array:
    """ang (B,3,S,D2) -> (B,S,D2) picking t/h/w section per freq index."""
    secs = np.asarray(sections)
    sel = jnp.asarray(np.repeat(np.arange(3), secs))  # (D2,)
    onehot = jax.nn.one_hot(sel, 3, dtype=ang.dtype)  # (D2, 3)
    return jnp.einsum("bksd,dk->bsd", ang, onehot)


def mrope_cos_sin(positions: jax.Array, dim: int, theta: float, sections):
    """M-RoPE (Qwen2-VL): positions (B, 3, S) -> cos/sin (B, S, dim/2);
    rotary freq indices are split into temporal/height/width sections
    (half-dim units summing to dim/2), each driven by its own position row.
    """
    assert int(np.sum(np.asarray(sections))) == dim // 2, (sections, dim)
    freqs = rope_freqs(dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,3,S,D2)
    ang = _mrope_select(ang, sections)  # (B,S,D2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, D); cos/sin broadcastable to (..., S, 1, D/2).

    Uses the llama 'rotate-half' convention on (even, odd) pairs split as
    first/second halves.
    """
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out1 = xf1 * cos - xf2 * sin
    out2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal table (n, d), float32."""
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = np.exp(-np.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32
    )


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_schema(cfg: ModelConfig):
    s = {}
    V, d = cfg.vocab_size, cfg.d_model
    # embeds-mode (VLM) still needs the token table: text tokens at decode
    s["embed"] = normal_param((V, d), ("vocab", "d_model"), 0.02, cfg.pdtype)
    if not cfg.tie_embeddings:
        s["unembed"] = normal_param(
            (d, V), ("d_model", "vocab"), 0.02, cfg.pdtype
        )
    return s


def embed_tokens(cfg: ModelConfig, p, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["embed"], tokens, axis=0)
    return x.astype(cfg.cdtype)


def unembed(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    """x (..., d) -> logits (..., V), fp32, vocab-sharded."""
    if cfg.tie_embeddings:
        w = p["embed"].astype(cfg.cdtype).T
    else:
        w = p["unembed"].astype(cfg.cdtype)
    logits = (x.astype(cfg.cdtype) @ w).astype(jnp.float32)
    return logits
