"""Whisper-style encoder (conv frontend stubbed to frame embeddings)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import BlockDef, ModelConfig
from repro.models.layers import apply_norm, norm_schema, sinusoidal_positions
from repro.models.transformer import apply_block_full, block_schema


def encoder_schema(cfg: ModelConfig):
    bdef = BlockDef(pattern=(("attn", "dense"),), repeat=cfg.encoder_layers)
    return {
        "blocks": block_schema(cfg, bdef),
        "final_norm": norm_schema(cfg),
    }


def apply_encoder(cfg: ModelConfig, p, enc_embeds: jax.Array) -> jax.Array:
    """enc_embeds (B, F, d) stub frame embeddings -> encoder states."""
    bdef = BlockDef(pattern=(("attn", "dense"),), repeat=cfg.encoder_layers)
    F = enc_embeds.shape[1]
    x = enc_embeds.astype(cfg.cdtype)
    x = x + sinusoidal_positions(F, cfg.d_model).astype(cfg.cdtype)
    x, _, _ = apply_block_full(
        cfg, bdef, p["blocks"], x, rope_cs=None, causal=False,
    )
    return apply_norm(cfg, p["final_norm"], x)
