"""GQA/MHA attention: chunked (flash-style) prefill/train + cached decode.

Two compute layouts, chosen per phase:

* train/prefill: KV heads are broadcast to the full head count so the head
  dim shards 16-way on "model" (MXU-dense); queries are processed in
  ``query_chunk`` blocks via lax.scan so the (S, S) score matrix is never
  materialized — the XLA-level equivalent of flash attention.  The Pallas
  kernel in kernels/flash_attention is the TPU hot path; this is the
  portable/sharded formulation the dry-run lowers.

* decode: factored (kv_head, group) layout with the KV cache *sequence*
  dim sharded on "model" (flash-decode): GQA archs have kv_heads (4-8) <
  model-parallel degree (16), so head-sharding cannot scale — seq-sharding
  can.  Softmax/combine over the sharded seq dim lowers to small
  all-reduces of per-head statistics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope
from repro.sharding.rules import param, shard, zeros_param

NEG_INF = -1e30


def attn_schema(cfg: ModelConfig, cross: bool = False):
    d, H, KH, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = {
        "wq": param((d, H, Dh), ("embed", "heads", "head_dim"), cfg.pdtype),
        "wk": param((d, KH, Dh), ("embed", "kv_heads", "head_dim"), cfg.pdtype),
        "wv": param((d, KH, Dh), ("embed", "kv_heads", "head_dim"), cfg.pdtype),
        "wo": param((H, Dh, d), ("heads", "head_dim", "embed"), cfg.pdtype),
    }
    return s


def attn_cache_schema(cfg: ModelConfig, batch: int, max_seq: int, long: bool):
    KH, Dh = cfg.num_kv_heads, cfg.head_dim
    seq_ax = "kv_seq_long" if long else "kv_seq"
    axes = ("batch", seq_ax, "kv_heads", "head_dim")
    return {
        "k": zeros_param((batch, max_seq, KH, Dh), axes, cfg.cdtype),
        "v": zeros_param((batch, max_seq, KH, Dh), axes, cfg.cdtype),
    }


def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(scores / cap)
    return scores


def chunked_attention(
    q: jax.Array,           # (B, Sq, H, Dh)
    k: jax.Array,           # (B, Sk, KH, Dh)
    v: jax.Array,
    *,
    query_chunk: int,
    causal: bool,
    softcap: float = 0.0,
) -> jax.Array:
    """Exact attention, scanned over query chunks (per-chunk full softmax)."""
    B, Sq, H, Dh = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    rep = H // KH
    scale = Dh ** -0.5
    if rep > 1:
        # broadcast KV heads so the full H dim shards on "model"
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    kpos = jnp.arange(Sk)

    def one_chunk(q_chunk: jax.Array, q_start: jax.Array) -> jax.Array:
        # q_chunk (B, C, H, Dh)
        C = q_chunk.shape[1]
        scores = jnp.einsum(
            "bqhd,bkhd->bhqk", q_chunk, k, preferred_element_type=jnp.float32
        ) * scale
        scores = _softcap(scores, softcap)
        if causal:
            qpos = q_start + jnp.arange(C)
            mask = kpos[None, :] <= qpos[:, None]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q_chunk.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        return shard(out, "batch", None, "heads", None)

    if Sq <= query_chunk:
        return one_chunk(q, jnp.asarray(0, jnp.int32))

    pad = (-Sq) % query_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (Sq + pad) // query_chunk
    qs = jnp.moveaxis(q.reshape(B, nc, query_chunk, H, Dh), 1, 0)

    def body(_, inp):
        i, q_chunk = inp
        return None, one_chunk(q_chunk, i * query_chunk)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nc), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq + pad, H, Dv)
    return out[:, :Sq] if pad else out


def apply_attn_full(
    cfg: ModelConfig,
    p,
    x: jax.Array,                 # (B, S, d)
    *,
    rope_cs=None,                 # (cos, sin) broadcastable to (B?,S,1,D/2)
    causal: bool = True,
    return_cache: bool = False,
    long: bool = False,
):
    """Train / prefill attention over a full sequence."""
    dt = cfg.cdtype
    x = x.astype(dt)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    kk = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    vv = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    q = shard(q, "batch", None, "heads", None)
    if rope_cs is not None:
        cos, sin = rope_cs
        q = apply_rope(q, cos, sin)
        kk = apply_rope(kk, cos, sin)
    out = chunked_attention(
        q, kk, vv,
        query_chunk=cfg.query_chunk,
        causal=causal,
        softcap=cfg.attn_logit_softcap,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    y = shard(y, "batch", None, "d_model")
    if return_cache:
        seq_ax = "kv_seq_long" if long else "kv_seq"
        cache = {
            "k": shard(kk, "batch", seq_ax, "kv_heads", None),
            "v": shard(vv, "batch", seq_ax, "kv_heads", None),
        }
        return y, cache
    return y, None


def apply_attn_decode(
    cfg: ModelConfig,
    p,
    x: jax.Array,                 # (B, d) single new token
    cache,                        # {"k","v"}: (B, Smax, KH, Dh)
    pos: jax.Array,               # () int32 current position
    *,
    rope_cs=None,                 # cos/sin for the single position
    long: bool = False,
):
    dt = cfg.cdtype
    B = x.shape[0]
    H, KH, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rep = H // KH
    x = x.astype(dt)
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"].astype(dt))
    k_new = jnp.einsum("bd,dhk->bhk", x, p["wk"].astype(dt))
    v_new = jnp.einsum("bd,dhk->bhk", x, p["wv"].astype(dt))
    if rope_cs is not None:
        cos, sin = rope_cs  # (1 or B, 1, D/2)
        q = apply_rope(q[:, None], cos, sin)[:, 0]
        k_new = apply_rope(k_new[:, None], cos, sin)[:, 0]
    seq_ax = "kv_seq_long" if long else "kv_seq"
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new[:, None], pos, axis=1
    )
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new[:, None], pos, axis=1
    )
    k = shard(k, "batch", seq_ax, "kv_heads", None)
    v = shard(v, "batch", seq_ax, "kv_heads", None)
    Smax = k.shape[1]
    # factored GQA decode: q (B, KH, rep, Dh) vs seq-sharded cache
    qf = q.reshape(B, KH, rep, Dh)
    scores = jnp.einsum(
        "bgrd,bsgd->bgrs", qf, k, preferred_element_type=jnp.float32
    ) * (Dh ** -0.5)
    scores = _softcap(scores, cfg.attn_logit_softcap)
    valid = jnp.arange(Smax) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx = jnp.einsum("bgrs,bsgd->bgrd", probs, v).reshape(B, H, Dh)
    y = jnp.einsum("bhk,hkd->bd", ctx, p["wo"].astype(dt))
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_cache_schema(cfg: ModelConfig, batch: int):
    KH, Dh, F = cfg.num_kv_heads, cfg.head_dim, cfg.encoder_frames
    axes = ("batch", "frames", "kv_heads", "head_dim")
    return {
        "k": zeros_param((batch, F, KH, Dh), axes, cfg.cdtype),
        "v": zeros_param((batch, F, KH, Dh), axes, cfg.cdtype),
    }


def cross_kv(cfg: ModelConfig, p, enc_out: jax.Array):
    dt = cfg.cdtype
    e = enc_out.astype(dt)
    k = jnp.einsum("bfd,dhk->bfhk", e, p["wk"].astype(dt))
    v = jnp.einsum("bfd,dhk->bfhk", e, p["wv"].astype(dt))
    return {"k": k, "v": v}


def apply_cross_attn(
    cfg: ModelConfig,
    p,
    x: jax.Array,                 # (B, S, d) or (B, d)
    kv,                           # cross-KV cache {"k","v"} (B, F, KH, Dh)
):
    dt = cfg.cdtype
    single = x.ndim == 2
    if single:
        x = x[:, None]
    x = x.astype(dt)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    q = shard(q, "batch", None, "heads", None)
    out = chunked_attention(
        q, kv["k"], kv["v"], query_chunk=cfg.query_chunk, causal=False,
        softcap=cfg.attn_logit_softcap,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return y[:, 0] if single else y
