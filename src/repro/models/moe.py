"""Mixture-of-Experts with capacity-based top-k routing.

Baseline dispatch is the t5x/flaxformer einsum formulation — robust under
pjit (experts shard on "model", token groups on "data") — wrapped in a
lax.scan over token groups so the (tokens, E, C) dispatch tensors stay
bounded regardless of sequence length.  A sort/scatter-based dispatch
(dispatch="scatter") removes the one-hot einsum FLOPs and is the
documented hillclimb for the compute-bound MoE cells (EXPERIMENTS.md
§Perf); see apply_moe_scatter.

Routing: softmax router (fp32), top-k with normalized gates, per-group
expert capacity C = ceil(T·k·cf / E) rounded to a multiple of 4.
Aux losses: switch load-balance + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.sharding.rules import current_rules, normal_param, param, shard

# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


def moe_schema(cfg: ModelConfig):
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff, m.num_experts
    # EP layout: weights shard E over "data" (+ TP on f over "model");
    # the embed dim must NOT be FSDP-sharded (its all-gather per use is
    # exactly what EP removes) — axes pick that automatically since
    # "data" is taken by experts_ep.
    if m.ep_over_dp:
        # EP layout: E → "data", d → "model" (the all-to-all payload is
        # d-sliced), f replicated.  256-way sharded, never re-gathered.
        s = {
            "router": normal_param((d, E), ("embed", "experts"), 0.02,
                                   jnp.float32),
            "w_gate": param((E, d, f), ("experts_ep", "ep_embed", None),
                            cfg.pdtype),
            "w_up": param((E, d, f), ("experts_ep", "ep_embed", None),
                          cfg.pdtype),
            "w_down": param((E, f, d), ("experts_ep", None, "ep_embed"),
                            cfg.pdtype),
        }
    else:
        s = {
            "router": normal_param((d, E), ("embed", "experts"), 0.02,
                                   jnp.float32),
            "w_gate": param((E, d, f), ("experts", "embed", "mlp"),
                            cfg.pdtype),
            "w_up": param((E, d, f), ("experts", "embed", "mlp"),
                          cfg.pdtype),
            "w_down": param((E, f, d), ("experts", "mlp", "embed"),
                            cfg.pdtype),
        }
    if m.num_shared_experts:
        fs = m.num_shared_experts * f
        s["shared"] = {
            "gate": param((d, fs), ("embed", "mlp"), cfg.pdtype),
            "up": param((d, fs), ("embed", "mlp"), cfg.pdtype),
            "down": param((fs, d), ("mlp", "embed"), cfg.pdtype),
        }
    return s


def expert_capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts)
    c = max(4, c)
    return (c + 3) // 4 * 4


def _dp_size() -> int:
    rules = current_rules()
    if rules is None:
        return 1
    return rules.mesh_axis_size(("pod", "data"))


# ---------------------------------------------------------------------------
# Routing (shared by both dispatch paths)
# ---------------------------------------------------------------------------


def route(cfg: ModelConfig, p, x_f32: jax.Array):
    """x (..., T, d) fp32 -> (gate (...,T,k), idx (...,T,k), aux terms)."""
    m = cfg.moe
    logits = x_f32 @ p["router"].astype(jnp.float32)          # (...,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, m.top_k)                 # (...,T,k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    # aux losses
    mask = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32)  # (...,T,k,E)
    f_e = jnp.mean(jnp.sum(mask, axis=-2), axis=-2)           # (...,E) routed frac*k
    p_e = jnp.mean(probs, axis=-2)                            # (...,E)
    lb = m.num_experts * jnp.mean(jnp.sum(f_e / m.top_k * p_e, axis=-1))
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gate, idx, mask, lb, z


def _positions_in_expert(mask: jax.Array) -> jax.Array:
    """mask (..., T, k, E) one-hot -> position of each (t,k) within its
    expert queue, token-major priority.  Returns (..., T, k)."""
    shp = mask.shape
    T, K, E = shp[-3], shp[-2], shp[-1]
    flat = mask.reshape(*shp[:-3], T * K, E)
    pos_e = jnp.cumsum(flat, axis=-2) - flat                  # count before
    pos = jnp.sum(pos_e * flat, axis=-1)                      # (..., T*K)
    return pos.reshape(*shp[:-3], T, K)


# ---------------------------------------------------------------------------
# Einsum (t5x-style) dispatch — baseline
# ---------------------------------------------------------------------------


def _moe_group_einsum(cfg: ModelConfig, p, x_g: jax.Array, C: int):
    """x_g (G, T, d) -> (y (G, T, d), lb, z).  G is data-sharded.

    ep_over_dp=False (baseline): experts shard on "model" only; with FSDP
    ("embed"→data) the expert weights are re-gathered over "data" at every
    use — the dominant collective in the MoE train baselines.

    ep_over_dp=True (hillclimb A): the dispatched token tensor is
    resharded with experts over ("data","model") — an all-to-all — and
    the expert weights stay fully sharded: no weight gathers, and expert
    weight grads are complete locally (every token using expert e visits
    its owner), so they need no cross-device reduction either.
    """
    m = cfg.moe
    dt = cfg.cdtype
    gate, idx, mask, lb, z = route(cfg, p, x_g.astype(jnp.float32))
    pos = _positions_in_expert(mask)                          # (G,T,k)
    keep = (pos < C).astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.einsum("gtke,gtkc->gtec", mask, pos_oh).astype(dt)
    combine = jnp.einsum(
        "gtke,gtkc,gtk->gtec", mask, pos_oh, gate
    ).astype(dt)
    dispatch = shard(dispatch, "batch", None, "experts", None)
    combine = shard(combine, "batch", None, "experts", None)
    xe = jnp.einsum("gtd,gtec->gecd", x_g.astype(dt), dispatch)
    xe = shard(xe, "batch", "experts", None, None)
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt))
    ) * jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dt))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    ye = shard(ye, "batch", "experts", None, None)
    y = jnp.einsum("gecd,gtec->gtd", ye, combine)
    y = shard(y, "batch", None, None)
    return y, lb, z


# ---------------------------------------------------------------------------
# Sort/scatter dispatch — FLOP-free routing (hillclimb path)
# ---------------------------------------------------------------------------


def _moe_group_scatter(cfg: ModelConfig, p, x_g: jax.Array, C: int):
    """Same contract as _moe_group_einsum but routes by sort+gather/scatter:
    no (T,E,C) one-hot matmuls, so HLO FLOPs ≈ useful expert FLOPs."""
    m = cfg.moe
    dt = cfg.cdtype
    G, T, d = x_g.shape
    E, K = m.num_experts, m.top_k
    gate, idx, mask, lb, z = route(cfg, p, x_g.astype(jnp.float32))
    pos = _positions_in_expert(mask)                          # (G,T,K)
    keep = pos < C

    def per_group(xg, idxg, gateg, posg, keepg):
        # xg (T,d); idxg/gateg/posg/keepg (T,K)
        slot = jnp.where(keepg, idxg * C + posg, E * C)       # (T,K)
        slot_f = slot.reshape(T * K).astype(jnp.int32)
        src = jnp.repeat(jnp.arange(T), K)
        buf = jnp.zeros((E * C + 1, d), dt)
        buf = buf.at[slot_f].set(xg.astype(dt)[src], mode="drop",
                                 unique_indices=False)
        xe = buf[: E * C].reshape(E, C, d)
        h = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dt))
        ) * jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dt))
        ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))
        ye_f = ye.reshape(E * C, d)
        gath = jnp.take(ye_f, jnp.clip(slot_f, 0, E * C - 1), axis=0)
        gath = gath * (keepg.reshape(T * K, 1)).astype(dt)
        w = gateg.reshape(T * K, 1).astype(dt)
        y = jnp.zeros((T, d), dt).at[src].add(gath * w)
        return y

    y = jax.vmap(per_group)(x_g, idx, gate, pos, keep)
    return y, lb, z


_GROUP_FNS = {"einsum": _moe_group_einsum, "scatter": _moe_group_scatter}


# ---------------------------------------------------------------------------
# Expert-parallel path (explicit shard_map; hillclimb A)
# ---------------------------------------------------------------------------


def apply_moe_ep(cfg: ModelConfig, p, x: jax.Array):
    """EP over "data" with TP over "model", fully manual collectives.

    Per (data, model) rank: route locally (scatter dispatch — no one-hot
    einsum FLOPs), all_to_all the d-SLICED token payload to expert
    owners, expert matmuls with E→data / d→model weights (psum over
    "model" before the nonlinearity), d-sliced payload back via the
    reverse all_to_all, per-token combine, one small all-gather of the
    output d-slices.  Wire per layer ≈ slots·d/tp·2 (a2a) + slots·f
    (psum) + tokens·d (AG) — vs. the baseline's re-gather of the full
    expert bank over "data" every use.
    """
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import current_rules

    m = cfg.moe
    rules = current_rules()
    mesh = rules.mesh if rules is not None else None
    if mesh is None or "data" not in mesh.shape \
            or m.num_experts % mesh.shape["data"]:
        # no mesh context (CPU smoke) or indivisible: einsum fallback
        return _apply_moe_grouped(cfg, p, x)
    dp = mesh.shape["data"]
    tp = mesh.shape.get("model", 1)
    pods = mesh.shape.get("pod", 1)
    E, K = m.num_experts, m.top_k
    B, S, d = x.shape
    N = B * S
    dt = cfg.cdtype
    if N % (dp * pods) or d % tp:
        return _apply_moe_grouped(cfg, p, x)
    # manual over pod too (XLA's partitioner crashes on this region with
    # an auto pod axis); EP stays INTRA-pod — the slow DCI link never
    # carries dispatch traffic, matching the paper's placement principle
    Tl = N // (dp * pods)
    C = expert_capacity(Tl, cfg)
    dl = d // tp
    El = E // dp
    batch_axes = ("pod", "data") if pods > 1 else ("data",)

    def body(xl, router, wg, wu, wd):
        # xl (Tl, d); wg/wu (El, dl, f); wd (El, f, dl)
        gate, idx, mask, lb, z = route(
            cfg, {"router": router}, xl.astype(jnp.float32)
        )
        pos = _positions_in_expert(mask)                     # (Tl, K)
        keep = pos < C
        slot = jnp.where(keep, idx * C + pos, E * C)
        slot_f = slot.reshape(Tl * K).astype(jnp.int32)
        src = jnp.repeat(jnp.arange(Tl), K)
        j = jax.lax.axis_index("model")
        xsl = jax.lax.dynamic_slice_in_dim(
            xl.astype(dt), j * dl, dl, axis=1
        )                                                     # (Tl, dl)
        buf = jnp.zeros((E * C + 1, dl), dt)
        buf = buf.at[slot_f].set(xsl[src], mode="drop")[: E * C]
        buf = buf.reshape(E, C, dl)
        # token-major -> expert-major over the SAME shards
        xe = jax.lax.all_to_all(
            buf, "data", split_axis=0, concat_axis=1, tiled=True
        )                                                     # (El, dp*C, dl)
        # expert FFN: contraction dim d split over "model"
        hg = jax.lax.psum(
            jnp.einsum("ead,edf->eaf", xe, wg.astype(dt)), "model"
        )
        hu = jax.lax.psum(
            jnp.einsum("ead,edf->eaf", xe, wu.astype(dt)), "model"
        )
        h = jax.nn.silu(hg) * hu
        ye = jnp.einsum("eaf,efd->ead", h, wd.astype(dt))     # d-sliced out
        back = jax.lax.all_to_all(
            ye, "data", split_axis=1, concat_axis=0, tiled=True
        ).reshape(E * C, dl)                                  # my slots
        gath = jnp.take(back, jnp.clip(slot_f, 0, E * C - 1), axis=0)
        gath = gath * keep.reshape(Tl * K, 1).astype(dt)
        w = gate.reshape(Tl * K, 1).astype(dt)
        y_slice = jnp.zeros((Tl, dl), dt).at[src].add(gath * w)
        y = jax.lax.all_gather(y_slice, "model", axis=1, tiled=True)
        lb = jax.lax.pmean(lb, batch_axes)
        z = jax.lax.pmean(z, batch_axes)
        return y, lb, z

    y, lb, z = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None),                   # tokens
            P(None, None),                         # router (replicated in)
            P("data", "model", None),              # w_gate
            P("data", "model", None),              # w_up
            P("data", None, "model"),              # w_down
        ),
        out_specs=(P(batch_axes, None), P(), P()),
        axis_names=set(batch_axes) | {"model"},
        check_vma=False,
    )(
        x.reshape(N, d), p["router"], p["w_gate"], p["w_up"], p["w_down"]
    )
    y = y.reshape(B, S, d)
    return y, lb, z


# ---------------------------------------------------------------------------
# Top-level MoE layer
# ---------------------------------------------------------------------------


def apply_moe(cfg: ModelConfig, p, x: jax.Array):
    """x (B, S, d) -> (y (B, S, d), {"lb_loss", "z_loss"})."""
    m = cfg.moe
    if m.ep_over_dp:
        y, lb, z = apply_moe_ep(cfg, p, x)
    else:
        y, lb, z = _apply_moe_grouped(cfg, p, x)

    if m.num_shared_experts:
        dt = cfg.cdtype
        sp = p["shared"]
        hs = jax.nn.silu(x.astype(dt) @ sp["gate"].astype(dt)) * (
            x.astype(dt) @ sp["up"].astype(dt)
        )
        hs = shard(hs, "batch", None, "mlp")
        y = y + hs @ sp["down"].astype(dt)

    aux = {
        "lb_loss": m.router_aux_weight * lb,
        "z_loss": m.router_z_weight * z,
    }
    return y, aux


def _apply_moe_grouped(cfg: ModelConfig, p, x: jax.Array):
    m = cfg.moe
    B, S, d = x.shape
    N = B * S
    dp = _dp_size()
    xf = x.reshape(N, d)
    group_fn = _GROUP_FNS[m.dispatch]

    if N % dp or (N // dp) < 4:
        dp_g = 1
    else:
        dp_g = dp
    per_shard = N // dp_g
    g_eff = min(m.group_size, per_shard)
    n_iter = per_shard // g_eff
    if per_shard % g_eff:
        n_iter, g_eff = 1, per_shard
    C = expert_capacity(g_eff, cfg)

    # (N, d) -> (dp_g, n_iter, g_eff, d): shard-local contiguous rows
    xg = xf.reshape(dp_g, n_iter, g_eff, d)
    xg = shard(xg, "batch", None, None, None)

    if n_iter == 1:
        y, lb, z = group_fn(cfg, p, xg[:, 0], C)
        y = y[:, None]
    else:
        xs = jnp.moveaxis(xg, 1, 0)  # (n_iter, dp_g, g_eff, d)
        xs = shard(xs, None, "batch", None, None)

        def body(acc, x_it):
            y_it, lb_it, z_it = group_fn(cfg, p, x_it, C)
            return (acc[0] + lb_it, acc[1] + z_it), y_it

        (lb, z), ys = jax.lax.scan(body, (0.0, 0.0), xs)
        lb, z = lb / n_iter, z / n_iter
        y = jnp.moveaxis(ys, 0, 1)   # (dp_g, n_iter, g_eff, d)

    y = y.reshape(B, S, d)
    y = shard(y, "batch", None, "d_model")
    return y, lb, z
