"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill use the expanded (non-absorbed) form — compute-optimal for
long sequences and MXU-dense (128 full heads shard 16-way on "model").
Decode uses the *absorbed* form against the compressed latent cache
(c_kv: kv_lora_rank + shared rope head): per-token work contracts through
the 512-dim latent instead of 128 heads × 192 dims, and the cache is
~14x smaller than GQA-equivalent KV — this is MLA's contribution and the
reason deepseek decode cells are memory-light in the roofline table.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import NEG_INF, chunked_attention
from repro.models.layers import apply_rope, rms_norm
from repro.sharding.rules import param, scale_param, shard, zeros_param


def mla_schema(cfg: ModelConfig):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    s = {}
    if m.q_lora_rank:
        s["wq_a"] = param((d, m.q_lora_rank), ("embed", "q_lora"), cfg.pdtype)
        s["q_norm"] = scale_param((m.q_lora_rank,), ("q_lora",), cfg.pdtype)
        s["wq_b"] = param(
            (m.q_lora_rank, H, qk), ("q_lora", "heads", "head_dim"), cfg.pdtype
        )
    else:
        s["wq"] = param((d, H, qk), ("embed", "heads", "head_dim"), cfg.pdtype)
    s["wkv_a"] = param(
        (d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "kv_lora"),
        cfg.pdtype,
    )
    s["kv_norm"] = scale_param((m.kv_lora_rank,), ("kv_lora",), cfg.pdtype)
    s["wkv_b"] = param(
        (m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
        ("kv_lora", "heads", "head_dim"), cfg.pdtype,
    )
    s["wo"] = param(
        (H, m.v_head_dim, d), ("heads", "head_dim", "embed"), cfg.pdtype
    )
    return s


def mla_cache_schema(cfg: ModelConfig, batch: int, max_seq: int, long: bool):
    m = cfg.mla
    seq_ax = "kv_seq_long" if long else "kv_seq"
    return {
        "ckv": zeros_param(
            (batch, max_seq, m.kv_lora_rank), ("batch", seq_ax, "kv_lora"),
            cfg.cdtype,
        ),
        "kpe": zeros_param(
            (batch, max_seq, m.qk_rope_head_dim), ("batch", seq_ax, "rope"),
            cfg.cdtype,
        ),
    }


def _project_q(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    dt = cfg.cdtype
    m = cfg.mla
    if m.q_lora_rank:
        qa = x @ p["wq_a"].astype(dt)
        qa = rms_norm(qa, p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("...r,rhk->...hk", qa, p["wq_b"].astype(dt))
    else:
        q = jnp.einsum("...d,dhk->...hk", x, p["wq"].astype(dt))
    return q  # (..., H, nope+rope)


def apply_mla_full(
    cfg: ModelConfig,
    p,
    x: jax.Array,                  # (B, S, d)
    *,
    rope_cs,                       # (cos, sin) for positions (S,)
    causal: bool = True,
    return_cache: bool = False,
    long: bool = False,
):
    dt = cfg.cdtype
    m = cfg.mla
    x = x.astype(dt)
    q = _project_q(cfg, p, x)      # (B,S,H,nope+rope)
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    kv_a = x @ p["wkv_a"].astype(dt)          # (B,S,kv_lora+rope)
    ckv = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_pe = kv_a[..., m.kv_lora_rank:]         # (B,S,rope) shared head
    cos, sin = rope_cs
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe = apply_rope(k_pe[:, :, None], cos, sin)[:, :, 0]
    kv = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"].astype(dt))
    k_nope = kv[..., : m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim:]          # (B,S,H,v_dim)
    H = cfg.num_heads
    k_pe_h = jnp.broadcast_to(
        k_pe[:, :, None], (*k_pe.shape[:2], H, m.qk_rope_head_dim)
    )
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_full = jnp.concatenate([k_nope, k_pe_h], axis=-1)
    q_full = shard(q_full, "batch", None, "heads", None)
    k_full = shard(k_full, "batch", None, "heads", None)
    # pad v to qk dim? no — chunked_attention handles mismatched v dim via
    # separate einsum; here KH == H so rep == 1 and v dim is independent.
    out = chunked_attention(
        q_full, k_full, v,
        query_chunk=cfg.query_chunk, causal=causal,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    y = shard(y, "batch", None, "d_model")
    if return_cache:
        seq_ax = "kv_seq_long" if long else "kv_seq"
        cache = {
            "ckv": shard(ckv, "batch", seq_ax, None),
            "kpe": shard(k_pe, "batch", seq_ax, None),
        }
        return y, cache
    return y, None


def apply_mla_decode(
    cfg: ModelConfig,
    p,
    x: jax.Array,                  # (B, d)
    cache,                         # {"ckv": (B,Smax,R), "kpe": (B,Smax,rope)}
    pos: jax.Array,
    *,
    rope_cs,
    long: bool = False,
):
    dt = cfg.cdtype
    m = cfg.mla
    x = x.astype(dt)
    H = cfg.num_heads
    q = _project_q(cfg, p, x)      # (B,H,nope+rope)
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    cos, sin = rope_cs
    q_pe = apply_rope(q_pe[:, None], cos, sin)[:, 0]
    kv_a = x @ p["wkv_a"].astype(dt)
    ckv_new = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    kpe_new = apply_rope(kv_a[:, None, m.kv_lora_rank:], cos, sin)[:, 0]
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new[:, None], pos, axis=1
    )
    kpe = jax.lax.dynamic_update_slice_in_dim(
        cache["kpe"], kpe_new[:, None], pos, axis=1
    )
    seq_ax = "kv_seq_long" if long else "kv_seq"
    ckv = shard(ckv, "batch", seq_ax, None)
    kpe = shard(kpe, "batch", seq_ax, None)
    # absorbed attention in latent space
    w_uk = p["wkv_b"][..., : m.qk_nope_head_dim].astype(dt)   # (R,H,nope)
    w_uv = p["wkv_b"][..., m.qk_nope_head_dim:].astype(dt)    # (R,H,v)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope, w_uk)          # (B,H,R)
    scores = (
        jnp.einsum("bhr,bsr->bhs", q_lat, ckv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhk,bsk->bhs", q_pe, kpe,
                     preferred_element_type=jnp.float32)
    ) * ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
    Smax = ckv.shape[1]
    valid = jnp.arange(Smax) <= pos
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", probs, ckv)          # (B,H,R)
    ctx = jnp.einsum("bhr,rhv->bhv", ctx_lat, w_uv)           # (B,H,v)
    y = jnp.einsum("bhv,hvd->bd", ctx, p["wo"].astype(dt))
    return y, {"ckv": ckv, "kpe": kpe}
