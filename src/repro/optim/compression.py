"""Cross-pod gradient compression + two-level reduction.

The paper's core constraint is the *slow link between the two
environments* (cluster <-> cloud Ethernet; here: inter-pod DCI vs
intra-pod ICI).  Baseline SPMD lets XLA all-reduce gradients over
(pod, data) jointly — every gradient byte crosses DCI at fp32/bf16 width.
This module implements the beyond-paper optimization: gradients are
reduced over "data" (fast ICI) by XLA automatically, then exchanged
across the "pod" axis explicitly in int8 (blockwise absmax), cutting DCI
bytes 4x vs fp32 / 2x vs bf16.

Mechanically this relies on shard_map's `auto` axes: the train step runs
manual over "pod" only (each pod is a paper "environment"), automatic
over data/model, and calls `cross_pod_reduce` on the per-pod gradient
pytree.  For 2 pods the exchange is a single ppermute of int8 payloads +
local dequant-add, which keeps the wire format actually 8-bit (a psum of
dequantized values would not).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import axis_size

CBLOCK = 256


def _q8(x: jax.Array):
    """Blockwise int8 quantization (flattened blocks of CBLOCK)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % CBLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, CBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    return q, scale, n


def _dq8(q: jax.Array, scale: jax.Array, n: int, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape)


def cross_pod_reduce(grads, axis: str = "pod", method: str = "int8"):
    """All-reduce a gradient pytree over `axis` (manual shard_map axis).

    method="none": plain psum (baseline).
    method="int8": quantize -> exchange int8 via ppermute ring -> local
    dequant-add.  Exact for 2 pods; for P pods it performs P-1 ring hops
    (each hop re-quantizes its own share — bounded error, documented).
    """
    if method == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis), grads)

    def reduce_leaf(g):
        npods = axis_size(axis)
        acc = g.astype(jnp.float32)
        q, scale, n = _q8(g.astype(jnp.float32))
        for hop in range(1, npods):
            perm = [(i, (i + hop) % npods) for i in range(npods)]
            q_r = jax.lax.ppermute(q, axis, perm)
            s_r = jax.lax.ppermute(scale, axis, perm)
            acc = acc + _dq8(q_r, s_r, n, g.shape)
        return acc.astype(g.dtype)

    return jax.tree.map(reduce_leaf, grads)


def compressed_bytes(n_params: int) -> tuple[int, int]:
    """(wire bytes with int8, wire bytes with fp32) per pod-hop."""
    blocks = (n_params + CBLOCK - 1) // CBLOCK
    return n_params + 4 * blocks, 4 * n_params
