"""Optimizers: sharded AdamW (+int8 moments), Adafactor, schedules."""
from repro.optim.adamw import Optimizer, make_adamw
from repro.optim.adafactor import make_adafactor
from repro.optim.schedule import constant, warmup_cosine


def make_optimizer(name: str, lr_fn=None) -> Optimizer:
    if name == "adamw":
        return make_adamw(lr_fn=lr_fn)
    if name == "adamw8bit":
        return make_adamw(lr_fn=lr_fn, int8=True)
    if name == "adafactor":
        return make_adafactor(lr_fn=lr_fn)
    raise ValueError(f"unknown optimizer {name!r}")


__all__ = [
    "Optimizer",
    "constant",
    "make_adafactor",
    "make_adamw",
    "make_optimizer",
    "warmup_cosine",
]
