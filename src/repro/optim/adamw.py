"""Sharded AdamW with optional int8-quantized moments ("8-bit Adam").

Optimizer state is ZeRO-1 sharded: each moment tensor inherits its param's
sharding plus an extra "data"-axis split on the largest still-unsharded
divisible dim (sharding/rules.zero1 rule), so 100B+ states spread over the
full pod instead of the model-parallel group only.

int8 moments use blockwise (last-dim blocks of 128) absmax quantization —
state bytes drop 4x vs fp32, the dequant/requant is elementwise and fuses
into the update.  bf16 params keep an fp32 master copy unless the config
opts out (DeepSeek-V3 uses Adafactor instead; see optim/adafactor.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    state_schema: Callable[[Any], Any]   # ParamSpec pytree for dry-run/ckpt


def _quantizable(x_shape, x_size) -> bool:
    return len(x_shape) > 0 and x_size >= QBLOCK and x_shape[-1] % QBLOCK == 0


def _q8(x: jax.Array):
    """Blockwise signed linear int8 quantization (for the 1st moment).

    q keeps the ORIGINAL param shape (so it inherits the param's
    sharding); only the scale carries the block structure.
    """
    if not _quantizable(x.shape, x.size):
        return x.astype(jnp.float32), None
    shp = x.shape[:-1] + (x.shape[-1] // QBLOCK, QBLOCK)
    xb = x.reshape(shp)
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    q = jnp.round(xb / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    return q.reshape(x.shape), scale.astype(jnp.float32)


def _dq8(q, scale, shape):
    if scale is None:
        return q
    shp = shape[:-1] + (shape[-1] // QBLOCK, QBLOCK)
    return (q.reshape(shp).astype(jnp.float32) * scale).reshape(shape)


_VLOG_FLOOR = 1e-24


def _q8log(x: jax.Array):
    """Blockwise log-space uint8 quantization (for the 2nd moment).

    v spans many orders of magnitude within a block; linear absmax
    underflows small entries to 0 and the update m/(sqrt(v)+eps) blows up.
    Affine quantization of log(v) keeps ~0.4%-of-log-range relative
    precision across the whole block (the same reason bitsandbytes uses
    dynamic-exponent codes).
    """
    if not _quantizable(x.shape, x.size):
        return x.astype(jnp.float32), None, None
    shp = x.shape[:-1] + (x.shape[-1] // QBLOCK, QBLOCK)
    xl = jnp.log(x.reshape(shp) + _VLOG_FLOOR)
    lo = jnp.min(xl, axis=-1, keepdims=True)
    hi = jnp.max(xl, axis=-1, keepdims=True)
    span = jnp.maximum(hi - lo, 1e-6)
    q = jnp.round((xl - lo) / span * 255.0 - 128.0).astype(jnp.int8)
    return q.reshape(x.shape), lo.astype(jnp.float32), span.astype(jnp.float32)


def _dq8log(q, lo, span, shape):
    if lo is None:
        return q
    shp = shape[:-1] + (shape[-1] // QBLOCK, QBLOCK)
    xl = (q.reshape(shp).astype(jnp.float32) + 128.0) / 255.0 * span + lo
    return (jnp.exp(xl) - _VLOG_FLOOR).reshape(shape)


def make_adamw(
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    lr_fn: Callable[[jax.Array], jax.Array] | None = None,
    int8: bool = False,
    master_fp32: bool = True,
) -> Optimizer:
    lr_fn = lr_fn or (lambda step: 1e-4)

    def moment_init(p, log: bool = False):
        z = jnp.zeros(p.shape, jnp.float32)
        if int8:
            if log:
                q, lo, span = _q8log(z)
                if lo is not None:
                    return {"q": q, "lo": lo, "span": span}
                return {"q": q}
            q, s = _q8(z)
            return {"q": q, "scale": s} if s is not None else {"q": q}
        return z

    def init(params):
        state = {
            "m": jax.tree.map(moment_init, params),
            "v": jax.tree.map(lambda p: moment_init(p, log=True), params),
            "count": jnp.zeros((), jnp.int32),
        }
        if master_fp32 and any(
            l.dtype == jnp.bfloat16 for l in jax.tree.leaves(params)
        ):
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params
            )
        return state

    def _get_moment(st, shape):
        if isinstance(st, dict):
            if "lo" in st:
                return _dq8log(st["q"], st["lo"], st["span"], shape)
            return _dq8(st["q"], st.get("scale"), shape)
        return st

    def _set_moment(old, val):
        if isinstance(old, dict):
            if "lo" in old:
                q, lo, span = _q8log(val)
                return {"q": q, "lo": lo, "span": span}
            q, s = _q8(val)
            return {"q": q, "scale": s} if s is not None else {"q": q}
        return val

    def update(grads, state, params, step):
        count = state["count"] + 1
        lr = lr_fn(step)
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        masters = state.get("master", params)

        is_moment = lambda x: isinstance(x, dict) and "q" in x
        p_leaves, p_def = jax.tree.flatten(params)
        g_leaves = jax.tree.leaves(grads)
        m_leaves, m_def = jax.tree.flatten(state["m"], is_leaf=is_moment)
        v_leaves, _ = jax.tree.flatten(state["v"], is_leaf=is_moment)
        ma_leaves = jax.tree.leaves(masters)

        new_m, new_v, new_master = [], [], []
        for g, m_st, v_st, master in zip(
            g_leaves, m_leaves, v_leaves, ma_leaves
        ):
            g = g.astype(jnp.float32)
            m = b1 * _get_moment(m_st, g.shape) + (1 - b1) * g
            v = b2 * _get_moment(v_st, g.shape) + (1 - b2) * jnp.square(g)
            mh, vh = m / c1, v / c2
            base = master.astype(jnp.float32)
            new = base - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * base)
            new_m.append(_set_moment(m_st, m))
            new_v.append(_set_moment(v_st, v))
            new_master.append(new)

        new_params = [
            nm.astype(p.dtype) for nm, p in zip(new_master, p_leaves)
        ]
        new_state = {
            "m": jax.tree.unflatten(m_def, new_m),
            "v": jax.tree.unflatten(m_def, new_v),
            "count": count,
        }
        if "master" in state:
            new_state["master"] = jax.tree.unflatten(p_def, new_master)
        return jax.tree.unflatten(p_def, new_params), new_state

    def state_schema(param_schema):
        import numpy as np

        from repro.sharding.rules import ParamSpec, is_spec

        def moment_spec(ps: ParamSpec, log: bool = False):
            zero = lambda k, s, d: jnp.zeros(s, d)
            size = int(np.prod(ps.shape)) if ps.shape else 1
            if int8 and _quantizable(ps.shape, size):
                sshape = ps.shape[:-1] + (ps.shape[-1] // QBLOCK, 1)
                saxes = ps.axes[:-1] + (None, None)
                out = {"q": ParamSpec(ps.shape, ps.axes, jnp.int8, zero)}
                if log:
                    out["lo"] = ParamSpec(sshape, saxes, jnp.float32, zero)
                    out["span"] = ParamSpec(sshape, saxes, jnp.float32, zero)
                else:
                    out["scale"] = ParamSpec(sshape, saxes, jnp.float32, zero)
                return out
            return ParamSpec(ps.shape, ps.axes, jnp.float32, zero)

        sch = {
            "m": jax.tree.map(moment_spec, param_schema, is_leaf=is_spec),
            "v": jax.tree.map(lambda ps: moment_spec(ps, log=True),
                              param_schema, is_leaf=is_spec),
            "count": ParamSpec((), (), jnp.int32,
                               lambda k, s, d: jnp.zeros(s, d)),
        }
        if master_fp32 and any(
            s.dtype == jnp.bfloat16 for s in jax.tree.leaves(
                param_schema, is_leaf=is_spec)
        ):
            sch["master"] = jax.tree.map(
                lambda ps: ParamSpec(ps.shape, ps.axes, jnp.float32, ps.init),
                param_schema, is_leaf=is_spec,
            )
        return sch

    return Optimizer(init=init, update=update, state_schema=state_schema)
