"""Adafactor (factored second moments, momentum-free) — for the ≥200B MoE
archs whose AdamW state would not fit pod HBM (DESIGN.md §6).

Follows Shazeer & Stern 2018 / the t5x implementation: rank-1 factored
second-moment statistics for >=2D params, decay 1 - t^-0.8, RMS-scaled
update clipping, relative step sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


def make_adafactor(
    *,
    lr_fn=None,
    eps1: float = 1e-30,
    eps2: float = 1e-3,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    lr_fn = lr_fn or (lambda step: 1e-4)

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def st(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "stats": jax.tree.map(st, params),
            "count": jnp.zeros((), jnp.int32),
        }

    # leaves larger than this get their update lax.map'ed over the
    # stacked-layers dim: the fp32 staging copies of a multi-GB bf16
    # leaf otherwise dominate peak memory (EXPERIMENTS.md §Perf,
    # dsv3 train cell: ~50 GB of optimizer temporaries)
    CHUNK_BYTES = 1 << 28

    def update(grads, state, params, step):
        count = state["count"] + 1
        t = count.astype(jnp.float32)
        beta2 = 1.0 - t ** -0.8
        lr = lr_fn(step)
        is_stat = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)

        p_leaves, p_def = jax.tree.flatten(params)
        g_leaves = jax.tree.leaves(grads)
        s_leaves, s_def = jax.tree.flatten(state["stats"], is_leaf=is_stat)

        def upd_factored(p, g, vr_old, vc_old):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps1
            vr = beta2 * vr_old + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc_old + (1 - beta2) * jnp.mean(g2, axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps1)
            )[..., None]
            u = g * rfac * jax.lax.rsqrt(vc)[..., None, :]
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            base = p.astype(jnp.float32)
            scale = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(base))), eps2)
            newp = base - lr * scale * u - lr * weight_decay * base
            return newp.astype(p.dtype), vr, vc

        new_p, new_s = [], []
        for p, g, st in zip(p_leaves, g_leaves, s_leaves):
            if "vr" in st:
                if (p.ndim >= 3 and p.shape[0] > 1
                        and p.size * 4 > CHUNK_BYTES):
                    # stacked-layer leaf: update one layer slice at a
                    # time (fp32 temporaries shrink by the stack depth;
                    # RMS clip becomes per-layer, which is if anything
                    # better-behaved)
                    newp, vr, vc = jax.lax.map(
                        lambda args: upd_factored(*args),
                        (p, g, st["vr"], st["vc"]),
                    )
                else:
                    newp, vr, vc = upd_factored(p, g, st["vr"], st["vc"])
                new_s.append({"vr": vr, "vc": vc})
                new_p.append(newp)
                continue
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps1
            v = beta2 * st["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(v)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            base = p.astype(jnp.float32)
            scale = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(base))), eps2)
            newp = base - lr * scale * u - lr * weight_decay * base
            new_s.append({"v": v})
            new_p.append(newp.astype(p.dtype))

        return (
            jax.tree.unflatten(p_def, new_p),
            {"stats": jax.tree.unflatten(s_def, new_s), "count": count},
        )

    def state_schema(param_schema):
        from repro.sharding.rules import ParamSpec, is_spec

        def st(ps: ParamSpec):
            zero = lambda k, s, d: jnp.zeros(s, d)
            if _factored(ps.shape):
                return {
                    "vr": ParamSpec(ps.shape[:-1], ps.axes[:-1], jnp.float32,
                                    zero),
                    "vc": ParamSpec(ps.shape[:-2] + ps.shape[-1:],
                                    ps.axes[:-2] + ps.axes[-1:], jnp.float32,
                                    zero),
                }
            return {"v": ParamSpec(ps.shape, ps.axes, jnp.float32, zero)}

        return {
            "stats": jax.tree.map(st, param_schema, is_leaf=is_spec),
            "count": ParamSpec((), (), jnp.int32,
                               lambda k, s, d: jnp.zeros(s, d)),
        }

    return Optimizer(init=init, update=update, state_schema=state_schema)
