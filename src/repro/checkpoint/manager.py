"""Checkpointing: async, atomic, reshard-on-restore.

This is the paper's Fig.1 step 2 ("save current state") and steps 5-7
(move + assimilate + restart): a checkpoint written under one mesh can be
restored under a *different* mesh/sharding — jax.device_put with the new
NamedSharding performs the redistribution, which IS the burst's state
movement on real hardware.

Layout: <dir>/step_<n>/
          manifest.json        {step, leaf paths, shapes, dtypes, crcs,
                                extra}
          <leaf_key>.npy       one array per pytree leaf
Writes go to step_<n>.tmp and are atomically swapped in (the previous
generation is renamed aside to step_<n>.old for the instant of the
swap); a torn write is never visible, and a crash mid-save can never
leave a truncated latest checkpoint shadowing a good older one
(DESIGN.md §19).  Async mode pushes the host-side serialization to a
daemon thread (off the training critical path); save(wait=True) or
close() joins it.

Integrity (DESIGN.md §19): every leaf is stamped with a CRC-32 of its
serialized bytes at save time.  ``restore()`` verifies before trusting:
a generation whose bytes do not match its manifest is treated as
corrupt, and the default restore falls back to the newest *intact*
generation (``keep`` is floored to 2 so a fallback always has a
candidate).  When no generation verifies, ``NoIntactCheckpointError``
names every step tried.

A SIGTERM handler can be installed for preemption-triggered snapshots
(install_preemption_hook): save, then exit cleanly so the restart path
resumes bit-consistently from the snapshot.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import signal
import threading
import warnings
import zlib
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

_SEP = "__"


class NoIntactCheckpointError(RuntimeError):
    """Every on-disk checkpoint generation failed integrity
    verification (or none exists) — there is nothing safe to restore
    (DESIGN.md §19)."""


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path) or "root"
        out[key] = leaf
    return out


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, *, async_save: bool = True,
                 keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        # at least 2 generations: a corrupt latest must always leave an
        # older candidate for the integrity fallback (DESIGN.md §19)
        self.keep = max(keep, 2)
        self.async_save = async_save
        self._q: queue.Queue = queue.Queue()
        self._worker: threading.Thread | None = None
        self._pending = 0
        self._lock = threading.Lock()
        if async_save:
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    # ------------------------------------------------------------------ save

    def save(self, step: int, state, extra: dict | None = None,
             wait: bool = False):
        """Snapshot `state` (pytree of arrays) at `step`.

        Device arrays are fetched to host here (cheap vs serialization);
        file I/O happens on the worker thread in async mode.
        """
        host = {
            k: np.asarray(v) for k, v in _flatten(state).items()
        }
        job = (step, host, dict(extra or {}))
        if self.async_save and not wait:
            with self._lock:
                self._pending += 1
            self._q.put(job)
        else:
            # a sync save may target the same step as a queued async one
            # (periodic + final save); drain the worker first so both
            # never race on the same step_*.tmp staging dir
            self.wait()
            self._write(job)

    def wait(self):
        if self.async_save:
            self._q.join()

    def close(self):
        self.wait()

    def _run(self):
        while True:
            job = self._q.get()
            try:
                self._write(job)
            finally:
                with self._lock:
                    self._pending -= 1
                self._q.task_done()

    def _write(self, job):
        step, host, extra = job
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, arr in host.items():
            fname = f"{key}.npy"
            true_dtype = str(arr.dtype)
            if arr.dtype.kind not in "biufc":
                # ml_dtypes (bfloat16, float8_*): persist as raw bytes
                stored = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            else:
                stored = arr
            np.save(tmp / fname, stored, allow_pickle=False)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": true_dtype,
                # content checksum of the serialized bytes — what
                # restore() verifies before trusting this generation
                "crc32": zlib.crc32((tmp / fname).read_bytes()),
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # atomic swap: never rmtree the live generation before the new
        # one is in place — a crash between those two operations would
        # otherwise lose BOTH (DESIGN.md §19).  Rename the old aside,
        # move the new in (os.replace is atomic on one filesystem),
        # then drop the old.
        old = self.dir / f"step_{step:08d}.old"
        if old.exists():
            shutil.rmtree(old)
        if final.exists():
            os.replace(final, old)
        os.replace(tmp, final)
        if old.exists():
            shutil.rmtree(old)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix in (".tmp", ".old") \
                    or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify(self, step: int) -> bool:
        """True iff the generation at ``step`` passes integrity
        verification: readable manifest and every leaf's bytes matching
        its stamped CRC-32 (DESIGN.md §19).  Legacy manifests without
        checksums are trusted (there is nothing to verify against)."""
        d = self.dir / f"step_{step:08d}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
            for meta in manifest["leaves"].values():
                crc = meta.get("crc32")
                if crc is None:
                    continue
                if zlib.crc32((d / meta["file"]).read_bytes()) != crc:
                    return False
        except (OSError, ValueError, KeyError):
            return False
        return True

    def restore(self, target_state, step: int | None = None,
                shardings=None) -> tuple[Any, dict]:
        """Load into the structure of `target_state` (pytree of arrays or
        ShapeDtypeStructs).  `shardings` (matching pytree) redistributes
        each leaf onto the *current* mesh — restoring under a different
        mesh than the save is the supported path (that is the burst).

        With ``step=None`` (the default), generations are verified
        newest-first and the newest *intact* one is restored — a
        corrupt latest falls back with a warning instead of silently
        resuming from garbage (DESIGN.md §19).  An explicit ``step``
        that fails verification raises instead: the caller asked for
        that generation specifically.
        """
        if step is None:
            steps = self.all_steps()
            if not steps:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
            step = None
            for s in reversed(steps):
                if self.verify(s):
                    step = s
                    break
                warnings.warn(
                    f"checkpoint step {s} failed integrity verification;"
                    f" falling back to an older generation",
                    stacklevel=2,
                )
            if step is None:
                raise NoIntactCheckpointError(
                    f"no intact checkpoint in {self.dir}: every "
                    f"generation failed integrity verification "
                    f"(steps tried: {steps})"
                )
        elif not self.verify(step):
            raise NoIntactCheckpointError(
                f"checkpoint step {step} in {self.dir} failed "
                f"integrity verification"
            )
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_target = _flatten(target_state)
        flat_shardings = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key, meta in manifest["leaves"].items():
            if key not in flat_target:
                continue
            arr = np.load(d / meta["file"], allow_pickle=False)
            if str(arr.dtype) != meta["dtype"]:
                arr = arr.view(jax.numpy.dtype(meta["dtype"]))
            sh = flat_shardings.get(key)
            out[key] = (
                jax.device_put(arr, sh) if sh is not None
                else jax.numpy.asarray(arr)
            )
        missing = set(flat_target) - set(out)
        if missing:
            raise KeyError(f"checkpoint at step {step} missing leaves: "
                           f"{sorted(missing)[:5]}...")
        # rebuild the pytree in target structure
        leaves_order, treedef = jax.tree_util.tree_flatten_with_path(
            target_state
        )
        vals = []
        for path, _ in leaves_order:
            key = _SEP.join(_path_str(p) for p in path) or "root"
            vals.append(out[key])
        return (
            jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(target_state), vals
            ),
            manifest["extra"],
        )


def install_preemption_hook(save_fn: Callable[[], None], *,
                            exit_code: int | None = 143):
    """SIGTERM -> snapshot -> clean exit (DESIGN.md §19).

    The platform is reclaiming us: ``save_fn`` persists the snapshot,
    then the process exits with ``exit_code`` (default 143 = 128 +
    SIGTERM, the conventional "terminated" status) so the supervisor's
    restart path restores from it and resumes bit-consistently.  Pass
    ``exit_code=None`` to chain to Python's default KeyboardInterrupt
    behavior instead of exiting.  Returns the previous SIGTERM handler
    so callers (and tests) can restore it.
    """

    def handler(signum, frame):
        try:
            save_fn()
        finally:
            if exit_code is None:
                signal.default_int_handler(signum, frame)
            else:
                raise SystemExit(exit_code)

    return signal.signal(signal.SIGTERM, handler)
