"""Checkpointing: async, atomic, reshard-on-restore.

This is the paper's Fig.1 step 2 ("save current state") and steps 5-7
(move + assimilate + restart): a checkpoint written under one mesh can be
restored under a *different* mesh/sharding — jax.device_put with the new
NamedSharding performs the redistribution, which IS the burst's state
movement on real hardware.

Layout: <dir>/step_<n>/
          manifest.json        {step, leaf paths, shapes, dtypes, extra}
          <leaf_key>.npy       one array per pytree leaf
Writes go to step_<n>.tmp and are atomically renamed; a torn write is
never visible.  Async mode pushes the host-side serialization to a
daemon thread (off the training critical path); save(wait=True) or
close() joins it.  A SIGTERM handler can be installed for preemption-
triggered snapshots (install_preemption_hook).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import signal
import threading
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

_SEP = "__"


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path) or "root"
        out[key] = leaf
    return out


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, *, async_save: bool = True,
                 keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._q: queue.Queue = queue.Queue()
        self._worker: threading.Thread | None = None
        self._pending = 0
        self._lock = threading.Lock()
        if async_save:
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    # ------------------------------------------------------------------ save

    def save(self, step: int, state, extra: dict | None = None,
             wait: bool = False):
        """Snapshot `state` (pytree of arrays) at `step`.

        Device arrays are fetched to host here (cheap vs serialization);
        file I/O happens on the worker thread in async mode.
        """
        host = {
            k: np.asarray(v) for k, v in _flatten(state).items()
        }
        job = (step, host, dict(extra or {}))
        if self.async_save and not wait:
            with self._lock:
                self._pending += 1
            self._q.put(job)
        else:
            # a sync save may target the same step as a queued async one
            # (periodic + final save); drain the worker first so both
            # never race on the same step_*.tmp staging dir
            self.wait()
            self._write(job)

    def wait(self):
        if self.async_save:
            self._q.join()

    def close(self):
        self.wait()

    def _run(self):
        while True:
            job = self._q.get()
            try:
                self._write(job)
            finally:
                with self._lock:
                    self._pending -= 1
                self._q.task_done()

    def _write(self, job):
        step, host, extra = job
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, arr in host.items():
            fname = f"{key}.npy"
            true_dtype = str(arr.dtype)
            if arr.dtype.kind not in "biufc":
                # ml_dtypes (bfloat16, float8_*): persist as raw bytes
                stored = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            else:
                stored = arr
            np.save(tmp / fname, stored, allow_pickle=False)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": true_dtype,
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_state, step: int | None = None,
                shardings=None) -> tuple[Any, dict]:
        """Load into the structure of `target_state` (pytree of arrays or
        ShapeDtypeStructs).  `shardings` (matching pytree) redistributes
        each leaf onto the *current* mesh — restoring under a different
        mesh than the save is the supported path (that is the burst).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_target = _flatten(target_state)
        flat_shardings = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key, meta in manifest["leaves"].items():
            if key not in flat_target:
                continue
            arr = np.load(d / meta["file"], allow_pickle=False)
            if str(arr.dtype) != meta["dtype"]:
                arr = arr.view(jax.numpy.dtype(meta["dtype"]))
            sh = flat_shardings.get(key)
            out[key] = (
                jax.device_put(arr, sh) if sh is not None
                else jax.numpy.asarray(arr)
            )
        missing = set(flat_target) - set(out)
        if missing:
            raise KeyError(f"checkpoint at step {step} missing leaves: "
                           f"{sorted(missing)[:5]}...")
        # rebuild the pytree in target structure
        leaves_order, treedef = jax.tree_util.tree_flatten_with_path(
            target_state
        )
        vals = []
        for path, _ in leaves_order:
            key = _SEP.join(_path_str(p) for p in path) or "root"
            vals.append(out[key])
        return (
            jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(target_state), vals
            ),
            manifest["extra"],
        )


def install_preemption_hook(save_fn: Callable[[], None]):
    """SIGTERM -> best-effort snapshot before the platform reclaims us."""

    def handler(signum, frame):
        try:
            save_fn()
        finally:
            signal.default_int_handler(signum, frame)

    signal.signal(signal.SIGTERM, handler)
