"""Train-step builders: microbatched grad accumulation, sharded optimizer
update, optional two-level compressed cross-pod reduction.

Two builders:
  * build_train_step       — pure-SPMD baseline (XLA schedules all
                             collectives, incl. the (pod,data) grad
                             all-reduce).
  * build_compressed_train_step — shard_map manual over "pod": gradients
                             reduce over "data" automatically (ICI), then
                             cross the pod boundary as int8 (DCI) via
                             optim/compression.cross_pod_reduce.  This is
                             the TPU rendering of the paper's cluster<->
                             cloud synchronization step (Fig.1 step 8)
                             plus the beyond-paper bandwidth optimization.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import configure_partial_auto, shard_map
from repro.configs.base import ModelConfig, RunConfig
from repro.models import model as M
from repro.optim import Optimizer
from repro.optim.compression import cross_pod_reduce
from repro.sharding.rules import (
    AxisRules,
    abstract_params,
    axis_rules,
    init_params,
    param_shardings,
    zero1_shardings,
)

# ---------------------------------------------------------------------------
# State schema / shardings
# ---------------------------------------------------------------------------


def state_schema(cfg: ModelConfig, run: RunConfig, optimizer: Optimizer):
    psch = M.schema(cfg)
    from repro.sharding.rules import ParamSpec

    return {
        "params": psch,
        "opt": optimizer.state_schema(psch),
        "step": ParamSpec((), (), jnp.int32, lambda k, s, d: jnp.zeros(s, d)),
    }


def state_shardings(sch, rules: AxisRules, run: RunConfig):
    out = {
        "params": param_shardings(sch["params"], rules),
        "step": rules.sharding((), ()),
    }
    shard_fn = zero1_shardings if run.zero1 else param_shardings
    out["opt"] = shard_fn(sch["opt"], rules)
    return out


def init_state(sch, key):
    params = init_params(sch["params"], key)
    return params  # opt state initialized by optimizer.init (runtime)


def batch_pspecs(batch_specs: dict, rules: AxisRules):
    """PartitionSpecs for a train/serve input dict (batch-dim sharded)."""
    out = {}
    for k, v in batch_specs.items():
        if v.shape == ():
            out[k] = P()
        else:
            out[k] = rules.spec(("batch",) + (None,) * (len(v.shape) - 1),
                                v.shape)
    return out


def batch_shardings(batch_specs: dict, rules: AxisRules):
    from jax.sharding import NamedSharding

    return {
        k: NamedSharding(rules.mesh, s)
        for k, s in batch_pspecs(batch_specs, rules).items()
    }


# ---------------------------------------------------------------------------
# Gradient computation (shared)
# ---------------------------------------------------------------------------


def _loss_of(cfg: ModelConfig, run: RunConfig):
    def f(params, mb):
        return M.loss_fn(
            cfg, params, mb, loss_chunk=run.loss_chunk, remat=run.remat,
        )

    return f


def compute_grads(cfg: ModelConfig, run: RunConfig, params, batch,
                  grad_pspecs=None):
    """Returns (grads, metrics).  Microbatched when run.microbatch is set
    and smaller than the global batch.

    Gradients are sharding-constrained to the parameter layout per
    microbatch: with FSDP params this turns the per-µbatch gradient
    all-reduce into a reduce-scatter (ZeRO-2 style) — without the
    constraint XLA keeps grads replicated over "data" and all-reduces
    full parameter volume every accumulation step.
    """
    loss_of = _loss_of(cfg, run)
    B = batch["tokens"].shape[0]
    mb_size = run.microbatch or B

    def constrain(g):
        if grad_pspecs is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            g, grad_pspecs,
        )

    if mb_size >= B:
        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
            params, batch
        )
        return constrain(grads), metrics

    assert B % mb_size == 0, (B, mb_size)
    n_acc = B // mb_size
    gdtype = jnp.dtype(run.grad_dtype)
    mbs = jax.tree.map(
        lambda x: x.reshape(n_acc, mb_size, *x.shape[1:]), batch
    )
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, gdtype), params)

    def body(carry, mb):
        gacc, lacc, nll, cnt = carry
        (loss, metrics), g = jax.value_and_grad(loss_of, has_aux=True)(
            params, mb
        )
        g = constrain(g)
        gacc = jax.tree.map(lambda a, b: a + b.astype(gdtype), gacc, g)
        return (
            gacc,
            lacc + loss,
            nll + metrics["nll_sum"],
            cnt + metrics["token_count"],
        ), None

    (gsum, lsum, nll, cnt), _ = jax.lax.scan(
        body, (g0, 0.0, 0.0, 0.0), mbs
    )
    # keep grads in the accumulation dtype — optimizers upcast per-leaf
    # (chunked over stacked layers); a blanket f32 cast here doubles the
    # live gradient footprint for the ≥200B models
    grads = jax.tree.map(lambda g: g / n_acc, gsum)
    metrics = {"loss": lsum / n_acc, "nll_sum": nll, "token_count": cnt}
    return grads, metrics


# ---------------------------------------------------------------------------
# Baseline SPMD train step
# ---------------------------------------------------------------------------


def build_train_step(
    cfg: ModelConfig, run: RunConfig, optimizer: Optimizer,
    rules: AxisRules | None = None,
):
    grad_pspecs = None
    if rules is not None:
        from jax.sharding import NamedSharding

        from repro.sharding.rules import param_pspecs

        grad_pspecs = jax.tree.map(
            lambda s: NamedSharding(rules.mesh, s),
            param_pspecs(M.schema(cfg), rules),
        )

    def step(state, batch):
        with axis_rules(rules):
            grads, metrics = compute_grads(
                cfg, run, state["params"], batch, grad_pspecs
            )
            new_params, new_opt = optimizer.update(
                grads, state["opt"], state["params"], state["step"]
            )
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    return step


# ---------------------------------------------------------------------------
# Compressed cross-pod train step (manual over "pod")
# ---------------------------------------------------------------------------


def build_compressed_train_step(
    cfg: ModelConfig, run: RunConfig, optimizer: Optimizer, rules: AxisRules,
):
    """Requires a mesh with a 'pod' axis.  Gradients cross the pod boundary
    as int8; everything else stays automatically sharded (data/model)."""
    # grad-of-scan inside a partial-auto region: opt into the
    # partitioner that can compile it on legacy JAX (no-op otherwise)
    configure_partial_auto()
    mesh = rules.mesh
    assert "pod" in mesh.shape, "compressed step needs a 'pod' mesh axis"
    npods = mesh.shape["pod"]
    # inside the manual-pod region, batch shards over data only
    inner_rules = dataclasses.replace(
        rules,
        rules={**rules.rules, "batch": (("data",),)},
    )

    def inner(state, batch):
        with axis_rules(inner_rules):
            grads, metrics = compute_grads(cfg, run, state["params"], batch)
            # each pod's grads are normalized by ITS token count; the
            # global gradient is the token-weighted mean across pods
            cnt = metrics["token_count"].astype(jnp.float32)
            grads = jax.tree.map(lambda g: g * cnt, grads)
            grads = cross_pod_reduce(
                grads, "pod", method=run.gradient_compression
            )
            cnt_total = jax.lax.psum(cnt, "pod")
            grads = jax.tree.map(lambda g: g / cnt_total, grads)
            metrics = jax.tree.map(
                lambda m: jax.lax.pmean(m, "pod"), metrics
            )
            new_params, new_opt = optimizer.update(
                grads, state["opt"], state["params"], state["step"]
            )
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    def step(state, batch):
        state_specs = jax.tree.map(lambda _: P(), state)
        batch_specs = jax.tree.map(
            lambda x: P("pod") if x.ndim else P(), batch
        )
        return shard_map(
            inner,
            mesh=mesh,
            in_specs=(state_specs, batch_specs),
            out_specs=(state_specs, P()),
            axis_names={"pod"},
            check_vma=False,
        )(state, batch)

    return step
