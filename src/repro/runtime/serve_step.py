"""Serving step builders: prefill + decode with sharded KV caches."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.sharding.rules import AxisRules, axis_rules, param_shardings


def build_prefill(cfg: ModelConfig, rules: AxisRules | None = None,
                  max_seq: int | None = None):
    def fn(params, inputs):
        with axis_rules(rules):
            return M.prefill(cfg, params, inputs, max_seq=max_seq)

    return fn


def build_decode(cfg: ModelConfig, rules: AxisRules | None = None):
    def fn(params, cache, inputs):
        with axis_rules(rules):
            return M.decode_step(cfg, params, cache, inputs)

    return fn


def cache_shardings(cfg: ModelConfig, batch: int, max_seq: int,
                    rules: AxisRules):
    sch = M.cache_schema(cfg, batch, max_seq)
    return param_shardings(sch, rules)


def serve_input_shardings(specs: dict, rules: AxisRules):
    out = {}
    for k, v in specs.items():
        if v.shape == ():
            out[k] = NamedSharding(rules.mesh, P())
        else:
            out[k] = NamedSharding(
                rules.mesh,
                rules.spec(("batch",) + (None,) * (len(v.shape) - 1), v.shape),
            )
    return out
