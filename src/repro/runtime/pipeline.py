"""Pipeline parallelism over the "pod" axis (GPipe, shard_map manual).

The TPU-native rendering of the paper's *slow cluster<->cloud link*
insight, as an alternative to cross-pod data parallelism: with DP the
entire gradient volume crosses DCI every step (granite-8b: ~1.3 GB/dev);
with 2-stage PP only the stage-boundary activations cross, between
matched device pairs (~66 MB/dev for the same cell) — each pod owns half
the layers, so layer-weight gradients never leave their pod.

Mechanics: shard_map manual over {"pod"} with data/model auto inside.
The stacked-layers dim of every block parameter is sharded P("pod") —
each pod holds its contiguous layer slice.  A lax.scan over
n_micro + stages - 1 ticks runs the GPipe fill/drain schedule; the
activation moves stage-to-stage via ppermute each tick.  jax.grad
through the tick scan IS the GPipe backward (ppermute transposes to the
reverse permute).  Embedding/unembedding params are replicated across
pods; their (stage-local) gradients are psum'd over "pod".

Restrictions (asserted): a single homogeneous BlockDef whose repeat
divides by the stage count; no MoE/cross-attention/MTP (their own
shard_map regions do not nest under a manual pod axis) — i.e. the dense
LM family, which is exactly where cross-pod DP vs PP is the interesting
trade.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import configure_partial_auto, mesh_and_manual, shard_map
from repro.configs.base import BlockDef, ModelConfig, RunConfig
from repro.models import model as M
from repro.models.layers import apply_norm, embed_tokens
from repro.models.transformer import apply_block_full
from repro.optim import Optimizer
from repro.sharding.rules import AxisRules, axis_rules, is_spec, shard


def pipeline_compatible(cfg: ModelConfig) -> bool:
    return (
        len(cfg.blocks) == 1
        and all(m == "attn" and mlp == "dense"
                for m, mlp in cfg.blocks[0].pattern)
        and not cfg.cross_attention
        and not cfg.mtp
        and cfg.moe is None
    )


def _block_param_specs(schema) -> Any:
    """P('pod') on the stacked-layers dim for block params, P() otherwise."""

    def spec_of(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        if any(isinstance(k, str) and k.startswith("b") and k[1:].isdigit()
               for k in keys):
            return P("pod")
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        schema, is_leaf=is_spec
    )
    return jax.tree_util.tree_unflatten(
        treedef, [spec_of(p, l) for p, l in flat]
    )


def build_pipeline_train_step(
    cfg: ModelConfig, run: RunConfig, optimizer: Optimizer,
    rules: AxisRules,
):
    """Returns (step_fn, state_in_specs) — step_fn(state, batch) with the
    state's block params stage-sharded over 'pod'."""
    # this builder constructs a grad-of-scan inside a partial-auto
    # region — opt into the partitioner that can compile it on legacy
    # JAX (no-op on jax.shard_map-native versions)
    configure_partial_auto()
    assert pipeline_compatible(cfg), cfg.name
    mesh = rules.mesh
    stages = mesh.shape.get("pod", 1)
    assert stages > 1, "pipeline needs a 'pod' axis"
    bdef = cfg.blocks[0]
    assert bdef.repeat % stages == 0, (bdef.repeat, stages)
    n_micro = run.pp_microbatches
    local_bdef = BlockDef(pattern=bdef.pattern,
                          repeat=bdef.repeat // stages)
    # inside the manual pod region: batch shards over "data", and the
    # residual/boundary activation over "model" (SP) — the ppermute then
    # moves per-device shards only, which is the whole point of PP here
    inner_rules = dataclasses.replace(
        rules,
        rules={**rules.rules, "batch": (("data",),),
               "seq_res": (("model",),)},
    )
    fwd_perm = [(i, i + 1) for i in range(stages - 1)]

    def loss_fn(params, batch, sid):
        # manual over pod: params['b0'] holds THIS stage's layer slice;
        # sid arrives as data (a P("pod")-sharded arange) rather than
        # lax.axis_index — partition-id lowering is not portable across
        # partitioners (see compat.configure_partial_auto)
        tokens = batch["tokens"]                   # (B, S) pod-replicated
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(tokens.shape, jnp.float32)
        B, S = tokens.shape
        assert B % n_micro == 0, (B, n_micro)
        mb = B // n_micro
        rope_cs = M.rope_full(cfg, S)
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        lmask = jnp.pad(mask[:, 1:], ((0, 0), (0, 1)))

        def stage_compute(x, m_idx, active):
            """Run this pod's layers on x where active."""
            is_first = sid == 0
            tok_m = jax.lax.dynamic_slice_in_dim(tokens, m_idx * mb, mb, 0)
            x_in = jnp.where(
                is_first, embed_tokens(cfg, params, tok_m), x
            )
            y, _, aux = apply_block_full(
                cfg, local_bdef, params["b0"], x_in,
                rope_cs=rope_cs, causal=True, remat=cfg.remat,
            )
            y = jnp.where(active, y, x)
            return y, jnp.where(active, aux, 0.0)

        def last_stage_loss(x, m_idx, active):
            h = apply_norm(cfg, params["final_norm"], x)
            lab = jax.lax.dynamic_slice_in_dim(labels, m_idx * mb, mb, 0)
            lm = jax.lax.dynamic_slice_in_dim(lmask, m_idx * mb, mb, 0)
            lm = lm * active.astype(lm.dtype)
            nll, cnt = M.chunked_xent(cfg, params, h, lab, lm,
                                      run.loss_chunk)
            return nll, cnt

        def tick(carry, t):
            x_cur, nll, cnt, aux_acc = carry
            m_idx = jnp.clip(t - sid, 0, n_micro - 1)
            active = (t - sid >= 0) & (t - sid < n_micro)
            y, aux = stage_compute(x_cur, m_idx, active)
            is_last = sid == stages - 1
            nll_t, cnt_t = last_stage_loss(y, m_idx, active & is_last)
            take = (active & is_last).astype(jnp.float32)
            # hand my output to the next stage for the next tick; keep it
            # (data, model)-sharded so only per-device shards cross DCI
            y = shard(y, "batch", "seq_res", None)
            x_next = jax.lax.ppermute(y, "pod", fwd_perm)
            return (
                x_next, nll + nll_t * take, cnt + cnt_t * take,
                aux_acc + aux,
            ), None

        x0 = shard(
            jnp.zeros((mb, S, cfg.d_model), cfg.cdtype),
            "batch", "seq_res", None,
        )
        (x_last, nll, cnt, aux), _ = jax.lax.scan(
            tick, (x0, 0.0, 0.0, 0.0), jnp.arange(n_micro + stages - 1)
        )
        nll = jax.lax.psum(nll, "pod")
        cnt = jax.lax.psum(cnt, "pod")
        aux = jax.lax.psum(aux, "pod") / stages
        loss = nll / jnp.maximum(cnt, 1.0) + aux
        return loss, {"loss": loss, "nll_sum": nll, "token_count": cnt}

    def inner(state, batch, sid_arr):
        sid = sid_arr[0]
        with axis_rules(inner_rules):
            # within-pod FSDP/TP of the stage's weights: the manual pod
            # split leaves them replicated over (data, model) otherwise
            from jax.sharding import NamedSharding

            from repro.sharding.rules import param_pspecs

            pspecs = param_pspecs(M.schema(cfg), inner_rules)
            am, _, constrainable = mesh_and_manual(mesh)

            def constrain(x, spec):
                if not constrainable:
                    return x
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(am, spec)
                )

            state = dict(state)
            state["params"] = jax.tree.map(
                constrain, state["params"], pspecs
            )
            (loss, metrics), grads = jax.value_and_grad(
                lambda p, b: loss_fn(p, b, sid), has_aux=True
            )(state["params"], batch)
            # shared (pod-replicated) params: sum partial grads across
            # stages; stage-local layer grads stay local (the PP win)
            def psum_shared(path, g):
                keys = [getattr(p, "key", None) for p in path]
                if any(isinstance(k, str) and k.startswith("b")
                       and k[1:].isdigit() for k in keys):
                    return g
                return jax.lax.psum(g, "pod")

            flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
            grads = jax.tree_util.tree_unflatten(
                treedef, [psum_shared(p, g) for p, g in flat]
            )
            new_params, new_opt = optimizer.update(
                grads, state["opt"], state["params"], state["step"]
            )
        return (
            {"params": new_params, "opt": new_opt,
             "step": state["step"] + 1},
            metrics,
        )

    psch = M.schema(cfg)
    param_specs = _block_param_specs(psch)
    opt_specs = _block_param_specs(optimizer.state_schema(psch))
    state_specs = {"params": param_specs, "opt": opt_specs, "step": P()}

    def step(state, batch):
        batch_specs = jax.tree.map(lambda _: P(), batch)
        sid_in = jnp.arange(stages, dtype=jnp.int32)
        return shard_map(
            inner,
            mesh=mesh,
            in_specs=(state_specs, batch_specs, P("pod")),
            out_specs=(state_specs, P()),
            axis_names={"pod"},
            check_vma=False,
        )(state, batch, sid_in)

    return step, state_specs
