"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), TPU v5e constants from launch/hw.py:

    T_compute    = HLO_FLOPs_per_device / peak_FLOP/s
    T_memory     = HLO_bytes_per_device / HBM_bw
    T_collective = ICI_bytes/ (link_bw × links)  +  DCI_bytes / DCI_bw

FLOPs / HBM bytes / collective bytes come from launch/hlo_cost.py — a
static cost model over the compiled HLO text that (unlike XLA's
cost_analysis) multiplies while-loop trip counts, recurses into fusions,
and attributes each collective to ICI vs inter-pod DCI via its replica
groups.  Collective bytes are *wire-true* per op type (all-reduce counted
2·size·(g-1)/g etc.), a refinement over the brief's operand-sum
convention; both conventions land within a small factor and the artifact
records per-type byte totals so either can be recomputed.
"""
from __future__ import annotations

from typing import Any

from repro.launch.hw import TPU_V5E, ChipSpec


def roofline_terms(
    flops_per_dev: float,
    bytes_per_dev: float,
    hc: dict,
    *,
    chip: ChipSpec = TPU_V5E,
) -> dict[str, Any]:
    t_comp = flops_per_dev / chip.peak_flops_bf16
    t_mem = bytes_per_dev / chip.hbm_bw
    dci = float(hc.get("collective_dci_bytes", 0.0))
    ici = float(hc.get("collective_bytes", 0.0)) - dci
    t_ici = ici / (chip.ici_link_bw * chip.ici_links)
    t_dci = dci / chip.dci_bw
    t_coll = t_ici + t_dci
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll,
             "collective_ici": t_ici, "collective_dci": t_dci}
    dom = max(("compute", "memory", "collective"), key=lambda k: terms[k])
    bound = max(terms["compute"], terms["memory"], terms["collective"])
    return {
        **terms,
        "dominant": dom,
        "step_time_lower_bound_s": bound,
        "roofline_fraction": t_comp / bound if bound > 0 else 0.0,
    }


def model_flops(n_active_params: int, tokens: int, *, train: bool) -> float:
    """6·N·D for train, 2·N·D for forward-only (MoE: N = active params)."""
    return (6.0 if train else 2.0) * n_active_params * tokens
