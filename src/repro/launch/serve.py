"""Batched serving driver: prefill + decode loop with a sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.runtime import serve_step
from repro.sharding.rules import init_params, make_rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    mesh = make_host_mesh()
    rules = make_rules(mesh, "serve")
    max_seq = args.prompt_len + args.gen

    params = init_params(M.schema(cfg), jax.random.key(0))
    prefill = jax.jit(serve_step.build_prefill(cfg, rules, max_seq=max_seq))
    decode = jax.jit(serve_step.build_decode(cfg, rules), donate_argnums=(1,))

    key = jax.random.key(1)
    B = args.batch
    inputs = {
        "tokens": jax.random.randint(
            key, (B, args.prompt_len), 0, cfg.vocab_size, jnp.int32
        )
    }
    if cfg.input_mode == "embeds":
        inputs["embeds"] = jax.random.normal(
            key, (B, args.prompt_len, cfg.d_model), jnp.float32
        ).astype(jnp.bfloat16)
    if cfg.rope_type == "mrope":
        inputs["positions"] = jnp.broadcast_to(
            jnp.arange(args.prompt_len)[None, None], (B, 3, args.prompt_len)
        ).astype(jnp.int32)
    if cfg.cross_attention:
        inputs["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_frames, cfg.d_model), jnp.float32
        ).astype(jnp.bfloat16)

    t0 = time.monotonic()
    logits, cache = prefill(params, inputs)
    jax.block_until_ready(logits)
    t_prefill = time.monotonic() - t0

    def sample(lg, k):
        if args.temperature <= 0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(k, lg / args.temperature).astype(
            jnp.int32
        )

    toks = [sample(logits, key)]
    t0 = time.monotonic()
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        dec_in = {"token": toks[-1], "pos": pos}
        if cfg.rope_type == "mrope":
            dec_in["positions"] = jnp.broadcast_to(
                pos[None, None], (B, 3)
            ).astype(jnp.int32)
        logits, cache = decode(params, cache, dec_in)
        key, sub = jax.random.split(key)
        toks.append(sample(logits, sub))
    jax.block_until_ready(toks[-1])
    t_decode = time.monotonic() - t0
    out = jnp.stack(toks, axis=1)
    print(f"[serve] prefill {args.prompt_len} tok × {B}: {t_prefill:.3f}s")
    print(f"[serve] decode {args.gen - 1} steps: {t_decode:.3f}s "
          f"({(args.gen - 1) * B / max(t_decode, 1e-9):.1f} tok/s)")
    print("[serve] sample output ids:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
