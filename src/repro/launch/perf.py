import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf-iteration harness (EXPERIMENTS.md §Perf).

Each experiment = (cell, variant): a named transform over the ModelConfig
/ RunConfig of one (arch × shape × mesh) cell.  The harness lowers +
compiles the variant, runs the HLO cost model, and writes
artifacts/perf/<arch>.<shape>.<mesh>/<variant>.json so every
hypothesis -> change -> measure step is recorded next to its baseline.

    python -m repro.launch.perf --list
    python -m repro.launch.perf --run dsv3-ep
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax

from repro.configs import RunConfig, SHAPES, get_config, input_specs
from repro.launch import dryrun as dr
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops, roofline_terms
from repro.models import model as M
from repro.optim import make_optimizer, warmup_cosine
from repro.runtime import serve_step, train_step as ts
from repro.sharding.rules import (
    abstract_params,
    cast_schema,
    make_rules,
    param_shardings,
)

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "perf"


@dataclasses.dataclass
class Experiment:
    name: str
    arch: str
    shape: str
    mesh: str                       # single | multi
    hypothesis: str
    cfg_fn: callable = None         # ModelConfig -> ModelConfig
    run_fn: callable = None         # RunConfig -> RunConfig


def _moe_ep(cfg):
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, ep_over_dp=True)
    )


def _moe_ep_scatter(cfg):
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, ep_over_dp=True,
                                     dispatch="scatter")
    )


def _moe_no_ep(cfg):
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, ep_over_dp=False)
    )


EXPERIMENTS = {
    # --- cell A: deepseek-v3-671b × train_4k × single (collective-bound)
    "dsv3-baseline-fsdp": Experiment(
        "dsv3-baseline-fsdp", "deepseek-v3-671b", "train_4k", "single",
        "Paper-faithful baseline record (pre-hillclimb defaults): FSDP-"
        "gathered experts, no SP. Kept regenerable so baseline vs "
        "optimized stay side by side in artifacts/perf.",
        cfg_fn=_moe_no_ep,
    ),
    "dsv3-ep": Experiment(
        "dsv3-ep", "deepseek-v3-671b", "train_4k", "single",
        "FSDP regathers expert weights every use (~3.7TB/dev AG). EP over "
        "(data×model) moves TOKENS via all-to-all instead: per layer "
        "~117MB/dev vs ~1.5GB/dev of weight AG, and expert grads become "
        "fully local. Predict T_coll 35.5s -> <8s.",
        cfg_fn=_moe_ep,
    ),
    "dsv3-ep-mb64": Experiment(
        "dsv3-ep-mb64", "deepseek-v3-671b", "train_4k", "single",
        "On top of EP: double microbatch 32->64 halves the number of "
        "dense-layer FSDP gather rounds per step. Predict residual AG "
        "halves; activation memory doubles (still under budget).",
        cfg_fn=_moe_ep,
        run_fn=lambda r: dataclasses.replace(r, microbatch=64),
    ),
    "dsv3-ep-scatter": Experiment(
        "dsv3-ep-scatter", "deepseek-v3-671b", "train_4k", "single",
        "On top of EP: scatter dispatch removes the one-hot dispatch/"
        "combine einsum FLOPs (2·T·(E·C)·d per group ≈ 1/3 of expert "
        "FLOPs). Predict T_comp 19s -> ~13s.",
        cfg_fn=_moe_ep_scatter,
    ),
    "dsv3-ep-sp": Experiment(
        "dsv3-ep-sp", "deepseek-v3-671b", "train_4k", "single",
        "On top of EP: the peak is the 58-layer f32 remat stash "
        "(12.7 GiB: XLA folds the first-use f32 convert into the saved "
        "residual). Sequence-shard the residual stream over 'model' "
        "(Megatron-SP): stash /16; adds AG/RS ~tokens·d·2B per layer "
        "(~0.9s total). Predict peak 56 -> ~45 GiB, T_coll +1s.",
        cfg_fn=_moe_ep,
        run_fn=lambda r: dataclasses.replace(r, seq_shard=True),
    ),
    "dsv3-ep-sp-multi": Experiment(
        "dsv3-ep-sp-multi", "deepseek-v3-671b", "train_4k", "multi",
        "Params+grads alone are 10.4 GB/chip at 256 chips — the single-"
        "pod cell cannot fit 16 GiB with any activation recipe. On 512 "
        "chips (2 pods) static state halves. Predict peak ~20 GiB "
        "(borderline; 4 pods would clear it).",
        cfg_fn=_moe_ep,
        run_fn=lambda r: dataclasses.replace(r, seq_shard=True),
    ),
    "dsv3-ep-sp-nomb": Experiment(
        "dsv3-ep-sp-nomb", "deepseek-v3-671b", "train_4k", "single",
        "With SP the remat stash is tiny; dropping grad accumulation "
        "removes the separate 5.2 GB/dev accumulator and the per-µbatch "
        "FSDP gather rounds. Predict peak -4 GiB, T_coll down.",
        cfg_fn=_moe_ep,
        run_fn=lambda r: dataclasses.replace(r, seq_shard=True,
                                             microbatch=None),
    ),
    # --- cell B: whisper-large-v3 × train_4k × single (worst fraction)
    "whisper-mb256": Experiment(
        "whisper-mb256", "whisper-large-v3", "train_4k", "single",
        "Memory term is dominated by per-µbatch encoder+cross-KV "
        "recompute under full remat. Run the whole batch in one µstep "
        "(no accumulation): encoder runs once. Predict T_mem 24.9s -> "
        "~14s.",
        run_fn=lambda r: dataclasses.replace(r, microbatch=None),
    ),
    "whisper-mb256-dots": Experiment(
        "whisper-mb256-dots", "whisper-large-v3", "train_4k", "single",
        "On top of mb256: remat 'dots' keeps matmul outputs (incl. "
        "cross-KV) so backward does not recompute the encoder path. "
        "Model is 1.5B — activations fit. Predict T_mem -> ~8s.",
        run_fn=lambda r: dataclasses.replace(r, microbatch=None,
                                             remat="dots"),
    ),
    "whisper-flatdp": Experiment(
        "whisper-flatdp", "whisper-large-v3", "train_4k", "single",
        "Root cause of the 0.099 fraction: 20 heads % 16 model ranks != 0"
        " -> attention replicated on every model rank (16x waste in both "
        "compute and memory terms). Flat DP uses 'model' as a second "
        "data axis (batch 256 = 16x16, per-dev batch 1). Predict "
        "T_comp 2.5 -> ~0.2s, T_mem 25 -> ~1.6s.",
        cfg_fn=lambda c: dataclasses.replace(c, flat_dp=True),
    ),
    "whisper-flatdp-dots": Experiment(
        "whisper-flatdp-dots", "whisper-large-v3", "train_4k", "single",
        "Flat DP + remat dots (per-dev batch 1: activations are tiny, "
        "full remat is pure waste). Predict T_comp down another ~25%.",
        cfg_fn=lambda c: dataclasses.replace(c, flat_dp=True),
        run_fn=lambda r: dataclasses.replace(r, remat="dots"),
    ),
    "whisper-flatdp-full": Experiment(
        "whisper-flatdp-full", "whisper-large-v3", "train_4k", "single",
        "flat_dp alone didn't engage: microbatch 128 < 256 so the batch "
        "dim can't split 256-way and falls back to data-only. Run the "
        "full batch per step (no accumulation): per-dev batch 1, "
        "attention finally distributed. Predict T_comp ~0.2s, T_mem "
        "~1.6s.",
        cfg_fn=lambda c: dataclasses.replace(c, flat_dp=True),
        run_fn=lambda r: dataclasses.replace(r, microbatch=None,
                                             remat="dots"),
    ),
    # --- cell C: granite-8b × train_4k × multi (the paper's technique)
    "granite-multi-int8": Experiment(
        "granite-multi-int8", "granite-8b", "train_4k", "multi",
        "Cross-pod DCI traffic is the paper's slow link. int8 gradient "
        "exchange over the pod axis cuts DCI bytes ~4x vs fp32 wire. "
        "Predict collective_dci -> /4.",
        run_fn=lambda r: dataclasses.replace(
            r, gradient_compression="int8"),
    ),
    "granite-multi-pp": Experiment(
        "granite-multi-pp", "granite-8b", "train_4k", "multi",
        "PP over the pod axis instead of cross-pod DP: only stage-"
        "boundary activations cross DCI (napkin: ~66 MB/dev vs 1.3 GB/dev "
        "of gradient exchange — ~20x less slow-link traffic), and layer "
        "grads never leave their pod. Cost: pipeline bubble "
        "(stages-1)/(n_micro+stages-1) ≈ 11% at 8 µbatches.",
        run_fn=lambda r: dataclasses.replace(
            r, pipeline_stages=2, pp_microbatches=8, microbatch=None),
    ),
    "granite-multi-mb128": Experiment(
        "granite-multi-mb128", "granite-8b", "train_4k", "multi",
        "Fewer accumulation rounds -> fewer FSDP gather sweeps. "
        "microbatch 64->128 halves gather volume; activation checkpoint "
        "memory doubles. Predict T_coll 1.92 -> ~1.1s.",
        run_fn=lambda r: dataclasses.replace(r, microbatch=128),
    ),
}


def build_variant(exp: Experiment):
    cfg = get_config(exp.arch)
    if exp.cfg_fn:
        cfg = exp.cfg_fn(cfg)
    shape = SHAPES[exp.shape]
    run = dr.run_config(cfg, shape)
    if exp.run_fn:
        run = exp.run_fn(run)
    mesh = make_production_mesh(multi_pod=exp.mesh == "multi")
    rules = make_rules(mesh, "train" if shape.kind == "train" else "serve",
                       flat_dp=cfg.flat_dp)
    if getattr(run, "seq_shard", False):
        rules = dataclasses.replace(
            rules, rules={**rules.rules, "seq_res": (("model",),)}
        )
    in_specs = input_specs(cfg, shape)
    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer, warmup_cosine())
        sch = ts.state_schema(cfg, run, opt)
        state_abs = abstract_params(sch)
        if run.pipeline_stages > 1 and "pod" in mesh.shape:
            from repro.runtime.pipeline import build_pipeline_train_step

            fn, _state_specs = build_pipeline_train_step(
                cfg, run, opt, rules
            )
            # shard_map's in_specs drive the pod split; jit-level
            # shardings are left unspecified for the dry-run lowering
            jf = jax.jit(fn, donate_argnums=(0,))
            return cfg, shape, mesh, jf, (state_abs, in_specs)
        state_sh = ts.state_shardings(sch, rules, run)
        batch_sh = ts.batch_shardings(in_specs, rules)
        if run.gradient_compression != "none" and "pod" in mesh.shape:
            fn = ts.build_compressed_train_step(cfg, run, opt, rules)
        else:
            fn = ts.build_train_step(cfg, run, opt, rules)
        jf = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
        return cfg, shape, mesh, jf, (state_abs, in_specs)
    psch = cast_schema(M.schema(cfg), jax.numpy.bfloat16)
    params_abs = abstract_params(psch)
    params_sh = param_shardings(psch, rules)
    input_sh = serve_step.serve_input_shardings(in_specs, rules)
    if shape.kind == "prefill":
        fn = serve_step.build_prefill(cfg, rules)
        jf = jax.jit(fn, in_shardings=(params_sh, input_sh))
        return cfg, shape, mesh, jf, (params_abs, in_specs)
    cache_sch = M.cache_schema(cfg, shape.global_batch, shape.seq_len)
    fn = serve_step.build_decode(cfg, rules)
    jf = jax.jit(
        fn,
        in_shardings=(params_sh, param_shardings(cache_sch, rules),
                      input_sh),
        donate_argnums=(1,),
    )
    return cfg, shape, mesh, jf, (
        params_abs, abstract_params(cache_sch), in_specs
    )


def run_experiment(exp: Experiment) -> dict:
    cfg, shape, mesh, jf, args = build_variant(exp)
    chips = mesh.devices.size
    t0 = time.time()
    lowered = jf.lower(*args)
    compiled = lowered.compile()
    t_compile = time.time() - t0
    hc = hlo_analyze(compiled.as_text(), total_devices=chips, pod_size=256)
    mem = dr._mem_analysis_dict(compiled)
    rl = roofline_terms(hc["flops"], hc["hbm_bytes"], hc)
    total, active = M.param_counts(cfg)
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind in ("train", "prefill") else 1
    )
    mf = model_flops(active, tokens, train=shape.kind == "train") / chips
    rec = {
        "experiment": exp.name,
        "hypothesis": exp.hypothesis,
        "arch": exp.arch, "shape": exp.shape, "mesh": exp.mesh,
        "compile_s": round(t_compile, 1),
        "hlo_flops_per_dev": hc["flops"],
        "hlo_bytes_per_dev": hc["hbm_bytes"],
        "collectives": {
            "total_bytes": hc["collective_bytes"],
            "dci_bytes": hc["collective_dci_bytes"],
            "by_type": hc["collective_by_type"],
        },
        "memory": mem,
        "roofline": rl,
        "useful_compute_ratio": mf / hc["flops"] if hc["flops"] else 0,
    }
    out = ARTIFACTS / f"{exp.arch}.{exp.shape}.{exp.mesh}"
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{exp.name}.json").write_text(json.dumps(rec, indent=1))
    print(
        f"[perf] {exp.name}: dom={rl['dominant']} "
        f"T=(c {rl['compute']:.2f} | m {rl['memory']:.2f} | "
        f"x {rl['collective']:.2f})s frac={rl['roofline_fraction']:.3f} "
        f"peak={mem.get('peak_bytes_per_device', 0) / 2**30:.1f}GiB",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--run", nargs="+", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list or not args.run:
        for name, e in EXPERIMENTS.items():
            print(f"{name}: [{e.arch} × {e.shape} × {e.mesh}] "
                  f"{e.hypothesis[:90]}")
        return
    for name in args.run:
        run_experiment(EXPERIMENTS[name])


if __name__ == "__main__":
    main()
