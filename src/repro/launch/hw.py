"""Target-hardware constants (TPU v5e) used by the roofline analysis.

This container runs on CPU; v5e is the *target*.  All roofline terms in
EXPERIMENTS.md are derived from compiled HLO + these constants.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float     # FLOP/s
    hbm_bw: float              # bytes/s
    hbm_bytes: int             # capacity
    ici_link_bw: float         # bytes/s per link per direction
    ici_links: int             # links per chip participating in a collective
    dci_bw: float              # inter-pod (data-center interconnect) bytes/s/chip


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,    # 197 TFLOP/s bf16
    hbm_bw=819e9,              # 819 GB/s
    hbm_bytes=16 * 1024**3,    # 16 GiB
    ici_link_bw=50e9,          # ~50 GB/s per link (brief-provided constant)
    ici_links=2,               # 2D torus on v5e: 2 axes usable per transfer
    dci_bw=6.25e9,             # ~50 Gbit/s/chip-equivalent across pods
)


def pod_flops(chips: int, spec: ChipSpec = TPU_V5E) -> float:
    return chips * spec.peak_flops_bf16


def pod_hbm_bw(chips: int, spec: ChipSpec = TPU_V5E) -> float:
    return chips * spec.hbm_bw


def pod_ici_bw(chips: int, spec: ChipSpec = TPU_V5E) -> float:
    return chips * spec.ici_link_bw
