"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
that importing this module never touches jax device state.  The dry-run
launcher sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import; everything else sees the real device count.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def _n(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single-pod (16,16) ("data","model") or 2-pod (2,16,16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, devices=jax.devices()[: _n(shape)])


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes, devices=jax.devices()[: _n(shape)])


def make_host_mesh(model: int | None = None) -> Mesh:
    """Small mesh over whatever devices exist (CPU tests, demos)."""
    n = len(jax.devices())
    model = model or 1
    if n % model:
        model = 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def chips(mesh: Mesh) -> int:
    return mesh.devices.size


def legal_slice_shapes(max_chips: int = 512):
    """Legal v5e slice chip counts (the planner rounds c_n up to these)."""
    out = []
    c = 1
    while c <= max_chips:
        out.append(c)
        c *= 2
    return out
