"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --deadline 120

Runs the full substrate: config -> model -> sharded train step ->
synthetic pipeline -> optimizer, with step-time monitoring, deadline
prediction (the paper's loop), periodic async checkpointing and
auto-resume.  --smoke shrinks the arch for CPU; without it the full
config is used (TPU-scale — on CPU use the dry-run instead).

The *elastic* path (actual mid-run re-meshing) needs >1 device; see
examples/elastic_burst_demo.py which launches with 8 host devices.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import RunConfig, get_config, smoke_config
from repro.configs.shapes import ShapeConfig
from repro.core import DeadlinePredictor, StepTimeMonitor
from repro.data.pipeline import SyntheticLMPipeline
from repro.launch.mesh import make_host_mesh
from repro.optim import make_optimizer, warmup_cosine
from repro.runtime import train_step as ts
from repro.sharding.rules import abstract_params, init_params, make_rules


def build_session(cfg, run, mesh, steps_total):
    rules = make_rules(mesh, "train")
    opt = make_optimizer(
        run.optimizer or cfg.optimizer,
        warmup_cosine(total_steps=steps_total),
    )
    sch = ts.state_schema(cfg, run, opt)
    shardings = ts.state_shardings(sch, rules, run)
    step_fn = jax.jit(
        ts.build_train_step(cfg, run, opt, rules), donate_argnums=(0,)
    )
    return opt, sch, shardings, step_fn, rules


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--deadline", type=float, default=None,
                    help="seconds; enables the monitoring/prediction loop")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    run = RunConfig(microbatch=args.microbatch, loss_chunk=min(512, args.seq))
    mesh = make_host_mesh()
    opt, sch, shardings, step_fn, rules = build_session(
        cfg, run, mesh, args.steps
    )

    pipeline = SyntheticLMPipeline(cfg, shape)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if args.resume and mgr and mgr.latest_step() is not None:
        abstract = abstract_params(sch)
        state, extra = mgr.restore(abstract, shardings=shardings)
        pipeline.restore(extra)
        start_step = int(extra.get("data_step", 0))
        print(f"[train] resumed from step {start_step}")
    else:
        params = init_params(sch["params"], jax.random.key(0))
        params = jax.device_put(params, shardings["params"])
        state = {
            "params": params,
            "opt": jax.jit(opt.init, out_shardings=shardings["opt"])(params),
            "step": jnp.zeros((), jnp.int32),
        }

    monitor = StepTimeMonitor()
    predictor = (
        DeadlinePredictor(args.deadline) if args.deadline else None
    )
    t_start = time.monotonic()
    for step in range(start_step, args.steps):
        batch = pipeline.batch_at(step)
        t0 = time.monotonic()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])  # blocks
        dt = time.monotonic() - t0
        monitor.observe(dt)
        pipeline.state.step = step + 1
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, extra=pipeline.state.to_extra())
        if (step + 1) % args.log_every == 0 or step == start_step:
            msg = (f"[train] step {step + 1}/{args.steps} "
                   f"loss={loss:.4f} {dt*1000:.0f}ms")
            if predictor:
                est = predictor.estimate(
                    monitor, step + 1, args.steps,
                    time.monotonic() - t_start,
                )
                msg += (f" est_total={est.estimated_total_s:.0f}s "
                        f"slack={est.slack_s:+.0f}s"
                        + (" [DEADLINE AT RISK — would burst]"
                           if est.will_miss else ""))
            print(msg, flush=True)
    if mgr:
        mgr.save(args.steps, state, extra=pipeline.state.to_extra(),
                 wait=True)
    print(f"[train] done in {time.monotonic() - t_start:.1f}s")


if __name__ == "__main__":
    main()
