"""Static cost model over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — with
scan-over-layers (and scanned attention/MoE/loss chunks) that undercounts
FLOPs, HBM bytes and collective bytes by the trip count (~30-60x here).
This module parses the compiled HLO text into computations, resolves
operand shapes through per-computation symbol tables, extracts while-loop
trip counts from their condition computations, and accumulates:

  flops              2·M·N·K for dots (incl. dots inside fusions)
  hbm_bytes          operand+output bytes at fusion boundaries (fusion
                     internals live in registers/VMEM — this is a closer
                     HBM-traffic model than cost_analysis's per-op sum)
  collective bytes   wire-true per type:
                       all-gather      out·(g-1)/g
                       all-reduce      2·out·(g-1)/g
                       reduce-scatter  in·(g-1)/g  (= out·(g-1))
                       all-to-all      out·(g-1)/g
                       collective-permute  out
                     each × enclosing trip counts, attributed ICI vs DCI
                     by whether its replica groups cross the pod boundary.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3b11fnuz": 1, "f8e8m0fnu": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLL_OPS = {
    "all-gather", "all-gather-start",
    "all-reduce", "all-reduce-start",
    "reduce-scatter",
    "all-to-all",
    "collective-permute", "collective-permute-start",
}
_SKIP_BYTES_OPS = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota",
    "get-dimension-size", "copy-start", "copy-done",
}


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: tuple[int, ...]

    @property
    def size(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.size * _DTYPE_BYTES.get(self.dtype, 4)


def _flat_bytes(t) -> int:
    if isinstance(t, Shape):
        return t.bytes
    return sum(_flat_bytes(x) for x in t)


_SHAPE_TOKEN = re.compile(r"(\w[\w\d]*)\[([\d,]*)\]")


def parse_type(s: str):
    """'f32[8,4]{1,0}' -> Shape; '(f32[2], s32[])' -> [Shape, Shape]."""
    s = s.strip()
    if s.startswith("("):
        # split top-level commas (brackets/braces guard layout commas)
        depth, parts, cur = 0, [], ""
        for ch in s[1:-1] if s.endswith(")") else s[1:]:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append(cur)
                cur = ""
            else:
                cur += ch
        if cur.strip():
            parts.append(cur)
        return [parse_type(p) for p in parts]
    m = _SHAPE_TOKEN.match(s)
    if not m:
        return Shape("opaque", ())
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return Shape(m.group(1), dims)


@dataclasses.dataclass
class Op:
    name: str
    out_type: Any            # Shape | list
    opcode: str
    operands: list[str]
    attrs: str
    is_root: bool
    raw_operands: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    params: dict[str, Any]
    ops: list[Op]
    symbols: dict[str, Any]


# header: "%name (p0: f32[..], p1: (f32[..], ..)) -> type {"
_COMP_HEAD = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->\s*(.+?)\s*\{\s*$"
)
_OP_LINE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\],\{\}:()$ ]+?)\s+"
    r"([\w\-]+)\((.*)$"
)


def _split_params(sig: str) -> dict[str, Any]:
    out: dict[str, Any] = {}
    depth, cur, parts = 0, "", []
    for ch in sig:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        parts.append(cur)
    for p in parts:
        if ":" not in p:
            continue
        nm, ty = p.split(":", 1)
        out[nm.strip().lstrip("%")] = parse_type(ty.strip())
    return out


def _operand_names(rest: str) -> tuple[list[str], str, str]:
    """Split 'a, %b), attr=..' -> (operand refs, attr tail, raw operands)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                ops_txt, attrs = rest[:i], rest[i + 1:]
                names = re.findall(r"%([\w.\-]+)", ops_txt)
                return names, attrs, ops_txt
    return re.findall(r"%([\w.\-]+)", rest), "", rest


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _COMP_HEAD.match(lines[i])
        if not m:
            i += 1
            continue
        name, sig, _ = m.group(1), m.group(2), m.group(3)
        if lines[i].startswith("ENTRY"):
            entry = name
        params = _split_params(sig)
        ops: list[Op] = []
        symbols: dict[str, Any] = dict(params)
        i += 1
        while i < len(lines) and not lines[i].startswith("}"):
            om = _OP_LINE.match(lines[i])
            if om:
                is_root = bool(om.group(1))
                nm = om.group(2)
                ty = parse_type(om.group(3).strip())
                opcode = om.group(4)
                operands, attrs, raw = _operand_names(om.group(5))
                op = Op(nm, ty, opcode, operands, attrs, is_root, raw)
                ops.append(op)
                symbols[nm] = ty
            i += 1
        comps[name] = Computation(name, params, ops, symbols)
        i += 1
    return comps, entry


# ---------------------------------------------------------------------------
# Cost accumulation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_dci_bytes: float = 0.0
    coll_by_type: dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: float = 0.0
    bytes_by_op: dict[str, float] = dataclasses.field(default_factory=dict)
    while_trips: list[int] = dataclasses.field(default_factory=list)
    warnings: list[str] = dataclasses.field(default_factory=list)

    def tally(self, opcode: str, nbytes: float):
        self.hbm_bytes += nbytes
        self.bytes_by_op[opcode] = self.bytes_by_op.get(opcode, 0.0) + nbytes

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.hbm_bytes += other.hbm_bytes * times
        self.coll_bytes += other.coll_bytes * times
        self.coll_dci_bytes += other.coll_dci_bytes * times
        self.coll_count += other.coll_count * times
        for k, v in other.coll_by_type.items():
            self.coll_by_type[k] = self.coll_by_type.get(k, 0.0) + v * times
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * times
        self.warnings.extend(other.warnings)
        self.while_trips.extend(other.while_trips)


_ATTR_REFS = re.compile(
    r"(calls|body|condition|to_apply|branch_computations)="
    r"(?:\{([^}]*)\}|%([\w.\-]+))"
)
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{(.*?)\}\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=(\S+?)[,\s]")
_SRC_TGT = re.compile(r"source_target_pairs=\{(.*?)\}\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def _group_info(attrs: str, total_devices: int, pod: int):
    """(group_size, crosses_pod) from replica_groups attrs."""
    m = _GROUPS_EXPL.search(attrs)
    if m:
        groups = m.group(1).split("},{")
        crosses = False
        gsize = 1
        for g in groups:
            ids = [int(x) for x in re.findall(r"\d+", g)]
            gsize = max(gsize, len(ids))
            if ids and max(ids) // pod != min(ids) // pod:
                crosses = True
        return gsize, crosses
    m = _GROUPS_IOTA.search(attrs + " ")
    if m:
        rows, cols, tail = int(m.group(1)), int(m.group(2)), m.group(3)
        if "T(" in tail or "(" in tail:
            # transposed iota: strided groups — conservatively mark as
            # crossing only if the stride pattern can span a pod
            return cols, total_devices > pod
        crosses = any(
            (g * cols) // pod != (g * cols + cols - 1) // pod
            for g in range(rows)
        )
        return cols, crosses
    m = _SRC_TGT.search(attrs)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", "{" + m.group(1) + "}")
        crosses = any(int(a) // pod != int(b) // pod for a, b in pairs)
        return 2, crosses
    return total_devices, total_devices > pod


def _collective_wire_bytes(opcode: str, out_bytes: int, gsize: int) -> float:
    g = max(gsize, 1)
    base = opcode.replace("-start", "")
    if base == "all-gather":
        return out_bytes * (g - 1) / g
    if base == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if base == "reduce-scatter":
        return out_bytes * (g - 1)
    if base == "all-to-all":
        return out_bytes * (g - 1) / g
    if base == "collective-permute":
        return float(out_bytes)
    return float(out_bytes)


def _while_trip_count(cond: Computation) -> int | None:
    """jax scans lower to `while (counter < N)`: read N from the condition."""
    consts: dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            mm = re.search(r"(-?\d+)", op.raw_operands)
            if mm:
                consts[op.name] = int(mm.group(1))
    root = next((o for o in cond.ops if o.is_root), None)
    if root is not None and root.opcode == "compare":
        for nm in root.operands:
            if nm in consts:
                return max(consts[nm], 1)
    # condition may be a fusion wrapping the compare; fall back to the
    # largest integer constant in the computation
    if consts:
        return max(max(consts.values()), 1)
    return None


_LAYOUT_OPS = {
    "parameter", "convert", "copy", "transpose", "bitcast", "reshape",
    "get-tuple-element", "tuple", "constant",
}


class HloCostModel:
    """TPU-semantics byte model: CPU-XLA materializes bf16->f32 converts
    and layout copies that the TPU fuses into MXU dots.  Layout-only
    fusions/ops are charged zero; consumers charge the *source* width
    resolved through the convert chain."""

    def __init__(self, text: str, *, total_devices: int, pod_size: int = 256):
        self.comps, self.entry = parse_module(text)
        self.total_devices = total_devices
        self.pod = pod_size
        self._memo: dict[str, Cost] = {}
        self._layout_comp: dict[str, bool] = {}
        self._producers: dict[str, dict[str, Op]] = {}
        # byte-width overrides for values whose true source is narrower
        # (e.g. while-carried f32 copies of bf16 weights hoisted by the
        # CPU backend): comp name -> {value name -> bytes}
        self._width_override: dict[str, dict[str, float]] = {}
        for _ in range(3):  # propagate through nested scans
            self._resolve_while_carries()

    def _shape_of(self, comp: Computation, name: str):
        t = comp.symbols.get(name)
        return t

    def _is_layout_comp(self, name: str) -> bool:
        if name in self._layout_comp:
            return self._layout_comp[name]
        comp = self.comps.get(name)
        ok = comp is not None and all(
            o.opcode in _LAYOUT_OPS for o in comp.ops
        )
        self._layout_comp[name] = ok
        return ok

    def _producer(self, comp: Computation, name: str) -> Op | None:
        prod = self._producers.get(comp.name)
        if prod is None:
            prod = {o.name: o for o in comp.ops}
            self._producers[comp.name] = prod
        return prod.get(name)

    def _resolve_while_carries(self):
        """For every while op, resolve each carried tuple element back to
        its initializer in the calling computation and record the narrower
        width for the body/cond computations' GTE values."""
        for comp in list(self.comps.values()):
            for op in comp.ops:
                if op.opcode != "while":
                    continue
                refs = {
                    am.group(1): (am.group(3) or am.group(2))
                    for am in _ATTR_REFS.finditer(op.attrs)
                }
                if not op.operands:
                    continue
                init = self._producer(comp, op.operands[0])
                if init is None or init.opcode != "tuple":
                    continue
                elem_bytes = [
                    self._resolved_bytes(comp, o) for o in init.operands
                ]
                for target in (refs.get("body"), refs.get("condition")):
                    tgt = self.comps.get(target or "")
                    if tgt is None:
                        continue
                    ov = self._width_override.setdefault(tgt.name, {})
                    for o2 in tgt.ops:
                        if o2.opcode != "get-tuple-element":
                            continue
                        mi = re.search(r"index=(\d+)", o2.attrs)
                        if not mi:
                            continue
                        idx = int(mi.group(1))
                        if idx < len(elem_bytes):
                            declared = _flat_bytes(
                                o2.out_type
                            ) if isinstance(o2.out_type, Shape) else None
                            if declared is not None:
                                ov[o2.name] = min(
                                    declared, elem_bytes[idx]
                                )

    def _resolved_bytes(self, comp: Computation, name: str,
                        depth: int = 0) -> float:
        """Operand bytes as TPU traffic: resolve through layout-only
        converts/copies to the narrowest source along the chain."""
        ov = self._width_override.get(comp.name, {}).get(name)
        t = self._shape_of(comp, name)
        here = _flat_bytes(t) if t is not None else 0.0
        if ov is not None:
            here = min(here, ov)
        if depth > 8:
            return here
        op = self._producer(comp, name)
        if op is None:
            return here
        src = None
        if op.opcode in ("convert", "copy", "transpose", "bitcast",
                         "reshape") and op.operands:
            src = op.operands[0]
        elif op.opcode in ("fusion", "call"):
            m = re.search(r"(?:calls|to_apply)=%([\w.\-]+)", op.attrs)
            if m and self._is_layout_comp(m.group(1)) and op.operands:
                # single-input layout fusion/call: step through
                big = max(
                    op.operands,
                    key=lambda o: _flat_bytes(
                        self._shape_of(comp, o) or Shape("opaque", ())
                    ),
                )
                src = big
        if src is not None:
            return min(here, self._resolved_bytes(comp, src, depth + 1))
        return here

    def _operand_bytes(self, comp: Computation, op: Op) -> float:
        return sum(self._resolved_bytes(comp, o) for o in op.operands)

    def _is_source_read(self, comp: Computation, name: str,
                        depth: int = 0) -> bool:
        """True if the value is (a layout-chain view of) an HBM-resident
        input: computation parameter, while carry, or constant.  Reads of
        such values are charged at consumers; intermediate values are
        charged once at their producer (write-once model)."""
        if depth > 8:
            return False
        op = self._producer(comp, name)
        if op is None:
            return True  # computation parameter
        if op.opcode in ("parameter", "get-tuple-element", "constant",
                         "iota"):
            return True
        if op.opcode in ("convert", "copy", "bitcast", "transpose",
                         "reshape") and op.operands:
            return self._is_source_read(comp, op.operands[0], depth + 1)
        if op.opcode in ("fusion", "call"):
            m = re.search(r"(?:calls|to_apply)=%([\w.\-]+)", op.attrs)
            if m and self._is_layout_comp(m.group(1)) and op.operands:
                big = max(
                    op.operands,
                    key=lambda o: _flat_bytes(
                        self._shape_of(comp, o) or Shape("opaque", ())
                    ),
                )
                return self._is_source_read(comp, big, depth + 1)
        return False

    def _source_read_bytes(self, comp: Computation, op: Op) -> float:
        return sum(
            self._resolved_bytes(comp, o)
            for o in op.operands
            if self._is_source_read(comp, o)
        )

    def _fusion_read_bytes(self, comp: Computation, op: Op,
                           fused: Computation) -> float:
        """HBM reads of a fusion: per fused-computation parameter, if the
        parameter is only consumed (through layout ops) by dynamic-slices,
        the fusion reads just the slices — not the whole (possibly
        stacked-over-layers) operand."""
        uses: dict[str, list[Op]] = {}
        dus_full_elems: list[int] = []
        for fop in fused.ops:
            for o in fop.operands:
                uses.setdefault(o, []).append(fop)
            if fop.opcode == "dynamic-update-slice" and isinstance(
                fop.out_type, Shape
            ):
                dus_full_elems.append(fop.out_type.size)

        # parameter(k) order matches operand order
        def param_index(fop: Op) -> int:
            m = re.search(r"^(\d+)", fop.raw_operands)
            return int(m.group(1)) if m else 0

        total = 0.0
        for fop in fused.ops:
            if fop.opcode != "parameter":
                continue
            idx = param_index(fop)
            if idx >= len(op.operands):
                continue
            if not self._is_source_read(comp, op.operands[idx]):
                continue  # intermediate: charged at its producer
            declared = _flat_bytes(fop.out_type) if isinstance(
                fop.out_type, Shape) else 0.0
            # DUS-aliased param (in-place cache update): skip the full read
            if isinstance(fop.out_type, Shape) and dus_full_elems and any(
                fop.out_type.size == f for f in dus_full_elems
            ):
                continue
            resolved = self._resolved_bytes(comp, op.operands[idx])
            charge = min(declared, resolved) if declared else resolved
            # walk through layout chains to terminal consumers
            frontier, terminals, seen = [fop.name], [], set()
            while frontier:
                nm = frontier.pop()
                if nm in seen:
                    continue
                seen.add(nm)
                for user in uses.get(nm, []):
                    if user.opcode in ("convert", "copy", "bitcast",
                                       "transpose", "reshape"):
                        frontier.append(user.name)
                    else:
                        terminals.append(user)
            if terminals and all(
                t.opcode in ("dynamic-slice", "gather") for t in terminals
            ) and declared and isinstance(fop.out_type, Shape) \
                    and fop.out_type.size:
                per_elem = charge / fop.out_type.size
                slice_elems = sum(
                    (t.out_type.size if isinstance(t.out_type, Shape)
                     else 0) for t in terminals
                )
                charge = min(charge, slice_elems * per_elem)
            total += charge
        return total

    def _fusion_dus_sizes(self, tgt: str) -> tuple[float, float]:
        """(sum of DUS full-buffer ELEMENT counts, sum of DUS update-slice
        ELEMENT counts) inside a fused computation — element counts avoid
        dtype-width confusion from CPU-backend f32 staging."""
        fused = self.comps.get(tgt)
        if fused is None:
            return 0.0, 0.0
        full = upd = 0.0
        for fop in fused.ops:
            if fop.opcode != "dynamic-update-slice":
                continue
            if isinstance(fop.out_type, Shape):
                full += fop.out_type.size
            u = (
                self._shape_of(fused, fop.operands[1])
                if len(fop.operands) > 1 else None
            )
            if isinstance(u, Shape):
                upd += u.size
        return full, upd

    _READ_ONLY_OPS = {
        "dynamic-slice", "select", "broadcast", "compare", "and", "or",
        "not", "concatenate",
    }

    def _is_read_fusion(self, tgt: str) -> bool:
        """Fusions whose non-layout work is only slicing/masking: on TPU
        these fuse into the consuming dot — no materialized output."""
        fused = self.comps.get(tgt)
        if fused is None:
            return False
        return all(
            o.opcode in _LAYOUT_OPS or o.opcode in self._READ_ONLY_OPS
            for o in fused.ops
        )

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        cost = Cost()
        self._memo[name] = cost  # break cycles
        if comp is None:
            cost.warnings.append(f"missing computation {name}")
            return cost
        for op in comp.ops:
            self._op_cost(comp, op, cost)
        return cost

    def _dot_flops(self, comp: Computation, op: Op) -> float:
        out = op.out_type
        out_size = out.size if isinstance(out, Shape) else _flat_bytes(out)
        lhs = self._shape_of(comp, op.operands[0]) if op.operands else None
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        k = 1
        if lhs is not None and isinstance(lhs, Shape) and m and m.group(1):
            for d in m.group(1).split(","):
                di = int(d)
                if di < len(lhs.dims):
                    k *= lhs.dims[di]
        return 2.0 * out_size * k

    def _op_cost(self, comp: Computation, op: Op, cost: Cost):
        refs = dict()
        for am in _ATTR_REFS.finditer(op.attrs):
            key = am.group(1)
            val = am.group(3) or am.group(2)
            refs[key] = val

        if op.opcode == "while":
            body = refs.get("body")
            cond = refs.get("condition")
            trips = None
            if cond and cond in self.comps:
                trips = _while_trip_count(self.comps[cond])
            if trips is None:
                trips = 1
                cost.warnings.append(f"unknown trip count for {op.name}")
            cost.while_trips.append(trips)
            if body:
                cost.add(self.comp_cost(body), trips)
            if cond:
                cost.add(self.comp_cost(cond), trips)
            return

        if op.opcode in ("call", "async-start"):
            tgt = refs.get("calls") or refs.get("to_apply")
            if tgt:
                if self._is_layout_comp(tgt):
                    # CPU-XLA wraps parallelized converts/copies in a
                    # `call` (e.g. %parallel_convert): pure layout work
                    # that the TPU fuses into the consumer — charge zero
                    # (consumers resolve through it to the source width)
                    return
                cost.add(self.comp_cost(tgt))
            return

        if op.opcode == "conditional":
            branches = refs.get("branch_computations", "")
            names = re.findall(r"%([\w.\-]+)", branches)
            if names:
                sub = [self.comp_cost(n) for n in names]
                # assume worst-case branch
                worst = max(sub, key=lambda c: c.flops + c.hbm_bytes)
                cost.add(worst)
            return

        if op.opcode in _COLL_OPS:
            out_b = _flat_bytes(op.out_type)
            gsize, crosses = _group_info(
                op.attrs, self.total_devices, self.pod
            )
            wire = _collective_wire_bytes(op.opcode, out_b, gsize)
            base = op.opcode.replace("-start", "")
            cost.coll_bytes += wire
            cost.coll_by_type[base] = cost.coll_by_type.get(base, 0.0) + wire
            cost.coll_count += 1
            if crosses:
                cost.coll_dci_bytes += wire
            cost.tally(base, out_b)  # collective also touches HBM
            return

        if op.opcode == "fusion":
            tgt = refs.get("calls")
            dus_full_el = dus_upd_el = 0.0
            read_only = False
            if tgt:
                if self._is_layout_comp(tgt):
                    return  # TPU fuses pure layout/convert chains
                sub = self.comp_cost(tgt)
                cost.flops += sub.flops  # dots inside fusions
                cost.coll_bytes += sub.coll_bytes
                cost.coll_dci_bytes += sub.coll_dci_bytes
                dus_full_el, dus_upd_el = self._fusion_dus_sizes(tgt)
                read_only = self._is_read_fusion(tgt)
            fused = self.comps.get(tgt) if tgt else None
            reads = (
                self._fusion_read_bytes(comp, op, fused)
                if fused is not None else self._source_read_bytes(comp, op)
            )
            out_b = _flat_bytes(op.out_type)
            out_el = (
                op.out_type.size if isinstance(op.out_type, Shape) else 0
            )
            if read_only:
                write = 0.0  # fuses into the consuming dot on TPU
            elif dus_full_el and out_el:
                # in-place DUS: the aliased buffer is neither read nor
                # written wholesale — only the update slices move
                per_el = out_b / out_el
                write = (
                    max(out_el - dus_full_el, 0.0) + 2.0 * dus_upd_el
                ) * per_el
            else:
                write = out_b
            cost.tally("fusion", reads + write)
            return

        if op.opcode == "dot":
            cost.flops += self._dot_flops(comp, op)
            cost.tally(
                "dot",
                self._source_read_bytes(comp, op) + _flat_bytes(op.out_type),
            )
            return

        if op.opcode in _SKIP_BYTES_OPS:
            return

        if op.opcode in ("convert", "copy", "transpose", "reshape"):
            # Bare layout/precision staging.  Single-core CPU XLA emits
            # these unfused at ENTRY level (multi-core hosts wrap them in
            # %parallel_* calls, zero-charged above); consumers already
            # resolve through the chain to the source width, so charging
            # here would double-count traffic the TPU never issues.
            return

        if op.opcode == "dynamic-update-slice":
            # in-place in practice: traffic = update slice (read + write)
            upd = (
                self._shape_of(comp, op.operands[1])
                if len(op.operands) > 1 else None
            )
            ub = _flat_bytes(upd) if upd is not None else 0
            cost.tally("dynamic-update-slice", 2.0 * ub)
            return

        if op.opcode in ("dynamic-slice", "gather", "slice"):
            # reads only the slice, not the (stacked) source operand
            mult = 2.0 if any(
                self._is_source_read(comp, o) for o in op.operands[:1]
            ) else 1.0
            cost.tally(op.opcode, mult * _flat_bytes(op.out_type))
            return

        if op.opcode == "convolution":
            cost.warnings.append("convolution flops not modeled")

        # default (write-once model): output write + source reads
        cost.tally(
            op.opcode,
            self._source_read_bytes(comp, op) + _flat_bytes(op.out_type),
        )

    def entry_cost(self) -> Cost:
        return self.comp_cost(self.entry)


def entry_boundary_bytes(text: str, field_shape: tuple[int, ...]) -> dict:
    """Launch-boundary traffic of a compiled module for one array shape.

    Sums the bytes of ENTRY parameters and results whose trailing dims
    equal ``field_shape`` — the data that must round-trip HBM between
    kernel launches no matter how well the interior fuses.  This is the
    HBM-traffic proxy for fused multi-step engines: a k-step fused block
    moves the wavefields across the boundary once per k steps, so its
    per-step boundary bytes drop k× vs the step-at-a-time engine
    (DESIGN.md §13; the per-op ``cost_analysis`` sum cannot see this —
    it charges intermediates identically inside and outside the fused
    region).  Returns {"param_bytes", "result_bytes", "total_bytes",
    "n_params", "n_results"}.
    """
    comps, entry = parse_module(text)
    c = comps[entry]
    tail = tuple(field_shape)

    def field_bytes(t) -> tuple[int, int]:
        if isinstance(t, Shape):
            match = len(t.dims) >= len(tail) and \
                tuple(t.dims[-len(tail):]) == tail
            return (t.bytes, 1) if match else (0, 0)
        pairs = [field_bytes(x) for x in t]
        return sum(b for b, _ in pairs), sum(n for _, n in pairs)

    pb = cn = 0
    for t in c.params.values():
        b, n = field_bytes(t)
        pb += b
        cn += n
    rb = rn = 0
    root = next((o for o in c.ops if o.is_root), None)
    if root is not None:
        rb, rn = field_bytes(root.out_type)
    return {
        "param_bytes": pb, "result_bytes": rb,
        "total_bytes": pb + rb, "n_params": cn, "n_results": rn,
    }


def shot_batch_strip_bytes(nz: int, nx: int, s: int, k: int = 1,
                           dtype_bytes: int = 4) -> dict:
    """Analytic per-strip-sweep HBM traffic of the shot-batched stencil
    engine vs the vmapped per-shot path (DESIGN.md §17).

    One k-step sweep over the grid reads the two wavefields and writes
    both outputs PER SHOT, but the two read-only model fields
    (``v2dt2``, ``sponge``) are shared: the vmapped per-shot engine
    re-streams them once per shot (``4·S`` array reads), the batched
    engine charges them once (``2·S + 2`` reads).  Writes are ``2·S``
    either way.  Returns the array counts, the byte totals, and
    ``traffic_ratio`` = vmapped/batched bytes — the model's upper bound
    on the batched speedup of a purely memory-bound sweep (≈ 4/3 at
    S=4, → 3/2 as S → ∞)."""
    field = nz * nx * dtype_bytes
    vm_reads, bt_reads = 4 * s, 2 * s + 2
    writes = 2 * s
    vm = (vm_reads + writes) * field
    bt = (bt_reads + writes) * field
    return {
        "field_bytes": field,
        "vmapped_read_arrays": vm_reads,
        "batched_read_arrays": bt_reads,
        "write_arrays": writes,
        "vmapped_bytes": vm,
        "batched_bytes": bt,
        "traffic_ratio": vm / bt,
        "launches_vmapped": s,          # grid passes per block
        "launches_batched": 1,
        "k": k,
        "s": s,
    }


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across JAX versions —
    older releases return a one-dict-per-partition list, newer ones a
    plain dict.  Callers always get the dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca


def analyze(text: str, *, total_devices: int, pod_size: int = 256) -> dict:
    model = HloCostModel(text, total_devices=total_devices, pod_size=pod_size)
    c = model.entry_cost()
    return {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "collective_bytes": c.coll_bytes,
        "collective_dci_bytes": c.coll_dci_bytes,
        "collective_by_type": {k: float(v) for k, v in c.coll_by_type.items()},
        "collective_count": c.coll_count,
        "while_trips": sorted(set(c.while_trips)),
        "warnings": sorted(set(c.warnings))[:10],
    }
