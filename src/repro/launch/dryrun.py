import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices to build the
production meshes.  Nothing else in the repo sets this flag — smoke tests
and benches see the real device count.

Per cell this produces a JSON artifact with:
  - compiled memory_analysis (per-device bytes vs the 16 GiB v5e budget)
  - cost_analysis FLOPs / bytes
  - collective operand bytes parsed from the compiled HLO (ICI vs DCI)
  - the three roofline terms + dominant bottleneck (launch/roofline.py)
  - MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (serve) and the
    useful-compute ratio

Usage:
  python -m repro.launch.dryrun --all                      # full matrix
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --summarize                # markdown table
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (
    ALL_ARCHS,
    RunConfig,
    SHAPES,
    cell_is_runnable,
    get_config,
    input_specs,
)
from repro.launch.hlo_cost import analyze as hlo_analyze, xla_cost_analysis
from repro.launch.hw import TPU_V5E
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops, roofline_terms
from repro.models import model as M
from repro.optim import make_optimizer, warmup_cosine
from repro.runtime import serve_step, train_step as ts
from repro.sharding.rules import (
    abstract_params,
    cast_schema,
    make_rules,
    param_shardings,
)

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# Per-arch train microbatch (global): bounds live activations per µ-step.
TRAIN_MICROBATCH = {
    "granite-8b": 64, "yi-6b": 64, "yi-9b": 32, "minitron-8b": 64,
    "qwen2-vl-72b": 16, "deepseek-v2-236b": 16, "deepseek-v3-671b": 32,
    "whisper-large-v3": None, "mamba2-370m": 64, "jamba-v0.1-52b": 16,
}

# Megatron-SP residuals for the big models (remat stash /16; §Perf A)
SEQ_SHARD = {"deepseek-v2-236b", "deepseek-v3-671b", "qwen2-vl-72b",
             "jamba-v0.1-52b", "whisper-large-v3"}


# ≥200B models accumulate grads in bf16 (param-sized fp32 accumulators
# would not fit pod HBM; Adafactor/8-bit moments tolerate bf16 grads).
BF16_GRADS = {"deepseek-v2-236b", "deepseek-v3-671b"}


def run_config(cfg, shape) -> RunConfig:
    return RunConfig(
        microbatch=TRAIN_MICROBATCH.get(cfg.name, 64)
        if shape.kind == "train" else None,
        grad_dtype="bfloat16" if cfg.name in BF16_GRADS else "float32",
        seq_shard=cfg.name in SEQ_SHARD and shape.kind == "train",
        loss_chunk=512,
    )


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (jitted_fn, example_args(abstract), donate) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    run = run_config(cfg, shape)
    rules = make_rules(
        mesh, "train" if SHAPES[shape_name].kind == "train" else "serve",
        flat_dp=cfg.flat_dp,
    )
    if run.seq_shard:
        rules = dataclasses.replace(
            rules, rules={**rules.rules, "seq_res": (("model",),)}
        )
    in_specs = input_specs(cfg, shape)

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer, warmup_cosine())
        sch = ts.state_schema(cfg, run, opt)
        state_abs = abstract_params(sch)
        state_sh = ts.state_shardings(sch, rules, run)
        batch_sh = ts.batch_shardings(in_specs, rules)
        fn = ts.build_train_step(cfg, run, opt, rules)
        jf = jax.jit(
            fn,
            in_shardings=(state_sh, batch_sh),
            donate_argnums=(0,),
        )
        return jf, (state_abs, in_specs)

    # serving weights are bf16 (inference-cast), matching real deployments
    psch = cast_schema(M.schema(cfg), jnp.bfloat16)
    params_abs = abstract_params(psch)
    params_sh = param_shardings(psch, rules)
    input_sh = serve_step.serve_input_shardings(in_specs, rules)

    if shape.kind == "prefill":
        fn = serve_step.build_prefill(cfg, rules)
        jf = jax.jit(fn, in_shardings=(params_sh, input_sh))
        return jf, (params_abs, in_specs)

    # decode
    cache_sch = M.cache_schema(cfg, shape.global_batch, shape.seq_len)
    cache_abs = abstract_params(cache_sch)
    cache_sh = param_shardings(cache_sch, rules)
    fn = serve_step.build_decode(cfg, rules)
    jf = jax.jit(
        fn,
        in_shardings=(params_sh, cache_sh, input_sh),
        donate_argnums=(1,),
    )
    return jf, (params_abs, cache_abs, in_specs)


def _mem_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}
    out = {}
    for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["peak_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                out_dir: Path = ARTIFACTS, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind,
    }
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        _write(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        jf, args = build_cell(arch, shape_name, mesh)
        lowered = jf.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()[-4000:]
        _write(rec, out_dir)
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: "
                  f"ERROR {e!r}", flush=True)
        return rec

    # NOTE: compiled.cost_analysis() counts while bodies ONCE — with
    # scan-over-layers that undercounts ~num_layers×.  launch/hlo_cost.py
    # multiplies trip counts; raw values kept for reference.
    ca = xla_cost_analysis(compiled) or {}
    hlo = compiled.as_text()
    t0 = time.time()
    hc = hlo_analyze(hlo, total_devices=chips, pod_size=256)
    t_analyze = time.time() - t0
    flops = hc["flops"]
    bytes_acc = hc["hbm_bytes"]
    mem = _mem_analysis_dict(compiled)
    rl = roofline_terms(flops, bytes_acc, hc)

    total, active = M.param_counts(cfg)
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind in ("train", "prefill") else 1
    )
    mf = model_flops(active, tokens, train=shape.kind == "train")
    mf_per_dev = mf / chips

    rec.update({
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "analyze_s": round(t_analyze, 2),
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_acc,
        "collectives": {
            "total_bytes": hc["collective_bytes"],
            "dci_bytes": hc["collective_dci_bytes"],
            "by_type": hc["collective_by_type"],
            "count": hc["collective_count"],
        },
        "while_trips": hc["while_trips"],
        "hlo_warnings": hc["warnings"],
        "memory": mem,
        "roofline": rl,
        "params_total": total,
        "params_active": active,
        "tokens_per_step": tokens,
        "model_flops_per_dev": mf_per_dev,
        "useful_compute_ratio": mf_per_dev / flops if flops else 0.0,
        "hbm_budget_ok": mem.get("peak_bytes_per_device", 0)
        <= TPU_V5E.hbm_bytes,
        "xla_cost_analysis_raw": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
    })
    _write(rec, out_dir)
    if verbose:
        peak = mem.get("peak_bytes_per_device", 0) / 2**30
        print(
            f"[dryrun] {arch} × {shape_name} × {mesh_name}: ok "
            f"compile={t_compile:.1f}s dom={rl['dominant']} "
            f"frac={rl['roofline_fraction']:.3f} peak={peak:.2f}GiB",
            flush=True,
        )
    return rec


def _cell_path(rec: dict, out_dir: Path) -> Path:
    return out_dir / rec["mesh"] / rec["arch"] / f"{rec['shape']}.json"


def _write(rec: dict, out_dir: Path):
    p = _cell_path(rec, out_dir)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(rec, indent=1))


def load_all(out_dir: Path = ARTIFACTS) -> list[dict]:
    return [
        json.loads(p.read_text()) for p in sorted(out_dir.glob("*/*/*.json"))
    ]


def summarize(out_dir: Path = ARTIFACTS) -> str:
    rows = load_all(out_dir)
    lines = [
        "| arch | shape | mesh | status | dom | T_comp(s) | T_mem(s) | "
        "T_coll(s) | frac | useful | peak GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status']} | — | — | — | — | — | — | — | — |"
            )
            continue
        rl = r["roofline"]
        peak = r["memory"].get("peak_bytes_per_device", 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{rl['dominant']} | {rl['compute']:.4f} | {rl['memory']:.4f} | "
            f"{rl['collective']:.4f} | {rl['roofline_fraction']:.3f} | "
            f"{r['useful_compute_ratio']:.3f} | {peak:.2f} | "
            f"{'Y' if r['hbm_budget_ok'] else 'N'} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have artifacts")
    ap.add_argument("--summarize", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.summarize:
        print(summarize(out_dir))
        return

    archs = [args.arch] if args.arch else ALL_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh
    ]
    if not (args.all or args.arch or args.shape):
        ap.error("pass --all or --arch/--shape")

    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                rec = {
                    "arch": arch, "shape": shape,
                    "mesh": "multi" if multi else "single",
                }
                p = _cell_path(rec, out_dir)
                if p.exists() and not args.force:
                    prev = json.loads(p.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[dryrun] cached: {p}", flush=True)
                        continue
                dryrun_cell(arch, shape, multi, out_dir)


if __name__ == "__main__":
    main()
