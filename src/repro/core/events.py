"""Event injection + clocks: simulated heterogeneity and faults.

What is simulated vs real (DESIGN.md §10): on real hardware the monitor
consumes wall-clock step times; in this CPU container the same code paths
are driven by a SimClock whose step duration reflects a configurable
per-environment slowdown (the paper's cloud-vs-cluster K), injected
congestion windows, stragglers and node failures.  The *decision* code
never knows which clock it is on.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


class WallClock:
    def now(self) -> float:
        return time.monotonic()


@dataclasses.dataclass
class SlowdownWindow:
    start_step: int
    end_step: int
    factor: float                # multiply step time by this


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str = "node_down"      # node_down | preemption
    pod: int = 0


@dataclasses.dataclass
class DeadlineChange:
    step: int
    new_deadline_s: float


@dataclasses.dataclass(frozen=True)
class BackgroundLoad:
    """A background tenant occupying site chips over a wall-clock window.

    The fleet simulator sums active BackgroundLoads into site demand, so
    the paper's "cluster overloaded" condition emerges from contention
    (demand / capacity) instead of a scripted SlowdownWindow.
    """

    start_s: float
    end_s: float
    chips: int
    name: str = "tenant"


@dataclasses.dataclass
class SimEnvironment:
    """Synthetic step-time generator for one execution platform."""

    name: str
    base_chip_seconds_per_step: float     # work: chip·s per step at K=1
    chips: int
    slowdown: float = 1.0                 # the paper's K for this env
    jitter: float = 0.02
    windows: list[SlowdownWindow] = dataclasses.field(default_factory=list)

    def step_time(self, step: int, rng) -> float:
        t = self.base_chip_seconds_per_step / self.chips * self.slowdown
        for w in self.windows:
            if w.start_step <= step < w.end_step:
                t *= w.factor
        return t * (1.0 + self.jitter * float(rng.standard_normal()))


@dataclasses.dataclass
class SimCluster:
    """Hybrid platform: on-premise pod + optional burst pods, stepped
    synchronously (paper step 8: per-step synchronization) — the combined
    step time is the max over environments plus a sync cost."""

    envs: list[SimEnvironment]
    sync_overhead_s: float = 0.0
    failures: list[FailureEvent] = dataclasses.field(default_factory=list)

    def step_time(self, step: int, shares, rng) -> float:
        """shares: fraction of work per env (γ-split, sums to 1)."""
        times = []
        for env, share in zip(self.envs, shares):
            if share <= 0:
                continue
            t = (
                env.base_chip_seconds_per_step * share / env.chips
                * env.slowdown
            )
            for w in env.windows:
                if w.start_step <= step < w.end_step:
                    t *= w.factor
            t *= (1.0 + env.jitter * float(rng.standard_normal()))
            times.append(t)
        base = max(times) if times else 0.0
        return base + (self.sync_overhead_s if len(times) > 1 else 0.0)

    def failure_at(self, step: int) -> FailureEvent | None:
        for f in self.failures:
            if f.step == step:
                return f
        return None
