"""Elastic orchestrator — paper Fig. 1, steps 2-8 as a state machine.

    MONITOR -> DECIDE -> CHECKPOINT -> REMESH -> RESHARD -> RESUME

The orchestrator owns the loop; the workload is behind a small Session
protocol so the same machinery drives (a) the simulated hybrid cluster
used by the paper-reproduction benchmarks and (b) the real JAX training
session in launch/train.py (where REMESH = jax.make_mesh over the grown
device set and RESHARD = checkpoint restore under the new shardings).

Fault tolerance beyond the paper: periodic checkpoints, failure events
trigger a shrink-and-restart from the last checkpoint, sustained
straggling triggers a γ rebalance using freshly measured throughputs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Protocol

from repro.core.allocator import HeterogeneousPlan, heterogeneous_split
from repro.core.deadline import DeadlinePredictor
from repro.core.monitor import StepTimeMonitor
from repro.core.planner import BurstDecision, BurstPlanner


@dataclasses.dataclass
class PodSpec:
    chips: int
    slowdown: float = 1.0            # paper's K for this environment
    name: str = "pod"


@dataclasses.dataclass
class Resources:
    pods: list[PodSpec]
    shares: list[float]              # work share per pod (sums to 1)

    @property
    def total_chips(self) -> int:
        return sum(p.chips for p in self.pods)


class Session(Protocol):
    def run_step(self, step: int) -> float: ...
    def checkpoint(self, step: int) -> Any: ...


class PodFailure(RuntimeError):
    def __init__(self, pod: int, step: int):
        super().__init__(f"pod {pod} failed at step {step}")
        self.pod = pod
        self.step = step


@dataclasses.dataclass
class OrchestratorEvent:
    step: int
    kind: str                        # burst | failure | rebalance | ckpt
    detail: dict


@dataclasses.dataclass
class RunRecord:
    completed: bool
    steps: int
    elapsed_s: float
    deadline_s: float
    met_deadline: bool
    events: list[OrchestratorEvent]
    step_times: list[float]
    final_resources: Resources | None = None


SessionFactory = Callable[[Resources, int, Any], Session]


class ElasticOrchestrator:
    def __init__(
        self,
        *,
        planner: BurstPlanner,
        predictor: DeadlinePredictor,
        monitor: StepTimeMonitor | None = None,
        check_every: int = 8,
        ckpt_every: int = 50,
        max_bursts: int = 2,
        rebalance_straggler_rate: float = 0.2,
    ):
        self.planner = planner
        self.predictor = predictor
        self.monitor = monitor or StepTimeMonitor()
        self.check_every = check_every
        self.ckpt_every = ckpt_every
        self.max_bursts = max_bursts
        self.rebalance_straggler_rate = rebalance_straggler_rate

    # ---- the γ-split applied to resources --------------------------------

    @staticmethod
    def apply_burst(res: Resources, decision: BurstDecision) -> Resources:
        pods = list(res.pods) + [
            PodSpec(
                chips=decision.chips_burst,
                slowdown=max(decision.correction_K, 1e-6),
                name=f"burst{len(res.pods)}",
            )
        ]
        tps = [p.chips / p.slowdown for p in pods]
        total = sum(tps)
        return Resources(pods=pods, shares=[t / total for t in tps])

    @staticmethod
    def rebalanced(res: Resources, measured_tps: list[float]) -> Resources:
        total = sum(measured_tps)
        if total <= 0:
            return res
        return Resources(
            pods=list(res.pods), shares=[t / total for t in measured_tps]
        )

    def split_plan(self, res: Resources, global_batch: int,
                   microbatch: int, seq_len: int) -> HeterogeneousPlan:
        return heterogeneous_split(
            global_batch=global_batch,
            microbatch=microbatch,
            seq_len=seq_len,
            throughputs=[p.chips / p.slowdown for p in res.pods],
        )

    # ---- main loop --------------------------------------------------------

    def run(
        self,
        *,
        session_factory: SessionFactory,
        initial: Resources,
        steps_total: int,
        overhead_s_fn: Callable[[BurstDecision], float] | None = None,
    ) -> RunRecord:
        res = initial
        session = session_factory(res, 0, None)
        elapsed = 0.0
        events: list[OrchestratorEvent] = []
        step_times: list[float] = []
        bursts_done = 0
        last_ckpt: Any = None
        last_ckpt_step = -1
        step = 0
        while step < steps_total:
            try:
                dt = session.run_step(step)
            except PodFailure as f:
                # fault tolerance: drop the failed pod, restart from the
                # last checkpoint (re-running the lost steps)
                events.append(OrchestratorEvent(
                    step, "failure", {"pod": f.pod}
                ))
                pods = [p for i, p in enumerate(res.pods) if i != f.pod]
                tps = [p.chips / p.slowdown for p in pods]
                res = Resources(
                    pods=pods, shares=[t / sum(tps) for t in tps]
                )
                restart = max(last_ckpt_step + 1, 0)
                elapsed += self.planner.overheads.restart_s
                session = session_factory(res, restart, last_ckpt)
                self.monitor.reset_window()
                step = restart
                continue
            self.monitor.observe(dt)
            elapsed += dt
            step_times.append(dt)
            step += 1

            if step % self.ckpt_every == 0:
                last_ckpt = session.checkpoint(step)
                last_ckpt_step = step
                events.append(OrchestratorEvent(step, "ckpt", {}))

            if step % self.check_every or step >= steps_total:
                continue

            est = self.predictor.estimate(
                self.monitor, step, steps_total, elapsed
            )
            eff_chips = sum(p.chips / p.slowdown for p in res.pods)
            decision = self.planner.plan(
                est, step, steps_total,
                observed_step_s=self.monitor.step_time(),
                effective_chips=eff_chips,
            )
            if decision.burst and bursts_done < self.max_bursts:
                # Fig.1 steps 2,5: save state, move it to the new nodes
                last_ckpt = session.checkpoint(step)
                last_ckpt_step = step
                overhead = (
                    overhead_s_fn(decision) if overhead_s_fn
                    else decision.overhead_s
                )
                elapsed += overhead
                # steps 3,4: expand resources with the γ split
                res = self.apply_burst(res, decision)
                # steps 6,7: assimilate state, restart at the stopped step
                session = session_factory(res, step, last_ckpt)
                self.monitor.reset_window()
                bursts_done += 1
                events.append(OrchestratorEvent(
                    step, "burst",
                    {
                        "chips": decision.chips_burst,
                        "K": decision.correction_K,
                        "overhead_s": overhead,
                        "est_stay": decision.est_time_stay_s,
                        "est_burst": decision.est_time_burst_s,
                        "shares": list(res.shares),
                    },
                ))
            elif (
                self.monitor.straggler_rate() > self.rebalance_straggler_rate
                and len(res.pods) > 1
            ):
                # straggler mitigation: shift γ toward healthy pods using
                # measured (not nominal) throughput
                tps = [p.chips / p.slowdown for p in res.pods]
                res = self.rebalanced(res, tps)
                session = session_factory(res, step, session.checkpoint(step))
                events.append(OrchestratorEvent(
                    step, "rebalance", {"shares": list(res.shares)}
                ))

        return RunRecord(
            completed=True,
            steps=steps_total,
            elapsed_s=elapsed,
            deadline_s=self.predictor.deadline_s,
            met_deadline=elapsed <= self.predictor.deadline_s,
            events=events,
            step_times=step_times,
            final_resources=res,
        )
