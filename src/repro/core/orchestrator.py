"""Elastic orchestrator — paper Fig. 1, steps 2-8 as a state machine.

    MONITOR -> DECIDE -> CHECKPOINT -> REMESH -> RESHARD -> RESUME

The orchestrator owns the loop; the workload is behind a small Session
protocol so the same machinery drives (a) the simulated hybrid cluster
used by the paper-reproduction benchmarks and (b) the real JAX training
session in launch/train.py (where REMESH = jax.make_mesh over the grown
device set and RESHARD = checkpoint restore under the new shardings).

Fault tolerance beyond the paper: periodic checkpoints, failure events
trigger a shrink-and-restart from the last checkpoint, sustained
straggling triggers a γ rebalance using freshly measured throughputs.

Beyond the paper's one-shot burst (its §4 names "scaling down" as future
work), the loop can be driven by an external *autoscaler policy* that is
consulted on a fixed check interval and answers with a ScaleAction —
GROW the elastic pod to a target slice, SHRINK it to a smaller one,
RETIRE it entirely, or HOLD.  Every transition goes through the
identical CHECKPOINT → REMESH → RESHARD → RESUME path as the paper's
burst, so growing and shrinking are symmetric and checkpoint/restore
invariants hold across both (DESIGN.md §8, §11).

Real-session elastic loop (DESIGN.md §14): the policy-driven mode is the
same machinery the fleet simulator evaluates, pointed at a *real*
Session (FWISession) —

  * ``eval_interval_s`` evaluates the policy on the session's clock
    (the elapsed time the monitor integrates) instead of a step count,
    matching the fleet's fixed-interval evaluation semantics;
  * ``deadline_changes`` applies mid-run deadline tightenings /
    relaxations first-class (paper §2: the deadline "could also change
    dynamically"), recorded into the predictor's history;
  * ``cloud_slowdown`` is the provider's *true* K stamped onto grown
    pods regardless of what the policy believed when sizing — the same
    sim-vs-real boundary the fleet's provision handler enforces;
  * elastic chip-seconds actually held are metered (``cloud_chip_s``)
    and priced through the planner's ``price_per_chip_hour``, so a real
    run reports the same hit-rate/cost/overhead axes as a FleetSim run.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Protocol, Sequence

import numpy as np

from repro.core.allocator import (
    HeterogeneousPlan,
    heterogeneous_split,
    proportional_shares,
)
from repro.core.deadline import DeadlineEstimate, DeadlinePredictor
from repro.core.monitor import StepTimeMonitor
from repro.core.planner import BurstDecision, BurstPlanner

#: pod-name prefixes that mark a pod as elastic (cloud-side, scalable);
#: everything else is the fixed on-premise allocation.
ELASTIC_PREFIXES = ("cloud", "burst")


@dataclasses.dataclass
class PodSpec:
    chips: int
    slowdown: float = 1.0            # paper's K for this environment
    name: str = "pod"


@dataclasses.dataclass
class Resources:
    pods: list[PodSpec]
    shares: list[float]              # work share per pod (sums to 1)

    @property
    def total_chips(self) -> int:
        return sum(p.chips for p in self.pods)


def elastic_chips(res: "Resources") -> int:
    """Chips currently held in elastic (cloud-side) pods."""
    return sum(
        p.chips for p in res.pods if p.name.startswith(ELASTIC_PREFIXES)
    )


@dataclasses.dataclass(frozen=True)
class ScaleAction:
    """One autoscaler verdict for the elastic pod.

    kind: "hold" | "grow" | "shrink" | "retire".  ``chips`` is the
    *target* elastic-pod size for grow/shrink (already legal-slice
    rounded by the policy); ``slowdown`` is the paper's K for chips
    provisioned by a grow.
    """

    kind: str
    chips: int = 0
    slowdown: float = 1.0
    reason: str = ""


HOLD = ScaleAction("hold")


@dataclasses.dataclass
class ScaleContext:
    """Everything a policy may look at when deciding (paper Fig. 1 inputs
    plus the fleet-level signals the paper's operator would eyeball)."""

    step: int
    steps_total: int
    elapsed_s: float
    est: DeadlineEstimate
    resources: "Resources"
    cloud_chips: int
    planner: BurstPlanner
    monitor: StepTimeMonitor
    legal: list[int]
    contention: float = 1.0          # site demand / capacity (>= 1)
    # ---- provider-health telemetry (DESIGN.md §19): lets a policy
    # hold off re-requesting from a provider that keeps denying it
    provision_failures: int = 0      # consecutive denials, 0 on success
    since_failure_s: float = math.inf  # time since the last denial


class AutoscalerPolicy(Protocol):
    """Interval-evaluated scaling policy (implementations: repro.sim)."""

    name: str

    def decide(self, ctx: ScaleContext) -> ScaleAction: ...


class Session(Protocol):
    def run_step(self, step: int) -> float: ...
    def checkpoint(self, step: int) -> Any: ...


class PodFailure(RuntimeError):
    def __init__(self, pod: int, step: int):
        super().__init__(f"pod {pod} failed at step {step}")
        self.pod = pod
        self.step = step


@dataclasses.dataclass
class OrchestratorEvent:
    step: int
    kind: str                        # burst | failure | rebalance | ckpt
    detail: dict


@dataclasses.dataclass
class RunRecord:
    completed: bool
    steps: int
    elapsed_s: float
    deadline_s: float
    met_deadline: bool
    events: list[OrchestratorEvent]
    step_times: list[float]
    final_resources: Resources | None = None
    cloud_chip_s: float = 0.0            # elastic chip-seconds held
    cloud_cost_usd: float = 0.0          # priced via planner ($/chip-h)
    retries: int = 0                     # provisioning denials (§19)
    gave_up: bool = False                # a grow was abandoned (§19)


SessionFactory = Callable[[Resources, int, Any], Session]


class ElasticOrchestrator:
    def __init__(
        self,
        *,
        planner: BurstPlanner,
        predictor: DeadlinePredictor,
        monitor: StepTimeMonitor | None = None,
        check_every: int = 8,
        ckpt_every: int = 50,
        max_bursts: int = 2,
        rebalance_straggler_rate: float = 0.2,
        eval_interval_s: float | None = None,
        cloud_slowdown: float | None = None,
        degraded_factor: float | None = None,
    ):
        self.planner = planner
        self.predictor = predictor
        self.monitor = monitor or StepTimeMonitor()
        self.check_every = check_every
        self.ckpt_every = ckpt_every
        self.max_bursts = max_bursts
        self.rebalance_straggler_rate = rebalance_straggler_rate
        #: evaluate decisions on the session clock every this many
        #: seconds instead of every ``check_every`` steps (fleet-style
        #: fixed-interval evaluation for real sessions, DESIGN.md §14)
        if eval_interval_s is not None and eval_interval_s <= 0:
            raise ValueError(
                f"eval_interval_s must be positive, got {eval_interval_s}"
            )
        self.eval_interval_s = eval_interval_s
        #: the provider's true K for grown pods — overrides whatever the
        #: policy believed when sizing (the sim-vs-real boundary the
        #: fleet's provision handler enforces, DESIGN.md §10)
        self.cloud_slowdown = cloud_slowdown
        #: degraded-pod detector (DESIGN.md §19): while elastic chips
        #: are held, a measured step time exceeding ``degraded_factor``
        #: × the planner's modeled step time forces a RETIRE so the
        #: loop re-stripes around the sick pod.  None disables it.
        self.degraded_factor = degraded_factor

    # ---- the γ-split applied to resources --------------------------------

    @staticmethod
    def apply_burst(res: Resources, decision: BurstDecision) -> Resources:
        pods = list(res.pods) + [
            PodSpec(
                chips=decision.chips_burst,
                slowdown=max(decision.correction_K, 1e-6),
                name=f"burst{len(res.pods)}",
            )
        ]
        shares = proportional_shares([p.chips / p.slowdown for p in pods])
        return Resources(pods=pods, shares=shares)

    @staticmethod
    def apply_scale(res: Resources, action: ScaleAction) -> Resources:
        """Resize the elastic pod to the action's target (γ re-split).

        grow/shrink converge on the same code path: set the single
        elastic pod to ``action.chips`` (creating it on first grow,
        keeping its measured K on resize) and recompute shares ∝
        chips/K.  retire (or a target of 0) drops every elastic pod and
        returns all work to the on-premise allocation.
        """
        if action.kind not in ("grow", "shrink", "retire"):
            return res
        fixed = [
            p for p in res.pods if not p.name.startswith(ELASTIC_PREFIXES)
        ]
        elastic = [
            p for p in res.pods if p.name.startswith(ELASTIC_PREFIXES)
        ]
        target = 0 if action.kind == "retire" else max(int(action.chips), 0)
        pods = list(fixed)
        if target > 0:
            slowdown = (
                elastic[0].slowdown if elastic
                else max(action.slowdown, 1e-6)
            )
            pods.append(PodSpec(chips=target, slowdown=slowdown,
                                name="cloud"))
        shares = proportional_shares([p.chips / p.slowdown for p in pods])
        return Resources(pods=pods, shares=shares)

    @staticmethod
    def rebalanced(res: Resources, measured_tps: list[float]) -> Resources:
        if sum(measured_tps) <= 0:
            return res
        return Resources(
            pods=list(res.pods), shares=proportional_shares(measured_tps)
        )

    def split_plan(self, res: Resources, global_batch: int,
                   microbatch: int, seq_len: int) -> HeterogeneousPlan:
        return heterogeneous_split(
            global_batch=global_batch,
            microbatch=microbatch,
            seq_len=seq_len,
            throughputs=[p.chips / p.slowdown for p in res.pods],
        )

    # ---- main loop --------------------------------------------------------

    def run(
        self,
        *,
        session_factory: SessionFactory,
        initial: Resources,
        steps_total: int,
        overhead_s_fn: Callable[[BurstDecision], float] | None = None,
        autoscaler: AutoscalerPolicy | None = None,
        deadline_changes: Sequence[tuple[float, float]] = (),
        fault_hook: Callable[[str, dict], bool] | None = None,
        retry_policy=None,
        rng: np.random.Generator | None = None,
    ) -> RunRecord:
        """Drive the session to ``steps_total`` (see class docstring).

        Failure hardening (DESIGN.md §19): ``fault_hook(kind, detail)``
        is consulted before each provisioning attempt — returning True
        denies it (the injection point for tests and chaos drills).
        Denials retry under ``retry_policy`` (any object with
        ``max_retries`` and ``backoff_s(attempt, rng)``, e.g.
        repro.sim.faults.RetryPolicy) with the backoff drawn from the
        seeded ``rng``; exhaustion surfaces as ``gave_up`` on the
        record and the loop carries on without the grow.
        """
        res = initial
        session = session_factory(res, 0, None)
        elapsed = 0.0
        cloud_chip_s = 0.0
        events: list[OrchestratorEvent] = []
        step_times: list[float] = []
        bursts_done = 0
        retries = 0
        gave_up = False
        provision_failures = 0
        last_failure_elapsed = -math.inf
        if rng is None:
            rng = np.random.default_rng(0)
        last_ckpt: Any = None
        last_ckpt_step = -1
        step = 0
        dl_sched = sorted(deadline_changes)
        dl_idx = 0
        next_eval = self.eval_interval_s or 0.0
        while step < steps_total:
            try:
                dt = session.run_step(step)
            except PodFailure as f:
                # fault tolerance: drop the failed pod, restart from the
                # last checkpoint (re-running the lost steps)
                events.append(OrchestratorEvent(
                    step, "failure", {"pod": f.pod}
                ))
                pods = [p for i, p in enumerate(res.pods) if i != f.pod]
                res = Resources(
                    pods=pods,
                    shares=proportional_shares(
                        [p.chips / p.slowdown for p in pods]
                    ),
                )
                restart = max(last_ckpt_step + 1, 0)
                elapsed += self.planner.overheads.restart_s
                cloud_chip_s += (
                    elastic_chips(res) * self.planner.overheads.restart_s
                )
                session = session_factory(res, restart, last_ckpt)
                self.monitor.reset_window()
                step = restart
                continue
            self.monitor.observe(dt)
            elapsed += dt
            cloud_chip_s += elastic_chips(res) * dt
            step_times.append(dt)
            step += 1

            # first-class dynamic deadlines (paper §2), recorded into
            # the predictor history at the session-clock time they land
            while dl_idx < len(dl_sched) and elapsed >= dl_sched[dl_idx][0]:
                self.predictor.set_deadline(
                    dl_sched[dl_idx][1], at_s=elapsed
                )
                events.append(OrchestratorEvent(
                    step, "deadline",
                    {"deadline_s": dl_sched[dl_idx][1],
                     "at_elapsed_s": elapsed},
                ))
                dl_idx += 1

            if step % self.ckpt_every == 0:
                last_ckpt = session.checkpoint(step)
                last_ckpt_step = step
                events.append(OrchestratorEvent(step, "ckpt", {}))

            if self.eval_interval_s is not None:
                # wall-clock-driven evaluation on the session's clock
                if elapsed < next_eval or step >= steps_total:
                    continue
                while next_eval <= elapsed:
                    next_eval += self.eval_interval_s
            elif step % self.check_every or step >= steps_total:
                continue

            est = self.predictor.estimate(
                self.monitor, step, steps_total, elapsed
            )
            eff_chips = sum(p.chips / p.slowdown for p in res.pods)
            if autoscaler is not None:
                # policy-driven mode: the interval-evaluated autoscaler
                # replaces the built-in burst-once decision, and every
                # resize rides the same ckpt -> remesh -> reshard path
                forced: ScaleAction | None = None
                if (
                    self.degraded_factor is not None
                    and elastic_chips(res) > 0
                ):
                    # degraded-pod detector (DESIGN.md §19): the cluster
                    # model says what this allocation *should* deliver;
                    # measuring far above it means a pod is sick —
                    # retire the elastic pod and re-stripe around it
                    t_meas = self.monitor.step_time()
                    t_model = (
                        self.planner.cluster_model.predict_time(eff_chips)
                        + self.planner.overheads.seam_s_per_step()
                    )
                    if t_model > 0 \
                            and t_meas > self.degraded_factor * t_model:
                        forced = ScaleAction(
                            "retire",
                            reason=(
                                f"degraded pod: measured {t_meas:.3f}s "
                                f"vs modeled {t_model:.3f}s"
                            ),
                        )
                        events.append(OrchestratorEvent(
                            step, "degraded",
                            {"measured_s": t_meas, "modeled_s": t_model},
                        ))
                if forced is not None:
                    action = forced
                else:
                    action = autoscaler.decide(ScaleContext(
                        step=step, steps_total=steps_total,
                        elapsed_s=elapsed,
                        est=est, resources=res,
                        cloud_chips=elastic_chips(res),
                        planner=self.planner, monitor=self.monitor,
                        legal=list(self.planner.legal),
                        provision_failures=provision_failures,
                        since_failure_s=elapsed - last_failure_elapsed,
                    ))
                if (
                    action.kind == "grow"
                    and self.cloud_slowdown is not None
                ):
                    # the pod's *true* K is the provider's, whatever the
                    # policy believed when sizing (DESIGN.md §10)
                    action = dataclasses.replace(
                        action, slowdown=self.cloud_slowdown
                    )
                if action.kind == "grow" and fault_hook is not None:
                    attempt = 1
                    while fault_hook("provision", {
                        "chips": action.chips, "attempt": attempt,
                        "step": step,
                    }):
                        retries += 1
                        provision_failures += 1
                        last_failure_elapsed = elapsed
                        events.append(OrchestratorEvent(
                            step, "provision_denied",
                            {"chips": action.chips, "attempt": attempt},
                        ))
                        if (retry_policy is None
                                or attempt > retry_policy.max_retries):
                            gave_up = True
                            events.append(OrchestratorEvent(
                                step, "provision_gave_up",
                                {"chips": action.chips,
                                 "attempts": attempt},
                            ))
                            action = HOLD
                            break
                        backoff = retry_policy.backoff_s(attempt, rng)
                        elapsed += backoff
                        events.append(OrchestratorEvent(
                            step, "provision_retry",
                            {"attempt": attempt + 1,
                             "backoff_s": backoff},
                        ))
                        attempt += 1
                    else:
                        provision_failures = 0
                new_res = self.apply_scale(res, action)
                if action.kind != "hold" and new_res.pods != res.pods:
                    last_ckpt = session.checkpoint(step)
                    last_ckpt_step = step
                    ov = self.planner.overheads
                    overhead = (
                        ov.total() if action.kind == "grow"
                        else ov.ckpt_s + ov.restart_s
                    )
                    elapsed += overhead
                    res = new_res
                    # provisioning is not billed (the provider's clock
                    # starts at attach, as in the fleet); the ckpt +
                    # restart legs hold the new allocation
                    cloud_chip_s += elastic_chips(res) * max(
                        overhead
                        - (ov.provision_s if action.kind == "grow"
                           else 0.0),
                        0.0,
                    )
                    session = session_factory(res, step, last_ckpt)
                    self.monitor.reset_window()
                    events.append(OrchestratorEvent(
                        step, "scale",
                        {
                            "kind": action.kind,
                            "cloud_chips": elastic_chips(res),
                            "overhead_s": overhead,
                            "reason": action.reason,
                            "shares": list(res.shares),
                        },
                    ))
                continue
            decision = self.planner.plan(
                est, step, steps_total,
                observed_step_s=self.monitor.step_time(),
                effective_chips=eff_chips,
            )
            if decision.burst and bursts_done < self.max_bursts:
                # Fig.1 steps 2,5: save state, move it to the new nodes
                last_ckpt = session.checkpoint(step)
                last_ckpt_step = step
                overhead = (
                    overhead_s_fn(decision) if overhead_s_fn
                    else decision.overhead_s
                )
                elapsed += overhead
                # steps 3,4: expand resources with the γ split
                res = self.apply_burst(res, decision)
                cloud_chip_s += elastic_chips(res) * max(
                    overhead - self.planner.overheads.provision_s, 0.0
                )
                # steps 6,7: assimilate state, restart at the stopped step
                session = session_factory(res, step, last_ckpt)
                self.monitor.reset_window()
                bursts_done += 1
                events.append(OrchestratorEvent(
                    step, "burst",
                    {
                        "chips": decision.chips_burst,
                        "K": decision.correction_K,
                        "overhead_s": overhead,
                        "est_stay": decision.est_time_stay_s,
                        "est_burst": decision.est_time_burst_s,
                        "shares": list(res.shares),
                    },
                ))
            elif (
                self.monitor.straggler_rate() > self.rebalance_straggler_rate
                and len(res.pods) > 1
            ):
                # straggler mitigation: shift γ toward healthy pods using
                # measured (not nominal) throughput
                tps = [p.chips / p.slowdown for p in res.pods]
                res = self.rebalanced(res, tps)
                session = session_factory(res, step, session.checkpoint(step))
                events.append(OrchestratorEvent(
                    step, "rebalance", {"shares": list(res.shares)}
                ))

        return RunRecord(
            completed=True,
            steps=steps_total,
            elapsed_s=elapsed,
            deadline_s=self.predictor.deadline_s,
            met_deadline=elapsed <= self.predictor.deadline_s,
            events=events,
            step_times=step_times,
            final_resources=res,
            cloud_chip_s=cloud_chip_s,
            cloud_cost_usd=self.planner.cost_usd(cloud_chip_s),
            retries=retries,
            gave_up=gave_up,
        )
