"""Burst planner — the paper's Fig. 1 decision pipeline, steps 1-4.

Given a deadline-miss prediction, compute (paper §2):
  step 3: the chip count needed in the elastic environment —
          solve L_cluster for the remaining-time budget, apply the
          correction factor K, subtract on-premise capacity (eq. 3),
          round up to a legal slice shape;
  step 4: the share of the domain (γ) to place there (eqs. 4-5) —
          for LM training, γ is the burst pod's share of the global
          batch, realized by the heterogeneous allocator.

Beyond the paper (its §3.3 names this as future work): the decision
inequality accounts for the burst overhead explicitly —
  T_after = T_ckpt + T_provision + T_transfer + T_restart
            + steps_remaining · t_step(after)
and bursting is only worth it if T_after < min(T_stay, deadline).

Cost-aware sizing (DESIGN.md §14; SLA/cost placement in the spirit of
arXiv:1507.05472): when the planner knows the provider's
``price_per_chip_hour``, the minimal-cores solve becomes the *floor* of
a candidate sweep over legal slices.  Each candidate's projected $ is
``price · chips · hold_s`` where ``hold_s`` is the retire-aware hold
time (the pod is dropped as soon as the remaining work fits on-premise
within the deadline, mirroring the `plan` policy's RETIRE rule).  The
``cost_weight`` knob w ∈ [0, 1] sets how much of the remaining time
budget may be spent chasing savings: a candidate is admissible only if
its projected completion consumes at most ``w · (deadline − elapsed)``,
so w = 0 reproduces the deadline-first minimal slice exactly and w = 1
takes the cheapest deadline-feasible slice.  With the empirically
fitted log-laws the cheapest slice is *not* always the smallest —
superlinear scaling regimes (cache effects on striped stencils) make a
larger slice finish and retire so much earlier that it bills fewer
chip-hours.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.capacity import (
    LogCapacityModel,
    burst_cores,
    correction_factor,
    round_to_legal_slice,
)
from repro.core.deadline import DeadlineEstimate
from repro.core.gamma import GammaModel


@dataclasses.dataclass(frozen=True)
class OverheadModel:
    """Fixed + size-dependent burst overheads (seconds).

    ``seam_latency_s``/``seam_syncs_per_step`` model the per-step halo
    synchronization over the slow cross-environment link (paper §3.3's
    21 KB message is latency-, not bandwidth-, dominated).  With the
    temporally-blocked solver, ``seam_syncs_per_step`` is
    ``halo_exchange_plan(...)["ppermutes_per_step"] / 2`` — k-step
    blocking cuts the recurring burst tax k×.

    Provenance of a *measured* seam (``with_measured_seam``): feed in the
    solver's ``halo_exchange_plan(cfg, n_stripes, k)`` (message shape and
    cadence) plus a per-ppermute latency measured by
    ``benchmarks/bench_overheads.py`` (jitted ``lax.ppermute`` dispatch
    over a seam-sized payload on this host).  One seam sync is one
    packed bidirectional exchange = 2 ppermutes, so
    ``seam_latency_s = 2 · t_ppermute`` and ``seam_syncs_per_step =
    ppermutes_per_step / 2 = 1/k``.  On real hardware substitute the
    cross-DCI ppermute timing; the CPU number is a dispatch-latency
    floor, not a network RTT."""

    ckpt_s: float = 10.0
    provision_s: float = 90.0           # slice spin-up
    restart_s: float = 30.0             # re-compile + re-shard + warmup
    transfer_bytes: float = 0.0         # checkpoint/state moved cross-env
    transfer_bw: float = 6.25e9         # DCI bytes/s
    seam_latency_s: float = 0.0         # one cross-env halo round trip
    seam_syncs_per_step: float = 1.0    # exchanges per timestep (1/k)

    def total(self) -> float:
        xfer = self.transfer_bytes / max(self.transfer_bw, 1.0)
        return self.ckpt_s + self.provision_s + self.restart_s + xfer

    def seam_s_per_step(self) -> float:
        return self.seam_latency_s * self.seam_syncs_per_step

    def with_measured_seam(
        self, plan: dict, ppermute_latency_s: float
    ) -> "OverheadModel":
        """Replace the default-zero seam with a measured one (ROADMAP
        item; provenance in the class docstring).  ``plan`` is
        ``fwi.domain.halo_exchange_plan(...)``."""
        return dataclasses.replace(
            self,
            seam_latency_s=(
                plan["ppermutes_per_exchange"] * ppermute_latency_s
            ),
            seam_syncs_per_step=plan["ppermutes_per_step"] / 2.0,
        )

    def with_overlapped_seam(
        self, plan: dict, ppermute_latency_s: float,
        compute_s_per_step: float = 0.0,
    ) -> "OverheadModel":
        """Measured seam AFTER comm/compute overlap (DESIGN.md §13).

        The overlapped engine issues the packed exchange first and
        computes the stripe interior — ``plan["overlap_fraction"]`` of
        the block's work — while it is in flight, so a k-step block
        costs ``max(interior, seam) + boundary`` instead of
        ``compute + seam``.  The seam surcharge over pure compute is
        therefore only the residue ``max(seam − interior, 0)``:

            seam_block     = ppermutes_per_exchange · t_ppermute
            interior_block = compute_s_per_step · k · overlap_fraction
            effective seam = max(seam_block − interior_block, 0)

        With ``compute_s_per_step = 0`` (unknown) this degrades to
        ``with_measured_seam`` — no overlap credit is taken.  On real
        hardware the hiding needs async collectives; the planner model
        assumes the schedule the engine's program order enables."""
        seam_block = plan["ppermutes_per_exchange"] * ppermute_latency_s
        interior_block = (
            compute_s_per_step * plan["steps_per_exchange"]
            * plan.get("overlap_fraction", 0.0)
        )
        return dataclasses.replace(
            self,
            seam_latency_s=max(seam_block - interior_block, 0.0),
            seam_syncs_per_step=plan["ppermutes_per_step"] / 2.0,
        )


@dataclasses.dataclass(frozen=True)
class BurstDecision:
    burst: bool
    reason: str
    chips_burst: int = 0
    gamma: int = 0                       # work units moved (µbatches/columns)
    gamma_total: int = 0
    est_time_stay_s: float = 0.0
    est_time_burst_s: float = 0.0
    overhead_s: float = 0.0
    correction_K: float = 1.0
    cores_needed: float = 0.0
    est_hold_s: float = 0.0              # projected cloud-pod hold time
    est_cost_usd: float = 0.0            # projected $ for the hold


class BurstPlanner:
    def __init__(
        self,
        *,
        cluster_model: LogCapacityModel,
        cloud_model: LogCapacityModel,
        chips_cluster: int,
        legal_slices: Sequence[int],
        overheads: OverheadModel = OverheadModel(),
        gamma_model: GammaModel | None = None,
        gamma_total: int = 0,
        max_burst_chips: int | None = None,
        price_per_chip_hour: float = 0.0,
        cost_weight: float = 0.0,
    ):
        self.cluster_model = cluster_model
        self.cloud_model = cloud_model
        self.chips_cluster = chips_cluster
        self.legal = list(legal_slices)
        self.overheads = overheads
        self.gamma_model = gamma_model
        self.gamma_total = gamma_total
        self.max_burst_chips = (
            max(self.legal) if max_burst_chips is None else max_burst_chips
        )
        #: provider $ per chip-hour (0 disables cost projection entirely)
        self.price_per_chip_hour = price_per_chip_hour
        #: cost/deadline trade-off knob w ∈ [0, 1] (module docstring):
        #: 0 = deadline-first minimal slice, 1 = cheapest feasible slice
        self.cost_weight = min(max(cost_weight, 0.0), 1.0)

    def cost_usd(self, chip_seconds: float) -> float:
        return chip_seconds / 3600.0 * self.price_per_chip_hour

    # ---- cost-aware sizing (DESIGN.md §14) ---------------------------

    def _burst_hold_s(
        self, chips: int, K: float, cluster_model: LogCapacityModel,
        steps_rem: int, budget_s: float,
    ) -> float:
        """Retire-aware hold-time projection for a candidate slice.

        The `plan` policy drops the pod once the remaining steps fit
        on-premise within the deadline; with per-step times t_burst
        (combined) and t_on (on-premise alone), the pod must be held
        until the accumulated head-start covers the on-premise deficit:

            hold = (steps_rem · t_on − budget) / (t_on / t_burst − 1)

        clamped to [0, steps_rem · t_burst] (never longer than running
        the whole remainder on the combined fleet)."""
        t_burst = self._post_burst_step_time(chips, K, cluster_model)
        t_on = cluster_model.predict_time(self.chips_cluster)
        full = steps_rem * t_burst
        if t_on <= t_burst:
            return full
        deficit = steps_rem * t_on - budget_s
        hold = deficit / (t_on / t_burst - 1.0)
        return min(max(hold, 0.0), full)

    def _cost_aware_choice(
        self, chips_min: int, K: float,
        cluster_model: LogCapacityModel, est: DeadlineEstimate,
        steps_rem: int, overhead_s: float,
    ) -> tuple[int, float, float]:
        """Pick the cheapest admissible legal slice ≥ the deadline-first
        solve; returns (chips, hold_s, cost_usd).  Admissibility: the
        candidate's projected completion must consume at most
        ``cost_weight · (deadline − elapsed)`` of the remaining time —
        when slack is tight no candidate qualifies and the deadline-first
        slice stands (with its own cost projection attached)."""
        budget_s = est.deadline_s - est.elapsed_s - overhead_s
        spendable = self.cost_weight * (est.deadline_s - est.elapsed_s)
        best = None
        for s in sorted(self.legal):
            if s < chips_min or s > self.max_burst_chips:
                continue
            t_after = steps_rem * self._post_burst_step_time(
                s, K, cluster_model
            )
            hold = self._burst_hold_s(
                s, K, cluster_model, steps_rem, budget_s
            )
            dollars = self.cost_usd(s * hold)
            if overhead_s + t_after > spendable:
                continue                    # too close to the deadline
            if best is None or dollars < best[2] * (1.0 - 1e-9):
                best = (s, hold, dollars)
        if best is None:                    # slack too tight: deadline-first
            hold = self._burst_hold_s(
                chips_min, K, cluster_model, steps_rem, budget_s
            )
            return chips_min, hold, self.cost_usd(chips_min * hold)
        return best

    def calibrated_cluster_model(
        self, observed_step_s: float | None, effective_chips: float | None,
    ) -> LogCapacityModel:
        """Online intercept calibration (beyond paper; its §3.3 flags the
        static fit as a source of inaccuracy): shift B so the model
        reproduces the *currently observed* step time at the current
        effective chip count — congestion moves the whole curve up."""
        if not observed_step_s or not effective_chips:
            return self.cluster_model
        predicted = self.cluster_model.predict_time(effective_chips)
        if predicted <= 0:
            return self.cluster_model
        shift = math.log10(max(observed_step_s, 1e-9) / predicted)
        m = self.cluster_model
        return LogCapacityModel(A=m.A, B=m.B + shift, name=m.name + "+cal")

    def plan(
        self,
        est: DeadlineEstimate,
        steps_done: int,
        steps_total: int,
        *,
        observed_step_s: float | None = None,
        effective_chips: float | None = None,
    ) -> BurstDecision:
        if not est.predictable:
            return BurstDecision(False, "step times not yet predictable")
        if not est.will_miss:
            return BurstDecision(
                False, "deadline met on current resources",
                est_time_stay_s=est.estimated_total_s,
            )
        steps_rem = max(steps_total - steps_done, 0)
        if steps_rem == 0:
            return BurstDecision(False, "no steps remaining")
        overhead = self.overheads.total()
        budget = est.deadline_s - est.elapsed_s - overhead
        if budget <= 0:
            return BurstDecision(
                False,
                "deadline unreachable even with burst (overhead exceeds "
                "remaining budget)",
                est_time_stay_s=est.estimated_total_s,
                overhead_s=overhead,
            )
        cluster_model = self.calibrated_cluster_model(
            observed_step_s, effective_chips
        )
        # --- paper step 3: chips needed -------------------------------
        # The capacity model is fitted on *per-step* times; scale the
        # remaining-time budget to a per-step budget.
        t_step_budget = budget / steps_rem
        cores_needed = cluster_model.cores_for(t_step_budget)
        K = correction_factor(
            self.cloud_model, cluster_model, max(cores_needed, 1.0)
        )
        c_n = burst_cores(cores_needed, self.chips_cluster, K)
        chips = round_to_legal_slice(c_n, self.legal)
        chips = min(chips, self.max_burst_chips)
        if chips == 0:
            return BurstDecision(
                False, "cluster alone satisfies the adjusted budget",
                est_time_stay_s=est.estimated_total_s,
                cores_needed=cores_needed, correction_K=K,
            )
        # --- cost-aware slice selection (DESIGN.md §14) ----------------
        hold_s = cost_usd = 0.0
        reason = "deadline at risk; bursting"
        if self.price_per_chip_hour > 0:
            if self.cost_weight > 0:
                chosen, hold_s, cost_usd = self._cost_aware_choice(
                    chips, K, cluster_model, est, steps_rem, overhead
                )
                if chosen != chips:
                    reason = (
                        f"deadline at risk; bursting {chosen} chips "
                        f"(cost-aware over minimal {chips}: "
                        f"${cost_usd:.2f} projected)"
                    )
                    chips = chosen
            else:
                hold_s = self._burst_hold_s(
                    chips, K, cluster_model, steps_rem,
                    est.deadline_s - est.elapsed_s - overhead,
                )
                cost_usd = self.cost_usd(chips * hold_s)
        # --- paper step 4: domain split γ ------------------------------
        # time the on-premise side may spend per step after the split
        gamma = 0
        if self.gamma_model is not None and self.gamma_total > 0:
            gamma = self.gamma_total - self.gamma_model.gamma_for(
                t_step_budget
            )
            gamma = min(max(gamma, 1), self.gamma_total - 1)
        else:
            # LM default: share ∝ burst throughput (chips / K)
            eff = chips / max(K, 1e-9)
            gamma_frac = eff / (self.chips_cluster + eff)
            gamma = max(int(self.gamma_total * gamma_frac), 1) \
                if self.gamma_total else 0
        # --- estimate post-burst completion ---------------------------
        t_step_after = self._post_burst_step_time(chips, K, cluster_model)
        t_burst = est.elapsed_s + overhead + steps_rem * t_step_after
        if t_burst >= est.estimated_total_s:
            return BurstDecision(
                False,
                "burst would not improve completion time "
                "(overhead dominates)",
                est_time_stay_s=est.estimated_total_s,
                est_time_burst_s=t_burst,
                overhead_s=overhead, correction_K=K,
                cores_needed=cores_needed,
            )
        return BurstDecision(
            True,
            reason,
            chips_burst=chips,
            gamma=gamma,
            gamma_total=self.gamma_total,
            est_time_stay_s=est.estimated_total_s,
            est_time_burst_s=t_burst,
            overhead_s=overhead,
            correction_K=K,
            cores_needed=cores_needed,
            est_hold_s=hold_s,
            est_cost_usd=cost_usd,
        )

    def _post_burst_step_time(
        self, chips_burst: int, K: float,
        cluster_model: LogCapacityModel | None = None,
    ) -> float:
        """Combined throughput of cluster + K-degraded burst slice."""
        m = cluster_model or self.cluster_model
        t_cluster = m.predict_time(self.chips_cluster)
        # effective chips: burst chips are 1/K as productive per the
        # correction factor (K >= 1 when the cloud is slower); every
        # split step also pays the cross-env seam synchronization
        eff = self.chips_cluster + chips_burst / max(K, 1e-9)
        base = m.predict_time(eff) if eff > 0 else t_cluster
        return base + self.overheads.seam_s_per_step()
