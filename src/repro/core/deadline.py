"""Deadline predictor — paper §2 step 1.

Extrapolates total completion time from the monitored per-step estimate
and compares against the (dynamically changeable) deadline.  The paper
notes the deadline "could also change dynamically" — set_deadline() may
be called at any time and the next check uses the new value.

Every change is also recorded with the clock time it took effect
(``set_deadline(..., at_s=...)``), so completed work can be judged
against the deadline *in force when it finished* rather than whatever
the deadline happens to be when the record is written
(``deadline_at``) — a job that finished before a later tightening must
not be retro-judged against the new, stricter value (DESIGN.md §14).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.monitor import StepTimeMonitor


@dataclasses.dataclass
class DeadlineEstimate:
    estimated_total_s: float
    elapsed_s: float
    remaining_s: float
    deadline_s: float
    slack_s: float
    will_miss: bool
    predictable: bool


class DeadlinePredictor:
    def __init__(self, deadline_s: float, margin_frac: float = 0.05):
        self.deadline_s = deadline_s
        self.margin_frac = margin_frac
        #: (effective_from_s, deadline_s) change log; the initial
        #: deadline is in force from the beginning of time
        self.history: list[tuple[float, float]] = [(-math.inf, deadline_s)]

    def set_deadline(self, deadline_s: float, at_s: float | None = None):
        """Change the deadline; ``at_s`` (caller's clock) records when
        the change took effect so ``deadline_at`` can answer queries
        about the past.  Without ``at_s`` the predictor has no clock to
        pin the change to, so it governs the *current* deadline
        (``deadline_s``) but is never presumed to predate any finite
        finish time — an untimestamped tightening must not retro-judge
        already-completed work."""
        self.deadline_s = deadline_s
        t = math.inf if at_s is None else float(at_s)
        self.history.append((t, deadline_s))

    def deadline_at(self, t_s: float) -> float:
        """The deadline in force at clock time ``t_s`` — what a job that
        finished then should be judged against.  Entries may be logged
        out of order; the latest-inserted entry at the greatest
        effective time ≤ ``t_s`` wins."""
        best_t = -math.inf
        in_force = self.history[0][1]
        for t, d in self.history:
            if t <= t_s and t >= best_t:
                best_t = t
                in_force = d
        return in_force

    def estimate(
        self,
        monitor: StepTimeMonitor,
        steps_done: int,
        steps_total: int,
        elapsed_s: float,
    ) -> DeadlineEstimate:
        t_step = monitor.step_time()
        remaining = max(steps_total - steps_done, 0) * t_step
        total = elapsed_s + remaining
        margin = self.margin_frac * self.deadline_s
        will_miss = total > self.deadline_s - margin
        return DeadlineEstimate(
            estimated_total_s=total,
            elapsed_s=elapsed_s,
            remaining_s=remaining,
            deadline_s=self.deadline_s,
            slack_s=self.deadline_s - total,
            will_miss=will_miss and monitor.predictable(),
            predictable=monitor.predictable(),
        )
