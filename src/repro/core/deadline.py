"""Deadline predictor — paper §2 step 1.

Extrapolates total completion time from the monitored per-step estimate
and compares against the (dynamically changeable) deadline.  The paper
notes the deadline "could also change dynamically" — set_deadline() may
be called at any time and the next check uses the new value.
"""
from __future__ import annotations

import dataclasses

from repro.core.monitor import StepTimeMonitor


@dataclasses.dataclass
class DeadlineEstimate:
    estimated_total_s: float
    elapsed_s: float
    remaining_s: float
    deadline_s: float
    slack_s: float
    will_miss: bool
    predictable: bool


class DeadlinePredictor:
    def __init__(self, deadline_s: float, margin_frac: float = 0.05):
        self.deadline_s = deadline_s
        self.margin_frac = margin_frac

    def set_deadline(self, deadline_s: float):
        self.deadline_s = deadline_s

    def estimate(
        self,
        monitor: StepTimeMonitor,
        steps_done: int,
        steps_total: int,
        elapsed_s: float,
    ) -> DeadlineEstimate:
        t_step = monitor.step_time()
        remaining = max(steps_total - steps_done, 0) * t_step
        total = elapsed_s + remaining
        margin = self.margin_frac * self.deadline_s
        will_miss = total > self.deadline_s - margin
        return DeadlineEstimate(
            estimated_total_s=total,
            elapsed_s=elapsed_s,
            remaining_s=remaining,
            deadline_s=self.deadline_s,
            slack_s=self.deadline_s - total,
            will_miss=will_miss and monitor.predictable(),
            predictable=monitor.predictable(),
        )
