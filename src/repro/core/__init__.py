# The paper's primary contribution: self-adaptive deadline-driven
# auto-scaling (cloud bursting) — monitoring, capacity models (eqs 1-3,
# 6-7), γ domain split (eqs 4-5, 8), burst planning (Fig. 1) and the
# elastic orchestrator that executes it on TPU multi-pod meshes.
from repro.core.allocator import (
    HeterogeneousPlan,
    PodShare,
    conservation_ok,
    heterogeneous_split,
)
from repro.core.capacity import (
    LogCapacityModel,
    ThroughputModel,
    burst_cores,
    correction_factor,
    round_to_legal_slice,
)
from repro.core.deadline import DeadlineEstimate, DeadlinePredictor
from repro.core.gamma import GammaModel, split_gamma
from repro.core.monitor import StepTimeMonitor
from repro.core.orchestrator import (
    BurstDecision,
    ElasticOrchestrator,
    PodFailure,
    PodSpec,
    Resources,
    RunRecord,
)
from repro.core.planner import BurstPlanner, OverheadModel

__all__ = [
    "BurstDecision",
    "BurstPlanner",
    "DeadlineEstimate",
    "DeadlinePredictor",
    "ElasticOrchestrator",
    "GammaModel",
    "HeterogeneousPlan",
    "LogCapacityModel",
    "OverheadModel",
    "PodFailure",
    "PodShare",
    "PodSpec",
    "Resources",
    "RunRecord",
    "StepTimeMonitor",
    "ThroughputModel",
    "burst_cores",
    "conservation_ok",
    "correction_factor",
    "heterogeneous_split",
    "round_to_legal_slice",
    "split_gamma",
]
