# The paper's primary contribution: self-adaptive deadline-driven
# auto-scaling (cloud bursting) — monitoring, capacity models (eqs 1-3,
# 6-7), γ domain split (eqs 4-5, 8), burst planning (Fig. 1) and the
# elastic orchestrator that executes it on TPU multi-pod meshes.
from repro.core.allocator import (
    HeterogeneousPlan,
    PodShare,
    conservation_ok,
    heterogeneous_split,
    max_min_fair_allocation,
    min_weighted_share,
    proportional_shares,
)
from repro.core.capacity import (
    LogCapacityModel,
    ThroughputModel,
    burst_cores,
    correction_factor,
    floor_to_legal_slice,
    legal_step_down,
    legal_step_up,
    round_to_legal_slice,
)
from repro.core.deadline import DeadlineEstimate, DeadlinePredictor
from repro.core.gamma import GammaModel, split_gamma
from repro.core.monitor import StepTimeMonitor
from repro.core.orchestrator import (
    AutoscalerPolicy,
    BurstDecision,
    ElasticOrchestrator,
    PodFailure,
    PodSpec,
    Resources,
    RunRecord,
    ScaleAction,
    ScaleContext,
    elastic_chips,
)
from repro.core.planner import BurstPlanner, OverheadModel

__all__ = [
    "AutoscalerPolicy",
    "BurstDecision",
    "BurstPlanner",
    "DeadlineEstimate",
    "DeadlinePredictor",
    "ElasticOrchestrator",
    "GammaModel",
    "HeterogeneousPlan",
    "LogCapacityModel",
    "OverheadModel",
    "PodFailure",
    "PodShare",
    "PodSpec",
    "Resources",
    "RunRecord",
    "ScaleAction",
    "ScaleContext",
    "StepTimeMonitor",
    "ThroughputModel",
    "burst_cores",
    "conservation_ok",
    "correction_factor",
    "elastic_chips",
    "floor_to_legal_slice",
    "heterogeneous_split",
    "legal_step_down",
    "legal_step_up",
    "max_min_fair_allocation",
    "min_weighted_share",
    "proportional_shares",
    "round_to_legal_slice",
    "split_gamma",
]
