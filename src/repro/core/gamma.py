"""Domain-split solver γ — paper eqs. (4), (5), (8).

The paper fixes one domain dimension and models execution time as linear
in the number of grid columns γ placed in the external environment:

    f(γ) = t = a·γ + b                (eq. 4)
    g(t) = γ = (t − b) / a            (eq. 5; fitted eq. 8)

γ must be an integer (column count).  The same linear model serves the LM
adaptation where the divisible dimension is the global batch: t is linear
in the local batch share for a fixed model, so γ becomes "microbatches
moved to the burst pod".
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class GammaModel:
    """t = a·γ + b (seconds per γ units kept/moved)."""

    a: float
    b: float
    name: str = ""

    def time_for(self, gamma: float) -> float:
        return self.a * gamma + self.b

    def gamma_for(self, t: float) -> int:
        """Paper eq. 5: γ = (t − b)/a, rounded up to an integer."""
        if self.a == 0:
            return 0
        g = (t - self.b) / self.a
        return max(int(-(-g // 1)), 0)  # ceil

    @staticmethod
    def fit(gammas: Sequence[float], times_s: Sequence[float],
            name: str = "") -> "GammaModel":
        assert len(gammas) == len(times_s) and len(gammas) >= 2
        n = len(gammas)
        mx = sum(gammas) / n
        my = sum(times_s) / n
        sxx = sum((x - mx) ** 2 for x in gammas)
        sxy = sum(
            (x - mx) * (y - my) for x, y in zip(gammas, times_s)
        )
        a = sxy / max(sxx, 1e-12)
        b = my - a * mx
        return GammaModel(a=a, b=b, name=name)

    def r2(self, gammas: Sequence[float], times_s: Sequence[float]) -> float:
        my = sum(times_s) / len(times_s)
        ss_tot = sum((y - my) ** 2 for y in times_s)
        ss_res = sum(
            (y - self.time_for(g)) ** 2 for g, y in zip(gammas, times_s)
        )
        return 1.0 - ss_res / max(ss_tot, 1e-12)


def split_gamma(total_columns: int, time_needed: float,
                model: GammaModel) -> int:
    """Columns to move off-premise so the on-premise part finishes in
    time_needed; clamped to [0, total_columns]."""
    keep = model.gamma_for(time_needed)
    move = total_columns - keep
    return min(max(move, 0), total_columns)
