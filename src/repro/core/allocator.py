"""Heterogeneous work allocator — the γ split realized for SPMD.

The paper assigns unequal domain shares to unequal environments.  SPMD
requires a uniform per-device program, so unequal shares are realized as
*unequal microbatch counts with padding + loss masking*: every pod runs
the same number of µ-steps (the max), but pods with a smaller share get
zero-masked filler microbatches.  Work conservation holds exactly: the
sum of unmasked tokens equals the global batch.

The striped/greedy second-level placement of the paper (§3.3) maps to
device order inside the mesh: a pod's microbatches are contiguous on its
"data" axis, so only the gradient reduction crosses the pod boundary.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


def max_min_fair_allocation(
    capacity: float,
    demands: Sequence[float],
    weights: Sequence[float] | None = None,
) -> list[float]:
    """Weighted max-min fair split of ``capacity`` across ``demands``.

    Progressive filling: capacity is poured into the unsatisfied
    demands in proportion to their weights until each is either
    satisfied (allocation == demand) or the capacity runs out — the
    classic water-filling definition of (weighted) max-min fairness.
    The fleet controller uses it to arbitrate simultaneous cloud-grow
    requests under the global budget cap, so no tenant can crowd the
    headroom out of another's ungranted request (DESIGN.md §16).

    Zero-weight demands are served only by whatever capacity is left
    after every positive-weight demand is satisfied.
    """
    n = len(demands)
    if weights is None:
        weights = [1.0] * n
    alloc = [0.0] * n
    left = max(float(capacity), 0.0)
    active = [
        i for i in range(n) if demands[i] > 0 and weights[i] > 0
    ]
    while active and left > 1e-12:
        wsum = sum(weights[i] for i in active)
        # the smallest per-weight top-up that satisfies some demand
        limit = min(
            (demands[i] - alloc[i]) / weights[i] for i in active
        )
        fill = min(limit, left / wsum)
        for i in active:
            alloc[i] += fill * weights[i]
        left -= fill * wsum
        active = [
            i for i in active if demands[i] - alloc[i] > 1e-12
        ]
    if left > 1e-12:
        # residual capacity flows to zero-weight demands, equally
        zero = [i for i in range(n) if demands[i] > 0 and weights[i] <= 0]
        while zero and left > 1e-12:
            fill = min(
                min(demands[i] - alloc[i] for i in zero), left / len(zero)
            )
            for i in zero:
                alloc[i] += fill
            left -= fill * len(zero)
            zero = [i for i in zero if demands[i] - alloc[i] > 1e-12]
    return alloc


def min_weighted_share(
    usage: Sequence[float],
    weights: Sequence[float],
    demands: Sequence[float] | None = None,
) -> float:
    """Max-min fairness score of a realized ``usage`` split, in [0, 1].

    1.0 means every positive-weight party received at least its
    weighted proportional share of the total served; lower values are
    the worst party's shortfall ratio (min_i (usage_i/weight_i) /
    (total/total_weight)).  With ``demands`` the entitlement is
    demand-bounded — a party that *asked* for less than its weighted
    share and got everything it asked for is fully satisfied, not a
    fairness victim.  The fleet tournament reports this as its fairness
    column (DESIGN.md §16): a scheduler that starves a tenant scores
    near 0 no matter how good its aggregate hit-rate looks.
    """
    if demands is None:
        demands = [math.inf] * len(usage)
    triples = [
        (u, w, d) for u, w, d in zip(usage, weights, demands)
        if w > 0 and d > 0
    ]
    if len(triples) <= 1:
        return 1.0
    total = sum(u for u, _, _ in triples)
    wtotal = sum(w for _, w, _ in triples)
    if total <= 0:
        return 1.0
    fair_rate = total / wtotal
    worst = min(
        u / min(w * fair_rate, d) for u, w, d in triples
    )
    return max(0.0, min(worst, 1.0))


def proportional_shares(throughputs: Sequence[float]) -> list[float]:
    """Normalized work shares ∝ throughput — the γ split as fractions.

    The paper's cloud pod contributes chips/K effective throughput; every
    place that recomputes shares after a fleet GROW/SHRINK/RETIRE or a
    rebalance goes through this one normalization (DESIGN.md §4).
    """
    total = sum(throughputs)
    if total <= 0:
        n = len(throughputs)
        return [1.0 / n] * n if n else []
    return [t / total for t in throughputs]


@dataclasses.dataclass(frozen=True)
class PodShare:
    pod: int
    microbatches: int            # real (unmasked) microbatches
    padded_microbatches: int     # uniform count run by every pod
    tokens: int


@dataclasses.dataclass(frozen=True)
class HeterogeneousPlan:
    shares: tuple[PodShare, ...]
    microbatch_size: int
    seq_len: int

    @property
    def total_tokens(self) -> int:
        return sum(s.tokens for s in self.shares)

    def mask_for(self, pod: int) -> np.ndarray:
        """(padded_microbatches,) 0/1 mask of real µ-batches for a pod."""
        sh = self.shares[pod]
        m = np.zeros(sh.padded_microbatches, np.float32)
        m[: sh.microbatches] = 1.0
        return m


def heterogeneous_split(
    *,
    global_batch: int,
    microbatch: int,
    seq_len: int,
    throughputs: Sequence[float],
) -> HeterogeneousPlan:
    """Split `global_batch` into per-pod microbatch counts ∝ throughput.

    throughputs: relative tokens/sec of each pod (the paper's 1/K for the
    cloud pod).  Total microbatches are preserved exactly; rounding
    residue goes to the fastest pod.
    """
    assert global_batch % microbatch == 0, (global_batch, microbatch)
    n_mb = global_batch // microbatch
    total_tp = sum(throughputs)
    raw = [n_mb * tp / total_tp for tp in throughputs]
    counts = [int(math.floor(r)) for r in raw]
    # distribute the remainder by largest fractional part, ties → fastest
    residue = n_mb - sum(counts)
    order = sorted(
        range(len(raw)),
        key=lambda i: (raw[i] - counts[i], throughputs[i]),
        reverse=True,
    )
    for i in range(residue):
        counts[order[i % len(order)]] += 1
    padded = max(counts) if counts else 0
    shares = tuple(
        PodShare(
            pod=i,
            microbatches=c,
            padded_microbatches=padded,
            tokens=c * microbatch * seq_len,
        )
        for i, c in enumerate(counts)
    )
    return HeterogeneousPlan(
        shares=shares, microbatch_size=microbatch, seq_len=seq_len
    )


def conservation_ok(plan: HeterogeneousPlan, global_batch: int) -> bool:
    return (
        sum(s.microbatches for s in plan.shares) * plan.microbatch_size
        == global_batch
    )
