"""Heterogeneous work allocator — the γ split realized for SPMD.

The paper assigns unequal domain shares to unequal environments.  SPMD
requires a uniform per-device program, so unequal shares are realized as
*unequal microbatch counts with padding + loss masking*: every pod runs
the same number of µ-steps (the max), but pods with a smaller share get
zero-masked filler microbatches.  Work conservation holds exactly: the
sum of unmasked tokens equals the global batch.

The striped/greedy second-level placement of the paper (§3.3) maps to
device order inside the mesh: a pod's microbatches are contiguous on its
"data" axis, so only the gradient reduction crosses the pod boundary.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


def proportional_shares(throughputs: Sequence[float]) -> list[float]:
    """Normalized work shares ∝ throughput — the γ split as fractions.

    The paper's cloud pod contributes chips/K effective throughput; every
    place that recomputes shares after a fleet GROW/SHRINK/RETIRE or a
    rebalance goes through this one normalization (DESIGN.md §4).
    """
    total = sum(throughputs)
    if total <= 0:
        n = len(throughputs)
        return [1.0 / n] * n if n else []
    return [t / total for t in throughputs]


@dataclasses.dataclass(frozen=True)
class PodShare:
    pod: int
    microbatches: int            # real (unmasked) microbatches
    padded_microbatches: int     # uniform count run by every pod
    tokens: int


@dataclasses.dataclass(frozen=True)
class HeterogeneousPlan:
    shares: tuple[PodShare, ...]
    microbatch_size: int
    seq_len: int

    @property
    def total_tokens(self) -> int:
        return sum(s.tokens for s in self.shares)

    def mask_for(self, pod: int) -> np.ndarray:
        """(padded_microbatches,) 0/1 mask of real µ-batches for a pod."""
        sh = self.shares[pod]
        m = np.zeros(sh.padded_microbatches, np.float32)
        m[: sh.microbatches] = 1.0
        return m


def heterogeneous_split(
    *,
    global_batch: int,
    microbatch: int,
    seq_len: int,
    throughputs: Sequence[float],
) -> HeterogeneousPlan:
    """Split `global_batch` into per-pod microbatch counts ∝ throughput.

    throughputs: relative tokens/sec of each pod (the paper's 1/K for the
    cloud pod).  Total microbatches are preserved exactly; rounding
    residue goes to the fastest pod.
    """
    assert global_batch % microbatch == 0, (global_batch, microbatch)
    n_mb = global_batch // microbatch
    total_tp = sum(throughputs)
    raw = [n_mb * tp / total_tp for tp in throughputs]
    counts = [int(math.floor(r)) for r in raw]
    # distribute the remainder by largest fractional part, ties → fastest
    residue = n_mb - sum(counts)
    order = sorted(
        range(len(raw)),
        key=lambda i: (raw[i] - counts[i], throughputs[i]),
        reverse=True,
    )
    for i in range(residue):
        counts[order[i % len(order)]] += 1
    padded = max(counts) if counts else 0
    shares = tuple(
        PodShare(
            pod=i,
            microbatches=c,
            padded_microbatches=padded,
            tokens=c * microbatch * seq_len,
        )
        for i, c in enumerate(counts)
    )
    return HeterogeneousPlan(
        shares=shares, microbatch_size=microbatch, seq_len=seq_len
    )


def conservation_ok(plan: HeterogeneousPlan, global_batch: int) -> bool:
    return (
        sum(s.microbatches for s in plan.shares) * plan.microbatch_size
        == global_batch
    )
