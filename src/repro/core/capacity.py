"""Capacity models — paper eqs. (1), (2), (3), (6), (7).

The paper empirically fits, per environment, a log-law between elapsed
time and core count:

    L_cluster(c) = -D·ln c + E        (eq. 2;  fitted eq. 7)
    L_cloud(c)   = -A·ln c + B        (eq. 1;  fitted eq. 6)

with L = log10(elapsed seconds) and c = cores.  The fit is done on a
small pre-processing job (paper §2) — here: a few monitored steps per
device count, or an analytic TPU cost model when no measurements exist.

The performance-correction factor between environments (paper §2):

    K(c) = L_cloud(c) / L_cluster(c)

and the cores to provision in the elastic environment (eq. 3):

    c_n = (c - c_cluster) · K

where c solves the cluster model for the deadline.  On TPU, "cores" are
chips and c_n is rounded UP to the nearest legal slice shape.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class LogCapacityModel:
    """L(c) = -A·ln c + B with L = log10(time in seconds)."""

    A: float
    B: float
    name: str = ""

    def log_time(self, cores: float) -> float:
        return -self.A * math.log(max(cores, 1e-12)) + self.B

    def predict_time(self, cores: float) -> float:
        """Elapsed seconds at `cores` (paper eq. 1/2 evaluated)."""
        return 10.0 ** self.log_time(cores)

    def cores_for(self, deadline_s: float) -> float:
        """Invert the model: cores needed to finish within deadline_s."""
        if deadline_s <= 0:
            return math.inf
        if self.A <= 0:
            return math.inf
        ln_c = (self.B - math.log10(deadline_s)) / self.A
        return math.exp(ln_c)

    @staticmethod
    def fit(cores: Sequence[float], times_s: Sequence[float],
            name: str = "") -> "LogCapacityModel":
        """Least-squares on (ln c, log10 t) — the paper's empirical fit."""
        assert len(cores) == len(times_s) and len(cores) >= 2
        xs = [math.log(c) for c in cores]
        ys = [math.log10(t) for t in times_s]
        n = len(xs)
        mx = sum(xs) / n
        my = sum(ys) / n
        sxx = sum((x - mx) ** 2 for x in xs)
        sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
        slope = sxy / max(sxx, 1e-12)
        intercept = my - slope * mx
        return LogCapacityModel(A=-slope, B=intercept, name=name)

    def r2(self, cores: Sequence[float], times_s: Sequence[float]) -> float:
        ys = [math.log10(t) for t in times_s]
        my = sum(ys) / len(ys)
        ss_tot = sum((y - my) ** 2 for y in ys)
        ss_res = sum(
            (y - self.log_time(c)) ** 2 for c, y in zip(cores, ys)
        )
        return 1.0 - ss_res / max(ss_tot, 1e-12)


def correction_factor(cloud: LogCapacityModel, cluster: LogCapacityModel,
                      cores: float, mode: str = "time") -> float:
    """Performance-correction factor K between environments (paper §2).

    mode="paper": K = L_cloud/L_cluster — the paper's literal ratio of
    log10 times.  Only meaningful when elapsed times are far from 1 s
    (the paper's jobs run 10^4-10^5 s); near log10(t)=0 it diverges.

    mode="time" (default): K = t_cloud/t_cluster = 10^(L_cloud−L_cluster)
    — the throughput ratio, dimensionless and stable at any time scale;
    this is what the planner uses.  bench_capacity_fit.py reports both
    (they agree to a few % in the paper's own regime).
    """
    lc = cluster.log_time(cores)
    ld = cloud.log_time(cores)
    if mode == "paper":
        if abs(lc) < 1e-12:
            return 1.0
        return ld / lc
    return 10.0 ** (ld - lc)


def burst_cores(
    cores_needed: float,
    cores_cluster: int,
    K: float,
) -> float:
    """Paper eq. 3: c_n = (c - c_cluster) · K (never negative)."""
    return max(cores_needed - cores_cluster, 0.0) * K


def round_to_legal_slice(c_n: float, legal: Sequence[int]) -> int:
    """Round the fractional chip demand UP to the nearest legal slice."""
    if c_n <= 0:
        return 0
    for s in sorted(legal):
        if s >= c_n:
            return s
    return max(legal)


def floor_to_legal_slice(c_n: float, legal: Sequence[int]) -> int:
    """Round the fractional chip grant DOWN to the nearest legal slice.

    The fleet controller's budget arbitration hands each competing
    grow request its max-min fair share of the remaining headroom;
    the share only becomes a provisionable pod at a legal slice shape,
    and rounding *up* would overspend the cap — so grants floor
    (0 means the request is denied this interval, DESIGN.md §16).
    """
    fit = [s for s in sorted(legal) if s <= c_n]
    return fit[-1] if fit else 0


def legal_step_up(current: int, legal: Sequence[int]) -> int:
    """Next legal slice strictly above `current` (max slice if at top).

    Reactive autoscalers grow one provisioning quantum at a time; on TPU
    the quantum is the next legal slice shape, not +1 chip.
    """
    for s in sorted(legal):
        if s > current:
            return s
    return max(legal)


def legal_step_down(current: int, legal: Sequence[int]) -> int:
    """Largest legal slice strictly below `current`; 0 means retire."""
    down = [s for s in sorted(legal) if s < current]
    return down[-1] if down else 0


@dataclasses.dataclass(frozen=True)
class ThroughputModel:
    """Linear-throughput alternative for per-step workloads.

    The paper's log-law models *total elapsed time* of a fixed job.  For
    step-periodic training the same machinery applies to step time; for
    near-perfect data parallelism t_step(c) ≈ w / c, which is the log-law
    with A = 1/ln(10).  We keep both: the fitted LogCapacityModel is used
    whenever measurements exist, this analytic fallback otherwise.
    """

    work_per_step: float  # chip-seconds per step

    def predict_step_time(self, chips: float) -> float:
        return self.work_per_step / max(chips, 1e-12)

    def chips_for_step_time(self, t_step: float) -> float:
        return self.work_per_step / max(t_step, 1e-12)


@dataclasses.dataclass(frozen=True)
class ShotBatchModel:
    """Affine shot-batch throughput law fitted from measured S-scaling:

        t_step(s) = a + b·s        (seconds per timestep, whole batch)

    ``a`` is the per-step cost the batch AMORTIZES — kernel launches /
    grid passes plus the shared model-field traffic the batched engine
    charges once (DESIGN.md §17); ``b`` is the irreducible per-shot
    cost (each shot's own wavefield reads/writes and stencil math).
    Feeding the planner this law instead of the naive ``s·t_step(1)``
    makes BurstPlanner's deadline calculus reflect the REAL batched
    engine: per-shot time falls as ``a/s + b``, so splitting a shot
    batch across more devices buys less than linear once ``a`` is
    amortized away."""

    a: float               # s/step, batch-amortized overhead
    b: float               # s/step/shot, irreducible per-shot work
    name: str = ""

    @staticmethod
    def fit(s_values: Sequence[float], t_steps: Sequence[float],
            name: str = "") -> "ShotBatchModel":
        """Least-squares fit of t_step(s) = a + b·s over measured
        (batch size, per-step wall clock) points; a is clamped at 0 so
        a noisily super-linear measurement can't imply negative
        overhead."""
        assert len(s_values) == len(t_steps) >= 2, (s_values, t_steps)
        n = float(len(s_values))
        ms = sum(s_values) / n
        mt = sum(t_steps) / n
        var = sum((s - ms) ** 2 for s in s_values)
        cov = sum((s - ms) * (t - mt)
                  for s, t in zip(s_values, t_steps))
        b = cov / var if var else 0.0
        a = max(mt - b * ms, 0.0)
        return ShotBatchModel(a=a, b=b, name=name)

    def t_step(self, s: float) -> float:
        """Seconds per timestep advancing a batch of ``s`` shots."""
        return self.a + self.b * max(s, 0.0)

    def per_shot_step_time(self, s: float) -> float:
        return self.t_step(s) / max(s, 1e-12)

    def amortization(self, s: float) -> float:
        """Speedup of the s-batch over s separate single-shot runs —
        the measured analogue of the traffic model's ratio."""
        return (s * self.t_step(1.0)) / max(self.t_step(s), 1e-12)
