"""Simulated hybrid-cluster session (paper-reproduction benchmarks).

Drives the ElasticOrchestrator with synthetic step times from
core/events.SimEnvironment — the same decision path a real TPU session
exercises, with wall-clock replaced by the simulated platform model
(DESIGN.md §10 records this boundary).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.events import SlowdownWindow
from repro.core.orchestrator import PodFailure, Resources


@dataclasses.dataclass
class SimWorkload:
    chip_seconds_per_step: float      # total work per step (chip·s)
    jitter: float = 0.02
    #: per-pod rate law exponent: a pod of c chips advances its share at
    #: rate ∝ c**alpha / K.  alpha = 1 is the work-conserving default;
    #: alpha > 1 models the superlinear regimes striped stencils hit
    #: when smaller per-device domains become cache-resident — the
    #: regime where the cost-aware planner's larger-but-cheaper slices
    #: are real (DESIGN.md §14).
    scaling_alpha: float = 1.0


class SimSession:
    """Session over a Resources allocation; per-step synchronization
    across pods (paper step 8) makes the step time the max over pods."""

    def __init__(
        self,
        workload: SimWorkload,
        res: Resources,
        start_step: int,
        restored,
        *,
        rng: np.random.Generator,
        windows: dict[int, list[SlowdownWindow]] | None = None,
        failures: dict[int, int] | None = None,  # step -> pod
        sync_overhead_s: float = 0.0,
        extra_slowdown: Callable[[int, int], float] | None = None,
    ):
        self.w = workload
        self.res = res
        self.rng = rng
        self.windows = windows or {}
        self.failures = failures or {}
        self.sync_overhead_s = sync_overhead_s
        # (pod_index, step) -> multiplicative slowdown, queried per step.
        # The fleet simulator hooks site contention in here so overload
        # *emerges* from background-tenant demand instead of being
        # scripted via SlowdownWindow (DESIGN.md §11).
        self.extra_slowdown = extra_slowdown
        # copy: the caller's checkpoint must stay immutable after restore
        self.state = dict(restored) if restored else {"step": start_step}

    def run_step(self, step: int) -> float:
        if step in self.failures:
            pod = self.failures.pop(step)
            if pod < len(self.res.pods):
                raise PodFailure(pod, step)
        times = []
        for i, (pod, share) in enumerate(
            zip(self.res.pods, self.res.shares)
        ):
            if share <= 0:
                continue
            t = (self.w.chip_seconds_per_step * share
                 / pod.chips ** self.w.scaling_alpha)
            t *= pod.slowdown
            for wdw in self.windows.get(i, []):
                if wdw.start_step <= step < wdw.end_step:
                    t *= wdw.factor
            if self.extra_slowdown is not None:
                t *= self.extra_slowdown(i, step)
            times.append(t)
        dt = max(times) if times else 0.0
        dt *= 1.0 + self.w.jitter * abs(float(self.rng.standard_normal()))
        if len(times) > 1:
            dt += self.sync_overhead_s
        self.state["step"] = step + 1
        return dt

    def checkpoint(self, step: int):
        return dict(self.state)


def sim_session_factory(workload: SimWorkload, *, rng=None, windows=None,
                        failures=None, sync_overhead_s=0.0,
                        extra_slowdown=None):
    rng = rng or np.random.default_rng(0)
    failures = dict(failures or {})

    def factory(res: Resources, start_step: int, restored) -> SimSession:
        return SimSession(
            workload, res, start_step, restored,
            rng=rng, windows=windows, failures=failures,
            sync_overhead_s=sync_overhead_s,
            extra_slowdown=extra_slowdown,
        )

    return factory
