"""Step-time monitor — paper §2 "time monitor".

The paper observes that PDE timesteps are near-constant, so a few
monitored steps predict the whole run.  We implement that check rather
than assume it: the monitor tracks a window of recent step times, flags
whether the series is *predictable* (robust coefficient of variation
below a threshold), and estimates the per-step time with a median-of-
window robust estimator plus an EWMA trend.  It also flags stragglers
(paper: "nodes down / concurrency in the local cluster") via a z-score
against the window median/MAD.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque


def _median(xs) -> float:
    s = sorted(xs)
    n = len(s)
    if n == 0:
        return 0.0
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


@dataclasses.dataclass
class StepStats:
    step: int
    seconds: float
    is_straggler: bool
    zscore: float


class StepTimeMonitor:
    def __init__(
        self,
        window: int = 32,
        ewma_alpha: float = 0.2,
        straggler_z: float = 4.0,
        predictable_cv: float = 0.25,
        warmup_steps: int = 2,
    ):
        self.window = window
        self.alpha = ewma_alpha
        self.straggler_z = straggler_z
        self.predictable_cv = predictable_cv
        self.warmup_steps = warmup_steps
        self._times: Deque[float] = deque(maxlen=window)
        self._all: Deque[float] = deque(maxlen=window)
        self._ewma: float | None = None
        self._count = 0
        self._consecutive_rejects = 0
        self.stragglers: list[StepStats] = []
        self.regime_changes: list[int] = []
        self.total_observed_s = 0.0

    def observe(self, seconds: float) -> StepStats:
        self._count += 1
        self.total_observed_s += seconds
        z = 0.0
        straggler = False
        if self._count > self.warmup_steps and len(self._times) >= 4:
            med = _median(self._times)
            mad = _median([abs(t - med) for t in self._times]) or 1e-9
            z = (seconds - med) / (1.4826 * mad)
            straggler = z > self.straggler_z
        stats = StepStats(self._count, seconds, straggler, z)
        self._all.append(seconds)
        if straggler:
            self.stragglers.append(stats)
            self._consecutive_rejects += 1
            # change-point handling: a sustained shift is a new regime
            # (paper: cluster congestion), not stragglers — flush the
            # window and trust the recent observations
            if self._consecutive_rejects >= max(4, self.window // 8):
                self._times.clear()
                recent = list(self._all)[-self._consecutive_rejects:]
                self._times.extend(recent)
                self._ewma = recent[-1]
                self.regime_changes.append(self._count)
                self._consecutive_rejects = 0
        else:
            self._consecutive_rejects = 0
        # isolated stragglers pollute the estimate of the *typical* step;
        # keep them out of the window but remember they happened (the
        # planner uses the straggler rate as a signal)
        if not straggler or self._count <= self.warmup_steps:
            self._times.append(seconds)
            self._ewma = (
                seconds if self._ewma is None
                else self.alpha * seconds + (1 - self.alpha) * self._ewma
            )
        return stats

    @property
    def count(self) -> int:
        return self._count

    def step_time(self) -> float:
        """Robust current per-step estimate (median ⊕ EWMA blend)."""
        if not self._times:
            return 0.0
        med = _median(self._times)
        if self._ewma is None:
            return med
        return 0.5 * (med + self._ewma)

    def predictable(self) -> bool:
        """Paper §2: initial steps are monitored to reason whether the
        workload is predictable before trusting extrapolation."""
        if len(self._times) < max(4, self.warmup_steps + 2):
            return False
        med = _median(self._times)
        if med <= 0:
            return False
        mad = _median([abs(t - med) for t in self._times])
        return (1.4826 * mad) / med <= self.predictable_cv

    def straggler_rate(self, last_n: int = 64) -> float:
        recent = [s for s in self.stragglers if s.step > self._count - last_n]
        return len(recent) / max(min(self._count, last_n), 1)

    def reset_window(self):
        """Called after a re-configuration (burst): old step times no
        longer describe the new platform."""
        self._times.clear()
        self._all.clear()
        self._ewma = None
        self._consecutive_rejects = 0
