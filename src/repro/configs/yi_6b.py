"""01.AI Yi-6B — llama-arch dense GQA kv=4 [arXiv:2403.04652; hf]."""
from repro.configs.base import ModelConfig, dense_blocks, register

YI_6B = register(ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    blocks=dense_blocks(32),
    rope_theta=5_000_000.0,
    param_dtype="float32",
    optimizer="adamw",
    remat="full",
    source="arXiv:2403.04652 (Yi); hf 01-ai/Yi-6B",
))
