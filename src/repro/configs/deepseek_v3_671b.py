"""DeepSeek-V3 671B — MLA + 1 shared / 256 routed top-8 MoE + MTP
[arXiv:2412.19437; hf deepseek-ai/DeepSeek-V3].

Assigned line "d_ff=2048" is the routed-expert hidden (hf
moe_intermediate_size); the first 3 dense layers use intermediate 18432
(hf intermediate_size).  MLA: q_lora 1536, kv_lora 512, rope head 64.
Optimizer: Adafactor — AdamW fp32 moments would need ~9.4 TiB of state,
exceeding a 256-chip v5e pod (DESIGN.md §6).
"""
from repro.configs.base import BlockDef, MLAConfig, ModelConfig, MoEConfig, register

DEEPSEEK_V3_671B = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,
    vocab_size=129280,
    blocks=(
        BlockDef(pattern=(("mla", "dense"),), repeat=3),
        BlockDef(pattern=(("mla", "moe"),), repeat=58),
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        num_shared_experts=1,
        top_k=8,
        d_ff=2048,
        capacity_factor=1.25,
        group_size=8192,
        # EP over "data" with explicit all-to-all dispatch: -74% collective
        # time and -43% compute vs FSDP-regathered experts
        # (EXPERIMENTS.md §Perf hillclimb A)
        ep_over_dp=True,
    ),
    rope_theta=10_000.0,
    mtp=True,
    param_dtype="bfloat16",
    optimizer="adafactor",
    remat="full",
    source="arXiv:2412.19437 (DeepSeek-V3); hf deepseek-ai/DeepSeek-V3",
))
