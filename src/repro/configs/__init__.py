"""Architecture registry — one module per assigned arch (+ the paper's FWI).

``get_config("<id>")`` resolves any of the ten assigned architectures;
``smoke_config(cfg)`` shrinks one for CPU tests.
"""
from repro.configs.base import REGISTRY, ModelConfig, RunConfig, get_config, register
from repro.configs.granite_8b import GRANITE_8B
from repro.configs.yi_6b import YI_6B
from repro.configs.yi_9b import YI_9B
from repro.configs.minitron_8b import MINITRON_8B
from repro.configs.deepseek_v3_671b import DEEPSEEK_V3_671B
from repro.configs.deepseek_v2_236b import DEEPSEEK_V2_236B
from repro.configs.qwen2_vl_72b import QWEN2_VL_72B
from repro.configs.whisper_large_v3 import WHISPER_LARGE_V3
from repro.configs.mamba2_370m import MAMBA2_370M
from repro.configs.jamba_v0_1_52b import JAMBA_V01_52B
from repro.configs.smoke import smoke_config
from repro.configs.shapes import SHAPES, SMOKE_SHAPES, ShapeConfig, cell_is_runnable, input_specs

ALL_ARCHS = [
    "granite-8b",
    "yi-6b",
    "yi-9b",
    "minitron-8b",
    "deepseek-v3-671b",
    "deepseek-v2-236b",
    "qwen2-vl-72b",
    "whisper-large-v3",
    "mamba2-370m",
    "jamba-v0.1-52b",
]

__all__ = [
    "ALL_ARCHS",
    "ModelConfig",
    "RunConfig",
    "REGISTRY",
    "SHAPES",
    "SMOKE_SHAPES",
    "ShapeConfig",
    "cell_is_runnable",
    "get_config",
    "input_specs",
    "register",
    "smoke_config",
]
