"""Jamba-v0.1 52B — Mamba+attention 1:7 interleave with 16-expert top-2 MoE
[arXiv:2403.19887; hf ai21labs/Jamba-v0.1].

Period-8 block: attention at in-period index 4, Mamba elsewhere (a=1, l=8);
MoE replaces the MLP at every other layer (e=2, odd offsets).  Jamba's
mixer is Mamba-1 (d_state=16, conv 4, expand 2); we adapt it to the
Mamba-2/SSD formulation (TPU-native chunked scan, same state size) —
recorded as a hardware-adaptation change in DESIGN.md.  Hybrid ->
subquadratic=True: the long_500k cell runs with the 4 attention layers'
KV cache sequence-sharded.
"""
from repro.configs.base import BlockDef, ModelConfig, MoEConfig, SSMConfig, register

_PERIOD = tuple(
    ("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

JAMBA_V01_52B = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    blocks=(BlockDef(pattern=_PERIOD, repeat=4),),
    moe=MoEConfig(
        num_experts=16,
        num_shared_experts=0,
        top_k=2,
        d_ff=14336,
        capacity_factor=1.25,
        group_size=4096,
    ),
    ssm=SSMConfig(
        d_state=16,
        d_conv=4,
        expand=2,
        head_dim=64,
        n_groups=1,
        chunk=256,
    ),
    rope_type="none",       # Jamba uses no positional encoding
    pos_embed="none",
    subquadratic=True,
    param_dtype="bfloat16",
    optimizer="adamw",
    remat="full",
    source="arXiv:2403.19887 (Jamba); hf ai21labs/Jamba-v0.1",
))
