"""DeepSeek-V2 236B — MLA kv_lora=512 + 2 shared / 160 routed top-6 MoE
[arXiv:2405.04434; hf deepseek-ai/DeepSeek-V2].

Assigned "d_ff=1536" is the routed-expert hidden; the single leading dense
layer uses intermediate 12288.  Optimizer: AdamW with int8-quantized
moments (8-bit Adam) — fp32 m+v would be ~1.9 TiB (DESIGN.md §6).
"""
from repro.configs.base import BlockDef, MLAConfig, ModelConfig, MoEConfig, register

DEEPSEEK_V2_236B = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=12288,
    vocab_size=102400,
    blocks=(
        BlockDef(pattern=(("mla", "dense"),), repeat=1),
        BlockDef(pattern=(("mla", "moe"),), repeat=59),
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160,
        num_shared_experts=2,
        top_k=6,
        d_ff=1536,
        capacity_factor=1.25,
        group_size=8192,
        # EP over "data" with explicit all-to-all dispatch: -74% collective
        # time and -43% compute vs FSDP-regathered experts
        # (EXPERIMENTS.md §Perf hillclimb A)
        ep_over_dp=True,
    ),
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    optimizer="adamw8bit",
    remat="full",
    source="arXiv:2405.04434 (DeepSeek-V2); hf deepseek-ai/DeepSeek-V2",
))
