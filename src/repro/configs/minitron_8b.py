"""NVIDIA Minitron-8B — pruned+distilled Nemotron-4 [arXiv:2407.14679; hf].

Nemotron uses squared-ReLU MLP (no gate); kept here via mlp_act="relu2".
vocab 256000 with a 256k sentencepiece tokenizer — the embedding table is
the dominant non-layer tensor and is vocab-sharded on "model".
"""
from repro.configs.base import ModelConfig, dense_blocks, register

MINITRON_8B = register(ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    blocks=dense_blocks(32),
    rope_theta=10_000.0,
    mlp_act="relu2",
    param_dtype="float32",
    optimizer="adamw",
    remat="full",
    source="arXiv:2407.14679 (Minitron); hf nvidia/Minitron-8B-Base",
))
