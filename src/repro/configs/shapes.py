"""Input-shape cells (assigned) + ShapeDtypeStruct input builders.

Four shapes per LM arch:
  train_4k     seq 4096   global_batch 256   -> train_step
  prefill_32k  seq 32768  global_batch 32    -> prefill_step
  decode_32k   seq 32768  global_batch 128   -> decode_step (1 new token)
  long_500k    seq 524288 global_batch 1     -> decode_step (sub-quadratic only)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# Smoke-scale variants of the same programs (CPU tests).
SMOKE_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 64, 4),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 64, 2),
    "decode_32k": ShapeConfig("decode_32k", "decode", 64, 4),
    "long_500k": ShapeConfig("long_500k", "decode", 128, 1),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k only runs for sub-quadratic (SSM/hybrid) archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "skipped: pure full-attention arch has no sub-quadratic path "
            "(DESIGN.md §7)"
        )
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every non-param model input.

    Weak-type-correct, shardable, no device allocation.  The KV/SSM cache
    specs for decode come from the model (models.model.cache_abstract).
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    specs: dict = {}
    if shape.kind == "train":
        if cfg.input_mode == "embeds":
            specs["embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = sds((B, S), jnp.int32)  # labels source
        else:
            specs["tokens"] = sds((B, S), jnp.int32)
        specs["loss_mask"] = sds((B, S), jnp.float32)
        if cfg.rope_type == "mrope":
            specs["positions"] = sds((B, 3, S), jnp.int32)
        if cfg.cross_attention:
            specs["enc_embeds"] = sds(
                (B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16
            )
    elif shape.kind == "prefill":
        if cfg.input_mode == "embeds":
            specs["embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        else:
            specs["tokens"] = sds((B, S), jnp.int32)
        if cfg.rope_type == "mrope":
            specs["positions"] = sds((B, 3, S), jnp.int32)
        if cfg.cross_attention:
            specs["enc_embeds"] = sds(
                (B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16
            )
    elif shape.kind == "decode":
        specs["token"] = sds((B,), jnp.int32)
        specs["pos"] = sds((), jnp.int32)
        if cfg.rope_type == "mrope":
            specs["positions"] = sds((B, 3), jnp.int32)
    else:
        raise ValueError(shape.kind)
    return specs


def tokens_like(spec_tree, key=None):
    """Materialize concrete inputs matching input_specs (smoke tests)."""
    key = key if key is not None else jax.random.key(0)

    def mk(s):
        nonlocal key
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            if s.shape == ():
                return jnp.asarray(3, s.dtype)
            return jax.random.randint(sub, s.shape, 0, 17).astype(s.dtype)
        return jax.random.normal(sub, s.shape, jnp.float32).astype(s.dtype)

    out = {}
    for k, v in spec_tree.items():
        if k == "loss_mask":
            out[k] = jnp.ones(v.shape, v.dtype)
        else:
            out[k] = mk(v)
    return out
