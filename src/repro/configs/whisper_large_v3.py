"""Whisper-large-v3 — encoder-decoder, conv frontend stubbed
[arXiv:2212.04356; unverified tier].

Assigned "32L" = 32 decoder layers; the symmetric 32-layer encoder is also
modeled (true whisper-large shape).  The log-mel + conv frontend is a STUB:
input_specs() provides precomputed frame embeddings (batch, 1500, d_model).
Whisper uses absolute sinusoidal positions (pos_embed="sinusoidal"), MHA
(kv=20 == heads), head_dim 64.  Decoder-only shapes (prefill/decode) attach
a cross-attention cache computed once from the encoder output.
"""
from repro.configs.base import BlockDef, ModelConfig, register

WHISPER_LARGE_V3 = register(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    blocks=(BlockDef(pattern=(("attn", "dense"),), repeat=32),),
    encoder_layers=32,
    encoder_frames=1500,
    cross_attention=True,
    pos_embed="sinusoidal",
    rope_type="none",
    mlp_act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    param_dtype="float32",
    optimizer="adamw",
    remat="full",  # "dots" saves unsharded score chunks: 84 GiB at multi
    # 20 heads cannot shard on a 16-way model axis: TP would replicate
    # attention on every model rank (16x). A 1.5B model on 256 chips is
    # best run fully data-parallel (EXPERIMENTS.md §Perf, hillclimb B:
    # step bound 24.9s -> 1.8s).
    flat_dp=True,
    source="arXiv:2212.04356 (Whisper); openai/whisper-large-v3 [unverified]",
))
