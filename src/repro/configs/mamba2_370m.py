"""Mamba2-370M — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified tier].

48 layers of pure Mamba-2 mixers (d_ff=0: no separate MLP — the mixer's
expand-2 projection is the FFN).  d_inner=2048, head_dim 64 -> 32 SSD
heads, d_state=128, chunked SSD scan for train/prefill, O(1) recurrent
state for decode -> runs the long_500k cell (subquadratic=True).
"""
from repro.configs.base import BlockDef, ModelConfig, SSMConfig, register

MAMBA2_370M = register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    blocks=(BlockDef(pattern=(("mamba", "none"),), repeat=48),),
    ssm=SSMConfig(
        d_state=128,
        d_conv=4,
        expand=2,
        head_dim=64,
        n_groups=1,
        chunk=256,
    ),
    rope_type="none",
    pos_embed="none",
    tie_embeddings=True,
    subquadratic=True,
    param_dtype="float32",
    optimizer="adamw",
    remat="full",
    source="arXiv:2405.21060 (Mamba-2/SSD); state-spaces/mamba2-370m [unverified]",
))
