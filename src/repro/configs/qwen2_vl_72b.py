"""Qwen2-VL-72B backbone — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

VLM: only the transformer BACKBONE is modeled; the vision frontend is a
STUB — input_specs() provides precomputed patch embeddings merged into the
token stream (input_mode="embeds") plus 3-D M-RoPE position ids
(temporal/height/width sections of the rotary dim).
"""
from repro.configs.base import ModelConfig, dense_blocks, register

QWEN2_VL_72B = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    blocks=dense_blocks(80),
    rope_theta=1_000_000.0,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),
    input_mode="embeds",
    param_dtype="bfloat16",
    optimizer="adamw",
    remat="full",
    source="arXiv:2409.12191 (Qwen2-VL); hf Qwen/Qwen2-VL-72B",
))
