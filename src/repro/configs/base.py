"""Model / run configuration dataclasses.

One ``ModelConfig`` describes an architecture; ``ShapeConfig`` (shapes.py)
describes an input-shape cell; ``RunConfig`` carries runtime knobs
(microbatching, remat, optimizer) so the same arch can be tuned per cell.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

MixerKind = Literal["attn", "mla", "mamba"]
MlpKind = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class BlockDef:
    """A repeated group of layers, scanned with stacked params.

    ``pattern`` lists (mixer, mlp) per layer inside one repeat unit; the
    unit is repeated ``repeat`` times via lax.scan.  Heterogeneous stacks
    (DeepSeek dense-then-MoE, Jamba 1:7 interleave) become several blocks.
    """

    pattern: tuple[tuple[MixerKind, MlpKind], ...]
    repeat: int

    @property
    def layers(self) -> int:
        return len(self.pattern) * self.repeat


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 1
    d_ff: int = 0                    # per-expert hidden
    capacity_factor: float = 1.25
    group_size: int = 2048           # tokens per dispatch group (einsum path)
    dispatch: str = "einsum"         # "einsum" | "scatter"
    # expert-parallel layout: shard experts over (data×model) and move
    # TOKENS via all-to-all instead of FSDP-regathering expert weights
    # every use (EXPERIMENTS.md §Perf hillclimb A) — needs
    # num_experts % (data·model) == 0.
    ep_over_dp: bool = False
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-4
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    dt_min: float = 1e-3
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 0             # 0 = full-rank q projection
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    blocks: tuple[BlockDef, ...] = ()
    # attention
    rope_theta: float = 1e4
    rope_type: str = "default"       # default | mrope | none
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    query_chunk: int = 1024          # chunked (flash-style) attention in XLA
    mlp_act: str = "swiglu"          # swiglu | relu2 | gelu
    pos_embed: str = "rope"          # rope | sinusoidal | none
    # sub-configs
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    mla: MLAConfig | None = None
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500       # stub conv-frontend output length
    cross_attention: bool = False
    # embeddings / IO
    tie_embeddings: bool = False
    input_mode: str = "tokens"       # tokens | embeds (vlm/audio stubs)
    mtp: bool = False                # DeepSeek-V3 multi-token prediction head
    mtp_weight: float = 0.3
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    # dtypes
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # runtime defaults (overridable per-cell)
    optimizer: str = "adamw"         # adamw | adamw8bit | adafactor
    remat: str = "full"              # none | dots | full
    # long-context capability flag (sub-quadratic decode memory/compute)
    subquadratic: bool = False
    # flat data parallelism: use the "model" mesh axis as extra DP (for
    # archs whose heads don't divide it — see sharding/rules.make_rules)
    flat_dp: bool = False
    source: str = ""                 # provenance note

    # ---- derived ----
    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def block_layers(self) -> int:
        return sum(b.layers for b in self.blocks)

    def validate(self):
        assert self.block_layers() == self.num_layers, (
            f"{self.name}: blocks cover {self.block_layers()} layers, "
            f"config says {self.num_layers}"
        )
        if self.num_heads and self.mla is None:
            assert self.d_model % self.num_heads == 0 or self.head_dim
        if self.moe is not None:
            assert any(
                mlp == "moe" for b in self.blocks for _, mlp in b.pattern
            )
        return self


def dense_blocks(n: int) -> tuple[BlockDef, ...]:
    return (BlockDef(pattern=(("attn", "dense"),), repeat=n),)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Per-cell runtime knobs."""

    microbatch: int | None = None    # global microbatch size (None = no accum)
    remat: str | None = None         # override ModelConfig.remat
    optimizer: str | None = None
    grad_dtype: str = "float32"      # gradient accumulation dtype
    zero1: bool = True               # shard optimizer state over data axis
    seq_shard: bool = False          # Megatron-SP residuals (see rules.py)
    loss_chunk: int = 512            # chunked xent over seq
    gradient_compression: str = "none"   # none | int8  (cross-pod)
    pipeline_stages: int = 1         # >1: GPipe over the "pod" axis
    pp_microbatches: int = 8


REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    cfg.validate()
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import side-effect registration
    from repro.configs import ALL_ARCHS  # noqa: F401

    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]
