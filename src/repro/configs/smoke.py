"""Reduced same-family configs for CPU smoke tests.

Shrinks width/depth/experts/vocab while keeping the exact layer pattern,
mixer kinds, MoE routing, MLA factorization, M-RoPE, MTP, etc. — so every
code path of the full config is exercised on CPU in milliseconds.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import BlockDef, MLAConfig, ModelConfig, MoEConfig, SSMConfig


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    blocks = tuple(
        BlockDef(pattern=b.pattern, repeat=min(b.repeat, 1)) for b in cfg.blocks
    )
    layers = sum(b.layers for b in blocks)
    moe = None
    if cfg.moe is not None:
        # capacity_factor = E/k makes C == group_size: drop-free routing, so
        # MoE outputs are group-composition invariant (prefill == decode).
        moe = MoEConfig(
            num_experts=8,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            top_k=min(cfg.moe.top_k, 2),
            d_ff=64,
            capacity_factor=4.0,
            group_size=16,
            dispatch=cfg.moe.dispatch,
            ep_over_dp=cfg.moe.ep_over_dp,
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = SSMConfig(
            d_state=16,
            d_conv=cfg.ssm.d_conv,
            expand=2,
            head_dim=16,
            n_groups=cfg.ssm.n_groups,
            chunk=16,
        )
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(
            q_lora_rank=32 if cfg.mla.q_lora_rank else 0,
            kv_lora_rank=32,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        )
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=64,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=128,
        blocks=blocks,
        moe=moe,
        ssm=ssm,
        mla=mla,
        mrope_sections=(2, 3, 3) if cfg.rope_type == "mrope"
        else cfg.mrope_sections,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_frames=16 if cfg.encoder_layers else cfg.encoder_frames,
        query_chunk=16,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    ).validate()
