"""IBM Granite-8B (code) — llama-arch dense GQA [arXiv:2405.04324; hf]."""
from repro.configs.base import ModelConfig, dense_blocks, register

GRANITE_8B = register(ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    blocks=dense_blocks(36),
    rope_theta=10_000_000.0,
    tie_embeddings=False,
    param_dtype="float32",
    optimizer="adamw",
    remat="full",
    source="arXiv:2405.04324 (Granite Code Models); hf ibm-granite/granite-8b-code-base",
))
