"""Deterministic synthetic token pipeline — sharded, checkpointable.

Batches are a pure function of (seed, step): restoring `step` from a
checkpoint restores the exact data stream with no iterator state files.
Documents are zipf-distributed token runs with EOS boundaries so the LM
loss is non-degenerate; loss masks zero out padding after final EOS.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int

    def to_extra(self) -> dict:
        return {"data_seed": self.seed, "data_step": self.step}

    @staticmethod
    def from_extra(extra: dict) -> "PipelineState":
        return PipelineState(
            seed=int(extra.get("data_seed", 0)),
            step=int(extra.get("data_step", 0)),
        )


class SyntheticLMPipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.state = PipelineState(seed=seed, step=0)

    def batch_at(self, step: int) -> dict:
        cfg, shape = self.cfg, self.shape
        B, S = shape.global_batch, shape.seq_len
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed, step])
        )
        # zipf-ish unigram stream with doc boundaries
        V = cfg.vocab_size
        ranks = rng.zipf(1.3, size=(B, S)).astype(np.int64)
        tokens = np.clip(ranks, 1, V - 1).astype(np.int32)
        doc_len = rng.integers(S // 4, S, size=(B,))
        mask = (np.arange(S)[None, :] < doc_len[:, None]).astype(np.float32)
        out = {
            "tokens": jnp.asarray(tokens),
            "loss_mask": jnp.asarray(mask),
        }
        if cfg.input_mode == "embeds":
            emb = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32)
            out["embeds"] = jnp.asarray(emb, jnp.bfloat16)
        if cfg.rope_type == "mrope":
            pos = np.broadcast_to(np.arange(S)[None, None], (B, 3, S))
            out["positions"] = jnp.asarray(pos.copy(), jnp.int32)
        if cfg.cross_attention:
            enc = rng.standard_normal(
                (B, cfg.encoder_frames, cfg.d_model)
            ).astype(np.float32)
            out["enc_embeds"] = jnp.asarray(enc, jnp.bfloat16)
        return out

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    def restore(self, extra: dict):
        self.state = PipelineState.from_extra(extra)
