"""Version-compatibility shims for the installed JAX.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` with a
slightly different keyword surface (``axis_names``/``check_vma`` instead
of ``auto``/``check_rep``).  Every module in this repo imports
``shard_map`` from here so the same call sites run on both APIs:

    from repro.compat import shard_map

    shard_map(f, mesh=mesh, in_specs=..., out_specs=...,
              axis_names={"pod"}, check_vma=False)

On a JAX that only ships the experimental API, ``axis_names`` is
translated to its complement (``auto`` = mesh axes NOT listed) and
``check_vma`` maps onto ``check_rep``.
"""
from __future__ import annotations

import threading

import jax

_MANUAL_CTX = threading.local()

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None, check_rep=None):
        """New-style ``jax.shard_map`` signature on the experimental API."""
        kwargs = {}
        manual = (
            frozenset(axis_names) if axis_names is not None
            else frozenset(mesh.axis_names)
        )
        if axis_names is not None:
            kwargs["auto"] = frozenset(mesh.axis_names) - manual
        if check_vma is None:
            check_vma = check_rep
        if check_vma is not None:
            kwargs["check_rep"] = bool(check_vma)

        def wrapped(*args):
            # record the manual axis set for mesh_and_manual() while the
            # body traces (the old API has no queryable abstract mesh)
            prev = getattr(_MANUAL_CTX, "v", None)
            _MANUAL_CTX.v = (mesh, manual)
            try:
                return f(*args)
            finally:
                _MANUAL_CTX.v = prev

        return _exp_shard_map(
            wrapped, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            **kwargs
        )


def mesh_and_manual(fallback_mesh=None):
    """(mesh, manual axis names, constrainable) inside/outside shard_map.

    New JAX: the abstract mesh plus its Manual-typed axes; sharding
    constraints (with manual axes dropped from the spec) are legal inside
    manual regions.  Old JAX: the physical mesh recorded by the compat
    ``shard_map`` wrapper — but ``with_sharding_constraint`` inside a
    manual region trips an XLA partitioner CHECK there, so
    ``constrainable`` is False and callers must skip the (purely
    performance) constraint.
    """
    gam = getattr(jax.sharding, "get_abstract_mesh", None)
    if gam is not None:
        am = gam()
        manual = {
            name
            for name, t in zip(
                getattr(am, "axis_names", ()), getattr(am, "axis_types", ())
            )
            if "Manual" in str(t)
        }
        return am, manual, True
    ctx = getattr(_MANUAL_CTX, "v", None)
    if ctx is not None:
        return ctx[0], set(ctx[1]), False
    return fallback_mesh, set(), True


def axis_size(name) -> int:
    """``jax.lax.axis_size`` with a legacy-JAX fallback (axis env)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    from jax._src import core as _core

    return _core.get_axis_env().axis_sizes[name]


def configure_partial_auto() -> None:
    """Work around a GSPMD partitioner CHECK on legacy JAX.

    On the experimental-shard_map JAX, differentiating a ``lax.scan``
    inside a partial-auto region (manual over "pod", auto data/model)
    aborts XLA with ``Check failed: sharding.IsManualSubgroup()``.  The
    shardy partitioner handles the same program; opt into it when the
    legacy API is in use.  Call once, before tracing any partial-auto
    step function.  No-op on JAX with native ``jax.shard_map``.
    """
    if not hasattr(jax, "shard_map"):
        jax.config.update("jax_use_shardy_partitioner", True)


__all__ = [
    "shard_map", "mesh_and_manual", "axis_size", "configure_partial_auto",
]
