"""repro-lint core: AST static-analysis framework (DESIGN.md §18).

The pieces every rule shares:

* ``Finding``      — one structured diagnostic (file:line:col, rule id,
                     message), ordered and hashable so reports are
                     stable and deduplicated.
* ``FileContext``  — a parsed source file (path, text, AST, per-line
                     suppression map).
* ``Rule``         — the protocol: a ``name`` and
                     ``run(ctxs, root) -> findings``.  Rules see the
                     WHOLE file set, so cross-file passes (e.g. the
                     vmem-budget rule reading the capacity formulas
                     from one module and the kernels from another) are
                     first-class; ``PerFileRule`` is the trivial
                     adapter for rules that only look at one file at a
                     time.
* ``Analyzer``     — loads files, runs rules, applies inline
                     suppressions, renders human or JSON output.

Suppressions: ``# lint: disable=<rule>[,<rule>...]`` on the finding's
line silences those rules there; on a comment-only line it also covers
the next line (the idiom for multi-line calls: put the comment — with
a justification after the rule list — right above the call).
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Iterable, Iterator, Protocol, runtime_checkable

SUPPRESS_RE = re.compile(r"#\s*lint:\s*disable=([\w,-]+)")

_EMPTY: frozenset[str] = frozenset()


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic: where (file:line:col), what (rule), why (message)."""

    file: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.file}:{self.line}:{self.col}: {self.rule} {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _suppression_map(lines: list[str]) -> dict[int, set[str]]:
    """line number -> rule names silenced there (1-based).

    A marker on a code line covers that line; a marker inside a
    comment block ALSO covers the next code line after the block, so
    multi-line justifications can sit above a multi-line call."""
    out: dict[int, set[str]] = {}

    def is_commentish(text: str) -> bool:
        s = text.strip()
        return not s or s.startswith("#")

    for idx, text in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        out.setdefault(idx, set()).update(rules)
        if text.lstrip().startswith("#"):
            nxt = idx + 1
            while nxt <= len(lines) and is_commentish(lines[nxt - 1]):
                nxt += 1
            out.setdefault(nxt, set()).update(rules)
    return out


class FileContext:
    """A parsed source file as rules see it."""

    def __init__(self, path: str | pathlib.Path, source: str,
                 rel: str | None = None):
        self.path = pathlib.Path(path)
        self.rel = rel if rel is not None else str(path)
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self._suppressed = _suppression_map(self.lines)

    @property
    def parts(self) -> tuple[str, ...]:
        return pathlib.PurePosixPath(self.rel.replace("\\", "/")).parts

    def suppressed(self, rule: str, line: int) -> bool:
        rules = self._suppressed.get(line, _EMPTY)
        return rule in rules or "all" in rules


@runtime_checkable
class Rule(Protocol):
    """A lint rule: a stable id and a pass over the parsed file set."""

    name: str

    def run(self, ctxs: list[FileContext],
            root: pathlib.Path) -> Iterator[Finding]: ...


class PerFileRule:
    """Adapter for rules that inspect one file at a time."""

    name = "per-file"

    def run(self, ctxs: list[FileContext],
            root: pathlib.Path) -> Iterator[Finding]:
        for ctx in ctxs:
            yield from self.check(ctx)

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        raise NotImplementedError


def iter_py_files(paths: Iterable[str | pathlib.Path],
                  root: pathlib.Path) -> Iterator[pathlib.Path]:
    for p in paths:
        p = pathlib.Path(p)
        if not p.is_absolute():
            p = root / p
        if p.is_dir():
            yield from sorted(
                f for f in p.rglob("*.py") if "__pycache__" not in f.parts
            )
        elif p.suffix == ".py" and p.exists():
            yield p


class Analyzer:
    """Load a file set, run rules over it, apply suppressions."""

    def __init__(self, rules: Iterable[Rule], root: str | pathlib.Path):
        self.rules = list(rules)
        self.root = pathlib.Path(root)

    def load(self, paths: Iterable[str | pathlib.Path]) -> list[FileContext]:
        ctxs = []
        for f in iter_py_files(paths, self.root):
            try:
                rel = str(f.relative_to(self.root))
            except ValueError:
                rel = str(f)
            ctxs.append(FileContext(f, f.read_text(), rel=rel))
        return ctxs

    def run(self, ctxs: list[FileContext]) -> list[Finding]:
        by_rel = {c.rel: c for c in ctxs}
        findings: set[Finding] = set()
        for rule in self.rules:
            for fd in rule.run(ctxs, self.root):
                ctx = by_rel.get(fd.file)
                if ctx is not None and ctx.suppressed(fd.rule, fd.line):
                    continue
                findings.add(fd)
        return sorted(findings)


def analyze_source(source: str, rules, filename: str = "fixture.py",
                   root: str | pathlib.Path | None = None) -> list[Finding]:
    """Run rules over one in-memory source blob (the test-fixture API).

    ``filename`` doubles as the relative path rules use for
    applicability (e.g. ``src/repro/sim/x.py`` for sim-determinism)."""
    if not isinstance(rules, (list, tuple)):
        rules = [rules]
    ctx = FileContext(filename, source, rel=filename)
    rootp = pathlib.Path(root) if root is not None else pathlib.Path(".")
    out: set[Finding] = set()
    for rule in rules:
        for fd in rule.run([ctx], rootp):
            if fd.file == ctx.rel and ctx.suppressed(fd.rule, fd.line):
                continue
            out.add(fd)
    return sorted(out)


def render_human(findings: list[Finding]) -> str:
    return "\n".join(f.render() for f in findings)


def to_json(findings: list[Finding], rules: Iterable[str] = ()) -> str:
    return json.dumps(
        {
            "version": 1,
            "rules": sorted(rules),
            "count": len(findings),
            "findings": [f.to_dict() for f in findings],
        },
        indent=2,
    )
