"""dma-pairing: static race detector for manual async-copy kernels.

The streamed stencil kernels (DESIGN.md §15/§17) drive their own
double-buffered window DMA: program ``i`` starts strip ``i+1``'s fetch
into the other slot (guarded by ``pl.when``), then waits on its own
slot.  The pairing is CROSS-PROGRAM — the ``.start()`` guarded by
``i + 1 < n`` is consumed by the ``.wait()`` of program ``i + 1`` — so
a naive "wait on every path" checker would flag the correct idiom.
This rule understands it instead:

* every async-copy producer (a helper returning ``make_async_copy``
  handles, or an inline ``make_async_copy``) with ``.start()`` calls
  in a kernel must also have ``.wait()`` calls, and vice versa — an
  unpaired start is an in-flight DMA racing the grid, an unpaired wait
  deadlocks;
* all waits must be UNGUARDED (a wait inside ``pl.when``/``if`` does
  not cover every control-flow path the start reaches);
* slot alternation: for each guarded-or-not start of copy
  ``(slot_expr, strip_expr)``, the consumer program is
  ``strip_expr(i)`` and its wait reads ``wait_slot(strip_expr(i))`` —
  the start's ``slot_expr(i)`` must equal it at every program id where
  the guard holds (checked numerically over sample ids via symeval).

Evaluation failures on the ALTERNATION check are treated as
"cannot prove" and skipped (exotic slot math shouldn't false-positive);
the PAIRING checks are structural and always enforced.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Iterator

from repro.analysis.core import FileContext, Finding, PerFileRule
from repro.analysis.symeval import SymEval, SymEvalError

RULE = "dma-pairing"

N_PROGRAMS = 6          # sample grid size the alternation is probed on


def _attr_name(func: ast.expr) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


class _Op:
    __slots__ = ("kind", "key", "slot", "strip", "guard", "line", "col")

    def __init__(self, kind, key, slot, strip, guard, line, col):
        self.kind, self.key = kind, key
        self.slot, self.strip, self.guard = slot, strip, guard
        self.line, self.col = line, col


def _is_when_decorated(fdef: ast.FunctionDef) -> ast.expr | None:
    for dec in fdef.decorator_list:
        if isinstance(dec, ast.Call) and _attr_name(dec.func) == "when" \
                and dec.args:
            return dec.args[0]
    return None


def _loop_ops(node: ast.For, guard) -> list[_Op]:
    """``for c in producer(slot, strip): c.start()/c.wait()``"""
    it = node.iter
    if not (isinstance(it, ast.Call) and isinstance(node.target, ast.Name)):
        return []
    key = _attr_name(it.func)
    if not key:
        return []
    slot = it.args[0] if len(it.args) >= 1 else None
    strip = it.args[1] if len(it.args) >= 2 else None
    ops = []
    for st in ast.walk(node):
        if (isinstance(st, ast.Call)
                and isinstance(st.func, ast.Attribute)
                and st.func.attr in ("start", "wait")
                and isinstance(st.func.value, ast.Name)
                and st.func.value.id == node.target.id):
            ops.append(_Op(st.func.attr, key, slot, strip, guard,
                           st.lineno, st.col_offset))
    return ops


def _inline_op(call: ast.Call, guard,
               locals_: dict[str, ast.expr]) -> _Op | None:
    """``make_async_copy(...).start()`` or ``h = make_async_copy(...);
    h.start()``"""
    if not (isinstance(call.func, ast.Attribute)
            and call.func.attr in ("start", "wait")):
        return None
    target = call.func.value
    if isinstance(target, ast.Name) and target.id in locals_:
        target = locals_[target.id]
    if isinstance(target, ast.Call) \
            and _attr_name(target.func) == "make_async_copy":
        return _Op(call.func.attr, "make_async_copy", None, None, guard,
                   call.lineno, call.col_offset)
    return None


def _collect(body: list[ast.stmt], guard,
             locals_: dict[str, ast.expr], ops: list[_Op]) -> None:
    for st in body:
        if isinstance(st, ast.FunctionDef):
            when = _is_when_decorated(st)
            _collect(st.body, when if when is not None else guard,
                     locals_, ops)
        elif isinstance(st, ast.For):
            loop = _loop_ops(st, guard)
            if loop:
                ops.extend(loop)
            else:
                _collect(st.body + st.orelse, guard, locals_, ops)
        elif isinstance(st, ast.If):
            _collect(st.body, st.test, locals_, ops)
            if st.orelse:
                _collect(st.orelse,
                         ast.UnaryOp(op=ast.Not(), operand=st.test),
                         locals_, ops)
        else:
            for node in ast.walk(st):
                if isinstance(node, ast.Call):
                    op = _inline_op(node, guard, locals_)
                    if op is not None:
                        ops.append(op)


def _local_assigns(fdef: ast.FunctionDef) -> dict[str, ast.expr]:
    out: dict[str, ast.expr] = {}
    for st in fdef.body:
        if (isinstance(st, ast.Assign) and len(st.targets) == 1
                and isinstance(st.targets[0], ast.Name)):
            out.setdefault(st.targets[0].id, st.value)
    return out


def _grid_names(fdef: ast.FunctionDef) -> tuple[str, str]:
    """Names bound to ``pl.program_id(0)`` / ``pl.num_programs(0)``."""
    pid, n = "i", "n"
    for name, expr in _local_assigns(fdef).items():
        if isinstance(expr, ast.Call):
            callee = _attr_name(expr.func)
            if callee == "program_id":
                pid = name
            elif callee == "num_programs":
                n = name
    return pid, n


class DmaPairingRule(PerFileRule):
    name = RULE

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fdef in [n for n in ctx.tree.body
                     if isinstance(n, ast.FunctionDef)]:
            yield from self._check_fn(ctx, fdef)

    def _check_fn(self, ctx: FileContext,
                  fdef: ast.FunctionDef) -> Iterator[Finding]:
        locals_ = _local_assigns(fdef)
        ops: list[_Op] = []
        _collect(fdef.body, None, locals_, ops)
        if not ops:
            return
        keys = sorted({op.key for op in ops})
        for key in keys:
            starts = [o for o in ops if o.key == key and o.kind == "start"]
            waits = [o for o in ops if o.key == key and o.kind == "wait"]
            if starts and not waits:
                o = starts[0]
                yield Finding(
                    ctx.rel, o.line, o.col, RULE,
                    f"async-copy `{key}(...).start()` in `{fdef.name}` "
                    f"has no matching `.wait()` — in-flight DMA races "
                    f"the consumer",
                )
                continue
            if waits and not starts:
                o = waits[0]
                yield Finding(
                    ctx.rel, o.line, o.col, RULE,
                    f"async-copy `{key}(...).wait()` in `{fdef.name}` "
                    f"has no matching `.start()` — this wait can "
                    f"deadlock",
                )
                continue
            if not starts:
                continue
            unguarded = [w for w in waits if w.guard is None]
            if not unguarded:
                o = waits[0]
                yield Finding(
                    ctx.rel, o.line, o.col, RULE,
                    f"every `.wait()` for `{key}` in `{fdef.name}` is "
                    f"guarded — the wait must run on all control-flow "
                    f"paths its `.start()` reaches",
                )
                continue
            yield from self._check_slots(ctx, fdef, starts, unguarded)

    def _check_slots(self, ctx: FileContext, fdef: ast.FunctionDef,
                     starts: list[_Op],
                     waits: list[_Op]) -> Iterator[Finding]:
        wait = next((w for w in waits
                     if w.slot is not None and w.strip is not None), None)
        if wait is None:
            return
        pid_name, n_name = _grid_names(fdef)

        def at(pid: int):
            return SymEval(ctx.tree,
                           env={pid_name: pid, n_name: N_PROGRAMS},
                           scope=fdef)

        for start in starts:
            if start.slot is None or start.strip is None:
                continue
            for pid in range(N_PROGRAMS):
                try:
                    ev = at(pid)
                    if start.guard is not None and \
                            not ev.eval(start.guard):
                        continue
                    strip = ev.eval(start.strip)
                    got = ev.eval(start.slot)
                    expected = at(int(strip)).eval(wait.slot)
                except (SymEvalError, TypeError, ValueError):
                    break            # cannot prove — don't false-positive
                if got != expected:
                    yield Finding(
                        ctx.rel, start.line, start.col, RULE,
                        f"double-buffer slot mismatch in `{fdef.name}`: "
                        f"program {pid} starts strip {int(strip)} into "
                        f"slot {int(got)} but that strip's `.wait()` "
                        f"reads slot {int(expected)}",
                    )
                    break
