"""design-citations: every section citation into DESIGN.md must resolve.

Docstrings across the repo anchor design claims with section-numbered
citations ("DESIGN.md" followed by ``§``-tokens), so DESIGN.md's
numbering is load-bearing for them.  This rule resolves every citation
in the linted file set against the actual ``§``-headings in DESIGN.md
and flags danglers — the same gate scripts/ci.sh used to run as a
standalone grep pass (now subsumed here, with proper file:line
findings).
"""
from __future__ import annotations

import pathlib
import re
from typing import Iterator

from repro.analysis.core import FileContext, Finding

RULE = "design-citations"

#: a heading like ``## §15 Streamed VMEM tiling``
HEADING_RE = re.compile(r"^#+\s+§([\w.-]+)", re.M)
#: a citation like ``DESIGN.md §15`` or ``DESIGN.md §13, §17``
CITE_RE = re.compile(r"DESIGN\.md\s+((?:§[\w.-]+)(?:,\s*§[\w.-]+)*)")
TOKEN_RE = re.compile(r"§([\w.-]+)")


class DesignCitationsRule:
    name = RULE

    def __init__(self, design_name: str = "DESIGN.md"):
        self.design_name = design_name

    def run(self, ctxs: list[FileContext],
            root: pathlib.Path) -> Iterator[Finding]:
        design = root / self.design_name
        if not design.is_file():
            return
        sections = set(HEADING_RE.findall(design.read_text()))
        for ctx in ctxs:
            for m in CITE_RE.finditer(ctx.source):
                for tok in TOKEN_RE.findall(m.group(1)):
                    if tok in sections:
                        continue
                    line = ctx.source.count("\n", 0, m.start()) + 1
                    nl = ctx.source.rfind("\n", 0, m.start())
                    yield Finding(
                        ctx.rel, line, m.start() - nl - 1, RULE,
                        f"dangling citation: DESIGN.md has no §{tok} "
                        f"heading",
                    )
