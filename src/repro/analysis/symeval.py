"""Mini symbolic evaluator for straight-line numeric Python (DESIGN.md §18).

The vmem-budget and dma-pairing rules need to EVALUATE small arithmetic
expressions lifted out of kernel source — BlockSpec shape tuples,
scratch shapes, double-buffer slot indices, and the analytic capacity
formulas themselves — at concrete sample points, without importing the
module (kernels import jax; the linter must stay import-free and fast).

``SymEval`` interprets a restricted AST subset against a module's tree:

* expressions: constants, names, ``+ - * / // % **``, unary ``+/-``,
  ``min``/``max``/``int``/``abs`` calls, boolean ops, comparisons
  (including ``is [not] None``), conditional expressions, tuples;
* calls to SAME-MODULE functions, executed as straight-line bodies
  (assignments, ``return``, ``if``/``else`` on decidable tests —
  loops, try, starred args are out of scope and raise);
* name resolution, in order: the caller-provided sample environment,
  the enclosing function's top-level assignments (lazily evaluated),
  the function's parameter defaults, then module-level constants.

Anything outside the subset raises ``SymEvalError`` — rules treat that
as "cannot prove", never as "ok".
"""
from __future__ import annotations

import ast


class SymEvalError(Exception):
    """Expression/statement outside the evaluable subset."""


_BINOPS = {
    ast.Add: lambda a, b: a + b,
    ast.Sub: lambda a, b: a - b,
    ast.Mult: lambda a, b: a * b,
    ast.Div: lambda a, b: a / b,
    ast.FloorDiv: lambda a, b: a // b,
    ast.Mod: lambda a, b: a % b,
    ast.Pow: lambda a, b: a ** b,
}

_BUILTINS = {"min": min, "max": max, "int": int, "abs": abs, "len": len,
             "float": float, "bool": bool}

_MAX_DEPTH = 64


class SymEval:
    """Evaluate expressions from ``tree`` at a concrete sample point.

    ``env`` — sample values (highest priority; shadows local assigns so
    a wrapper's ``k = int(src_vals.shape[0])`` never needs evaluating
    when the sample provides ``k``).
    ``scope`` — a FunctionDef whose top-level assignments and parameter
    defaults become lazily-evaluated fallbacks (the wrapper function a
    pallas_call lives in).
    """

    def __init__(self, tree: ast.Module, env: dict | None = None,
                 scope: ast.FunctionDef | None = None):
        self.env = dict(env or {})
        self.consts: dict[str, ast.expr] = {}
        self.functions: dict[str, ast.FunctionDef] = {}
        for st in tree.body:
            if (isinstance(st, ast.Assign) and len(st.targets) == 1
                    and isinstance(st.targets[0], ast.Name)):
                self.consts[st.targets[0].id] = st.value
            elif isinstance(st, ast.AnnAssign) and st.value is not None \
                    and isinstance(st.target, ast.Name):
                self.consts[st.target.id] = st.value
            elif isinstance(st, ast.FunctionDef):
                self.functions[st.name] = st
        self.local_exprs: dict[str, ast.expr] = {}
        self.local_defaults: dict[str, object] = {}
        if scope is not None:
            for st in scope.body:
                if (isinstance(st, ast.Assign) and len(st.targets) == 1
                        and isinstance(st.targets[0], ast.Name)):
                    self.local_exprs.setdefault(st.targets[0].id, st.value)
            a = scope.args
            pos = a.posonlyargs + a.args
            for arg, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
                if isinstance(d, ast.Constant):
                    self.local_defaults[arg.arg] = d.value
            for arg, d in zip(a.kwonlyargs, a.kw_defaults):
                if isinstance(d, ast.Constant):
                    self.local_defaults[arg.arg] = d.value
        self._memo: dict[str, object] = {}
        self._resolving: set[str] = set()

    # -- name resolution ---------------------------------------------------

    def _name(self, nid: str, frame: dict | None):
        if frame is not None:
            if nid in frame:
                return frame[nid]
            if nid in self.consts:
                return self.eval(self.consts[nid], frame={})
            raise SymEvalError(f"unresolved name {nid!r}")
        if nid in self.env:
            return self.env[nid]
        if nid in self._memo:
            return self._memo[nid]
        if nid in self.local_exprs and nid not in self._resolving:
            self._resolving.add(nid)
            try:
                val = self.eval(self.local_exprs[nid])
            finally:
                self._resolving.discard(nid)
            self._memo[nid] = val
            return val
        if nid in self.local_defaults:
            return self.local_defaults[nid]
        if nid in self.consts:
            return self.eval(self.consts[nid], frame={})
        raise SymEvalError(f"unresolved name {nid!r}")

    # -- expression evaluation ---------------------------------------------

    def eval(self, node: ast.expr, frame: dict | None = None,
             depth: int = 0):
        """Evaluate ``node``.  ``frame=None`` means top-level scope
        (sample env + wrapper locals); a dict frame means inside a
        called function (parameters + module constants only)."""
        if depth > _MAX_DEPTH:
            raise SymEvalError("evaluation too deep")
        if isinstance(node, ast.Constant):
            return node.value
        if isinstance(node, ast.Name):
            return self._name(node.id, frame)
        if isinstance(node, ast.Tuple):
            return tuple(self.eval(e, frame, depth + 1) for e in node.elts)
        if isinstance(node, ast.UnaryOp):
            v = self.eval(node.operand, frame, depth + 1)
            if isinstance(node.op, ast.USub):
                return -v
            if isinstance(node.op, ast.UAdd):
                return +v
            if isinstance(node.op, ast.Not):
                return not v
            raise SymEvalError("unsupported unary op")
        if isinstance(node, ast.BinOp):
            op = _BINOPS.get(type(node.op))
            if op is None:
                raise SymEvalError(
                    f"unsupported operator {type(node.op).__name__}")
            a = self.eval(node.left, frame, depth + 1)
            b = self.eval(node.right, frame, depth + 1)
            try:
                return op(a, b)
            except TypeError as e:
                raise SymEvalError(str(e)) from None
        if isinstance(node, ast.BoolOp):
            isand = isinstance(node.op, ast.And)
            val = isand
            for v in node.values:
                val = self.eval(v, frame, depth + 1)
                if bool(val) != isand:
                    return val
            return val
        if isinstance(node, ast.Compare):
            left = self.eval(node.left, frame, depth + 1)
            for op, cmp in zip(node.ops, node.comparators):
                right = self.eval(cmp, frame, depth + 1)
                if not _compare(op, left, right):
                    return False
                left = right
            return True
        if isinstance(node, ast.IfExp):
            test = self.eval(node.test, frame, depth + 1)
            branch = node.body if test else node.orelse
            return self.eval(branch, frame, depth + 1)
        if isinstance(node, ast.Call):
            return self._call(node, frame, depth)
        raise SymEvalError(f"unsupported expr {type(node).__name__}")

    def _call(self, node: ast.Call, frame: dict | None, depth: int):
        if not isinstance(node.func, ast.Name):
            raise SymEvalError("only plain-name calls are evaluable")
        if any(isinstance(a, ast.Starred) for a in node.args) or \
                any(kw.arg is None for kw in node.keywords):
            raise SymEvalError("starred call arguments")
        args = [self.eval(a, frame, depth + 1) for a in node.args]
        kwargs = {kw.arg: self.eval(kw.value, frame, depth + 1)
                  for kw in node.keywords}
        fname = node.func.id
        if fname in self.functions:
            return self.call(fname, args, kwargs, depth + 1)
        if fname in _BUILTINS and not kwargs:
            try:
                return _BUILTINS[fname](*args)
            except (TypeError, ValueError) as e:
                raise SymEvalError(str(e)) from None
        raise SymEvalError(f"uncallable function {fname!r}")

    # -- function-body execution -------------------------------------------

    def call(self, fname: str, args: list | None = None,
             kwargs: dict | None = None, depth: int = 0):
        """Call module function ``fname`` with concrete arguments."""
        fdef = self.functions.get(fname)
        if fdef is None:
            raise SymEvalError(f"no such function {fname!r}")
        frame = self._bind(fdef, list(args or []), dict(kwargs or {}))
        ret, done = self._exec(fdef.body, frame, depth)
        if not done:
            raise SymEvalError(f"{fname} fell off the end")
        return ret

    def _bind(self, fdef: ast.FunctionDef, args: list,
              kwargs: dict) -> dict:
        a = fdef.args
        pos = [p.arg for p in a.posonlyargs + a.args]
        frame: dict = {}
        for name, val in zip(pos, args):
            frame[name] = val
        if len(args) > len(pos):
            raise SymEvalError(f"too many args for {fdef.name}")
        for name, val in kwargs.items():
            if name in frame:
                raise SymEvalError(f"duplicate arg {name!r}")
            frame[name] = val
        defaults = dict(zip(pos[len(pos) - len(a.defaults):],
                            a.defaults))
        defaults.update({p.arg: d for p, d in zip(a.kwonlyargs,
                                                  a.kw_defaults)
                         if d is not None})
        for p in pos + [p.arg for p in a.kwonlyargs]:
            if p in frame:
                continue
            if p in defaults:
                frame[p] = self.eval(defaults[p], frame={})
            else:
                raise SymEvalError(f"missing arg {p!r} for {fdef.name}")
        return frame

    def _exec(self, stmts: list[ast.stmt], frame: dict, depth: int):
        if depth > _MAX_DEPTH:
            raise SymEvalError("call too deep")
        for st in stmts:
            if isinstance(st, ast.Return):
                if st.value is None:
                    return None, True
                return self.eval(st.value, frame, depth + 1), True
            if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                    and isinstance(st.targets[0], ast.Name):
                frame[st.targets[0].id] = self.eval(st.value, frame,
                                                    depth + 1)
            elif isinstance(st, ast.Expr) and isinstance(st.value,
                                                         ast.Constant):
                continue  # docstring
            elif isinstance(st, ast.If):
                test = self.eval(st.test, frame, depth + 1)
                ret, done = self._exec(st.body if test else st.orelse,
                                       frame, depth + 1)
                if done:
                    return ret, True
            elif isinstance(st, ast.Raise):
                raise SymEvalError("raise statement reached")
            elif isinstance(st, ast.Pass):
                continue
            else:
                raise SymEvalError(
                    f"unsupported statement {type(st).__name__}")
        return None, False


def _compare(op: ast.cmpop, left, right) -> bool:
    if isinstance(op, ast.Is):
        return left is right
    if isinstance(op, ast.IsNot):
        return left is not right
    try:
        if isinstance(op, ast.Eq):
            return left == right
        if isinstance(op, ast.NotEq):
            return left != right
        if isinstance(op, ast.Lt):
            return left < right
        if isinstance(op, ast.LtE):
            return left <= right
        if isinstance(op, ast.Gt):
            return left > right
        if isinstance(op, ast.GtE):
            return left >= right
    except TypeError as e:
        raise SymEvalError(str(e)) from None
    raise SymEvalError(f"unsupported comparison {type(op).__name__}")
