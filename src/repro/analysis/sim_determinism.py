"""sim-determinism: protect ``repro.sim``'s bitwise-determinism pin.

The fleet simulator is pinned bit-identical across processes and
platforms (DESIGN.md §16 — the tournament CI diffs full event streams),
which one careless iteration order can silently break: Python ``set``
order depends on PYTHONHASHSEED, dict order on insertion history, and
wall-clock / unseeded RNG on the machine.  Inside ``repro/sim`` this
rule flags:

* statement-level ``for`` loops over ``.items()/.keys()/.values()``
  views or set-valued expressions (wrap in ``sorted(...)`` or iterate
  an explicit ordered tuple);
* list/generator/dict comprehensions drawing from a set or dict view,
  unless the comprehension feeds an order-insensitive reducer
  (``sum``/``min``/``max``/``len``/``any``/``all``/``sorted``/``set``/
  ``frozenset``) or is itself a set comprehension;
* ``list(...)``/``tuple(...)`` materializations of set-valued
  expressions or dict views;
* ``import random`` (the unseeded global stdlib RNG) and bare
  ``np.random.*`` module calls; ``np.random.default_rng()`` with no
  seed;
* wall-clock reads (``time.time``/``perf_counter``/``monotonic``,
  ``datetime.now``/``utcnow``/``today``);
* ``id(...)`` (CPython address — run-dependent ordering key).

Set-valued names are tracked flow-insensitively per scope: a name
assigned a set literal/comprehension/``set()``/``frozenset()`` call or
a union/intersection of those counts as a set everywhere in the scope.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import FileContext, Finding, PerFileRule

RULE = "sim-determinism"

DICT_VIEWS = {"items", "keys", "values"}
SAFE_REDUCERS = {"sum", "min", "max", "len", "any", "all", "sorted",
                 "set", "frozenset"}
SAFE_RNG = {"default_rng", "Generator", "SeedSequence", "PCG64",
            "Philox", "MT19937", "BitGenerator"}
CLOCKS = {"time": {"time", "time_ns", "perf_counter", "perf_counter_ns",
                   "monotonic", "monotonic_ns"},
          "datetime": {"now", "utcnow", "today"},
          "date": {"today"}}


def _terminal(func: ast.expr) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _chain(node: ast.expr) -> list[str]:
    """Dotted attribute chain, e.g. ``np.random.rand`` -> [np,random,rand]."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


def _is_dict_view(node: ast.expr) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in DICT_VIEWS
            and not node.args)


def _is_set_valued(node: ast.expr, set_names: set[str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Name):
        return node.id in set_names
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return (_is_set_valued(node.left, set_names)
                or _is_set_valued(node.right, set_names))
    return False


def _scope_walk(body: list[ast.stmt]) -> Iterator[ast.AST]:
    """Walk a scope's statements without descending into nested defs."""
    stack: list[ast.AST] = list(body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                stack.append(child)


def _set_names(body: list[ast.stmt]) -> set[str]:
    names: set[str] = set()
    for _ in range(2):                      # one fixpoint pass for chains
        for node in _scope_walk(body):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and _is_set_valued(node.value, names):
                names.add(node.targets[0].id)
    return names


class SimDeterminismRule(PerFileRule):
    name = RULE

    def applies(self, ctx: FileContext) -> bool:
        return "sim" in ctx.parts

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if not self.applies(ctx):
            return
        parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(ctx.tree):
            for child in ast.iter_child_nodes(node):
                parents[child] = node
        scopes: list[list[ast.stmt]] = [ctx.tree.body] + [
            n.body for n in ast.walk(ctx.tree)
            if isinstance(n, ast.FunctionDef)
        ]
        for body in scopes:
            yield from self._check_scope(ctx, body, parents)
        yield from self._check_rng_and_clocks(ctx)

    # -- iteration order ---------------------------------------------------

    def _check_scope(self, ctx: FileContext, body: list[ast.stmt],
                     parents) -> Iterator[Finding]:
        set_names = _set_names(body)

        def unordered(node):
            return _is_dict_view(node) or _is_set_valued(node, set_names)

        for node in _scope_walk(body):
            if isinstance(node, ast.For) and unordered(node.iter):
                kind = "dict view" if _is_dict_view(node.iter) else "set"
                yield Finding(
                    ctx.rel, node.iter.lineno, node.iter.col_offset, RULE,
                    f"for-loop over a {kind} — iteration order is a "
                    f"hidden determinism dependency; iterate "
                    f"sorted(...) or an explicit ordered tuple",
                )
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp,
                                   ast.DictComp)):
                for gen in node.generators:
                    if unordered(gen.iter) and \
                            not self._reduced(node, parents):
                        kind = ("dict view" if _is_dict_view(gen.iter)
                                else "set")
                        yield Finding(
                            ctx.rel, gen.iter.lineno, gen.iter.col_offset,
                            RULE,
                            f"comprehension over a {kind} produces an "
                            f"order-dependent result; wrap the source "
                            f"in sorted(...) or reduce "
                            f"order-insensitively",
                        )
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id in ("list", "tuple") \
                    and len(node.args) == 1 and unordered(node.args[0]):
                yield Finding(
                    ctx.rel, node.lineno, node.col_offset, RULE,
                    f"{node.func.id}(...) materializes a set/dict view "
                    f"in hash/insertion order; use sorted(...)",
                )

    def _reduced(self, comp: ast.AST, parents) -> bool:
        """True when the comprehension feeds an order-insensitive
        reducer (its immediate consumer is a SAFE_REDUCERS call)."""
        parent = parents.get(comp)
        return (isinstance(parent, ast.Call)
                and comp in parent.args
                and _terminal(parent.func) in SAFE_REDUCERS)

    # -- entropy sources ---------------------------------------------------

    def _check_rng_and_clocks(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.split(".")[0] == "random":
                        yield Finding(
                            ctx.rel, node.lineno, node.col_offset, RULE,
                            "stdlib `random` is an unseeded process-"
                            "global RNG; use np.random.default_rng("
                            "seed)",
                        )
            elif isinstance(node, ast.ImportFrom):
                if (node.module or "").split(".")[0] == "random":
                    yield Finding(
                        ctx.rel, node.lineno, node.col_offset, RULE,
                        "stdlib `random` is an unseeded process-global "
                        "RNG; use np.random.default_rng(seed)",
                    )
            elif isinstance(node, ast.Call):
                yield from self._check_call(ctx, node)

    def _check_call(self, ctx: FileContext,
                    node: ast.Call) -> Iterator[Finding]:
        chain = _chain(node.func)
        if len(chain) >= 2 and "random" in chain[:-1]:
            if chain[-1] not in SAFE_RNG:
                yield Finding(
                    ctx.rel, node.lineno, node.col_offset, RULE,
                    f"`{'.'.join(chain)}` draws from the global numpy "
                    f"RNG; use a seeded default_rng",
                )
            elif chain[-1] == "default_rng" and not node.args:
                yield Finding(
                    ctx.rel, node.lineno, node.col_offset, RULE,
                    "default_rng() without a seed pulls OS entropy; "
                    "pass an explicit seed",
                )
        if len(chain) == 2 and chain[1] in CLOCKS.get(chain[0], ()):
            yield Finding(
                ctx.rel, node.lineno, node.col_offset, RULE,
                f"`{'.'.join(chain)}` reads the wall clock — sim time "
                f"must come from the event loop",
            )
        if isinstance(node.func, ast.Name) and node.func.id == "id" \
                and node.args:
            yield Finding(
                ctx.rel, node.lineno, node.col_offset, RULE,
                "id() is a CPython address — run-dependent; order by a "
                "stable key instead",
            )
        for kw in node.keywords:
            if kw.arg == "key" and isinstance(kw.value, ast.Name) \
                    and kw.value.id == "id":
                yield Finding(
                    ctx.rel, node.lineno, node.col_offset, RULE,
                    "key=id sorts by CPython address — run-dependent; "
                    "use a stable key",
                )
