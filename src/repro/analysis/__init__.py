"""repro-lint: AST static-analysis suite for the repro codebase.

Framework (``core``): ``Rule`` protocol, per-file and cross-file
passes, structured ``Finding``s, ``# lint: disable=<rule>``
suppressions, human/JSON output.  Rules (DESIGN.md §18):

* ``vmem-budget``      — pallas_call scratch/BlockSpec bytes vs the
                         analytic capacity formulas
* ``dma-pairing``      — async-copy start/wait pairing + double-buffer
                         slot alternation
* ``sim-determinism``  — unordered iteration / entropy sources in
                         ``repro.sim``
* ``tracer-hygiene``   — host-sync footguns reachable from traced code
* ``design-citations`` — docstring section citations resolve against
                         DESIGN.md's headings

Import-light on purpose: no jax, so ``scripts/lint.py`` starts cold in
well under the CI stage's 10 s budget.
"""
from repro.analysis.core import (
    Analyzer,
    FileContext,
    Finding,
    PerFileRule,
    Rule,
    analyze_source,
    iter_py_files,
    render_human,
    to_json,
)
from repro.analysis.design_citations import DesignCitationsRule
from repro.analysis.dma_pairing import DmaPairingRule
from repro.analysis.sim_determinism import SimDeterminismRule
from repro.analysis.symeval import SymEval, SymEvalError
from repro.analysis.tracer_hygiene import TracerHygieneRule
from repro.analysis.vmem_budget import VmemBudgetRule

ALL_RULES = (
    VmemBudgetRule,
    DmaPairingRule,
    SimDeterminismRule,
    TracerHygieneRule,
    DesignCitationsRule,
)


def default_rules() -> list[Rule]:
    """One instance of every registered rule."""
    return [cls() for cls in ALL_RULES]


__all__ = [
    "ALL_RULES",
    "Analyzer",
    "DesignCitationsRule",
    "DmaPairingRule",
    "FileContext",
    "Finding",
    "PerFileRule",
    "Rule",
    "SimDeterminismRule",
    "SymEval",
    "SymEvalError",
    "TracerHygieneRule",
    "VmemBudgetRule",
    "analyze_source",
    "default_rules",
    "iter_py_files",
    "render_human",
    "to_json",
]
