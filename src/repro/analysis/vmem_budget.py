"""vmem-budget: tie pallas_call VMEM bytes to the capacity formulas.

The stencil engine's tiling decisions (stream vs resident, strip
height, shot tile) all plan against two analytic formulas —
``resident_vmem_bytes`` / ``stream_vmem_bytes`` — that live NEXT TO the
kernels they describe but, before this rule, were only tied to them by
prose (DESIGN.md §15/§17).  This rule closes the loop statically:

* every ``pl.pallas_call`` under ``kernels/`` gets its VMEM footprint
  extracted symbolically — BlockSpec block shapes (a constant index
  map is fetched once, ×1; a moving map is double-buffered by the
  Pallas pipeline, ×2; ``memory_space=ANY`` stays in HBM, ×0) plus
  ``pltpu.VMEM`` scratch shapes (DMA semaphores are free) at 4 B/elem
  (the engine is f32);
* kernels in ``WRAPPER_FORMULAS`` are evaluated at sample points and
  compared against their formula — drift beyond ``REL_TOL`` (the
  formulas deliberately ignore the tiny scalar source blocks) is a
  finding;
* ``should_stream`` must equal ``resident_vmem_bytes(...) > budget``
  at every sample point (the auto-dispatch contract);
* a streamed kernel (any HBM/ANY input) must pin
  ``vmem_limit_bytes`` compiler params somewhere in its wrapper;
* an UNMAPPED pallas_call that uses VMEM scratch or HBM streaming is
  itself a finding — new capacity-relevant kernels must either get a
  formula mapping or a justified suppression.

Everything is evaluated from the AST (``symeval``) — no jax import.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Iterator

from repro.analysis.core import FileContext, Finding
from repro.analysis.symeval import SymEval, SymEvalError

RULE = "vmem-budget"

#: f32 engine — all counted blocks/scratch are 4-byte elements
ELEM_BYTES = 4

#: relative drift tolerance: the formulas round off the (1, k)/(S, 2)
#: scalar source blocks (~tens of bytes against MBs of windows)
REL_TOL = 0.01

#: concrete sample points the symbolic totals are compared at; all
#: satisfy the kernels' own invariants (nz % bz == 0, trapezoid fits)
SAMPLES = (
    {"nz": 512, "nx": 256, "bz": 32, "k": 4, "ns": 3},
    {"nz": 1024, "nx": 128, "bz": 64, "k": 2, "ns": 2},
)

#: extra absolute budgets the should_stream consistency is probed at
#: (the rule also probes resident_bytes ± 10%, which straddles the
#: decision boundary whatever the formula's scale is)
BUDGET_SAMPLES = (1024 * 1024, 16 * 1024 * 1024)

#: wrapper function -> (formula name, sample -> formula kwargs)
WRAPPER_FORMULAS = {
    "wave_block_pallas": ("resident_vmem_bytes", lambda e: {
        "nz": e["nz"], "nx": e["nx"], "k": e["k"], "bz": e["bz"], "s": 1}),
    "wave_block_shots_pallas": ("resident_vmem_bytes", lambda e: {
        "nz": e["nz"], "nx": e["nx"], "k": e["k"], "bz": e["bz"],
        "s": e["ns"]}),
    "wave_block_stream_pallas": ("stream_vmem_bytes", lambda e: {
        "nz": e["nz"], "nx": e["nx"], "bz": e["bz"], "k": e["k"], "s": 1}),
    "wave_block_shots_stream_pallas": ("stream_vmem_bytes", lambda e: {
        "nz": e["nz"], "nx": e["nx"], "bz": e["bz"], "k": e["k"],
        "s": e["ns"]}),
}

FORMULA_NAMES = ("resident_vmem_bytes", "stream_vmem_bytes")


def _attr_name(func: ast.expr) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _is_pallas_call(node: ast.Call) -> bool:
    return _attr_name(node.func) == "pallas_call"


def _kw(call: ast.Call, name: str) -> ast.expr | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _local_assigns(fdef: ast.FunctionDef) -> dict[str, ast.expr]:
    out: dict[str, ast.expr] = {}
    for st in fdef.body:
        if (isinstance(st, ast.Assign) and len(st.targets) == 1
                and isinstance(st.targets[0], ast.Name)):
            out.setdefault(st.targets[0].id, st.value)
    return out


def _spec_bytes(spec: ast.expr, locals_: dict[str, ast.expr],
                ev: SymEval) -> int:
    """VMEM bytes one BlockSpec pins: block elems × 4 B × pipeline
    multiplier (constant index map ×1, moving ×2, ANY memory ×0)."""
    if isinstance(spec, ast.Name) and spec.id in locals_:
        spec = locals_[spec.id]
    if not (isinstance(spec, ast.Call)
            and _attr_name(spec.func) == "BlockSpec"):
        raise SymEvalError("spec is not a BlockSpec call")
    if not spec.args:                       # memory_space=ANY: HBM-resident
        return 0
    shape = ev.eval(spec.args[0])
    if not isinstance(shape, tuple):
        raise SymEvalError("BlockSpec shape is not a tuple")
    elems = 1
    for d in shape:
        elems *= int(d)
    mult = 2                                # moving: pipeline double-buffers
    if len(spec.args) > 1 and isinstance(spec.args[1], ast.Lambda):
        body = spec.args[1].body
        if isinstance(body, ast.Tuple) and all(
                isinstance(e, ast.Constant) for e in body.elts):
            mult = 1                        # constant map: fetched once
    return elems * ELEM_BYTES * mult


def _scratch_bytes(node: ast.expr, ev: SymEval) -> int:
    """Bytes of one scratch_shapes entry (semaphores are free)."""
    if not isinstance(node, ast.Call):
        raise SymEvalError("unrecognized scratch entry")
    name = _attr_name(node.func)
    if name == "VMEM":
        shape = ev.eval(node.args[0])
        elems = 1
        for d in shape:
            elems *= int(d)
        return elems * ELEM_BYTES
    if name == "DMA" or name == "SemaphoreType":
        return 0
    raise SymEvalError(f"unrecognized scratch entry {name!r}")


def _spec_list(call: ast.Call, key: str,
               locals_: dict[str, ast.expr]) -> list[ast.expr]:
    node = _kw(call, key)
    if node is None:
        return []
    if isinstance(node, ast.Name) and node.id in locals_:
        node = locals_[node.id]
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]                           # single un-listed spec


def _has_any_spec(specs: list[ast.expr],
                  locals_: dict[str, ast.expr]) -> bool:
    for spec in specs:
        if isinstance(spec, ast.Name) and spec.id in locals_:
            spec = locals_[spec.id]
        if (isinstance(spec, ast.Call)
                and _attr_name(spec.func) == "BlockSpec"
                and not spec.args):
            return True
    return False


def _mentions_vmem_limit(fdef: ast.FunctionDef) -> bool:
    for node in ast.walk(fdef):
        if isinstance(node, ast.keyword) and node.arg == "vmem_limit_bytes":
            return True
        if isinstance(node, ast.Constant) and node.value == "vmem_limit_bytes":
            return True
    return False


class VmemBudgetRule:
    """Cross-file pass: formulas from the stencil module, pallas_calls
    from every module under ``kernels/``."""

    name = RULE

    def run(self, ctxs: list[FileContext],
            root: pathlib.Path) -> Iterator[Finding]:
        kernel_ctxs = [c for c in ctxs if "kernels" in c.parts]
        formula_ctx = next(
            (c for c in kernel_ctxs
             if all(f in SymEval(c.tree).functions for f in FORMULA_NAMES)),
            None,
        )
        if formula_ctx is not None:
            yield from self._check_should_stream(formula_ctx)
        for ctx in kernel_ctxs:
            for fdef in [n for n in ctx.tree.body
                         if isinstance(n, ast.FunctionDef)]:
                for node in ast.walk(fdef):
                    if isinstance(node, ast.Call) and _is_pallas_call(node):
                        yield from self._check_site(
                            ctx, fdef, node, formula_ctx)

    # -- per-site ----------------------------------------------------------

    def _check_site(self, ctx: FileContext, fdef: ast.FunctionDef,
                    call: ast.Call,
                    formula_ctx: FileContext | None) -> Iterator[Finding]:
        locals_ = _local_assigns(fdef)
        in_specs = _spec_list(call, "in_specs", locals_)
        out_specs = _spec_list(call, "out_specs", locals_)
        scratch = _spec_list(call, "scratch_shapes", locals_)
        streams = _has_any_spec(in_specs + out_specs, locals_)
        mapped = fdef.name in WRAPPER_FORMULAS

        if not mapped:
            if scratch or streams:
                yield Finding(
                    ctx.rel, call.lineno, call.col_offset, RULE,
                    f"pallas_call in `{fdef.name}` uses VMEM scratch or "
                    f"HBM streaming but has no capacity-formula mapping "
                    f"(WRAPPER_FORMULAS) — add one or suppress with a "
                    f"justification",
                )
            return

        if streams and not _mentions_vmem_limit(fdef):
            yield Finding(
                ctx.rel, call.lineno, call.col_offset, RULE,
                f"streamed pallas_call in `{fdef.name}` (ANY-memory "
                f"inputs) does not pin vmem_limit_bytes compiler params",
            )

        if formula_ctx is None:
            yield Finding(
                ctx.rel, call.lineno, call.col_offset, RULE,
                f"`{fdef.name}` is formula-mapped but no module in the "
                f"file set defines {'/'.join(FORMULA_NAMES)}",
            )
            return

        formula, kwargs_of = WRAPPER_FORMULAS[fdef.name]
        for sample in SAMPLES:
            try:
                ev = SymEval(ctx.tree, env=dict(sample), scope=fdef)
                kernel_bytes = sum(
                    _spec_bytes(s, locals_, ev)
                    for s in in_specs + out_specs
                ) + sum(_scratch_bytes(s, ev) for s in scratch)
                fev = SymEval(formula_ctx.tree)
                formula_bytes = fev.call(formula, kwargs=kwargs_of(sample))
            except SymEvalError as e:
                yield Finding(
                    ctx.rel, call.lineno, call.col_offset, RULE,
                    f"could not evaluate `{fdef.name}` VMEM bytes vs "
                    f"{formula} at {sample}: {e}",
                )
                return
            drift = abs(kernel_bytes - formula_bytes)
            if drift > REL_TOL * formula_bytes:
                yield Finding(
                    ctx.rel, call.lineno, call.col_offset, RULE,
                    f"`{fdef.name}` VMEM bytes drift from {formula} at "
                    f"{sample}: kernel={kernel_bytes} formula="
                    f"{formula_bytes} ({drift} B, tol "
                    f"{REL_TOL:.0%})",
                )
                return

    # -- dispatch-rule consistency -----------------------------------------

    def _check_should_stream(self,
                             ctx: FileContext) -> Iterator[Finding]:
        ev = SymEval(ctx.tree)
        if "should_stream" not in ev.functions:
            return
        line = ev.functions["should_stream"].lineno
        for sample in SAMPLES:
            try:
                resident = ev.call("resident_vmem_bytes", kwargs={
                    "nz": sample["nz"], "nx": sample["nx"],
                    "k": sample["k"], "s": sample["ns"],
                })
            except SymEvalError as e:
                yield Finding(
                    ctx.rel, line, 0, RULE,
                    f"could not evaluate resident_vmem_bytes at "
                    f"{sample}: {e}",
                )
                return
            budgets = (int(resident * 0.9), int(resident * 1.1),
                       *BUDGET_SAMPLES)
            for budget in budgets:
                try:
                    got = ev.call("should_stream", kwargs={
                        "nz": sample["nz"], "nx": sample["nx"],
                        "k": sample["k"], "vmem_budget": budget,
                        "s": sample["ns"],
                    })
                except SymEvalError as e:
                    yield Finding(
                        ctx.rel, line, 0, RULE,
                        f"could not evaluate should_stream consistency "
                        f"at {sample}, budget={budget}: {e}",
                    )
                    return
                if bool(got) != (resident > budget):
                    yield Finding(
                        ctx.rel, line, 0, RULE,
                        f"should_stream({sample}, budget={budget}) = "
                        f"{got} but resident_vmem_bytes = {resident} "
                        f"(> budget is {resident > budget}) — dispatch "
                        f"rule drifted from the capacity model",
                    )
                    return
