"""tracer-hygiene: host-sync footguns inside traced code.

A ``.item()`` / ``float()`` / ``np.asarray`` / ``print`` on a traced
value either fails at trace time or — worse, under ``jit`` on
concrete-shaped debugging paths — silently forces a device→host sync
in the hot loop.  This rule builds a per-module call graph and flags
those calls only in functions REACHABLE from traced roots:

* roots: functions decorated with ``jit`` (including
  ``functools.partial(jax.jit, ...)``), kernel bodies handed to
  ``pl.pallas_call`` (through ``functools.partial``), ``lax.scan``
  bodies, and targets of ``vmap``/``pmap``/``shard_map``/``grad``/
  ``remat``/``jit`` calls (lambda targets contribute the functions
  they call);
* reachability: same-module calls by name, transitively, plus every
  function nested inside a reachable one (nested defs execute inside
  the trace);
* exemptions that keep the rule precise on this codebase's idioms:
  ``float``/``int``/``bool`` of a constant, of anything rooted in
  ``.shape``/``.ndim``/``.size``/``.dtype``/``len(...)`` (static at
  trace time), or of names listed in any ``static_argnames`` in the
  module (static parameters are Python values inside the trace);
  ``pl.debug_print``/``jax.debug.print`` are not ``print``.

Setup-time builders (memoized factories that CALL jitted functions but
are never traced themselves) are correctly outside the reachable set —
their ``np.asarray`` staging is fine and stays unflagged.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import FileContext, Finding, PerFileRule

RULE = "tracer-hygiene"

TRANSFORMS = {"vmap", "pmap", "shard_map", "grad", "value_and_grad",
              "remat", "checkpoint", "jit"}
CASTS = {"float", "int", "bool"}
STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}
NUMPY_NAMES = {"np", "numpy"}


def _terminal(func: ast.expr) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _partial_target(node: ast.expr) -> ast.expr | None:
    """``functools.partial(f, ...)`` -> ``f`` (else None)."""
    if isinstance(node, ast.Call) and _terminal(node.func) == "partial" \
            and node.args:
        return node.args[0]
    return None


def _is_jit_expr(node: ast.expr) -> bool:
    if _terminal(node) in ("jit", "pjit"):
        return True
    target = _partial_target(node)
    return target is not None and _terminal(target) in ("jit", "pjit")


def _lambda_callees(lam: ast.Lambda) -> Iterator[str]:
    for node in ast.walk(lam.body):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            yield node.func.id


class _Module:
    """Per-module function table, roots, static names, reachability."""

    def __init__(self, tree: ast.Module):
        self.defs: dict[str, ast.FunctionDef] = {}
        self.nested: dict[ast.FunctionDef, list[ast.FunctionDef]] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef):
                self.defs[node.name] = node
                self.nested[node] = [
                    c for c in ast.walk(node)
                    if isinstance(c, ast.FunctionDef) and c is not node
                ]
        self.static = self._static_names(tree)
        self.roots = self._roots(tree)
        self.reachable = self._reach()

    def _static_names(self, tree) -> set[str]:
        names: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.keyword) \
                    and node.arg == "static_argnames":
                v = node.value
                elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) \
                    else [v]
                for e in elts:
                    if isinstance(e, ast.Constant) \
                            and isinstance(e.value, str):
                        names.add(e.value)
        return names

    def _roots(self, tree) -> set[ast.FunctionDef]:
        roots: set[ast.FunctionDef] = set()

        def add_target(node: ast.expr | None):
            if node is None:
                return
            target = _partial_target(node)
            if target is not None:
                node = target
            if isinstance(node, ast.Name) and node.id in self.defs:
                roots.add(self.defs[node.id])
            elif isinstance(node, ast.Lambda):
                for name in _lambda_callees(node):
                    if name in self.defs:
                        roots.add(self.defs[name])

        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef):
                if any(_is_jit_expr(d) for d in node.decorator_list):
                    roots.add(node)
            elif isinstance(node, ast.Call):
                name = _terminal(node.func)
                if name == "pallas_call" and node.args:
                    add_target(node.args[0])
                elif name == "scan" and node.args:
                    add_target(node.args[0])
                elif name in TRANSFORMS and node.args:
                    add_target(node.args[0])
        return roots

    def _reach(self) -> set[ast.FunctionDef]:
        seen: set[ast.FunctionDef] = set()
        queue = list(self.roots)
        while queue:
            fdef = queue.pop()
            if fdef in seen:
                continue
            seen.add(fdef)
            queue.extend(self.nested.get(fdef, ()))
            for node in ast.walk(fdef):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Name) \
                        and node.func.id in self.defs:
                    queue.append(self.defs[node.func.id])
        return seen


def _own_nodes(fdef: ast.FunctionDef) -> Iterator[ast.AST]:
    """Walk a def's body without descending into nested defs (those are
    reachable in their own right and checked separately)."""
    stack: list[ast.AST] = list(fdef.body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                stack.append(child)


def _static_cast_arg(arg: ast.expr, static: set[str]) -> bool:
    """Is this float()/int() argument static at trace time?"""
    if isinstance(arg, ast.Constant):
        return True
    names: list[str] = []
    for node in ast.walk(arg):
        if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
            return True
        if isinstance(node, ast.Call) and _terminal(node.func) == "len":
            return True
        if isinstance(node, ast.Name):
            names.append(node.id)
    return bool(names) and all(n in static for n in names)


class TracerHygieneRule(PerFileRule):
    name = RULE

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        mod = _Module(ctx.tree)
        if not mod.reachable:
            return
        for fdef in sorted(mod.reachable, key=lambda f: f.lineno):
            yield from self._check_fn(ctx, mod, fdef)

    def _check_fn(self, ctx: FileContext, mod: _Module,
                  fdef: ast.FunctionDef) -> Iterator[Finding]:
        for node in _own_nodes(fdef):
            if not isinstance(node, ast.Call):
                continue
            where = (ctx.rel, node.lineno, node.col_offset, RULE)
            func = node.func
            if isinstance(func, ast.Attribute):
                if func.attr == "item" and not node.args:
                    yield Finding(*where,
                                  f"`.item()` in traced `{fdef.name}` "
                                  f"forces a device→host sync")
                elif func.attr == "block_until_ready":
                    yield Finding(*where,
                                  f"`.block_until_ready()` in traced "
                                  f"`{fdef.name}` blocks the host")
                elif func.attr == "device_get":
                    yield Finding(*where,
                                  f"`device_get` in traced "
                                  f"`{fdef.name}` forces a host sync")
                elif func.attr in ("asarray", "array") \
                        and isinstance(func.value, ast.Name) \
                        and func.value.id in NUMPY_NAMES:
                    yield Finding(*where,
                                  f"`{func.value.id}.{func.attr}` in "
                                  f"traced `{fdef.name}` materializes "
                                  f"on host; use jnp")
            elif isinstance(func, ast.Name):
                if func.id in CASTS and node.args and \
                        not _static_cast_arg(node.args[0], mod.static):
                    yield Finding(*where,
                                  f"`{func.id}()` on a traced value in "
                                  f"`{fdef.name}` forces a host sync "
                                  f"(static shapes/args are exempt)")
                elif func.id == "print":
                    yield Finding(*where,
                                  f"`print()` in traced `{fdef.name}` "
                                  f"runs at trace time only; use "
                                  f"jax.debug.print")
