"""Scenario generator for the hybrid-fleet simulator (DESIGN.md §11).

Each scenario is a reproducible world the policy suite is scored
against: foreground scientific jobs on a shared Site, background tenant
demand (the organic "cluster overloaded" condition), and the fault /
deadline dynamics the ROADMAP's scenario-diversity axis asks for.  The
paper's own experiment is essentially ``overload_ramp`` with one job;
the rest generalize it:

  calm              light contention — the no-cost sanity world
  overload_ramp     sustained tenant ramp past capacity (paper §3.3)
  transient_spike   a spike that clears — tests SHRINK/RETIRE and that
                    cloud spend stops once load is gone
  deadline_squeeze  the deadline tightens mid-run (paper §2 notes it
                    "could also change dynamically")
  spot_market       overload on spot-priced cloud chips that get
                    reclaimed mid-run
  node_failures     on-premise nodes die; jobs fall back to checkpoints
  superlinear_cache overload on a cache-superlinear workload — the
                    regime where cost-aware slice sizing (DESIGN.md
                    §14) buys the same hit-rate for fewer cloud $

Queued (multi-tenant) scenarios drive the fleet layer (DESIGN.md §16):
jobs arrive as a *stream* into the CentralQueue instead of being placed
on arrival, a Scheduler picks placements, and a fleet autoscaler sizes
the shared cloud pool under a global budget:

  multi_tenant_rush three tenants of unequal weight flood the queue
                    far past site capacity — the tournament's overload
                    world (fairness + starvation live here)
  diurnal_stream    a day of sinusoidally-modulated Poisson arrivals —
                    the queue-pressure signal the pool policies track

All sizes are in simulated seconds/chips; a full policy×scenario sweep
runs in well under a minute of wall time on CPU.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import OverheadModel
from repro.core.events import BackgroundLoad
from repro.sim.faults import FaultPlan, RetryPolicy
from repro.sim.fleet import CloudProvider, JobSpec
from repro.sim.queue import Tenant

__all__ = [
    "SEAM_PROBE",
    "SHOT_BATCH_PROBE",
    "Scenario",
    "calm",
    "deadline_squeeze",
    "default_scenarios",
    "diurnal_jobs",
    "diurnal_stream",
    "fault_storm",
    "multi_tenant_rush",
    "node_failures",
    "overheads_from_probe",
    "overload_ramp",
    "poisson_background",
    "poisson_jobs",
    "preemption_pressure",
    "queued_scenarios",
    "shot_batch_model_from_probe",
    "spot_market",
    "superlinear_cache",
    "transient_spike",
]

#: shared world constants — one knob set so scenarios stay comparable
SITE_CHIPS = 256
ONPREM_CHIPS = 128
WORK = 1000.0                    # chip·s per step -> 7.8 s/step on 128

#: MEASURED seam probe for the cross-environment halo synchronization —
#: a committed snapshot of ``fwi.calibrate.measure_seam_latency()``
#: (kept as a literal so the sim layer stays jax-free; re-run the probe
#: to refresh).  Recorded 2026-08-08 on a 2-device host stripe mesh
#: (XLA_FLAGS=--xla_force_host_platform_device_count=2): a REAL
#: cross-device packed ppermute over the engine's 300 KB k=4 exchange
#: payload, plus the measured stripe-interior fused-block compute the
#: pipeline schedule hides it behind.  On real multi-pod hardware the
#: same probe returns the cross-DCI RTT instead.
SEAM_PROBE = {
    "plan": {
        "k": 4, "steps_per_exchange": 4, "ppermutes_per_exchange": 2,
        "ppermutes_per_step": 0.5, "bytes_per_exchange": 307200,
        "bytes_per_step": 76800.0, "interior_cols": 300,
        "boundary_cols": 48, "overlap_fraction": 0.862069,
        "redundant_frac": 0.106667,
    },
    "ppermute_latency_s": 5.2959e-4,
    "interior_compute_s_per_step": 1.7816e-3,
    "n_stripes": 2,
    "mesh_devices": 2,
    "backend": "cpu",
}


def overheads_from_probe(
    probe: dict, *, ckpt_s: float = 5.0, provision_s: float = 60.0,
    restart_s: float = 15.0,
) -> OverheadModel:
    """Build the planner's ``OverheadModel`` from a measured seam probe
    (``fwi.calibrate.measure_seam_latency``), NOT the dispatch-latency
    floor: ``with_overlapped_seam`` charges only the residue the
    pipeline/overlap engine cannot hide behind the measured
    stripe-interior compute (DESIGN.md §15).  With the committed probe
    the interior block (≈7 ms) dwarfs the packed exchange (≈1 ms), so
    the effective seam is 0 — exactly what the BurstPlanner should
    believe about the overlap-and-fuse engine."""
    return OverheadModel(
        ckpt_s=ckpt_s, provision_s=provision_s, restart_s=restart_s,
    ).with_overlapped_seam(
        probe["plan"], probe["ppermute_latency_s"],
        probe["interior_compute_s_per_step"],
    )


#: MEASURED shot-batch scaling probe for the batched stencil engine
#: (DESIGN.md §17) — a committed snapshot of the streamed shot-batched
#: kernel's per-timestep wall clock vs batch size S (600×600, k=8,
#: bz=120, Pallas interpret on CPU, best-of-4; re-run
#: ``benchmarks/bench_fused_scan.py --shot-batch`` to refresh).
#: ``t_step_vmapped_s4`` is the PRE-batching engine (one kernel per
#: shot) at the full batch — the 1.47× the batched engine banks shows
#: up BETWEEN the engines, while within the batched engine the CPU
#: interpreter's scaling is near-affine (the model-field-traffic share
#: the analytic ratio credits is invisible to an emulated memory
#: hierarchy; on TPU the traffic model bounds it at 4S/(2S+2)).
SHOT_BATCH_PROBE = {
    "config": {"nz": 600, "nx": 600, "k": 8, "bz": 120,
               "engine": "pallas_batched_stream", "backend": "cpu"},
    "s_values": (1, 2, 4),
    "t_step_s": (2.333e-3, 4.791e-3, 10.292e-3),
    "t_step_vmapped_s4": 15.72e-3,
    "batched_vs_vmapped": 1.475,
}


def shot_batch_model_from_probe(probe: dict | None = None):
    """Fit the planner's ``ShotBatchModel`` (``t_step(s) = a + b·s``)
    from a measured shot-batch probe, so BurstPlanner's deadline
    calculus uses the REAL batched engine's throughput law instead of
    the naive ``s · t_step(1)`` — see ``core.capacity.ShotBatchModel``.
    """
    from repro.core.capacity import ShotBatchModel

    p = probe if probe is not None else SHOT_BATCH_PROBE
    return ShotBatchModel.fit(
        p["s_values"], p["t_step_s"],
        name=p.get("config", {}).get("engine", "shot_batch"),
    )


OVERHEADS = overheads_from_probe(SEAM_PROBE)
CLOUD = CloudProvider(
    legal_slices=(16, 32, 64, 128, 256),
    provision_delay_s=60.0,
    price_per_chip_hour=3.0,
    slowdown=1.4,
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    jobs: tuple[JobSpec, ...]
    background: tuple[BackgroundLoad, ...] = ()
    deadline_changes: tuple[tuple[float, str, float], ...] = ()
    failures: tuple[tuple[float, str], ...] = ()
    site_chips: int = SITE_CHIPS
    cloud: CloudProvider = CLOUD
    overheads: OverheadModel = OVERHEADS
    eval_interval_s: float = 30.0
    ckpt_every: int = 25
    description: str = ""
    #: BurstPlanner cost/deadline trade-off knob (DESIGN.md §14);
    #: 0 keeps the deadline-first minimal-slice solve
    planner_cost_weight: float = 0.0
    # ---- fleet-of-jobs layer (DESIGN.md §16); defaults reduce the
    # ---- controller exactly to the PR-2 place-on-arrival FleetSim
    #: "immediate" (no queue) or a SCHEDULER_FACTORIES name
    scheduler: str = "immediate"
    #: "none" (no shared pool) or a FLEET_POLICY_FACTORIES name
    fleet_policy: str = "none"
    #: hard cap on concurrent cloud chips held OR staged fleet-wide
    cloud_chip_cap: int | None = None
    #: $ gate: no NEW provisioning once accrued spend crosses this
    cloud_budget_usd: float = float("inf")
    #: declared fair-share tenants; job tenants missing here get weight 1
    tenants: tuple[Tenant, ...] = ()
    #: starvation guard: a weighted tenant waiting longer than this
    #: blocks all admissions that would overtake it
    starve_patience_s: float = 900.0
    # ---- fault layer (DESIGN.md §19); defaults keep every existing
    # ---- scenario bit-identical (no fault draws are ever taken)
    #: seeded fault mix injected into the run; None = fault-free
    faults: FaultPlan | None = None
    #: provisioning retry/backoff; None = give up on first denial
    retry: RetryPolicy | None = None
    #: hardened rollback: verify checkpoint generations and fall back
    #: to the newest intact one.  False trusts the latest blindly — a
    #: corrupt restore collapses the job back to step 0
    ckpt_integrity: bool = True
    #: checkpoint generations each job keeps (floored to 2)
    ckpt_keep: int = 3
    #: scavenger preemption: checkpoint a running zero-weight job
    #: through the ckpt→restart path to admit an expired weighted one
    preemption: bool = False
    #: admission-time deadline handling for infeasible deadlines:
    #: "accept" (run anyway), "renegotiate" (counter-offer the
    #: capacity-model minimum), "reject" (decline the job)
    admission: str = "accept"
    #: safety margin on the renegotiated counter-offer deadline
    admission_margin: float = 0.1


def _jobs(n: int, *, steps: int, deadline_s: float,
          stagger_s: float = 60.0) -> tuple[JobSpec, ...]:
    return tuple(
        JobSpec(
            name=f"job{i}",
            arrival_s=i * stagger_s,
            steps_total=steps,
            deadline_s=deadline_s,
            chip_seconds_per_step=WORK,
            onprem_chips=ONPREM_CHIPS,
        )
        for i in range(n)
    )


def poisson_background(
    rng: np.random.Generator,
    *,
    rate_per_hour: float,
    mean_duration_s: float,
    mean_chips: float,
    horizon_s: float,
) -> tuple[BackgroundLoad, ...]:
    """Poisson tenant arrivals with exponential durations — demand that
    *emerges* from a stochastic process rather than a script."""
    loads = []
    t = 0.0
    while True:
        t += float(rng.exponential(3600.0 / rate_per_hour))
        if t >= horizon_s:
            break
        dur = float(rng.exponential(mean_duration_s))
        chips = max(8, int(rng.poisson(mean_chips)))
        loads.append(BackgroundLoad(t, t + dur, chips))
    return tuple(loads)


def calm(seed: int = 0) -> Scenario:
    rng = np.random.default_rng([seed, 100])
    return Scenario(
        name="calm",
        jobs=_jobs(2, steps=150, deadline_s=1700.0),
        background=poisson_background(
            rng, rate_per_hour=4.0, mean_duration_s=200.0,
            mean_chips=32.0, horizon_s=1500.0,
        ),
        description="light tenant load; every policy should hit at "
                    "(near-)zero cloud cost",
    )


def overload_ramp(seed: int = 0) -> Scenario:
    return Scenario(
        name="overload_ramp",
        jobs=_jobs(2, steps=200, deadline_s=2100.0),
        background=(
            BackgroundLoad(300.0, 10.0 ** 9, 128, name="ramp1"),
            BackgroundLoad(500.0, 10.0 ** 9, 256, name="ramp2"),
        ),
        description="sustained tenant ramp to 2.5x capacity — the paper "
                    "§3.3 congestion, emergent from demand",
    )


def transient_spike(seed: int = 0) -> Scenario:
    return Scenario(
        name="transient_spike",
        jobs=_jobs(2, steps=250, deadline_s=2700.0),
        background=(
            BackgroundLoad(200.0, 600.0, 384, name="spike"),
        ),
        description="a 400 s contention spike that clears — the right "
                    "move is burst-then-retire; cloud spend must stop",
    )


def deadline_squeeze(seed: int = 0) -> Scenario:
    jobs = _jobs(2, steps=200, deadline_s=2600.0)
    return Scenario(
        name="deadline_squeeze",
        jobs=jobs,
        background=(BackgroundLoad(300.0, 10.0 ** 9, 128, name="ramp"),),
        deadline_changes=tuple(
            (800.0, j.name, 2000.0) for j in jobs
        ),
        description="moderate load, then the deadline tightens from "
                    "2600 s to 2000 s mid-run",
    )


def spot_market(seed: int = 0) -> Scenario:
    base = overload_ramp(seed)
    return dataclasses.replace(
        base,
        name="spot_market",
        jobs=tuple(
            dataclasses.replace(j, deadline_s=2400.0) for j in base.jobs
        ),
        cloud=dataclasses.replace(
            CLOUD, spot=True, spot_mean_life_s=700.0,
            price_per_chip_hour=1.0,
        ),
        description="overload on spot chips: cheaper, but pods get "
                    "reclaimed and jobs fall back to checkpoints",
    )


def node_failures(seed: int = 0) -> Scenario:
    rng = np.random.default_rng([seed, 200])
    jobs = _jobs(2, steps=200, deadline_s=2500.0)
    fails = tuple(
        (float(rng.uniform(400.0, 1400.0)), j.name) for j in jobs
    )
    return Scenario(
        name="node_failures",
        jobs=jobs,
        background=(BackgroundLoad(200.0, 10.0 ** 9, 96, name="bg"),),
        failures=fails,
        description="on-premise node failures force rollbacks to the "
                    "last checkpoint under moderate load",
    )


def superlinear_cache(seed: int = 0,
                      cost_weight: float = 0.6) -> Scenario:
    """Overload on a cache-superlinear workload (t ∝ 1/c^1.3): striped
    stencils whose per-device domains go cache-resident speed up faster
    than linearly, so a larger slice finishes and retires early enough
    to bill *fewer* chip-hours — the regime where the cost-aware
    planner's larger-but-cheaper choice is real (DESIGN.md §14).  Run
    with ``cost_weight=0`` for the cost-blind bracket."""
    alpha = 1.3
    # normalize W so the on-premise step time matches the other
    # scenarios (7.8 s/step on 128 chips) despite the steeper law
    work = WORK * float(ONPREM_CHIPS ** (alpha - 1.0))
    jobs = tuple(
        dataclasses.replace(j, chip_seconds_per_step=work,
                            scaling_alpha=alpha, deadline_s=2300.0)
        for j in _jobs(2, steps=200, deadline_s=2300.0)
    )
    return Scenario(
        name="superlinear_cache",
        jobs=jobs,
        background=(
            BackgroundLoad(300.0, 10.0 ** 9, 192, name="ramp"),
        ),
        planner_cost_weight=cost_weight,
        description="sustained overload on a superlinearly-scaling "
                    "workload — cost-aware sizing should buy the same "
                    "hit-rate for fewer cloud $",
    )


def fault_storm(seed: int = 0, *, hardened: bool = True) -> Scenario:
    """Overload under an adversarial fault mix (DESIGN.md §19): the
    ``overload_ramp`` world where bursting is *required* to hit the
    deadline, plus provisioning denials/timeouts, two market-wide
    reclaim storms, frequent silent checkpoint corruption, and
    straggler pods.  ``hardened=True`` arms the robustness machinery
    (retry/backoff + checkpoint-integrity fallback); ``hardened=False``
    is the unhardened baseline — one provisioning denial gives up, and
    a corrupt latest checkpoint is trusted blindly, collapsing the
    rollback to step 0.  The fault draws themselves are identical in
    both variants (same FaultPlan, same seeds)."""
    plan = FaultPlan(
        provision_fail_p=0.35,
        provision_timeout_p=0.25,
        provision_timeout_x=3.0,
        # one market-wide crunch late in the run: every elastic pod is
        # reclaimed when a full restart can no longer make the deadline
        # but a newest-intact-generation fallback still can
        reclaim_storms=((1450.0, 1.0),),
        ckpt_corrupt_p=0.6,
        straggler_p=0.1,
        straggler_x=2.0,
    )
    return Scenario(
        name="fault_storm",
        jobs=_jobs(2, steps=200, deadline_s=2200.0),
        background=(
            BackgroundLoad(300.0, 10.0 ** 9, 128, name="ramp1"),
            BackgroundLoad(500.0, 10.0 ** 9, 256, name="ramp2"),
        ),
        ckpt_every=20,
        ckpt_keep=4,
        faults=plan,
        retry=RetryPolicy(max_retries=4, base_s=10.0, mult=2.0,
                          cap_s=120.0) if hardened else None,
        ckpt_integrity=hardened,
        description="overload_ramp under provisioning denials, reclaim "
                    "storms, checkpoint corruption and stragglers — "
                    "the hardened loop keeps its hit-rate where the "
                    "unhardened baseline collapses",
    )


def preemption_pressure(seed: int = 0) -> Scenario:
    """A scavenger monopolizes the site when a weighted job arrives:
    with ``preemption=True`` the starvation guard checkpoints the
    zero-weight job through the ckpt→restart path and admits the
    expired weighted entry within one evaluation interval
    (DESIGN.md §19)."""
    work = 8.0 * 128
    return Scenario(
        name="preemption_pressure",
        jobs=(
            JobSpec(name="scav0", arrival_s=0.0, steps_total=400,
                    deadline_s=10.0 ** 6, chip_seconds_per_step=work,
                    onprem_chips=128, tenant="scav"),
            JobSpec(name="gold0", arrival_s=60.0, steps_total=60,
                    deadline_s=1500.0, chip_seconds_per_step=work,
                    onprem_chips=128, tenant="gold"),
        ),
        site_chips=128,
        scheduler="fill",
        tenants=(Tenant("gold", weight=2.0), Tenant("scav", weight=0.0)),
        starve_patience_s=180.0,
        preemption=True,
        description="a long scavenger holds the whole site; the "
                    "starved weighted job is admitted by preempting it",
    )


def default_scenarios(seed: int = 0) -> tuple[Scenario, ...]:
    return (
        calm(seed),
        overload_ramp(seed),
        transient_spike(seed),
        deadline_squeeze(seed),
        spot_market(seed),
        node_failures(seed),
        superlinear_cache(seed),
    )


# ---- job streams for the fleet layer (DESIGN.md §16) ----------------------

def _stream_job(
    rng: np.random.Generator, i: int, t: float,
    tenants: tuple[str, ...],
    steps_rng: tuple[int, int], chips_choices: tuple[int, ...],
    work_per_chip_s: float, slack: tuple[float, float],
    name_prefix: str,
) -> JobSpec:
    """One job of a stream: small (site fits several at once), with a
    deadline drawn as a slack multiple of its own on-premise runtime —
    so queue wait is exactly what eats the slack under overload."""
    steps = int(rng.integers(steps_rng[0], steps_rng[1] + 1))
    chips = int(rng.choice(np.asarray(chips_choices)))
    work = work_per_chip_s * chips       # work_per_chip_s s/step on-prem
    run_s = steps * work_per_chip_s
    return JobSpec(
        name=f"{name_prefix}{i}",
        arrival_s=t,
        steps_total=steps,
        deadline_s=run_s * float(rng.uniform(*slack)),
        chip_seconds_per_step=work,
        onprem_chips=chips,
        tenant=tenants[i % len(tenants)],
    )


def poisson_jobs(
    rng: np.random.Generator,
    *,
    n: int,
    rate_per_hour: float,
    tenants: tuple[str, ...] = ("user0",),
    steps_rng: tuple[int, int] = (20, 60),
    chips_choices: tuple[int, ...] = (16, 32, 64),
    work_per_chip_s: float = 8.0,
    slack: tuple[float, float] = (4.0, 10.0),
    name_prefix: str = "job",
) -> tuple[JobSpec, ...]:
    """A Poisson stream of ``n`` foreground jobs, tenants assigned
    round-robin (so tenant mix is exact, not sampled)."""
    out = []
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(3600.0 / rate_per_hour))
        out.append(_stream_job(
            rng, i, t, tenants, steps_rng, chips_choices,
            work_per_chip_s, slack, name_prefix,
        ))
    return tuple(out)


def diurnal_jobs(
    rng: np.random.Generator,
    *,
    n: int,
    base_rate_per_hour: float,
    peak_rate_per_hour: float,
    period_s: float = 86400.0,
    tenants: tuple[str, ...] = ("user0",),
    steps_rng: tuple[int, int] = (20, 60),
    chips_choices: tuple[int, ...] = (16, 32, 64),
    work_per_chip_s: float = 8.0,
    slack: tuple[float, float] = (4.0, 10.0),
    name_prefix: str = "job",
) -> tuple[JobSpec, ...]:
    """Sinusoidally-modulated Poisson arrivals (thinning construction):
    the rate climbs from ``base`` at t=0 to ``peak`` half a period in —
    the day/night pressure signal the pool forecasters track."""
    out = []
    t = 0.0
    i = 0
    while i < n:
        t += float(rng.exponential(3600.0 / peak_rate_per_hour))
        phase = 0.5 - 0.5 * math.cos(2.0 * math.pi * t / period_s)
        rate = (base_rate_per_hour
                + (peak_rate_per_hour - base_rate_per_hour) * phase)
        if float(rng.uniform()) * peak_rate_per_hour > rate:
            continue                     # thinned out
        out.append(_stream_job(
            rng, i, t, tenants, steps_rng, chips_choices,
            work_per_chip_s, slack, name_prefix,
        ))
        i += 1
    return tuple(out)


def multi_tenant_rush(seed: int = 0, n_jobs: int = 60,
                      rate_per_hour: float = 240.0,
                      budget_usd: float = 400.0) -> Scenario:
    """Three tenants of unequal weight flood the queue far past site
    capacity: sustained offered load ≈ 3× the 256-chip site, so hit
    rates separate on (scheduler, fleet-policy) quality and the
    fairness column is live.  ``n_jobs=1000+`` is the tournament's
    thousand-concurrent-jobs configuration — same world, longer rush."""
    rng = np.random.default_rng([seed, 300])
    return Scenario(
        name="multi_tenant_rush",
        jobs=poisson_jobs(
            rng, n=n_jobs, rate_per_hour=rate_per_hour,
            tenants=("gold", "silver", "silver", "scav"),
        ),
        scheduler="fill",
        fleet_policy="adapt",
        cloud_chip_cap=512,
        cloud_budget_usd=budget_usd,
        tenants=(
            Tenant("gold", weight=3.0, priority=1.0),
            Tenant("silver", weight=1.0),
            Tenant("scav", weight=0.0),     # scavenger: runs on leftovers
        ),
        starve_patience_s=600.0,
        description="weighted tenants rush the queue at ~3x site "
                    "capacity; placement + pool policy decide who hits",
    )


def diurnal_stream(seed: int = 0, n_jobs: int = 48,
                   budget_usd: float = 300.0) -> Scenario:
    """A compressed day of diurnal arrivals from two equal tenants: the
    pool forecasters (reg/conpaas) get a predictable pressure wave to
    track; over-provisioning shows up directly in pool_cost."""
    rng = np.random.default_rng([seed, 400])
    return Scenario(
        name="diurnal_stream",
        jobs=diurnal_jobs(
            rng, n=n_jobs, base_rate_per_hour=30.0,
            peak_rate_per_hour=360.0, period_s=7200.0,
            tenants=("ops", "research"),
        ),
        scheduler="best-fit",
        fleet_policy="reg",
        cloud_chip_cap=512,
        cloud_budget_usd=budget_usd,
        tenants=(Tenant("ops"), Tenant("research")),
        description="sinusoidal arrival wave (2 h period): forecasting "
                    "pool policies should pre-provision into the crest "
                    "and drain into the trough",
    )


def queued_scenarios(seed: int = 0) -> tuple[Scenario, ...]:
    """The fleet-layer worlds the tournament runs (DESIGN.md §16)."""
    return (multi_tenant_rush(seed), diurnal_stream(seed))
