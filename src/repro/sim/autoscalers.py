"""Auto-scaler policy suite — the paper's Fig. 1 decision loop as one
policy among several, evaluated on a fixed interval (DESIGN.md §11).

The paper's contribution is a *deadline-aware, model-driven* scaler
(capacity models eqs. 1-3 + γ split).  To show what that buys, the fleet
simulator runs it against the classic policy families the auto-scaling
literature benchmarks (React/Hist in the style of the OpenDC prototype
suite) and two brackets:

  no-burst      lower bracket: the static on-premise allocation
  always-burst  upper bracket: provision the maximum slice on arrival
  react         reactive: one legal slice up on a predicted miss, one
                down when slack is comfortable (no model, no sizing)
  hist          predictive: percentile-of-history step time projects
                completion; grows/retires on the projection
  plan          deadline-aware: BurstPlanner sizes the slice via the
                capacity models and K; retires as soon as the on-premise
                side alone meets the deadline

Every policy answers with a ScaleAction; the orchestrator/fleet applies
it through the identical CHECKPOINT → REMESH → RESHARD → RESUME path, so
policies differ only in *when* and *how much* — never in mechanism.
"""
from __future__ import annotations

from collections import deque

from repro.core.capacity import (
    legal_step_down,
    legal_step_up,
    round_to_legal_slice,
)
from repro.core.orchestrator import (
    ELASTIC_PREFIXES,
    HOLD,
    AutoscalerPolicy,
    ScaleAction,
    ScaleContext,
)

__all__ = [
    "AutoscalerPolicy",
    "AlwaysBurstAutoscaler",
    "HistAutoscaler",
    "NoBurstAutoscaler",
    "PlanAutoscaler",
    "ReactAutoscaler",
    "POLICY_FACTORIES",
]


class NoBurstAutoscaler:
    """Baseline: never touch the cloud (the paper's 'static' run)."""

    name = "no-burst"

    def decide(self, ctx: ScaleContext) -> ScaleAction:
        return HOLD


class AlwaysBurstAutoscaler:
    """Upper bracket: hold the largest legal slice for the whole run.

    Maximizes the chance of hitting the deadline and the bill alike —
    the cost anchor the paper's adaptive approach is judged against.
    """

    name = "always-burst"

    def __init__(self, chips: int | None = None, slowdown: float = 1.4):
        self.chips = chips
        self.slowdown = slowdown

    def decide(self, ctx: ScaleContext) -> ScaleAction:
        target = self.chips or max(ctx.legal)
        if ctx.cloud_chips < target:
            return ScaleAction("grow", chips=target,
                               slowdown=self.slowdown,
                               reason="always-burst holds max slice")
        return HOLD


class ReactAutoscaler:
    """Reactive scaler: step the slice up/down on the current signal.

    No capacity model: if the deadline estimate says miss, grow by one
    legal slice; if slack exceeds ``shrink_slack_frac`` of the deadline,
    step down (0 chips ⇒ retire).  The provisioning quantum is the next
    legal slice shape (capacity.legal_step_up/down).
    """

    name = "react"

    def __init__(self, slowdown: float = 1.4,
                 shrink_slack_frac: float = 0.25):
        self.slowdown = slowdown
        self.shrink_slack_frac = shrink_slack_frac

    def decide(self, ctx: ScaleContext) -> ScaleAction:
        est = ctx.est
        if not est.predictable:
            return HOLD
        if est.will_miss:
            up = legal_step_up(ctx.cloud_chips, ctx.legal)
            if up > ctx.cloud_chips:
                return ScaleAction("grow", chips=up,
                                   slowdown=self.slowdown,
                                   reason="reactive step up on miss")
            return HOLD
        if (
            ctx.cloud_chips > 0
            and est.slack_s > self.shrink_slack_frac * est.deadline_s
        ):
            down = legal_step_down(ctx.cloud_chips, ctx.legal)
            if down == 0:
                return ScaleAction("retire",
                                   reason="reactive retire on slack")
            return ScaleAction("shrink", chips=down,
                               reason="reactive step down on slack")
        return HOLD


class HistAutoscaler:
    """Predictive scaler: percentile-of-history step time.

    Keeps a window of observed per-step times; projects completion with
    a conservative percentile (growth) and an optimistic one (retire),
    so transient spikes don't whipsaw the slice.  Sizing uses the
    work-conservation identity t ∝ 1/chips on the *percentile* step
    time — a model-free cousin of the paper's capacity inversion.
    """

    name = "hist"

    def __init__(self, window: int = 64, grow_pct: float = 0.9,
                 shrink_pct: float = 0.5, slowdown: float = 1.4,
                 margin_frac: float = 0.1):
        self.window = window
        self.grow_pct = grow_pct
        self.shrink_pct = shrink_pct
        self.slowdown = slowdown
        self.margin_frac = margin_frac
        self._hist: deque[float] = deque(maxlen=window)

    @staticmethod
    def _pct(xs: list[float], q: float) -> float:
        s = sorted(xs)
        return s[min(int(q * len(s)), len(s) - 1)]

    def decide(self, ctx: ScaleContext) -> ScaleAction:
        t_now = ctx.monitor.step_time()
        if t_now > 0:
            self._hist.append(t_now)
        if len(self._hist) < 4 or not ctx.est.predictable:
            return HOLD
        steps_rem = max(ctx.steps_total - ctx.step, 0)
        if steps_rem == 0:
            return HOLD
        budget = ctx.est.deadline_s * (1 - self.margin_frac) \
            - ctx.elapsed_s
        t_grow = self._pct(list(self._hist), self.grow_pct)
        if steps_rem * t_grow > budget > 0:
            # invert t ∝ 1/chips at the pessimistic percentile: how many
            # effective chips would bring the projection inside budget?
            eff_now = sum(
                p.chips / p.slowdown for p in ctx.resources.pods
            )
            eff_needed = eff_now * steps_rem * t_grow / budget
            extra = (eff_needed - eff_now) * self.slowdown
            target = round_to_legal_slice(
                ctx.cloud_chips + extra, ctx.legal
            )
            if target > ctx.cloud_chips:
                return ScaleAction(
                    "grow", chips=target, slowdown=self.slowdown,
                    reason=f"p{int(self.grow_pct * 100)} projects miss",
                )
            return HOLD
        if ctx.cloud_chips > 0 and budget > 0:
            # would the optimistic projection hold *without* the cloud?
            t_opt = self._pct(list(self._hist), self.shrink_pct)
            eff_now = sum(
                p.chips / p.slowdown for p in ctx.resources.pods
            )
            eff_onprem = eff_now - ctx.cloud_chips / self.slowdown
            if eff_onprem > 0:
                t_onprem = t_opt * eff_now / eff_onprem
                if steps_rem * t_onprem < budget:
                    return ScaleAction(
                        "retire",
                        reason=f"p{int(self.shrink_pct * 100)} projects "
                               "hit without cloud",
                    )
        return HOLD


class PlanAutoscaler:
    """Deadline-aware scaler — the paper's pipeline, made reversible.

    GROW: BurstPlanner.plan() runs the full Fig. 1 chain (deadline
    estimate → calibrated capacity model → eq. 3 chips → K correction →
    legal slice), so the slice is *sized*, not stepped.  RETIRE: as soon
    as the projected on-premise-only completion (observed step time
    rescaled by the effective-chip ratio) fits the deadline with margin,
    the cloud pod is dropped — the scale-*down* the paper leaves as
    future work (§4).
    """

    name = "plan"

    def __init__(self, retire_margin_frac: float = 0.15):
        self.retire_margin_frac = retire_margin_frac

    def decide(self, ctx: ScaleContext) -> ScaleAction:
        est = ctx.est
        if not est.predictable:
            return HOLD
        eff_now = sum(p.chips / p.slowdown for p in ctx.resources.pods)
        decision = ctx.planner.plan(
            est, ctx.step, ctx.steps_total,
            observed_step_s=ctx.monitor.step_time(),
            effective_chips=eff_now,
        )
        if decision.burst and decision.chips_burst > ctx.cloud_chips:
            reason = decision.reason
            if decision.est_cost_usd > 0 and "$" not in reason:
                # cost-aware planner (DESIGN.md §14): surface the
                # projected bill for the sized slice in the audit trail
                reason += f" (~${decision.est_cost_usd:.2f} projected)"
            return ScaleAction(
                "grow", chips=decision.chips_burst,
                slowdown=max(decision.correction_K, 1e-6),
                reason=reason,
            )
        if ctx.cloud_chips > 0:
            cloud_pods = [
                p for p in ctx.resources.pods
                if p.name.startswith(ELASTIC_PREFIXES)
            ]
            eff_cloud = sum(p.chips / p.slowdown for p in cloud_pods)
            eff_onprem = eff_now - eff_cloud
            steps_rem = max(ctx.steps_total - ctx.step, 0)
            t_now = ctx.monitor.step_time()
            if eff_onprem > 0 and t_now > 0:
                # project the on-premise-alone step time through the
                # *calibrated capacity model* (same curve the sizing
                # uses), not a linear effective-chip rescale — on
                # non-linear laws the linear rescale under-estimates and
                # retires too eagerly, thrashing grow/retire cycles
                cal = ctx.planner.calibrated_cluster_model(
                    t_now, eff_now
                )
                t_onprem = cal.predict_time(ctx.planner.chips_cluster)
                ov = ctx.planner.overheads
                projected = (
                    ctx.elapsed_s + ov.ckpt_s + ov.restart_s
                    + steps_rem * t_onprem
                )
                if projected < (1 - self.retire_margin_frac) \
                        * est.deadline_s:
                    return ScaleAction(
                        "retire",
                        reason="on-premise alone meets deadline "
                               f"({projected:.0f}s < {est.deadline_s:.0f}s)",
                    )
        return HOLD


#: fresh-instance factories (Hist is stateful, one instance per job)
POLICY_FACTORIES = {
    "no-burst": NoBurstAutoscaler,
    "always-burst": AlwaysBurstAutoscaler,
    "react": ReactAutoscaler,
    "hist": HistAutoscaler,
    "plan": PlanAutoscaler,
}
