"""Auto-scaler policy suite — the paper's Fig. 1 decision loop as one
policy among several, evaluated on a fixed interval (DESIGN.md §11).

The paper's contribution is a *deadline-aware, model-driven* scaler
(capacity models eqs. 1-3 + γ split).  To show what that buys, the fleet
simulator runs it against the classic policy families the auto-scaling
literature benchmarks (React/Hist in the style of the OpenDC prototype
suite) and two brackets:

  no-burst      lower bracket: the static on-premise allocation
  always-burst  upper bracket: provision the maximum slice on arrival
  react         reactive: one legal slice up on a predicted miss, one
                down when slack is comfortable (no model, no sizing)
  hist          predictive: percentile-of-history step time projects
                completion; grows/retires on the projection
  plan          deadline-aware: BurstPlanner sizes the slice via the
                capacity models and K; retires as soon as the on-premise
                side alone meets the deadline

Every policy answers with a ScaleAction; the orchestrator/fleet applies
it through the identical CHECKPOINT → REMESH → RESHARD → RESUME path, so
policies differ only in *when* and *how much* — never in mechanism.

Fleet-level policies (DESIGN.md §16): a second, queue-driven level on
top of the per-job suite.  A FleetAutoscaler sees the *fleet* signals —
queue depth, queued work, aggregate predicted lateness of the running
jobs — and answers with a target for the fleet's total cloud footprint
(held + staged + pooled chips).  The FleetController converges the
pre-provisioned pool toward that target, so queued jobs can start on
cloud chips (VM-MAD's queue-driven cluster expansion) and late jobs can
draw a slice without paying the provisioning delay.  The variants port
the OpenDC prototype zoo: ``adapt`` is the estimator/controller pair
from SNIPPETS.md, ``reg`` a regression forecaster, ``conpaas`` a
percentile provisioner, ``token`` a budget-paced token bucket.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Protocol

from repro.core.capacity import (
    legal_step_down,
    legal_step_up,
    round_to_legal_slice,
)
from repro.core.orchestrator import (
    ELASTIC_PREFIXES,
    HOLD,
    AutoscalerPolicy,
    ScaleAction,
    ScaleContext,
)

__all__ = [
    "AutoscalerPolicy",
    "AlwaysBurstAutoscaler",
    "provider_backoff_active",
    "AdaptFleetAutoscaler",
    "ConpaasFleetAutoscaler",
    "FLEET_POLICY_FACTORIES",
    "FleetAutoscaler",
    "FleetContext",
    "HistAutoscaler",
    "NoBurstAutoscaler",
    "PlanAutoscaler",
    "ReactAutoscaler",
    "RegFleetAutoscaler",
    "TokenFleetAutoscaler",
    "POLICY_FACTORIES",
]


def provider_backoff_active(ctx: ScaleContext, base_s: float = 60.0,
                            cap_s: float = 960.0) -> bool:
    """Capped exponential provider cooldown (DESIGN.md §19).

    After ``ctx.provision_failures`` consecutive denials, hold off
    re-requesting for ``min(base_s * 2**(failures-1), cap_s)`` seconds
    since the last denial — hammering a provider that keeps saying no
    just burns evaluation intervals.  Every grow-capable policy gates
    its grow on this, so the whole suite inherits the cooldown."""
    if ctx.provision_failures <= 0:
        return False
    cooldown = min(base_s * 2.0 ** (ctx.provision_failures - 1), cap_s)
    return ctx.since_failure_s < cooldown


class NoBurstAutoscaler:
    """Baseline: never touch the cloud (the paper's 'static' run)."""

    name = "no-burst"

    def decide(self, ctx: ScaleContext) -> ScaleAction:
        return HOLD


class AlwaysBurstAutoscaler:
    """Upper bracket: hold the largest legal slice for the whole run.

    Maximizes the chance of hitting the deadline and the bill alike —
    the cost anchor the paper's adaptive approach is judged against.
    """

    name = "always-burst"

    def __init__(self, chips: int | None = None, slowdown: float = 1.4):
        self.chips = chips
        self.slowdown = slowdown

    def decide(self, ctx: ScaleContext) -> ScaleAction:
        target = self.chips or max(ctx.legal)
        if ctx.cloud_chips < target:
            if provider_backoff_active(ctx):
                return HOLD
            return ScaleAction("grow", chips=target,
                               slowdown=self.slowdown,
                               reason="always-burst holds max slice")
        return HOLD


class ReactAutoscaler:
    """Reactive scaler: step the slice up/down on the current signal.

    No capacity model: if the deadline estimate says miss, grow by one
    legal slice; if slack exceeds ``shrink_slack_frac`` of the deadline,
    step down (0 chips ⇒ retire).  The provisioning quantum is the next
    legal slice shape (capacity.legal_step_up/down).
    """

    name = "react"

    def __init__(self, slowdown: float = 1.4,
                 shrink_slack_frac: float = 0.25):
        self.slowdown = slowdown
        self.shrink_slack_frac = shrink_slack_frac

    def decide(self, ctx: ScaleContext) -> ScaleAction:
        est = ctx.est
        if not est.predictable:
            return HOLD
        if est.will_miss:
            if provider_backoff_active(ctx):
                return HOLD
            up = legal_step_up(ctx.cloud_chips, ctx.legal)
            if up > ctx.cloud_chips:
                return ScaleAction("grow", chips=up,
                                   slowdown=self.slowdown,
                                   reason="reactive step up on miss")
            return HOLD
        if (
            ctx.cloud_chips > 0
            and est.slack_s > self.shrink_slack_frac * est.deadline_s
        ):
            down = legal_step_down(ctx.cloud_chips, ctx.legal)
            if down == 0:
                return ScaleAction("retire",
                                   reason="reactive retire on slack")
            return ScaleAction("shrink", chips=down,
                               reason="reactive step down on slack")
        return HOLD


class HistAutoscaler:
    """Predictive scaler: percentile-of-history step time.

    Keeps a window of observed per-step times; projects completion with
    a conservative percentile (growth) and an optimistic one (retire),
    so transient spikes don't whipsaw the slice.  Sizing uses the
    work-conservation identity t ∝ 1/chips on the *percentile* step
    time — a model-free cousin of the paper's capacity inversion.
    """

    name = "hist"

    def __init__(self, window: int = 64, grow_pct: float = 0.9,
                 shrink_pct: float = 0.5, slowdown: float = 1.4,
                 margin_frac: float = 0.1):
        self.window = window
        self.grow_pct = grow_pct
        self.shrink_pct = shrink_pct
        self.slowdown = slowdown
        self.margin_frac = margin_frac
        self._hist: deque[float] = deque(maxlen=window)

    @staticmethod
    def _pct(xs: list[float], q: float) -> float:
        s = sorted(xs)
        return s[min(int(q * len(s)), len(s) - 1)]

    def decide(self, ctx: ScaleContext) -> ScaleAction:
        t_now = ctx.monitor.step_time()
        if t_now > 0:
            self._hist.append(t_now)
        if len(self._hist) < 4 or not ctx.est.predictable:
            return HOLD
        steps_rem = max(ctx.steps_total - ctx.step, 0)
        if steps_rem == 0:
            return HOLD
        budget = ctx.est.deadline_s * (1 - self.margin_frac) \
            - ctx.elapsed_s
        t_grow = self._pct(list(self._hist), self.grow_pct)
        if steps_rem * t_grow > budget > 0:
            # invert t ∝ 1/chips at the pessimistic percentile: how many
            # effective chips would bring the projection inside budget?
            eff_now = sum(
                p.chips / p.slowdown for p in ctx.resources.pods
            )
            eff_needed = eff_now * steps_rem * t_grow / budget
            extra = (eff_needed - eff_now) * self.slowdown
            target = round_to_legal_slice(
                ctx.cloud_chips + extra, ctx.legal
            )
            if target > ctx.cloud_chips:
                if provider_backoff_active(ctx):
                    return HOLD
                return ScaleAction(
                    "grow", chips=target, slowdown=self.slowdown,
                    reason=f"p{int(self.grow_pct * 100)} projects miss",
                )
            return HOLD
        if ctx.cloud_chips > 0 and budget > 0:
            # would the optimistic projection hold *without* the cloud?
            t_opt = self._pct(list(self._hist), self.shrink_pct)
            eff_now = sum(
                p.chips / p.slowdown for p in ctx.resources.pods
            )
            eff_onprem = eff_now - ctx.cloud_chips / self.slowdown
            if eff_onprem > 0:
                t_onprem = t_opt * eff_now / eff_onprem
                if steps_rem * t_onprem < budget:
                    return ScaleAction(
                        "retire",
                        reason=f"p{int(self.shrink_pct * 100)} projects "
                               "hit without cloud",
                    )
        return HOLD


class PlanAutoscaler:
    """Deadline-aware scaler — the paper's pipeline, made reversible.

    GROW: BurstPlanner.plan() runs the full Fig. 1 chain (deadline
    estimate → calibrated capacity model → eq. 3 chips → K correction →
    legal slice), so the slice is *sized*, not stepped.  RETIRE: as soon
    as the projected on-premise-only completion (observed step time
    rescaled by the effective-chip ratio) fits the deadline with margin,
    the cloud pod is dropped — the scale-*down* the paper leaves as
    future work (§4).
    """

    name = "plan"

    def __init__(self, retire_margin_frac: float = 0.15):
        self.retire_margin_frac = retire_margin_frac

    def decide(self, ctx: ScaleContext) -> ScaleAction:
        est = ctx.est
        if not est.predictable:
            return HOLD
        eff_now = sum(p.chips / p.slowdown for p in ctx.resources.pods)
        decision = ctx.planner.plan(
            est, ctx.step, ctx.steps_total,
            observed_step_s=ctx.monitor.step_time(),
            effective_chips=eff_now,
        )
        if decision.burst and decision.chips_burst > ctx.cloud_chips:
            if provider_backoff_active(ctx):
                return HOLD
            reason = decision.reason
            if decision.est_cost_usd > 0 and "$" not in reason:
                # cost-aware planner (DESIGN.md §14): surface the
                # projected bill for the sized slice in the audit trail
                reason += f" (~${decision.est_cost_usd:.2f} projected)"
            return ScaleAction(
                "grow", chips=decision.chips_burst,
                slowdown=max(decision.correction_K, 1e-6),
                reason=reason,
            )
        if ctx.cloud_chips > 0:
            cloud_pods = [
                p for p in ctx.resources.pods
                if p.name.startswith(ELASTIC_PREFIXES)
            ]
            eff_cloud = sum(p.chips / p.slowdown for p in cloud_pods)
            eff_onprem = eff_now - eff_cloud
            steps_rem = max(ctx.steps_total - ctx.step, 0)
            t_now = ctx.monitor.step_time()
            if eff_onprem > 0 and t_now > 0:
                # project the on-premise-alone step time through the
                # *calibrated capacity model* (same curve the sizing
                # uses), not a linear effective-chip rescale — on
                # non-linear laws the linear rescale under-estimates and
                # retires too eagerly, thrashing grow/retire cycles
                cal = ctx.planner.calibrated_cluster_model(
                    t_now, eff_now
                )
                t_onprem = cal.predict_time(ctx.planner.chips_cluster)
                ov = ctx.planner.overheads
                projected = (
                    ctx.elapsed_s + ov.ckpt_s + ov.restart_s
                    + steps_rem * t_onprem
                )
                if projected < (1 - self.retire_margin_frac) \
                        * est.deadline_s:
                    return ScaleAction(
                        "retire",
                        reason="on-premise alone meets deadline "
                               f"({projected:.0f}s < {est.deadline_s:.0f}s)",
                    )
        return HOLD


#: fresh-instance factories (Hist is stateful, one instance per job)
POLICY_FACTORIES = {
    "no-burst": NoBurstAutoscaler,
    "always-burst": AlwaysBurstAutoscaler,
    "react": ReactAutoscaler,
    "hist": HistAutoscaler,
    "plan": PlanAutoscaler,
}


# ===================================================================== #
#  Fleet-level (queue-driven) policies — DESIGN.md §16                  #
# ===================================================================== #


@dataclasses.dataclass
class FleetContext:
    """Fleet signals a queue-driven policy may look at each interval."""

    now: float
    interval_s: float
    queue_depth: int
    queued_chips: int              # Σ chips requested by waiting jobs
    queued_work_chip_s: float      # Σ remaining work of waiting jobs
    running: int                   # admitted, unfinished jobs
    late_jobs: int                 # running jobs predicting a miss
    lateness_s: float              # Σ max(0, −slack) over running jobs
    cloud_committed: int           # held + staged + pooled chips
    pool_free: int                 # provisioned, unattached pool chips
    legal: list[int]
    site_free: int
    budget_left_usd: float         # ∞ when uncapped
    price_per_chip_hour: float
    cloud_slowdown: float = 1.4


class FleetAutoscaler(Protocol):
    """Queue-driven capacity policy: answers with the desired TOTAL
    fleet cloud footprint (held + staged + pooled chips).  The
    controller grows/shrinks the pre-provisioned pool toward it."""

    name: str

    def target(self, ctx: FleetContext) -> int: ...


def _demand_chips(ctx: FleetContext) -> float:
    """The raw demand signal every fleet variant filters: cloud chips
    that would (a) host the queued work the site has no room for and
    (b) erase the running jobs' aggregate predicted lateness within
    roughly one evaluation interval."""
    overflow = max(ctx.queued_chips - ctx.site_free, 0)
    hosting = overflow * ctx.cloud_slowdown
    # chip·s of extra capacity needed to claw back the lateness in ~one
    # interval, charged at the provider's K
    rescue = (
        ctx.lateness_s / max(ctx.interval_s, 1.0) * ctx.cloud_slowdown
        * (ctx.late_jobs > 0)
    )
    return hosting + rescue


def _clip_target(ctx: FleetContext, chips: float) -> int:
    """Round a fractional target to a legal total and respect budget
    exhaustion (a spent budget can only shrink, never grow)."""
    if ctx.budget_left_usd <= 0:
        return min(ctx.cloud_committed, ctx.pool_free)
    if chips <= 0:
        return 0
    target = round_to_legal_slice(chips, ctx.legal)
    return min(target, max(ctx.legal) * 4)


class AdaptFleetAutoscaler:
    """OpenDC ``adapt``-style estimator/controller (SNIPPETS.md).

    Estimator: smooth the demand signal and its per-interval delta.
    Controller: the scaling rate R is the smoothed delta damped
    asymmetrically — scale-downs react an order of magnitude slower
    than scale-ups (the prototype divides negative R by 15) so a
    transient lull does not flap the pool.  The target is the current
    footprint plus R, legal-rounded.
    """

    name = "adapt"

    def __init__(self, up_gain: float = 1.0, down_damp: float = 8.0):
        self.up_gain = up_gain
        self.down_damp = down_damp
        self._prev_demand: float | None = None
        self._rate = 0.0

    def target(self, ctx: FleetContext) -> int:
        demand = _demand_chips(ctx)
        if self._prev_demand is None:
            delta = demand - ctx.cloud_committed
        else:
            delta = demand - self._prev_demand
        self._prev_demand = demand
        if delta >= 0:
            self._rate = self.up_gain * delta
        else:
            self._rate = delta / self.down_damp
        want = max(ctx.cloud_committed + self._rate, demand * (delta >= 0))
        return _clip_target(ctx, want)


class RegFleetAutoscaler:
    """Regression forecaster (OpenDC ``reg``): ordinary least squares
    over the recent (t, demand) history predicts the demand one
    interval ahead; the pool is provisioned for the forecast, so a
    diurnal ramp is met *before* the queue actually fills."""

    name = "reg"

    def __init__(self, window: int = 12):
        self.window = window
        self._hist: deque[tuple[float, float]] = deque(maxlen=window)

    def target(self, ctx: FleetContext) -> int:
        demand = _demand_chips(ctx)
        self._hist.append((ctx.now, demand))
        if len(self._hist) < 3:
            return _clip_target(ctx, demand)
        ts = [t for t, _ in self._hist]
        ds = [d for _, d in self._hist]
        n = len(ts)
        tm = sum(ts) / n
        dm = sum(ds) / n
        sxx = sum((t - tm) ** 2 for t in ts)
        if sxx <= 0:
            return _clip_target(ctx, demand)
        slope = sum(
            (t - tm) * (d - dm) for t, d in zip(ts, ds)
        ) / sxx
        forecast = dm + slope * (ctx.now + ctx.interval_s - tm)
        return _clip_target(ctx, max(forecast, 0.0))


class ConpaasFleetAutoscaler:
    """Percentile provisioner (ConPaaS-style): hold enough pool for the
    ``pct`` percentile of the recent demand history — robust to spikes
    (they shift the tail slowly) while still tracking sustained load."""

    name = "conpaas"

    def __init__(self, window: int = 24, pct: float = 0.8):
        self.window = window
        self.pct = pct
        self._hist: deque[float] = deque(maxlen=window)

    def target(self, ctx: FleetContext) -> int:
        self._hist.append(_demand_chips(ctx))
        s = sorted(self._hist)
        want = s[min(int(self.pct * len(s)), len(s) - 1)]
        return _clip_target(ctx, want)


class TokenFleetAutoscaler:
    """Budget-paced token bucket (OpenDC ``token``): each interval
    earns tokens worth ``spend_frac`` of the remaining cloud budget's
    steady-state burn; adding pool capacity spends tokens at the
    provider's $-rate.  Demand above the current footprint is served
    only as far as the bucket allows, so the policy *paces* spend over
    the run instead of blowing the budget on the first rush."""

    name = "token"

    def __init__(self, spend_frac: float = 0.05, horizon_s: float = 3600.0):
        self.spend_frac = spend_frac
        self.horizon_s = horizon_s
        self._tokens_usd = 0.0

    def target(self, ctx: FleetContext) -> int:
        budget = ctx.budget_left_usd
        if budget == float("inf"):
            # uncapped budget: pace against a nominal hourly burn of
            # one max slice so the bucket still smooths the rush
            budget = (
                max(ctx.legal) * ctx.price_per_chip_hour
            )
        self._tokens_usd += (
            self.spend_frac * budget * ctx.interval_s / self.horizon_s
        )
        demand = _demand_chips(ctx)
        grow = max(demand - ctx.cloud_committed, 0.0)
        if grow <= 0:
            return _clip_target(ctx, demand)
        # $ to hold `grow` chips for one horizon-paced hold
        usd_per_chip = ctx.price_per_chip_hour * ctx.interval_s / 3600.0
        affordable = (
            self._tokens_usd / usd_per_chip if usd_per_chip > 0 else grow
        )
        granted = min(grow, affordable)
        self._tokens_usd -= granted * usd_per_chip
        return _clip_target(ctx, ctx.cloud_committed + granted)


FLEET_POLICY_FACTORIES = {
    "adapt": AdaptFleetAutoscaler,
    "reg": RegFleetAutoscaler,
    "conpaas": ConpaasFleetAutoscaler,
    "token": TokenFleetAutoscaler,
}
