"""Placement schedulers for the central job queue (DESIGN.md §16).

Given the fair-share-ordered queue and the free capacity of each
placement target — the on-premise site and (when a fleet autoscaler
holds one) the pre-provisioned cloud pool — a Scheduler picks which
waiting jobs start where.  The policy families mirror the OpenDC
scheduler zoo (best-fit / worst-fit / fill) plus the FIFO baseline the
tournament brackets against:

  fifo        strict order, no skipping, site-first: the head blocks
              the queue until it fits somewhere (classic batch queue)
  fill        first-fit backfill: walk the fair-share order, admit
              anything that fits somewhere, skip what doesn't
  best-fit    repeatedly admit the (entry, target) pair leaving the
              least free capacity behind — packs tightest, so large
              jobs still find contiguous room
  worst-fit   admit the pair leaving the MOST free capacity — keeps
              headroom for the next arrival at some packing cost

Placement prefers the site over the cloud pool at equal fit: site
chips are already paid for, pool chips bill per hour and run at the
provider's K slowdown.  Every scheduler returns placements only; the
FleetController applies them (and enforces the starvation guard) so
mechanism stays policy-independent, exactly like the per-job
ScaleAction split (DESIGN.md §11, §16).
"""
from __future__ import annotations

from typing import Protocol

from repro.sim.queue import QueueEntry

__all__ = [
    "BestFitScheduler",
    "FifoScheduler",
    "FillScheduler",
    "Placement",
    "SCHEDULER_FACTORIES",
    "Scheduler",
    "WorstFitScheduler",
]

#: placement targets, in preference order at equal fit
SITE = "site"
CLOUD = "cloud"

#: (entry, target) pair the controller should admit
Placement = tuple[QueueEntry, str]


class Scheduler(Protocol):
    """Admission policy over the fair-share-ordered queue."""

    name: str

    def select(
        self, ordered: list[QueueEntry], free: dict[str, int]
    ) -> list[Placement]: ...


def _fits(entry: QueueEntry, free: dict[str, int]) -> list[str]:
    """Targets that can hold the entry, site preferred."""
    out = []
    for tgt in (SITE, CLOUD):
        if free.get(tgt, 0) >= entry.chips:
            out.append(tgt)
    return out


class FifoScheduler:
    """Arrival order, head-of-line blocking — the classic batch queue.

    Ignores the fair-share ranking on purpose: FIFO is the tournament's
    discipline baseline, so it must be the undoctored thing the other
    schedulers are judged against.
    """

    name = "fifo"

    def select(self, ordered, free):
        free = dict(free)
        out: list[Placement] = []
        for e in sorted(ordered, key=lambda e: (e.enqueued_s, e.name)):
            fit = _fits(e, free)
            if not fit:
                break                      # the head blocks the queue
            out.append((e, fit[0]))
            free[fit[0]] -= e.chips
        return out


class FillScheduler:
    """First-fit backfill in fair-share order: admit whatever fits,
    skip what doesn't.  The workhorse — fair-share picks who deserves
    chips, fill makes sure no chip idles while anyone fits."""

    name = "fill"

    def select(self, ordered, free):
        free = dict(free)
        out: list[Placement] = []
        for e in ordered:
            fit = _fits(e, free)
            if fit:
                out.append((e, fit[0]))
                free[fit[0]] -= e.chips
        return out


class _FitScheduler:
    """Shared body of best-fit / worst-fit: repeatedly score every
    (entry, target) pair by the free capacity left behind and admit the
    extreme one; fair-share order breaks score ties."""

    #: pick the pair minimizing (best-fit) or maximizing (worst-fit)
    #: the leftover capacity at its target
    _sign = 1

    def select(self, ordered, free):
        free = dict(free)
        waiting = list(ordered)
        out: list[Placement] = []
        while True:
            best: tuple | None = None
            for rank, e in enumerate(waiting):
                for tgt in _fits(e, free):
                    leftover = free[tgt] - e.chips
                    # site preferred at equal leftover (tgt==CLOUD is 1)
                    key = (self._sign * leftover, rank, tgt == CLOUD)
                    if best is None or key < best[0]:
                        best = (key, e, tgt)
            if best is None:
                return out
            _, e, tgt = best
            out.append((e, tgt))
            free[tgt] -= e.chips
            waiting.remove(e)


class BestFitScheduler(_FitScheduler):
    """Tightest packing: admit the job/target pair that leaves the
    least free capacity behind (min leftover)."""

    name = "best-fit"
    _sign = 1


class WorstFitScheduler(_FitScheduler):
    """Maximum headroom: admit the pair that leaves the MOST free
    capacity behind, so the next arrival has room (max leftover)."""

    name = "worst-fit"
    _sign = -1


SCHEDULER_FACTORIES = {
    "fifo": FifoScheduler,
    "fill": FillScheduler,
    "best-fit": BestFitScheduler,
    "worst-fit": WorstFitScheduler,
}
