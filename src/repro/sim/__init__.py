# Hybrid-fleet layer: the paper's single-job burst decision driven at
# fleet scale — site contention, cloud provisioning/cost/spot dynamics,
# and an interval-evaluated autoscaler policy suite (DESIGN.md §11).
from repro.sim.autoscalers import (
    POLICY_FACTORIES,
    AlwaysBurstAutoscaler,
    HistAutoscaler,
    NoBurstAutoscaler,
    PlanAutoscaler,
    ReactAutoscaler,
)
from repro.sim.fleet import (
    CloudProvider,
    FleetRecord,
    FleetSim,
    JobRecord,
    JobSpec,
    Site,
)
from repro.sim.scenarios import (
    Scenario,
    default_scenarios,
    superlinear_cache,
)

__all__ = [
    "AlwaysBurstAutoscaler",
    "CloudProvider",
    "FleetRecord",
    "FleetSim",
    "HistAutoscaler",
    "JobRecord",
    "JobSpec",
    "NoBurstAutoscaler",
    "POLICY_FACTORIES",
    "PlanAutoscaler",
    "ReactAutoscaler",
    "Scenario",
    "Site",
    "default_scenarios",
    "superlinear_cache",
]
