"""Discrete-event hybrid-fleet simulator (DESIGN.md §11, §16).

The paper evaluates one job bursting once from one loaded cluster.  This
module drives the *same single-job decision code* — StepTimeMonitor,
DeadlinePredictor, BurstPlanner, SimSession, the orchestrator's
apply_scale γ re-split — at fleet scale:

  Site             on-premise capacity; foreground jobs plus background
                   tenant arrivals create demand, and the "cluster
                   overloaded" condition is *emergent* contention
                   (demand / capacity), not a scripted SlowdownWindow
  CloudProvider    elastic capacity with provisioning delay,
                   per-chip-hour price, legal slice shapes, optional
                   spot reclaims
  JobController    per-job runtime: one session, one monitor/predictor/
                   planner, one per-job autoscaler policy — the paper's
                   whole Fig. 1 loop, owned per job
  FleetController  the fleet-of-jobs layer (DESIGN.md §16): owns the
                   site(s), the provider, the CentralQueue + placement
                   Scheduler, the pre-provisioned cloud pool a
                   FleetAutoscaler sizes on queue pressure, the global
                   cloud-budget caps, and all billing
  FleetSim         the PR-2 name for the event loop; now a thin alias
                   of FleetController

Decisions compose from two levels: the fleet level admits queued jobs
(fair-share order, scheduler placement, starvation guard) and converges
the shared cloud pool toward the queue-driven policy's target; the job
level runs the paper's deadline loop and asks for GROW/SHRINK/RETIRE,
which the fleet arbitrates under the global caps — pool chips first
(no provisioning delay), then max-min-fair provisioning headroom.

Per job, the policy's ScaleAction takes effect at the next step boundary
through CHECKPOINT → REMESH → RESHARD → RESUME, exactly like the
orchestrator's burst path: grow pays the full overhead chain (minus
provisioning, which overlaps with execution in the fleet), shrink/retire
pay checkpoint + restart.  Reclaims and failures roll the job back to
its last checkpoint.  All randomness flows from per-job seeded
Generators, so runs are bit-deterministic for a given (scenario,
scheduler, policy, seed) tuple.

Fault layer (DESIGN.md §19): a scenario may carry a ``FaultPlan`` —
provisioning denials/timeouts (retried under the scenario's
``RetryPolicy`` with capped exponential backoff, surfacing ``retries``
/ ``gave_up``), correlated reclaim storms, silently-corrupt checkpoint
writes (rollback falls back to the newest *intact* generation when
``ckpt_integrity`` is on; an unhardened run trusts the latest blindly
and collapses to step 0), and straggler pods attaching with a degraded
K.  On top of the fault layer the admission pass gains scavenger
*preemption* (checkpoint a running zero-weight job through the
ckpt→restart path to admit an expired weighted entry) and admission-
time deadline *renegotiation* (counter-offer or reject an infeasible
deadline using the same calibrated capacity model the planner sizes
with).  All fault draws come from dedicated per-job seeded streams, so
fault runs stay bit-deterministic per (scenario, policy, seed).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable

import numpy as np

from repro.core import (
    BurstPlanner,
    DeadlinePredictor,
    ElasticOrchestrator,
    LogCapacityModel,
    PodSpec,
    Resources,
    ScaleAction,
    ScaleContext,
    StepTimeMonitor,
    elastic_chips,
    floor_to_legal_slice,
    max_min_fair_allocation,
    min_weighted_share,
    proportional_shares,
    round_to_legal_slice,
)
from repro.core.events import BackgroundLoad
from repro.core.orchestrator import AutoscalerPolicy
from repro.core.sim_session import SimSession, SimWorkload
from repro.sim.autoscalers import (
    FLEET_POLICY_FACTORIES,
    FleetAutoscaler,
    FleetContext,
)
from repro.sim.faults import FaultInjector, RetryPolicy
from repro.sim.queue import CentralQueue, QueueEntry, Tenant, tenants_for
from repro.sim.schedulers import CLOUD, SCHEDULER_FACTORIES, SITE, Scheduler

__all__ = [
    "CloudProvider",
    "FleetController",
    "FleetRecord",
    "FleetSim",
    "JobController",
    "JobRecord",
    "JobSpec",
    "RENTED_POD",
    "Site",
]

_MAX_EVENTS = 2_000_000

#: base-pod name for jobs the scheduler places ON the cloud pool
#: (VM-MAD-style cluster expansion).  Deliberately NOT an
#: ELASTIC_PREFIXES name: the per-job policy may still grow/retire an
#: elastic pod on top without apply_scale dropping the job's home pod.
RENTED_POD = "rented"


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One foreground scientific job (the paper's FWI analogue)."""

    name: str
    arrival_s: float
    steps_total: int
    deadline_s: float                 # relative to arrival
    chip_seconds_per_step: float      # work per step (chip·s)
    onprem_chips: int
    jitter: float = 0.01
    #: rate-law exponent t_step ∝ 1 / chips**alpha (SimWorkload docs);
    #: the per-job capacity models are fitted on the same law, so the
    #: paper's pre-processing fit stays exact
    scaling_alpha: float = 1.0
    #: fair-share tenant this job bills against (DESIGN.md §16)
    tenant: str = "user0"
    #: per-job priority boost on top of the tenant's (queue tie-break)
    priority: float = 0.0


class Site:
    """On-premise cluster: finite chips shared by foreground jobs and
    background tenants.  Oversubscription slows every on-premise pod by
    demand/capacity — the organic version of the paper's congestion."""

    def __init__(self, chips: int, name: str = "site"):
        self.chips = chips
        self.name = name
        self._fg_chips: dict[str, int] = {}
        self.background: tuple[BackgroundLoad, ...] = ()

    def attach(self, job: str, chips: int) -> None:
        self._fg_chips[job] = chips

    def release(self, job: str) -> None:
        self._fg_chips.pop(job, None)

    def foreground(self) -> int:
        return sum(self._fg_chips.values())

    def free(self) -> int:
        """Chips not held by foreground jobs (background tenants do not
        reserve capacity — they contend for it, see contention())."""
        return max(self.chips - self.foreground(), 0)

    def demand(self, t: float) -> int:
        bg = sum(
            b.chips for b in self.background if b.start_s <= t < b.end_s
        )
        return self.foreground() + bg

    def contention(self, t: float) -> float:
        return max(1.0, self.demand(t) / self.chips)


@dataclasses.dataclass(frozen=True)
class CloudProvider:
    """Elastic environment: what the paper calls "the cloud"."""

    legal_slices: tuple[int, ...] = (16, 32, 64, 128, 256)
    provision_delay_s: float = 90.0
    price_per_chip_hour: float = 3.0
    slowdown: float = 1.4             # paper's K per cloud chip
    spot: bool = False
    spot_mean_life_s: float = 1800.0

    def cost(self, chip_seconds: float) -> float:
        return chip_seconds / 3600.0 * self.price_per_chip_hour


@dataclasses.dataclass
class JobRecord:
    name: str
    finished: bool
    finish_s: float
    elapsed_s: float
    deadline_s: float
    met_deadline: bool
    steps_total: int
    cloud_chip_s: float
    cloud_cost: float
    overhead_s: float
    rollbacks: int
    events: list[tuple[float, str, dict]]
    tenant: str = "user0"
    #: finished | running | queued | pending (pre-arrival) | rejected
    state: str = "finished"
    wait_s: float = 0.0               # queue wait before placement
    # ---- fault layer (DESIGN.md §19) ----
    retries: int = 0                  # provisioning attempts denied
    gave_up: bool = False             # a grow request was abandoned
    preemptions: int = 0              # times checkpointed off the site
    renegotiated: bool = False        # deadline counter-offered at admit


@dataclasses.dataclass
class FleetRecord:
    scenario: str
    policy: str
    jobs: list[JobRecord]
    hit_rate: float
    cloud_cost: float
    useful_frac: float
    cloud_timeline: list[tuple[float, int]]   # (t, fleet cloud chips)
    makespan_s: float
    scheduler: str = "immediate"
    fleet_policy: str = "none"
    #: max-min fairness of realized per-tenant service (allocator.
    #: min_weighted_share); 1.0 for single-tenant scenarios
    fairness: float = 1.0
    mean_wait_s: float = 0.0
    max_wait_s: float = 0.0
    queued_at_end: int = 0
    pool_cost: float = 0.0            # idle pool $ (included in cloud_cost)
    fleet_events: list[tuple[float, str, dict]] = dataclasses.field(
        default_factory=list
    )


class JobController:
    """Per-job controller: one session plus the paper's Fig. 1 loop
    state (monitor, predictor, planner, per-job policy).  The
    FleetController owns everything shared; this object owns exactly
    one job's runtime (DESIGN.md §16)."""

    def __init__(self, spec: JobSpec, policy: AutoscalerPolicy):
        self.spec = spec
        self.policy = policy
        self.res: Resources | None = None
        self.session: SimSession | None = None
        self.monitor = StepTimeMonitor()
        self.predictor = DeadlinePredictor(spec.deadline_s)
        self.planner: BurstPlanner | None = None
        self.rng: np.random.Generator | None = None
        self.spot_rng: np.random.Generator | None = None
        self.steps_done = 0
        self.last_ckpt = None
        self.last_ckpt_step = 0
        #: checkpoint generations, oldest first: (step, state, intact);
        #: the initial state is an implicit intact generation (§19)
        self.ckpt_gens: list[tuple[int, object, bool]] = [(0, None, True)]
        self.faults: FaultInjector | None = None
        self.retries = 0              # provisioning attempts denied
        self.gave_up = False
        self.provision_failures = 0   # consecutive, reset on success
        self.last_failure_s = -math.inf
        self.preemptions = 0
        self.site_banked_chip_s = 0.0  # site chip·s served pre-preemption
        self.rejected = False
        self.renegotiated = False
        self.ever_placed = False
        self.arrived = False
        self.queued = False
        self.finished = False
        self.finish_s = 0.0
        self.admit_s = 0.0            # placement time (== arrival when
        self.wait_s = 0.0             # admission is immediate)
        self.step_epoch = 0           # invalidates in-flight step events
        self.cloud_epoch = 0          # invalidates stale spot reclaims
        self.pending_action: ScaleAction | None = None
        self.pending_target = 0       # chips requested, not yet online
        self.staged_from_pool = 0     # staged chips drawn from the pool
        self.rented_chips = 0         # cloud-hosted base pod (CLOUD place)
        self.cloud_since = 0.0
        self.cloud_chip_s = 0.0
        self.overhead_s = 0.0
        self.rollbacks = 0
        self.events: list[tuple[float, str, dict]] = []

    @property
    def cloud_chips(self) -> int:
        return elastic_chips(self.res) if self.res else 0

    @property
    def billable_chips(self) -> int:
        """Cloud chips currently billing: the elastic pod plus a
        cloud-hosted (rented) base pod."""
        return self.cloud_chips + self.rented_chips

    def staged_grow(self) -> int:
        """Chips staged by a pending grow (pool draw or completed
        provision awaiting the step boundary)."""
        if (self.pending_action is not None
                and self.pending_action.kind == "grow"):
            return self.pending_action.chips
        return 0

    def cloud_committed(self) -> int:
        """This job's full cloud footprint for the global caps: chips
        held OR staged for it (the PR 4 double-request fix, fleet-wide:
        staged pods count, DESIGN.md §16) plus its rented base pod."""
        return (
            max(self.cloud_chips, self.pending_target, self.staged_grow())
            + self.rented_chips
        )

    @property
    def state(self) -> str:
        if self.rejected:
            return "rejected"
        if self.finished:
            return "finished"
        if self.arrived:
            return "running"
        if self.queued:
            return "queued"
        return "pending"


#: PR-2 name of the per-job runtime, kept for external callers
_JobRt = JobController


class FleetController:
    """Event-driven multi-job run of one scenario (DESIGN.md §16).

    Owns the shared world — Site, CloudProvider, CentralQueue +
    Scheduler, the fleet-policy-sized cloud pool, the global budget
    caps and all billing — and one JobController per job.  With the
    scenario's default ``scheduler="immediate"`` (and no fleet policy
    or caps) it reduces exactly to the PR-2 FleetSim: every job is
    placed on arrival and scales independently.
    """

    def __init__(
        self,
        scenario,                      # scenarios.Scenario
        policy_factory: Callable[[], AutoscalerPolicy],
        *,
        seed: int = 0,
        scheduler: Scheduler | str | None = None,
        fleet_policy: FleetAutoscaler | str | None = None,
    ):
        self.sc = scenario
        self.site = Site(scenario.site_chips)
        self.site.background = tuple(scenario.background)
        self.cloud: CloudProvider = scenario.cloud
        self.seed = seed
        self.now = 0.0
        self._seq = 0
        self._heap: list[tuple[float, int, str, tuple]] = []
        self.jobs = [
            JobController(spec, policy_factory()) for spec in scenario.jobs
        ]
        self.cloud_timeline: list[tuple[float, int]] = [(0.0, 0)]

        # ---- fleet-of-jobs layer (all off by default) --------------------
        sched = scheduler if scheduler is not None else \
            getattr(scenario, "scheduler", "immediate")
        if isinstance(sched, str):
            sched = (
                None if sched == "immediate"
                else SCHEDULER_FACTORIES[sched]()
            )
        self.scheduler: Scheduler | None = sched
        fp = fleet_policy if fleet_policy is not None else \
            getattr(scenario, "fleet_policy", "none")
        if isinstance(fp, str):
            fp = (
                None if fp in ("", "none")
                else FLEET_POLICY_FACTORIES[fp]()
            )
        self.fleet_policy: FleetAutoscaler | None = fp
        self.queue = CentralQueue(
            tenants_for(
                (s.tenant for s in scenario.jobs),
                getattr(scenario, "tenants", ()),
            )
        )
        self.chip_cap: int | None = getattr(scenario, "cloud_chip_cap", None)
        self.budget_usd: float = getattr(
            scenario, "cloud_budget_usd", math.inf
        )
        self.starve_patience_s: float = getattr(
            scenario, "starve_patience_s", 900.0
        )
        # ---- fault layer + robustness knobs (DESIGN.md §19) --------------
        self.faults = getattr(scenario, "faults", None)
        self.retry: RetryPolicy | None = getattr(scenario, "retry", None)
        self.ckpt_integrity: bool = getattr(
            scenario, "ckpt_integrity", True
        )
        self.ckpt_keep: int = max(getattr(scenario, "ckpt_keep", 3), 2)
        self.preemption: bool = getattr(scenario, "preemption", False)
        self.admission: str = getattr(scenario, "admission", "accept")
        self.admission_margin: float = getattr(
            scenario, "admission_margin", 0.1
        )
        if self.faults is not None:
            for i, j in enumerate(self.jobs):
                j.faults = FaultInjector(self.faults, seed, i)
        #: fleet-level stream for the pool's storm draw (per-job storm
        #: hits come from each job's own injector stream)
        self._storm_rng = (
            np.random.default_rng([seed, 911])
            if self.faults is not None else None
        )
        # the shared pre-provisioned pool the fleet policy sizes
        self.pool_free = 0
        self.pool_pending = 0
        self.pool_since = 0.0
        self.pool_chip_s = 0.0
        self._tenant_served: dict[str, float] = {}
        self._fairness_sum = 0.0
        self._fairness_n = 0
        self.fleet_events: list[tuple[float, str, dict]] = []

        if self.scheduler is not None:
            biggest = max(
                self.site.chips,
                max(self.cloud.legal_slices)
                if self.fleet_policy is not None else 0,
            )
            for s in scenario.jobs:
                if s.onprem_chips > biggest:
                    raise ValueError(
                        f"job {s.name!r} requests {s.onprem_chips} chips "
                        f"but no placement target can ever hold more "
                        f"than {biggest}"
                    )

    # ---- event plumbing ---------------------------------------------------

    def _push(self, t: float, kind: str, payload: tuple = ()) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, payload))

    def _fleet_event(self, kind: str, detail: dict) -> None:
        self.fleet_events.append((self.now, kind, detail))

    # ---- job lifecycle ----------------------------------------------------

    def _make_session(self, jrt: JobController, start_step: int,
                      restored) -> SimSession:
        def contention_slowdown(i: int, step: int, jrt=jrt) -> float:
            pod = jrt.res.pods[i]
            if pod.name == self.site.name:
                return self.site.contention(self.now)
            return 1.0

        return SimSession(
            SimWorkload(jrt.spec.chip_seconds_per_step, jrt.spec.jitter,
                        scaling_alpha=jrt.spec.scaling_alpha),
            jrt.res, start_step, restored,
            rng=jrt.rng,
            extra_slowdown=contention_slowdown,
        )

    def _make_planner(self, spec: JobSpec) -> BurstPlanner:
        """Per-job capacity models from the workload's own scaling law
        (t = W/c**α), cloud curve K× above — the paper's pre-processing
        fit, done analytically since the simulated law is known."""
        cs = sorted(set(self.cloud.legal_slices) | {spec.onprem_chips})
        w = spec.chip_seconds_per_step
        a = spec.scaling_alpha
        return BurstPlanner(
            cluster_model=LogCapacityModel.fit(
                cs, [w / c ** a for c in cs], name="site"),
            cloud_model=LogCapacityModel.fit(
                cs, [self.cloud.slowdown * w / c ** a for c in cs],
                name="cloud"),
            chips_cluster=spec.onprem_chips,
            legal_slices=self.cloud.legal_slices,
            overheads=self.sc.overheads,
            price_per_chip_hour=self.cloud.price_per_chip_hour,
            cost_weight=self.sc.planner_cost_weight,
        )

    def _min_completion_s(self, spec: JobSpec) -> float:
        """Best-case completion time the calibrated capacity model can
        promise (DESIGN.md §19): home pod plus the largest legal slice
        at the provider's K (seam included), plus one full overhead
        chain — the feasibility bound admission renegotiation uses."""
        planner = self._make_planner(spec)
        t_best = planner._post_burst_step_time(
            max(self.cloud.legal_slices), self.cloud.slowdown
        )
        return spec.steps_total * t_best + self.sc.overheads.total()

    def _arrive(self, jrt: JobController) -> None:
        spec = jrt.spec
        if self.admission in ("renegotiate", "reject"):
            t_min = self._min_completion_s(spec)
            if spec.deadline_s < t_min:
                if self.admission == "reject":
                    # the paper's rejection case: tell the tenant the
                    # deadline cannot be met; the job never runs and is
                    # excluded from the hit-rate denominator
                    jrt.rejected = True
                    jrt.events.append((self.now, "admission_rejected", {
                        "deadline_s": spec.deadline_s,
                        "min_feasible_s": t_min,
                    }))
                    self._fleet_event("admission_rejected", {
                        "job": spec.name, "deadline_s": spec.deadline_s,
                        "min_feasible_s": t_min,
                    })
                    return
                offer = t_min * (1.0 + self.admission_margin)
                jrt.predictor.set_deadline(offer, at_s=self.now)
                jrt.renegotiated = True
                jrt.events.append((self.now, "deadline_renegotiated", {
                    "asked_s": spec.deadline_s, "offered_s": offer,
                    "min_feasible_s": t_min,
                }))
        if self.scheduler is not None:
            jrt.queued = True
            self.queue.push(QueueEntry(
                name=spec.name, tenant=spec.tenant,
                chips=spec.onprem_chips,
                work_chip_s=spec.steps_total * spec.chip_seconds_per_step,
                enqueued_s=self.now, priority=spec.priority,
            ))
            jrt.events.append((self.now, "queued", {
                "chips": spec.onprem_chips, "tenant": spec.tenant,
            }))
            self._admit_pass()
            return
        self._place(jrt, SITE)

    def _place(self, jrt: JobController, placement: str) -> None:
        """Start a job on its placement target — the one path by which
        a job begins running, whether admitted immediately (legacy),
        from the queue by the scheduler, or *resumed* from its newest
        intact checkpoint generation after a preemption (§19)."""
        spec = jrt.spec
        resuming = jrt.ever_placed
        if jrt.rng is None:
            idx = self.jobs.index(jrt)
            jrt.rng = np.random.default_rng([self.seed, idx])
            jrt.spot_rng = np.random.default_rng([self.seed, idx, 1])
        if placement == SITE:
            base = PodSpec(spec.onprem_chips, name=self.site.name)
            self.site.attach(spec.name, spec.onprem_chips)
        else:
            # VM-MAD-style expansion: the job's home pod lives on
            # pre-provisioned pool chips at the provider's K
            self._bill_pool()
            assert self.pool_free >= spec.onprem_chips, (
                "scheduler placed onto more pool than exists"
            )
            self.pool_free -= spec.onprem_chips
            jrt.rented_chips = spec.onprem_chips
            jrt.cloud_since = self.now
            base = PodSpec(
                spec.onprem_chips, slowdown=self.cloud.slowdown,
                name=RENTED_POD,
            )
            self._fleet_event("pool_host", {
                "job": spec.name, "chips": spec.onprem_chips,
            })
        jrt.res = Resources(pods=[base], shares=[1.0])
        if jrt.planner is None:
            jrt.planner = self._make_planner(spec)
        start, restored = self._restore_ckpt(jrt)
        jrt.steps_done = start
        jrt.session = self._make_session(jrt, start, restored)
        jrt.monitor.reset_window()
        jrt.arrived = True
        jrt.queued = False
        jrt.admit_s = self.now
        if resuming:
            jrt.events.append((self.now, "resume", {
                "resume_step": start, "placement": placement,
            }))
        else:
            jrt.wait_s = max(self.now - spec.arrival_s, 0.0)
            jrt.events.append((self.now, "arrival", {}))
        jrt.ever_placed = True
        if self.scheduler is not None:
            self._record_timeline()
        self._start_step(
            jrt,
            extra_delay_s=self.sc.overheads.restart_s if resuming else 0.0,
        )

    # ---- admission (queued modes only) ------------------------------------

    def _tenant_usage(self) -> dict[str, float]:
        """Served chip·seconds per tenant up to `now`: the home pod's
        chips over its held interval plus billed/accrued cloud time —
        the usage the fair-share deficit ranking normalizes by weight."""
        usage = dict(self._tenant_served)
        for j in self.jobs:
            if not j.arrived:
                continue
            end = j.finish_s if j.finished else self.now
            held = j.spec.onprem_chips * max(end - j.admit_s, 0.0)
            cloud = j.cloud_chip_s
            if not j.finished and j.billable_chips > 0:
                cloud += j.billable_chips * max(
                    self.now - j.cloud_since, 0.0
                )
            usage[j.spec.tenant] = (
                usage.get(j.spec.tenant, 0.0) + held + cloud
            )
        return usage

    def _tenant_demand(self, usage: dict[str, float]) -> dict[str, float]:
        """Demand ceiling per tenant: what it consumed plus the work it
        still has queued or in flight — the bound that keeps the
        fairness score from blaming the scheduler for tenants that
        simply asked for less than their entitlement."""
        demand = dict(usage)
        for j in self.jobs:
            if j.finished or not (j.queued or j.arrived):
                continue
            steps_left = j.spec.steps_total - (
                j.steps_done if j.arrived else 0
            )
            demand[j.spec.tenant] = (
                demand.get(j.spec.tenant, 0.0)
                + steps_left * j.spec.chip_seconds_per_step
            )
        return demand

    def _fairness_snapshot(self) -> float:
        usage = self._tenant_usage()
        demand = self._tenant_demand(usage)
        tenants = sorted({j.spec.tenant for j in self.jobs})
        return min_weighted_share(
            [usage.get(t, 0.0) for t in tenants],
            [self.queue.tenants.get(t, Tenant(t)).weight
             for t in tenants],
            [demand.get(t, 0.0) for t in tenants],
        )

    def _admit_pass(self) -> None:
        """One admission round: fair-share-order the queue, enforce the
        starvation guard, let the Scheduler pick placements, start the
        picked jobs.  Site capacity is never over-allocated: admission
        only spends ``Site.free()`` / ``pool_free`` chips."""
        if self.scheduler is None or len(self.queue) == 0:
            return
        ordered = self.queue.order(self._tenant_usage())
        free = {SITE: self.site.free()}
        if self.fleet_policy is not None:
            free[CLOUD] = self.pool_free
        expired = [
            e for e in ordered
            if self.queue.tenants[e.tenant].weight > 0
            and e.wait_s(self.now) > self.starve_patience_s
        ]
        if expired:
            # starvation guard: while any weighted tenant has waited
            # past patience, ONLY its entries may be admitted (greedy
            # first-fit over the expired set, fair-share order)
            placements = []
            for e in expired:
                # fixed site-then-cloud order, not free.items(): the
                # admission order must never depend on dict history
                for tgt in (SITE, CLOUD):
                    if tgt in free and free[tgt] >= e.chips:
                        placements.append((e, tgt))
                        free[tgt] -= e.chips
                        break
            if not placements and self.preemption:
                # last resort before blocking: checkpoint zero-weight
                # scavengers off the site to seat the expired head (§19)
                head = expired[0]
                if self._preempt_for(head):
                    placements.append((head, SITE))
                    free[SITE] = self.site.free() - head.chips
            if not placements:
                self._fleet_event("admission_blocked", {
                    "head": expired[0].name,
                    "waited_s": expired[0].wait_s(self.now),
                })
                return
        else:
            placements = self.scheduler.select(ordered, free)
        admitted = {e.name for e, _ in placements}
        ranks = {e.name: i for i, e in enumerate(ordered)}
        max_rank = max(
            (ranks[n] for n in admitted), default=-1
        )
        for e in ordered:
            if e.name not in admitted and ranks[e.name] < max_rank:
                e.skips += 1
        for entry, target in placements:
            self.queue.remove(entry.name)
            jrt = self._by_name(entry.name)
            assert target == SITE or self.fleet_policy is not None
            assert target != SITE or self.site.free() >= entry.chips, (
                "scheduler over-allocated the site"
            )
            self._place(jrt, target)
            jrt.events.append((self.now, "admit", {
                "placement": target, "chips": entry.chips,
                "wait_s": jrt.wait_s, "skips": entry.skips,
                "site_used_after": self.site.foreground(),
                "expired_present": bool(expired),
                "entry_expired": any(
                    x.name == entry.name for x in expired
                ),
            }))

    # ---- scavenger preemption (DESIGN.md §19) -----------------------------

    def _preempt_for(self, entry: QueueEntry) -> bool:
        """Checkpoint zero-weight scavengers off the site until the
        expired weighted ``entry`` fits.  Victims leave through the
        existing ckpt→restart path, re-queue at their current progress,
        and resume from the newest intact generation when capacity
        returns — the ROADMAP's preemption-through-checkpoint item."""
        victims = sorted(
            (
                j for j in self.jobs
                if j.arrived and not j.finished and j.rented_chips == 0
                and self.queue.tenants.get(
                    j.spec.tenant, Tenant(j.spec.tenant)
                ).weight == 0.0
            ),
            key=lambda j: (-j.spec.onprem_chips, j.spec.name),
        )
        for v in victims:
            if self.site.free() >= entry.chips:
                break
            self._preempt(v, entry.name)
        return self.site.free() >= entry.chips

    def _preempt(self, jrt: JobController, for_job: str) -> None:
        """Take one scavenger off the site: checkpoint at the current
        step, drop every cloud pod, release the home pod, re-queue."""
        self._save_ckpt(jrt, jrt.steps_done,
                        jrt.session.checkpoint(jrt.steps_done))
        jrt.preemptions += 1
        jrt.step_epoch += 1            # invalidate the in-flight step
        self._bill_cloud(jrt)
        before = jrt.cloud_chips
        if jrt.cloud_chips > 0:
            jrt.cloud_epoch += 1       # invalidate stale spot reclaims
            jrt.res = ElasticOrchestrator.apply_scale(
                jrt.res, ScaleAction("retire", reason="preempted")
            )
        self._release_elastic(jrt, before, 0, reclaimed=False)
        self._return_staged_pool(jrt)
        jrt.pending_action = None
        jrt.pending_target = 0
        self.site.release(jrt.spec.name)
        # bank the served site interval now: admit_s resets on resume,
        # so fairness accounting would otherwise lose this window
        served = jrt.spec.onprem_chips * max(self.now - jrt.admit_s, 0.0)
        jrt.site_banked_chip_s += served
        self._tenant_served[jrt.spec.tenant] = (
            self._tenant_served.get(jrt.spec.tenant, 0.0) + served
        )
        jrt.arrived = False
        jrt.queued = True
        steps_left = jrt.spec.steps_total - jrt.last_ckpt_step
        self.queue.push(QueueEntry(
            name=jrt.spec.name, tenant=jrt.spec.tenant,
            chips=jrt.spec.onprem_chips,
            work_chip_s=steps_left * jrt.spec.chip_seconds_per_step,
            enqueued_s=self.now, priority=jrt.spec.priority,
            preemptions=jrt.preemptions,
        ))
        jrt.events.append((self.now, "preempted", {
            "for": for_job, "ckpt_step": jrt.last_ckpt_step,
        }))
        self._fleet_event("preempt", {
            "victim": jrt.spec.name, "for": for_job,
            "chips": jrt.spec.onprem_chips,
        })
        self._record_timeline()

    # ---- billing ----------------------------------------------------------

    def _start_step(self, jrt: JobController,
                    extra_delay_s: float = 0.0) -> None:
        dt = jrt.session.run_step(jrt.steps_done)
        jrt.overhead_s += extra_delay_s
        self._push(self.now + extra_delay_s + dt, "step_done",
                   (jrt, jrt.step_epoch, dt))

    def _bill_cloud(self, jrt: JobController) -> None:
        chips = jrt.billable_chips
        if chips > 0:
            jrt.cloud_chip_s += chips * (self.now - jrt.cloud_since)
            jrt.cloud_since = self.now

    def _bill_pool(self) -> None:
        if self.pool_free > 0:
            self.pool_chip_s += self.pool_free * (self.now - self.pool_since)
        self.pool_since = self.now

    def _spent_usd(self) -> float:
        """Cloud $ committed so far, accrued to `now` — the number the
        global budget gate compares against (DESIGN.md §16)."""
        chip_s = self.pool_chip_s
        if self.pool_free > 0:
            chip_s += self.pool_free * (self.now - self.pool_since)
        for j in self.jobs:
            chip_s += j.cloud_chip_s
            if not j.finished and j.arrived and j.billable_chips > 0:
                chip_s += j.billable_chips * max(
                    self.now - j.cloud_since, 0.0
                )
        return self.cloud.cost(chip_s)

    def _fleet_committed(self) -> int:
        """Fleet-wide cloud footprint: chips held by or staged for ANY
        job, plus the pool (free + provisioning).  Staged pods count —
        otherwise the window between provision-complete and attach
        lets the fleet exceed its caps (DESIGN.md §16)."""
        held = sum(
            j.cloud_committed() for j in self.jobs
            if j.arrived and not j.finished
        )
        return held + self.pool_free + self.pool_pending

    def _record_timeline(self) -> None:
        total = sum(j.billable_chips for j in self.jobs if j.arrived
                    and not j.finished) + self.pool_free
        self.cloud_timeline.append((self.now, total))

    def _measured_tps(self, jrt: JobController) -> list[float]:
        """Per-pod throughput as the monitor would measure it *now*:
        nominal chips/K, derated by site contention for on-premise
        pods.  Feeds the orchestrator's γ rebalance."""
        c = self.site.contention(self.now)
        return [
            p.chips / p.slowdown
            / (c if p.name == self.site.name else 1.0)
            for p in jrt.res.pods
        ]

    # ---- checkpoint generations (DESIGN.md §19) ---------------------------

    def _save_ckpt(self, jrt: JobController, step: int, state) -> None:
        """Record one checkpoint generation.  With a fault plan active
        the write may be *silently* corrupt — nothing notices until a
        restore verifies integrity (DESIGN.md §19).  At most
        ``ckpt_keep`` generations are retained (never fewer than 2, so
        one bad write can never strand the job without a fallback)."""
        intact = True
        if jrt.faults is not None:
            intact = not jrt.faults.ckpt_corrupt()
            if not intact:
                jrt.events.append((self.now, "ckpt_corrupt", {
                    "step": step,
                }))
        jrt.ckpt_gens.append((step, state, intact))
        del jrt.ckpt_gens[:-self.ckpt_keep]
        jrt.last_ckpt = state
        jrt.last_ckpt_step = step

    def _restore_ckpt(self, jrt: JobController) -> tuple[int, object]:
        """Pick the checkpoint a rollback/resume restarts from.

        Hardened (``ckpt_integrity`` on): verify and fall back to the
        newest *intact* generation, paying the extra lost steps when
        the latest write was corrupt.  Unhardened: trust the newest
        blindly — a corrupt latest collapses the job to step 0, the
        failure mode the integrity layer exists to prevent (§19).
        """
        newest = jrt.ckpt_gens[-1]
        if self.ckpt_integrity:
            for step, state, intact in reversed(jrt.ckpt_gens):
                if intact:
                    if step != newest[0]:
                        jrt.events.append((self.now, "ckpt_fallback", {
                            "bad_step": newest[0], "resume_step": step,
                        }))
                    return step, state
            jrt.events.append((self.now, "ckpt_none_intact", {}))
            return 0, None
        step, state, intact = newest
        if not intact:
            jrt.events.append((self.now, "ckpt_restore_failed", {
                "step": step,
            }))
            return 0, None
        return step, state

    # ---- scale transitions ------------------------------------------------

    def _return_staged_pool(self, jrt: JobController) -> None:
        """Give back pool chips staged for a grow that will not attach
        (superseded or rolled back) — they must not leak."""
        if jrt.staged_from_pool > 0:
            self._bill_pool()
            self.pool_free += jrt.staged_from_pool
            self._fleet_event("pool_return", {
                "job": jrt.spec.name, "chips": jrt.staged_from_pool,
                "why": "staged grow cancelled",
            })
            jrt.staged_from_pool = 0

    def _release_elastic(self, jrt: JobController, before: int,
                         after: int, reclaimed: bool) -> None:
        """Elastic chips dropped by a shrink/retire go back to the pool
        when a fleet policy holds one (they are still paid for until it
        shrinks); chips a spot reclaim took are simply gone."""
        drop = before - after
        if drop <= 0 or reclaimed or self.fleet_policy is None:
            return
        self._bill_pool()
        self.pool_free += drop
        self._fleet_event("pool_return", {
            "job": jrt.spec.name, "chips": drop, "why": "scale down",
        })

    def _rescale(self, jrt: JobController, action: ScaleAction,
                 overhead_s: float) -> None:
        """Apply a ScaleAction at a step boundary: checkpoint, re-split
        γ, rebuild the session on the new Resources, pay the overhead.
        Shares always land on *measured* throughputs (the paper's γ from
        current conditions, not nominal chip counts)."""
        ckpt = jrt.session.checkpoint(jrt.steps_done)
        # the new session resumes from the in-memory state; corruption
        # (if drawn) poisons only the *written* generation (§19)
        self._save_ckpt(jrt, jrt.steps_done, ckpt)
        self._bill_cloud(jrt)
        before = jrt.cloud_chips
        if action.kind != "rebalance":
            jrt.res = ElasticOrchestrator.apply_scale(jrt.res, action)
        jrt.res = ElasticOrchestrator.rebalanced(
            jrt.res, self._measured_tps(jrt)
        )
        if action.kind == "grow":
            jrt.staged_from_pool = 0      # drawn chips are now attached
        self._release_elastic(jrt, before, jrt.cloud_chips,
                              reclaimed=False)
        if jrt.billable_chips > 0:
            jrt.cloud_since = self.now
        jrt.session = self._make_session(jrt, jrt.steps_done, ckpt)
        jrt.monitor.reset_window()
        jrt.events.append((self.now, "scale", {
            "kind": action.kind, "cloud_chips": jrt.cloud_chips,
            "overhead_s": overhead_s, "reason": action.reason,
        }))
        self._record_timeline()
        if action.kind == "grow" and self.cloud.spot:
            jrt.cloud_epoch += 1
            life = float(
                jrt.spot_rng.exponential(self.cloud.spot_mean_life_s)
            )
            self._push(self.now + life, "reclaim",
                       (jrt, jrt.cloud_epoch))
        self._start_step(jrt, extra_delay_s=overhead_s)

    def _rollback(self, jrt: JobController, kind: str,
                  drop_cloud: bool) -> None:
        """Fall back to the last checkpoint (spot reclaim / node
        failure): lost steps are re-run, restart overhead is paid."""
        jrt.rollbacks += 1
        jrt.step_epoch += 1
        self._bill_cloud(jrt)
        if drop_cloud:
            jrt.cloud_epoch += 1
            jrt.res = ElasticOrchestrator.apply_scale(
                jrt.res, ScaleAction("retire", reason=kind)
            )
        self._return_staged_pool(jrt)
        jrt.pending_action = None
        jrt.pending_target = 0
        resume_step, state = self._restore_ckpt(jrt)
        lost = jrt.steps_done - resume_step
        jrt.steps_done = resume_step
        jrt.session = self._make_session(jrt, resume_step, state)
        jrt.monitor.reset_window()
        restart = self.sc.overheads.restart_s
        jrt.events.append((self.now, kind, {
            "resume_step": jrt.steps_done, "cloud_chips": jrt.cloud_chips,
            "lost_steps": lost,
        }))
        self._record_timeline()
        self._start_step(jrt, extra_delay_s=restart)

    def _finish(self, jrt: JobController) -> None:
        jrt.finished = True
        jrt.finish_s = self.now
        self._bill_cloud(jrt)
        before = jrt.cloud_chips
        if jrt.cloud_chips > 0:
            jrt.res = ElasticOrchestrator.apply_scale(
                jrt.res, ScaleAction("retire", reason="job finished")
            )
        self._release_elastic(jrt, before, 0, reclaimed=False)
        self._return_staged_pool(jrt)
        if jrt.rented_chips > 0:
            # the home pod's pool chips come back for the next admit
            self._bill_pool()
            self.pool_free += jrt.rented_chips
            self._fleet_event("pool_return", {
                "job": jrt.spec.name, "chips": jrt.rented_chips,
                "why": "job finished",
            })
            jrt.rented_chips = 0
        self.site.release(jrt.spec.name)
        # bank the tenant's served time for the fair-share deficit
        self._tenant_served[jrt.spec.tenant] = (
            self._tenant_served.get(jrt.spec.tenant, 0.0)
            + jrt.spec.onprem_chips * max(self.now - jrt.admit_s, 0.0)
            + jrt.cloud_chip_s
        )
        jrt.events.append((self.now, "finish", {
            "elapsed_s": self.now - jrt.spec.arrival_s,
        }))
        self._record_timeline()
        if all(j.finished or j.rejected for j in self.jobs) \
                and self.pool_free > 0:
            self._bill_pool()
            self._fleet_event("pool_drain", {"chips": self.pool_free})
            self.pool_free = 0
            self._record_timeline()
        self._admit_pass()

    # ---- event handlers ---------------------------------------------------

    def _on_step_done(self, jrt: JobController, epoch: int,
                      dt: float) -> None:
        if jrt.finished or epoch != jrt.step_epoch:
            return
        jrt.monitor.observe(dt)
        jrt.steps_done += 1
        if jrt.steps_done % self.sc.ckpt_every == 0:
            self._save_ckpt(jrt, jrt.steps_done,
                            jrt.session.checkpoint(jrt.steps_done))
        if jrt.steps_done >= jrt.spec.steps_total:
            self._finish(jrt)
            return
        if jrt.pending_action is not None:
            action, jrt.pending_action = jrt.pending_action, None
            ov = self.sc.overheads
            # provisioning overlapped with execution; attach pays the
            # checkpoint + restart legs only (grow or shrink alike)
            self._rescale(jrt, action, ov.ckpt_s + ov.restart_s)
            return
        self._start_step(jrt)

    def _fleet_tick(self) -> None:
        """Fleet-level decision (DESIGN.md §16): size the shared pool
        toward the queue-driven policy's target footprint."""
        committed = self._fleet_committed()
        running = [
            j for j in self.jobs if j.arrived and not j.finished
        ]
        late = 0
        lateness = 0.0
        for j in running:
            est = j.predictor.estimate(
                j.monitor, j.steps_done, j.spec.steps_total,
                self.now - j.spec.arrival_s,
            )
            if est.predictable and est.slack_s < 0:
                late += 1
                lateness += -est.slack_s
        ctx = FleetContext(
            now=self.now, interval_s=self.sc.eval_interval_s,
            queue_depth=self.queue.depth,
            queued_chips=self.queue.queued_chips(),
            queued_work_chip_s=self.queue.queued_work_chip_s(),
            running=len(running), late_jobs=late, lateness_s=lateness,
            cloud_committed=committed, pool_free=self.pool_free,
            legal=list(self.cloud.legal_slices),
            site_free=self.site.free(),
            budget_left_usd=self.budget_usd - self._spent_usd(),
            price_per_chip_hour=self.cloud.price_per_chip_hour,
            cloud_slowdown=self.cloud.slowdown,
        )
        target = max(int(self.fleet_policy.target(ctx)), 0)
        if target > committed:
            grow = round_to_legal_slice(
                target - committed, self.cloud.legal_slices
            )
            grow = self._cap_grow(grow)
            if grow > 0:
                self.pool_pending += grow
                self._push(self.now + self.cloud.provision_delay_s,
                           "pool_online", (grow,))
                self._fleet_event("pool_provision_request", {
                    "chips": grow, "target": target,
                })
        elif target < committed and self.pool_free > 0:
            drop = min(self.pool_free, committed - target)
            self._bill_pool()
            self.pool_free -= drop
            self._fleet_event("pool_shrink", {"chips": drop})
            self._record_timeline()

    def _cap_grow(self, chips: int) -> int:
        """Clamp a requested provisioning increment to the global caps:
        the concurrent-chip cap (counting everything held + staged) and
        the $ budget gate (no NEW provisioning once spent)."""
        if chips <= 0:
            return 0
        if self.budget_usd != math.inf \
                and self._spent_usd() >= self.budget_usd:
            return 0
        if self.chip_cap is not None:
            headroom = self.chip_cap - self._fleet_committed()
            chips = min(chips, max(headroom, 0))
        return floor_to_legal_slice(chips, self.cloud.legal_slices)

    def _on_evaluate(self) -> None:
        if self.fleet_policy is not None:
            self._fleet_tick()
        wants: list[tuple[JobController, int, str]] = []
        for jrt in self.jobs:
            if not jrt.arrived or jrt.finished:
                continue
            elapsed = self.now - jrt.spec.arrival_s
            est = jrt.predictor.estimate(
                jrt.monitor, jrt.steps_done, jrt.spec.steps_total,
                elapsed,
            )
            ctx = ScaleContext(
                step=jrt.steps_done, steps_total=jrt.spec.steps_total,
                elapsed_s=elapsed, est=est, resources=jrt.res,
                cloud_chips=jrt.cloud_chips, planner=jrt.planner,
                monitor=jrt.monitor,
                legal=list(self.cloud.legal_slices),
                contention=self.site.contention(self.now),
                provision_failures=jrt.provision_failures,
                since_failure_s=self.now - jrt.last_failure_s,
            )
            action = jrt.policy.decide(ctx)
            wants_grow = False
            if action.kind == "grow":
                target = max(action.chips, 0)
                # chips already staged for the next step boundary count
                # as held — otherwise the window between
                # provision-complete and attach double-requests (and
                # double-pays) the same slice
                if target > max(jrt.cloud_chips, jrt.pending_target,
                                jrt.staged_grow()):
                    wants.append((jrt, target, action.reason))
                    wants_grow = True
            elif action.kind in ("shrink", "retire") \
                    and jrt.cloud_chips > 0:
                self._return_staged_pool(jrt)
                jrt.pending_action = action
                jrt.pending_target = 0
            if (
                jrt.pending_action is None
                and not wants_grow
                and len(jrt.res.pods) > 1
                and jrt.pending_target == 0
            ):
                # γ drift: conditions moved since the last split (e.g. a
                # spike cleared) — re-split on measured throughput, the
                # fleet analogue of the orchestrator's rebalance path
                want = proportional_shares(self._measured_tps(jrt))
                drift = max(
                    abs(a - b) for a, b in zip(want, jrt.res.shares)
                )
                if drift > 0.1:
                    jrt.pending_action = ScaleAction(
                        "rebalance",
                        reason=f"share drift {drift:.2f}",
                    )
        if wants:
            self._arbitrate_grows(wants)
        self._admit_pass()
        if self.scheduler is not None and len(self.queue) > 0:
            # fairness is judged where it is contested: while anyone
            # waits, sample the demand-bounded min weighted share
            self._fairness_sum += self._fairness_snapshot()
            self._fairness_n += 1
        if any(not (j.finished or j.rejected) for j in self.jobs):
            self._push(self.now + self.sc.eval_interval_s, "evaluate")

    def _arbitrate_grows(
        self, wants: list[tuple[JobController, int, str]]
    ) -> None:
        """Level-2 arbitration of this tick's per-job grow requests
        (DESIGN.md §16).  Pool chips first — a draw attaches at the
        next step boundary with NO provisioning delay, the entire point
        of pre-provisioning on queue pressure.  What the pool cannot
        cover competes for the remaining cap headroom, split max-min
        fair by tenant weight and floored to legal slices, so one
        tenant's burst cannot crowd out another's under a tight cap."""
        provisioning: list[tuple[JobController, int, str]] = []
        for jrt, target, reason in wants:
            inc = target - jrt.cloud_chips
            if (self.fleet_policy is not None and inc > 0
                    and self.pool_free >= inc):
                self._bill_pool()
                self.pool_free -= inc
                self._return_staged_pool(jrt)
                k = self.cloud.slowdown
                if jrt.faults is not None:
                    k = jrt.faults.straggler_k(k)
                    if k > self.cloud.slowdown:
                        jrt.events.append((self.now, "straggler_pod", {
                            "chips": target, "slowdown": k,
                        }))
                jrt.pending_action = ScaleAction(
                    "grow", chips=target, slowdown=k,
                    reason=f"{reason} [pool]",
                )
                jrt.staged_from_pool = inc
                jrt.pending_target = 0
                jrt.events.append((self.now, "pool_draw", {
                    "chips": inc, "target": target,
                }))
                self._fleet_event("pool_draw", {
                    "job": jrt.spec.name, "chips": inc,
                })
            else:
                provisioning.append((jrt, target, reason))
        if not provisioning:
            return
        if self.budget_usd != math.inf \
                and self._spent_usd() >= self.budget_usd:
            for jrt, target, _ in provisioning:
                jrt.events.append((self.now, "cloud_denied", {
                    "wanted": target, "why": "budget exhausted",
                }))
            return
        if self.chip_cap is None:
            granted = [t for _, t, _ in provisioning]
        else:
            headroom = max(self.chip_cap - self._fleet_committed(), 0)
            demands = [
                float(t - j.cloud_committed() + j.rented_chips)
                for j, t, _ in provisioning
            ]
            weights = [
                self.queue.tenants.get(
                    j.spec.tenant, Tenant(j.spec.tenant)
                ).weight
                for j, _, _ in provisioning
            ]
            alloc = max_min_fair_allocation(headroom, demands, weights)
            granted = []
            for (jrt, target, _), inc in zip(provisioning, alloc):
                base = jrt.cloud_committed() - jrt.rented_chips
                granted.append(
                    floor_to_legal_slice(
                        base + inc, self.cloud.legal_slices
                    )
                )
        for (jrt, target, reason), grant in zip(provisioning, granted):
            if grant > max(jrt.cloud_chips, jrt.pending_target,
                           jrt.staged_grow()):
                jrt.pending_target = grant
                self._request_provision(jrt, grant, reason)
                jrt.events.append((self.now, "provision_request", {
                    "chips": grant, "reason": reason,
                }))
            else:
                jrt.events.append((self.now, "cloud_denied", {
                    "wanted": target, "granted": grant,
                    "why": "cap headroom",
                }))

    def _request_provision(self, jrt: JobController, target: int,
                           reason: str, attempt: int = 1) -> None:
        """Issue one provisioning attempt.  The fault draw happens at
        request time (DESIGN.md §19): a denial is only *discovered*
        when the provider answers after the provisioning delay, and a
        "timeout" stretches that delay by ``provision_timeout_x``."""
        denied, delay_x = (False, 1.0)
        if jrt.faults is not None:
            denied, delay_x = jrt.faults.provision_outcome()
            if delay_x > 1.0:
                jrt.events.append((self.now, "provision_timeout", {
                    "chips": target, "attempt": attempt,
                    "delay_x": delay_x,
                }))
        self._push(
            self.now + self.cloud.provision_delay_s * delay_x,
            "provision", (jrt, target, reason, attempt, denied),
        )

    def _on_provision(self, jrt: JobController, target: int,
                      reason: str, attempt: int = 1,
                      denied: bool = False) -> None:
        if jrt.finished or jrt.pending_target != target:
            return                     # superseded or moot
        if denied:
            jrt.retries += 1
            jrt.provision_failures += 1
            jrt.last_failure_s = self.now
            jrt.events.append((self.now, "provision_denied", {
                "chips": target, "attempt": attempt,
            }))
            if (self.retry is not None
                    and attempt <= self.retry.max_retries):
                # capped exponential backoff, jitter from the job's own
                # fault stream — bit-deterministic per seed (§19)
                backoff = self.retry.backoff_s(attempt, jrt.faults.rng)
                jrt.events.append((self.now, "provision_retry", {
                    "attempt": attempt + 1, "backoff_s": backoff,
                }))
                self._push(self.now + backoff, "provision_retry",
                           (jrt, target, reason, attempt + 1))
            else:
                jrt.gave_up = True
                jrt.pending_target = 0
                jrt.events.append((self.now, "provision_gave_up", {
                    "chips": target, "attempts": attempt,
                }))
            return
        jrt.pending_target = 0
        jrt.provision_failures = 0
        self._return_staged_pool(jrt)
        # the pod's *true* K is the provider's, whatever the policy
        # believed when sizing — the sim-vs-real boundary (DESIGN.md §10)
        # ... unless the straggler draw hits and it lands degraded (§19)
        k = self.cloud.slowdown
        if jrt.faults is not None:
            k = jrt.faults.straggler_k(k)
            if k > self.cloud.slowdown:
                jrt.events.append((self.now, "straggler_pod", {
                    "chips": target, "slowdown": k,
                }))
        jrt.pending_action = ScaleAction(
            "grow", chips=target, slowdown=k, reason=reason,
        )

    def _on_pool_online(self, chips: int) -> None:
        self._bill_pool()
        self.pool_pending -= chips
        self.pool_free += chips
        self._fleet_event("pool_online", {"chips": chips})
        self._record_timeline()
        self._admit_pass()

    def _on_storm(self, p: float) -> None:
        """Correlated reclaim storm (DESIGN.md §19): at one instant the
        provider reclaims elastic capacity market-wide — every job
        holding elastic chips is hit independently with probability
        ``p`` (from its own fault stream), and the idle pool is
        reclaimed with the same probability from the fleet stream."""
        self._fleet_event("reclaim_storm", {"p": p})
        if self.pool_free > 0 \
                and float(self._storm_rng.uniform()) < p:
            self._bill_pool()
            self._fleet_event("pool_reclaimed", {
                "chips": self.pool_free,
            })
            self.pool_free = 0
            self._record_timeline()
        for jrt in self.jobs:
            if (jrt.arrived and not jrt.finished
                    and jrt.cloud_chips > 0
                    and jrt.faults.storm_hit(p)):
                self._rollback(jrt, "spot_reclaim", drop_cloud=True)

    # ---- run --------------------------------------------------------------

    def run(self, until_s: float | None = None) -> FleetRecord:
        """Run the event loop to completion, or — with ``until_s`` —
        stop the clock there and return a mid-run snapshot (billing
        accrued up to ``until_s`` on every held pod, DESIGN.md §16)."""
        for jrt in self.jobs:
            self._push(jrt.spec.arrival_s, "arrival", (jrt,))
        for t, name, new_deadline in self.sc.deadline_changes:
            self._push(t, "deadline", (name, new_deadline))
        for t, name in self.sc.failures:
            self._push(t, "fail", (name,))
        if self.faults is not None:
            for t, p in self.faults.reclaim_storms:
                self._push(t, "storm", (p,))
        first = min(
            (j.spec.arrival_s for j in self.jobs), default=0.0
        )
        self._push(first + self.sc.eval_interval_s, "evaluate")

        n_events = 0
        while self._heap:
            if until_s is not None and self._heap[0][0] > until_s:
                self.now = until_s
                break
            n_events += 1
            if n_events > _MAX_EVENTS:
                raise RuntimeError("fleet sim event budget exceeded")
            t, _, kind, payload = heapq.heappop(self._heap)
            self.now = t
            if kind == "arrival":
                self._arrive(payload[0])
            elif kind == "step_done":
                self._on_step_done(*payload)
            elif kind == "evaluate":
                self._on_evaluate()
            elif kind == "provision":
                self._on_provision(*payload)
            elif kind == "provision_retry":
                jrt, target, reason, attempt = payload
                if not jrt.finished and jrt.pending_target == target:
                    self._request_provision(jrt, target, reason, attempt)
            elif kind == "pool_online":
                self._on_pool_online(*payload)
            elif kind == "storm":
                self._on_storm(*payload)
            elif kind == "reclaim":
                jrt, epoch = payload
                if (not jrt.finished and epoch == jrt.cloud_epoch
                        and jrt.cloud_chips > 0):
                    self._rollback(jrt, "spot_reclaim", drop_cloud=True)
            elif kind == "fail":
                jrt = self._by_name(payload[0])
                if jrt is not None and jrt.arrived and not jrt.finished:
                    self._rollback(jrt, "node_failure", drop_cloud=False)
            elif kind == "deadline":
                jrt = self._by_name(payload[0])
                if jrt is not None and not jrt.finished \
                        and not jrt.rejected:
                    jrt.predictor.set_deadline(payload[1], at_s=self.now)
                    jrt.events.append((self.now, "deadline_change", {
                        "new_deadline_s": payload[1],
                    }))
        return self._record()

    def _by_name(self, name: str) -> JobController | None:
        for j in self.jobs:
            if j.spec.name == name:
                return j
        return None

    def _record(self) -> FleetRecord:
        jobs = []
        useful = 0.0
        consumed = 0.0
        for jrt in self.jobs:
            # unfinished jobs report elapsed-so-far (now − arrival), not
            # a garbage negative interval from an unset finish_s
            end = jrt.finish_s if jrt.finished else self.now
            elapsed = (
                max(end - jrt.spec.arrival_s, 0.0)
                if (jrt.arrived or jrt.queued) else 0.0
            )
            # judge against the deadline in force when the job finished
            # (deadline_changes applied later must not retro-tighten)
            deadline = jrt.predictor.deadline_at(end)
            met = jrt.finished and elapsed <= deadline
            # a mid-run snapshot must include the chip-seconds accrued
            # on EVERY currently-held pod (elastic and rented alike)
            # that _bill_cloud has not yet flushed (it only runs at
            # scale/finish/rollback events)
            cloud_s = jrt.cloud_chip_s
            if not jrt.finished and jrt.arrived \
                    and jrt.billable_chips > 0:
                cloud_s += jrt.billable_chips * max(
                    self.now - jrt.cloud_since, 0.0
                )
            cost = self.cloud.cost(cloud_s)
            wait = jrt.wait_s if jrt.arrived else (
                max(self.now - jrt.spec.arrival_s, 0.0)
                if jrt.queued else 0.0
            )
            jobs.append(JobRecord(
                name=jrt.spec.name, finished=jrt.finished,
                finish_s=jrt.finish_s, elapsed_s=elapsed,
                deadline_s=deadline, met_deadline=met,
                steps_total=jrt.spec.steps_total,
                cloud_chip_s=cloud_s, cloud_cost=cost,
                overhead_s=jrt.overhead_s, rollbacks=jrt.rollbacks,
                events=jrt.events, tenant=jrt.spec.tenant,
                state=jrt.state, wait_s=wait,
                retries=jrt.retries, gave_up=jrt.gave_up,
                preemptions=jrt.preemptions,
                renegotiated=jrt.renegotiated,
            ))
            # useful chip·s per step at the on-premise operating point
            # of the job's rate law (== chip_seconds_per_step at α = 1)
            useful += jrt.steps_done * (
                jrt.spec.chip_seconds_per_step
                / jrt.spec.onprem_chips ** (jrt.spec.scaling_alpha - 1.0)
            )
            if jrt.arrived:
                run_end = jrt.finish_s if jrt.finished else self.now
                consumed += jrt.spec.onprem_chips * max(
                    run_end - jrt.admit_s, 0.0
                ) + cloud_s
            elif jrt.preemptions > 0:
                # preempted and still queued: its cloud time was real
                consumed += cloud_s
            consumed += jrt.site_banked_chip_s
        # rejected jobs never ran: the admission control *said no*, so
        # they are excluded from the hit-rate denominator (§19)
        done = [j for j in jobs if j.state != "rejected"]
        pool_s = self.pool_chip_s
        if self.pool_free > 0:
            pool_s += self.pool_free * (self.now - self.pool_since)
        pool_cost = self.cloud.cost(pool_s)
        consumed += pool_s
        # fairness is the mean demand-bounded min weighted share over
        # the contended window (queue non-empty); with no contention
        # ever, the final snapshot (trivially 1.0 when all demand met)
        fairness = (
            self._fairness_sum / self._fairness_n
            if self._fairness_n else self._fairness_snapshot()
        )
        waits = [j.wait_s for j in jobs if j.state != "pending"]
        return FleetRecord(
            scenario=self.sc.name,
            policy=self.jobs[0].policy.name if self.jobs else "?",
            jobs=jobs,
            hit_rate=(
                sum(j.met_deadline for j in done) / len(done)
                if done else 0.0
            ),
            cloud_cost=sum(j.cloud_cost for j in jobs) + pool_cost,
            useful_frac=(
                min(useful / consumed, 1.0) if consumed > 0 else 0.0
            ),
            cloud_timeline=self.cloud_timeline,
            makespan_s=max(
                (j.finish_s for j in jobs if j.finished), default=0.0
            ),
            scheduler=(
                self.scheduler.name if self.scheduler else "immediate"
            ),
            fleet_policy=(
                self.fleet_policy.name if self.fleet_policy else "none"
            ),
            fairness=fairness,
            mean_wait_s=(sum(waits) / len(waits)) if waits else 0.0,
            max_wait_s=max(waits, default=0.0),
            queued_at_end=sum(j.state == "queued" for j in jobs),
            pool_cost=pool_cost,
            fleet_events=self.fleet_events,
        )


class FleetSim(FleetController):
    """PR-2 name for the fleet event loop, kept for every existing
    caller: ``FleetSim(scenario, policy_factory, seed=...)`` behaves
    exactly as before for scenarios that keep the default
    ``scheduler="immediate"`` (no queue, no pool, no caps)."""
