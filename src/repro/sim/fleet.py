"""Discrete-event hybrid-fleet simulator (DESIGN.md §11).

The paper evaluates one job bursting once from one loaded cluster.  This
module drives the *same single-job decision code* — StepTimeMonitor,
DeadlinePredictor, BurstPlanner, SimSession, the orchestrator's
apply_scale γ re-split — at fleet scale:

  Site           on-premise capacity; foreground jobs plus background
                 tenant arrivals create demand, and the "cluster
                 overloaded" condition is *emergent* contention
                 (demand / capacity), not a scripted SlowdownWindow
  CloudProvider  elastic capacity with provisioning delay, per-chip-hour
                 price, legal slice shapes, optional spot reclaims
  FleetSim       event loop (heapq, virtual clock): job arrivals, step
                 completions, fixed-interval autoscaler evaluation,
                 provision-complete attachment, spot reclaims, node
                 failures, mid-run deadline changes

Per job, the policy's ScaleAction takes effect at the next step boundary
through CHECKPOINT → REMESH → RESHARD → RESUME, exactly like the
orchestrator's burst path: grow pays the full overhead chain (minus
provisioning, which overlaps with execution in the fleet), shrink/retire
pay checkpoint + restart.  Reclaims and failures roll the job back to
its last checkpoint.  All randomness flows from per-job seeded
Generators, so runs are bit-deterministic for a given (scenario, policy,
seed) triple.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable

import numpy as np

from repro.core import (
    BurstPlanner,
    DeadlinePredictor,
    ElasticOrchestrator,
    LogCapacityModel,
    PodSpec,
    Resources,
    ScaleAction,
    ScaleContext,
    StepTimeMonitor,
    elastic_chips,
    proportional_shares,
)
from repro.core.events import BackgroundLoad
from repro.core.orchestrator import AutoscalerPolicy
from repro.core.sim_session import SimSession, SimWorkload

__all__ = [
    "CloudProvider",
    "FleetRecord",
    "FleetSim",
    "JobRecord",
    "JobSpec",
    "Site",
]

_MAX_EVENTS = 2_000_000


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One foreground scientific job (the paper's FWI analogue)."""

    name: str
    arrival_s: float
    steps_total: int
    deadline_s: float                 # relative to arrival
    chip_seconds_per_step: float      # work per step (chip·s)
    onprem_chips: int
    jitter: float = 0.01
    #: rate-law exponent t_step ∝ 1 / chips**alpha (SimWorkload docs);
    #: the per-job capacity models are fitted on the same law, so the
    #: paper's pre-processing fit stays exact
    scaling_alpha: float = 1.0


class Site:
    """On-premise cluster: finite chips shared by foreground jobs and
    background tenants.  Oversubscription slows every on-premise pod by
    demand/capacity — the organic version of the paper's congestion."""

    def __init__(self, chips: int, name: str = "site"):
        self.chips = chips
        self.name = name
        self._fg_chips: dict[str, int] = {}
        self.background: tuple[BackgroundLoad, ...] = ()

    def attach(self, job: str, chips: int) -> None:
        self._fg_chips[job] = chips

    def release(self, job: str) -> None:
        self._fg_chips.pop(job, None)

    def demand(self, t: float) -> int:
        bg = sum(
            b.chips for b in self.background if b.start_s <= t < b.end_s
        )
        return sum(self._fg_chips.values()) + bg

    def contention(self, t: float) -> float:
        return max(1.0, self.demand(t) / self.chips)


@dataclasses.dataclass(frozen=True)
class CloudProvider:
    """Elastic environment: what the paper calls "the cloud"."""

    legal_slices: tuple[int, ...] = (16, 32, 64, 128, 256)
    provision_delay_s: float = 90.0
    price_per_chip_hour: float = 3.0
    slowdown: float = 1.4             # paper's K per cloud chip
    spot: bool = False
    spot_mean_life_s: float = 1800.0

    def cost(self, chip_seconds: float) -> float:
        return chip_seconds / 3600.0 * self.price_per_chip_hour


@dataclasses.dataclass
class JobRecord:
    name: str
    finished: bool
    finish_s: float
    elapsed_s: float
    deadline_s: float
    met_deadline: bool
    steps_total: int
    cloud_chip_s: float
    cloud_cost: float
    overhead_s: float
    rollbacks: int
    events: list[tuple[float, str, dict]]


@dataclasses.dataclass
class FleetRecord:
    scenario: str
    policy: str
    jobs: list[JobRecord]
    hit_rate: float
    cloud_cost: float
    useful_frac: float
    cloud_timeline: list[tuple[float, int]]   # (t, fleet cloud chips)
    makespan_s: float


class _JobRt:
    """Mutable per-job runtime the event handlers share."""

    def __init__(self, spec: JobSpec, policy: AutoscalerPolicy):
        self.spec = spec
        self.policy = policy
        self.res: Resources | None = None
        self.session: SimSession | None = None
        self.monitor = StepTimeMonitor()
        self.predictor = DeadlinePredictor(spec.deadline_s)
        self.planner: BurstPlanner | None = None
        self.rng: np.random.Generator | None = None
        self.spot_rng: np.random.Generator | None = None
        self.steps_done = 0
        self.last_ckpt = None
        self.last_ckpt_step = 0
        self.arrived = False
        self.finished = False
        self.finish_s = 0.0
        self.step_epoch = 0           # invalidates in-flight step events
        self.cloud_epoch = 0          # invalidates stale spot reclaims
        self.pending_action: ScaleAction | None = None
        self.pending_target = 0       # chips requested, not yet online
        self.cloud_since = 0.0
        self.cloud_chip_s = 0.0
        self.overhead_s = 0.0
        self.rollbacks = 0
        self.events: list[tuple[float, str, dict]] = []

    @property
    def cloud_chips(self) -> int:
        return elastic_chips(self.res) if self.res else 0


class FleetSim:
    """Event-driven multi-job run of one scenario under one policy."""

    def __init__(
        self,
        scenario,                      # scenarios.Scenario
        policy_factory: Callable[[], AutoscalerPolicy],
        *,
        seed: int = 0,
    ):
        self.sc = scenario
        self.site = Site(scenario.site_chips)
        self.site.background = tuple(scenario.background)
        self.cloud: CloudProvider = scenario.cloud
        self.seed = seed
        self.now = 0.0
        self._seq = 0
        self._heap: list[tuple[float, int, str, tuple]] = []
        self.jobs = [
            _JobRt(spec, policy_factory()) for spec in scenario.jobs
        ]
        self.cloud_timeline: list[tuple[float, int]] = [(0.0, 0)]

    # ---- event plumbing ---------------------------------------------------

    def _push(self, t: float, kind: str, payload: tuple = ()) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, payload))

    # ---- job lifecycle ----------------------------------------------------

    def _make_session(self, jrt: _JobRt, start_step: int,
                      restored) -> SimSession:
        def contention_slowdown(i: int, step: int, jrt=jrt) -> float:
            pod = jrt.res.pods[i]
            if pod.name == self.site.name:
                return self.site.contention(self.now)
            return 1.0

        return SimSession(
            SimWorkload(jrt.spec.chip_seconds_per_step, jrt.spec.jitter,
                        scaling_alpha=jrt.spec.scaling_alpha),
            jrt.res, start_step, restored,
            rng=jrt.rng,
            extra_slowdown=contention_slowdown,
        )

    def _arrive(self, jrt: _JobRt) -> None:
        spec = jrt.spec
        idx = self.jobs.index(jrt)
        jrt.rng = np.random.default_rng([self.seed, idx])
        jrt.spot_rng = np.random.default_rng([self.seed, idx, 1])
        jrt.res = Resources(
            pods=[PodSpec(spec.onprem_chips, name=self.site.name)],
            shares=[1.0],
        )
        # per-job capacity models from the workload's own scaling law
        # (t = W/c), cloud curve K× above — the paper's pre-processing
        # fit, done analytically since the simulated law is known
        cs = sorted(set(self.cloud.legal_slices)
                    | {spec.onprem_chips})
        w = spec.chip_seconds_per_step
        a = spec.scaling_alpha
        jrt.planner = BurstPlanner(
            cluster_model=LogCapacityModel.fit(
                cs, [w / c ** a for c in cs], name="site"),
            cloud_model=LogCapacityModel.fit(
                cs, [self.cloud.slowdown * w / c ** a for c in cs],
                name="cloud"),
            chips_cluster=spec.onprem_chips,
            legal_slices=self.cloud.legal_slices,
            overheads=self.sc.overheads,
            price_per_chip_hour=self.cloud.price_per_chip_hour,
            cost_weight=self.sc.planner_cost_weight,
        )
        self.site.attach(spec.name, spec.onprem_chips)
        jrt.session = self._make_session(jrt, 0, None)
        jrt.arrived = True
        jrt.events.append((self.now, "arrival", {}))
        self._start_step(jrt)

    def _start_step(self, jrt: _JobRt, extra_delay_s: float = 0.0) -> None:
        dt = jrt.session.run_step(jrt.steps_done)
        jrt.overhead_s += extra_delay_s
        self._push(self.now + extra_delay_s + dt, "step_done",
                   (jrt, jrt.step_epoch, dt))

    def _bill_cloud(self, jrt: _JobRt) -> None:
        chips = jrt.cloud_chips
        if chips > 0:
            jrt.cloud_chip_s += chips * (self.now - jrt.cloud_since)
            jrt.cloud_since = self.now

    def _record_timeline(self) -> None:
        total = sum(j.cloud_chips for j in self.jobs if j.arrived
                    and not j.finished)
        self.cloud_timeline.append((self.now, total))

    def _measured_tps(self, jrt: _JobRt) -> list[float]:
        """Per-pod throughput as the monitor would measure it *now*:
        nominal chips/K, derated by site contention for on-premise
        pods.  Feeds the orchestrator's γ rebalance."""
        c = self.site.contention(self.now)
        return [
            p.chips / p.slowdown
            / (c if p.name == self.site.name else 1.0)
            for p in jrt.res.pods
        ]

    def _rescale(self, jrt: _JobRt, action: ScaleAction,
                 overhead_s: float) -> None:
        """Apply a ScaleAction at a step boundary: checkpoint, re-split
        γ, rebuild the session on the new Resources, pay the overhead.
        Shares always land on *measured* throughputs (the paper's γ from
        current conditions, not nominal chip counts)."""
        ckpt = jrt.session.checkpoint(jrt.steps_done)
        jrt.last_ckpt = ckpt
        jrt.last_ckpt_step = jrt.steps_done
        self._bill_cloud(jrt)
        if action.kind != "rebalance":
            jrt.res = ElasticOrchestrator.apply_scale(jrt.res, action)
        jrt.res = ElasticOrchestrator.rebalanced(
            jrt.res, self._measured_tps(jrt)
        )
        if jrt.cloud_chips > 0:
            jrt.cloud_since = self.now
        jrt.session = self._make_session(jrt, jrt.steps_done, ckpt)
        jrt.monitor.reset_window()
        jrt.events.append((self.now, "scale", {
            "kind": action.kind, "cloud_chips": jrt.cloud_chips,
            "overhead_s": overhead_s, "reason": action.reason,
        }))
        self._record_timeline()
        if action.kind == "grow" and self.cloud.spot:
            jrt.cloud_epoch += 1
            life = float(
                jrt.spot_rng.exponential(self.cloud.spot_mean_life_s)
            )
            self._push(self.now + life, "reclaim",
                       (jrt, jrt.cloud_epoch))
        self._start_step(jrt, extra_delay_s=overhead_s)

    def _rollback(self, jrt: _JobRt, kind: str, drop_cloud: bool) -> None:
        """Fall back to the last checkpoint (spot reclaim / node
        failure): lost steps are re-run, restart overhead is paid."""
        jrt.rollbacks += 1
        jrt.step_epoch += 1
        self._bill_cloud(jrt)
        if drop_cloud:
            jrt.cloud_epoch += 1
            jrt.res = ElasticOrchestrator.apply_scale(
                jrt.res, ScaleAction("retire", reason=kind)
            )
        jrt.pending_action = None
        jrt.pending_target = 0
        jrt.steps_done = jrt.last_ckpt_step
        jrt.session = self._make_session(
            jrt, jrt.last_ckpt_step, jrt.last_ckpt
        )
        jrt.monitor.reset_window()
        restart = self.sc.overheads.restart_s
        jrt.events.append((self.now, kind, {
            "resume_step": jrt.steps_done, "cloud_chips": jrt.cloud_chips,
        }))
        self._record_timeline()
        self._start_step(jrt, extra_delay_s=restart)

    def _finish(self, jrt: _JobRt) -> None:
        jrt.finished = True
        jrt.finish_s = self.now
        self._bill_cloud(jrt)
        if jrt.cloud_chips > 0:
            jrt.res = ElasticOrchestrator.apply_scale(
                jrt.res, ScaleAction("retire", reason="job finished")
            )
        self.site.release(jrt.spec.name)
        jrt.events.append((self.now, "finish", {
            "elapsed_s": self.now - jrt.spec.arrival_s,
        }))
        self._record_timeline()

    # ---- event handlers ---------------------------------------------------

    def _on_step_done(self, jrt: _JobRt, epoch: int, dt: float) -> None:
        if jrt.finished or epoch != jrt.step_epoch:
            return
        jrt.monitor.observe(dt)
        jrt.steps_done += 1
        if jrt.steps_done % self.sc.ckpt_every == 0:
            jrt.last_ckpt = jrt.session.checkpoint(jrt.steps_done)
            jrt.last_ckpt_step = jrt.steps_done
        if jrt.steps_done >= jrt.spec.steps_total:
            self._finish(jrt)
            return
        if jrt.pending_action is not None:
            action, jrt.pending_action = jrt.pending_action, None
            ov = self.sc.overheads
            # provisioning overlapped with execution; attach pays the
            # checkpoint + restart legs only (grow or shrink alike)
            self._rescale(jrt, action, ov.ckpt_s + ov.restart_s)
            return
        self._start_step(jrt)

    def _on_evaluate(self) -> None:
        for jrt in self.jobs:
            if not jrt.arrived or jrt.finished:
                continue
            elapsed = self.now - jrt.spec.arrival_s
            est = jrt.predictor.estimate(
                jrt.monitor, jrt.steps_done, jrt.spec.steps_total,
                elapsed,
            )
            ctx = ScaleContext(
                step=jrt.steps_done, steps_total=jrt.spec.steps_total,
                elapsed_s=elapsed, est=est, resources=jrt.res,
                cloud_chips=jrt.cloud_chips, planner=jrt.planner,
                monitor=jrt.monitor,
                legal=list(self.cloud.legal_slices),
                contention=self.site.contention(self.now),
            )
            action = jrt.policy.decide(ctx)
            if action.kind == "grow":
                target = max(action.chips, 0)
                # chips already staged for the next step boundary count
                # as held — otherwise the window between
                # provision-complete and attach double-requests (and
                # double-pays) the same slice
                staged = (
                    jrt.pending_action.chips
                    if (jrt.pending_action is not None
                        and jrt.pending_action.kind == "grow") else 0
                )
                if target > max(jrt.cloud_chips, jrt.pending_target,
                                staged):
                    jrt.pending_target = target
                    self._push(
                        self.now + self.cloud.provision_delay_s,
                        "provision", (jrt, target, action.reason),
                    )
                    jrt.events.append((self.now, "provision_request", {
                        "chips": target, "reason": action.reason,
                    }))
            elif action.kind in ("shrink", "retire") \
                    and jrt.cloud_chips > 0:
                jrt.pending_action = action
                jrt.pending_target = 0
            if (
                jrt.pending_action is None
                and len(jrt.res.pods) > 1
                and jrt.pending_target == 0
            ):
                # γ drift: conditions moved since the last split (e.g. a
                # spike cleared) — re-split on measured throughput, the
                # fleet analogue of the orchestrator's rebalance path
                want = proportional_shares(self._measured_tps(jrt))
                drift = max(
                    abs(a - b) for a, b in zip(want, jrt.res.shares)
                )
                if drift > 0.1:
                    jrt.pending_action = ScaleAction(
                        "rebalance",
                        reason=f"share drift {drift:.2f}",
                    )
        if any(not j.finished for j in self.jobs):
            self._push(self.now + self.sc.eval_interval_s, "evaluate")

    def _on_provision(self, jrt: _JobRt, target: int,
                      reason: str) -> None:
        if jrt.finished or jrt.pending_target != target:
            return                     # superseded or moot
        jrt.pending_target = 0
        # the pod's *true* K is the provider's, whatever the policy
        # believed when sizing — the sim-vs-real boundary (DESIGN.md §10)
        jrt.pending_action = ScaleAction(
            "grow", chips=target, slowdown=self.cloud.slowdown,
            reason=reason,
        )

    # ---- run --------------------------------------------------------------

    def run(self) -> FleetRecord:
        for jrt in self.jobs:
            self._push(jrt.spec.arrival_s, "arrival", (jrt,))
        for t, name, new_deadline in self.sc.deadline_changes:
            self._push(t, "deadline", (name, new_deadline))
        for t, name in self.sc.failures:
            self._push(t, "fail", (name,))
        first = min(
            (j.spec.arrival_s for j in self.jobs), default=0.0
        )
        self._push(first + self.sc.eval_interval_s, "evaluate")

        n_events = 0
        while self._heap:
            n_events += 1
            if n_events > _MAX_EVENTS:
                raise RuntimeError("fleet sim event budget exceeded")
            t, _, kind, payload = heapq.heappop(self._heap)
            self.now = t
            if kind == "arrival":
                self._arrive(payload[0])
            elif kind == "step_done":
                self._on_step_done(*payload)
            elif kind == "evaluate":
                self._on_evaluate()
            elif kind == "provision":
                self._on_provision(*payload)
            elif kind == "reclaim":
                jrt, epoch = payload
                if (not jrt.finished and epoch == jrt.cloud_epoch
                        and jrt.cloud_chips > 0):
                    self._rollback(jrt, "spot_reclaim", drop_cloud=True)
            elif kind == "fail":
                jrt = self._by_name(payload[0])
                if jrt is not None and jrt.arrived and not jrt.finished:
                    self._rollback(jrt, "node_failure", drop_cloud=False)
            elif kind == "deadline":
                jrt = self._by_name(payload[0])
                if jrt is not None and not jrt.finished:
                    jrt.predictor.set_deadline(payload[1], at_s=self.now)
                    jrt.events.append((self.now, "deadline_change", {
                        "new_deadline_s": payload[1],
                    }))
        return self._record()

    def _by_name(self, name: str) -> _JobRt | None:
        for j in self.jobs:
            if j.spec.name == name:
                return j
        return None

    def _record(self) -> FleetRecord:
        jobs = []
        useful = 0.0
        consumed = 0.0
        for jrt in self.jobs:
            # unfinished jobs report elapsed-so-far (now − arrival), not
            # a garbage negative interval from an unset finish_s
            end = jrt.finish_s if jrt.finished else self.now
            elapsed = (
                max(end - jrt.spec.arrival_s, 0.0) if jrt.arrived else 0.0
            )
            # judge against the deadline in force when the job finished
            # (deadline_changes applied later must not retro-tighten)
            deadline = jrt.predictor.deadline_at(end)
            met = jrt.finished and elapsed <= deadline
            # a mid-run snapshot must include the chip-seconds accrued
            # on a currently-held pod that _bill_cloud has not yet
            # flushed (it only runs at scale/finish/rollback events)
            cloud_s = jrt.cloud_chip_s
            if not jrt.finished and jrt.arrived and jrt.cloud_chips > 0:
                cloud_s += jrt.cloud_chips * max(
                    self.now - jrt.cloud_since, 0.0
                )
            cost = self.cloud.cost(cloud_s)
            jobs.append(JobRecord(
                name=jrt.spec.name, finished=jrt.finished,
                finish_s=jrt.finish_s, elapsed_s=elapsed,
                deadline_s=deadline, met_deadline=met,
                steps_total=jrt.spec.steps_total,
                cloud_chip_s=cloud_s, cloud_cost=cost,
                overhead_s=jrt.overhead_s, rollbacks=jrt.rollbacks,
                events=jrt.events,
            ))
            # useful chip·s per step at the on-premise operating point
            # of the job's rate law (== chip_seconds_per_step at α = 1)
            useful += jrt.steps_done * (
                jrt.spec.chip_seconds_per_step
                / jrt.spec.onprem_chips ** (jrt.spec.scaling_alpha - 1.0)
            )
            consumed += jrt.spec.onprem_chips * elapsed + cloud_s
        done = [j for j in jobs]
        return FleetRecord(
            scenario=self.sc.name,
            policy=self.jobs[0].policy.name if self.jobs else "?",
            jobs=jobs,
            hit_rate=(
                sum(j.met_deadline for j in done) / len(done)
                if done else 0.0
            ),
            cloud_cost=sum(j.cloud_cost for j in jobs),
            useful_frac=(
                min(useful / consumed, 1.0) if consumed > 0 else 0.0
            ),
            cloud_timeline=self.cloud_timeline,
            makespan_s=max(
                (j.finish_s for j in jobs if j.finished), default=0.0
            ),
        )
