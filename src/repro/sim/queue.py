"""Central job queue with fair-share + priority ordering (DESIGN.md §16).

The paper manages ONE application's deadline; production scale means a
*stream* of FWI sessions from many users competing for one hybrid
fleet.  This module is the admission side of that problem, in the shape
of VM-MAD's queue-driven cluster expansion (arXiv:1302.2529) and the
SLA-advisor's placement-across-jobs view (arXiv:1507.05472):

  Tenant         a user/group with a fair-share ``weight`` and a
                 ``priority`` tie-break; zero-weight tenants only run
                 when nobody else wants the chips
  QueueEntry     one job waiting for placement (chips requested,
                 remaining work, enqueue time, skip count)
  CentralQueue   the queue itself; ``order()`` ranks waiting entries by
                 weighted fair-share deficit — the tenant whose served
                 usage per unit weight is lowest goes first — then
                 priority, then arrival

The queue only *orders*; which ordered entry is admitted where is the
Scheduler's placement call (repro.sim.schedulers), and the starvation
guard — nobody may be admitted past a patience-expired head entry — is
enforced once, in the FleetController's admission pass, so every
scheduler inherits it (DESIGN.md §16).
"""
from __future__ import annotations

import dataclasses

__all__ = [
    "CentralQueue",
    "QueueEntry",
    "Tenant",
    "tenants_for",
]


@dataclasses.dataclass(frozen=True)
class Tenant:
    """A user/group competing for the fleet.

    ``weight`` is the fair-share entitlement (usage is normalized by it
    when ranking); ``priority`` breaks deficit ties, higher first.  A
    weight of 0 marks a scavenger tenant: it is ranked after every
    positive-weight tenant and the starvation guard does not protect it.
    """

    name: str
    weight: float = 1.0
    priority: float = 0.0


@dataclasses.dataclass
class QueueEntry:
    """One job waiting in the central queue."""

    name: str
    tenant: str
    chips: int                     # on-premise-equivalent chips requested
    work_chip_s: float             # total remaining work (chip·seconds)
    enqueued_s: float
    priority: float = 0.0          # per-job boost on top of the tenant's
    skips: int = 0                 # admission passes that overtook it
    preemptions: int = 0           # times checkpointed off the site (§19)

    def wait_s(self, now: float) -> float:
        return max(now - self.enqueued_s, 0.0)


def tenants_for(names, declared: tuple[Tenant, ...] = ()) -> dict[str, Tenant]:
    """Tenant table for a job stream: declared tenants win; any tenant
    name that appears only on jobs gets a default weight-1 entry."""
    table = {t.name: t for t in declared}
    for n in names:
        table.setdefault(n, Tenant(name=n))
    return table


class CentralQueue:
    """FIFO-arrival queue ranked by weighted fair-share deficit.

    The ranking key for an entry of tenant T is
    ``(usage[T] / weight[T], -priority, enqueued_s, name)``: the tenant
    that has consumed the least site time per unit weight goes first —
    the deficit form of weighted fair queueing the HPC fair-share
    schedulers (SLURM multifactor, OpenDC's CentralQueue) use.  Usage
    is supplied by the caller (the FleetController meters served
    chip·seconds per tenant), so the queue itself stays stateless about
    history and trivially deterministic.
    """

    def __init__(self, tenants: dict[str, Tenant] | None = None):
        self.tenants = dict(tenants or {})
        self._entries: dict[str, QueueEntry] = {}

    # ---- membership -------------------------------------------------------

    def push(self, entry: QueueEntry) -> None:
        if entry.name in self._entries:
            raise ValueError(f"job {entry.name!r} already queued")
        self.tenants.setdefault(entry.tenant, Tenant(name=entry.tenant))
        self._entries[entry.name] = entry

    def remove(self, name: str) -> QueueEntry:
        return self._entries.pop(name)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    @property
    def depth(self) -> int:
        return len(self._entries)

    def queued_chips(self) -> int:
        return sum(e.chips for e in self._entries.values())

    def queued_work_chip_s(self) -> float:
        return sum(e.work_chip_s for e in self._entries.values())

    # ---- ordering ---------------------------------------------------------

    def _rank(self, e: QueueEntry, usage: dict[str, float]):
        t = self.tenants.get(e.tenant, Tenant(name=e.tenant))
        if t.weight > 0:
            deficit = usage.get(e.tenant, 0.0) / t.weight
            scavenger = 0
        else:
            deficit = 0.0
            scavenger = 1                  # after every weighted tenant
        return (
            scavenger, deficit, -(t.priority + e.priority),
            e.enqueued_s, e.name,
        )

    def order(self, usage: dict[str, float] | None = None) -> list[QueueEntry]:
        """Waiting entries, most-deserving first.  ``usage`` maps tenant
        name -> served chip·seconds so far (missing = 0)."""
        usage = usage or {}
        return sorted(
            self._entries.values(), key=lambda e: self._rank(e, usage)
        )
