"""Fault model for the hybrid-fleet simulator (DESIGN.md §19).

The paper's premise is meeting deadlines on an *unreliable* substrate,
yet a simulator in which provisioning always succeeds and checkpoints
are always intact only exercises the happy path.  This module is the
seeded fault layer the hardened elastic loop is scored against:

  FaultPlan      declarative fault mix for a scenario — provisioning
                 denials and slow-provision "timeouts", correlated
                 spot-reclaim storms, silent checkpoint-write
                 corruption, straggler pods landing with a degraded K
  RetryPolicy    capped exponential backoff with jitter for
                 provisioning retries; the jitter draw comes from a
                 seeded Generator the caller supplies, so a retried
                 run stays bit-deterministic per (scenario, seed)
  FaultInjector  one job's draw source: every probabilistic fault is
                 drawn from a per-job ``default_rng([seed, idx, 7])``
                 stream, independent of other jobs and of the step /
                 spot-life streams, so adding faults to one job never
                 perturbs another's trajectory

Determinism contract (DESIGN.md §19): all draws flow from seeded
per-job streams in event-loop order; the module holds no wall-clock,
no global RNG, and no set/dict iteration — the ``sim-determinism``
lint rule gates on it like the rest of ``repro/sim``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "FaultInjector",
    "FaultPlan",
    "RetryPolicy",
]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative fault mix injected into a FleetSim run.

    Probabilities are per-draw: ``provision_fail_p`` per provisioning
    attempt, ``ckpt_corrupt_p`` per checkpoint write, ``straggler_p``
    per pod attach.  ``reclaim_storms`` are correlated events: at each
    ``(t_s, p)`` every job holding elastic chips is reclaimed with
    probability ``p`` *at the same instant* — the market-wide capacity
    crunch independent per-job spot lifetimes cannot model.
    """

    #: per-attempt probability a provisioning request is denied
    provision_fail_p: float = 0.0
    #: per-attempt probability provisioning is slow ("timeout"): the
    #: provider still delivers, after ``provision_timeout_x`` × delay
    provision_timeout_p: float = 0.0
    provision_timeout_x: float = 4.0
    #: correlated reclaim storms: tuple of (t_s, per-job hit probability)
    reclaim_storms: tuple[tuple[float, float], ...] = ()
    #: per-save probability a written checkpoint is silently corrupt
    ckpt_corrupt_p: float = 0.0
    #: per-attach probability a grown pod is a straggler whose true K is
    #: ``straggler_x`` × the provider's nominal slowdown
    straggler_p: float = 0.0
    straggler_x: float = 3.0

    def any_faults(self) -> bool:
        return bool(
            self.provision_fail_p > 0.0
            or self.provision_timeout_p > 0.0
            or self.reclaim_storms
            or self.ckpt_corrupt_p > 0.0
            or self.straggler_p > 0.0
        )


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff + jitter for provisioning retries
    (DESIGN.md §19).

    Attempt ``k`` (1-based) that fails waits
    ``min(base_s * mult**(k-1), cap_s) * (1 + jitter_frac * U)`` before
    re-requesting, with ``U ~ Uniform[0, 1)`` drawn from the caller's
    seeded Generator — jitter de-synchronizes a fleet of retriers
    without breaking per-seed bit-determinism.  ``max_retries`` bounds
    the re-requests after the first attempt; exhaustion is surfaced as
    ``gave_up`` on the run record.
    """

    max_retries: int = 4
    base_s: float = 5.0
    mult: float = 2.0
    cap_s: float = 120.0
    jitter_frac: float = 0.1

    def backoff_s(self, attempt: int, rng: np.random.Generator) -> float:
        """Backoff before re-attempting after failed attempt ``attempt``
        (1-based).  Always consumes exactly one draw from ``rng`` so the
        stream position is attempt-count deterministic."""
        u = float(rng.uniform())
        base = min(self.base_s * self.mult ** max(attempt - 1, 0),
                   self.cap_s)
        return base * (1.0 + self.jitter_frac * u)


class FaultInjector:
    """Seeded per-job draw source for one :class:`FaultPlan`.

    All of a job's fault draws come from one dedicated
    ``default_rng([seed, job_index, 7])`` stream (DESIGN.md §19) —
    disjoint from the step-jitter (``[seed, idx]``) and spot-lifetime
    (``[seed, idx, 1]``) streams — so enabling faults never shifts the
    draws an existing scenario already consumes, and each fault draw
    happens at a deterministic point of the event loop.
    """

    def __init__(self, plan: FaultPlan, seed: int, job_index: int):
        self.plan = plan
        self.rng = np.random.default_rng([seed, job_index, 7])

    def provision_outcome(self) -> tuple[bool, float]:
        """One provisioning attempt: ``(denied, delay_multiplier)``.

        Both draws always happen (even when their probabilities are 0)
        so the stream position per attempt is fixed regardless of the
        plan's parameters.
        """
        denied = float(self.rng.uniform()) < self.plan.provision_fail_p
        slow = float(self.rng.uniform()) < self.plan.provision_timeout_p
        return denied, (self.plan.provision_timeout_x if slow else 1.0)

    def ckpt_corrupt(self) -> bool:
        """Draw whether this checkpoint write is silently corrupt."""
        return float(self.rng.uniform()) < self.plan.ckpt_corrupt_p

    def straggler_k(self, nominal_slowdown: float) -> float:
        """The true K of a freshly attached pod: nominal, or degraded
        by ``straggler_x`` when the straggler draw hits."""
        if float(self.rng.uniform()) < self.plan.straggler_p:
            return nominal_slowdown * self.plan.straggler_x
        return nominal_slowdown

    def storm_hit(self, p: float) -> bool:
        """Per-job draw for one correlated reclaim storm."""
        return float(self.rng.uniform()) < p
