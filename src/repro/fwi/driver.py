"""Self-adaptive FWI driver — the paper end-to-end on the real solver.

An FWISession runs the striped sharded solver over the current stripe
count and emulates the slower burst environment by stretching the
measured time with the configured K for the share of stripes placed
there (per-step synchronization means the step takes the slowest
environment's time — paper step 8).  The ElasticOrchestrator drives
monitoring → prediction → burst exactly as for LM training;
CHECKPOINT/RESHARD are real: fields are pulled to host and re-placed
under the new stripe mesh.

Measurement is AMORTIZED over a scan block: the session dispatches one
jitted ``make_sharded_scan_runner`` call covering ``scan_block``
timesteps (temporally blocked at ``exchange_interval`` steps per halo
exchange) and reports wall/steps for each logical step inside the block.
Single-step dispatch timings on the seed were dominated by Python/jit
dispatch, not solver time — exactly the overhead the scan-fused engine
removes.  Model arrays and compiled runners are memoized (solver.py /
domain.py lru_caches), so a RESHARD rebuild re-traces nothing that was
already compiled for an equal mesh.
"""
from __future__ import annotations

import dataclasses
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import (
    CheckpointManager,
    install_preemption_hook,
)
from repro.core.orchestrator import Resources, Session, elastic_chips
from repro.fwi.domain import (
    effective_block,
    make_sharded_scan_runner,
    stripe_mesh,
)
from repro.fwi.solver import FWIConfig, ShotState
from repro.kernels.stencil.ops import autotune_bz_k, pick_bz_block, pick_k


@dataclasses.dataclass
class TimeModel:
    """How a step's wall time is derived (DESIGN.md §10).

    measure=True: real wall clock of the sharded solver on this host,
    scaled by the *modeled* parallel speedup (CPU has one core; stripes
    over host devices don't speed up wall time) and stretched by the
    burst environment's K on its work share.
    """

    chip_seconds_per_step: float | None = None  # None -> measure
    congestion: dict[int, float] = dataclasses.field(default_factory=dict)
    congestion_until: int = 10 ** 9
    congestion_from: int = 0
    congestion_factor: float = 1.0
    jitter: float = 0.01
    #: platform-model rate-law exponent (t ∝ 1/chips**alpha), matching
    #: SimWorkload.scaling_alpha so the sim-vs-real harness can run the
    #: same scenario through both worlds (DESIGN.md §14)
    scaling_alpha: float = 1.0


class FWISession(Session):
    def __init__(
        self,
        cfg: FWIConfig,
        res: Resources,
        start_step: int,
        restored,
        *,
        time_model: TimeModel,
        rng: np.random.Generator,
        n_stripes: int | None = None,
        exchange_interval: int | None = 4,
        scan_block: int = 8,
        use_pallas: bool = False,
        autotune: bool = False,
    ):
        self.cfg = cfg
        self.res = res
        self.tm = time_model
        self.rng = rng
        n = n_stripes or min(len(jax.devices()), max(res.total_chips, 1))
        while cfg.nx % n:
            n -= 1
        self.mesh = stripe_mesh(n)
        bz = None
        if autotune and use_pallas:
            # joint (strip height, block length) tuned at the PER-STRIPE
            # width the engine actually runs (not the global NX);
            # memoized per (shape, backend) so a RESHARD rebuild does
            # not re-time.  If the stripe clamp shrinks the tuned k,
            # re-derive bz for the clamped k instead of keeping the
            # strip that won jointly with the larger one.
            bz, exchange_interval = autotune_bz_k(cfg.nz, cfg.nx // n)
            keff = effective_block(cfg, n, exchange_interval)
            if keff != exchange_interval:
                exchange_interval = keff
                bz = pick_bz_block(cfg.nz, keff)
        elif exchange_interval is None:
            exchange_interval = pick_k(cfg.nz)
        self.runner, place, self.k = make_sharded_scan_runner(
            cfg, self.mesh, k=exchange_interval, use_pallas=use_pallas,
            bz=bz,
        )
        # timesteps per measured dispatch (multiple of the exchange
        # interval so every block is fully temporally blocked)
        self.block = max(scan_block // self.k, 1) * self.k
        if restored is not None:
            st = ShotState(
                p=jnp.asarray(restored["p"]),
                p_prev=jnp.asarray(restored["p_prev"]),
                t=int(restored["t"]),
            )
        else:
            st = ShotState.init(cfg)
        self.p, self.p_prev = place((st.p, st.p_prev))
        self.t = st.t
        # logical steps already covered by the last dispatched block —
        # carried through checkpoints so a mid-block RESHARD resumes the
        # remaining steps instead of re-dispatching (physical timesteps
        # then exceed logical steps only by the final block's tail)
        self._pending = int(restored.get("pending", 0)) \
            if restored is not None else 0
        self._amortized = float(restored.get("amortized_s", 0.0)) \
            if restored is not None else 0.0
        # fleet signature of the Resources the amortized step time was
        # measured under; a RESHARD onto a different fleet must not feed
        # the predictor the OLD fleet's step time, so a mismatch
        # rescales the estimate by the modeled effective-throughput
        # ratio until the next dispatched block re-measures it
        self._n_stripes = n
        self._res_sig = (
            n, tuple((p.chips, round(p.slowdown, 9)) for p in res.pods)
        )
        self._eff = sum(
            p.chips / max(p.slowdown, 1e-9) for p in res.pods
        )
        if restored is not None and self._amortized > 0.0:
            old_sig = restored.get("res_sig")
            old_eff = float(restored.get("amortized_eff", 0.0))
            if (old_sig is not None and old_sig != self._res_sig
                    and old_eff > 0.0 and self._eff > 0.0):
                self._amortized *= old_eff / self._eff

    def _advance_block(self) -> float:
        """Dispatch one scan block; returns amortized wall s/step."""
        blocks = self.block // self.k
        t0 = time.monotonic()
        p, pp, _ = self.runner(self.p, self.p_prev, self.t, blocks)
        jax.block_until_ready(p)
        dt = time.monotonic() - t0
        self.p, self.p_prev = p, pp
        self.t += blocks * self.k
        return dt / (blocks * self.k)

    def run_step(self, step: int) -> float:
        if self._pending <= 0:
            self._amortized = self._advance_block()
            self._pending = self.block
        self._pending -= 1
        wall = self._amortized
        if self.tm.chip_seconds_per_step is not None:
            # platform-model time: work split over pods, slowest wins
            times = []
            for pod, share in zip(self.res.pods, self.res.shares):
                if share <= 0:
                    continue
                t = (self.tm.chip_seconds_per_step * share
                     / pod.chips ** self.tm.scaling_alpha
                     * pod.slowdown)
                if (pod.name == "cluster"
                        and self.tm.congestion_from <= step
                        < self.tm.congestion_until):
                    t *= self.tm.congestion_factor
                times.append(t)
            dt = max(times)
        else:
            dt = wall
            k_max = max(
                (p.slowdown for p, s in zip(self.res.pods, self.res.shares)
                 if s > 0), default=1.0,
            )
            if k_max > 1.0:
                time.sleep(wall * (k_max - 1.0))
                dt = wall * k_max
        return dt * (1.0 + self.tm.jitter * abs(self.rng.standard_normal()))

    def checkpoint(self, step: int):
        return {
            "p": np.asarray(self.p),
            "p_prev": np.asarray(self.p_prev),
            "t": self.t,
            "pending": self._pending,
            "amortized_s": self._amortized,
            "res_sig": self._res_sig,
            "amortized_eff": self._eff,
        }


def save_session_snapshot(manager: CheckpointManager, steps_done: int,
                          snap: dict) -> None:
    """Persist an FWISession.checkpoint() dict through the
    CheckpointManager (DESIGN.md §19): wavefields go as array leaves
    (checksummed per leaf), scalars and the resource signature ride in
    the manifest's ``extra``.  Blocks until the write is durable — a
    preemption snapshot that is still in a queue when the process dies
    never happened."""
    arrays = {"p": snap["p"], "p_prev": snap["p_prev"]}
    n, pods = snap["res_sig"]
    extra = {
        "t": int(snap["t"]),
        "pending": int(snap["pending"]),
        "amortized_s": float(snap["amortized_s"]),
        "amortized_eff": float(snap["amortized_eff"]),
        "res_sig": [n, [list(x) for x in pods]],
        "steps_done": int(steps_done),
    }
    manager.save(steps_done, arrays, extra=extra, wait=True)


def load_session_snapshot(manager: CheckpointManager,
                          step: int | None = None) -> tuple[dict, int]:
    """Inverse of save_session_snapshot: returns ``(restored,
    steps_done)`` where ``restored`` feeds FWISession(...) directly.
    JSON round-trips the resource signature as nested lists; it is
    rebuilt as nested *tuples* here because FWISession compares it with
    ``!=`` against a tuple-of-tuples signature (DESIGN.md §19)."""
    state, extra = manager.restore({"p": 0, "p_prev": 0}, step=step)
    n, pods = extra["res_sig"]
    restored = {
        "p": np.asarray(state["p"]),
        "p_prev": np.asarray(state["p_prev"]),
        "t": int(extra["t"]),
        "pending": int(extra["pending"]),
        "amortized_s": float(extra["amortized_s"]),
        "amortized_eff": float(extra["amortized_eff"]),
        "res_sig": (n, tuple(tuple(x) for x in pods)),
    }
    return restored, int(extra["steps_done"])


class PreemptionGuard:
    """SIGTERM → durable snapshot → clean exit, torn-state-free
    (DESIGN.md §19).

    Python signal handlers run *between bytecodes*, so a handler that
    called ``session.checkpoint()`` directly could observe a session
    mid-update (``_advance_block`` assigns ``p``/``p_prev`` and ``t``
    in separate stores).  The guard instead has the driver loop
    ``publish()`` a coherent snapshot at each step boundary — one
    STORE_SUBSCR into a single slot, atomic with respect to signal
    delivery — and the SIGTERM handler persists whatever snapshot was
    last published.  The restart path resumes from it bit-consistently
    via load_session_snapshot.
    """

    def __init__(self, manager: CheckpointManager, *,
                 exit_code: int = 143):
        self.manager = manager
        self.exit_code = exit_code
        self._slot: list = [None]    # (steps_done, checkpoint dict)
        self._prev_handler = None

    def publish(self, session: Session, steps_done: int) -> None:
        """Record the step-boundary snapshot the handler may persist.
        Call from the driver loop after each completed step."""
        self._slot[0] = (steps_done, session.checkpoint(steps_done))

    def install(self) -> "PreemptionGuard":
        self._prev_handler = install_preemption_hook(
            self._save, exit_code=self.exit_code
        )
        return self

    def uninstall(self) -> None:
        if self._prev_handler is not None:
            signal.signal(signal.SIGTERM, self._prev_handler)
            self._prev_handler = None

    def _save(self) -> None:
        snap = self._slot[0]
        if snap is None:
            return
        steps_done, state = snap
        save_session_snapshot(self.manager, steps_done, state)


def elastic_stripes_for(base_stripes: int = 1, grown_stripes: int = 2):
    """``stripes_for`` mapping for the real elastic loop (DESIGN.md
    §14): while an elastic (cloud/burst) pod is attached the domain is
    re-striped across ``grown_stripes`` devices, and a RETIRE collapses
    it back — so every policy-driven GROW/SHRINK exercises the real
    ckpt → remesh → reshard path, not just a share re-split."""

    def stripes(res: Resources) -> int:
        return grown_stripes if elastic_chips(res) > 0 else base_stripes

    return stripes


def fwi_session_factory(cfg: FWIConfig, time_model: TimeModel,
                        *, seed: int = 0, stripes_for=None,
                        exchange_interval: int | None = 4,
                        scan_block: int = 8,
                        use_pallas: bool = False,
                        autotune: bool = False):
    rng = np.random.default_rng(seed)

    def factory(res: Resources, start_step: int, restored) -> FWISession:
        n = stripes_for(res) if stripes_for else None
        return FWISession(
            cfg, res, start_step, restored,
            time_model=time_model, rng=rng, n_stripes=n,
            exchange_interval=exchange_interval, scan_block=scan_block,
            use_pallas=use_pallas, autotune=autotune,
        )

    return factory
