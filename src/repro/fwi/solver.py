"""FWI forward modeling — the paper's target application (§3.1).

2-D acoustic wave propagation over a layered velocity model with a salt
body, Ricker-wavelet point sources ("shots" from the acquisition ship),
receiver traces sampled at the surface.  Multiple shots are independent
(task-parallel) over the same velocity model (data-parallel) — exactly
the structure the paper exploits to split work between environments.

Propagation engine layout (the scan-fused hot loop):

* ``make_step_fn``       — one jitted timestep (kept for interactive /
                           single-step use and as the equivalence oracle).
* ``make_scan_runner``   — jit-once ``lax.scan`` over timesteps with the
                           UNJITTED step body inlined (a nested jit
                           inside a scan body defeats XLA's loop fusion
                           and costs ~3× on CPU), receiver traces
                           collected as scan outputs, and the body
                           unrolled (default 8×) so consecutive steps
                           fuse.  This is what ``run_forward``, the
                           calibration sweeps and the driver use.
* model-building (``velocity_model``/``sponge_taper``/``ricker``) and
  both runner factories are memoized on the (frozen, hashable)
  ``FWIConfig`` — a RESHARD-triggered session rebuild re-uses the cached
  arrays and compiled runners instead of recomputing and re-tracing.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.stencil.ops import wave_step


@dataclasses.dataclass(frozen=True)
class FWIConfig:
    nz: int = 600                 # paper Table 2: 600 x 600 grid
    nx: int = 600
    dt: float = 5e-4              # s
    dx: float = 5.0               # m
    timesteps: int = 600
    n_shots: int = 4              # paper Table 2: 4 shots
    sponge_width: int = 32
    sponge_strength: float = 0.0125
    source_freq: float = 12.0     # Hz Ricker
    receiver_depth: int = 2

    def shot_positions(self) -> np.ndarray:
        xs = np.linspace(self.nx * 0.2, self.nx * 0.8, self.n_shots)
        return np.stack(
            [np.full(self.n_shots, 4.0), xs], axis=1
        ).astype(np.int32)


@functools.lru_cache(maxsize=64)
def velocity_model(cfg: FWIConfig) -> jnp.ndarray:
    """Layered model with a salt dome (paper Fig. 3 bottom).  Memoized:
    session rebuilds after RESHARD reuse the device array."""
    z = np.arange(cfg.nz)[:, None]
    x = np.arange(cfg.nx)[None, :]
    v = 1500.0 + 2.2 * z                       # depth gradient, m/s
    for depth, dv in ((cfg.nz // 3, 400.0), (cfg.nz // 2, 500.0)):
        v = v + dv * (z > depth)
    # salt dome: high-velocity ellipse
    cz, cx = int(cfg.nz * 0.62), int(cfg.nx * 0.5)
    dome = ((z - cz) / (0.18 * cfg.nz)) ** 2 + (
        (x - cx) / (0.25 * cfg.nx)
    ) ** 2 < 1.0
    v = np.where(dome, 4500.0, v)
    return jnp.asarray(v, jnp.float32)


@functools.lru_cache(maxsize=64)
def sponge_taper(cfg: FWIConfig) -> jnp.ndarray:
    w = cfg.sponge_width
    z = np.arange(cfg.nz)[:, None] + np.zeros((1, cfg.nx))
    x = np.arange(cfg.nx)[None, :] + np.zeros((cfg.nz, 1))
    dist = np.minimum.reduce([
        z, cfg.nz - 1 - z, x, cfg.nx - 1 - x,
        np.full((cfg.nz, cfg.nx), float(w)),
    ])
    taper = np.exp(-(cfg.sponge_strength * (w - dist)) ** 2)
    return jnp.asarray(np.where(dist >= w, 1.0, taper), jnp.float32)


@functools.lru_cache(maxsize=64)
def ricker(cfg: FWIConfig) -> jnp.ndarray:
    t = np.arange(cfg.timesteps) * cfg.dt
    t0 = 1.2 / cfg.source_freq
    a = (np.pi * cfg.source_freq * (t - t0)) ** 2
    return jnp.asarray((1 - 2 * a) * np.exp(-a) * 1e3, jnp.float32)


@dataclasses.dataclass
class ShotState:
    """Propagation state for a batch of shots — the checkpointable unit
    (paper Fig.1 step 2 saves exactly this)."""

    p: jnp.ndarray        # (S, NZ, NX)
    p_prev: jnp.ndarray
    t: int

    @staticmethod
    def init(cfg: FWIConfig) -> "ShotState":
        shape = (cfg.n_shots, cfg.nz, cfg.nx)
        return ShotState(
            p=jnp.zeros(shape, jnp.float32),
            p_prev=jnp.zeros(shape, jnp.float32),
            t=0,
        )


@functools.lru_cache(maxsize=32)
def _raw_step_fn(cfg: FWIConfig, use_pallas: bool):
    """Unjitted step(p, p_prev, t) -> (p_next, p_damped, trace) advancing
    all shots one timestep — inlined into the scan body by the runner."""
    v = velocity_model(cfg)
    v2dt2 = (v * cfg.dt / cfg.dx) ** 2
    sponge = sponge_taper(cfg)
    wavelet = ricker(cfg)
    pos = cfg.shot_positions()
    src_z = jnp.asarray(pos[:, 0])
    src_x = jnp.asarray(pos[:, 1])

    def one_shot(p, p_prev, t, zi, xi):
        p_next, p_damped = wave_step(
            p, p_prev, v2dt2, sponge, use_pallas=use_pallas
        )
        src = wavelet[jnp.clip(t, 0, cfg.timesteps - 1)] * (cfg.dt ** 2)
        p_next = p_next.at[zi, xi].add(src)
        return p_next, p_damped

    def step(p, p_prev, t):
        p_next, p_damped = jax.vmap(
            one_shot, in_axes=(0, 0, None, 0, 0)
        )(p, p_prev, t, src_z, src_x)
        trace = p_next[:, cfg.receiver_depth, :]     # (S, NX) receivers
        return p_next, p_damped, trace

    return step


@functools.lru_cache(maxsize=32)
def make_step_fn(cfg: FWIConfig, *, use_pallas: bool = False):
    """Returns jitted step(state_fields, t) advancing one timestep."""
    return jax.jit(_raw_step_fn(cfg, use_pallas))


@functools.lru_cache(maxsize=32)
def make_scan_runner(cfg: FWIConfig, *, use_pallas: bool = False,
                     collect_traces: bool = False, unroll: int = 8):
    """jit-once multi-step propagator (lax.scan over timesteps).

    run(p, p_prev, t0, steps) -> (p, p_prev)                 [default]
                             -> (p, p_prev, traces (S,T,NX)) [collect]

    ``t0`` is traced, ``steps`` static — restarting at a different
    offset does not retrace.  The factory is memoized, so RESHARD /
    restart paths reuse the compiled runner.
    """
    step = _raw_step_fn(cfg, use_pallas)

    @functools.partial(jax.jit, static_argnames=("steps",))
    def run(p, p_prev, t0, steps: int):
        def body(carry, i):
            p, pp = carry
            pn, pd, tr = step(p, pp, t0 + i)
            return (pn, pd), (tr if collect_traces else None)

        (p, pp), traces = jax.lax.scan(
            body, (p, p_prev), jnp.arange(steps),
            unroll=min(unroll, max(steps, 1)),
        )
        if collect_traces:
            # scan stacks on axis 0 (time); traces as (S, T, NX)
            return p, pp, jnp.swapaxes(traces, 0, 1)
        return p, pp

    return run


def run_forward(cfg: FWIConfig, *, use_pallas: bool = False,
                state: ShotState | None = None,
                steps: int | None = None):
    """Propagate `steps` timesteps (default: to completion) through the
    scan-fused runner.  Returns (state, traces (S, T, NX) for the steps
    actually run)."""
    st = state or ShotState.init(cfg)
    steps = steps if steps is not None else cfg.timesteps - st.t
    if steps <= 0:
        return st, jnp.zeros((cfg.n_shots, 0, cfg.nx), jnp.float32)
    run = make_scan_runner(cfg, use_pallas=use_pallas, collect_traces=True)
    p, pp, traces = run(st.p, st.p_prev, st.t, steps)
    return ShotState(p=p, p_prev=pp, t=st.t + steps), traces
