"""FWI forward modeling — the paper's target application (§3.1).

2-D acoustic wave propagation over a layered velocity model with a salt
body, Ricker-wavelet point sources ("shots" from the acquisition ship),
receiver traces sampled at the surface.  Multiple shots are independent
(task-parallel) over the same velocity model (data-parallel) — exactly
the structure the paper exploits to split work between environments.

Propagation engine layout (the overlap-and-fuse hot loop):

* ``make_step_fn``       — one jitted timestep (kept for interactive /
                           single-step use and as the equivalence oracle).
* ``make_scan_runner``   — the PR 1 engine: jit-once ``lax.scan`` over
                           timesteps with the UNJITTED step body inlined
                           (a nested jit inside a scan body defeats
                           XLA's loop fusion and costs ~3× on CPU),
                           receiver traces collected as scan outputs,
                           and the body unrolled (default 8×).  Kept as
                           the bench baseline and equivalence oracle.
* ``make_block_runner``  — the fused engine: ``lax.scan`` over k-step
                           fused blocks (``kernels.stencil.ops
                           .wave_block``), each block one fused region —
                           source injection, sponge damping and receiver
                           capture in the step epilogue, the damped
                           previous field folded into the next leapfrog
                           expression instead of materialized per step,
                           and (XLA path) the field held padded across
                           inner steps.  Bit-identical to the scan
                           runner; this is what ``run_forward`` uses
                           (DESIGN.md §13).
* model-building (``velocity_model``/``sponge_taper``/``ricker``) and
  all runner factories are memoized on the (frozen, hashable)
  ``FWIConfig`` plus their full engine knobs — ``make_block_runner``
  keys on ``(cfg, k, bz, use_pallas, collect_traces)`` so autotuned
  variants never collide in the cache, and a RESHARD-triggered session
  rebuild re-uses the cached arrays and compiled runners instead of
  recomputing and re-tracing.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.stencil.ops import pick_k, wave_block, wave_step


@dataclasses.dataclass(frozen=True)
class FWIConfig:
    nz: int = 600                 # paper Table 2: 600 x 600 grid
    nx: int = 600
    dt: float = 5e-4              # s
    dx: float = 5.0               # m
    timesteps: int = 600
    n_shots: int = 4              # paper Table 2: 4 shots
    sponge_width: int = 32
    sponge_strength: float = 0.0125
    source_freq: float = 12.0     # Hz Ricker
    receiver_depth: int = 2

    def shot_positions(self) -> np.ndarray:
        xs = np.linspace(self.nx * 0.2, self.nx * 0.8, self.n_shots)
        return np.stack(
            [np.full(self.n_shots, 4.0), xs], axis=1
        ).astype(np.int32)


@functools.lru_cache(maxsize=64)
def velocity_model(cfg: FWIConfig) -> jnp.ndarray:
    """Layered model with a salt dome (paper Fig. 3 bottom).  Memoized:
    session rebuilds after RESHARD reuse the device array."""
    z = np.arange(cfg.nz)[:, None]
    x = np.arange(cfg.nx)[None, :]
    v = 1500.0 + 2.2 * z                       # depth gradient, m/s
    for depth, dv in ((cfg.nz // 3, 400.0), (cfg.nz // 2, 500.0)):
        v = v + dv * (z > depth)
    # salt dome: high-velocity ellipse
    cz, cx = int(cfg.nz * 0.62), int(cfg.nx * 0.5)
    dome = ((z - cz) / (0.18 * cfg.nz)) ** 2 + (
        (x - cx) / (0.25 * cfg.nx)
    ) ** 2 < 1.0
    v = np.where(dome, 4500.0, v)
    return jnp.asarray(v, jnp.float32)


@functools.lru_cache(maxsize=64)
def sponge_taper(cfg: FWIConfig) -> jnp.ndarray:
    w = cfg.sponge_width
    z = np.arange(cfg.nz)[:, None] + np.zeros((1, cfg.nx))
    x = np.arange(cfg.nx)[None, :] + np.zeros((cfg.nz, 1))
    dist = np.minimum.reduce([
        z, cfg.nz - 1 - z, x, cfg.nx - 1 - x,
        np.full((cfg.nz, cfg.nx), float(w)),
    ])
    taper = np.exp(-(cfg.sponge_strength * (w - dist)) ** 2)
    return jnp.asarray(np.where(dist >= w, 1.0, taper), jnp.float32)


@functools.lru_cache(maxsize=64)
def ricker(cfg: FWIConfig) -> jnp.ndarray:
    t = np.arange(cfg.timesteps) * cfg.dt
    t0 = 1.2 / cfg.source_freq
    a = (np.pi * cfg.source_freq * (t - t0)) ** 2
    return jnp.asarray((1 - 2 * a) * np.exp(-a) * 1e3, jnp.float32)


@dataclasses.dataclass
class ShotState:
    """Propagation state for a batch of shots — the checkpointable unit
    (paper Fig.1 step 2 saves exactly this)."""

    p: jnp.ndarray        # (S, NZ, NX)
    p_prev: jnp.ndarray
    t: int

    @staticmethod
    def init(cfg: FWIConfig) -> "ShotState":
        shape = (cfg.n_shots, cfg.nz, cfg.nx)
        return ShotState(
            p=jnp.zeros(shape, jnp.float32),
            p_prev=jnp.zeros(shape, jnp.float32),
            t=0,
        )


@functools.lru_cache(maxsize=32)
def _raw_step_fn(cfg: FWIConfig, use_pallas: bool):
    """Unjitted step(p, p_prev, t) -> (p_next, p_damped, trace) advancing
    all shots one timestep — inlined into the scan body by the runner."""
    v = velocity_model(cfg)
    v2dt2 = (v * cfg.dt / cfg.dx) ** 2
    sponge = sponge_taper(cfg)
    wavelet = ricker(cfg)
    pos = cfg.shot_positions()
    src_z = jnp.asarray(pos[:, 0])
    src_x = jnp.asarray(pos[:, 1])

    def one_shot(p, p_prev, t, zi, xi):
        p_next, p_damped = wave_step(
            p, p_prev, v2dt2, sponge, use_pallas=use_pallas
        )
        src = wavelet[jnp.clip(t, 0, cfg.timesteps - 1)] * (cfg.dt ** 2)
        p_next = p_next.at[zi, xi].add(src)
        return p_next, p_damped

    def step(p, p_prev, t):
        p_next, p_damped = jax.vmap(
            one_shot, in_axes=(0, 0, None, 0, 0)
        )(p, p_prev, t, src_z, src_x)
        trace = p_next[:, cfg.receiver_depth, :]     # (S, NX) receivers
        return p_next, p_damped, trace

    return step


@functools.lru_cache(maxsize=32)
def make_step_fn(cfg: FWIConfig, *, use_pallas: bool = False):
    """Returns jitted step(state_fields, t) advancing one timestep."""
    return jax.jit(_raw_step_fn(cfg, use_pallas))


@functools.lru_cache(maxsize=32)
def make_scan_runner(cfg: FWIConfig, *, use_pallas: bool = False,
                     collect_traces: bool = False, unroll: int = 8):
    """jit-once multi-step propagator (lax.scan over timesteps).

    run(p, p_prev, t0, steps) -> (p, p_prev)                 [default]
                             -> (p, p_prev, traces (S,T,NX)) [collect]

    ``t0`` is traced, ``steps`` static — restarting at a different
    offset does not retrace.  The factory is memoized, so RESHARD /
    restart paths reuse the compiled runner.
    """
    step = _raw_step_fn(cfg, use_pallas)

    @functools.partial(jax.jit, static_argnames=("steps",))
    def run(p, p_prev, t0, steps: int):
        def body(carry, i):
            p, pp = carry
            pn, pd, tr = step(p, pp, t0 + i)
            return (pn, pd), (tr if collect_traces else None)

        (p, pp), traces = jax.lax.scan(
            body, (p, p_prev), jnp.arange(steps),
            unroll=min(unroll, max(steps, 1)),
        )
        if collect_traces:
            # scan stacks on axis 0 (time); traces as (S, T, NX)
            return p, pp, jnp.swapaxes(traces, 0, 1)
        return p, pp

    return run


def _block_scan_body(cfg: FWIConfig, k: int, use_pallas: bool,
                     bz: int | None, collect_traces: bool,
                     stream: bool | None = None,
                     vmem_budget: int | None = None,
                     shot_tile: int | None = None):
    """Shared scan-over-fused-blocks body: local_run(p, p_prev, src_z,
    src_x, t0, steps static) -> (p, p_prev[, traces]) — UNJITTED, so
    both the single-host and the shot-sharded runner jit at their own
    boundary.  Source positions are arguments (not closure) so a
    shot-sharded caller can pass its local shard's sources.

    The whole shot batch advances through ONE shot-batched
    ``wave_block`` call per block (the 3-D dispatch, DESIGN.md §17) —
    not a ``vmap`` over per-shot kernels — so the shared model fields
    are read once per strip for all local shots and the batch costs one
    kernel launch per block.  Bit-identical to the old vmapped body on
    the XLA path (``wave_block_shots_ref`` is pinned bitwise vs
    ``vmap``-of-``wave_block_ref``)."""
    v = velocity_model(cfg)
    v2dt2 = (v * cfg.dt / cfg.dx) ** 2
    sponge = sponge_taper(cfg)
    wavelet = ricker(cfg)

    def block(p, p_prev, src_z, src_x, t0b, kk: int):
        srcv = wavelet[
            jnp.clip(t0b + jnp.arange(kk), 0, cfg.timesteps - 1)
        ] * (cfg.dt ** 2)
        return wave_block(
            p, p_prev, v2dt2, sponge, srcv, src_z, src_x,
            receiver_row=cfg.receiver_depth,
            use_pallas=use_pallas, bz=bz,
            stream=stream, vmem_budget=vmem_budget,
            shot_tile=shot_tile,
        )

    def local_run(p, p_prev, src_z, src_x, t0, steps: int):
        blocks, tail = divmod(steps, k)

        def body(carry, b):
            pc, pp = carry
            pn, pd, tr = block(pc, pp, src_z, src_x, t0 + b * k, k)
            return (pn, pd), (tr if collect_traces else None)

        traces = jnp.zeros((p.shape[0], 0, cfg.nx), jnp.float32)
        if blocks:
            (p, p_prev), trs = jax.lax.scan(
                body, (p, p_prev), jnp.arange(blocks)
            )
            if collect_traces:
                # (blocks, S, k, NX) -> (S, blocks*k, NX)
                trs = jnp.moveaxis(trs, 0, 1)
                traces = trs.reshape(trs.shape[0], -1, trs.shape[-1])
        if tail:
            p, p_prev, tr = block(
                p, p_prev, src_z, src_x, t0 + blocks * k, tail
            )
            if collect_traces:
                traces = jnp.concatenate([traces, tr], axis=1)
        if collect_traces:
            return p, p_prev, traces
        return p, p_prev

    return local_run


@functools.lru_cache(maxsize=64)
def make_block_runner(cfg: FWIConfig, *, k: int | None = None,
                      use_pallas: bool = False, bz: int | None = None,
                      collect_traces: bool = True,
                      stream: bool | None = None,
                      vmem_budget: int | None = None,
                      shot_tile: int | None = None):
    """jit-once FUSED multi-step propagator: ``lax.scan`` over k-step
    fused blocks (one ``wave_block`` per block — DESIGN.md §13).

    run(p, p_prev, t0, steps) -> (p, p_prev, traces (S, steps, NX))

    ``t0`` is traced, ``steps`` static; a non-multiple-of-k step count
    runs a tail block of the remainder length.  Bit-identical to
    ``make_scan_runner`` on the XLA path (the block body is a pure
    re-scheduling of the same ops — and the auto-selected STREAMED
    tiling for production grids keeps that contract via
    ``wave_block_strips_ref``, see DESIGN.md §15).  Memoized on the
    FULL knob set (cfg, k, bz, use_pallas, collect_traces, stream,
    vmem_budget, shot_tile) so autotuned variants don't collide in the
    cache."""
    if k is None:
        k = pick_k(cfg.nz)
    pos = cfg.shot_positions()
    src_z = jnp.asarray(pos[:, 0])
    src_x = jnp.asarray(pos[:, 1])
    local_run = _block_scan_body(cfg, k, use_pallas, bz, collect_traces,
                                 stream, vmem_budget, shot_tile)

    @functools.partial(jax.jit, static_argnames=("steps",))
    def run(p, p_prev, t0, steps: int):
        return local_run(p, p_prev, src_z, src_x, t0, steps)

    run.k = k
    return run


@functools.lru_cache(maxsize=16)
def make_shot_parallel_runner(cfg: FWIConfig, n_devices: int, *,
                              k: int | None = None,
                              use_pallas: bool = False,
                              bz: int | None = None,
                              collect_traces: bool = True,
                              stream: bool | None = None,
                              vmem_budget: int | None = None,
                              shot_tile: int | None = None):
    """Fused block runner with the SHOT axis sharded over devices — the
    paper's FIRST-level task-parallel split (§3.1: shots are
    independent), realized on the fused engine (DESIGN.md §13).

    Zero communication: each device owns its whole-domain shot shard
    and runs the identical scan-over-fused-blocks body on it (one
    shot-batched kernel per block — DESIGN.md §17), so parallel
    efficiency is bounded only by the host (no halos, no redundant
    columns — the complementary axis to the striped γ-split in
    fwi/domain.py, which is what cross-ENVIRONMENT placement needs).
    Returns (run, place): run(p, p_prev, t0, steps) as make_block_runner;
    place() shards the (S, NZ, NX) fields on shot axis 0.

    UNEVEN shot splits are supported by remainder placement: when
    ``n_shots % n_devices != 0`` the batch is padded to the next
    multiple by replicating shot 0 (positions included), the padded
    shots propagate as throwaway duplicates, and every output is sliced
    back to the real ``n_shots`` — so an elastic GROW to a non-divisor
    device count (4 shots → 3 devices) runs instead of crashing, at the
    cost of the duplicates' compute.  ``place`` accepts either padded
    or unpadded fields; ``run`` pads unpadded inputs itself.

    Contract: matches the single-host block runner to f32-ULP
    `allclose` (~1e-7 relative), NOT bitwise — the smaller per-device
    batch changes XLA's vectorization/FMA contraction of the stencil
    fusions.  (The striped runner keeps the batch intact and stays
    bitwise; this one trades that for perfect parallel efficiency.)"""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import shard_map

    if k is None:
        k = pick_k(cfg.nz)
    pad = (-cfg.n_shots) % n_devices     # remainder-placement padding
    mesh = jax.make_mesh((n_devices,), ("shot",),
                         devices=jax.devices()[:n_devices])
    pos = cfg.shot_positions()
    if pad:
        pos = np.concatenate([pos, np.repeat(pos[:1], pad, axis=0)])
    src_z = jnp.asarray(pos[:, 0])
    src_x = jnp.asarray(pos[:, 1])
    local_run = _block_scan_body(cfg, k, use_pallas, bz, collect_traces,
                                 stream, vmem_budget, shot_tile)
    out_specs = (
        (P("shot"), P("shot"), P("shot")) if collect_traces
        else (P("shot"), P("shot"))
    )

    def _pad_shots(f):
        if pad and f.shape[0] == cfg.n_shots:
            f = jnp.concatenate([f, jnp.repeat(f[:1], pad, axis=0)])
        return f

    @functools.partial(jax.jit, static_argnames=("steps",))
    def run(p, p_prev, t0, steps: int):
        p, p_prev = _pad_shots(p), _pad_shots(p_prev)
        sm = shard_map(
            lambda a, b, sz, sx, t: local_run(a, b, sz, sx, t, steps),
            mesh=mesh,
            in_specs=(P("shot"), P("shot"), P("shot"), P("shot"), P()),
            out_specs=out_specs,
            check_vma=False,
        )
        out = sm(p, p_prev, src_z, src_x, t0)
        if pad:
            out = tuple(o[:cfg.n_shots] for o in out)
        return out

    sh = NamedSharding(mesh, P("shot"))

    def place(state_fields):
        padded = jax.tree_util.tree_map(_pad_shots, state_fields)
        return jax.device_put(padded, sh)

    run.k = k
    return run, place


def run_forward(cfg: FWIConfig, *, use_pallas: bool = False,
                state: ShotState | None = None,
                steps: int | None = None, k: int | None = None):
    """Propagate `steps` timesteps (default: to completion) through the
    fused block runner.  Returns (state, traces (S, T, NX) for the
    steps actually run)."""
    st = state or ShotState.init(cfg)
    steps = steps if steps is not None else cfg.timesteps - st.t
    if steps <= 0:
        return st, jnp.zeros((cfg.n_shots, 0, cfg.nx), jnp.float32)
    run = make_block_runner(cfg, k=k, use_pallas=use_pallas,
                            collect_traces=True)
    p, pp, traces = run(st.p, st.p_prev, st.t, steps)
    return ShotState(p=p, p_prev=pp, t=st.t + steps), traces
