"""Empirical calibration — paper §3.2 (eqs. 6, 7, 8).

Two fits, same methodology as the paper's pre-processing phase:

* t(γ): execution time vs domain width — REAL wall-clock measurements of
  the solver on this machine over a sweep of widths (paper Fig. 5).  The
  linear model (eq. 4) is fitted with gamma.GammaModel.

* L(c): log-time vs chip count for each environment (paper Fig. 4).  A
  single CPU core cannot vary real chip counts, so the samples come from
  the measured single-device step time scaled by c and by the
  environment slowdown K — the *fitting code path* is identical to what
  runs on real hardware (DESIGN.md §10 records this boundary).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.capacity import LogCapacityModel
from repro.core.gamma import GammaModel
from repro.fwi.solver import FWIConfig, run_forward


def measure_gamma_sweep(
    base: FWIConfig,
    widths: list[int],
    *,
    steps: int = 30,
    repeats: int = 2,
) -> tuple[list[int], list[float]]:
    """REAL wall-clock: time `steps` timesteps at each domain width.

    Uses the scanned (jit-once) propagator so python dispatch overhead
    does not pollute the per-step estimate (the paper's fit assumes
    compute-dominated steps)."""
    import jax

    from repro.fwi.solver import ShotState, make_scan_runner

    times = []
    for nx in widths:
        cfg = FWIConfig(
            nz=base.nz, nx=nx, dt=base.dt, dx=base.dx,
            timesteps=steps, n_shots=base.n_shots,
            sponge_width=base.sponge_width,
        )
        runner = make_scan_runner(cfg)
        st = ShotState.init(cfg)
        jax.block_until_ready(runner(st.p, st.p_prev, 0, steps))  # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.monotonic()
            jax.block_until_ready(runner(st.p, st.p_prev, 0, steps))
            best = min(best, time.monotonic() - t0)
        times.append(best / steps)
    return widths, times


def fit_gamma_model(base: FWIConfig, widths=None, **kw) -> GammaModel:
    widths = widths or [128, 192, 256, 384, 512]
    g, t = measure_gamma_sweep(base, widths, **kw)
    return GammaModel.fit(g, t, name="fwi-width")


def measure_seam_latency(
    cfg: FWIConfig | None = None,
    *,
    n_stripes: int = 2,
    k: int = 4,
    iters: int = 30,
    blocks: int = 8,
) -> dict:
    """REAL seam probe feeding ``OverheadModel.with_overlapped_seam``.

    Two measurements with the exact shapes the sharded engine uses:

    * ``ppermute_latency_s`` — median wall time of one jitted packed
      halo ``ppermute`` over the real ``bytes_per_exchange`` payload of
      ``halo_exchange_plan(cfg, n_stripes, k)``, on a stripe mesh of
      ``min(n_stripes, len(jax.devices()))`` devices.  With a
      multi-device mesh this is a genuine CROSS-DEVICE transfer (the
      number the pipeline schedule must hide); on one device it
      degrades to the dispatch-latency floor — ``mesh_devices`` in the
      returned dict records which one was measured.
    * ``interior_compute_s_per_step`` — measured per-step time of the
      stripe-INTERIOR fused block (the k-step ``wave_block`` window at
      the stripe-local width ``nx / n_stripes``), i.e. the compute the
      in-flight exchange can hide behind.

    The returned dict is the provenance-carrying input of
    ``sim.scenarios.overheads_from_probe`` (committed there as a
    literal snapshot so the sim layer stays jax-free) and of the
    measured-vs-modeled seam rows in ``benchmarks/bench_overheads.py``
    (DESIGN.md §15)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.fwi.domain import halo_exchange_plan, stripe_mesh
    from repro.fwi.solver import ShotState, make_block_runner

    cfg = cfg or FWIConfig()
    plan = halo_exchange_plan(cfg, n_stripes, k=k)
    k = int(plan["k"])                     # effective (clamped) block
    n_mesh = max(min(n_stripes, len(jax.devices())), 1)
    mesh = stripe_mesh(n_mesh)
    perm = [(i, (i + 1) % n_mesh) for i in range(n_mesh)]
    words = max(int(plan["bytes_per_exchange"]) // 4, 1)

    f = jax.jit(shard_map(
        lambda x: jax.lax.ppermute(x, "stripe", perm),
        mesh=mesh, in_specs=P("stripe"), out_specs=P("stripe"),
    ))
    x = jnp.zeros((words * n_mesh,), jnp.float32)  # per-device payload
    f(x).block_until_ready()                       # compile
    ts = []
    for _ in range(iters):
        t0 = time.monotonic()
        f(x).block_until_ready()
        ts.append(time.monotonic() - t0)
    t_pp = sorted(ts)[len(ts) // 2]

    icfg = FWIConfig(
        nz=cfg.nz, nx=cfg.nx // n_stripes, dt=cfg.dt, dx=cfg.dx,
        timesteps=cfg.timesteps, n_shots=cfg.n_shots,
        sponge_width=cfg.sponge_width,
    )
    st = ShotState.init(icfg)
    blk = make_block_runner(icfg, k=k, collect_traces=False)
    steps = k * blocks
    jax.block_until_ready(blk(st.p, st.p_prev, 0, steps))  # compile
    best = float("inf")
    for _ in range(2):
        t0 = time.monotonic()
        jax.block_until_ready(blk(st.p, st.p_prev, 0, steps))
        best = min(best, time.monotonic() - t0)
    t_int = best / steps

    return {
        "plan": plan,
        "ppermute_latency_s": t_pp,
        "interior_compute_s_per_step": t_int,
        "n_stripes": n_stripes,
        "mesh_devices": n_mesh,
        "backend": jax.default_backend(),
    }


def measure_single_device_step(cfg: FWIConfig, steps: int = 30) -> float:
    run_forward(cfg, steps=2)
    t0 = time.monotonic()
    run_forward(cfg, steps=steps)
    return (time.monotonic() - t0) / steps


def fit_capacity_models(
    cfg: FWIConfig,
    *,
    chip_counts=(8, 16, 32, 64, 128, 256),
    cloud_slowdown: float = 1.4,
    noise: float = 0.01,
    seed: int = 0,
    measured_step_s: float | None = None,
) -> tuple[LogCapacityModel, LogCapacityModel, dict]:
    """Fit eqs. 6-7.  Samples = measured 1-device step time / c (ideal
    data-parallel scaling of the striped solver) × environment slowdown,
    with measurement noise — simulated scaling, real fitting path."""
    t1 = measured_step_s or measure_single_device_step(cfg)
    rng = np.random.default_rng(seed)
    cs = list(chip_counts)
    t_cluster = [
        t1 / c * (1.0 + noise * abs(rng.standard_normal())) for c in cs
    ]
    t_cloud = [
        t1 / c * cloud_slowdown * (1.0 + noise * abs(rng.standard_normal()))
        for c in cs
    ]
    cluster = LogCapacityModel.fit(cs, t_cluster, "fwi-cluster")
    cloud = LogCapacityModel.fit(cs, t_cloud, "fwi-cloud")
    samples = {
        "chips": cs, "t_cluster": t_cluster, "t_cloud": t_cloud,
        "t1_measured": t1,
    }
    return cluster, cloud, samples
