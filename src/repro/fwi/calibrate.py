"""Empirical calibration — paper §3.2 (eqs. 6, 7, 8).

Two fits, same methodology as the paper's pre-processing phase:

* t(γ): execution time vs domain width — REAL wall-clock measurements of
  the solver on this machine over a sweep of widths (paper Fig. 5).  The
  linear model (eq. 4) is fitted with gamma.GammaModel.

* L(c): log-time vs chip count for each environment (paper Fig. 4).  A
  single CPU core cannot vary real chip counts, so the samples come from
  the measured single-device step time scaled by c and by the
  environment slowdown K — the *fitting code path* is identical to what
  runs on real hardware (DESIGN.md §10 records this boundary).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.capacity import LogCapacityModel
from repro.core.gamma import GammaModel
from repro.fwi.solver import FWIConfig, run_forward


def measure_gamma_sweep(
    base: FWIConfig,
    widths: list[int],
    *,
    steps: int = 30,
    repeats: int = 2,
) -> tuple[list[int], list[float]]:
    """REAL wall-clock: time `steps` timesteps at each domain width.

    Uses the scanned (jit-once) propagator so python dispatch overhead
    does not pollute the per-step estimate (the paper's fit assumes
    compute-dominated steps)."""
    import jax

    from repro.fwi.solver import ShotState, make_scan_runner

    times = []
    for nx in widths:
        cfg = FWIConfig(
            nz=base.nz, nx=nx, dt=base.dt, dx=base.dx,
            timesteps=steps, n_shots=base.n_shots,
            sponge_width=base.sponge_width,
        )
        runner = make_scan_runner(cfg)
        st = ShotState.init(cfg)
        jax.block_until_ready(runner(st.p, st.p_prev, 0, steps))  # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.monotonic()
            jax.block_until_ready(runner(st.p, st.p_prev, 0, steps))
            best = min(best, time.monotonic() - t0)
        times.append(best / steps)
    return widths, times


def fit_gamma_model(base: FWIConfig, widths=None, **kw) -> GammaModel:
    widths = widths or [128, 192, 256, 384, 512]
    g, t = measure_gamma_sweep(base, widths, **kw)
    return GammaModel.fit(g, t, name="fwi-width")


def measure_single_device_step(cfg: FWIConfig, steps: int = 30) -> float:
    run_forward(cfg, steps=2)
    t0 = time.monotonic()
    run_forward(cfg, steps=steps)
    return (time.monotonic() - t0) / steps


def fit_capacity_models(
    cfg: FWIConfig,
    *,
    chip_counts=(8, 16, 32, 64, 128, 256),
    cloud_slowdown: float = 1.4,
    noise: float = 0.01,
    seed: int = 0,
    measured_step_s: float | None = None,
) -> tuple[LogCapacityModel, LogCapacityModel, dict]:
    """Fit eqs. 6-7.  Samples = measured 1-device step time / c (ideal
    data-parallel scaling of the striped solver) × environment slowdown,
    with measurement noise — simulated scaling, real fitting path."""
    t1 = measured_step_s or measure_single_device_step(cfg)
    rng = np.random.default_rng(seed)
    cs = list(chip_counts)
    t_cluster = [
        t1 / c * (1.0 + noise * abs(rng.standard_normal())) for c in cs
    ]
    t_cloud = [
        t1 / c * cloud_slowdown * (1.0 + noise * abs(rng.standard_normal()))
        for c in cs
    ]
    cluster = LogCapacityModel.fit(cs, t_cluster, "fwi-cluster")
    cloud = LogCapacityModel.fit(cs, t_cloud, "fwi-cloud")
    samples = {
        "chips": cs, "t_cluster": t_cluster, "t_cloud": t_cloud,
        "t1_measured": t1,
    }
    return cluster, cloud, samples
