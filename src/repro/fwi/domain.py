"""Striped domain decomposition + halo exchange (paper Fig. 2).

The x-axis (width) is cut into contiguous column stripes, one per device
on a 1-D ("stripe",) mesh; the height is fixed — exactly the paper's
simplification.  Each timestep exchanges a 2-column halo with stripe
neighbors via shard_map + lax.ppermute (the jax-native rendering of the
MPI halo exchange), so per-step traffic is 2 columns × NZ × 4 B per
neighbor pair — the TPU analogue of the paper's "total message size is
only 21 KB" measurement, which bench_overheads.py reproduces.

The γ-split maps stripes to environments: with the right γ·(NX/stripes)
columns assigned to burst-pod devices, only ONE stripe seam crosses the
slow link (greedy striped placement, paper §3.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.fwi.solver import FWIConfig, ricker, sponge_taper, velocity_model
from repro.kernels.stencil.ref import C0, C1, C2

HALO = 2


def stripe_mesh(n_devices: int | None = None) -> Mesh:
    devs = jax.devices()
    n = n_devices or len(devs)
    return jax.make_mesh((n,), ("stripe",), devices=devs[:n])


def _exchange_halo(p_local: jnp.ndarray, axis_name: str):
    """p_local (..., NZ, NXl): returns (left_halo, right_halo) each
    (..., NZ, HALO) received from stripe neighbors (zeros at domain edge).
    """
    idx = jax.lax.axis_index(axis_name)
    n = jax.lax.axis_size(axis_name)
    right_edge = p_local[..., -HALO:]
    left_edge = p_local[..., :HALO]
    # send my right edge to my right neighbor (they receive left halo)
    from_left = jax.lax.ppermute(
        right_edge, axis_name, [(i, (i + 1) % n) for i in range(n)]
    )
    from_right = jax.lax.ppermute(
        left_edge, axis_name, [(i, (i - 1) % n) for i in range(n)]
    )
    zero = jnp.zeros_like(from_left)
    left_halo = jnp.where(idx == 0, zero, from_left)
    right_halo = jnp.where(idx == n - 1, zero, from_right)
    return left_halo, right_halo


def _lap_with_halo(pext: jnp.ndarray, nxl: int) -> jnp.ndarray:
    """pext (..., NZ, NXl + 2*HALO) -> 4th-order laplacian (..., NZ, NXl).

    x-direction uses the halo-extended array; z-direction uses in-stripe
    shifts with zero boundary (stripes span full height)."""
    c = pext[..., HALO: HALO + nxl]

    def shift_z(a, d):
        out = jnp.roll(a, d, axis=-2)
        if d > 0:
            return out.at[..., :d, :].set(0.0)
        return out.at[..., d:, :].set(0.0)

    lap = 2.0 * C0 * c
    lap += C1 * (pext[..., HALO - 1: HALO - 1 + nxl]
                 + pext[..., HALO + 1: HALO + 1 + nxl])
    lap += C2 * (pext[..., HALO - 2: HALO - 2 + nxl]
                 + pext[..., HALO + 2: HALO + 2 + nxl])
    lap += C1 * (shift_z(c, 1) + shift_z(c, -1))
    lap += C2 * (shift_z(c, 2) + shift_z(c, -2))
    return lap


def make_sharded_step(cfg: FWIConfig, mesh: Mesh):
    """Sharded timestep: fields (S, NZ, NX) sharded on x over "stripe"."""
    n = mesh.shape["stripe"]
    assert cfg.nx % n == 0, (cfg.nx, n)
    nxl = cfg.nx // n
    v = velocity_model(cfg)
    v2dt2 = (v * cfg.dt / cfg.dx) ** 2
    sponge = sponge_taper(cfg)
    wavelet = ricker(cfg)
    pos = cfg.shot_positions()
    src_z = jnp.asarray(pos[:, 0])
    src_x = jnp.asarray(pos[:, 1])
    sh = NamedSharding(mesh, P(None, None, "stripe"))
    rep = NamedSharding(mesh, P())

    def local_step(p, p_prev, v2, sp, t):
        # p (S, NZ, NXl) local stripe
        left, right = _exchange_halo(p, "stripe")
        pext = jnp.concatenate([left, p, right], axis=-1)
        lap = _lap_with_halo(pext, p.shape[-1])
        p_next = (2.0 * p - p_prev + v2 * lap) * sp
        p_damped = p * sp
        # source injection: global x position -> local column if owned
        idx = jax.lax.axis_index("stripe")
        x0 = idx * p.shape[-1]
        src = wavelet[t] * (cfg.dt ** 2)

        def inject(pn, zi, xi):
            owned = (xi >= x0) & (xi < x0 + pn.shape[-1])
            xloc = jnp.clip(xi - x0, 0, pn.shape[-1] - 1)
            return pn.at[zi, xloc].add(jnp.where(owned, src, 0.0))

        p_next = jax.vmap(inject)(p_next, src_z, src_x)
        trace = p_next[:, cfg.receiver_depth, :]
        return p_next, p_damped, trace

    step = jax.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(P(None, None, "stripe"), P(None, None, "stripe"),
                  P(None, "stripe"), P(None, "stripe"), P()),
        out_specs=(P(None, None, "stripe"), P(None, None, "stripe"),
                   P(None, "stripe")),
    )

    @jax.jit
    def sharded_step(p, p_prev, t):
        return step(p, p_prev, v2dt2, sponge, t)

    def place(state_fields):
        return jax.device_put(state_fields, sh)

    return sharded_step, place


def halo_bytes_per_step(cfg: FWIConfig, n_stripes: int) -> int:
    """Per-seam traffic — the paper's 21 KB message-size claim analogue."""
    return 2 * HALO * cfg.nz * cfg.n_shots * 4  # send+recv, f32
