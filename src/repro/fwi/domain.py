"""Striped domain decomposition + overlapped, temporally-blocked halo
exchange (paper Fig. 2, communication-avoiding + communication-hiding).

The x-axis (width) is cut into contiguous column stripes, one per device
on a 1-D ("stripe",) mesh; the height is fixed — exactly the paper's
simplification.  The γ-split maps stripes to environments: with the
right γ·(NX/stripes) columns assigned to burst-pod devices, only ONE
stripe seam crosses the slow link (greedy striped placement, §3.3).

Communication avoidance (the paper's "total message size is only 21 KB"
measurement is about per-step seam LATENCY, which dominates over the
slow cluster↔cloud link): instead of a 2-column (HALO) exchange every
timestep, each stripe exchanges a k·HALO-wide halo ONCE and then runs k
timesteps with ZERO communication.  Incorrect values creep inward from
a window edge at HALO cells per step, so after k steps exactly the
owned region is clean — standard overlapping ("ghost-zone") temporal
blocking.  For k > 1 the previous-field edges ride in the SAME message
(stacked), so ppermute invocations per timestep drop k× (2 per block vs
2 per step) while amortized bytes stay flat.

Communication HIDING comes in three schedules (DESIGN.md §13, §15):

* ``"overlap"`` — within one block: the packed exchange is issued
  FIRST; the INTERIOR of the stripe — every column ≥ k·HALO from a
  seam, which by construction never reads the halo within one k-step
  block — is computed as one fused ``wave_block`` while the ppermute
  is in flight; two narrow (3·k·HALO-column) BOUNDARY windows that do
  consume the received halos are computed after and stitched in.
  Per-block cost drops from ``compute + seam`` to
  ``max(interior, seam) + boundary``.
* ``"pipeline"`` — ACROSS scan blocks: the received halos ride in the
  scan carry, each block computes its boundary windows first from the
  CARRIED halos, issues the NEXT block's exchange from their fresh
  edge columns, then computes interior + stitch — so a whole block of
  compute covers each exchange instead of only the interior window
  (one eager prologue exchange; one wasted epilogue exchange).  The
  per-block op graph is the overlap schedule's, reordered: pinned
  BITWISE equal.
* ``"fused"`` — comm-avoiding single window, exchange on the critical
  path, least redundant compute (2·k·HALO columns vs 6·k·HALO for the
  split schedules).

The splits only pay where collectives are async, so ``pick_schedule``
auto-selects per backend (TPU: "pipeline"; synchronous hosts:
"fused"); ``pick_overlap`` is the legacy boolean view.
``halo_exchange_plan`` exports the seam-traffic AND overlap
bookkeeping (``overlap_fraction``) that ``OverheadModel
.with_overlapped_seam``, the ``measure_seam_latency`` probe and the
overhead benches consume.

Physical domain edges need no special-casing: every window is
zero-extended in x, which at a physical edge IS the reference's
zero-halo convention, and at a seam marks the redundant zone that the
trapezoidal shrink discards.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.fwi.solver import FWIConfig, ricker, sponge_taper, velocity_model
from repro.kernels.stencil.ops import wave_block

HALO = 2


def stripe_mesh(n_devices: int | None = None) -> Mesh:
    devs = jax.devices()
    n = n_devices or len(devs)
    return jax.make_mesh((n,), ("stripe",), devices=devs[:n])


def pick_overlap(backend: str | None = None) -> bool:
    """Schedule selection for the sharded block body (DESIGN.md §13).

    The interior/boundary overlap split only pays where collectives are
    ASYNC (TPU: collective-permute-start/done hide behind the interior
    fusion); on hosts whose ppermute is synchronous the split is pure
    overhead — 6·k·HALO redundant columns instead of 2·k·HALO — so the
    comm-avoiding single-window schedule wins.  Same auto-selection
    spirit as the kernel's ``default_interpret``/``pick_bz``.

    Kept as the boolean (PR 3) view of the choice; the full three-way
    schedule selection lives in ``pick_schedule`` (DESIGN.md §15)."""
    return (backend or jax.default_backend()) == "tpu"


def pick_schedule(backend: str | None = None) -> str:
    """Three-way schedule auto-selection for the sharded scan runner.

    * ``"pipeline"`` — double-buffered halo exchange ACROSS scan blocks:
      block b+1's packed ppermute is issued before block b's interior
      compute and seam stitch, so the exchange hides behind a whole
      block of work instead of only the same block's interior window.
      Needs async collectives — selected on TPU.
    * ``"overlap"``  — PR 3's within-block split (exchange first,
      interior while it flies, boundary windows after).
    * ``"fused"``    — comm-avoiding single window, exchange on the
      critical path; least redundant compute, the right choice where
      collectives are synchronous anyway (CPU hosts).

    All three produce BIT-IDENTICAL results on the XLA path — the
    invariance tests pin it — so this is purely a performance choice;
    ``measure_seam_latency`` (fwi/calibrate.py) audits it."""
    return "pipeline" if (backend or jax.default_backend()) == "tpu" \
        else "fused"


def _as_schedule(overlap) -> str:
    """Normalize the legacy bool knob: True -> "overlap", False ->
    "fused"; strings pass through; None -> ``pick_schedule()``."""
    if overlap is None:
        return pick_schedule()
    if isinstance(overlap, str):
        if overlap not in ("fused", "overlap", "pipeline"):
            raise ValueError(f"unknown halo schedule: {overlap!r}")
        return overlap
    return "overlap" if overlap else "fused"


def _exchange_halo(edges_r: jnp.ndarray, edges_l: jnp.ndarray,
                   axis_name: str):
    """One packed bidirectional exchange.  ``edges_r``/``edges_l`` are
    my right/left edge payloads (..., NZ, pad); returns what I receive
    from my left/right neighbors, zeroed at the physical domain edge.
    Exactly TWO ppermutes regardless of how many fields are packed in."""
    idx = jax.lax.axis_index(axis_name)
    n = axis_size(axis_name)
    from_left = jax.lax.ppermute(
        edges_r, axis_name, [(i, (i + 1) % n) for i in range(n)]
    )
    from_right = jax.lax.ppermute(
        edges_l, axis_name, [(i, (i - 1) % n) for i in range(n)]
    )
    zero = jnp.zeros_like(from_left)
    left_halo = jnp.where(idx == 0, zero, from_left)
    right_halo = jnp.where(idx == n - 1, zero, from_right)
    return left_halo, right_halo


def _overlapped_field(arr: np.ndarray, n: int, pad: int) -> jnp.ndarray:
    """(NZ, NX) -> (n, NZ, NXl + 2·pad) per-stripe windows with real
    neighbor values in the overlap and zeros outside the domain."""
    nz, nx = arr.shape
    nxl = nx // n
    a = np.pad(np.asarray(arr), ((0, 0), (pad, pad)))
    return jnp.asarray(np.stack(
        [a[:, i * nxl: i * nxl + nxl + 2 * pad] for i in range(n)]
    ), jnp.float32)


def effective_block(cfg: FWIConfig, n_stripes: int, k: int) -> int:
    """Clamp k so the overlap windows fit inside one stripe: the
    interior/boundary split needs the two 2·k·HALO-column boundary
    source regions to be disjoint, i.e. 2·k·HALO ≤ NX/stripes."""
    nxl = cfg.nx // n_stripes
    return max(1, min(k, nxl // (2 * HALO)))


@functools.lru_cache(maxsize=32)
def _sharded_block_parts(cfg: FWIConfig, mesh: Mesh, k: int,
                         use_pallas: bool, bz: int | None = None,
                         schedule: str = "overlap"):
    """(sms, v2e_all, spe_all, place, k): the UNJITTED shard_map'd
    k-step fused block bodies plus their closure fields — callers jit at
    their own boundary (wrapping the body in its own jit inside a
    lax.scan defeats XLA's loop fusion; see solver.py).  ``sms`` is a
    dict: ``{"block"}`` for the "fused"/"overlap" schedules,
    ``{"prologue", "pipeline"}`` for "pipeline".

    schedule="overlap" realizes the within-block comm/compute-overlap
    schedule (DESIGN.md §13): packed halo ppermute issued first; the
    stripe INTERIOR advanced k fused steps (independent of the
    exchange, overlappable with it); the two 3·k·HALO boundary windows
    — batched into ONE ``wave_block`` call — consume the received halos
    and patch the k·HALO seam-adjacent column strips.
    schedule="fused" is the comm-AVOIDING schedule only: one fused
    window over the whole extended stripe, exchange on the critical
    path (less redundant compute — 2·k·HALO vs 6·k·HALO extra columns —
    for hosts whose collectives are synchronous anyway).
    schedule="pipeline" double-buffers the exchange ACROSS scan blocks
    (DESIGN.md §15): the halos arrive in the scan CARRY, the boundary
    windows run first (their valid columns are the stripe's fresh
    edges), block b+1's packed ppermute is issued from those fresh
    edges BEFORE block b's interior compute and seam stitch, and the
    interior fusion plus stitch fly under it.

    On the XLA path the overlap and pipeline schedules are pinned
    bitwise-identical to the reference (the pipeline computes the same
    per-block graph as overlap — only the exchange's position in the
    schedule moves); the single-window schedule computes the identical
    op sequence but its different fusion shapes may flush denormal
    wavefront tails differently — equal up to sub-normal (< 1.2e-38)
    noise.
    """
    n = mesh.shape["stripe"]
    assert cfg.nx % n == 0, (cfg.nx, n)
    nxl = cfg.nx // n
    k = effective_block(cfg, n, k)
    pad = k * HALO
    v = velocity_model(cfg)
    v2dt2 = (v * cfg.dt / cfg.dx) ** 2
    sponge = sponge_taper(cfg)
    v2e_all = _overlapped_field(np.asarray(v2dt2), n, pad)
    spe_all = _overlapped_field(np.asarray(sponge), n, pad)
    wavelet = ricker(cfg)
    pos = cfg.shot_positions()
    src_z = jnp.asarray(pos[:, 0])
    src_x = jnp.asarray(pos[:, 1])
    sh = NamedSharding(mesh, P(None, None, "stripe"))

    def exchange_edges(p_r, p_l, pp_r, pp_l):
        # ONE packed exchange for the whole k-step block; for k > 1 the
        # p_prev edges ride in the same message (leading stacked axis)
        if k > 1:
            left, right = _exchange_halo(
                jnp.stack([p_r, pp_r]), jnp.stack([p_l, pp_l]), "stripe"
            )
            return left[0], right[0], left[1], right[1]
        lh_p, rh_p = _exchange_halo(p_r, p_l, "stripe")
        # k=1 never reads the p_prev halo (halo outputs are discarded
        # after one step) — zero-extend
        z = jnp.zeros_like(pp_l)
        return lh_p, rh_p, z, z

    def make_srcv(t0):
        return wavelet[
            jnp.clip(t0 + jnp.arange(k), 0, cfg.timesteps - 1)
        ] * (cfg.dt ** 2)

    # --- k fused steps on a window via wave_block -------------------
    def window(px, ppx, vw, sw, wx0, x0, srcv):
        # wx0: local column of window column 0 (traced).  Sources
        # inject into EVERY window covering their column, so redundant
        # zones track true neighbor physics; each window's valid region
        # is stitched disjointly below.  The whole shot batch advances
        # in ONE shot-batched wave_block (3-D dispatch, DESIGN.md §17)
        # with per-shot (S, k) amplitudes masked by window coverage —
        # bitwise-equal to the old vmap-of-per-shot form on the XLA
        # path (wave_block_shots_ref's pinned contract).
        w = px.shape[-1]
        xloc = src_x - x0 - wx0                  # (S,) per-shot column
        covered = (xloc >= 0) & (xloc < w)
        sv = jnp.where(covered[:, None], srcv[None, :], 0.0)
        xc = jnp.clip(xloc, 0, w - 1)
        return wave_block(
            px, ppx, vw, sw, sv, src_z, xc,
            receiver_row=cfg.receiver_depth,
            use_pallas=use_pallas, bz=bz,
        )

    def interior(p, p_prev, v2e, spe, x0, srcv):
        # valid after k steps: columns [pad, nxl-pad) — everything the
        # seams cannot influence within one block
        return window(
            p, p_prev, v2e[:, pad: pad + nxl], spe[:, pad: pad + nxl],
            0, x0, srcv,
        )

    def boundary(p, p_prev, lh_p, rh_p, lh_pp, rh_pp, v2e, spe, x0, srcv):
        # two BOUNDARY windows, batched into ONE call:
        # left covers local [-pad, 2·pad) -> valid [0, pad);
        # right covers [nxl-2·pad, nxl+pad) -> valid [nxl-pad, nxl)
        bp = jnp.stack([
            jnp.concatenate([lh_p, p[..., : 2 * pad]], axis=-1),
            jnp.concatenate([p[..., -2 * pad:], rh_p], axis=-1),
        ])
        bpp = jnp.stack([
            jnp.concatenate([lh_pp, p_prev[..., : 2 * pad]], axis=-1),
            jnp.concatenate([p_prev[..., -2 * pad:], rh_pp], axis=-1),
        ])
        bv = jnp.stack([v2e[:, : 3 * pad], v2e[:, nxl - pad:]])
        bs = jnp.stack([spe[:, : 3 * pad], spe[:, nxl - pad:]])
        wx0s = jnp.array([-pad, nxl - 2 * pad], jnp.int32)
        return jax.vmap(window, in_axes=(0, 0, 0, 0, 0, None, None))(
            bp, bpp, bv, bs, wx0s, x0, srcv
        )

    def stitch(bnd, mid, axis=-1):
        # stitch the disjoint valid regions
        sl = [slice(None)] * (bnd.ndim - 1)
        sl[axis] = slice(pad, 2 * pad)
        mi = [slice(None)] * mid.ndim
        mi[axis] = slice(pad, nxl - pad)
        return jnp.concatenate(
            [bnd[0][tuple(sl)], mid[tuple(mi)], bnd[1][tuple(sl)]],
            axis=axis,
        )

    def local_block(p, p_prev, v2e, spe, t0):
        # p (S, NZ, NXl) local stripe; v2e/spe (1, NZ, NXl + 2·pad)
        v2e, spe = v2e[0], spe[0]
        x0 = jax.lax.axis_index("stripe") * nxl   # global x of column 0
        srcv = make_srcv(t0)

        # 1) packed halo exchange, issued FIRST
        lh_p, rh_p, lh_pp, rh_pp = exchange_edges(
            p[..., -pad:], p[..., :pad],
            p_prev[..., -pad:], p_prev[..., :pad],
        )

        if schedule == "fused":
            # comm-avoiding only: ONE window over the extended stripe
            # [-pad, nxl+pad); its zero-extension creep exactly eats
            # the halos, leaving [0, nxl) valid after k steps
            pe, ppe, tre = window(
                jnp.concatenate([lh_p, p, rh_p], axis=-1),
                jnp.concatenate([lh_pp, p_prev, rh_pp], axis=-1),
                v2e, spe, -pad, x0, srcv,
            )
            sl = (Ellipsis, slice(pad, pad + nxl))
            return pe[sl], ppe[sl], tre[sl]

        # 2) INTERIOR (no halo dependency) while the exchange flies;
        # 3) boundary windows consume the received halos; 4) stitch
        pi, ppi, tri = interior(p, p_prev, v2e, spe, x0, srcv)
        pb, ppb, trb = boundary(
            p, p_prev, lh_p, rh_p, lh_pp, rh_pp, v2e, spe, x0, srcv
        )
        return stitch(pb, pi), stitch(ppb, ppi), stitch(trb, tri)

    def local_prologue(p, p_prev):
        # eager packed exchange priming the pipeline's halo carry for
        # block 0 — the only on-critical-path exchange of the whole scan
        return jnp.stack(exchange_edges(
            p[..., -pad:], p[..., :pad],
            p_prev[..., -pad:], p_prev[..., :pad],
        ))

    def local_pipeline_block(p, p_prev, v2e, spe, t0, halos):
        # halos (4, S, NZ, pad): [lh_p, rh_p, lh_pp, rh_pp] carried from
        # the PREVIOUS block's exchange, already in flight a full block
        v2e, spe = v2e[0], spe[0]
        x0 = jax.lax.axis_index("stripe") * nxl
        srcv = make_srcv(t0)
        lh_p, rh_p, lh_pp, rh_pp = halos[0], halos[1], halos[2], halos[3]

        # 1) BOUNDARY first: its valid columns [pad, 2·pad) are exactly
        # the stripe's fresh edge columns after this block
        pb, ppb, trb = boundary(
            p, p_prev, lh_p, rh_p, lh_pp, rh_pp, v2e, spe, x0, srcv
        )
        # 2) issue block b+1's packed ppermute from those fresh edges —
        # BEFORE the interior compute and the seam stitch, so the
        # exchange hides behind a whole block of work
        nh = exchange_edges(
            pb[1][..., pad: 2 * pad], pb[0][..., pad: 2 * pad],
            ppb[1][..., pad: 2 * pad], ppb[0][..., pad: 2 * pad],
        )
        # 3) interior — the big fusion the in-flight exchange rides over
        pi, ppi, tri = interior(p, p_prev, v2e, spe, x0, srcv)
        # 4) stitch; the fresh halos join the scan carry
        return (stitch(pb, pi), stitch(ppb, ppi), stitch(trb, tri),
                jnp.stack(nh))

    field = P(None, None, "stripe")
    parts = P("stripe", None, None)
    halo_sp = P(None, None, None, "stripe")
    # pallas_call has no replication-checking rule; the bodies are
    # replication-safe by construction (everything is stripe-local)
    sms = {}
    if schedule == "pipeline":
        sms["prologue"] = shard_map(
            local_prologue, mesh=mesh, in_specs=(field, field),
            out_specs=halo_sp, check_vma=False,
        )
        sms["pipeline"] = shard_map(
            local_pipeline_block, mesh=mesh,
            in_specs=(field, field, parts, parts, P(), halo_sp),
            out_specs=(field, field, field, halo_sp), check_vma=False,
        )
    else:
        sms["block"] = shard_map(
            local_block, mesh=mesh,
            in_specs=(field, field, parts, parts, P()),
            out_specs=(field, field, field), check_vma=False,
        )

    def place(state_fields):
        return jax.device_put(state_fields, sh)

    return sms, v2e_all, spe_all, place, k


@functools.lru_cache(maxsize=32)
def make_sharded_multistep(cfg: FWIConfig, mesh: Mesh, *, k: int = 1,
                           use_pallas: bool = False,
                           bz: int | None = None,
                           overlap: bool | str | None = None):
    """Temporally-blocked, comm/compute-overlapped sharded propagator.

    Returns (block_step, place): ``block_step(p, p_prev, t0)`` advances
    ALL k timesteps with a single packed halo exchange and returns
    (p, p_prev, traces) with traces (S, k, NX).  Fields are (S, NZ, NX)
    sharded on x over "stripe".  ``overlap`` takes the legacy bool
    (True="overlap", False="fused") or a schedule name; ``None``
    auto-selects per backend (``pick_schedule``).  The cross-block
    "pipeline" schedule needs a scan to carry halos through, so the
    single-block API maps it to its within-block form, "overlap".

    The requested k may be clamped so the overlap fits in one stripe
    (``effective_block``); callers advancing t0 must use the EFFECTIVE
    block size, exposed as ``block_step.k``.
    """
    schedule = _as_schedule(overlap)
    if schedule == "pipeline":
        schedule = "overlap"
    sms, v2e_all, spe_all, place, k = _sharded_block_parts(
        cfg, mesh, k, use_pallas, bz, schedule
    )
    sm = sms["block"]

    jit_block = jax.jit(
        lambda p, p_prev, t0: sm(p, p_prev, v2e_all, spe_all, t0)
    )

    def block_step(p, p_prev, t0):
        return jit_block(p, p_prev, t0)

    block_step.k = k
    return block_step, place


@functools.lru_cache(maxsize=32)
def make_sharded_step(cfg: FWIConfig, mesh: Mesh, *,
                      use_pallas: bool = False):
    """Single-timestep sharded propagator (k=1 temporal block) — the
    seed-compatible interface: step(p, p_prev, t) -> (p, p_prev, trace)
    with trace (S, NX)."""
    block_step, place = make_sharded_multistep(
        cfg, mesh, k=1, use_pallas=use_pallas
    )

    @jax.jit
    def step(p, p_prev, t):
        pn, pp, tr = block_step(p, p_prev, t)
        return pn, pp, tr[:, 0]

    return step, place


@functools.lru_cache(maxsize=32)
def make_sharded_scan_runner(cfg: FWIConfig, mesh: Mesh, *, k: int = 4,
                             use_pallas: bool = False,
                             bz: int | None = None,
                             overlap: bool | str | None = None):
    """Scan-fused, overlapped, temporally-blocked runner:
    run(p, p_prev, t0, blocks) advances blocks·k timesteps in ONE
    dispatch (a lax.scan over k-step fused blocks, one packed halo
    exchange per block).  ``overlap`` takes the legacy bool or a
    schedule name ("fused"/"overlap"/"pipeline"); ``None`` auto-selects
    per backend (``pick_schedule`` — "pipeline" where collectives are
    async).  Under "pipeline" the halos ride in the scan CARRY: a
    prologue exchange primes block 0, each block issues block b+1's
    ppermute before its own interior compute and stitch, and the last
    block's exchange is discarded (one wasted epilogue message —
    the price of keeping every other exchange a full block ahead).
    Returns (p, p_prev, traces (S, blocks·k, NX))."""
    schedule = _as_schedule(overlap)
    sms, v2e_all, spe_all, place, k = _sharded_block_parts(
        cfg, mesh, k, use_pallas, bz, schedule
    )

    if schedule == "pipeline":
        sm_pro, sm_pipe = sms["prologue"], sms["pipeline"]

        @functools.partial(jax.jit, static_argnames=("blocks",))
        def run(p, p_prev, t0, blocks: int):
            halos = sm_pro(p, p_prev)

            def body(carry, b):
                p, pp, h = carry
                pn, pd, tr, hn = sm_pipe(
                    p, pp, v2e_all, spe_all, t0 + b * k, h
                )
                return (pn, pd, hn), tr

            (p, pp, _), traces = jax.lax.scan(
                body, (p, p_prev, halos), jnp.arange(blocks)
            )
            # (blocks, S, k, NX) -> (S, blocks·k, NX)
            traces = jnp.moveaxis(traces, 0, 1)
            traces = traces.reshape(
                traces.shape[0], -1, traces.shape[-1]
            )
            return p, pp, traces
    else:
        sm = sms["block"]

        @functools.partial(jax.jit, static_argnames=("blocks",))
        def run(p, p_prev, t0, blocks: int):
            def body(carry, b):
                p, pp = carry
                pn, pd, tr = sm(p, pp, v2e_all, spe_all, t0 + b * k)
                return (pn, pd), tr

            (p, pp), traces = jax.lax.scan(
                body, (p, p_prev), jnp.arange(blocks)
            )
            # (blocks, S, k, NX) -> (S, blocks·k, NX)
            traces = jnp.moveaxis(traces, 0, 1)
            traces = traces.reshape(
                traces.shape[0], -1, traces.shape[-1]
            )
            return p, pp, traces

    return run, place, k


def halo_bytes_per_step(cfg: FWIConfig, n_stripes: int, k: int = 1) -> int:
    """Per-seam traffic amortized per timestep — the paper's 21 KB
    message-size claim analogue.  k=1 exchanges only the p edges; k>1
    packs p and p_prev edges into the same (k·HALO-wide) message.
    Delegates to ``halo_exchange_plan`` so the effective-block clamp
    applies here too."""
    return int(halo_exchange_plan(cfg, n_stripes, k)["bytes_per_step"])


def halo_exchange_plan(cfg: FWIConfig, n_stripes: int, k: int = 1) -> dict:
    """Seam-traffic + overlap model for the burst planner / benches.

    Beyond the message bookkeeping, exports the comm/compute-overlap
    shape of the k-step block (DESIGN.md §13): ``overlap_fraction`` is
    the share of the block's column-work that is INDEPENDENT of the
    exchange (the interior window) and can therefore hide the seam —
    ``OverheadModel.with_overlapped_seam`` turns it plus a measured
    ppermute latency into the effective (un-hidden) seam residue.
    ``redundant_frac`` is the extra trapezoid compute the boundary
    windows pay (4·k·HALO of 2·k·HALO patched columns) relative to the
    stripe width."""
    k = effective_block(cfg, n_stripes, k)
    pad = k * HALO
    nxl = cfg.nx // n_stripes
    fields = 1 if k == 1 else 2
    per_exchange = 2 * fields * pad * cfg.nz * cfg.n_shots * 4
    interior_cols = nxl                   # overlappable with the seam
    boundary_cols = 2 * 3 * pad           # two 3·k·HALO windows, after
    return {
        "k": k,
        "steps_per_exchange": k,
        "ppermutes_per_exchange": 2,
        "ppermutes_per_step": 2.0 / k,
        "bytes_per_exchange": per_exchange,
        "bytes_per_step": per_exchange / k,
        "interior_cols": interior_cols,
        "boundary_cols": boundary_cols,
        "overlap_fraction": interior_cols / (interior_cols + boundary_cols),
        "redundant_frac": 4.0 * pad / nxl,
    }
