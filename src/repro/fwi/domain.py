"""Striped domain decomposition + temporally-blocked halo exchange
(paper Fig. 2, communication-avoiding).

The x-axis (width) is cut into contiguous column stripes, one per device
on a 1-D ("stripe",) mesh; the height is fixed — exactly the paper's
simplification.  The γ-split maps stripes to environments: with the
right γ·(NX/stripes) columns assigned to burst-pod devices, only ONE
stripe seam crosses the slow link (greedy striped placement, §3.3).

Communication avoidance (the paper's "total message size is only 21 KB"
measurement is about per-step seam LATENCY, which dominates over the
slow cluster↔cloud link): instead of a 2-column (HALO) exchange every
timestep, each stripe exchanges a k·HALO-wide halo ONCE and then runs k
timesteps with ZERO communication.  Redundant halo cells evolve with
true neighbor physics (the overlapped velocity/sponge fields carry real
neighbor values); incorrect values creep inward from the overlap edge at
HALO cells per step, so after k steps exactly the interior stripe is
clean — standard overlapping ("ghost-zone") temporal blocking.  For
k > 1 the previous-field edges ride in the SAME message (stacked), so
ppermute invocations per timestep drop k× (2 per block vs 2 per step)
while amortized bytes stay flat — the latency win the burst planner
models via ``halo_exchange_plan``.

Physical domain edges need no special-casing: the overlapped sponge is
zero-padded outside the domain, so out-of-domain halo cells multiply to
zero every inner step — identical to the reference's zero-halo
convention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.fwi.solver import FWIConfig, ricker, sponge_taper, velocity_model
from repro.kernels.stencil.ops import wave_step

HALO = 2


def stripe_mesh(n_devices: int | None = None) -> Mesh:
    devs = jax.devices()
    n = n_devices or len(devs)
    return jax.make_mesh((n,), ("stripe",), devices=devs[:n])


def _exchange_halo(edges_r: jnp.ndarray, edges_l: jnp.ndarray,
                   axis_name: str):
    """One packed bidirectional exchange.  ``edges_r``/``edges_l`` are
    my right/left edge payloads (..., NZ, pad); returns what I receive
    from my left/right neighbors, zeroed at the physical domain edge.
    Exactly TWO ppermutes regardless of how many fields are packed in."""
    idx = jax.lax.axis_index(axis_name)
    n = axis_size(axis_name)
    from_left = jax.lax.ppermute(
        edges_r, axis_name, [(i, (i + 1) % n) for i in range(n)]
    )
    from_right = jax.lax.ppermute(
        edges_l, axis_name, [(i, (i - 1) % n) for i in range(n)]
    )
    zero = jnp.zeros_like(from_left)
    left_halo = jnp.where(idx == 0, zero, from_left)
    right_halo = jnp.where(idx == n - 1, zero, from_right)
    return left_halo, right_halo


def _overlapped_field(arr: np.ndarray, n: int, pad: int) -> jnp.ndarray:
    """(NZ, NX) -> (n, NZ, NXl + 2·pad) per-stripe windows with real
    neighbor values in the overlap and zeros outside the domain."""
    nz, nx = arr.shape
    nxl = nx // n
    a = np.pad(np.asarray(arr), ((0, 0), (pad, pad)))
    return jnp.asarray(np.stack(
        [a[:, i * nxl: i * nxl + nxl + 2 * pad] for i in range(n)]
    ), jnp.float32)


def effective_block(cfg: FWIConfig, n_stripes: int, k: int) -> int:
    """Clamp k so the k·HALO overlap fits inside one stripe."""
    nxl = cfg.nx // n_stripes
    return max(1, min(k, nxl // HALO))


@functools.lru_cache(maxsize=32)
def _sharded_block_parts(cfg: FWIConfig, mesh: Mesh, k: int,
                         use_pallas: bool):
    """(sm, v2e_all, spe_all, place, k): the UNJITTED shard_map'd k-step
    body plus its closure fields — callers jit at their own boundary
    (wrapping the body in its own jit inside a lax.scan defeats XLA's
    loop fusion; see solver.py)."""
    n = mesh.shape["stripe"]
    assert cfg.nx % n == 0, (cfg.nx, n)
    nxl = cfg.nx // n
    k = effective_block(cfg, n, k)
    pad = k * HALO
    v = velocity_model(cfg)
    v2dt2 = (v * cfg.dt / cfg.dx) ** 2
    sponge = sponge_taper(cfg)
    v2e_all = _overlapped_field(np.asarray(v2dt2), n, pad)
    spe_all = _overlapped_field(np.asarray(sponge), n, pad)
    wavelet = ricker(cfg)
    pos = cfg.shot_positions()
    src_z = jnp.asarray(pos[:, 0])
    src_x = jnp.asarray(pos[:, 1])
    sh = NamedSharding(mesh, P(None, None, "stripe"))

    def local_block(p, p_prev, v2e, spe, t0):
        # p (S, NZ, NXl) local stripe; v2e/spe (1, NZ, NXl + 2·pad)
        v2e, spe = v2e[0], spe[0]
        idx = jax.lax.axis_index("stripe")
        # ONE exchange for the whole k-step block; for k > 1 the p_prev
        # edges ride in the same message (leading stacked axis)
        if k > 1:
            er = jnp.stack([p[..., -pad:], p_prev[..., -pad:]])
            el = jnp.stack([p[..., :pad], p_prev[..., :pad]])
            left, right = _exchange_halo(er, el, "stripe")
            pe = jnp.concatenate([left[0], p, right[0]], axis=-1)
            ppe = jnp.concatenate([left[1], p_prev, right[1]], axis=-1)
        else:
            left, right = _exchange_halo(
                p[..., -pad:], p[..., :pad], "stripe"
            )
            pe = jnp.concatenate([left, p, right], axis=-1)
            # k=1 never reads the p_prev halo (halo outputs are
            # discarded after one step) — zero-extend
            zl = jnp.zeros_like(p_prev[..., :pad])
            ppe = jnp.concatenate([zl, p_prev, zl], axis=-1)

        x0 = idx * nxl - pad          # global x of extended column 0
        width = nxl + 2 * pad

        if use_pallas:
            # the Pallas kernel is 2-D (NZ, W); map over shots
            step_fields = jax.vmap(
                lambda a, b: wave_step(a, b, v2e, spe, use_pallas=True)
            )
        else:
            def step_fields(a, b):
                return wave_step(a, b, v2e, spe)

        def inject(pn, zi, xi, src):
            owned = (xi >= x0) & (xi < x0 + width)
            xloc = jnp.clip(xi - x0, 0, width - 1)
            return pn.at[zi, xloc].add(jnp.where(owned, src, 0.0))

        traces = []
        for j in range(k):
            pn, pd = step_fields(pe, ppe)
            # sources must land in the halo overlap too, so redundant
            # cells track true neighbor physics
            src = wavelet[jnp.clip(t0 + j, 0, cfg.timesteps - 1)] \
                * (cfg.dt ** 2)
            pn = jax.vmap(inject, in_axes=(0, 0, 0, None))(
                pn, src_z, src_x, src
            )
            traces.append(pn[:, cfg.receiver_depth, pad: pad + nxl])
            pe, ppe = pn, pd
        tr = jnp.stack(traces, axis=1)          # (S, k, NXl)
        return (pe[..., pad: pad + nxl], ppe[..., pad: pad + nxl], tr)

    sm = shard_map(
        local_block,
        mesh=mesh,
        in_specs=(P(None, None, "stripe"), P(None, None, "stripe"),
                  P("stripe", None, None), P("stripe", None, None), P()),
        out_specs=(P(None, None, "stripe"), P(None, None, "stripe"),
                   P(None, None, "stripe")),
        # pallas_call has no replication-checking rule; the body is
        # replication-safe by construction (everything is stripe-local)
        check_vma=False,
    )

    def place(state_fields):
        return jax.device_put(state_fields, sh)

    return sm, v2e_all, spe_all, place, k


@functools.lru_cache(maxsize=32)
def make_sharded_multistep(cfg: FWIConfig, mesh: Mesh, *, k: int = 1,
                           use_pallas: bool = False):
    """Temporally-blocked sharded propagator.

    Returns (block_step, place): ``block_step(p, p_prev, t0)`` advances
    ALL k timesteps with a single packed halo exchange and returns
    (p, p_prev, traces) with traces (S, k, NX).  Fields are (S, NZ, NX)
    sharded on x over "stripe".

    The requested k may be clamped so the overlap fits in one stripe
    (``effective_block``); callers advancing t0 must use the EFFECTIVE
    block size, exposed as ``block_step.k``.
    """
    sm, v2e_all, spe_all, place, k = _sharded_block_parts(
        cfg, mesh, k, use_pallas
    )

    jit_block = jax.jit(
        lambda p, p_prev, t0: sm(p, p_prev, v2e_all, spe_all, t0)
    )

    def block_step(p, p_prev, t0):
        return jit_block(p, p_prev, t0)

    block_step.k = k
    return block_step, place


@functools.lru_cache(maxsize=32)
def make_sharded_step(cfg: FWIConfig, mesh: Mesh, *,
                      use_pallas: bool = False):
    """Single-timestep sharded propagator (k=1 temporal block) — the
    seed-compatible interface: step(p, p_prev, t) -> (p, p_prev, trace)
    with trace (S, NX)."""
    block_step, place = make_sharded_multistep(
        cfg, mesh, k=1, use_pallas=use_pallas
    )

    @jax.jit
    def step(p, p_prev, t):
        pn, pp, tr = block_step(p, p_prev, t)
        return pn, pp, tr[:, 0]

    return step, place


@functools.lru_cache(maxsize=32)
def make_sharded_scan_runner(cfg: FWIConfig, mesh: Mesh, *, k: int = 4,
                             use_pallas: bool = False):
    """Scan-fused temporally-blocked runner: run(p, p_prev, t0, blocks)
    advances blocks·k timesteps in ONE dispatch (a lax.scan over k-step
    blocks, one packed halo exchange per block).  Returns
    (p, p_prev, traces (S, blocks·k, NX))."""
    sm, v2e_all, spe_all, place, k = _sharded_block_parts(
        cfg, mesh, k, use_pallas
    )

    @functools.partial(jax.jit, static_argnames=("blocks",))
    def run(p, p_prev, t0, blocks: int):
        def body(carry, b):
            p, pp = carry
            pn, pd, tr = sm(p, pp, v2e_all, spe_all, t0 + b * k)
            return (pn, pd), tr

        (p, pp), traces = jax.lax.scan(
            body, (p, p_prev), jnp.arange(blocks)
        )
        # (blocks, S, k, NX) -> (S, blocks·k, NX)
        traces = jnp.moveaxis(traces, 0, 1)
        traces = traces.reshape(traces.shape[0], -1, traces.shape[-1])
        return p, pp, traces

    return run, place, k


def halo_bytes_per_step(cfg: FWIConfig, n_stripes: int, k: int = 1) -> int:
    """Per-seam traffic amortized per timestep — the paper's 21 KB
    message-size claim analogue.  k=1 exchanges only the p edges; k>1
    packs p and p_prev edges into the same (k·HALO-wide) message.
    Delegates to ``halo_exchange_plan`` so the effective-block clamp
    applies here too."""
    return int(halo_exchange_plan(cfg, n_stripes, k)["bytes_per_step"])


def halo_exchange_plan(cfg: FWIConfig, n_stripes: int, k: int = 1) -> dict:
    """Seam-traffic model for the burst planner / overhead benches."""
    k = effective_block(cfg, n_stripes, k)
    fields = 1 if k == 1 else 2
    per_exchange = 2 * fields * k * HALO * cfg.nz * cfg.n_shots * 4
    return {
        "k": k,
        "steps_per_exchange": k,
        "ppermutes_per_exchange": 2,
        "ppermutes_per_step": 2.0 / k,
        "bytes_per_exchange": per_exchange,
        "bytes_per_step": per_exchange / k,
    }
